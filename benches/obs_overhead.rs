//! Disabled-tracer overhead bench: proves the observability layer is
//! effectively free when tracing is off (the CI bar is ≤1% of a LeNet-5
//! int8 fast-path frame).
//!
//! A/A timing of the whole executor with and without instrumentation is
//! dominated by run-to-run noise at these scales, so the bound is built
//! deterministically instead: measure the cost of one disabled span guard
//! (one relaxed atomic load, no allocation), multiply by a conservative
//! estimate of guard sites hit per frame, and divide by the measured
//! frame time. Results land in `target/BENCH_obs_overhead.json`
//! (`FLOW_BENCH_OUT` overrides) via the unified [`BenchWriter`].
//!
//! ```sh
//! cargo bench --bench obs_overhead
//! ```

use std::time::Duration;

use tvm_fpga_flow::data;
use tvm_fpga_flow::graph::models;
use tvm_fpga_flow::obs;
use tvm_fpga_flow::quant::{calibrate_analytic, Calibrator, Executor, FastExecutor, QScheme};
use tvm_fpga_flow::texpr::Precision;
use tvm_fpga_flow::util::bench::{bench, BenchWriter, RunMeta};
use tvm_fpga_flow::util::json::Json;
use tvm_fpga_flow::util::scratch::Scratch;

/// The guard-site batch measured per bench iteration. Timer resolution is
/// far coarser than one disabled guard, so each iteration runs a fixed
/// block of them and the per-guard cost is the quotient.
const GUARDS_PER_ITER: u64 = 10_000;

fn main() {
    obs::disable();

    // Cost of one disabled span guard (constructed and dropped).
    let guard = bench(
        "disabled_span_guard_x10k",
        Duration::from_millis(50),
        Duration::from_millis(300),
        100_000,
        || {
            for _ in 0..GUARDS_PER_ITER {
                let _s = obs::span("bench", "probe");
            }
        },
    );
    println!("{}", guard.report());
    let guard_ns = guard.median.as_nanos() as f64 / GUARDS_PER_ITER as f64;

    // Cost of one bare enabled() check, the gate used by counter sites.
    let check = bench(
        "disabled_enabled_check_x10k",
        Duration::from_millis(50),
        Duration::from_millis(300),
        100_000,
        || {
            let mut hits = 0u64;
            for _ in 0..GUARDS_PER_ITER {
                hits += obs::enabled() as u64;
            }
            hits
        },
    );
    println!("{}", check.report());
    let check_ns = check.median.as_nanos() as f64 / GUARDS_PER_ITER as f64;

    // The protected workload: one LeNet-5 int8 fast-path frame.
    let g = models::lenet5();
    let exec = Executor::new(&g);
    let table = calibrate_analytic(&g, Calibrator::Percentile(99.9));
    let batch = data::for_network(&g.name, 16, 42).expect("lenet5 ships a frame generator");
    let mut scratch = Scratch::new();
    let mut fast =
        FastExecutor::quantized(&exec, &table, Precision::Int8, QScheme::PerChannel, true, &mut scratch);
    let mut i = 0usize;
    let frame = bench(
        "lenet5/int8/fast_frame",
        Duration::from_millis(50),
        Duration::from_millis(400),
        100_000,
        || {
            i += 1;
            std::hint::black_box(fast.forward_traced(batch.frame(i % 16)));
        },
    );
    println!("{}", frame.report());
    fast.release(&mut scratch);
    let frame_ns = frame.median.as_nanos() as f64;

    // Guard sites a traced frame would hit if every per-node span existed
    // on the disabled path: one frame span + one per node, doubled for
    // headroom (counter gates, nested helpers).
    let sites = (2 * (g.nodes.len() + 1)) as f64;
    let overhead_ns = sites * guard_ns;
    let overhead_pct = 100.0 * overhead_ns / frame_ns;
    println!(
        "\ndisabled span guard: {guard_ns:.2} ns, enabled() check: {check_ns:.2} ns, \
         frame: {:.2} µs",
        frame_ns / 1_000.0
    );
    println!(
        "estimated disabled-mode overhead: {sites:.0} sites x {guard_ns:.2} ns = \
         {overhead_ns:.0} ns = {overhead_pct:.3}% of a frame (bar: 1%)"
    );

    let mut w = BenchWriter::new(RunMeta::new("obs_overhead").precision("int8"));
    w.stats(&[guard.clone(), check.clone(), frame.clone()]);
    w.insert("disabled_span_guard_ns", Json::Num(guard_ns));
    w.insert("disabled_enabled_check_ns", Json::Num(check_ns));
    w.insert("frame_ns", Json::Num(frame_ns));
    w.insert("guard_sites_per_frame", Json::Num(sites));
    w.insert("overhead_pct", Json::Num(overhead_pct));
    let path = w.write().expect("write bench json");
    println!("wrote {}", path.display());

    assert!(
        overhead_pct <= 1.0,
        "disabled-mode observability overhead {overhead_pct:.3}% exceeds the 1% bar"
    );
}
