//! SLO admission-control acceptance bench: replay a 10x-overload bursty
//! trace against a 2-replica LeNet-5 `SimEngine` fleet and prove the
//! serving properties the admission controller promises:
//!
//! - shed-before-queue: rejected requests record **zero** queue latency
//!   (`queue_samples == completed` in the final snapshot),
//! - the books balance (`completed == submitted` after shutdown),
//! - class-0 (gold) p99 stays inside its SLO while class-2 (bulk)
//!   absorbs ≥ 90% of the shedding.
//!
//! The fleet's real capacity is measured closed-loop first, so the
//! 10x-overload trace is 10x *this machine's* capacity — the bench
//! self-calibrates instead of trusting the modeled FPS against OS sleep
//! granularity. Results go to `target/BENCH_serve.json` (`FLOW_BENCH_OUT`
//! overrides) via the unified [`BenchWriter`].
//!
//! ```sh
//! cargo bench --bench serve_slo
//! ```

use std::time::{Duration, Instant};

use tvm_fpga_flow::coordinator::loadgen::{replay, LoadTrace};
use tvm_fpga_flow::coordinator::{
    EngineSpec, InferenceServer, ServerConfig, SimEngine, SloClass,
};
use tvm_fpga_flow::flow::multi::ReplicaPlan;
use tvm_fpga_flow::graph::models;
use tvm_fpga_flow::util::bench::{BenchWriter, RunMeta, Table};
use tvm_fpga_flow::util::json::Json;

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

const GOLD_SLO_US: u64 = 250_000;

fn fleet(plan: &ReplicaPlan, net: &tvm_fpga_flow::graph::Graph) -> Vec<EngineSpec> {
    // 4x slower than modeled: keeps per-batch sleeps well above OS timer
    // granularity so the measured capacity is stable.
    SimEngine::from_plan(plan, net, 8)
        .expect("engines")
        .into_iter()
        .map(|e| EngineSpec::Sim(e.with_time_scale(0.25)))
        .collect()
}

fn server(
    plan: &ReplicaPlan,
    net: &tvm_fpga_flow::graph::Graph,
    queue_capacity: usize,
) -> InferenceServer {
    InferenceServer::start(ServerConfig {
        replicas: fleet(plan, net),
        max_batch: 8,
        max_wait: Duration::from_micros(500),
        queue_capacity,
        classes: vec![
            SloClass::new("gold", Duration::from_micros(GOLD_SLO_US)),
            SloClass::new("silver", Duration::from_millis(500)),
            SloClass::best_effort("bulk"),
        ],
        ..Default::default()
    })
    .expect("server starts")
}

fn main() {
    let net = models::lenet5();
    let plan = ReplicaPlan::build_cycled(&net, &["stratix10sx"], 2, None).expect("plan compiles");
    let frames: Vec<Vec<f32>> = {
        let data = tvm_fpga_flow::data::for_network("lenet5", 16, 7).expect("lenet5 data");
        (0..data.frames()).map(|i| data.frame(i).to_vec()).collect()
    };

    // Phase 1 — measure what the fleet actually sustains. The probe
    // queue is deep enough that nothing sheds, so elapsed time is pure
    // service time.
    let probe = server(&plan, &net, 1024);
    let warm = 256usize;
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..warm)
        .map(|i| probe.infer_class_async(frames[i % frames.len()].clone(), 2).expect("queue holds"))
        .collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let capacity_rps = warm as f64 / t0.elapsed().as_secs_f64();
    probe.shutdown();
    println!("measured fleet capacity: {capacity_rps:.0} req/s (2x lenet5@stratix10sx)");

    // Phase 2 — a bursty trace offering ~10x that capacity. Gold+silver
    // are 8% of traffic, inside the fleet's 10% serving budget, so the
    // overload must be absorbed by bulk.
    let requests = 2_000usize;
    let burst = 200usize;
    let period_us = ((burst as f64 / (10.0 * capacity_rps)) * 1e6).max(100.0) as u64;
    let trace = LoadTrace::bursty(requests, burst, period_us, &[4, 4, 92], 7);
    let overload = trace.offered_rps() / capacity_rps;
    println!(
        "trace: {requests} requests in bursts of {burst} every {period_us}us — \
         {:.0} rps offered ({overload:.1}x capacity)",
        trace.offered_rps()
    );
    assert!(overload >= 8.0, "trace must overload the fleet ~10x, got {overload:.1}x");

    let srv = server(&plan, &net, 128);
    let mut report = replay(&srv, &trace, &frames);
    report.snapshot = srv.shutdown();

    let mut t = Table::new(
        "per-class outcome under the 10x-overload burst",
        &["class", "deadline", "sent", "ok", "shed", "shed rate", "p99 us"],
    );
    let mut class_rows = Vec::new();
    for (i, c) in report.classes.iter().enumerate() {
        t.row(&[
            format!("{i} {}", c.name),
            c.deadline_us.map(|d| format!("{d}us")).unwrap_or_else(|| "-".into()),
            c.sent.to_string(),
            c.ok.to_string(),
            c.shed_total().to_string(),
            format!("{:.1}%", c.shed_rate() * 100.0),
            c.p99_us.map(|p| p.to_string()).unwrap_or_else(|| "-".into()),
        ]);
        class_rows.push(obj(vec![
            ("class", Json::Num(i as f64)),
            ("name", Json::Str(c.name.clone())),
            ("sent", Json::Num(c.sent as f64)),
            ("ok", Json::Num(c.ok as f64)),
            ("shed", Json::Num(c.shed_total() as f64)),
            ("shed_rate", Json::Num(c.shed_rate())),
            ("p99_us", c.p99_us.map(|p| Json::Num(p as f64)).unwrap_or(Json::Null)),
        ]));
    }
    t.print();

    let snap = &report.snapshot;
    // Books balance: every accepted request was answered exactly once.
    assert_eq!(snap.completed, snap.submitted, "completed != submitted after shutdown");
    // Shed-before-queue: only dispatched requests record queue latency,
    // so rejected traffic contributes zero queue samples.
    assert_eq!(
        snap.queue_samples, snap.completed,
        "shed requests must never record queue latency"
    );

    let shed = report.total_shed();
    assert!(shed > 0, "a 10x overload must shed");
    let bulk_share = report.shed_share(2);
    println!(
        "shed: {shed} total, bulk absorbed {:.1}% (acceptance floor: 90%)",
        bulk_share * 100.0
    );
    assert!(
        bulk_share >= 0.9,
        "bulk must absorb >= 90% of the shedding, got {:.1}%",
        bulk_share * 100.0
    );

    let gold = &report.classes[0];
    assert!(gold.ok > 0, "gold traffic must be served under overload");
    let gold_p99 = gold.p99_us.expect("gold latency recorded");
    println!("gold p99: {gold_p99}us (SLO {GOLD_SLO_US}us)");
    assert!(
        gold_p99 <= GOLD_SLO_US,
        "gold p99 {gold_p99}us blew the {GOLD_SLO_US}us SLO under overload"
    );

    let mut w = BenchWriter::new(RunMeta::new("serve"));
    w.insert("capacity_rps", Json::Num(capacity_rps));
    w.insert("offered_rps", Json::Num(report.offered_rps));
    w.insert("achieved_rps", Json::Num(report.achieved_rps));
    w.insert("overload_factor", Json::Num(overload));
    w.insert("total_shed", Json::Num(shed as f64));
    w.insert("bulk_shed_share", Json::Num(bulk_share));
    w.insert("gold_p99_us", Json::Num(gold_p99 as f64));
    w.insert("gold_slo_us", Json::Num(GOLD_SLO_US as f64));
    w.insert("classes", Json::Arr(class_rows));
    w.insert("completed", Json::Num(snap.completed as f64));
    w.insert("submitted", Json::Num(snap.submitted as f64));
    w.insert("queue_samples", Json::Num(snap.queue_samples as f64));
    let path = w.write().expect("write bench json");
    println!("wrote {}", path.display());
}
