//! Regenerates **Table V**: FPS of the simulated S10SX accelerators
//! against CPU baselines — measured on this host through the PJRT runtime
//! (the XLA-CPU executables are the analog of the paper's optimized
//! TVM-LLVM/TensorFlow CPU builds) — plus the paper's published columns.
//!
//! Requires `make artifacts`.
//!
//! ```sh
//! cargo bench --bench table5_cpu_gpu
//! ```

use std::time::Instant;

use tvm_fpga_flow::data;
use tvm_fpga_flow::flow::{Compiler, OptLevel};
use tvm_fpga_flow::graph::models;
use tvm_fpga_flow::metrics::paper;
use tvm_fpga_flow::runtime::{Impl, Manifest, Runtime};
use tvm_fpga_flow::util::bench::Table;

fn measure_cpu_fps(rt: &Runtime, net: &str, frames: usize) -> f64 {
    // Batch 1 everywhere: the paper's Table V is unbatched inference.
    let batch = 1;
    let model = rt.load(net, Impl::Ref, batch).expect("load ref model");
    let fe = model.frame_elems();
    let data = data::for_network(net, batch.max(frames.min(16)), 0).unwrap();
    // Warmup.
    let chunk: Vec<f32> = data.data[..batch * fe].to_vec();
    model.infer(&rt.client, &chunk).expect("warmup");
    let t0 = Instant::now();
    let mut done = 0usize;
    while done < frames {
        model.infer(&rt.client, &chunk).expect("infer");
        done += batch;
    }
    done as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    if !Manifest::default_dir().join("manifest.json").exists() {
        eprintln!("run `make artifacts` first");
        std::process::exit(1);
    }
    let rt = Runtime::new(Manifest::default_dir()).expect("runtime");
    let flow = Compiler::default();

    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut table = Table::new(
        &format!("Table V — FPS vs CPU/GPU (sim S10SX | measured XLA-CPU @{cores} core(s) | paper row)"),
        &["network", "S10SX (sim)", "XLA-CPU (meas)", "paper S10SX", "TVM-1t", "TVM-56t", "TF", "TF-cuDNN"],
    );

    let mut rows = Vec::new();
    for (name, p_fpga, p_1t, p_56t, p_tf, p_gpu) in paper::TABLE5 {
        let g = models::by_name(name).unwrap();
        let acc = flow.compile(&g, Compiler::paper_mode(name), OptLevel::Optimized).unwrap();
        let fpga = acc.performance.fps;
        let frames = if name == "lenet5" { 512 } else { 4 };
        let cpu = measure_cpu_fps(&rt, name, frames);
        rows.push((name, fpga, cpu));
        table.row(&[
            name.into(),
            format!("{fpga:.2}"),
            format!("{cpu:.2}"),
            format!("{p_fpga:.2}"),
            format!("{p_1t:.1}"),
            format!("{p_56t:.1}"),
            format!("{p_tf:.1}"),
            format!("{p_gpu:.1}"),
        ]);
    }
    table.print();

    // Shape checks mirroring the paper's §V-D conclusions. On this host the
    // measured XLA-CPU column is the few-thread analog of TVM-1t (the
    // sandbox exposes a single core); the many-thread comparison uses the
    // paper's own TVM-56t column.
    // The paper's FPGA beats TVM-1t by 1.94–3.83×. A 2026 core is several
    // times faster than a 2019 Xeon core, so against *this* host's single
    // thread we require "competitive or better" (≥ 0.5×) everywhere and a
    // strict win where the paper's margin was largest relative to the CPU
    // work (MobileNet: depthwise layers parallelize poorly on CPU).
    for (name, fpga, cpu) in &rows {
        let r = fpga / cpu;
        println!("  {name}: sim-FPGA/1t-CPU = {r:.2}x");
        assert!(r > 0.5, "{name}: sim FPGA {fpga} not competitive with 1-thread CPU {cpu}");
    }
    let mobile = &rows[1];
    assert!(mobile.1 > mobile.2, "mobilenet: FPGA must beat the 1-thread CPU");
    let mobilenet = &rows[1];
    let resnet = &rows[2];
    assert!(mobilenet.1 < paper::TABLE5[1].3, "MobileNet: FPGA must lose to the 56-thread CPU");
    assert!(resnet.1 < paper::TABLE5[2].3, "ResNet: FPGA must lose to the 56-thread CPU");
    println!(
        "shape check: FPGA competitive-or-better vs this host's 1-thread CPU,\n\
         loses to the 56-thread column on MobileNet/ResNet (as in §V-D) ✓"
    );
    println!(
        "note: measured on {cores} host core(s) through XLA:CPU — the optimized-\n\
         CPU-framework analog; the paper's absolute numbers are a dual Xeon 8280."
    );
}
