//! Regenerates **§V-E**: the comparisons with Caffeinated FPGAs
//! (DiCecco et al.), TensorFlow-to-Cloud-FPGAs (Hadjis et al.) and
//! DNNWeaver (via Venieris et al.) in the paper's GFLOPS terms.
//!
//! ```sh
//! cargo bench --bench sec5e_related_work
//! ```

use tvm_fpga_flow::flow::{Compiler, OptLevel};
use tvm_fpga_flow::graph::models;
use tvm_fpga_flow::metrics::paper;
use tvm_fpga_flow::util::bench::Table;

fn main() {
    let flow = Compiler::default();

    // --- DiCecco: 3×3-conv GFLOPS of ResNet-34 ---------------------------
    let resnet = models::resnet34();
    let acc = flow.compile(&resnet, Compiler::paper_mode("resnet34"), OptLevel::Optimized).unwrap();
    let ours_3x3 = acc.performance.fps * resnet.flops_3x3_conv() as f64 / 1e9;

    // --- Hadjis: LeNet-5 GFLOPS (normalized to FP-op count) --------------
    let lenet = models::lenet5();
    let lacc = flow.compile(&lenet, Compiler::paper_mode("lenet5"), OptLevel::Optimized).unwrap();
    // The paper normalizes with its 389K FP-op count (§V-E).
    let ours_lenet = lacc.performance.fps * paper::SEC5E_LENET_FLOPS / 1e9;

    // --- DNNWeaver: their AlexNet vs our MobileNetV1 ----------------------
    let mobilenet = models::mobilenet_v1();
    let macc = flow.compile(&mobilenet, Compiler::paper_mode("mobilenet_v1"), OptLevel::Optimized).unwrap();
    let ours_mobile_gflops = macc.performance.fps * paper::SEC5E_MOBILENET_FLOPS / 1e9;
    // Venieris et al. report DNNWeaver AlexNet at 9.22× the paper's
    // MobileNet GFLOPS: reconstruct their absolute number from the paper.
    let paper_mobile_gflops = paper::TABLE5[1].1 * paper::SEC5E_MOBILENET_FLOPS / 1e9;
    let dnnweaver_gflops = paper_mobile_gflops * paper::SEC5E_DNNWEAVER_SPEEDUP;

    let mut t = Table::new(
        "§V-E — comparison to existing work (GFLOPS)",
        &["comparison", "ours", "theirs", "ratio", "paper's ratio"],
    );
    t.row(&[
        "DiCecco 3x3 Winograd vs our ResNet-34 3x3".into(),
        format!("{ours_3x3:.1}"),
        format!("{:.1}", paper::SEC5E_DICECCO_GFLOPS),
        format!("{:.2}x", ours_3x3 / paper::SEC5E_DICECCO_GFLOPS),
        "1.40x (70.4 vs 50)".into(),
    ]);
    t.row(&[
        "Hadjis LeNet-5 (normalized) vs ours".into(),
        format!("{ours_lenet:.2}"),
        format!("{:.2}", paper::SEC5E_HADJIS_GFLOPS_NORM),
        format!("{:.2}x", ours_lenet / paper::SEC5E_HADJIS_GFLOPS_NORM),
        "3.23x (1.91 vs 0.59)".into(),
    ]);
    t.row(&[
        "DNNWeaver AlexNet vs our MobileNetV1".into(),
        format!("{ours_mobile_gflops:.2}"),
        format!("{dnnweaver_gflops:.2}"),
        format!("{:.2}x slower", dnnweaver_gflops / ours_mobile_gflops),
        "9.22x slower".into(),
    ]);
    t.print();

    // Shape: we beat the HLS approaches, lose to hand-optimized RTL.
    assert!(ours_lenet / paper::SEC5E_HADJIS_GFLOPS_NORM > 1.0, "must beat Hadjis per §V-E");
    assert!(dnnweaver_gflops / ours_mobile_gflops > 1.0, "DNNWeaver must win per §V-E");
    println!("shape check: beats HLS flows, loses to hand-optimized RTL templates ✓");
}
