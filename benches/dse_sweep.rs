//! §IV-J factor-selection sweep (the paper's future-work DSE): evaluate
//! tile candidates under the three legality rules and time the explorer.
//! Everything measured is recorded to `target/BENCH_dse.json`
//! (`FLOW_BENCH_OUT` overrides) via the unified [`BenchWriter`].
//!
//! ```sh
//! cargo bench --bench dse_sweep
//! ```

use tvm_fpga_flow::dse;
use tvm_fpga_flow::flow::{Compiler, Mode, OptLevel};
use tvm_fpga_flow::graph::models;
use tvm_fpga_flow::util::bench::{bench, BenchWriter, RunMeta, Table};
use tvm_fpga_flow::util::json::Json;

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn main() {
    let mut w = BenchWriter::new(RunMeta::new("dse").target("stratix10sx"));
    let mut rows_json = Vec::new();
    let mut t = Table::new(
        "DSE outcomes per network",
        &["network", "points", "rejected", "cache hit%", "default FPS", "best FPS", "gain"],
    );
    for name in ["lenet5", "mobilenet_v1", "resnet34"] {
        let g = models::by_name(name).unwrap();
        let mode = Compiler::paper_mode(name);
        let default_fps =
            Compiler::default().compile(&g, mode, OptLevel::Optimized).unwrap().performance.fps;
        // Fresh compiler per sweep: the hit% column must reflect the
        // sweep's own duplicates, not a memo pre-warmed by other rows.
        let sweep = Compiler::default();
        let r = match mode {
            Mode::Folded => dse::explore_folded(&sweep, &g, 16),
            Mode::Pipelined => dse::explore_pipelined(&sweep, &g),
        };
        let best = r.best.as_ref().map(|b| b.fps).unwrap_or(0.0);
        let rejected = r.log.iter().filter(|p| p.rejected.is_some()).count();
        rows_json.push(obj(vec![
            ("network", Json::Str(name.to_string())),
            ("points", Json::Num(r.evaluated as f64)),
            ("rejected", Json::Num(rejected as f64)),
            ("cache_hit_rate", Json::Num(r.synth_cache_hit_rate())),
            ("default_fps", Json::Num(default_fps)),
            ("best_fps", Json::Num(best)),
            ("gain", Json::Num(best / default_fps)),
        ]));
        t.row(&[
            name.into(),
            r.evaluated.to_string(),
            rejected.to_string(),
            format!("{:.0}", r.synth_cache_hit_rate() * 100.0),
            format!("{default_fps:.2}"),
            format!("{best:.2}"),
            format!("{:.2}x", best / default_fps),
        ]);
    }
    t.print();

    let g = models::mobilenet_v1();
    // Cold compiler per iteration so the timing covers real synthesis, not
    // memo lookups against a cache warmed by earlier sweeps.
    let stats = bench(
        "dse/explore_folded/mobilenet(budget=8,cold)",
        std::time::Duration::from_millis(100),
        std::time::Duration::from_secs(2),
        1_000,
        || {
            let cold = Compiler::default();
            dse::explore_folded(&cold, &g, 8)
        },
    );
    println!("{}", stats.report());
    let shared = Compiler::default();
    let _ = dse::explore_folded(&shared, &g, 8);
    let warm = dse::explore_folded(&shared, &g, 8);
    println!(
        "warm re-sweep: {:.0}% synthesis cache hit rate ({} hits / {} misses)",
        warm.synth_cache_hit_rate() * 100.0,
        warm.synth_cache.hits,
        warm.synth_cache.misses
    );
    println!("(each point replaces a 3–12 h Quartus run in the paper's manual sweep)");

    // The pipeline-partition cut search reuses the same synthesis memo:
    // time it and record what the cost model chose.
    let link = tvm_fpga_flow::flow::multi::Link::default();
    let resnet = models::resnet34();
    let part = dse::explore_partitions(&resnet, &["stratix10sx", "stratix10sx"], &link)
        .expect("partition search runs");
    let best = part.best.as_ref().expect("a 2-stage resnet34 partition exists");
    println!(
        "partition search: resnet34 on 2x stratix10sx → cuts {:?}, {:.2} FPS, {} evaluated",
        best.cuts, best.fps, part.evaluated
    );
    let part_stats = bench(
        "dse/explore_partitions/resnet34(2dev,cold)",
        std::time::Duration::from_millis(100),
        std::time::Duration::from_secs(2),
        1_000,
        || dse::explore_partitions(&resnet, &["stratix10sx", "stratix10sx"], &link).unwrap(),
    );
    println!("{}", part_stats.report());

    w.insert("sweeps", Json::Arr(rows_json));
    w.insert(
        "warm_cache_hit_rate",
        Json::Num(warm.synth_cache_hit_rate()),
    );
    w.insert(
        "partition_search",
        obj(vec![
            ("network", Json::Str("resnet34".to_string())),
            ("devices", Json::Num(2.0)),
            ("cuts", Json::Arr(best.cuts.iter().map(|&c| Json::Num(c as f64)).collect())),
            ("fps", Json::Num(best.fps)),
            ("evaluated", Json::Num(part.evaluated as f64)),
        ]),
    );
    w.stats(&[stats, part_stats]);
    let path = w.write().expect("write bench json");
    println!("wrote {}", path.display());
}
