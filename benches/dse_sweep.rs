//! §IV-J factor-selection sweep (the paper's future-work DSE): evaluate
//! tile candidates under the three legality rules and time the explorer.
//!
//! ```sh
//! cargo bench --bench dse_sweep
//! ```

use tvm_fpga_flow::dse;
use tvm_fpga_flow::flow::{Compiler, Mode, OptLevel};
use tvm_fpga_flow::graph::models;
use tvm_fpga_flow::util::bench::{bench, Table};

fn main() {
    let mut t = Table::new(
        "DSE outcomes per network",
        &["network", "points", "rejected", "cache hit%", "default FPS", "best FPS", "gain"],
    );
    for name in ["lenet5", "mobilenet_v1", "resnet34"] {
        let g = models::by_name(name).unwrap();
        let mode = Compiler::paper_mode(name);
        let default_fps =
            Compiler::default().compile(&g, mode, OptLevel::Optimized).unwrap().performance.fps;
        // Fresh compiler per sweep: the hit% column must reflect the
        // sweep's own duplicates, not a memo pre-warmed by other rows.
        let sweep = Compiler::default();
        let r = match mode {
            Mode::Folded => dse::explore_folded(&sweep, &g, 16),
            Mode::Pipelined => dse::explore_pipelined(&sweep, &g),
        };
        let best = r.best.as_ref().map(|b| b.fps).unwrap_or(0.0);
        t.row(&[
            name.into(),
            r.evaluated.to_string(),
            r.log.iter().filter(|p| p.rejected.is_some()).count().to_string(),
            format!("{:.0}", r.synth_cache_hit_rate() * 100.0),
            format!("{default_fps:.2}"),
            format!("{best:.2}"),
            format!("{:.2}x", best / default_fps),
        ]);
    }
    t.print();

    let g = models::mobilenet_v1();
    // Cold compiler per iteration so the timing covers real synthesis, not
    // memo lookups against a cache warmed by earlier sweeps.
    let stats = bench(
        "dse/explore_folded/mobilenet(budget=8,cold)",
        std::time::Duration::from_millis(100),
        std::time::Duration::from_secs(2),
        1_000,
        || {
            let cold = Compiler::default();
            dse::explore_folded(&cold, &g, 8)
        },
    );
    println!("{}", stats.report());
    let shared = Compiler::default();
    let _ = dse::explore_folded(&shared, &g, 8);
    let warm = dse::explore_folded(&shared, &g, 8);
    println!(
        "warm re-sweep: {:.0}% synthesis cache hit rate ({} hits / {} misses)",
        warm.synth_cache_hit_rate() * 100.0,
        warm.synth_cache.hits,
        warm.synth_cache.misses
    );
    println!("(each point replaces a 3–12 h Quartus run in the paper's manual sweep)");
}
