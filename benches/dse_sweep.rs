//! §IV-J factor-selection sweep (the paper's future-work DSE): evaluate
//! tile candidates under the three legality rules and time the explorer.
//!
//! ```sh
//! cargo bench --bench dse_sweep
//! ```

use tvm_fpga_flow::dse;
use tvm_fpga_flow::flow::{Flow, OptLevel};
use tvm_fpga_flow::graph::models;
use tvm_fpga_flow::util::bench::{bench, Table};

fn main() {
    let flow = Flow::new();

    let mut t = Table::new(
        "DSE outcomes per network",
        &["network", "points", "rejected", "default FPS", "best FPS", "gain"],
    );
    for name in ["lenet5", "mobilenet_v1", "resnet34"] {
        let g = models::by_name(name).unwrap();
        let mode = Flow::paper_mode(name);
        let default_fps = flow.compile(&g, mode, OptLevel::Optimized).unwrap().performance.fps;
        let r = match mode {
            tvm_fpga_flow::flow::Mode::Folded => dse::explore_folded(&flow, &g, 16),
            tvm_fpga_flow::flow::Mode::Pipelined => dse::explore_pipelined(&flow, &g),
        };
        let best = r.best.as_ref().map(|b| b.fps).unwrap_or(0.0);
        t.row(&[
            name.into(),
            r.evaluated.to_string(),
            r.log.iter().filter(|p| p.rejected.is_some()).count().to_string(),
            format!("{default_fps:.2}"),
            format!("{best:.2}"),
            format!("{:.2}x", best / default_fps),
        ]);
    }
    t.print();

    let g = models::mobilenet_v1();
    let stats = bench(
        "dse/explore_folded/mobilenet(budget=8)",
        std::time::Duration::from_millis(100),
        std::time::Duration::from_secs(2),
        1_000,
        || dse::explore_folded(&flow, &g, 8),
    );
    println!("{}", stats.report());
    println!("(each point replaces a 3–12 h Quartus run in the paper's manual sweep)");
}
