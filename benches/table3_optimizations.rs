//! Regenerates **Table III**: which optimizations the flow applies per
//! network (pattern-based application, Table I), checked against the paper.
//!
//! ```sh
//! cargo bench --bench table3_optimizations
//! ```

use tvm_fpga_flow::flow::{Compiler, OptLevel};
use tvm_fpga_flow::graph::models;
use tvm_fpga_flow::metrics::paper;
use tvm_fpga_flow::schedule::OptKind;
use tvm_fpga_flow::util::bench::{quick, Table};

fn main() {
    let flow = Compiler::default();
    let mut table = Table::new(
        "Table III — applied optimizations (✓ = ours, ● = paper)",
        &["network", "PK", "LU", "LT", "LF", "CW", "OF", "CH", "AR", "CE"],
    );

    let mut mismatches = 0;
    for (name, expected) in paper::TABLE3 {
        let g = models::by_name(name).unwrap();
        let acc = flow.compile(&g, Compiler::paper_mode(name), OptLevel::Optimized).expect("compiles");
        let mut row = vec![name.to_string()];
        for opt in OptKind::table_order() {
            let ours = acc.applied.contains(&opt);
            let theirs = expected.contains(&opt.abbrev());
            if ours != theirs {
                mismatches += 1;
            }
            row.push(match (ours, theirs) {
                (true, true) => "✓●".into(),
                (true, false) => "✓ ".into(),
                (false, true) => " ●".into(),
                (false, false) => "  ".into(),
            });
        }
        table.row(&row);
    }
    table.print();
    println!("cells disagreeing with the paper: {mismatches} / 27");
    assert_eq!(mismatches, 0, "Table III must match the paper exactly");

    let g = models::mobilenet_v1();
    let stats = quick("pattern_application/mobilenet_v1", || {
        tvm_fpga_flow::flow::patterns::build_folded(
            &g,
            &tvm_fpga_flow::flow::OptConfig::optimized(),
            &tvm_fpga_flow::flow::default_factors(&g),
        )
    });
    println!("{}", stats.report());
}
