//! Ablation over the §V-F / DESIGN.md design choices: drop one Table-I
//! optimization at a time from each network's optimized configuration and
//! report the FPS (and resource) impact — quantifying each optimization's
//! individual contribution, which the paper only reports in aggregate.
//!
//! ```sh
//! cargo bench --bench ablation_opts
//! ```

use tvm_fpga_flow::flow::{default_factors, Compiler, Mode, OptConfig, OptLevel};
use tvm_fpga_flow::graph::models;
use tvm_fpga_flow::schedule::OptKind;
use tvm_fpga_flow::util::bench::Table;

fn main() {
    let flow = Compiler::default();
    for name in ["lenet5", "mobilenet_v1", "resnet34"] {
        let g = models::by_name(name).unwrap();
        let mode = Compiler::paper_mode(name);
        let full = flow.compile(&g, mode, OptLevel::Optimized).unwrap();
        let full_fps = full.performance.fps;

        let mut t = Table::new(
            &format!("ablation — {name} ({}, full = {full_fps:.2} FPS)", mode.name()),
            &["dropped", "FPS", "x vs full", "fmax", "logic%", "note"],
        );
        let candidates: &[OptKind] = match mode {
            Mode::Pipelined => &[
                OptKind::Unroll,
                OptKind::Fuse,
                OptKind::CachedWrite,
                OptKind::FloatOpt,
                OptKind::Channels,
                OptKind::Autorun,
                OptKind::Concurrent,
            ],
            Mode::Folded => &[
                OptKind::Parameterize,
                OptKind::Unroll,
                OptKind::Tile,
                OptKind::Fuse,
                OptKind::CachedWrite,
                OptKind::FloatOpt,
            ],
        };
        for &opt in candidates {
            let cfg = OptConfig::optimized().without(opt);
            match flow.compile_with(&g, mode, &cfg, &default_factors(&g)) {
                Ok(acc) => {
                    let fps = acc.performance.fps;
                    t.row(&[
                        opt.abbrev().into(),
                        format!("{fps:.2}"),
                        format!("{:.2}x", fps / full_fps),
                        format!("{:.0}", acc.synthesis.fmax_mhz),
                        format!("{:.0}", acc.synthesis.resources.utilization.logic_frac * 100.0),
                        String::new(),
                    ]);
                }
                Err(_) => {
                    t.row(&[
                        opt.abbrev().into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "does not synthesize".into(),
                    ]);
                }
            }
        }
        t.print();
    }
    println!(
        "Reading: dropping LU/LT costs the most compute throughput; dropping CW \
         re-introduces global read-modify-write accumulation; dropping PK on the \
         folded nets recreates the paper's 'may not synthesize' failure mode."
    );
}
