//! Regenerates **Table IV**: FPS of base (TVM-default) versus optimized
//! circuits and the speedup, vs the paper. The headline claim ("up to
//! 846× for ResNet-34") is asserted in order-of-magnitude form.
//!
//! ```sh
//! cargo bench --bench table4_base_vs_opt
//! ```

use tvm_fpga_flow::flow::{Compiler, OptLevel};
use tvm_fpga_flow::graph::models;
use tvm_fpga_flow::metrics::paper;
use tvm_fpga_flow::util::bench::{quick, Table};

fn main() {
    let flow = Compiler::default();
    let mut table = Table::new(
        "Table IV — FPS of base versus optimized circuits (ours | paper)",
        &["network", "base", "optimized", "speedup"],
    );

    let mut speedups = Vec::new();
    for (name, pb, po, ps) in paper::TABLE4 {
        let g = models::by_name(name).unwrap();
        let mode = Compiler::paper_mode(name);
        let base = flow.compile(&g, mode, OptLevel::Base).expect("base compiles");
        let opt = flow.compile(&g, mode, OptLevel::Optimized).expect("opt compiles");
        let s = opt.performance.fps / base.performance.fps;
        speedups.push((name, s, ps));
        table.row(&[
            name.into(),
            format!("{:.4} | {pb:.4}", base.performance.fps),
            format!("{:.2} | {po:.2}", opt.performance.fps),
            format!("{s:.1}x | {ps:.1}x"),
        ]);
    }
    table.print();

    // Shape assertions: same ordering and order of magnitude as the paper.
    for (name, ours, theirs) in &speedups {
        let ratio = ours / theirs;
        assert!(
            (0.2..5.0).contains(&ratio),
            "{name}: speedup {ours:.1}x vs paper {theirs:.1}x out of shape"
        );
    }
    assert!(speedups[0].1 < speedups[1].1 && speedups[1].1 < speedups[2].1,
        "speedup must grow with network size as in the paper");
    println!("shape check: speedups ordered lenet < mobilenet < resnet, each within 5x of paper ✓");

    let g = models::resnet34();
    let stats = quick("compile_base+opt/resnet34", || {
        let b = flow.compile(&g, Compiler::paper_mode("resnet34"), OptLevel::Base).unwrap();
        let o = flow.compile(&g, Compiler::paper_mode("resnet34"), OptLevel::Optimized).unwrap();
        (b.performance.fps, o.performance.fps)
    });
    println!("{}", stats.report());
}
