//! L3 hot-path micro-benchmarks: PJRT execute latency for the matmul
//! micro-kernels and the LeNet-5 executables, plus coordinator dispatch
//! overhead. This is the §Perf profiling entry point for the rust layer.
//!
//! Requires `make artifacts`.
//!
//! ```sh
//! cargo bench --bench runtime_hot_path
//! ```

use std::time::Duration;

use tvm_fpga_flow::coordinator::{InferenceServer, ServerConfig};
use tvm_fpga_flow::data;
use tvm_fpga_flow::runtime::{Impl, Manifest, Runtime};
use tvm_fpga_flow::util::bench::{bench, quick};

fn main() {
    if !Manifest::default_dir().join("manifest.json").exists() {
        eprintln!("run `make artifacts` first");
        std::process::exit(1);
    }
    if !tvm_fpga_flow::runtime::backend_available() {
        eprintln!("PJRT backend unavailable (stubbed xla bindings); see rust/src/runtime/xla.rs");
        std::process::exit(1);
    }
    let rt = Runtime::new(Manifest::default_dir()).expect("runtime");

    // --- matmul micro-kernels (the L1 hot-spot, via the full AOT path) ---
    for (m, k, n) in [(256, 256, 256), (512, 512, 512), (1024, 1024, 128)] {
        let exe = rt.load_matmul(m, k, n).expect("matmul exe");
        let a: Vec<f32> = (0..m * k).map(|i| (i % 13) as f32 * 0.1).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i % 7) as f32 * 0.1).collect();
        let abuf = rt.client.buffer_from_host_buffer(&a, &[m, k], None).unwrap();
        let bbuf = rt.client.buffer_from_host_buffer(&b, &[k, n], None).unwrap();
        let stats = quick(&format!("pjrt/matmul_{m}x{k}x{n}"), || {
            exe.execute_b(&[&abuf, &bbuf]).expect("exec")
        });
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        println!(
            "{}  ({:.2} GFLOP/s at median)",
            stats.report(),
            flops / stats.median.as_secs_f64() / 1e9
        );
    }

    // --- LeNet end-to-end executables ------------------------------------
    let b1 = rt.load("lenet5", Impl::Ref, 1).unwrap();
    let b16 = rt.load("lenet5", Impl::Ref, 16).unwrap();
    let pallas1 = rt.load("lenet5", Impl::Pallas, 1).unwrap();
    let frames = data::mnist_like(16, 32, 1);

    let f1 = frames.frame(0).to_vec();
    // §Perf before/after: naive literal path (weights re-marshalled every
    // call) vs pre-transferred device buffers.
    let before = quick("pjrt/lenet5_ref_b1 (literals, before)", || {
        b1.infer_via_literals(&f1).unwrap()
    });
    println!("{}", before.report());
    let stats = quick("pjrt/lenet5_ref_b1 (buffers, after)", || b1.infer(&rt.client, &f1).unwrap());
    println!(
        "{}  (speedup over literal path: {:.2}x)",
        stats.report(),
        before.median.as_secs_f64() / stats.median.as_secs_f64()
    );
    let stats = quick("pjrt/lenet5_pallas_b1", || pallas1.infer(&rt.client, &f1).unwrap());
    println!("{}", stats.report());
    let all = frames.data.clone();
    let stats = quick("pjrt/lenet5_ref_b16", || b16.infer(&rt.client, &all).unwrap());
    println!(
        "{}  ({:.0} frames/s at median)",
        stats.report(),
        16.0 / stats.median.as_secs_f64()
    );

    // --- coordinator dispatch overhead ------------------------------------
    let server = InferenceServer::start(ServerConfig {
        workers: 2,
        max_wait: Duration::from_micros(200),
        ..Default::default()
    })
    .unwrap();
    let stats = bench(
        "coordinator/infer_roundtrip",
        Duration::from_millis(100),
        Duration::from_secs(1),
        100_000,
        || server.infer(f1.clone()).unwrap(),
    );
    println!("{}", stats.report());
    let snap = server.shutdown();
    println!(
        "coordinator: {} completed, p50 {}µs p99 {}µs",
        snap.completed,
        snap.p50_us.unwrap_or(0),
        snap.p99_us.unwrap_or(0)
    );
}
