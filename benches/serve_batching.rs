//! Serving-throughput bench: the dynamic batcher vs frame-at-a-time
//! dispatch on the *same* simulated accelerator, plus a heterogeneous
//! replica-scaling sweep. Runs without artifacts (engines are modeled).
//!
//! ```sh
//! cargo bench --bench serve_batching
//! ```
//!
//! Acceptance: with `max_batch = 8` the batcher must reach ≥ 4× the
//! frames/sec of the `max_batch = 1` server (the §IV-F amortization,
//! measured at the serving layer). Everything measured is recorded to
//! `target/BENCH_serve.json` (`FLOW_BENCH_OUT` overrides) via the
//! unified [`BenchWriter`].

use std::time::{Duration, Instant};

use tvm_fpga_flow::coordinator::{EngineSpec, InferenceServer, ServerConfig, SimEngine};
use tvm_fpga_flow::flow::multi::ReplicaPlan;
use tvm_fpga_flow::graph::models;
use tvm_fpga_flow::util::bench::{BenchWriter, RunMeta, Table};
use tvm_fpga_flow::util::json::Json;

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

const FRAME_ELEMS: usize = 16;
const CLASSES: usize = 10;

fn run(replicas: Vec<EngineSpec>, max_batch: usize, requests: usize) -> (f64, String, f64) {
    let server = InferenceServer::start(ServerConfig {
        replicas,
        max_batch,
        max_wait: Duration::from_millis(2),
        ..Default::default()
    })
    .expect("server starts");
    let data = tvm_fpga_flow::data::mnist_like(requests, 4, 7);
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..requests)
        .map(|i| server.infer_async(data.frame(i).to_vec()).expect("queue sized for burst"))
        .collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let dt = t0.elapsed().as_secs_f64();
    let stats = server.shutdown();
    assert_eq!(stats.completed, requests as u64);
    let occ = stats.replicas.iter().map(|r| r.occupancy).fold(0.0f64, f64::max);
    (requests as f64 / dt, stats.batch_hist_render(), occ)
}

fn main() {
    // One modeled accelerator: 2 ms dispatch overhead (host round-trip +
    // kernel launch), 50 µs per frame once the pipeline is primed.
    let accel = SimEngine::new(
        "bench-accel",
        FRAME_ELEMS,
        CLASSES,
        8,
        Duration::from_millis(2),
        Duration::from_micros(50),
    );
    let requests = 256;

    let mut t = Table::new(
        "dynamic batching on one simulated accelerator (256 requests)",
        &["max_batch", "req/s", "batch histogram", "peak occupancy"],
    );
    let mut fps_by_batch = Vec::new();
    let mut batching_rows = Vec::new();
    for max_batch in [1usize, 2, 4, 8] {
        let (fps, hist, occ) =
            run(vec![EngineSpec::Sim(accel.clone())], max_batch, requests);
        fps_by_batch.push((max_batch, fps));
        batching_rows.push(obj(vec![
            ("max_batch", Json::Num(max_batch as f64)),
            ("req_per_s", Json::Num(fps)),
            ("peak_occupancy", Json::Num(occ)),
        ]));
        t.row(&[
            max_batch.to_string(),
            format!("{fps:.0}"),
            hist,
            format!("{:.0}%", occ * 100.0),
        ]);
    }
    t.print();

    let fps1 = fps_by_batch[0].1;
    let fps8 = fps_by_batch.last().unwrap().1;
    let speedup = fps8 / fps1;
    println!(
        "max_batch=8 vs max_batch=1: {speedup:.2}x frames/sec (acceptance floor: 4x)"
    );
    assert!(
        speedup >= 4.0,
        "dynamic batcher below the 4x acceptance floor: {speedup:.2}x"
    );

    // Replica scaling with a heterogeneous fleet compiled through the
    // staged flow (weights ∝ modeled FPS per target).
    let net = models::lenet5();
    let mut t = Table::new(
        "replica scaling — lenet5, sim engines from the staged flow (256 requests)",
        &["replicas", "targets", "req/s", "peak occupancy"],
    );
    let mut replica_rows = Vec::new();
    for targets in [
        vec!["stratix10sx"],
        vec!["stratix10sx", "arria10gx"],
        vec!["stratix10sx", "arria10gx", "agilex7"],
    ] {
        let plan = ReplicaPlan::build(&net, &targets).expect("plan compiles");
        let engines = SimEngine::from_plan(&plan, &net, 8).expect("engines");
        let specs: Vec<EngineSpec> = engines
            .into_iter()
            .map(|e| EngineSpec::Sim(e.with_time_scale(10.0)))
            .collect();
        let n = specs.len();
        let (fps, _, occ) = run(specs, 8, requests);
        replica_rows.push(obj(vec![
            ("replicas", Json::Num(n as f64)),
            ("targets", Json::Str(targets.join(","))),
            ("req_per_s", Json::Num(fps)),
            ("peak_occupancy", Json::Num(occ)),
        ]));
        t.row(&[
            n.to_string(),
            targets.join(","),
            format!("{fps:.0}"),
            format!("{:.0}%", occ * 100.0),
        ]);
    }
    t.print();
    println!(
        "Batching amortizes the per-dispatch host overhead (§IV-F autorun \
         analog); replicas add §IV-G-style concurrency across whole \
         accelerators, weighted by each target's modeled throughput."
    );

    let mut w = BenchWriter::new(RunMeta::new("serve"));
    w.insert("requests", Json::Num(requests as f64));
    w.insert("batch_1_vs_8_speedup", Json::Num(speedup));
    w.insert("batching", Json::Arr(batching_rows));
    w.insert("replica_scaling", Json::Arr(replica_rows));
    let path = w.write().expect("write bench json");
    println!("wrote {}", path.display());
}
