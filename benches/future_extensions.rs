//! The paper's §VII future-work directions, implemented and measured:
//!
//! 1. quantized networks (fp16/int8 datapaths);
//! 2. sparse computations (zero-skipping datapaths, HPIPE-style);
//! 3. design-space exploration (covered by `dse_sweep`);
//! 4. multi-FPGA deployments;
//! plus the §V-F mitigations: vector types and mixed pipelined/folded
//! execution.
//!
//! ```sh
//! cargo bench --bench future_extensions
//! ```

use tvm_fpga_flow::flow::multi::Link;
use tvm_fpga_flow::flow::{default_factors, Compiler, Mode, OptConfig, OptLevel};
use tvm_fpga_flow::graph::models;
use tvm_fpga_flow::texpr::Precision;
use tvm_fpga_flow::util::bench::Table;

fn main() {
    let flow = Compiler::default();

    // ---- 1. reduced precision -------------------------------------------
    let mut t = Table::new(
        "§VII ext. 1 — reduced-precision datapaths (folded, optimized)",
        &["network", "precision", "FPS", "fmax", "dsp%", "logic%", "bram%", "vs fp32"],
    );
    for name in ["mobilenet_v1", "resnet34"] {
        let g = models::by_name(name).unwrap();
        let plan = default_factors(&g);
        let f32_fps = flow.compile(&g, Mode::Folded, OptLevel::Optimized).unwrap().performance.fps;
        for p in [Precision::F32, Precision::F16, Precision::Int8] {
            let cfg = OptConfig::optimized().with_precision(p);
            match flow.compile_with(&g, Mode::Folded, &cfg, &plan) {
                Ok(acc) => {
                    let u = &acc.synthesis.resources.utilization;
                    t.row(&[
                        name.into(),
                        p.name().into(),
                        format!("{:.2}", acc.performance.fps),
                        format!("{:.0}", acc.synthesis.fmax_mhz),
                        format!("{:.1}", u.dsp_frac * 100.0),
                        format!("{:.1}", u.logic_frac * 100.0),
                        format!("{:.1}", u.bram_frac * 100.0),
                        format!("{:.2}x", acc.performance.fps / f32_fps),
                    ]);
                }
                Err(e) => t.row(&[name.into(), p.name().into(), format!("error: {e}"), "".into(), "".into(), "".into(), "".into(), "".into()]),
            }
        }
    }
    t.print();
    // Shape: quantization must never hurt and should help the memory-bound net.
    for name in ["mobilenet_v1", "resnet34"] {
        let g = models::by_name(name).unwrap();
        let plan = default_factors(&g);
        let f32_fps = flow.compile(&g, Mode::Folded, OptLevel::Optimized).unwrap().performance.fps;
        let int8 = flow
            .compile_with(&g, Mode::Folded, &OptConfig::optimized().with_precision(Precision::Int8), &plan)
            .unwrap()
            .performance
            .fps;
        assert!(int8 >= f32_fps * 0.95, "{name}: int8 {int8} vs fp32 {f32_fps}");
    }

    // ---- 2. sparsity (zero-skipping) --------------------------------------
    let mut t = Table::new(
        "§VII ext. 2 — sparse (zero-skipping) datapaths, ResNet-34 folded",
        &["weight density", "FPS", "logic%", "vs dense"],
    );
    {
        let g = models::by_name("resnet34").unwrap();
        let plan = default_factors(&g);
        let dense = flow.compile(&g, Mode::Folded, OptLevel::Optimized).unwrap().performance.fps;
        let mut prev = 0.0;
        for density in [1.0, 0.5, 0.25] {
            let cfg = OptConfig::optimized().with_sparsity(density);
            let acc = flow.compile_with(&g, Mode::Folded, &cfg, &plan).unwrap();
            t.row(&[
                format!("{density:.2}"),
                format!("{:.2}", acc.performance.fps),
                format!("{:.1}", acc.synthesis.resources.utilization.logic_frac * 100.0),
                format!("{:.2}x", acc.performance.fps / dense),
            ]);
            assert!(acc.performance.fps > prev, "sparser must be faster");
            prev = acc.performance.fps;
        }
    }
    t.print();

    // ---- §V-F mitigation: vector types ----------------------------------
    let mut t = Table::new("§V-F mitigation — vector types on strided loads", &["network", "config", "base FPS", "note"]);
    for name in ["resnet34"] {
        let g = models::by_name(name).unwrap();
        let plan = default_factors(&g);
        // Vectorization matters most for the *base* schedule, where strided
        // ifmap reads stall the pipeline.
        let base = flow.compile_with(&g, Mode::Folded, &OptConfig::base(), &plan).unwrap();
        let vec = flow
            .compile_with(&g, Mode::Folded, &OptConfig::base().with_vectors(), &plan)
            .unwrap();
        t.row(&[name.into(), "base".into(), format!("{:.4}", base.performance.fps), String::new()]);
        t.row(&[
            name.into(),
            "base + vector types".into(),
            format!("{:.4}", vec.performance.fps),
            format!("{:.1}x", vec.performance.fps / base.performance.fps),
        ]);
        assert!(vec.performance.fps > base.performance.fps * 1.5, "vectorization must relieve strided stalls");
    }
    t.print();

    // ---- mixed pipelined/folded (hybrid) ---------------------------------
    let mut t = Table::new("§V-F mitigation — mixed pipelined/folded deployment", &["network", "pure folded FPS", "hybrid FPS", "cut", "front ms", "back ms"]);
    for name in ["mobilenet_v1", "resnet34"] {
        let g = models::by_name(name).unwrap();
        let plan = default_factors(&g);
        let folded = flow.compile(&g, Mode::Folded, OptLevel::Optimized).unwrap().performance.fps;
        match flow.best_hybrid(&g, &OptConfig::optimized(), &plan) {
            Some(h) => t.row(&[
                name.into(),
                format!("{folded:.2}"),
                format!("{:.2}", h.fps),
                h.cut.to_string(),
                format!("{:.2}", h.front_interval_s * 1e3),
                format!("{:.2}", h.back_time_s * 1e3),
            ]),
            None => t.row(&[name.into(), format!("{folded:.2}"), "no clean cut fits".into(), "-".into(), "-".into(), "-".into()]),
        }
    }
    t.print();

    // ---- 4. multi-FPGA ----------------------------------------------------
    let mut t = Table::new("§VII ext. 4 — multi-FPGA scaling (folded, optimized)", &["network", "devices", "FPS", "scaling vs 1"]);
    for name in ["resnet34", "vgg16"] {
        let g = models::by_name(name).unwrap();
        let plan = default_factors(&g);
        let single = flow.compile(&g, Mode::Folded, OptLevel::Optimized).unwrap().performance.fps;
        for d in [1usize, 2, 4] {
            match flow.compile_multi(&g, d, &OptConfig::optimized(), &plan, &Link::default()) {
                Ok(m) => t.row(&[
                    name.into(),
                    d.to_string(),
                    format!("{:.2}", m.fps),
                    format!("{:.2}x", m.fps / single),
                ]),
                Err(e) => t.row(&[name.into(), d.to_string(), format!("error: {e}"), "".into()]),
            }
        }
    }
    t.print();
    println!(
        "Reading: int8 doubles DSP packing and halves traffic; vector types \
         rescue the base schedule's strided loads; hybrid helps when the \
         front layers' global round-trips dominate; multi-FPGA scales \
         super-linearly at first because each smaller design routes at a \
         higher f_max (§V-F congestion in reverse)."
    );
}
