//! Host-executor fast-path bench: frames-per-second of the allocating
//! reference [`Executor`] vs the arena-backed [`FastExecutor`] on
//! LeNet-5 (always) and MobileNetV1 (`FLOW_BENCH_HEAVY=1` — ~570M MACs
//! per frame makes the baseline leg slow), at all three precisions, plus
//! a fusion break-even sweep over the differ's random chains.
//!
//! The run asserts the acceptance bar — **≥5x on the int8 LeNet-5 hot
//! path** — and records everything measured to `target/BENCH_executor.json`
//! (`FLOW_BENCH_OUT` overrides; point it at the repo-root
//! `BENCH_executor.json` to refresh the committed note). The
//! [`FUSE_BREAK_EVEN_ELEMS`] default in `quant/exec.rs` comes from the
//! sweep here: re-run it after touching the epilogue kernels.
//!
//! ```sh
//! cargo bench --bench executor_fastpath
//! FLOW_BENCH_HEAVY=1 cargo bench --bench executor_fastpath
//! ```

use std::time::Duration;

use tvm_fpga_flow::data;
use tvm_fpga_flow::graph::models;
use tvm_fpga_flow::graph::Graph;
use tvm_fpga_flow::quant::{
    calibrate_analytic, Calibrator, Executor, FastExecutor, QScheme, FUSE_BREAK_EVEN_ELEMS,
};
use tvm_fpga_flow::texpr::Precision;
use tvm_fpga_flow::util::bench::{bench, BenchStats, BenchWriter, RunMeta, Table};
use tvm_fpga_flow::util::json::Json;
use tvm_fpga_flow::util::scratch::Scratch;
use tvm_fpga_flow::verify::differ::random_chain;

/// One (net, precision) before/after measurement.
struct Row {
    net: String,
    precision: &'static str,
    baseline_fps: f64,
    fast_fps: f64,
}

impl Row {
    fn speedup(&self) -> f64 {
        self.fast_fps / self.baseline_fps
    }
}

fn fps(stats: &BenchStats) -> f64 {
    1.0 / stats.median.as_secs_f64()
}

/// Measure one frame loop. `budget` bounds the timed window; the harness
/// still insists on ≥10 iterations, so heavy nets get a small budget and
/// simply pay for their 10 frames.
fn run(name: &str, budget: Duration, f: impl FnMut()) -> BenchStats {
    let stats = bench(name, Duration::from_millis(20), budget, 100_000, f);
    println!("{}", stats.report());
    stats
}

fn bench_net(g: &Graph, frames: usize, budget: Duration, rows: &mut Vec<Row>) {
    let exec = Executor::new(g);
    let table = calibrate_analytic(g, Calibrator::Percentile(99.9));
    let batch = data::for_network(&g.name, frames, 42).expect("bench nets ship frame generators");
    let mut scratch = Scratch::new();
    for precision in [Precision::F32, Precision::F16, Precision::Int8] {
        let p = precision.name();
        let mut i = 0usize;
        let baseline = run(&format!("{}/{p}/baseline", g.name), budget, || {
            i += 1;
            let frame = batch.frame(i % frames);
            std::hint::black_box(if precision == Precision::F32 {
                exec.forward(frame, |_, _| {})
            } else {
                exec.forward_quantized(frame, &table, precision, QScheme::PerChannel)
            });
        });
        let mut fast = match precision {
            Precision::F32 => FastExecutor::reference(&exec, true, &mut scratch),
            _ => FastExecutor::quantized(
                &exec,
                &table,
                precision,
                QScheme::PerChannel,
                true,
                &mut scratch,
            ),
        };
        let mut j = 0usize;
        let fast_stats = run(&format!("{}/{p}/fast", g.name), budget, || {
            j += 1;
            std::hint::black_box(fast.forward(batch.frame(j % frames)));
        });
        fast.release(&mut scratch);
        rows.push(Row {
            net: g.name.clone(),
            precision: p,
            baseline_fps: fps(&baseline),
            fast_fps: fps(&fast_stats),
        });
    }
}

/// Fused vs unfused fast path across chain sizes — the measurement behind
/// the [`FUSE_BREAK_EVEN_ELEMS`] default. Each row is one random chain
/// (the differ's generator); `elems` is the largest compute-node output.
fn fusion_sweep() -> Vec<(u64, usize, f64, f64)> {
    let mut out = Vec::new();
    for seed in [1u64, 2, 3, 5, 8, 13] {
        let g = random_chain(seed);
        let exec = Executor::new(&g);
        let elems = g.nodes.iter().map(|n| n.shape.elems()).max().unwrap_or(0);
        let frames = tvm_fpga_flow::verify::frames_for(&g, 2, seed);
        let mut scratch = Scratch::new();
        let mut measure = |fuse: bool| {
            let mut fast = FastExecutor::reference(&exec, fuse, &mut scratch);
            let mut i = 0usize;
            let stats = run(
                &format!("fusion/chain{seed}/{}", if fuse { "fused" } else { "unfused" }),
                Duration::from_millis(200),
                || {
                    i += 1;
                    std::hint::black_box(fast.forward(&frames[i % frames.len()]));
                },
            );
            fast.release(&mut scratch);
            fps(&stats)
        };
        let unfused = measure(false);
        let fused = measure(true);
        out.push((seed, elems, unfused, fused));
    }
    out
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_json(rows: &[Row], sweep: &[(u64, usize, f64, f64)], heavy: bool) {
    let mut w = BenchWriter::new(RunMeta::new("executor"));
    w.insert("fuse_break_even_elems", Json::Num(FUSE_BREAK_EVEN_ELEMS as f64));
    w.insert("heavy_nets_included", Json::Bool(heavy));
    w.insert(
        "executors",
        Json::Arr(
            rows.iter()
                .map(|r| {
                    obj(vec![
                        ("net", Json::Str(r.net.clone())),
                        ("precision", Json::Str(r.precision.to_string())),
                        ("baseline_fps", Json::Num(r.baseline_fps)),
                        ("fast_fps", Json::Num(r.fast_fps)),
                        ("speedup", Json::Num(r.speedup())),
                    ])
                })
                .collect(),
        ),
    );
    w.insert(
        "fusion_sweep",
        Json::Arr(
            sweep
                .iter()
                .map(|&(seed, elems, unfused, fused)| {
                    obj(vec![
                        ("chain_seed", Json::Num(seed as f64)),
                        ("max_elems", Json::Num(elems as f64)),
                        ("unfused_fps", Json::Num(unfused)),
                        ("fused_fps", Json::Num(fused)),
                        ("fused_over_unfused", Json::Num(fused / unfused)),
                    ])
                })
                .collect(),
        ),
    );
    let path = w.write().expect("write bench json");
    println!("\nwrote {}", path.display());
}

fn main() {
    let heavy = std::env::var("FLOW_BENCH_HEAVY").is_ok();
    let mut rows = Vec::new();

    bench_net(&models::lenet5(), 16, Duration::from_millis(400), &mut rows);
    if heavy {
        // MobileNetV1's baseline leg runs ~10 frames at naive-conv speed;
        // expect this section to take minutes.
        bench_net(&models::mobilenet_v1(), 2, Duration::from_millis(100), &mut rows);
    } else {
        println!("(skipping mobilenet_v1 — set FLOW_BENCH_HEAVY=1 to include it)");
    }

    let sweep = fusion_sweep();

    let mut t = Table::new(
        "Executor fast path: frames/s (baseline alloc-per-node vs scratch arena)",
        &["net", "precision", "baseline fps", "fast fps", "speedup"],
    );
    for r in &rows {
        t.row(&[
            r.net.clone(),
            r.precision.to_string(),
            format!("{:.1}", r.baseline_fps),
            format!("{:.1}", r.fast_fps),
            format!("{:.2}x", r.speedup()),
        ]);
    }
    t.print();

    let mut t = Table::new(
        &format!(
            "Fusion break-even sweep (FUSE_BREAK_EVEN_ELEMS = {FUSE_BREAK_EVEN_ELEMS})"
        ),
        &["chain seed", "max elems", "unfused fps", "fused fps", "fused/unfused"],
    );
    for (seed, elems, unfused, fused) in &sweep {
        t.row(&[
            seed.to_string(),
            elems.to_string(),
            format!("{unfused:.0}"),
            format!("{fused:.0}"),
            format!("{:.3}", fused / unfused),
        ]);
    }
    t.print();

    write_json(&rows, &sweep, heavy);

    // Acceptance bar: the int8 LeNet-5 hot path must be ≥5x the
    // allocating baseline (ISSUE 7 / ROADMAP open item 3).
    let int8 = rows
        .iter()
        .find(|r| r.net == "lenet5" && r.precision == "int8")
        .expect("lenet5 int8 row");
    println!(
        "\nint8 lenet5 speedup: {:.2}x (bar: 5x)",
        int8.speedup()
    );
    assert!(
        int8.speedup() >= 5.0,
        "int8 fast path regressed below the 5x bar: {:.2}x",
        int8.speedup()
    );
}
