//! Pipeline-parallel multi-FPGA bench: the latency-balancing cut search
//! (ISSUE 9) measured end to end.
//!
//! ```sh
//! cargo bench --bench pipeline_parallel
//! ```
//!
//! Three legs, all recorded to `target/BENCH_pipeline.json`
//! (`FLOW_BENCH_OUT` overrides) via the unified [`BenchWriter`]:
//!
//! 1. **Throughput**: ResNet-34 on a 2-device Stratix 10SX pipeline must
//!    model ≥ **1.5×** the FPS of the best single-device plan (the
//!    acceptance bar — a balanced cut halves the bottleneck interval and
//!    the host link adds microseconds against millisecond stages).
//! 2. **Serving**: the same plan runs on the [`PipelineServer`] stage
//!    workers (time-scaled), proving the steady state overlaps stages:
//!    wall throughput beats serial stage-by-stage execution and the
//!    snapshot attributes the bottleneck to the plan's bottleneck stage.
//! 3. **Capacity escape**: a synthetic net that blows one Arria 10's
//!    BRAM budget (FLOW030 single-device) compiles, serves and verifies
//!    — at all three precisions, int8 bit-exact — once split across two
//!    devices.

use std::time::Instant;

use tvm_fpga_flow::analysis::Lint;
use tvm_fpga_flow::coordinator::{PipelineConfig, PipelineServer};
use tvm_fpga_flow::flow::multi::{Link, PipelinePlan};
use tvm_fpga_flow::flow::Compiler;
use tvm_fpga_flow::graph::models;
use tvm_fpga_flow::graph::{Activation, Graph, GraphBuilder, Op, Shape};
use tvm_fpga_flow::texpr::Precision;
use tvm_fpga_flow::util::bench::{BenchWriter, RunMeta, Table};
use tvm_fpga_flow::util::json::Json;
use tvm_fpga_flow::verify::{frames_for, verify_partition, VerifyOptions};

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// A deep, skinny conv chain sized to overflow the Arria 10 GX BRAM
/// budget in one folded design (each conv layer adds per-layer descriptor
/// storage and shape-dispatch logic to the parameterized kernel) while
/// either half fits comfortably. Tanh keeps 300+ stacked activations
/// bounded, so the verification oracle stays finite.
fn oversized_chain() -> Graph {
    let (mut b, x) = GraphBuilder::new("deepchain320", Shape::Chw(4, 16, 16));
    let mut y = x;
    for block in 0..4 {
        for i in 0..80 {
            y = b.add(
                format!("b{block}.c{i}"),
                Op::Conv2d {
                    out_channels: 4,
                    kernel: 3,
                    stride: 1,
                    padding: 1,
                    bias: true,
                    activation: Activation::Tanh,
                },
                &[y],
            );
        }
        if block < 3 {
            // Spatial reductions are the partitioner's candidate cut
            // points, so each block boundary is a legal stage frontier.
            y = b.add(
                format!("b{block}.pool"),
                Op::MaxPool { kernel: 2, stride: 2, padding: 0 },
                &[y],
            );
        }
    }
    b.finish(y)
}

/// Serve `plan` on the stage pipeline (time-scaled) and return
/// `(wall_fps, snapshot)`.
fn serve_plan(
    plan: &PipelinePlan,
    time_scale: f64,
    frames: usize,
) -> (f64, tvm_fpga_flow::coordinator::StatsSnapshot) {
    let cfg = PipelineConfig::from_plan(plan).with_time_scale(time_scale);
    let elems = cfg.frame_elems;
    let server = PipelineServer::start(cfg).expect("pipeline server starts");
    let frame: Vec<f32> = (0..elems).map(|i| (i % 17) as f32 * 0.1).collect();
    let t0 = Instant::now();
    let pending: Vec<_> = (0..frames)
        .map(|_| server.infer_async(frame.clone()).expect("queue sized for the burst"))
        .collect();
    for rx in pending {
        rx.recv().unwrap().unwrap();
    }
    let wall_fps = frames as f64 / t0.elapsed().as_secs_f64();
    let stats = server.shutdown();
    assert_eq!(stats.completed, frames as u64);
    (wall_fps, stats)
}

fn main() {
    let mut w = BenchWriter::new(RunMeta::new("pipeline"));
    let link = Link::default();

    // ---- 1. ResNet-34: 2-device pipeline vs best single-device plan ----
    let g = models::resnet34();
    let single = PipelinePlan::build(&g, &["stratix10sx"], &link).expect("single-device plan");
    let t0 = Instant::now();
    let plan = PipelinePlan::build(&g, &["stratix10sx", "stratix10sx"], &link)
        .expect("2-device plan");
    let search_s = t0.elapsed().as_secs_f64();
    let speedup = plan.fps / single.fps;

    let mut t = Table::new(
        "resnet34 pipeline partition (2x stratix10sx)",
        &["stage", "compute ms", "transfer ms", "kB in", "occupancy"],
    );
    for (st, occ) in plan.stages.iter().zip(plan.occupancy()) {
        t.row(&[
            st.graph.name.clone(),
            format!("{:.2}", st.cost.compute_s * 1e3),
            format!("{:.3}", st.cost.transfer_s * 1e3),
            format!("{:.1}", st.cost.transfer_bytes as f64 / 1e3),
            format!("{occ:.2}"),
        ]);
    }
    t.print();
    println!(
        "resnet34: single-device {:.2} FPS → 2-device pipeline {:.2} FPS \
         ({speedup:.2}x, cuts {:?}, {} cut sets searched in {search_s:.2}s, \
         {} synth-memo hits)",
        single.fps, plan.fps, plan.cuts, plan.evaluated, plan.synth_cache.hits
    );
    assert!(
        speedup >= 1.5,
        "2-device pipeline below the 1.5x acceptance bar: {speedup:.2}x"
    );

    // ---- 2. Serve the plan: stages must overlap in steady state --------
    let time_scale = 5.0;
    let frames = 48;
    let (wall_fps, stats) = serve_plan(&plan, time_scale, frames);
    // Serial (no overlap) rate = 1 / sum(stage times); the pipeline must
    // beat it — steady state is set by max(stage), not the sum.
    let serial_s: f64 = plan.stages.iter().map(|s| s.cost.stage_s()).sum::<f64>() / time_scale;
    let overlap = wall_fps * serial_s;
    println!(
        "served {frames} frames at {wall_fps:.0} FPS (time scale {time_scale}): \
         {overlap:.2}x the no-overlap rate; bottleneck stage {:?} (plan says {})",
        stats.bottleneck(),
        plan.bottleneck
    );
    assert!(
        overlap > 1.2,
        "stage workers are not overlapping: {overlap:.2}x the serial rate"
    );
    // Attribution via measured busy time: only decidable when the cost
    // model's bottleneck actually stands out (a perfectly balanced cut
    // leaves the argmax to scheduler jitter).
    let mut times: Vec<f64> = plan.stages.iter().map(|s| s.cost.stage_s()).collect();
    times.sort_by(|a, b| b.partial_cmp(a).unwrap());
    if times[0] > times[1] * 1.05 {
        assert_eq!(
            stats.bottleneck(),
            Some(plan.bottleneck),
            "served bottleneck attribution disagrees with the cost model"
        );
    }

    // ---- 3. Over-budget net escapes one device via a 2-stage split -----
    let big = oversized_chain();
    let compiler = Compiler::for_target("arria10gx").expect("arria10gx registered");
    let mut session = compiler.graph(&big);
    let report = session.lower().expect("folded lowering succeeds").analyze();
    let bram_over = report
        .diagnostics
        .iter()
        .any(|d| d.lint == Lint::OverBudget && d.message.contains("BRAM"));
    println!(
        "single arria10gx: {} diagnostic(s), BRAM over budget: {bram_over}",
        report.diagnostics.len()
    );
    assert!(bram_over, "the synthetic chain must blow the single-device BRAM budget");

    let split = PipelinePlan::build(&big, &["arria10gx", "arria10gx"], &link)
        .expect("the over-budget chain must compile as a 2-stage pipeline");
    assert_eq!(split.stages.len(), 2);
    assert!(split.analysis.is_clean(true), "partitioned stages must fit their budgets");
    let (split_fps, split_stats) = serve_plan(&split, 50.0, 32);
    println!(
        "deepchain320 on 2x arria10gx: cuts {:?}, {:.2} modeled FPS, served at {split_fps:.0} \
         FPS (scaled), {} stage workers",
        split.cuts,
        split.fps,
        split_stats.replicas.len()
    );

    let frames_data = frames_for(&big, 2, 11);
    let opts = VerifyOptions::default();
    let mut verify_rows = Vec::new();
    for precision in [Precision::F32, Precision::F16, Precision::Int8] {
        let r = verify_partition(&big, &split.cuts, precision, &frames_data, &opts);
        println!(
            "verify deepchain320 K=2 @ {}: max rel err {:.2e}, bit-exact {}",
            precision.name(),
            r.max_rel_err,
            r.bit_exact
        );
        assert!(r.passed, "partitioned {} execution diverged: {:?}", precision.name(), r.failure);
        if precision == Precision::Int8 {
            assert!(r.bit_exact, "int8 partition must be bit-exact");
        }
        verify_rows.push(obj(vec![
            ("precision", Json::Str(precision.name().to_string())),
            ("max_rel_err", Json::Num(r.max_rel_err)),
            ("bit_exact", Json::Bool(r.bit_exact)),
            ("passed", Json::Bool(r.passed)),
        ]));
    }

    w.insert(
        "resnet34_2dev",
        obj(vec![
            ("single_fps", Json::Num(single.fps)),
            ("pipeline_fps", Json::Num(plan.fps)),
            ("speedup", Json::Num(speedup)),
            ("cuts", Json::Arr(plan.cuts.iter().map(|&c| Json::Num(c as f64)).collect())),
            ("bottleneck_stage", Json::Num(plan.bottleneck as f64)),
            ("cut_sets_evaluated", Json::Num(plan.evaluated as f64)),
            ("search_s", Json::Num(search_s)),
            ("served_overlap_vs_serial", Json::Num(overlap)),
        ]),
    );
    w.insert(
        "over_budget_escape",
        obj(vec![
            ("network", Json::Str(big.name.clone())),
            ("single_device_bram_over", Json::Bool(bram_over)),
            ("cuts", Json::Arr(split.cuts.iter().map(|&c| Json::Num(c as f64)).collect())),
            ("pipeline_fps", Json::Num(split.fps)),
            ("verify", Json::Arr(verify_rows)),
        ]),
    );
    let path = w.write().expect("write bench json");
    println!("wrote {}", path.display());
}
