//! Regenerates **Table II**: resource utilization and f_max of the
//! optimized accelerators for the three evaluation networks, vs the paper.
//! Also times the synthesis path (graph → kernels → AOC model).
//!
//! ```sh
//! cargo bench --bench table2_resources
//! ```

use tvm_fpga_flow::flow::{Compiler, OptLevel};
use tvm_fpga_flow::graph::models;
use tvm_fpga_flow::metrics::{deviation_pct, paper};
use tvm_fpga_flow::util::bench::{quick, Table};

fn main() {
    let flow = Compiler::default();
    let mut table = Table::new(
        "Table II — resource utilization and f_max (ours | paper)",
        &["network", "logic %", "BRAM %", "DSP %", "f_max MHz", "max dev"],
    );

    for (name, pl, pb, pd, pf) in paper::TABLE2 {
        let g = models::by_name(name).unwrap();
        let acc = flow.compile(&g, Compiler::paper_mode(name), OptLevel::Optimized).expect("compiles");
        let (l, b, d, f) = acc.synthesis.table2_row();
        let dev = [
            deviation_pct(l, pl),
            deviation_pct(b, pb),
            deviation_pct(d, pd),
            deviation_pct(f, pf),
        ]
        .into_iter()
        .fold(0.0f64, f64::max);
        table.row(&[
            name.into(),
            format!("{l:.0} | {pl:.0}"),
            format!("{b:.0} | {pb:.0}"),
            format!("{d:.0} | {pd:.0}"),
            format!("{f:.0} | {pf:.0}"),
            format!("{dev:.0}%"),
        ]);
    }
    table.print();

    // Criterion-style timing of the synthesis path itself (the paper's
    // equivalent step is 3–12 h of Quartus, §IV-J).
    for name in ["lenet5", "mobilenet_v1", "resnet34"] {
        let g = models::by_name(name).unwrap();
        let stats = quick(&format!("synthesize/{name}"), || {
            flow.compile(&g, Compiler::paper_mode(name), OptLevel::Optimized).unwrap()
        });
        println!("{}", stats.report());
    }
}
