//! Regenerates **Table II**: resource utilization and f_max of the
//! optimized accelerators for the three evaluation networks, vs the paper
//! — plus the int8 column the paper's §VII anticipates, asserting the
//! modeled DSP/BRAM savings of the quantized datapath. Also times the
//! synthesis path (graph → kernels → AOC model). Everything measured is
//! recorded to `target/BENCH_table2.json` (`FLOW_BENCH_OUT` overrides)
//! via the unified [`BenchWriter`].
//!
//! ```sh
//! cargo bench --bench table2_resources
//! ```

use tvm_fpga_flow::flow::{Compiler, ModeChoice, OptLevel};
use tvm_fpga_flow::graph::models;
use tvm_fpga_flow::metrics::{deviation_pct, paper};
use tvm_fpga_flow::quant::QuantConfig;
use tvm_fpga_flow::util::bench::{quick, BenchWriter, RunMeta, Table};
use tvm_fpga_flow::util::json::Json;

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn main() {
    let flow = Compiler::default();
    let mut w = BenchWriter::new(RunMeta::new("table2").target("stratix10sx"));
    let mut rows_json = Vec::new();
    let mut q_rows_json = Vec::new();
    let mut table = Table::new(
        "Table II — resource utilization and f_max (ours | paper)",
        &["network", "logic %", "BRAM %", "DSP %", "f_max MHz", "max dev"],
    );

    for (name, pl, pb, pd, pf) in paper::TABLE2 {
        let g = models::by_name(name).unwrap();
        let acc = flow.compile(&g, Compiler::paper_mode(name), OptLevel::Optimized).expect("compiles");
        let (l, b, d, f) = acc.synthesis.table2_row();
        let dev = [
            deviation_pct(l, pl),
            deviation_pct(b, pb),
            deviation_pct(d, pd),
            deviation_pct(f, pf),
        ]
        .into_iter()
        .fold(0.0f64, f64::max);
        rows_json.push(obj(vec![
            ("network", Json::Str(name.to_string())),
            ("logic_pct", Json::Num(l)),
            ("bram_pct", Json::Num(b)),
            ("dsp_pct", Json::Num(d)),
            ("fmax_mhz", Json::Num(f)),
            ("paper_logic_pct", Json::Num(pl)),
            ("paper_bram_pct", Json::Num(pb)),
            ("paper_dsp_pct", Json::Num(pd)),
            ("paper_fmax_mhz", Json::Num(pf)),
            ("max_deviation_pct", Json::Num(dev)),
        ]));
        table.row(&[
            name.into(),
            format!("{l:.0} | {pl:.0}"),
            format!("{b:.0} | {pb:.0}"),
            format!("{d:.0} | {pd:.0}"),
            format!("{f:.0} | {pf:.0}"),
            format!("{dev:.0}%"),
        ]);
    }
    table.print();

    // int8 vs fp32 (§VII reduced precision): the quantized datapath must
    // pay for itself on every network — DSPs pack 2:1 and BRAM narrows.
    // Both columns compile the pass-folded graph (the quantization
    // front-end always BN-folds), so the delta is precision alone.
    let mut qtable = Table::new(
        "Table II-Q — int8 vs fp32 modeled resources (per network)",
        &["network", "DSP % (f32→int8)", "BRAM % (f32→int8)", "f_max (f32→int8)", "FPS (f32→int8)", "top-1 Δpp"],
    );
    for (name, ..) in paper::TABLE2 {
        let g = models::by_name(name).unwrap();
        let mode = ModeChoice::from(Compiler::paper_mode(name));
        let (g_folded, _) = tvm_fpga_flow::graph::passes::standard_pipeline(&g);
        let f32_acc = flow.compile(&g_folded, mode, OptLevel::Optimized).expect("f32 compiles");
        let int8_acc = flow
            .graph(&g)
            .mode(mode)
            .with_quantization(QuantConfig::int8())
            .run()
            .expect("int8 compiles");
        let uf = &f32_acc.synthesis.resources.utilization;
        let ui = &int8_acc.synthesis.resources.utilization;
        assert!(
            ui.dsp_frac < uf.dsp_frac,
            "{name}: int8 DSPs {:.3} must undercut f32 {:.3}",
            ui.dsp_frac,
            uf.dsp_frac
        );
        assert!(
            ui.bram_frac < uf.bram_frac,
            "{name}: int8 BRAM {:.3} must undercut f32 {:.3}",
            ui.bram_frac,
            uf.bram_frac
        );
        let delta = int8_acc.quant.as_ref().map(|q| q.accuracy.delta_pp).unwrap_or(0.0);
        assert!(delta < 5.0, "{name}: accuracy delta {delta}pp out of band");
        q_rows_json.push(obj(vec![
            ("network", Json::Str(name.to_string())),
            ("f32_dsp_pct", Json::Num(uf.dsp_frac * 100.0)),
            ("int8_dsp_pct", Json::Num(ui.dsp_frac * 100.0)),
            ("f32_bram_pct", Json::Num(uf.bram_frac * 100.0)),
            ("int8_bram_pct", Json::Num(ui.bram_frac * 100.0)),
            ("f32_fps", Json::Num(f32_acc.performance.fps)),
            ("int8_fps", Json::Num(int8_acc.performance.fps)),
            ("top1_delta_pp", Json::Num(delta)),
        ]));
        qtable.row(&[
            name.into(),
            format!("{:.1} → {:.1}", uf.dsp_frac * 100.0, ui.dsp_frac * 100.0),
            format!("{:.1} → {:.1}", uf.bram_frac * 100.0, ui.bram_frac * 100.0),
            format!("{:.0} → {:.0}", f32_acc.synthesis.fmax_mhz, int8_acc.synthesis.fmax_mhz),
            format!("{:.1} → {:.1}", f32_acc.performance.fps, int8_acc.performance.fps),
            format!("{delta:.2}"),
        ]);
    }
    qtable.print();

    // Criterion-style timing of the synthesis path itself (the paper's
    // equivalent step is 3–12 h of Quartus, §IV-J).
    let mut timings = Vec::new();
    for name in ["lenet5", "mobilenet_v1", "resnet34"] {
        let g = models::by_name(name).unwrap();
        let stats = quick(&format!("synthesize/{name}"), || {
            flow.compile(&g, Compiler::paper_mode(name), OptLevel::Optimized).unwrap()
        });
        println!("{}", stats.report());
        timings.push(stats);
    }

    w.insert("table2", Json::Arr(rows_json));
    w.insert("table2_int8", Json::Arr(q_rows_json));
    w.stats(&timings);
    let path = w.write().expect("write bench json");
    println!("wrote {}", path.display());
}
