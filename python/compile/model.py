"""L2: the paper's three evaluation networks in JAX, calling L1 kernels.

LeNet-5 (MNIST, §V-A), MobileNetV1 (α=1.0, 224², ImageNet head) and
ResNet-34 (224², ImageNet head) — the exact networks the paper generates
accelerators for. Each network has two functional paths:

  apply(params, x, impl="pallas")  — every MAC flows through the L1 Pallas
      kernels (interpret=True). This is the path AOT-lowered into
      artifacts/<net>.hlo.txt and executed by the rust runtime for
      functional verification of the full stack.
  apply(params, x, impl="ref")     — pure jnp/lax (XLA-native convs).
      Lowered into artifacts/<net>_ref.hlo.txt; XLA:CPU compiles these to
      optimized native loops, so the rust runtime uses them as the
      honest "optimized CPU framework" baseline of Table V (the analog of
      TVM-LLVM / TensorFlow in the paper).

Weights are deterministic synthetic values (seeded per layer name): the
paper's Tables measure *throughput*, which is value-independent; numerics
are still verified end-to-end (pallas vs ref paths must agree).

Block-size heuristic: interpret-mode Pallas pays a fixed cost per grid
step, so convs pick large bm / full-K bk tiles (measured 15× faster than
the naive 128³ tiling at 112²; EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from .kernels import conv as kconv
from .kernels import pool as kpool
from .kernels import ref as kref

# ---------------------------------------------------------------------------
# Parameter initialization (deterministic, value-irrelevant but non-trivial)
# ---------------------------------------------------------------------------


def _seed_for(name: str) -> int:
    return int.from_bytes(hashlib.sha256(name.encode()).digest()[:4], "little")


def _he(name: str, shape, fan_in: int) -> np.ndarray:
    rng = np.random.default_rng(_seed_for(name))
    return (rng.standard_normal(shape) * np.sqrt(2.0 / fan_in)).astype(np.float32)


def _zeros(shape) -> np.ndarray:
    return np.zeros(shape, np.float32)


def _ones(shape) -> np.ndarray:
    return np.ones(shape, np.float32)


@dataclass
class ParamSet:
    """Ordered parameter list; order == HLO parameter order after the image."""
    names: list = field(default_factory=list)
    values: list = field(default_factory=list)

    def add(self, name: str, value: np.ndarray) -> None:
        self.names.append(name)
        self.values.append(value)

    def conv(self, name: str, o: int, i: int, k: int, bias: bool = True):
        self.add(f"{name}.w", _he(f"{name}.w", (o, i, k, k), i * k * k))
        if bias:
            self.add(f"{name}.b", _zeros((o,)))

    def dwconv(self, name: str, c: int, k: int):
        self.add(f"{name}.w", _he(f"{name}.w", (c, 1, k, k), k * k))

    def bn(self, name: str, c: int):
        self.add(f"{name}.gamma", _ones((c,)))
        self.add(f"{name}.beta", _zeros((c,)))
        rng = np.random.default_rng(_seed_for(f"{name}.stats"))
        self.add(f"{name}.mean", (rng.standard_normal(c) * 0.1).astype(np.float32))
        self.add(f"{name}.var", (_ones((c,)) + rng.random(c).astype(np.float32) * 0.1))

    def dense(self, name: str, i: int, o: int):
        self.add(f"{name}.w", _he(f"{name}.w", (i, o), i))
        self.add(f"{name}.b", _zeros((o,)))


class _P:
    """Cursor over a flat parameter list during apply()."""

    def __init__(self, params):
        self.params = list(params)
        self.i = 0

    def take(self, n: int = 1):
        vals = self.params[self.i:self.i + n]
        self.i += n
        return vals[0] if n == 1 else vals

    def done(self):
        assert self.i == len(self.params), \
            f"consumed {self.i} of {len(self.params)} params"


# Interpret-mode Pallas grid-step overhead dominates; pick tiles that
# minimize grid steps (see module docstring).
_CONV_BM, _CONV_BN, _CONV_BK_CAP = 2048, 128, 1152


def _conv_blocks(k_total: int):
    return dict(bm=_CONV_BM, bn=_CONV_BN, bk=min(_CONV_BK_CAP, k_total))


def _conv(x, w, b, stride, padding, act, impl):
    if impl == "pallas":
        kdim = w.shape[1] * w.shape[2] * w.shape[3]
        return kconv.conv2d(x, w, b, stride=stride, padding=padding, act=act,
                            **_conv_blocks(kdim))
    return kref.conv2d(x, w, stride=stride, padding=padding, bias=b, act=act)


def _dwconv(x, w, stride, padding, act, impl):
    if impl == "pallas":
        return kconv.depthwise_conv2d(x, w, None, stride=stride,
                                      padding=padding, act=act)
    return kref.depthwise_conv2d(x, w, stride=stride, padding=padding, act=act)


def _dense(x, w, b, act, impl):
    if impl == "pallas":
        return kconv.dense(x, w, b, act=act)
    return kref.matmul_bias_act(x, w, b, act)


def _maxpool(x, k, stride, padding, impl):
    if impl == "pallas":
        return kpool.pool2d(x, k=k, stride=stride, padding=padding, mode="max")
    return kref.maxpool2d(x, k, stride, padding)


def _avgpool(x, k, impl):
    if impl == "pallas":
        return kpool.pool2d(x, k=k, mode="avg")
    return kref.avgpool2d(x, k)


def _gap(x, impl):
    if impl == "pallas":
        return kpool.global_avgpool(x)
    return kref.global_avgpool(x)


def _bn(x, g, b, m, v):
    # Batchnorm is always folded arithmetic (the paper fuses it into the
    # conv loop — LF); numerically identical in both impls.
    return kref.batchnorm(x, g, b, m, v)


# ---------------------------------------------------------------------------
# LeNet-5  (32×32×1 input, classic C1..F7; ~390K MACs)
# ---------------------------------------------------------------------------


def lenet5_params() -> ParamSet:
    p = ParamSet()
    p.conv("c1", 6, 1, 5)
    p.conv("c3", 16, 6, 5)
    p.dense("f5", 400, 120)
    p.dense("f6", 120, 84)
    p.dense("f7", 84, 10)
    return p


def lenet5_apply(params, x, impl: str = "pallas"):
    """x: (N, 1, 32, 32) → logits (N, 10)."""
    p = _P(params)
    w, b = p.take(2)
    y = _conv(x, w, b, 1, 0, "tanh", impl)          # (N, 6, 28, 28)
    y = _avgpool(y, 2, impl)                        # (N, 6, 14, 14)
    w, b = p.take(2)
    y = _conv(y, w, b, 1, 0, "tanh", impl)          # (N, 16, 10, 10)
    y = _avgpool(y, 2, impl)                        # (N, 16, 5, 5)
    y = y.reshape(y.shape[0], -1)                   # (N, 400)
    w, b = p.take(2)
    y = _dense(y, w, b, "tanh", impl)
    w, b = p.take(2)
    y = _dense(y, w, b, "tanh", impl)
    w, b = p.take(2)
    y = _dense(y, w, b, "none", impl)
    p.done()
    return y


# ---------------------------------------------------------------------------
# MobileNetV1  (α=1.0, 224²; 13 depthwise-separable blocks; §V-A)
# ---------------------------------------------------------------------------

# (stride of the dw conv, output channels of the pointwise conv)
MOBILENET_BLOCKS = [
    (1, 64), (2, 128), (1, 128), (2, 256), (1, 256), (2, 512),
    (1, 512), (1, 512), (1, 512), (1, 512), (1, 512),
    (2, 1024), (1, 1024),
]


def mobilenet_v1_params() -> ParamSet:
    p = ParamSet()
    p.conv("conv1", 32, 3, 3, bias=False)
    p.bn("conv1.bn", 32)
    c = 32
    for i, (stride, cout) in enumerate(MOBILENET_BLOCKS):
        p.dwconv(f"b{i}.dw", c, 3)
        p.bn(f"b{i}.dw.bn", c)
        p.conv(f"b{i}.pw", cout, c, 1, bias=False)
        p.bn(f"b{i}.pw.bn", cout)
        c = cout
    p.dense("fc", 1024, 1000)
    return p


def mobilenet_v1_apply(params, x, impl: str = "pallas"):
    """x: (N, 3, 224, 224) → logits (N, 1000)."""
    p = _P(params)
    w = p.take()
    g, b_, m, v = p.take(4)
    y = _conv(x, w, None, 2, 1, "none", impl)
    y = kref.apply_act(_bn(y, g, b_, m, v), "relu6")
    c = 32
    for stride, cout in MOBILENET_BLOCKS:
        wd = p.take()
        g, b_, m, v = p.take(4)
        y = _dwconv(y, wd, stride, 1, "none", impl)
        y = kref.apply_act(_bn(y, g, b_, m, v), "relu6")
        wp = p.take()
        g, b_, m, v = p.take(4)
        y = _conv(y, wp, None, 1, 0, "none", impl)
        y = kref.apply_act(_bn(y, g, b_, m, v), "relu6")
        c = cout
    y = _gap(y, impl)                               # (N, 1024)
    w, b_ = p.take(2)
    y = _dense(y, w, b_, "none", impl)
    p.done()
    return y


# ---------------------------------------------------------------------------
# ResNet-34  (224²; basic blocks [3, 4, 6, 3]; §V-A)
# ---------------------------------------------------------------------------

RESNET34_STAGES = [(64, 3), (128, 4), (256, 6), (512, 3)]


def resnet34_params() -> ParamSet:
    p = ParamSet()
    p.conv("conv1", 64, 3, 7, bias=False)
    p.bn("conv1.bn", 64)
    cin = 64
    for s, (c, nblocks) in enumerate(RESNET34_STAGES):
        for b in range(nblocks):
            name = f"s{s}b{b}"
            p.conv(f"{name}.conv1", c, cin, 3, bias=False)
            p.bn(f"{name}.bn1", c)
            p.conv(f"{name}.conv2", c, c, 3, bias=False)
            p.bn(f"{name}.bn2", c)
            if b == 0 and cin != c:
                p.conv(f"{name}.down", c, cin, 1, bias=False)
                p.bn(f"{name}.down.bn", c)
            cin = c
    p.dense("fc", 512, 1000)
    return p


def resnet34_apply(params, x, impl: str = "pallas"):
    """x: (N, 3, 224, 224) → logits (N, 1000)."""
    p = _P(params)
    w = p.take()
    g, b_, m, v = p.take(4)
    y = _conv(x, w, None, 2, 3, "none", impl)        # (N, 64, 112, 112)
    y = kref.apply_act(_bn(y, g, b_, m, v), "relu")
    y = _maxpool(y, 3, 2, 1, impl)                   # (N, 64, 56, 56)
    cin = 64
    for s, (c, nblocks) in enumerate(RESNET34_STAGES):
        for b in range(nblocks):
            stride = 2 if (b == 0 and s > 0) else 1
            w1 = p.take()
            g1, be1, m1, v1 = p.take(4)
            w2 = p.take()
            g2, be2, m2, v2 = p.take(4)
            z = _conv(y, w1, None, stride, 1, "none", impl)
            z = kref.apply_act(_bn(z, g1, be1, m1, v1), "relu")
            z = _conv(z, w2, None, 1, 1, "none", impl)
            z = _bn(z, g2, be2, m2, v2)
            if b == 0 and cin != c:
                wd = p.take()
                gd, bd, md, vd = p.take(4)
                y = _conv(y, wd, None, stride, 0, "none", impl)
                y = _bn(y, gd, bd, md, vd)
            y = kref.apply_act(z + y, "relu")
            cin = c
    y = _gap(y, impl)                                # (N, 512)
    w, b_ = p.take(2)
    y = _dense(y, w, b_, "none", impl)
    p.done()
    return y


# ---------------------------------------------------------------------------
# Registry used by aot.py, tests, and the Makefile
# ---------------------------------------------------------------------------

NETWORKS = {
    "lenet5": dict(
        params=lenet5_params, apply=lenet5_apply,
        input_shape=(1, 32, 32), num_classes=10),
    "mobilenet_v1": dict(
        params=mobilenet_v1_params, apply=mobilenet_v1_apply,
        input_shape=(3, 224, 224), num_classes=1000),
    "resnet34": dict(
        params=resnet34_params, apply=resnet34_apply,
        input_shape=(3, 224, 224), num_classes=1000),
}


def make_inputs(net: str, batch: int = 1, seed: int = 0):
    """Deterministic input batch + device-ready parameter list."""
    spec = NETWORKS[net]
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((batch, *spec["input_shape"])).astype(np.float32)
    pset = spec["params"]()
    return jnp.asarray(x), [jnp.asarray(v) for v in pset.values], pset
