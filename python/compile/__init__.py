"""Build-time compile path: L1 Pallas kernels + L2 JAX models + AOT lowering.

Nothing in this package is imported at request time; `make artifacts` runs
aot.py once and the rust coordinator loads the emitted HLO text.
"""
