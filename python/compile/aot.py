"""AOT lowering: JAX (L2, calling L1 Pallas) → HLO text artifacts for rust.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the rust `xla` crate) rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Per network this emits into artifacts/:
  <net>.b<B>.hlo.txt       pallas-kernel path (functional verification)
  <net>_ref.b<B>.hlo.txt   pure-XLA path (optimized CPU baseline, Table V)
  <net>.weights.bin        all parameters, f32 LE, concatenated
  <net>.manifest.json      parameter order/shapes/offsets + input spec
plus kernels/matmul_<M>x<K>x<N>.hlo.txt micro-executables for the runtime
hot-path bench, and manifest.json indexing everything.

Python runs ONCE here (`make artifacts`); never on the request path.
"""
from __future__ import annotations

import argparse
import functools
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import matmul as mm


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_network(net: str, impl: str, batch: int) -> str:
    spec = model.NETWORKS[net]
    apply_fn = spec["apply"]
    x, params, _ = model.make_inputs(net, batch=batch)

    def fn(x, *params):
        return (apply_fn(list(params), x, impl=impl),)

    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct(x.shape, x.dtype),
        *[jax.ShapeDtypeStruct(p.shape, p.dtype) for p in params])
    return to_hlo_text(lowered)


def write_weights(net: str, out_dir: pathlib.Path) -> dict:
    pset = model.NETWORKS[net]["params"]()
    blob = bytearray()
    entries = []
    for name, value in zip(pset.names, pset.values):
        arr = np.ascontiguousarray(value, dtype=np.float32)
        entries.append(dict(name=name, shape=list(arr.shape),
                            offset=len(blob), nbytes=arr.nbytes))
        blob.extend(arr.tobytes())
    (out_dir / f"{net}.weights.bin").write_bytes(bytes(blob))
    return dict(params=entries, total_bytes=len(blob))


def lower_matmul(m: int, k: int, n: int) -> str:
    fn = functools.partial(mm.matmul, bm=min(512, m), bn=min(128, n),
                           bk=min(512, k))

    def wrapped(a, b):
        return (fn(a, b),)

    lowered = jax.jit(wrapped).lower(
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32))
    return to_hlo_text(lowered)


# (network, batch sizes) — lenet5 also gets a batched executable for the
# coordinator's dynamic batcher demo.
PLAN = {
    "lenet5": [1, 16],
    "mobilenet_v1": [1],
    "resnet34": [1],
}
MATMUL_SHAPES = [(256, 256, 256), (512, 512, 512), (1024, 1024, 128)]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts",
                    help="artifacts output directory")
    ap.add_argument("--nets", default=",".join(PLAN),
                    help="comma-separated subset of networks")
    args = ap.parse_args()

    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    (out / "kernels").mkdir(exist_ok=True)
    index = dict(networks={}, kernels=[], generated_unix=int(time.time()))

    for net in args.nets.split(","):
        spec = model.NETWORKS[net]
        t0 = time.time()
        meta = write_weights(net, out)
        executables = []
        for batch in PLAN[net]:
            for impl, suffix in [("pallas", ""), ("ref", "_ref")]:
                text = lower_network(net, impl, batch)
                name = f"{net}{suffix}.b{batch}.hlo.txt"
                (out / name).write_text(text)
                executables.append(dict(file=name, impl=impl, batch=batch,
                                        hlo_chars=len(text)))
        index["networks"][net] = dict(
            input_shape=list(spec["input_shape"]),
            num_classes=spec["num_classes"],
            weights_file=f"{net}.weights.bin",
            executables=executables,
            **meta,
        )
        print(f"[aot] {net}: {len(executables)} executables, "
              f"{meta['total_bytes'] / 1e6:.1f} MB weights, "
              f"{time.time() - t0:.1f}s")

    for m, k, n in MATMUL_SHAPES:
        text = lower_matmul(m, k, n)
        name = f"kernels/matmul_{m}x{k}x{n}.hlo.txt"
        (out / name).write_text(text)
        index["kernels"].append(dict(file=name, m=m, k=k, n=n))
        print(f"[aot] matmul {m}x{k}x{n}")

    (out / "manifest.json").write_text(json.dumps(index, indent=2))
    print(f"[aot] wrote {out / 'manifest.json'}")


if __name__ == "__main__":
    main()
