"""Pure-jnp correctness oracles for the Pallas kernels.

Every Pallas kernel in this package has a reference implementation here,
written with plain jax.numpy / lax ops. pytest asserts allclose between the
kernel (interpret=True) and these oracles across shape/dtype sweeps — this is
the core L1 correctness signal of the build.

Layout conventions (matching the paper's TVM NCHW kernels):
  feature maps: (N, C, H, W)    weights: (O, I, KH, KW)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """C = A @ B with f32 accumulation."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def matmul_bias_act(a, b, bias=None, act: str | None = None):
    """Fused matmul + bias + activation — the paper's loop-fusion (LF) target."""
    out = jnp.matmul(a, b, preferred_element_type=jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    out = apply_act(out, act)
    return out.astype(a.dtype)


def apply_act(x, act: str | None):
    if act is None or act == "none":
        return x
    if act == "relu":
        return jnp.maximum(x, 0.0)
    if act == "relu6":
        return jnp.clip(x, 0.0, 6.0)
    if act == "tanh":
        return jnp.tanh(x)
    raise ValueError(f"unknown activation {act!r}")


def conv2d(x, w, stride: int = 1, padding: int = 0, bias=None, act: str | None = None):
    """Direct NCHW conv2d oracle via lax.conv_general_dilated."""
    out = lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    if bias is not None:
        out = out + bias.astype(jnp.float32)[None, :, None, None]
    out = apply_act(out, act)
    return out.astype(x.dtype)


def depthwise_conv2d(x, w, stride: int = 1, padding: int = 0, bias=None,
                     act: str | None = None):
    """Depthwise NCHW conv oracle. w: (C, 1, KH, KW)."""
    c = x.shape[1]
    out = lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=c,
    )
    if bias is not None:
        out = out + bias.astype(jnp.float32)[None, :, None, None]
    out = apply_act(out, act)
    return out.astype(x.dtype)


def batchnorm(x, gamma, beta, mean, var, eps: float = 1e-3):
    """Inference-mode batchnorm over channel dim of NCHW."""
    inv = gamma.astype(jnp.float32) * lax.rsqrt(var.astype(jnp.float32) + eps)
    out = (x.astype(jnp.float32) - mean.astype(jnp.float32)[None, :, None, None]) \
        * inv[None, :, None, None] + beta.astype(jnp.float32)[None, :, None, None]
    return out.astype(x.dtype)


def maxpool2d(x, k: int = 2, stride: int | None = None, padding: int = 0):
    stride = stride or k
    return lax.reduce_window(
        x, -jnp.inf, lax.max,
        window_dimensions=(1, 1, k, k),
        window_strides=(1, 1, stride, stride),
        padding=[(0, 0), (0, 0), (padding, padding), (padding, padding)],
    )


def avgpool2d(x, k: int = 2, stride: int | None = None, padding: int = 0):
    stride = stride or k
    summed = lax.reduce_window(
        x.astype(jnp.float32), 0.0, lax.add,
        window_dimensions=(1, 1, k, k),
        window_strides=(1, 1, stride, stride),
        padding=[(0, 0), (0, 0), (padding, padding), (padding, padding)],
    )
    return (summed / float(k * k)).astype(x.dtype)


def global_avgpool(x):
    """NCHW → NC."""
    return jnp.mean(x.astype(jnp.float32), axis=(2, 3)).astype(x.dtype)


def im2col(x, kh: int, kw: int, stride: int, padding: int):
    """Unfold NCHW into (N * OH * OW, C * KH * KW) patch matrix.

    This is the oracle for the layout transform the Pallas conv kernel uses to
    map the paper's unrolled DSP loops onto MXU-shaped matmul tiles.
    """
    n, c, h, w = x.shape
    oh = (h + 2 * padding - kh) // stride + 1
    ow = (w + 2 * padding - kw) // stride + 1
    patches = lax.conv_general_dilated_patches(
        x.astype(jnp.float32),
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )  # (N, C*KH*KW, OH, OW)
    patches = jnp.transpose(patches, (0, 2, 3, 1)).reshape(n * oh * ow, c * kh * kw)
    return patches.astype(x.dtype), oh, ow


def conv2d_im2col(x, w, stride: int = 1, padding: int = 0, bias=None,
                  act: str | None = None):
    """Conv via explicit im2col + matmul — bit-matched path for the Pallas kernel."""
    o, i, kh, kw = w.shape
    cols, oh, ow = im2col(x, kh, kw, stride, padding)
    wmat = w.reshape(o, i * kh * kw).T  # (C*KH*KW, O)
    out = matmul_bias_act(cols, wmat, bias=bias, act=act)  # (N*OH*OW, O)
    n = x.shape[0]
    return jnp.transpose(out.reshape(n, oh, ow, o), (0, 3, 1, 2))
