"""L1 Pallas kernel: tiled matmul with fused bias + activation.

This is the compute hot-spot of the whole flow. The paper unrolls/tiles the
convolution reduction loops so AOC replicates DSPs and widens LSUs
(§IV-A/B); on the TPU target the same schedule decision becomes the
(bm, bn, bk) BlockSpec tile feeding the MXU:

  * the bm×bk and bk×bn input blocks are the "burst-coalesced LSU" loads
    HBM→VMEM (contiguous last-dim blocks ≙ coalesced bursts),
  * the f32 VMEM scratch accumulator is the paper's cached-write (§IV-D):
    accumulation lives on-chip, never read-modify-written in global memory,
  * the fused bias+activation epilogue is the paper's loop fusion (§IV-C),
    removing the temporary global array between conv and activation.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; numerics are identical, and TPU efficiency is estimated
analytically (DESIGN.md §Perf, EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref

# Default MXU-shaped tile. 128 matches the MXU systolic-array edge; it is
# also the analog of the paper's §IV-J rule-1 bandwidth roof (the unroll
# factor must not exceed what the memory system can feed per cycle).
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _matmul_bias_kernel(a_ref, b_ref, bias_ref, o_ref, acc_ref, *,
                        act: str, nsteps: int):
    """One (bm, bn) output tile; grid dim 2 walks the K reduction."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU-shaped partial product, accumulated in f32 VMEM scratch.
    acc_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.float32),
        b_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nsteps - 1)
    def _epilogue():
        out = acc_ref[...]
        if bias_ref is not None:
            out = out + bias_ref[...].astype(jnp.float32)
        out = ref.apply_act(out, act)
        o_ref[...] = out.astype(o_ref.dtype)


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, act: str, nsteps: int):
    _matmul_bias_kernel(a_ref, b_ref, None, o_ref, acc_ref,
                        act=act, nsteps=nsteps)


def _pad_to(x, mult: int, axis: int):
    rem = (-x.shape[axis]) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


def _shrink(block: int, dim: int) -> int:
    """Shrink a block edge for small matrices: smallest power of two ≥ 8
    that covers `dim`, capped at `block` — avoids padding a 10-wide logits
    matrix out to a full 128 MXU tile."""
    p = 8
    while p < dim and p < block:
        p *= 2
    return min(block, p)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "act", "interpret"))
def matmul(a, b, bias=None, *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
           bk: int = DEFAULT_BK, act: str = "none", interpret: bool = True):
    """C = act(A @ B + bias) as a tiled Pallas kernel.

    a: (M, K), b: (K, N), bias: (N,) or None; returns (M, N) in a.dtype.
    Arbitrary M/N/K — inputs are zero-padded up to the tile grid and the
    result is sliced back. (The paper instead *requires* divisibility —
    §IV-J rule 2; the rust legality checker enforces that rule on the FPGA
    path, while the TPU kernel tolerates ragged edges via padding.)
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"matmul shape mismatch: {a.shape} @ {b.shape}"
    out_dtype = a.dtype

    bm_, bn_, bk_ = _shrink(bm, m), _shrink(bn, n), _shrink(bk, k)

    ap = _pad_to(_pad_to(a, bm_, 0), bk_, 1)
    bp = _pad_to(_pad_to(b, bk_, 0), bn_, 1)
    mp, kp = ap.shape
    np_ = bp.shape[1]
    nsteps = kp // bk_
    grid = (mp // bm_, np_ // bn_, nsteps)

    common = dict(
        grid=grid,
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        interpret=interpret,
    )
    a_spec = pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk))
    b_spec = pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j))

    if bias is not None:
        biasp = _pad_to(bias.astype(jnp.float32).reshape(1, -1), bn_, 1)
        out = pl.pallas_call(
            functools.partial(_matmul_bias_kernel, act=act, nsteps=nsteps),
            in_specs=[a_spec, b_spec,
                      pl.BlockSpec((1, bn_), lambda i, j, kk: (0, j))],
            **common,
        )(ap, bp, biasp)
    else:
        out = pl.pallas_call(
            functools.partial(_matmul_kernel, act=act, nsteps=nsteps),
            in_specs=[a_spec, b_spec],
            **common,
        )(ap, bp)
    return out[:m, :n]


def vmem_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """VMEM working set of one grid step: A block + B block + bias row +
    f32 accumulator + output block. Used by the §Perf analytical model."""
    return (bm * bk + bk * bn + bn) * dtype_bytes + bm * bn * 4 + bm * bn * dtype_bytes


def mxu_utilization(m: int, n: int, k: int, bm: int, bn: int, bk: int) -> float:
    """Fraction of MXU-issued MACs that are useful (non-padding) work —
    the TPU analog of the paper's DSP-utilization discussion (§V-F)."""
    import math
    gm, gn, gk = math.ceil(m / bm), math.ceil(n / bn), math.ceil(k / bk)
    issued = gm * gn * gk * bm * bn * bk
    useful = m * n * k
    return useful / issued if issued else 0.0
