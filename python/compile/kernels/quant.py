"""L1 Pallas kernel: int8 matmul with int32 accumulation + dequantize.

The L1 counterpart of the flow's §VII reduced-precision extension: on the
FPGA side int8 packs two MACs per DSP; on the TPU side int8 operands feed
the MXU at double rate with an int32 accumulator. This kernel mirrors the
fp32 tiled matmul's structure (K-grid accumulation in scratch) with
symmetric per-tensor quantization.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import matmul as mm


def quantize_symmetric(x, bits: int = 8):
    """Symmetric per-tensor quantization → (int8 values, scale)."""
    qmax = float(2 ** (bits - 1) - 1)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


def _int8_kernel(a_ref, b_ref, o_ref, acc_ref, *, nsteps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # int8 × int8 → int32 accumulation (MXU int path / packed DSPs).
    acc_ref[...] += jax.lax.dot_general(
        a_ref[...].astype(jnp.int32),
        b_ref[...].astype(jnp.int32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == nsteps - 1)
    def _out():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul_int8(a_q, b_q, *, bm: int = 128, bn: int = 128, bk: int = 128,
                interpret: bool = True):
    """C_int32 = A_int8 @ B_int8 via a tiled Pallas kernel."""
    m, k = a_q.shape
    k2, n = b_q.shape
    assert k == k2
    bm_, bn_, bk_ = mm._shrink(bm, m), mm._shrink(bn, n), mm._shrink(bk, k)
    ap = mm._pad_to(mm._pad_to(a_q, bm_, 0), bk_, 1)
    bp = mm._pad_to(mm._pad_to(b_q, bk_, 0), bn_, 1)
    mp, kp = ap.shape
    np_ = bp.shape[1]
    nsteps = kp // bk_

    out = pl.pallas_call(
        functools.partial(_int8_kernel, nsteps=nsteps),
        grid=(mp // bm_, np_ // bn_, nsteps),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.int32)],
        interpret=interpret,
    )(ap, bp)
    return out[:m, :n]


def matmul_quantized(a, b, *, interpret: bool = True):
    """fp32 in → quantize → int8 matmul → dequantize → fp32 out."""
    a_q, sa = quantize_symmetric(a)
    b_q, sb = quantize_symmetric(b)
    c = matmul_int8(a_q, b_q, interpret=interpret)
    return c.astype(jnp.float32) * (sa * sb)
