"""L1: Pallas kernels for the paper's compute hot-spots.

- matmul: tiled MXU matmul with fused bias+activation (the conv workhorse)
- conv:   im2col conv2d + depthwise conv + dense
- pool:   max/avg/global pooling
- ref:    pure-jnp oracles for all of the above

All kernels run with interpret=True (CPU image; see DESIGN.md).
"""
from . import conv, matmul, pool, quant, ref, winograd  # noqa: F401
