"""L1 Pallas kernels: max/avg pooling.

In the paper, pooling layers are weightless kernels declared *autorun*
(§IV-F) and fed through channels. Here they are small VPU-style Pallas
kernels blocked over (batch, channel) grid steps; the KxK window taps are
fully unrolled — the paper's LU applied to the window loops.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _pool_kernel(x_ref, o_ref, *, k: int, stride: int, mode: str):
    """x_ref: (1, bc, IH, IW) pre-padded; o_ref: (1, bc, OH, OW)."""
    oh, ow = o_ref.shape[2], o_ref.shape[3]
    xv = x_ref[...].astype(jnp.float32)
    acc = None
    for r in range(k):
        for s in range(k):
            win = lax.slice(
                xv, (0, 0, r, s),
                (1, xv.shape[1], r + (oh - 1) * stride + 1,
                 s + (ow - 1) * stride + 1),
                (1, 1, stride, stride))
            if acc is None:
                acc = win
            elif mode == "max":
                acc = jnp.maximum(acc, win)
            else:
                acc = acc + win
    if mode == "avg":
        acc = acc / float(k * k)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "k", "stride", "padding", "mode", "bc", "interpret"))
def pool2d(x, *, k: int = 2, stride: int | None = None, padding: int = 0,
           mode: str = "max", bc: int = 32, interpret: bool = True):
    """NCHW max/avg pool. Padding uses -inf for max, 0 for avg (matching
    the lax.reduce_window oracle in ref.py)."""
    stride = stride if stride is not None else k
    n, c, h, w = x.shape
    pad_val = -jnp.inf if mode == "max" else 0.0
    xp = jnp.pad(x, ((0, 0), (0, 0), (padding, padding), (padding, padding)),
                 constant_values=pad_val)
    ih, iw = xp.shape[2], xp.shape[3]
    oh = (ih - k) // stride + 1
    ow = (iw - k) // stride + 1

    bc = min(bc, c)
    if c % bc != 0:
        bc = c

    out = pl.pallas_call(
        functools.partial(_pool_kernel, k=k, stride=stride, mode=mode),
        grid=(n, c // bc),
        in_specs=[pl.BlockSpec((1, bc, ih, iw), lambda b, cc: (b, cc, 0, 0))],
        out_specs=pl.BlockSpec((1, bc, oh, ow), lambda b, cc: (b, cc, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c, oh, ow), x.dtype),
        interpret=interpret,
    )(xp)
    return out


def global_avgpool(x, *, interpret: bool = True):
    """NCHW → NC global average pool (MobileNet/ResNet heads)."""
    n, c, h, w = x.shape
    out = pool2d(x, k=h, stride=h, mode="avg", interpret=interpret)
    return out.reshape(n, c)
