"""L1 Pallas kernels: conv2d (im2col × MXU matmul) and depthwise conv.

The paper's convolution kernels are loop nests whose reduction loops are
strip-mined and fully unrolled so AOC replicates DSPs (§IV-A/B). The TPU
re-think (DESIGN.md §Hardware-adaptation): gather the conv into an
(N·OH·OW) × (C·KH·KW) patch matrix and feed MXU-shaped matmul tiles. The
patch gather is pure layout (XLA fuses it); every MAC flows through the
Pallas matmul kernel, so the schedule parameters (bm, bn, bk) govern the
conv exactly as the unroll/tile factors govern the paper's DSP array.

Depthwise convolutions (MobileNetV1's companion op) have no shared
reduction across channels — im2col×matmul would waste the MXU on a
block-diagonal operand. They get their own VPU-style kernel that blocks
over channels, the same specialization the paper applies by grouping
kernels by filter size and stride (§IV-H).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from . import matmul as mm
from . import ref


def conv2d(x, w, bias=None, *, stride: int = 1, padding: int = 0,
           act: str = "none", bm: int = mm.DEFAULT_BM, bn: int = mm.DEFAULT_BN,
           bk: int = mm.DEFAULT_BK, interpret: bool = True):
    """NCHW conv2d: im2col patch gather + Pallas tiled matmul.

    x: (N, C, H, W), w: (O, C, KH, KW), bias: (O,) | None → (N, O, OH, OW).
    """
    n = x.shape[0]
    o, i, kh, kw = w.shape
    cols, oh, ow = ref.im2col(x, kh, kw, stride, padding)
    wmat = w.reshape(o, i * kh * kw).T  # (C·KH·KW, O)
    out = mm.matmul(cols, wmat, bias, bm=bm, bn=bn, bk=bk, act=act,
                    interpret=interpret)  # (N·OH·OW, O)
    return jnp.transpose(out.reshape(n, oh, ow, o), (0, 3, 1, 2))


def _dw_kernel(x_ref, w_ref, bias_ref, o_ref, *, kh: int, kw: int,
               stride: int, act: str):
    """Depthwise conv over one (batch, channel-block) grid step.

    x_ref: (1, bc, IH, IW) pre-padded input block
    w_ref: (bc, KH, KW), bias_ref: (1, bc), o_ref: (1, bc, OH, OW)
    The KH×KW taps are unrolled (python loop == full unroll — the paper's
    LU on the filter loops); the spatial dims vectorize on the VPU.
    """
    oh, ow = o_ref.shape[2], o_ref.shape[3]
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for r in range(kh):
        for s in range(kw):
            # strided window starting at tap (r, s)
            win = lax.slice(
                x_ref[...].astype(jnp.float32),
                (0, 0, r, s),
                (1, x_ref.shape[1], r + (oh - 1) * stride + 1,
                 s + (ow - 1) * stride + 1),
                (1, 1, stride, stride),
            )
            acc += win * w_ref[:, r, s][None, :, None, None].astype(jnp.float32)
    if bias_ref is not None:
        acc += bias_ref[...][:, :, None, None].astype(jnp.float32)
    o_ref[...] = ref.apply_act(acc, act).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "stride", "padding", "act", "bc", "interpret"))
def depthwise_conv2d(x, w, bias=None, *, stride: int = 1, padding: int = 0,
                     act: str = "none", bc: int = 32, interpret: bool = True):
    """Depthwise NCHW conv. x: (N, C, H, W), w: (C, 1, KH, KW), bias: (C,)|None."""
    n, c, h, w_ = x.shape
    kh, kw = w.shape[2], w.shape[3]
    xp = jnp.pad(x, ((0, 0), (0, 0), (padding, padding), (padding, padding)))
    ih, iw = xp.shape[2], xp.shape[3]
    oh = (ih - kh) // stride + 1
    ow = (iw - kw) // stride + 1

    bc = min(bc, c)
    if c % bc != 0:  # channel blocks must tile evenly; fall back to whole C
        bc = c
    wk = w.reshape(c, kh, kw)

    kern = functools.partial(_dw_kernel, kh=kh, kw=kw, stride=stride, act=act)
    if bias is None:
        def kern_nb(x_ref, w_ref, o_ref):
            return kern(x_ref, w_ref, None, o_ref)
        fn = kern_nb
        extra_specs, extra_args = [], []
    else:
        fn = kern
        extra_specs = [pl.BlockSpec((1, bc), lambda b, cc: (0, cc))]
        extra_args = [bias.reshape(1, c)]

    out = pl.pallas_call(
        fn,
        grid=(n, c // bc),
        in_specs=[
            pl.BlockSpec((1, bc, ih, iw), lambda b, cc: (b, cc, 0, 0)),
            pl.BlockSpec((bc, kh, kw), lambda b, cc: (cc, 0, 0)),
            *extra_specs,
        ],
        out_specs=pl.BlockSpec((1, bc, oh, ow), lambda b, cc: (b, cc, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c, oh, ow), x.dtype),
        interpret=interpret,
    )(xp, wk, *extra_args)
    return out


def dense(x, w, bias=None, *, act: str = "none", interpret: bool = True,
          bm: int = mm.DEFAULT_BM, bn: int = mm.DEFAULT_BN,
          bk: int = mm.DEFAULT_BK):
    """Fully-connected layer on the Pallas matmul. x: (N, K), w: (K, O)."""
    return mm.matmul(x, w, bias, act=act, bm=bm, bn=bn, bk=bk,
                     interpret=interpret)
