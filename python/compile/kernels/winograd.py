"""L1 Pallas kernel: Winograd F(2×2, 3×3) convolution.

The §V-E comparator (DiCecco et al., "Caffeinated FPGAs") is a hand-
optimized Winograd 3×3 engine; this kernel implements the same F(2,3)
transform family so the comparison in `benches/sec5e_related_work.rs` is
apples-to-apples at the algorithm level. Winograd computes each 2×2 output
tile from a 4×4 input tile with 16 multiplies instead of 36 — a 2.25×
multiply reduction for 3×3/s1 convolutions.

Structure: input/filter transforms are small dense matmuls applied as
layout ops; the element-wise product over the 16 transform points is a
batched (16, C) × (C, K) contraction that flows through the Pallas matmul
kernel — so the MXU does all heavy lifting, as in conv.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import matmul as mm
from . import ref

# F(2x2, 3x3) transform matrices (Lavin & Gray, 2016).
_B_T = jnp.array(
    [[1, 0, -1, 0],
     [0, 1, 1, 0],
     [0, -1, 1, 0],
     [0, 1, 0, -1]], jnp.float32)
_G = jnp.array(
    [[1, 0, 0],
     [0.5, 0.5, 0.5],
     [0.5, -0.5, 0.5],
     [0, 0, 1]], jnp.float32)
_A_T = jnp.array(
    [[1, 1, 1, 0],
     [0, 1, -1, -1]], jnp.float32)


def _filter_transform(w):
    """(O, C, 3, 3) → (16, C, O): U = G g Gᵀ per (o, c)."""
    o, c = w.shape[0], w.shape[1]
    u = jnp.einsum("ij,ocjk,lk->ocil", _G, w.astype(jnp.float32), _G)
    return u.reshape(o, c, 16).transpose(2, 1, 0)  # (16, C, O)


def _input_transform(x, tiles_h, tiles_w):
    """(N, C, H, W) padded → (16, N·tiles, C): V = Bᵀ d B per 4×4 tile."""
    n, c = x.shape[0], x.shape[1]
    # Gather overlapping 4×4 tiles with stride 2.
    d = jax.lax.conv_general_dilated_patches(
        x.astype(jnp.float32),
        filter_shape=(4, 4),
        window_strides=(2, 2),
        padding=[(0, 0), (0, 0)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )  # (N, C·16, th, tw)
    d = d.reshape(n, c, 4, 4, tiles_h, tiles_w)
    v = jnp.einsum("ij,ncjkhw,lk->ncilhw", _B_T, d, _B_T)
    v = v.reshape(n, c, 16, tiles_h * tiles_w)
    return v.transpose(2, 0, 3, 1).reshape(16, n * tiles_h * tiles_w, c)


@functools.partial(jax.jit, static_argnames=("padding", "interpret"))
def conv2d_winograd(x, w, bias=None, *, padding: int = 1,
                    interpret: bool = True):
    """3×3 stride-1 conv via Winograd F(2,3). x: (N,C,H,W), w: (O,C,3,3)."""
    assert w.shape[2] == 3 and w.shape[3] == 3, "winograd kernel is 3x3 only"
    n, c, h, w_in = x.shape
    o = w.shape[0]
    oh, ow = h + 2 * padding - 2, w_in + 2 * padding - 2

    # Pad input so the 4×4/stride-2 tiling covers the output exactly.
    tiles_h, tiles_w = -(-oh // 2), -(-ow // 2)
    need_h = 2 * tiles_h + 2
    need_w = 2 * tiles_w + 2
    xp = jnp.pad(x, ((0, 0), (0, 0),
                     (padding, need_h - h - padding),
                     (padding, need_w - w_in - padding)))

    u = _filter_transform(w)                     # (16, C, O)
    v = _input_transform(xp, tiles_h, tiles_w)   # (16, T, C)

    # 16 independent (T, C) @ (C, O) products through the Pallas matmul.
    def one_point(i, acc):
        m = mm.matmul(v[i], u[i], bm=512, bn=128,
                      bk=min(mm.DEFAULT_BK, max(8, c)), interpret=interpret)
        return acc.at[i].set(m)

    t = v.shape[1]
    out = jnp.zeros((16, t, o), jnp.float32)
    for i in range(16):  # unrolled: 16 pallas_call sites in the HLO
        out = one_point(i, out)

    # Output transform: Y = Aᵀ m A per tile.
    m = out.reshape(4, 4, n, tiles_h, tiles_w, o)
    y = jnp.einsum("ij,jkntwo,lk->niltwo", _A_T, m, _A_T)  # (N,2,2,th,tw,O)
    y = y.transpose(0, 5, 3, 1, 4, 2).reshape(n, o, 2 * tiles_h, 2 * tiles_w)
    y = y[:, :, :oh, :ow]
    if bias is not None:
        y = y + bias.astype(jnp.float32)[None, :, None, None]
    return y.astype(x.dtype)


def multiply_count(n, c, h, w, o, padding: int = 1):
    """Multiplies used by F(2,3) vs direct 3×3 — the 2.25× claim."""
    oh, ow = h + 2 * padding - 2, w + 2 * padding - 2
    tiles = -(-oh // 2) * (-(-ow // 2))
    wino = 16 * tiles * c * o * n
    direct = oh * ow * 9 * c * o * n
    return wino, direct
