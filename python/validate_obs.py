#!/usr/bin/env python3
"""Validate fpga-flow observability exports against the committed schemas.

Two subcommands, one per export format:

    python3 python/validate_obs.py trace   target/trace-lenet5.json
    python3 python/validate_obs.py metrics target/metrics-lenet5.prom

``trace`` validates a Chrome trace-event file (written by ``fpga-flow
profile`` or ``--trace-out`` on any subcommand) against
``schemas/trace.schema.json`` and then performs structural checks the
schema cannot express: the first event is the process_name metadata
event, span ids are unique, and every parent_id refers to a span that
exists.  Optional ``--expect-cats`` / ``--expect-names`` assert that
specific categories or span names appear at least once (CI uses this to
pin the four compile stages and the serve request lifecycle).

``metrics`` parses Prometheus text exposition format into the canonical
object described by ``schemas/metrics.schema.json`` (one entry per
metric family), validates it, checks every family listed in the
schema's ``x-required-families`` extension is present, and enforces the
histogram rules (le labels, cumulative monotone buckets, terminal
+Inf == _count, _sum/_count present).

Only the standard library is used: the JSON-Schema subset interpreter
below covers exactly the keywords the two committed schemas need
($ref into #/definitions, oneOf, type, const, enum, required,
properties, additionalProperties:false, items, minItems, minimum,
minLength, pattern).
"""

import argparse
import json
import math
import re
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
SCHEMA_DIR = REPO_ROOT / "schemas"

# ---------------------------------------------------------------------------
# Minimal JSON-Schema (draft-07 subset) interpreter
# ---------------------------------------------------------------------------

_TYPES = {
    "object": dict,
    "array": list,
    "string": str,
    "number": (int, float),
    "boolean": bool,
    "null": type(None),
}


def _resolve_ref(root, ref):
    if not ref.startswith("#/"):
        raise ValueError(f"unsupported $ref: {ref}")
    node = root
    for part in ref[2:].split("/"):
        node = node[part]
    return node


def _type_ok(value, name):
    py = _TYPES[name]
    if name == "number":
        return isinstance(value, py) and not isinstance(value, bool)
    if name == "boolean":
        return isinstance(value, bool)
    return isinstance(value, py)


def schema_errors(value, schema, root, path="$"):
    """All violations of `schema` by `value`, as human-readable strings."""
    errs = []
    if "$ref" in schema:
        return schema_errors(value, _resolve_ref(root, schema["$ref"]), root, path)

    if "oneOf" in schema:
        branches = [schema_errors(value, s, root, path) for s in schema["oneOf"]]
        matches = sum(1 for b in branches if not b)
        if matches != 1:
            detail = "; ".join(b[0] for b in branches if b)[:400]
            errs.append(f"{path}: matched {matches} of {len(branches)} oneOf branches ({detail})")
        return errs

    if "const" in schema and value != schema["const"]:
        errs.append(f"{path}: expected {schema['const']!r}, got {value!r}")
    if "enum" in schema and value not in schema["enum"]:
        errs.append(f"{path}: {value!r} not in {schema['enum']}")
    if "type" in schema and not _type_ok(value, schema["type"]):
        errs.append(f"{path}: expected {schema['type']}, got {type(value).__name__}")
        return errs  # child keywords assume the type held

    if isinstance(value, dict):
        for key in schema.get("required", []):
            if key not in value:
                errs.append(f"{path}: missing required key {key!r}")
        props = schema.get("properties", {})
        for key, sub in props.items():
            if key in value:
                errs.extend(schema_errors(value[key], sub, root, f"{path}.{key}"))
        if schema.get("additionalProperties") is False:
            for key in value:
                if key not in props:
                    errs.append(f"{path}: unexpected key {key!r}")

    if isinstance(value, list):
        if "minItems" in schema and len(value) < schema["minItems"]:
            errs.append(f"{path}: {len(value)} items < minItems {schema['minItems']}")
        if "items" in schema:
            for i, item in enumerate(value):
                errs.extend(schema_errors(item, schema["items"], root, f"{path}[{i}]"))

    if isinstance(value, (int, float)) and not isinstance(value, bool):
        if "minimum" in schema and value < schema["minimum"]:
            errs.append(f"{path}: {value} < minimum {schema['minimum']}")

    if isinstance(value, str):
        if "minLength" in schema and len(value) < schema["minLength"]:
            errs.append(f"{path}: length {len(value)} < minLength {schema['minLength']}")
        if "pattern" in schema and not re.search(schema["pattern"], value):
            errs.append(f"{path}: {value!r} does not match /{schema['pattern']}/")

    return errs


def load_schema(name):
    with open(SCHEMA_DIR / name, encoding="utf-8") as f:
        return json.load(f)


# ---------------------------------------------------------------------------
# trace
# ---------------------------------------------------------------------------

def validate_trace(path, expect_cats, expect_names):
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    schema = load_schema("trace.schema.json")
    errs = schema_errors(doc, schema, schema)

    events = doc.get("traceEvents", [])
    spans = [e for e in events if isinstance(e, dict) and e.get("ph") == "X"]
    metas = [e for e in events if isinstance(e, dict) and e.get("ph") == "M"]

    if not events or events[0].get("ph") != "M":
        errs.append("traceEvents[0]: must be the process_name metadata event")
    if len(metas) != 1:
        errs.append(f"expected exactly 1 metadata event, found {len(metas)}")
    if not spans:
        errs.append("trace contains no complete (ph 'X') span events")

    ids = [e.get("args", {}).get("span_id") for e in spans]
    if len(ids) != len(set(ids)):
        errs.append("span_id values are not unique")
    known = set(ids)
    for e in spans:
        parent = e.get("args", {}).get("parent_id")
        if parent is not None and parent not in known:
            errs.append(f"span {e.get('name')!r}: parent_id {parent} refers to no recorded span")

    cats = {e.get("cat") for e in spans}
    names = {e.get("name") for e in spans}
    for cat in expect_cats:
        if cat not in cats:
            errs.append(f"expected category {cat!r} absent (have: {sorted(c for c in cats if c)})")
    for name in expect_names:
        if name not in names:
            errs.append(f"expected span name {name!r} absent")

    return errs, f"{len(spans)} spans, {len(cats)} categories"


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

_SAMPLE_RE = re.compile(
    r'^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)'
    r'(?:\{(?P<labels>[^}]*)\})?'
    r'\s+(?P<value>[^\s]+)$'
)
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def parse_prometheus(text):
    """Prometheus text → the canonical {families: [...]} object, plus
    parse errors."""
    families, errs = [], []
    current = None
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# HELP "):
            parts = line[len("# HELP "):].split(" ", 1)
            current = {"name": parts[0], "help": parts[1] if len(parts) > 1 else "",
                       "type": "untyped", "samples": []}
            families.append(current)
        elif line.startswith("# TYPE "):
            parts = line[len("# TYPE "):].split(" ", 1)
            if current is None or current["name"] != parts[0]:
                errs.append(f"line {lineno}: TYPE for {parts[0]!r} without preceding HELP")
            else:
                current["type"] = parts[1].strip() if len(parts) > 1 else "untyped"
        elif line.startswith("#"):
            continue
        else:
            m = _SAMPLE_RE.match(line)
            if not m:
                errs.append(f"line {lineno}: unparseable sample line {line!r}")
                continue
            try:
                value = float(m.group("value"))
            except ValueError:
                errs.append(f"line {lineno}: non-numeric value {m.group('value')!r}")
                continue
            labels = dict(_LABEL_RE.findall(m.group("labels") or ""))
            if current is None or not m.group("name").startswith(current["name"]):
                errs.append(f"line {lineno}: sample {m.group('name')!r} outside its family block")
                continue
            current["samples"].append(
                {"name": m.group("name"), "labels": labels, "value": value})
    return {"families": families}, errs


def check_histogram(fam):
    errs = []
    name = fam["name"]
    buckets = [s for s in fam["samples"] if s["name"] == f"{name}_bucket"]
    sums = [s for s in fam["samples"] if s["name"] == f"{name}_sum"]
    counts = [s for s in fam["samples"] if s["name"] == f"{name}_count"]
    if not buckets:
        errs.append(f"histogram {name}: no _bucket samples")
    if len(sums) != 1 or len(counts) != 1:
        errs.append(f"histogram {name}: expected exactly one _sum and one _count")
        return errs
    prev = -math.inf
    prev_count = -1.0
    for s in buckets:
        le = s["labels"].get("le")
        if le is None:
            errs.append(f"histogram {name}: bucket without le label")
            continue
        bound = math.inf if le == "+Inf" else float(le)
        if bound <= prev:
            errs.append(f"histogram {name}: le bounds not strictly increasing at {le!r}")
        if s["value"] < prev_count:
            errs.append(f"histogram {name}: cumulative count decreases at le={le!r}")
        prev, prev_count = bound, s["value"]
    if not buckets or buckets[-1]["labels"].get("le") != "+Inf":
        errs.append(f"histogram {name}: last bucket must be le=\"+Inf\"")
    elif buckets[-1]["value"] != counts[0]["value"]:
        errs.append(
            f"histogram {name}: +Inf bucket {buckets[-1]['value']} != _count {counts[0]['value']}")
    return errs


def validate_metrics(path):
    with open(path, encoding="utf-8") as f:
        text = f.read()
    doc, errs = parse_prometheus(text)
    schema = load_schema("metrics.schema.json")
    errs.extend(schema_errors(doc, schema, schema))

    have = {fam["name"] for fam in doc["families"]}
    for req in schema.get("x-required-families", []):
        if req not in have:
            errs.append(f"required metric family {req!r} absent")
    for fam in doc["families"]:
        if fam["type"] == "histogram":
            errs.extend(check_histogram(fam))
        elif fam["type"] == "counter":
            for s in fam["samples"]:
                if s["value"] < 0 or not math.isfinite(s["value"]):
                    errs.append(f"counter {fam['name']}: invalid value {s['value']}")

    n_hist = sum(1 for fam in doc["families"] if fam["type"] == "histogram")
    return errs, f"{len(doc['families'])} families ({n_hist} histograms)"


# ---------------------------------------------------------------------------
# cli
# ---------------------------------------------------------------------------

def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)
    t = sub.add_parser("trace", help="validate a Chrome trace-event export")
    t.add_argument("path")
    t.add_argument("--expect-cats", default="",
                   help="comma-separated categories that must appear")
    t.add_argument("--expect-names", default="",
                   help="comma-separated span names that must appear")
    m = sub.add_parser("metrics", help="validate a Prometheus text export")
    m.add_argument("path")
    args = ap.parse_args(argv)

    if args.cmd == "trace":
        cats = [c for c in args.expect_cats.split(",") if c]
        names = [n for n in args.expect_names.split(",") if n]
        errs, summary = validate_trace(args.path, cats, names)
    else:
        errs, summary = validate_metrics(args.path)

    if errs:
        for e in errs:
            print(f"FAIL {args.path}: {e}", file=sys.stderr)
        return 1
    print(f"OK {args.path}: {summary}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
