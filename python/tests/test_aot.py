"""AOT pipeline: HLO text must round-trip through the xla_extension parser
(the exact path the rust runtime uses) and execute with correct numerics."""
import json
import pathlib

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

ARTIFACTS = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


def test_hlo_text_nonempty_and_parseable():
    text = aot.lower_network("lenet5", "ref", 1)
    assert "ENTRY" in text and "f32[" in text
    from jax._src.lib import xla_client as xc
    # The rust side re-parses this text; the python parser is the same C++.
    mod = xc._xla.hlo_module_from_text(text)
    assert mod is not None


def test_lower_matmul_contains_dot_or_loop():
    text = aot.lower_matmul(64, 64, 64)
    assert "ENTRY" in text


def test_weights_blob_layout(tmp_path):
    meta = aot.write_weights("lenet5", tmp_path)
    blob = (tmp_path / "lenet5.weights.bin").read_bytes()
    assert len(blob) == meta["total_bytes"]
    pset = model.lenet5_params()
    # Round-trip the first and last parameters from raw bytes.
    first = meta["params"][0]
    arr = np.frombuffer(blob, np.float32,
                        count=first["nbytes"] // 4,
                        offset=first["offset"]).reshape(first["shape"])
    np.testing.assert_array_equal(arr, pset.values[0])
    last = meta["params"][-1]
    arr = np.frombuffer(blob, np.float32, count=last["nbytes"] // 4,
                        offset=last["offset"]).reshape(last["shape"])
    np.testing.assert_array_equal(arr, pset.values[-1])


@pytest.mark.skipif(not (ARTIFACTS / "manifest.json").exists(),
                    reason="run `make artifacts` first")
def test_manifest_index_consistent():
    index = json.loads((ARTIFACTS / "manifest.json").read_text())
    for net, entry in index["networks"].items():
        assert (ARTIFACTS / entry["weights_file"]).exists()
        total = sum(p["nbytes"] for p in entry["params"])
        assert total == entry["total_bytes"]
        for exe in entry["executables"]:
            f = ARTIFACTS / exe["file"]
            assert f.exists(), f
            assert f.stat().st_size > 100


def test_hlo_text_parse_roundtrip():
    """HLO text must survive parse → proto → reparse: this is the exact
    interchange the rust runtime performs (HloModuleProto::from_text_file).
    Execution-level round-trip numerics are covered by the rust integration
    test `runtime::tests` + examples/end_to_end.rs."""
    from jax._src.lib import xla_client as xc

    text = aot.lower_network("lenet5", "ref", 1)
    mod = xc._xla.hlo_module_from_text(text)
    proto = mod.as_serialized_hlo_module_proto()
    assert len(proto) > 1000
    # parameter count: image + 10 weight tensors
    n_params = len(model.lenet5_params().values)
    assert text.count("parameter(") >= n_params + 1


def test_pallas_and_ref_hlo_have_same_signature():
    """Both impl paths must expose the identical (image, *weights) → logits
    ABI so the rust runtime can swap them freely."""
    t_ref = aot.lower_network("lenet5", "ref", 1)
    t_pal = aot.lower_network("lenet5", "pallas", 1)
    assert t_ref.count("ENTRY") == t_pal.count("ENTRY") == 1
    import re

    def entry_params(t):
        return len(re.findall(r"parameter\(\d+\)", t.split("ENTRY")[1]))

    assert entry_params(t_ref) == entry_params(t_pal)
