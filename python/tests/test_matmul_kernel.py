"""L1 correctness: Pallas tiled matmul vs pure-jnp oracle.

Hypothesis sweeps shapes (ragged, tiny, tile-aligned), block sizes, dtypes,
and the fused bias/activation epilogue.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as mm
from compile.kernels import ref

TOL = dict(rtol=2e-4, atol=2e-4)


def _rand(shape, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype)


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 200),
    n=st.integers(1, 200),
)
def test_matmul_ragged_shapes(m, k, n):
    a, b = _rand((m, k), seed=m * 7 + k), _rand((k, n), seed=n * 13 + k)
    np.testing.assert_allclose(mm.matmul(a, b), ref.matmul(a, b), **TOL)


@settings(max_examples=20, deadline=None)
@given(
    bm=st.sampled_from([8, 16, 32, 64, 128]),
    bn=st.sampled_from([8, 16, 32, 64, 128]),
    bk=st.sampled_from([8, 16, 32, 64, 128]),
)
def test_matmul_block_sizes(bm, bn, bk):
    """Any legal tile produces the same numbers — the schedule only moves
    work between grid steps (the paper's claim that unroll/tile factors are
    performance-only knobs)."""
    a, b = _rand((96, 112), seed=1), _rand((112, 80), seed=2)
    got = mm.matmul(a, b, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, ref.matmul(a, b), **TOL)


@pytest.mark.parametrize("act", ["none", "relu", "relu6", "tanh"])
@pytest.mark.parametrize("with_bias", [False, True])
def test_matmul_fused_epilogue(act, with_bias):
    a, b = _rand((70, 45), seed=3), _rand((45, 33), seed=4)
    bias = _rand((33,), seed=5) if with_bias else None
    got = mm.matmul(a, b, bias, act=act)
    want = ref.matmul_bias_act(a, b, bias, act)
    np.testing.assert_allclose(got, want, **TOL)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    a, b = _rand((64, 64), dtype, 6), _rand((64, 64), dtype, 7)
    got = mm.matmul(a, b)
    want = ref.matmul(a, b)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else TOL
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)
    assert got.dtype == dtype


def test_matmul_identity():
    a = _rand((40, 40), seed=8)
    eye = jnp.eye(40, dtype=jnp.float32)
    np.testing.assert_allclose(mm.matmul(a, eye), a, **TOL)


def test_matmul_zeros():
    a = _rand((17, 23), seed=9)
    z = jnp.zeros((23, 31), jnp.float32)
    np.testing.assert_allclose(mm.matmul(a, z), jnp.zeros((17, 31)), **TOL)


def test_matmul_single_element():
    a = jnp.asarray([[3.0]], jnp.float32)
    b = jnp.asarray([[4.0]], jnp.float32)
    np.testing.assert_allclose(mm.matmul(a, b), [[12.0]], **TOL)


def test_matmul_shape_mismatch_raises():
    a, b = _rand((4, 5)), _rand((6, 4))
    with pytest.raises(AssertionError):
        mm.matmul(a, b)


def test_vmem_bytes_monotone():
    """Bigger tiles never shrink the VMEM working set (used by §Perf model)."""
    prev = 0
    for b in [32, 64, 128, 256]:
        cur = mm.vmem_bytes(b, b, b)
        assert cur > prev
        prev = cur


def test_mxu_utilization_bounds():
    assert mm.mxu_utilization(128, 128, 128, 128, 128, 128) == 1.0
    u = mm.mxu_utilization(100, 100, 100, 128, 128, 128)
    assert 0.0 < u < 1.0
    # exactly the padding ratio
    np.testing.assert_allclose(u, (100 ** 3) / (128 ** 3))
