"""L1 correctness: conv2d / depthwise / dense / pooling Pallas kernels vs
lax-based oracles, across shape/stride/padding sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv as kconv
from compile.kernels import pool as kpool
from compile.kernels import ref

TOL = dict(rtol=5e-4, atol=5e-4)


def _rand(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 3),
    cin=st.integers(1, 8),
    cout=st.integers(1, 12),
    hw=st.integers(6, 20),
    k=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
)
def test_conv2d_shapes(n, cin, cout, hw, k, stride):
    pad = k // 2
    x = _rand((n, cin, hw, hw), seed=hw * 31 + cin)
    w = _rand((cout, cin, k, k), seed=cout * 17 + k, scale=0.3)
    got = kconv.conv2d(x, w, stride=stride, padding=pad)
    want = ref.conv2d(x, w, stride=stride, padding=pad)
    np.testing.assert_allclose(got, want, **TOL)


@pytest.mark.parametrize("k,stride,pad", [(5, 1, 0), (7, 2, 3), (1, 1, 0), (3, 2, 1)])
def test_conv2d_paper_layer_geometries(k, stride, pad):
    """The filter/stride groups the paper parameterizes kernels by (§IV-H):
    7×7/2 (ResNet conv1), 3×3 (workhorse), 1×1 (MobileNet pointwise), 5×5
    (LeNet)."""
    x = _rand((1, 4, 16, 16), seed=1)
    w = _rand((6, 4, k, k), seed=2, scale=0.3)
    b = _rand((6,), seed=3)
    got = kconv.conv2d(x, w, b, stride=stride, padding=pad, act="relu")
    want = ref.conv2d(x, w, stride=stride, padding=pad, bias=b, act="relu")
    np.testing.assert_allclose(got, want, **TOL)


def test_conv2d_matches_im2col_oracle():
    x = _rand((2, 3, 12, 12), seed=4)
    w = _rand((5, 3, 3, 3), seed=5, scale=0.3)
    got = kconv.conv2d(x, w, stride=1, padding=1)
    want = ref.conv2d_im2col(x, w, stride=1, padding=1)
    np.testing.assert_allclose(got, want, **TOL)


@settings(max_examples=20, deadline=None)
@given(
    c=st.integers(1, 16),
    hw=st.integers(6, 18),
    stride=st.sampled_from([1, 2]),
    bc=st.sampled_from([4, 8, 32]),
)
def test_depthwise_shapes(c, hw, stride, bc):
    x = _rand((2, c, hw, hw), seed=c * 3 + hw)
    w = _rand((c, 1, 3, 3), seed=c, scale=0.3)
    got = kconv.depthwise_conv2d(x, w, stride=stride, padding=1, bc=bc)
    want = ref.depthwise_conv2d(x, w, stride=stride, padding=1)
    np.testing.assert_allclose(got, want, **TOL)


def test_depthwise_bias_act():
    x = _rand((1, 8, 10, 10), seed=6)
    w = _rand((8, 1, 3, 3), seed=7, scale=0.3)
    b = _rand((8,), seed=8)
    got = kconv.depthwise_conv2d(x, w, b, stride=1, padding=1, act="relu6")
    want = ref.depthwise_conv2d(x, w, stride=1, padding=1, bias=b, act="relu6")
    np.testing.assert_allclose(got, want, **TOL)


def test_dense_matches_ref():
    x = _rand((9, 400), seed=9)
    w = _rand((400, 120), seed=10, scale=0.1)
    b = _rand((120,), seed=11)
    got = kconv.dense(x, w, b, act="tanh")
    want = ref.matmul_bias_act(x, w, b, "tanh")
    np.testing.assert_allclose(got, want, **TOL)


@settings(max_examples=20, deadline=None)
@given(
    c=st.integers(1, 8),
    hw=st.sampled_from([8, 12, 14, 16]),
    k=st.sampled_from([2, 3]),
    mode=st.sampled_from(["max", "avg"]),
)
def test_pool_shapes(c, hw, k, mode):
    x = _rand((2, c, hw, hw), seed=c * 5 + hw)
    got = kpool.pool2d(x, k=k, mode=mode)
    want = (ref.maxpool2d if mode == "max" else ref.avgpool2d)(x, k)
    np.testing.assert_allclose(got, want, **TOL)


def test_pool_stride_padding():
    """ResNet's 3×3/2 pad-1 maxpool — padding fills -inf, not zeros."""
    x = _rand((1, 4, 14, 14), seed=12)
    got = kpool.pool2d(x, k=3, stride=2, padding=1, mode="max")
    want = ref.maxpool2d(x, 3, 2, 1)
    np.testing.assert_allclose(got, want, **TOL)


def test_global_avgpool():
    x = _rand((3, 7, 9, 9), seed=13)
    np.testing.assert_allclose(kpool.global_avgpool(x),
                               ref.global_avgpool(x), **TOL)


def test_pool_negative_inputs_max():
    """All-negative maps: max-pool must not leak the 0 padding value."""
    x = -jnp.abs(_rand((1, 2, 8, 8), seed=14)) - 1.0
    got = kpool.pool2d(x, k=3, stride=2, padding=1, mode="max")
    want = ref.maxpool2d(x, 3, 2, 1)
    np.testing.assert_allclose(got, want, **TOL)
    assert np.all(np.asarray(got) < 0)
