"""L1 correctness: int8 Pallas matmul vs exact integer reference, and the
quantize→matmul→dequantize path vs fp32 within quantization error."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import quant, ref


def _rand(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 96), k=st.integers(1, 96), n=st.integers(1, 96))
def test_int8_matmul_is_exact(m, k, n):
    rng = np.random.default_rng(m * 31 + k * 7 + n)
    a = jnp.asarray(rng.integers(-127, 128, size=(m, k)), jnp.int8)
    b = jnp.asarray(rng.integers(-127, 128, size=(k, n)), jnp.int8)
    got = quant.matmul_int8(a, b)
    want = np.asarray(a, np.int64) @ np.asarray(b, np.int64)
    # int32 accumulation is exact for these ranges (k ≤ 96 × 127² < 2³¹)
    np.testing.assert_array_equal(np.asarray(got, np.int64), want)
    assert got.dtype == jnp.int32


def test_quantize_symmetric_roundtrip():
    x = _rand((40, 40), seed=1, scale=3.0)
    q, s = quant.quantize_symmetric(x)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(q, np.float32) * float(s) - np.asarray(x))
    # max quantization error ≤ scale/2
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_dequantized_matmul_close_to_fp32():
    a = _rand((64, 80), seed=2)
    b = _rand((80, 48), seed=3)
    got = quant.matmul_quantized(a, b)
    want = np.asarray(ref.matmul(a, b))
    # int8 symmetric quantization: relative error a few percent
    denom = np.abs(want).mean()
    rel = np.abs(np.asarray(got) - want).mean() / denom
    assert rel < 0.05, rel


def test_zero_inputs():
    a = jnp.zeros((16, 16), jnp.int8)
    b = jnp.zeros((16, 16), jnp.int8)
    got = quant.matmul_int8(a, b)
    assert np.all(np.asarray(got) == 0)
