"""L2 correctness: full networks — pallas path vs XLA-ref path, shapes,
parameter accounting, determinism."""
import numpy as np
import pytest

from compile import model

# MACs per network as the rust graph layer computes them; cross-checked
# here from the python parameter/shape definitions.
EXPECTED_PARAM_COUNTS = {
    "lenet5": 61_706,
    "mobilenet_v1": 4_253_864,
    "resnet34": 21_814_696,
}


@pytest.mark.parametrize("net", list(model.NETWORKS))
def test_param_counts(net):
    pset = model.NETWORKS[net]["params"]()
    total = sum(int(np.prod(v.shape)) for v in pset.values)
    assert total == EXPECTED_PARAM_COUNTS[net], f"{net}: {total}"


@pytest.mark.parametrize("net", list(model.NETWORKS))
def test_ref_output_shape(net):
    x, params, _ = model.make_inputs(net, batch=2)
    out = model.NETWORKS[net]["apply"](params, x, impl="ref")
    assert out.shape == (2, model.NETWORKS[net]["num_classes"])
    assert np.isfinite(np.asarray(out)).all()


def test_lenet5_pallas_matches_ref():
    x, params, _ = model.make_inputs("lenet5", batch=4)
    ref_out = model.NETWORKS["lenet5"]["apply"](params, x, impl="ref")
    pal_out = model.NETWORKS["lenet5"]["apply"](params, x, impl="pallas")
    np.testing.assert_allclose(np.asarray(pal_out), np.asarray(ref_out),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.slow
@pytest.mark.parametrize("net", ["mobilenet_v1", "resnet34"])
def test_large_net_pallas_matches_ref(net):
    x, params, _ = model.make_inputs(net, batch=1)
    ref_out = model.NETWORKS[net]["apply"](params, x, impl="ref")
    pal_out = model.NETWORKS[net]["apply"](params, x, impl="pallas")
    np.testing.assert_allclose(np.asarray(pal_out), np.asarray(ref_out),
                               rtol=5e-3, atol=5e-3)


def test_weights_deterministic():
    a = model.NETWORKS["lenet5"]["params"]()
    b = model.NETWORKS["lenet5"]["params"]()
    assert a.names == b.names
    for va, vb in zip(a.values, b.values):
        np.testing.assert_array_equal(va, vb)


def test_make_inputs_deterministic():
    xa, _, _ = model.make_inputs("lenet5", batch=3, seed=42)
    xb, _, _ = model.make_inputs("lenet5", batch=3, seed=42)
    np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))
    xc, _, _ = model.make_inputs("lenet5", batch=3, seed=43)
    assert not np.array_equal(np.asarray(xa), np.asarray(xc))


def test_param_names_unique():
    for net in model.NETWORKS:
        names = model.NETWORKS[net]["params"]().names
        assert len(names) == len(set(names)), f"dup param names in {net}"


def test_mobilenet_block_structure():
    """13 separable blocks, channel doubling at stride-2 points (§V-A)."""
    assert len(model.MOBILENET_BLOCKS) == 13
    assert model.MOBILENET_BLOCKS[-1][1] == 1024
    strides = [s for s, _ in model.MOBILENET_BLOCKS]
    assert strides.count(2) == 4


def test_resnet34_stage_structure():
    assert [n for _, n in model.RESNET34_STAGES] == [3, 4, 6, 3]
    # 34 = 1 (conv1) + 2 * (3+4+6+3) + 1 (fc)
    assert 1 + 2 * sum(n for _, n in model.RESNET34_STAGES) + 1 == 34
