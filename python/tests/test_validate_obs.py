"""The observability-export validator must accept what the Rust emitters
produce and reject the failure shapes CI exists to catch.  The fixtures
here mirror `obs::Trace::to_chrome_json` and
`obs::Registry::render_prometheus` byte-for-byte in structure; if either
Rust emitter changes shape, update the schema AND these fixtures
together."""
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import validate_obs


def _trace(events):
    return {
        "displayTimeUnit": "ms",
        "traceEvents": [
            {"args": {"name": "fpga-flow"}, "name": "process_name",
             "ph": "M", "pid": 1, "tid": 0},
            *events,
        ],
    }


def _span(span_id, cat="compile", name="lower", parent=None, **args):
    a = {"span_id": span_id, **args}
    if parent is not None:
        a["parent_id"] = parent
    return {"args": a, "cat": cat, "dur": 10, "name": name, "ph": "X",
            "pid": 1, "tid": 1, "ts": 0}


def _write_trace(tmp_path, doc):
    p = tmp_path / "trace.json"
    p.write_text(json.dumps(doc))
    return str(p)


def test_valid_trace_passes(tmp_path):
    doc = _trace([
        _span(1, "compile", "lower"),
        _span(2, "pass", "fuse_conv_relu", parent=1, matched=3),
        _span(3, "serve", "request", ok=True),
    ])
    errs, summary = validate_obs.validate_trace(
        _write_trace(tmp_path, doc), ["compile", "pass", "serve"], ["lower", "request"])
    assert errs == []
    assert "3 spans" in summary


def test_unknown_category_and_dangling_parent_fail(tmp_path):
    doc = _trace([_span(1, "nonsense"), _span(2, parent=99)])
    errs, _ = validate_obs.validate_trace(_write_trace(tmp_path, doc), [], [])
    assert any("oneOf" in e for e in errs)
    assert any("parent_id 99" in e for e in errs)


def test_missing_expected_stage_fails(tmp_path):
    doc = _trace([_span(1, "compile", "lower")])
    errs, _ = validate_obs.validate_trace(
        _write_trace(tmp_path, doc), ["compile"], ["synthesize"])
    assert any("'synthesize' absent" in e for e in errs)


def test_missing_metadata_event_fails(tmp_path):
    doc = {"displayTimeUnit": "ms", "traceEvents": [_span(1)]}
    errs, _ = validate_obs.validate_trace(_write_trace(tmp_path, doc), [], [])
    assert any("metadata" in e for e in errs)


PROM_FAMILIES = {
    "flow_analyses_total": ("counter", "flow_analyses_total 6"),
    "flow_exec_buffers": ("gauge", "flow_exec_buffers 12"),
    "flow_exec_scratch_checkouts": ("gauge", "flow_exec_scratch_checkouts 24"),
    "flow_exec_scratch_hits": ("gauge", "flow_exec_scratch_hits 12"),
    "flow_lower_total": ("counter", "flow_lower_total 1"),
    "flow_passes_applied_total": ("counter", "flow_passes_applied_total 9"),
    "flow_serve_batch_size": ("histogram", "\n".join([
        'flow_serve_batch_size_bucket{le="1"} 2',
        'flow_serve_batch_size_bucket{le="2"} 5',
        'flow_serve_batch_size_bucket{le="+Inf"} 5',
        "flow_serve_batch_size_sum 8",
        "flow_serve_batch_size_count 5",
    ])),
    "flow_serve_batches_total": ("counter", "flow_serve_batches_total 5"),
    "flow_serve_completed_total": ("counter", "flow_serve_completed_total 100"),
    "flow_serve_latency_p99_us": ("gauge", "flow_serve_latency_p99_us 1234.5"),
    "flow_serve_submitted_total": ("counter", "flow_serve_submitted_total 100"),
}


def _prom_text(overrides=None, drop=()):
    lines = []
    for name, (kind, body) in sorted(PROM_FAMILIES.items()):
        if name in drop:
            continue
        lines.append(f"# HELP {name} help text for {name}")
        lines.append(f"# TYPE {name} {kind}")
        lines.append((overrides or {}).get(name, body))
    return "\n".join(lines) + "\n"


def _write_prom(tmp_path, text):
    p = tmp_path / "metrics.prom"
    p.write_text(text)
    return str(p)


def test_valid_metrics_pass(tmp_path):
    errs, summary = validate_obs.validate_metrics(_write_prom(tmp_path, _prom_text()))
    assert errs == []
    assert "1 histograms" in summary


def test_missing_required_family_fails(tmp_path):
    errs, _ = validate_obs.validate_metrics(
        _write_prom(tmp_path, _prom_text(drop={"flow_lower_total"})))
    assert any("flow_lower_total" in e for e in errs)


def test_non_monotone_histogram_fails(tmp_path):
    bad = "\n".join([
        'flow_serve_batch_size_bucket{le="1"} 5',
        'flow_serve_batch_size_bucket{le="2"} 2',
        'flow_serve_batch_size_bucket{le="+Inf"} 2',
        "flow_serve_batch_size_sum 8",
        "flow_serve_batch_size_count 5",
    ])
    errs, _ = validate_obs.validate_metrics(
        _write_prom(tmp_path, _prom_text({"flow_serve_batch_size": bad})))
    assert any("cumulative count decreases" in e for e in errs)
    assert any("+Inf bucket" in e for e in errs)


def test_inf_bucket_must_equal_count(tmp_path):
    bad = "\n".join([
        'flow_serve_batch_size_bucket{le="1"} 2',
        'flow_serve_batch_size_bucket{le="+Inf"} 4',
        "flow_serve_batch_size_sum 8",
        "flow_serve_batch_size_count 5",
    ])
    errs, _ = validate_obs.validate_metrics(
        _write_prom(tmp_path, _prom_text({"flow_serve_batch_size": bad})))
    assert any("+Inf bucket 4.0 != _count 5.0" in e for e in errs)


def test_garbage_line_fails(tmp_path):
    errs, _ = validate_obs.validate_metrics(
        _write_prom(tmp_path, _prom_text() + "this is not prometheus\n"))
    assert any("unparseable" in e for e in errs)
