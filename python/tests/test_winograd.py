"""L1 correctness: Winograd F(2,3) conv vs the direct-conv oracle."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, winograd

TOL = dict(rtol=3e-3, atol=3e-3)


def _rand(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 2),
    c=st.integers(1, 8),
    o=st.integers(1, 8),
    h=st.integers(6, 16),
    w=st.integers(6, 16),
)
def test_winograd_matches_direct(n, c, o, h, w):
    x = _rand((n, c, h, w), seed=h * 31 + w)
    k = _rand((o, c, 3, 3), seed=c * 7 + o, scale=0.3)
    got = winograd.conv2d_winograd(x, k, padding=1)
    want = ref.conv2d(x, k, stride=1, padding=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_winograd_with_bias():
    x = _rand((1, 4, 10, 10), seed=1)
    k = _rand((6, 4, 3, 3), seed=2, scale=0.3)
    b = _rand((6,), seed=3)
    got = winograd.conv2d_winograd(x, k, b, padding=1)
    want = ref.conv2d(x, k, stride=1, padding=1, bias=b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_winograd_valid_padding():
    x = _rand((1, 3, 8, 8), seed=4)
    k = _rand((2, 3, 3, 3), seed=5, scale=0.3)
    got = winograd.conv2d_winograd(x, k, padding=0)
    want = ref.conv2d(x, k, stride=1, padding=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_winograd_rejects_non_3x3():
    x = _rand((1, 3, 8, 8))
    k = _rand((2, 3, 5, 5))
    with pytest.raises(AssertionError):
        winograd.conv2d_winograd(x, k)


def test_multiply_reduction_is_2_25x():
    # The DiCecco engine's raison d'être: 36 multiplies → 16 per 2×2 tile.
    wino, direct = winograd.multiply_count(1, 64, 56, 56, 64)
    assert abs(direct / wino - 2.25) < 1e-9


def test_resnet_conv_shape():
    """The exact geometry DiCecco's engine targets (ResNet 3×3 layers)."""
    x = _rand((1, 16, 28, 28), seed=6)
    k = _rand((16, 16, 3, 3), seed=7, scale=0.2)
    got = winograd.conv2d_winograd(x, k, padding=1)
    assert got.shape == (1, 16, 28, 28)
    want = ref.conv2d(x, k, stride=1, padding=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)
