//! MobileNetV1 in folded mode (§III, §IV-H): parameterized kernels, group
//! structure, per-layer timing, and the §III motivation — 1×1 convolutions
//! dominate, so one parameterized kernel serves 13 layers.
//!
//! ```sh
//! cargo run --release --example mobilenet_folded
//! ```

use tvm_fpga_flow::flow::{Compiler, Mode, OptConfig, OptLevel};
use tvm_fpga_flow::graph::models;
use tvm_fpga_flow::schedule::OptKind;
use tvm_fpga_flow::util::bench::Table;

fn main() -> tvm_fpga_flow::Result<()> {
    let flow = Compiler::default();
    let net = models::mobilenet_v1();

    // §III: the workhorse op claim.
    let pw_macs: u64 = net
        .nodes
        .iter()
        .filter(|n| matches!(n.op, tvm_fpga_flow::graph::Op::Conv2d { kernel: 1, .. }))
        .map(|n| n.cost.macs)
        .sum();
    println!(
        "MobileNetV1: {:.1}% of MACs are 1x1 convolutions (paper §III: 94.9% of multiply-adds)",
        100.0 * pw_macs as f64 / net.total_macs() as f64
    );

    let acc = flow.compile(&net, Mode::Folded, OptLevel::Optimized)?;
    let mut t = Table::new("parameterized kernel groups (§IV-H)", &["kernel", "group", "layers served", "lanes (DSPs)"]);
    for k in &acc.program.kernels {
        t.row(&[
            k.name.clone(),
            k.group.map(|g| g.to_string()).unwrap_or_else(|| "-".into()),
            k.layers.len().to_string(),
            k.nest.total_unroll().to_string(),
        ]);
    }
    t.print();

    let (logic, bram, dsp, fmax) = acc.synthesis.table2_row();
    println!(
        "resources: logic {logic:.0}% bram {bram:.0}% dsp {dsp:.0}% fmax {fmax:.0} MHz (paper: 46/48/15/187)"
    );
    println!(
        "performance: {:.1} FPS, {:.1} ms/frame, launch overhead {:.0}% (paper: 30.3 FPS)",
        acc.performance.fps,
        acc.performance.frame_time_s * 1e3,
        acc.performance.host_frac * 100.0
    );

    // Without PK the per-layer design must not fit (§IV: "A one-to-one
    // layer-to-kernel mapping can easily exhaust resources").
    let no_pk = OptConfig::optimized().without(OptKind::Parameterize);
    match flow.compile_with(&net, Mode::Folded, &no_pk, &tvm_fpga_flow::flow::default_factors(&net)) {
        Ok(acc) => println!(
            "without PK: {} kernels, logic {:.0}% — unexpectedly fits",
            acc.program.kernels.len(),
            acc.synthesis.resources.utilization.logic_frac * 100.0
        ),
        Err(e) => println!("without PK: {e} — matches the paper's 'may not synthesize at all'"),
    }
    Ok(())
}
