//! End-to-end driver: proves all three layers compose on a real small
//! workload, and regenerates the paper's headline rows.
//!
//! 1. **Functional path** — the rust runtime loads the HLO executables
//!    AOT-lowered from the JAX models (L2) that route every MAC through the
//!    Pallas kernels (L1); classifies N = 1000 synthetic MNIST-like frames
//!    through LeNet-5 (the paper's §V-C workload size), cross-checking the
//!    Pallas path against the XLA-ref path frame by frame; runs a frame
//!    through MobileNetV1 and ResNet-34 too.
//! 2. **Compilation flow** — compiles all three networks base + optimized
//!    and prints the Table IV rows.
//! 3. Records everything EXPERIMENTS.md quotes.
//!
//! ```sh
//! make artifacts && cargo run --release --example end_to_end
//! ```

use std::time::Instant;

use tvm_fpga_flow::data;
use tvm_fpga_flow::flow::{Compiler, OptLevel};
use tvm_fpga_flow::graph::models;
use tvm_fpga_flow::metrics::{self, paper};
use tvm_fpga_flow::runtime::{Impl, Manifest, Runtime};
use tvm_fpga_flow::util::bench::Table;

fn main() -> tvm_fpga_flow::Result<()> {
    if !Manifest::default_dir().join("manifest.json").exists() {
        anyhow::bail!("run `make artifacts` first");
    }
    let rt = Runtime::new(Manifest::default_dir())?;

    // ---- 1a. LeNet-5, N=1000 frames, pallas vs ref cross-check ----------
    println!("[1/3] functional path: LeNet-5, N=1000 frames (batch 16)");
    let pallas = rt.load("lenet5", Impl::Pallas, 16)?;
    let refm = rt.load("lenet5", Impl::Ref, 16)?;
    let frames = data::mnist_like(1008, 32, 42); // 63 batches of 16
    let fe = pallas.frame_elems();

    let mut agree = 0usize;
    let mut total = 0usize;
    let t0 = Instant::now();
    let mut pallas_time = 0.0;
    for b in 0..63 {
        let chunk = &frames.data[b * 16 * fe..(b + 1) * 16 * fe];
        let tp = Instant::now();
        let p = pallas.classify(&rt.client, chunk)?;
        pallas_time += tp.elapsed().as_secs_f64();
        let r = refm.classify(&rt.client, chunk)?;
        for (x, y) in p.iter().zip(&r) {
            total += 1;
            if x == y {
                agree += 1;
            }
        }
        if total >= 1000 {
            break;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    let lenet = models::by_name("lenet5").unwrap();
    let fps_pallas = metrics::fps(total as u64, pallas_time);
    println!(
        "  {total} frames: pallas==ref on {agree}/{total} predictions; \
         pallas path {fps_pallas:.0} FPS ({:.2} GFLOPS) on CPU/PJRT; wall {dt:.2}s",
        metrics::gflops(fps_pallas, lenet.total_flops())
    );
    assert_eq!(agree, total, "pallas and ref paths must agree");

    // ---- 1b. one frame through the big networks --------------------------
    for net in ["mobilenet_v1", "resnet34"] {
        let g = models::by_name(net).unwrap();
        let ref1 = rt.load(net, Impl::Ref, 1)?;
        let imgs = data::for_network(net, 1, 7).unwrap();
        let t0 = Instant::now();
        let pred_ref = ref1.classify(&rt.client, imgs.frame(0))?[0];
        let ref_ms = t0.elapsed().as_secs_f64() * 1e3;

        let pal1 = rt.load(net, Impl::Pallas, 1)?;
        let t0 = Instant::now();
        let pred_pal = pal1.classify(&rt.client, imgs.frame(0))?[0];
        let pal_ms = t0.elapsed().as_secs_f64() * 1e3;
        assert_eq!(pred_ref, pred_pal, "{net}: pallas vs ref prediction");
        println!(
            "  {net}: pallas==ref (class {pred_ref}); ref {ref_ms:.0} ms/frame, \
             pallas(interpret) {pal_ms:.0} ms/frame, {:.2} GFLOPs/frame",
            g.total_flops() as f64 / 1e9
        );
    }

    // ---- 2. the compilation flow: Table IV ------------------------------
    println!("\n[2/3] compilation flow: Table IV (base vs optimized, simulated S10SX)");
    let flow = Compiler::default();
    let mut t4 = Table::new("Table IV — FPS of base versus optimized circuits", &["network", "base", "optimized", "speedup", "paper"]);
    for (name, pb, po, ps) in paper::TABLE4 {
        let g = models::by_name(name).unwrap();
        let mode = Compiler::paper_mode(name);
        let base = flow.compile(&g, mode, OptLevel::Base)?;
        let opt = flow.compile(&g, mode, OptLevel::Optimized)?;
        t4.row(&[
            name.into(),
            format!("{:.4}", base.performance.fps),
            format!("{:.2}", opt.performance.fps),
            format!("{:.1}x", opt.performance.fps / base.performance.fps),
            format!("{pb:.4} → {po:.2} ({ps:.1}x)"),
        ]);
    }
    t4.print();

    // ---- 3. summary -------------------------------------------------------
    println!("[3/3] all layers composed: Pallas (L1) → JAX/HLO (L2) → rust PJRT + flow (L3). OK");
    Ok(())
}
