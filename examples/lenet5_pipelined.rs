//! LeNet-5 in pipelined mode (§III): per-stage analysis, channel-depth
//! dynamics through the event-driven engine, and the pseudo-OpenCL dump.
//!
//! ```sh
//! cargo run --release --example lenet5_pipelined
//! ```

use tvm_fpga_flow::flow::{Compiler, Mode, OptLevel};
use tvm_fpga_flow::graph::models;
use tvm_fpga_flow::sim::engine;
use tvm_fpga_flow::util::bench::Table;

fn main() -> tvm_fpga_flow::Result<()> {
    let flow = Compiler::default();
    let net = models::lenet5();
    let acc = flow.compile(&net, Mode::Pipelined, OptLevel::Optimized)?;

    let mut t = Table::new(
        &format!("LeNet-5 pipeline stages @ {:.0} MHz", acc.synthesis.fmax_mhz),
        &["stage", "lanes", "autorun", "cycles/frame"],
    );
    for (k, l) in acc.program.kernels.iter().zip(&acc.performance.per_layer) {
        t.row(&[
            k.name.clone(),
            k.nest.total_unroll().to_string(),
            if k.autorun { "yes".into() } else { "no".into() },
            format!("{:.0}", l.cycles),
        ]);
    }
    t.print();
    println!(
        "throughput: {:.0} FPS — bottleneck '{}', host fraction {:.0}% \
         (the PCIe round-trip dominates tiny networks, which is why the \
         paper's LeNet lands at ~5K FPS, §IV-F)",
        acc.performance.fps,
        acc.performance.bottleneck,
        acc.performance.host_frac * 100.0
    );

    // Channel-depth dynamics (§IV-E buffered channels): simulate the stage
    // graph with shallow vs paper-sized FIFOs.
    let stages: Vec<(String, f64, u64)> = acc
        .performance
        .per_layer
        .iter()
        .zip(&acc.program.kernels)
        .map(|(l, k)| (k.name.clone(), l.cycles, (k.nest.out_elems / 16).max(1)))
        .collect();
    let stages = engine::stages_from_cycles(&stages);
    let mut t = Table::new("channel depth sweep (event-driven engine)", &["depth (tokens)", "steady cycles/frame", "stall cycles"]);
    for depth in [1u64, 4, 16, 64, 294] {
        let rep = engine::simulate(&stages, depth, 6);
        t.row(&[
            depth.to_string(),
            format!("{:.0}", rep.steady_interval_cycles),
            format!("{:.0}", rep.stall_cycles),
        ]);
    }
    t.print();
    println!("(294 tokens ≈ the 4704-float largest feature map at 16 floats/token — the §IV-J depth rule)");

    println!("\n--- generated pseudo-OpenCL (first kernel) ---");
    let src = acc.program.to_pseudo_opencl();
    for line in src.lines().take(24) {
        println!("{line}");
    }
    Ok(())
}
