//! Design-space exploration (§IV-J future work, automated): sweep tile
//! factors under the three legality rules and report the Pareto-ish best.
//! The synthesis memo turns revisited kernel programs into cache hits.
//!
//! ```sh
//! cargo run --release --example dse_explorer -- --net mobilenet_v1 --budget 20 --target stratix10sx
//! ```

use tvm_fpga_flow::dse;
use tvm_fpga_flow::flow::{Compiler, Mode};
use tvm_fpga_flow::graph::models;
use tvm_fpga_flow::util::bench::Table;
use tvm_fpga_flow::util::cli::Args;

fn main() -> tvm_fpga_flow::Result<()> {
    let args = Args::from_env();
    let name = args.opt_or("net", "mobilenet_v1");
    let budget: usize = args.opt_parse("budget").unwrap_or(20);
    let net = models::by_name(name).ok_or_else(|| anyhow::anyhow!("unknown net {name}"))?;
    let compiler = Compiler::for_target(args.opt_or("target", "stratix10sx"))?;

    let mode = Mode::auto(&net, &compiler.target.device);
    let result = match mode {
        Mode::Folded => dse::explore_folded(&compiler, &net, budget),
        Mode::Pipelined => dse::explore_pipelined(&compiler, &net),
    };

    println!(
        "{name} on {}: evaluated {} points, {} rejected (rule violations / routing failures)",
        compiler.target.name,
        result.evaluated,
        result.log.iter().filter(|p| p.rejected.is_some()).count()
    );
    println!(
        "synthesis cache: {} hits / {} misses ({:.0}% of synthesis requests skipped)",
        result.synth_cache.hits,
        result.synth_cache.misses,
        result.synth_cache_hit_rate() * 100.0
    );

    // Top 10 routed points by FPS.
    let mut routed: Vec<_> = result.log.iter().filter(|p| p.rejected.is_none()).collect();
    routed.sort_by(|a, b| b.fps.total_cmp(&a.fps));
    let mut t = Table::new("top design points", &["FPS", "fmax", "dsp%", "logic%", "bram%"]);
    for p in routed.iter().take(10) {
        t.row(&[
            format!("{:.2}", p.fps),
            format!("{:.0}", p.fmax_mhz),
            format!("{:.1}", p.dsp_frac * 100.0),
            format!("{:.1}", p.logic_frac * 100.0),
            format!("{:.1}", p.bram_frac * 100.0),
        ]);
    }
    t.print();

    if let Some(best) = &result.best {
        println!("best factor plan:");
        for (g, (a, b)) in &best.plan.group_tiles {
            println!("  {g}: ({a}, {b})");
        }
        println!(
            "\nThe paper swept these by hand at 3-12 hours of place-and-route per \
             point (§IV-J); the model evaluates {} points in milliseconds.",
            result.evaluated
        );
    }
    Ok(())
}
