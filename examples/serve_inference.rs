//! Serving demo: the coordinator routes a Poisson request stream to
//! command-queue workers with dynamic batching, over the PJRT runtime
//! executing the AOT-compiled LeNet-5 (python never runs here).
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_inference
//! ```

use std::time::{Duration, Instant};

use tvm_fpga_flow::coordinator::{InferenceServer, ServerConfig};
use tvm_fpga_flow::data;
use tvm_fpga_flow::runtime::Manifest;
use tvm_fpga_flow::util::bench::Table;
use tvm_fpga_flow::util::rng::Rng;

fn main() -> tvm_fpga_flow::Result<()> {
    if !Manifest::default_dir().join("manifest.json").exists() {
        anyhow::bail!("run `make artifacts` first");
    }
    let frames = data::mnist_like(256, 32, 11);
    let mut table = Table::new(
        "serving LeNet-5: command queues × batching (CE/§IV-G analog)",
        &["queues", "batching", "req/s", "p50 µs", "p99 µs", "batched frames"],
    );

    for (workers, batching) in [(1, false), (1, true), (2, true), (4, true)] {
        let server = InferenceServer::start(ServerConfig {
            workers,
            max_batch: if batching { 16 } else { 1 },
            max_wait: Duration::from_millis(2),
            ..Default::default()
        })?;
        // Poisson open-loop arrivals at ~4k req/s for 512 requests.
        let mut rng = Rng::new(5);
        let t0 = Instant::now();
        let mut pending = Vec::new();
        for i in 0..512usize {
            pending.push(server.infer_async(frames.frame(i % 256).to_vec())?);
            let gap = rng.exp(4000.0);
            if gap > 10e-6 {
                std::thread::sleep(Duration::from_secs_f64(gap.min(0.002)));
            }
        }
        for rx in pending {
            rx.recv().map_err(|_| anyhow::anyhow!("dropped"))??;
        }
        let dt = t0.elapsed().as_secs_f64();
        let stats = server.shutdown();
        table.row(&[
            workers.to_string(),
            if batching { "on".into() } else { "off".into() },
            format!("{:.0}", 512.0 / dt),
            stats.p50_us.map(|v| v.to_string()).unwrap_or_default(),
            stats.p99_us.map(|v| v.to_string()).unwrap_or_default(),
            stats.batched_frames.to_string(),
        ]);
    }
    table.print();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "One queue serializes execution (the paper's single-command-queue \
         pathology, §IV-G); batching amortizes per-dispatch overhead (§IV-F). \
         Extra queues help only with real parallel hardware — this host has \
         {cores} core(s), so added queues beyond that just contend."
    );
    Ok(())
}
