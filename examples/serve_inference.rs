//! Serving demo: the dynamic-batching replica scheduler routing a Poisson
//! request stream across accelerator replicas.
//!
//! Replicas are simulated engines compiled through the staged flow for
//! *different* registry targets, so this runs without artifacts or a PJRT
//! build; pass `REPRO_ARTIFACTS` + `--engine pjrt` to `fpga-flow serve`
//! for the runtime-backed equivalent.
//!
//! ```sh
//! cargo run --release --example serve_inference
//! ```

use std::time::{Duration, Instant};

use tvm_fpga_flow::coordinator::{EngineSpec, InferenceServer, ServerConfig, SimEngine};
use tvm_fpga_flow::data;
use tvm_fpga_flow::flow::multi::ReplicaPlan;
use tvm_fpga_flow::graph::models;
use tvm_fpga_flow::util::bench::Table;
use tvm_fpga_flow::util::rng::Rng;

fn main() -> tvm_fpga_flow::Result<()> {
    let net = models::lenet5();
    let frames = data::mnist_like(256, 32, 11);
    let mut table = Table::new(
        "serving LeNet-5: replicas × batching (CE/§IV-G + autorun/§IV-F analogs)",
        &["fleet", "max_batch", "req/s", "p50 µs", "p99 µs", "mean batch", "occupancy"],
    );

    for (targets, max_batch) in [
        (vec!["stratix10sx"], 1),
        (vec!["stratix10sx"], 16),
        (vec!["stratix10sx", "arria10gx"], 16),
        (vec!["stratix10sx", "arria10gx", "agilex7"], 16),
    ] {
        // Compile one accelerator per target through the staged sessions;
        // routing weight follows each design's modeled FPS.
        let plan = ReplicaPlan::build(&net, &targets)?;
        let replicas: Vec<EngineSpec> = SimEngine::from_plan(&plan, &net, max_batch)?
            .into_iter()
            .map(EngineSpec::Sim)
            .collect();
        let fleet = targets.join("+");
        let server = InferenceServer::start(ServerConfig {
            max_batch,
            max_wait: Duration::from_millis(2),
            replicas,
            ..Default::default()
        })?;

        // Poisson open-loop arrivals at ~4k req/s for 512 requests.
        let mut rng = Rng::new(5);
        let t0 = Instant::now();
        let mut pending = Vec::new();
        for i in 0..512usize {
            pending.push(server.infer_async(frames.frame(i % 256).to_vec())?);
            let gap = rng.exp(4000.0);
            if gap > 10e-6 {
                std::thread::sleep(Duration::from_secs_f64(gap.min(0.002)));
            }
        }
        for rx in pending {
            rx.recv().map_err(|_| anyhow::anyhow!("dropped"))??;
        }
        let dt = t0.elapsed().as_secs_f64();
        let stats = server.shutdown();
        let occupancy: Vec<String> =
            stats.replicas.iter().map(|r| format!("{:.0}%", r.occupancy * 100.0)).collect();
        table.row(&[
            fleet,
            max_batch.to_string(),
            format!("{:.0}", 512.0 / dt),
            stats.p50_us.map(|v| v.to_string()).unwrap_or_default(),
            stats.p99_us.map(|v| v.to_string()).unwrap_or_default(),
            format!("{:.2}", stats.mean_batch_size()),
            occupancy.join(" "),
        ]);
    }
    table.print();
    println!(
        "One unbatched replica serializes dispatches (the single-command-queue \
         pathology, §IV-G); batching amortizes per-dispatch overhead (§IV-F); \
         extra replicas shard batches weighted by each target's modeled FPS — \
         the heterogeneous fleet keeps the fast board ~full while the slower \
         boards absorb overflow."
    );
    Ok(())
}
