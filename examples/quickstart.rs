//! Quickstart: compile LeNet-5 through the staged flow and print what the
//! paper's Table II/IV rows look like for it.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use tvm_fpga_flow::flow::{Compiler, Mode, OptConfig, OptLevel};
use tvm_fpga_flow::graph::models;

fn main() -> tvm_fpga_flow::Result<()> {
    let compiler = Compiler::for_target("stratix10sx")?;
    let net = models::lenet5();
    println!(
        "LeNet-5: {} nodes, {} params, {:.0} KFLOPs/frame",
        net.nodes.len(),
        net.total_params(),
        net.total_flops() as f64 / 1e3
    );

    // TVM-default schedule (the paper's "base").
    let base = compiler.compile(&net, Mode::Pipelined, OptLevel::Base)?;

    // All Table-I optimizations, stage by stage this time: lower to
    // scheduled kernels, synthesize through the AOC model, simulate.
    let mut session = compiler
        .graph(&net)
        .mode(Mode::Pipelined)
        .opts(OptConfig::optimized());
    let lowered = session.lower()?;
    println!(
        "\nlowered      : {} kernels on {} ({} opts applied)",
        lowered.program.kernels.len(),
        lowered.target().name,
        lowered.applied.len()
    );
    let design = lowered.synthesize()?;
    let opt = design.simulate()?;

    let (logic, bram, dsp, fmax) = opt.synthesis.table2_row();
    println!("\noptimized accelerator (pipelined mode):");
    println!("  kernels   : {} ({} autorun), {} channels, {} queues",
        opt.program.kernels.len(), opt.program.autorun_count(),
        opt.program.channels.len(), opt.program.queues);
    println!("  applied   : {}", opt.applied.iter().map(|o| o.abbrev()).collect::<Vec<_>>().join(" "));
    println!("  resources : logic {logic:.0}%  bram {bram:.0}%  dsp {dsp:.0}%  fmax {fmax:.0} MHz");
    println!("  FPS       : {:.0}  (base schedule: {:.0} → {:.1}x speedup)",
        opt.performance.fps, base.performance.fps,
        opt.performance.fps / base.performance.fps);

    // Re-entering synthesis is free: the memo recalls the report.
    let again = lowered.synthesize()?;
    println!("  re-synth  : cache {} ({} hits / {} misses so far)",
        if again.cache_hit { "hit" } else { "miss" },
        compiler.cache_stats().hits, compiler.cache_stats().misses);

    println!("\npaper (Tables II & IV): logic 25% bram 19% dsp 5% fmax 218; 524 → 4917 FPS (9.38x)");
    Ok(())
}
