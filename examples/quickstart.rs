//! Quickstart: compile LeNet-5 through the whole flow and print what the
//! paper's Table II/IV rows look like for it.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use tvm_fpga_flow::flow::{Flow, Mode, OptLevel};
use tvm_fpga_flow::graph::models;

fn main() -> tvm_fpga_flow::Result<()> {
    let flow = Flow::new();
    let net = models::lenet5();
    println!(
        "LeNet-5: {} nodes, {} params, {:.0} KFLOPs/frame",
        net.nodes.len(),
        net.total_params(),
        net.total_flops() as f64 / 1e3
    );

    // TVM-default schedule (the paper's "base").
    let base = flow.compile(&net, Mode::Pipelined, OptLevel::Base)?;
    // All Table-I optimizations.
    let opt = flow.compile(&net, Mode::Pipelined, OptLevel::Optimized)?;

    let (logic, bram, dsp, fmax) = opt.synthesis.table2_row();
    println!("\noptimized accelerator (pipelined mode):");
    println!("  kernels   : {} ({} autorun), {} channels, {} queues",
        opt.program.kernels.len(), opt.program.autorun_count(),
        opt.program.channels.len(), opt.program.queues);
    println!("  applied   : {}", opt.applied.iter().map(|o| o.abbrev()).collect::<Vec<_>>().join(" "));
    println!("  resources : logic {logic:.0}%  bram {bram:.0}%  dsp {dsp:.0}%  fmax {fmax:.0} MHz");
    println!("  FPS       : {:.0}  (base schedule: {:.0} → {:.1}x speedup)",
        opt.performance.fps, base.performance.fps,
        opt.performance.fps / base.performance.fps);
    println!("\npaper (Tables II & IV): logic 25% bram 19% dsp 5% fmax 218; 524 → 4917 FPS (9.38x)");
    Ok(())
}
