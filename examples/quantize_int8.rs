//! Quantization walkthrough: calibrate LeNet-5 on representative frames,
//! compile int8 and fp32 accelerators side by side, measure the real
//! top-1 agreement through the quantized executor, and sweep the
//! precision Pareto front.
//!
//! ```sh
//! cargo run --release --example quantize_int8
//! ```

use tvm_fpga_flow::dse::explore_precisions;
use tvm_fpga_flow::flow::{Compiler, Mode, ModeChoice};
use tvm_fpga_flow::graph::models;
use tvm_fpga_flow::quant::{self, QuantConfig};
use tvm_fpga_flow::texpr::Precision;

fn main() -> tvm_fpga_flow::Result<()> {
    let compiler = Compiler::for_target("stratix10sx")?;
    let net = models::lenet5();

    // 1. Calibrate empirically (16 frames through the reference executor)
    //    and quantize: BN-fold → ranges → quantize/dequantize rewrite.
    let prep = quant::prepare(&net, &QuantConfig::int8().with_data(16))?;
    let rep = &prep.report;
    println!(
        "calibration : {} on {} frames → {} quantize / {} dequantize boundaries, {} folded",
        rep.calibrator,
        rep.calibration_frames,
        rep.stats.quantize_nodes,
        rep.stats.dequantize_nodes,
        rep.stats.folded_pairs
    );
    println!(
        "accuracy    : {:.1}% top-1 agreement vs fp32 (measured, \u{0394} {:.2}pp)",
        rep.accuracy.top1_agreement * 100.0,
        rep.accuracy.delta_pp
    );

    // 2. Compile both precisions through the staged session.
    let f32_acc = compiler.graph(&net).mode(ModeChoice::Pipelined).run()?;
    let int8_acc = compiler
        .graph(&net)
        .mode(ModeChoice::Pipelined)
        .with_quantization(QuantConfig::int8().with_data(16))
        .run()?;
    let (fl, fb, fd, ff) = f32_acc.synthesis.table2_row();
    let (il, ib, id, i_f) = int8_acc.synthesis.table2_row();
    println!("\n             logic   bram    dsp    fmax     fps");
    println!(
        "fp32       : {fl:>5.1}% {fb:>5.1}% {fd:>5.1}% {ff:>6.0}M {:>7.0}",
        f32_acc.performance.fps
    );
    println!(
        "int8       : {il:>5.1}% {ib:>5.1}% {id:>5.1}% {i_f:>6.0}M {:>7.0}",
        int8_acc.performance.fps
    );

    // 3. The emitted kernels carry the dtype metadata.
    let src = int8_acc.program.to_pseudo_opencl();
    let line = src.lines().find(|l| l.starts_with("channel")).unwrap_or("");
    println!("\nint8 codegen: {line}");

    // 4. Precision as a DSE dimension: the Pareto front.
    let front = explore_precisions(
        &compiler,
        &net,
        Mode::Pipelined,
        4,
        &[Precision::F32, Precision::Int8],
    )?;
    println!("\npareto front ({} points):", front.pareto.len());
    for p in &front.pareto {
        println!(
            "  {:<5} {:>8.0} FPS  dsp {:>4.1}%  logic {:>4.1}%  bram {:>4.1}%  \u{0394} {:.2}pp",
            p.precision.name(),
            p.fps,
            p.dsp_frac * 100.0,
            p.logic_frac * 100.0,
            p.bram_frac * 100.0,
            p.accuracy_delta_pp
        );
    }
    if front.beats_baseline_on_resources(Precision::Int8) {
        println!("int8 strictly beats the fp32 baseline on every modeled resource at \u{2265} its FPS");
    }
    Ok(())
}
