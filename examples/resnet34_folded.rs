//! ResNet-34 in folded mode: the largest evaluation network — residual
//! topology, the §V-F bottleneck discussion (DSP underutilization, f_max
//! loss with bigger tiles), and the §V-E 3×3-conv GFLOPS figure.
//!
//! ```sh
//! cargo run --release --example resnet34_folded
//! ```

use tvm_fpga_flow::flow::{default_factors, Compiler, Mode, OptConfig, OptLevel};
use tvm_fpga_flow::graph::{models, GroupKind, ParamGroup};
use tvm_fpga_flow::util::bench::Table;

fn main() -> tvm_fpga_flow::Result<()> {
    let flow = Compiler::default();
    let net = models::resnet34();
    let acc = flow.compile(&net, Mode::Folded, OptLevel::Optimized)?;

    let (logic, bram, dsp, fmax) = acc.synthesis.table2_row();
    println!("ResNet-34 folded: {} kernels, {} layer invocations/frame", acc.program.kernels.len(), acc.work.len());
    println!("resources: logic {logic:.0}% bram {bram:.0}% dsp {dsp:.0}% fmax {fmax:.0} MHz (paper: 59/61/16/125)");
    println!("performance: {:.2} FPS (paper Table IV: 7.04, Table V: 4.6)", acc.performance.fps);

    // §V-E: GFLOPS of the 3×3 convolutions.
    let f3x3 = net.flops_3x3_conv();
    let gflops_3x3 = acc.performance.fps * f3x3 as f64 / 1e9;
    println!(
        "3x3-conv GFLOPS: {gflops_3x3:.1} at our simulated FPS ({:.0}% of per-frame FLOPs are 3x3 convs; paper reports 70.4)",
        100.0 * f3x3 as f64 / net.total_flops() as f64
    );

    // §V-F: pushing the 3×3 tile bigger — DSP% rises, f_max falls, and
    // eventually routing fails before all DSPs are used.
    let mut t = Table::new("§V-F sweep: 3x3s1 tile vs fmax / FPS", &["tile", "lanes", "dsp%", "fmax", "FPS", "outcome"]);
    let g3 = ParamGroup { kind: GroupKind::Conv, kernel: 3, stride: 1 };
    for (t_ic, t_oc) in [(4, 4), (8, 8), (8, 16), (16, 16), (16, 32), (32, 32)] {
        let mut plan = default_factors(&net);
        plan.group_tiles.insert(g3, (t_ic, t_oc));
        match flow.compile_with(&net, Mode::Folded, &OptConfig::optimized(), &plan) {
            Ok(a) => t.row(&[
                format!("({t_ic},{t_oc})"),
                format!("{}", 9 * t_ic * t_oc),
                format!("{:.1}", a.synthesis.resources.utilization.dsp_frac * 100.0),
                format!("{:.0}", a.synthesis.fmax_mhz),
                format!("{:.2}", a.performance.fps),
                "routed".into(),
            ]),
            Err(_) => t.row(&[
                format!("({t_ic},{t_oc})"),
                format!("{}", 9 * t_ic * t_oc),
                "-".into(),
                "-".into(),
                "-".into(),
                "ROUTING FAILURE".into(),
            ]),
        }
    }
    t.print();
    println!("(paper §V-F: \"larger tile sizes lead to … routing failure before utilizing all DSPs\")");
    Ok(())
}
