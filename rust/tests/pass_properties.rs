//! Property tests over the pass pipeline (via `util::prop`):
//!
//! * the optimized schedule pipeline is **idempotent** — running it a
//!   second time over its own output changes nothing;
//! * graph passes preserve node-count invariants — BN-fold removes only
//!   BatchNorm nodes (everything else survives bit-for-bit in count), and
//!   the quantize/dequantize folding chain never produces more boundaries
//!   than the unfused per-node wrapping would.

use tvm_fpga_flow::flow::patterns::{build_with_passes, default_factors, OptConfig};
use tvm_fpga_flow::flow::Mode;
use tvm_fpga_flow::graph::{models, passes, Graph, Op};
use tvm_fpga_flow::pass::{PassManager, ScheduleCtx};
use tvm_fpga_flow::quant::rewrite::{grid_capable, insert_qdq};
use tvm_fpga_flow::schedule::OptKind;
use tvm_fpga_flow::texpr::Precision;
use tvm_fpga_flow::util::prop;
use tvm_fpga_flow::util::rng::Rng;
// One chain generator for the whole test estate: the differential fuzzer
// (rust/tests/differential.rs) and these pipeline properties exercise the
// same graph family, so coverage can't silently drift apart.
use tvm_fpga_flow::verify::differ::random_chain;

/// Seeded random layer chain from the shared generator (convs optionally
/// BN'd / activated, depthwise convs, bounded pools, flatten + dense).
fn chain_for(rng: &mut Rng) -> Graph {
    random_chain(rng.next_u64())
}

fn count_op(g: &Graph, f: impl Fn(&Op) -> bool) -> usize {
    g.nodes.iter().filter(|n| f(&n.op)).count()
}

#[test]
fn optimized_schedule_pipeline_is_idempotent() {
    prop::check("schedule-pipeline-idempotent", |rng, _case| {
        let g = match rng.below(3) {
            0 => models::lenet5(),
            1 => models::mobilenet_v1(),
            _ => models::resnet34(),
        };
        let mode = if rng.below(2) == 0 { Mode::Pipelined } else { Mode::Folded };
        let mut cfg = OptConfig::optimized();
        for kind in [
            OptKind::Unroll,
            OptKind::Tile,
            OptKind::Fuse,
            OptKind::CachedWrite,
            OptKind::FloatOpt,
            OptKind::Channels,
            OptKind::Autorun,
            OptKind::Concurrent,
            OptKind::Parameterize,
        ] {
            if rng.below(4) == 0 {
                cfg = cfg.without(kind);
            }
        }
        if rng.below(4) == 0 {
            cfg = cfg.with_precision(Precision::Int8);
        }
        if rng.below(4) == 0 {
            cfg = cfg.with_vectors();
        }
        if rng.below(4) == 0 {
            cfg = cfg.with_sparsity(0.5);
        }

        let plan = default_factors(&g);
        let built = build_with_passes(&g, mode, &cfg, &plan);

        // Re-run the exact same pipeline over its own output: every pass
        // must be a fixed point (kernels, nests, applied sets, channels,
        // queues and autorun flags all unchanged).
        let mut second = built.program.clone();
        let pipeline = cfg.schedule_pipeline();
        let mut pm = PassManager::new();
        pm.run_schedule_passes(&pipeline, &ScheduleCtx { graph: &g, plan: &plan, mode }, &mut second);
        assert_eq!(
            format!("{:?}", built.program),
            format!("{second:?}"),
            "pipeline not idempotent for {} {:?} cfg {:?}",
            g.name,
            mode,
            cfg
        );
    });
}

#[test]
fn bn_fold_removes_only_batchnorm_nodes() {
    prop::check("bn-fold-node-invariants", |rng, _case| {
        let g = chain_for(rng);
        g.validate().expect("generator builds valid graphs");
        let bn_before = count_op(&g, |op| matches!(op, Op::BatchNorm));
        let others_before = g.nodes.len() - bn_before;

        let (folded, stats) = passes::fold_batchnorm(&g);
        folded.validate().expect("bn-fold preserves validity");
        let bn_after = count_op(&folded, |op| matches!(op, Op::BatchNorm));
        let others_after = folded.nodes.len() - bn_after;

        // Only BN nodes disappear; every other op kind survives.
        assert_eq!(others_after, others_before, "non-BN node count changed");
        assert_eq!(stats.removed, bn_before - bn_after, "{stats:?}");
        assert_eq!(
            count_op(&g, |op| matches!(op, Op::Conv2d { .. } | Op::DepthwiseConv2d { .. })),
            count_op(&folded, |op| matches!(op, Op::Conv2d { .. } | Op::DepthwiseConv2d { .. })),
        );
        // Structural rewrite only: MACs and the output shape are intact.
        assert_eq!(g.total_macs(), folded.total_macs());
        assert_eq!(g.nodes[g.output].shape, folded.nodes[folded.output].shape);
    });
}

#[test]
fn qdq_fold_never_increases_boundary_count() {
    prop::check("qdq-boundary-invariants", |rng, _case| {
        let g = chain_for(rng);
        let (folded, _) = passes::standard_pipeline(&g);
        let (rewritten, stats) = insert_qdq(&folded, Precision::Int8);
        rewritten.validate().expect("qdq rewrite preserves validity");

        // The unfused baseline wraps every grid-capable node in its own
        // boundaries: one quantize per input edge plus one dequantize.
        // Folding must never exceed that.
        let naive: usize = folded
            .topo()
            .filter(|n| grid_capable(&n.op))
            .map(|n| n.inputs.len() + 1)
            .sum();
        let boundaries = stats.quantize_nodes + stats.dequantize_nodes;
        assert!(
            boundaries <= naive,
            "{} boundaries exceed the unfused {} (stats {stats:?})",
            boundaries,
            naive
        );
        // Inserted boundary nodes are the only additions.
        assert_eq!(rewritten.nodes.len(), folded.nodes.len() + boundaries);
        assert_eq!(folded.total_macs(), rewritten.total_macs());
        // Every folded pair is a quantized→quantized edge that kept the
        // activations on the grid — there must be at least one whenever
        // two grid ops are adjacent and boundaries were created at all.
        if boundaries > 0 {
            let adjacent_grid_edges = folded
                .topo()
                .filter(|n| grid_capable(&n.op))
                .flat_map(|n| n.inputs.iter())
                .filter(|&&i| grid_capable(&folded.nodes[i].op))
                .count();
            assert!(stats.folded_pairs >= adjacent_grid_edges.min(1), "{stats:?}");
        }
    });
}
