//! Failure-injection tests: corrupted/truncated artifacts and hostile
//! manifest contents must produce clean errors, never panics or UB.

use std::fs;

use tvm_fpga_flow::runtime::{Impl, Manifest, Runtime};

fn artifacts_ready() -> bool {
    Manifest::default_dir().join("manifest.json").exists()
}

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("tvm_fpga_flow_test_{tag}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&d);
    fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn missing_artifacts_dir_is_clean_error() {
    let err = Manifest::load("/nonexistent/path/xyz");
    assert!(err.is_err());
    assert!(format!("{}", err.err().unwrap()).contains("make artifacts"));
}

#[test]
fn corrupt_manifest_is_clean_error() {
    let d = temp_dir("corrupt");
    fs::write(d.join("manifest.json"), "{ not json !!!").unwrap();
    let err = Manifest::load(&d);
    assert!(err.is_err());
    let _ = fs::remove_dir_all(&d);
}

#[test]
fn manifest_missing_networks_key_is_clean_error() {
    let d = temp_dir("nonet");
    fs::write(d.join("manifest.json"), r#"{"kernels": []}"#).unwrap();
    assert!(Manifest::load(&d).is_err());
    let _ = fs::remove_dir_all(&d);
}

#[test]
fn truncated_weights_blob_is_clean_error() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let src = Manifest::default_dir();
    let d = temp_dir("truncated");
    // Copy manifest + lenet HLO, but truncate the weights blob.
    fs::copy(src.join("manifest.json"), d.join("manifest.json")).unwrap();
    for f in ["lenet5_ref.b1.hlo.txt"] {
        fs::copy(src.join(f), d.join(f)).unwrap();
    }
    let blob = fs::read(src.join("lenet5.weights.bin")).unwrap();
    fs::write(d.join("lenet5.weights.bin"), &blob[..blob.len() / 2]).unwrap();

    let rt = Runtime::new(&d).unwrap();
    let err = rt.load("lenet5", Impl::Ref, 1);
    assert!(err.is_err(), "truncated weights must fail to load");
    let msg = format!("{}", err.err().unwrap());
    assert!(msg.contains("blob too short") || msg.contains("No such file"), "{msg}");
    let _ = fs::remove_dir_all(&d);
}

#[test]
fn garbage_hlo_text_is_clean_error() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let src = Manifest::default_dir();
    let d = temp_dir("badhlo");
    fs::copy(src.join("manifest.json"), d.join("manifest.json")).unwrap();
    fs::copy(src.join("lenet5.weights.bin"), d.join("lenet5.weights.bin")).unwrap();
    fs::write(d.join("lenet5_ref.b1.hlo.txt"), "ENTRY { this is not hlo }").unwrap();

    let rt = Runtime::new(&d).unwrap();
    let err = rt.load("lenet5", Impl::Ref, 1);
    assert!(err.is_err(), "garbage HLO must fail to parse/compile");
    let _ = fs::remove_dir_all(&d);
}

#[test]
fn unknown_network_and_batch_are_clean_errors() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let rt = Runtime::new(Manifest::default_dir()).unwrap();
    assert!(rt.load("inception", Impl::Ref, 1).is_err());
    assert!(rt.load("lenet5", Impl::Ref, 7).is_err(), "no batch-7 executable exists");
}
