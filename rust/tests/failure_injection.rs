//! Failure-injection tests: corrupted/truncated artifacts and hostile
//! manifest contents must produce clean errors, never panics or UB.

use std::fs;

use tvm_fpga_flow::runtime::{Impl, Manifest, Runtime};

fn artifacts_ready() -> bool {
    Manifest::default_dir().join("manifest.json").exists()
}

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("tvm_fpga_flow_test_{tag}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&d);
    fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn missing_artifacts_dir_is_clean_error() {
    let err = Manifest::load("/nonexistent/path/xyz");
    assert!(err.is_err());
    assert!(format!("{}", err.err().unwrap()).contains("make artifacts"));
}

#[test]
fn corrupt_manifest_is_clean_error() {
    let d = temp_dir("corrupt");
    fs::write(d.join("manifest.json"), "{ not json !!!").unwrap();
    let err = Manifest::load(&d);
    assert!(err.is_err());
    let _ = fs::remove_dir_all(&d);
}

#[test]
fn manifest_missing_networks_key_is_clean_error() {
    let d = temp_dir("nonet");
    fs::write(d.join("manifest.json"), r#"{"kernels": []}"#).unwrap();
    assert!(Manifest::load(&d).is_err());
    let _ = fs::remove_dir_all(&d);
}

#[test]
fn truncated_weights_blob_is_clean_error() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let src = Manifest::default_dir();
    let d = temp_dir("truncated");
    // Copy manifest + lenet HLO, but truncate the weights blob.
    fs::copy(src.join("manifest.json"), d.join("manifest.json")).unwrap();
    for f in ["lenet5_ref.b1.hlo.txt"] {
        fs::copy(src.join(f), d.join(f)).unwrap();
    }
    let blob = fs::read(src.join("lenet5.weights.bin")).unwrap();
    fs::write(d.join("lenet5.weights.bin"), &blob[..blob.len() / 2]).unwrap();

    let rt = Runtime::new(&d).unwrap();
    let err = rt.load("lenet5", Impl::Ref, 1);
    assert!(err.is_err(), "truncated weights must fail to load");
    let msg = format!("{}", err.err().unwrap());
    assert!(msg.contains("blob too short") || msg.contains("No such file"), "{msg}");
    let _ = fs::remove_dir_all(&d);
}

#[test]
fn garbage_hlo_text_is_clean_error() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let src = Manifest::default_dir();
    let d = temp_dir("badhlo");
    fs::copy(src.join("manifest.json"), d.join("manifest.json")).unwrap();
    fs::copy(src.join("lenet5.weights.bin"), d.join("lenet5.weights.bin")).unwrap();
    fs::write(d.join("lenet5_ref.b1.hlo.txt"), "ENTRY { this is not hlo }").unwrap();

    let rt = Runtime::new(&d).unwrap();
    let err = rt.load("lenet5", Impl::Ref, 1);
    assert!(err.is_err(), "garbage HLO must fail to parse/compile");
    let _ = fs::remove_dir_all(&d);
}

#[test]
fn unknown_network_and_batch_are_clean_errors() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let rt = Runtime::new(Manifest::default_dir()).unwrap();
    assert!(rt.load("inception", Impl::Ref, 1).is_err());
    assert!(rt.load("lenet5", Impl::Ref, 7).is_err(), "no batch-7 executable exists");
}

/// Chaos scenario for the stage pipeline: one stage is injected with a
/// 5x service time. The pipeline must (a) keep completing frames — no
/// deadlock under sustained backpressure, (b) degrade throughput to the
/// bottleneck's rate rather than the sum of stage times, and (c)
/// attribute the slowdown to the slow stage in the stats snapshot.
#[test]
fn slow_stage_degrades_throughput_without_deadlock() {
    use std::time::{Duration, Instant};
    use tvm_fpga_flow::coordinator::{PipelineConfig, PipelineServer, StageSpec};

    let slow = Duration::from_millis(10);
    let cfg = PipelineConfig {
        stages: vec![
            StageSpec { name: "front".into(), stage_time: Duration::from_millis(2), transfer_bytes: 0 },
            StageSpec { name: "chaos".into(), stage_time: slow, transfer_bytes: 64 },
            StageSpec { name: "back".into(), stage_time: Duration::from_millis(2), transfer_bytes: 64 },
        ],
        frame_elems: 16,
        num_classes: 10,
        channel_depth: 2,
        queue_capacity: 64,
        time_scale: 1.0,
        classes: Vec::new(),
    };
    let server = PipelineServer::start(cfg).expect("pipeline starts");
    let frame: Vec<f32> = (0..16).map(|i| i as f32).collect();
    let n = 30usize;
    let t0 = Instant::now();
    let pending: Vec<_> = (0..n)
        .map(|_| server.infer_async(frame.clone()).expect("queue holds the burst"))
        .collect();
    for rx in pending {
        rx.recv().expect("worker alive").expect("no inference error");
    }
    let wall = t0.elapsed();
    let stats = server.shutdown();

    assert_eq!(stats.completed, n as u64, "every frame must drain despite the slow stage");
    assert_eq!(stats.rejected, 0);
    // Steady state is set by the bottleneck: the run must take at least
    // n * slow (minus the pipeline fill) and nowhere near n * sum(stages)
    // would be needed if stages serialized per frame — but it must also
    // not collapse below the bottleneck rate (which would mean frames
    // skipped a stage).
    let floor = slow * (n as u32 - 2);
    assert!(
        wall >= floor,
        "finished in {wall:?} — faster than the bottleneck allows ({floor:?}); \
         frames must have bypassed the slow stage"
    );
    let ceiling = slow * (n as u32) + Duration::from_millis(200);
    assert!(
        wall <= ceiling,
        "took {wall:?} (> {ceiling:?}): backpressure is serializing stages \
         instead of overlapping them"
    );
    // Attribution: the chaos stage owns the busy time.
    assert_eq!(
        stats.bottleneck(),
        Some(1),
        "snapshot must attribute the bottleneck to the injected slow stage"
    );
    let busy: Vec<u64> = stats.replicas.iter().map(|r| r.busy_us).collect();
    assert!(
        busy[1] > 3 * busy[0] && busy[1] > 3 * busy[2],
        "slow stage busy time must dominate: {busy:?}"
    );
}

/// Chaos scenario for the replica fleet, under *replayed* load: one of
/// two replicas panics mid-batch partway through the run. The fleet must
/// (a) keep serving on the survivor, (b) lose only the requests that were
/// physically in the dead replica's hands (its in-flight batch plus its
/// one staged batch), and (c) keep every per-class count consistent —
/// nothing silently vanishes.
#[test]
fn replica_kill_under_replayed_load_bounds_the_damage() {
    use std::time::Duration;
    use tvm_fpga_flow::coordinator::loadgen::{replay, LoadTrace};
    use tvm_fpga_flow::coordinator::{
        EngineSpec, InferenceServer, ServerConfig, SimEngine, SloClass,
    };

    const ELEMS: usize = 16;
    const MAX_BATCH: usize = 8;
    let engine = || {
        SimEngine::new("sim", ELEMS, 10, MAX_BATCH, Duration::ZERO, Duration::from_micros(200))
    };
    let server = InferenceServer::start(ServerConfig {
        replicas: vec![
            EngineSpec::Sim(engine()),
            EngineSpec::Sim(engine().with_chaos_kill_after(16)),
        ],
        max_batch: MAX_BATCH,
        max_wait: Duration::from_micros(500),
        queue_capacity: 256,
        classes: vec![
            SloClass::new("gold", Duration::from_millis(50)),
            SloClass::new("silver", Duration::from_millis(200)),
            SloClass::best_effort("bulk"),
        ],
        ..Default::default()
    })
    .unwrap();

    // 200 arrivals over ~100 ms, 25% gold / 25% silver / 50% bulk — light
    // enough that the healthy replica alone can absorb it.
    let trace = LoadTrace::bursty(200, 20, 10_000, &[1, 1, 2], 7);
    let frames: Vec<Vec<f32>> = (0..8).map(|i| vec![i as f32; ELEMS]).collect();
    let report = replay(&server, &trace, &frames);
    let snap = server.shutdown();

    // Client-side accounting closes per class: every request the trace
    // offered is exactly one of answered / shed / errored / dropped.
    // (`shed_overload` covers both submit-time refusals and
    // post-acceptance evictions, so the identity is against `sent`.)
    for c in &report.classes {
        assert_eq!(c.sent, c.ok + c.errored + c.dropped + c.shed_total(), "{c:?}");
    }
    let accepted: u64 = report.classes.iter().map(|c| c.accepted).sum();
    let dropped: u64 = report.classes.iter().map(|c| c.dropped).sum();
    let ok: u64 = report.classes.iter().map(|c| c.ok).sum();
    let errored: u64 = report.classes.iter().map(|c| c.errored).sum();
    assert_eq!(errored, 0, "nothing in this scenario produces engine errors");

    // The kill drops the batch mid-execution plus at most the one staged
    // batch behind it — never more.
    assert!(dropped >= 1, "the poisoned replica crossed 16 frames; its batch must drop");
    assert!(
        dropped <= 2 * MAX_BATCH as u64,
        "a dead replica holds at most one executing + one staged batch, \
         but {dropped} requests dropped"
    );
    // Everything else is answered: the survivor absorbed the rest.
    assert_eq!(ok, accepted - dropped, "non-dropped requests must all answer");
    assert_eq!(snap.completed, accepted - dropped);
    assert!(
        snap.replicas[0].frames > snap.replicas[1].frames,
        "routing must flow around the corpse: {:?} vs {:?}",
        snap.replicas[0].frames,
        snap.replicas[1].frames
    );
    // Under this light load the gold SLO survives the crash.
    if let Some(p99) = report.classes[0].p99_us {
        assert!(p99 <= 50_000, "gold p99 {p99}us blew its 50ms budget despite spare capacity");
    }
}

/// Chaos scenario: a hidden straggler. One replica silently runs 20x
/// slower than the throughput model its routing weight advertises, while
/// the trace offers more load than the degraded fleet can serve. The
/// coordinator must keep the books balanced (no lost requests), shed the
/// overload out of the *lowest* class, and keep answered gold traffic
/// inside its SLO.
#[test]
fn slow_replica_sheds_low_class_first_and_keeps_gold_slo() {
    use std::time::Duration;
    use tvm_fpga_flow::coordinator::loadgen::{replay, LoadTrace};
    use tvm_fpga_flow::coordinator::{
        EngineSpec, InferenceServer, ServerConfig, SimEngine, SloClass,
    };

    const ELEMS: usize = 16;
    let engine = || {
        SimEngine::new("sim", ELEMS, 10, 8, Duration::ZERO, Duration::from_micros(500))
    };
    let server = InferenceServer::start(ServerConfig {
        replicas: vec![
            EngineSpec::Sim(engine()),
            EngineSpec::Sim(engine().with_chaos_slowdown(20.0)),
        ],
        max_batch: 8,
        max_wait: Duration::from_micros(500),
        queue_capacity: 32,
        classes: vec![
            SloClass::new("gold", Duration::from_millis(500)),
            SloClass::new("silver", Duration::from_secs(1)),
            SloClass::best_effort("bulk"),
        ],
        ..Default::default()
    })
    .unwrap();

    // 300 arrivals in 50-request bursts every 5 ms — far past what the
    // half-crippled fleet sustains, so the queue must overflow.
    let trace = LoadTrace::bursty(300, 50, 5_000, &[1, 2, 7], 11);
    let frames: Vec<Vec<f32>> = (0..8).map(|i| vec![0.5 + i as f32; ELEMS]).collect();
    let report = replay(&server, &trace, &frames);
    let snap = server.shutdown();

    // Nothing vanishes: a straggler slows, it does not drop.
    let dropped: u64 = report.classes.iter().map(|c| c.dropped).sum();
    assert_eq!(dropped, 0, "a slow replica must not lose requests");
    assert_eq!(snap.completed, snap.submitted, "books must balance at shutdown");

    // The overload was real and the shedding landed on the bottom class.
    let shed = report.total_shed();
    assert!(shed > 0, "10x overload on a crippled fleet must shed something");
    assert!(
        report.shed_share(2) >= 0.5,
        "bulk must absorb the bulk of the shedding: shares {:?}",
        (0..3).map(|i| report.shed_share(i)).collect::<Vec<_>>()
    );
    assert!(
        report.classes[0].shed_total() <= report.classes[2].shed_total(),
        "gold must never shed more than bulk"
    );

    // Answered gold stays inside its budget even with the straggler in
    // the rotation.
    if let Some(p99) = report.classes[0].p99_us {
        assert!(p99 <= 500_000, "gold p99 {p99}us blew its 500ms budget");
    }

    // The slowdown is invisible to the router's weight but visible in the
    // fleet stats: the straggler soaks busy time while the healthy
    // replica serves more frames via overflow routing.
    assert!(
        snap.replicas[0].frames > snap.replicas[1].frames,
        "healthy replica must absorb overflow: {} vs {}",
        snap.replicas[0].frames,
        snap.replicas[1].frames
    );
    assert!(
        snap.replicas[1].busy_us > snap.replicas[0].busy_us,
        "straggler busy time must dominate: {} vs {}",
        snap.replicas[1].busy_us,
        snap.replicas[0].busy_us
    );
}
