//! Failure-injection tests: corrupted/truncated artifacts and hostile
//! manifest contents must produce clean errors, never panics or UB.

use std::fs;

use tvm_fpga_flow::runtime::{Impl, Manifest, Runtime};

fn artifacts_ready() -> bool {
    Manifest::default_dir().join("manifest.json").exists()
}

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("tvm_fpga_flow_test_{tag}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&d);
    fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn missing_artifacts_dir_is_clean_error() {
    let err = Manifest::load("/nonexistent/path/xyz");
    assert!(err.is_err());
    assert!(format!("{}", err.err().unwrap()).contains("make artifacts"));
}

#[test]
fn corrupt_manifest_is_clean_error() {
    let d = temp_dir("corrupt");
    fs::write(d.join("manifest.json"), "{ not json !!!").unwrap();
    let err = Manifest::load(&d);
    assert!(err.is_err());
    let _ = fs::remove_dir_all(&d);
}

#[test]
fn manifest_missing_networks_key_is_clean_error() {
    let d = temp_dir("nonet");
    fs::write(d.join("manifest.json"), r#"{"kernels": []}"#).unwrap();
    assert!(Manifest::load(&d).is_err());
    let _ = fs::remove_dir_all(&d);
}

#[test]
fn truncated_weights_blob_is_clean_error() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let src = Manifest::default_dir();
    let d = temp_dir("truncated");
    // Copy manifest + lenet HLO, but truncate the weights blob.
    fs::copy(src.join("manifest.json"), d.join("manifest.json")).unwrap();
    for f in ["lenet5_ref.b1.hlo.txt"] {
        fs::copy(src.join(f), d.join(f)).unwrap();
    }
    let blob = fs::read(src.join("lenet5.weights.bin")).unwrap();
    fs::write(d.join("lenet5.weights.bin"), &blob[..blob.len() / 2]).unwrap();

    let rt = Runtime::new(&d).unwrap();
    let err = rt.load("lenet5", Impl::Ref, 1);
    assert!(err.is_err(), "truncated weights must fail to load");
    let msg = format!("{}", err.err().unwrap());
    assert!(msg.contains("blob too short") || msg.contains("No such file"), "{msg}");
    let _ = fs::remove_dir_all(&d);
}

#[test]
fn garbage_hlo_text_is_clean_error() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let src = Manifest::default_dir();
    let d = temp_dir("badhlo");
    fs::copy(src.join("manifest.json"), d.join("manifest.json")).unwrap();
    fs::copy(src.join("lenet5.weights.bin"), d.join("lenet5.weights.bin")).unwrap();
    fs::write(d.join("lenet5_ref.b1.hlo.txt"), "ENTRY { this is not hlo }").unwrap();

    let rt = Runtime::new(&d).unwrap();
    let err = rt.load("lenet5", Impl::Ref, 1);
    assert!(err.is_err(), "garbage HLO must fail to parse/compile");
    let _ = fs::remove_dir_all(&d);
}

#[test]
fn unknown_network_and_batch_are_clean_errors() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let rt = Runtime::new(Manifest::default_dir()).unwrap();
    assert!(rt.load("inception", Impl::Ref, 1).is_err());
    assert!(rt.load("lenet5", Impl::Ref, 7).is_err(), "no batch-7 executable exists");
}

/// Chaos scenario for the stage pipeline: one stage is injected with a
/// 5x service time. The pipeline must (a) keep completing frames — no
/// deadlock under sustained backpressure, (b) degrade throughput to the
/// bottleneck's rate rather than the sum of stage times, and (c)
/// attribute the slowdown to the slow stage in the stats snapshot.
#[test]
fn slow_stage_degrades_throughput_without_deadlock() {
    use std::time::{Duration, Instant};
    use tvm_fpga_flow::coordinator::{PipelineConfig, PipelineServer, StageSpec};

    let slow = Duration::from_millis(10);
    let cfg = PipelineConfig {
        stages: vec![
            StageSpec { name: "front".into(), stage_time: Duration::from_millis(2), transfer_bytes: 0 },
            StageSpec { name: "chaos".into(), stage_time: slow, transfer_bytes: 64 },
            StageSpec { name: "back".into(), stage_time: Duration::from_millis(2), transfer_bytes: 64 },
        ],
        frame_elems: 16,
        num_classes: 10,
        channel_depth: 2,
        queue_capacity: 64,
        time_scale: 1.0,
    };
    let server = PipelineServer::start(cfg).expect("pipeline starts");
    let frame: Vec<f32> = (0..16).map(|i| i as f32).collect();
    let n = 30usize;
    let t0 = Instant::now();
    let pending: Vec<_> = (0..n)
        .map(|_| server.infer_async(frame.clone()).expect("queue holds the burst"))
        .collect();
    for rx in pending {
        rx.recv().expect("worker alive").expect("no inference error");
    }
    let wall = t0.elapsed();
    let stats = server.shutdown();

    assert_eq!(stats.completed, n as u64, "every frame must drain despite the slow stage");
    assert_eq!(stats.rejected, 0);
    // Steady state is set by the bottleneck: the run must take at least
    // n * slow (minus the pipeline fill) and nowhere near n * sum(stages)
    // would be needed if stages serialized per frame — but it must also
    // not collapse below the bottleneck rate (which would mean frames
    // skipped a stage).
    let floor = slow * (n as u32 - 2);
    assert!(
        wall >= floor,
        "finished in {wall:?} — faster than the bottleneck allows ({floor:?}); \
         frames must have bypassed the slow stage"
    );
    let ceiling = slow * (n as u32) + Duration::from_millis(200);
    assert!(
        wall <= ceiling,
        "took {wall:?} (> {ceiling:?}): backpressure is serializing stages \
         instead of overlapping them"
    );
    // Attribution: the chaos stage owns the busy time.
    assert_eq!(
        stats.bottleneck(),
        Some(1),
        "snapshot must attribute the bottleneck to the injected slow stage"
    );
    let busy: Vec<u64> = stats.replicas.iter().map(|r| r.busy_us).collect();
    assert!(
        busy[1] > 3 * busy[0] && busy[1] > 3 * busy[2],
        "slow stage busy time must dominate: {busy:?}"
    );
}
