//! SLO admission-control and priority-queue tests: the concurrent
//! property test for the priority [`BatchQueue`], typed deadline
//! rejection, shed-lowest-first eviction, and queue-latency-driven
//! autoscaling.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier, Mutex};
use std::time::Duration;

use tvm_fpga_flow::coordinator::{
    BatchQueue, EngineSpec, HysteresisPolicy, InferenceServer, PushError, ServerConfig,
    ServerError, SimEngine, SloClass,
};
use tvm_fpga_flow::util::prop;
use tvm_fpga_flow::util::rng::Rng;

const ELEMS: usize = 16;

fn frame(tag: usize) -> Vec<f32> {
    (0..ELEMS).map(|i| (tag * 31 + i) as f32).collect()
}

fn sim(frame_time_us: u64, native_batch: usize) -> SimEngine {
    SimEngine::new(
        "sim",
        ELEMS,
        10,
        native_batch,
        Duration::ZERO,
        Duration::from_micros(frame_time_us),
    )
}

/// N pushers x M poppers hammering one priority queue: no item is lost or
/// duplicated (popped ∪ evicted == accepted, disjoint), batches never
/// exceed `max_batch`, and within every batch class indices are
/// non-decreasing — a lower-priority item is never flushed ahead of a
/// higher-priority one sharing its batch.
#[test]
fn concurrent_pushers_and_poppers_conserve_items_and_order_batches() {
    prop::check("priority queue conservation", |rng, _case| {
        let capacity = 8 + rng.below(24) as usize;
        let max_batch = 2 + rng.below(7) as usize;
        let num_classes = 1 + rng.below(3) as usize;
        let n_pushers = 2 + rng.below(3) as usize;
        let n_poppers = 1 + rng.below(2) as usize;
        let per_pusher = 32u64;

        let queue: Arc<BatchQueue<(usize, u64)>> = Arc::new(BatchQueue::with_classes(
            capacity,
            max_batch,
            Duration::from_micros(500),
            num_classes,
        ));
        let start = Arc::new(Barrier::new(n_pushers + n_poppers));
        let accepted = Arc::new(Mutex::new(Vec::<u64>::new()));
        let evicted = Arc::new(Mutex::new(Vec::<u64>::new()));
        let popped = Arc::new(Mutex::new(Vec::<u64>::new()));
        let batches = Arc::new(AtomicU64::new(0));

        let mut handles = Vec::new();
        for p in 0..n_pushers {
            let queue = Arc::clone(&queue);
            let start = Arc::clone(&start);
            let accepted = Arc::clone(&accepted);
            let evicted = Arc::clone(&evicted);
            let seed = rng.below(u64::MAX);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(seed ^ p as u64);
                start.wait();
                for i in 0..per_pusher {
                    let uid = p as u64 * 1_000_000 + i;
                    let class = rng.below(num_classes as u64) as usize;
                    match queue.push_class((class, uid), class) {
                        Ok(victim) => {
                            accepted.lock().unwrap().push(uid);
                            if let Some((_, v_uid)) = victim {
                                evicted.lock().unwrap().push(v_uid);
                            }
                        }
                        Err(PushError::Full(_)) => {} // refused, never entered
                        Err(PushError::Closed(_)) => panic!("queue closed while pushing"),
                    }
                }
            }));
        }
        let mut popper_handles = Vec::new();
        for _ in 0..n_poppers {
            let queue = Arc::clone(&queue);
            let start = Arc::clone(&start);
            let popped = Arc::clone(&popped);
            let batches = Arc::clone(&batches);
            popper_handles.push(std::thread::spawn(move || {
                start.wait();
                while let Some(batch) = queue.pop_batch() {
                    batches.fetch_add(1, Ordering::Relaxed);
                    assert!(batch.len() <= max_batch, "batch of {} > {max_batch}", batch.len());
                    assert!(!batch.is_empty());
                    // Lanes drain highest-priority-first: class indices
                    // are non-decreasing through any one batch.
                    for w in batch.windows(2) {
                        assert!(
                            w[0].0 <= w[1].0,
                            "class {} flushed after class {} in one batch",
                            w[0].0,
                            w[1].0
                        );
                    }
                    popped.lock().unwrap().extend(batch.iter().map(|&(_, uid)| uid));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        queue.close();
        for h in popper_handles {
            h.join().unwrap();
        }

        let mut accepted = accepted.lock().unwrap().clone();
        let mut seen: Vec<u64> = popped.lock().unwrap().clone();
        let evicted = evicted.lock().unwrap().clone();
        seen.extend(&evicted);
        accepted.sort_unstable();
        seen.sort_unstable();
        // No duplicates anywhere (an item both popped and evicted would
        // collide here), and the accounting closes exactly.
        assert_eq!(seen, accepted, "popped ∪ evicted must equal the accepted pushes");
        // Every flush is attributed to exactly one wake cause.
        let fc = queue.flush_counts();
        assert_eq!(fc.full + fc.deadline + fc.close, batches.load(Ordering::Relaxed));
    });
}

/// A deadline the current latency signals cannot meet is refused with the
/// typed error *before* touching the queue — shed requests record no
/// queue latency.
#[test]
fn unmeetable_deadline_is_typed_and_sheds_before_queueing() {
    let server = InferenceServer::start(ServerConfig {
        replicas: vec![EngineSpec::Sim(sim(400, 4))],
        max_batch: 4,
        max_wait: Duration::from_micros(300),
        classes: vec![
            SloClass::new("tight", Duration::from_micros(1)),
            SloClass::best_effort("bulk"),
        ],
        ..Default::default()
    })
    .unwrap();

    // Warm the admission signals through the best-effort lane: queue
    // percentiles + execution EWMA are zero (cold start admits) until
    // real batches flow.
    for i in 0..6 {
        server.infer_class(frame(i), 1).unwrap();
    }

    let err = server.infer_class(frame(99), 0).expect_err("1us budget must be refused");
    match err.downcast_ref::<ServerError>() {
        Some(ServerError::DeadlineUnmeetable { deadline_us, predicted_us }) => {
            assert_eq!(*deadline_us, 1);
            assert!(*predicted_us > 1, "prediction must come from live signals");
        }
        other => panic!("expected DeadlineUnmeetable, got {other:?}"),
    }
    assert!(format!("{err}").contains("deadline unmeetable"), "{err}");

    let stats = server.shutdown();
    assert_eq!(stats.deadline_rejected, 1);
    assert_eq!(stats.classes[0].shed_deadline, 1);
    assert_eq!(stats.classes[0].completed, 0);
    assert_eq!(stats.classes[1].completed, 6);
    // Shed-before-queue, observable: only dispatched requests record
    // queue latency, so the sample count equals completions exactly.
    assert_eq!(stats.queue_samples, stats.completed);
    assert_eq!(stats.completed, stats.submitted, "shed requests never count as submitted");
}

/// Under queue pressure the shedding lands on the lowest class: gold
/// keeps being admitted (evicting queued bulk if it must) and every gold
/// request is answered, while bulk absorbs all of the Overloaded errors.
#[test]
fn overload_sheds_lowest_class_first() {
    let server = InferenceServer::start(ServerConfig {
        replicas: vec![EngineSpec::Sim(sim(2_000, 4))],
        max_batch: 4,
        max_wait: Duration::from_micros(500),
        queue_capacity: 8,
        classes: vec![SloClass::best_effort("gold"), SloClass::best_effort("bulk")],
        ..Default::default()
    })
    .unwrap();

    // Flood the bulk lane far past queue capacity; keep the accepted
    // receivers (some will be evicted later by arriving gold).
    let mut bulk_rx = Vec::new();
    let mut bulk_refused = 0u64;
    for i in 0..40 {
        match server.infer_class_async(frame(i), 1) {
            Ok(rx) => bulk_rx.push(rx),
            Err(e) => {
                assert!(
                    matches!(e.downcast_ref::<ServerError>(), Some(ServerError::Overloaded { .. })),
                    "bulk refusal must be typed Overloaded: {e}"
                );
                bulk_refused += 1;
            }
        }
    }
    assert!(bulk_refused > 0, "40 pushes into an 8-slot queue must refuse some");

    // Gold arrives into the full queue: admitted (evicting bulk when no
    // free slot remains) and always answered.
    let gold_rx: Vec<_> =
        (0..3).map(|i| server.infer_class_async(frame(100 + i), 0).expect("gold admitted")).collect();
    for rx in gold_rx {
        rx.recv().unwrap().expect("every gold request answers");
    }
    let mut bulk_evicted = 0u64;
    for rx in bulk_rx {
        match rx.recv().unwrap() {
            Ok(_) => {}
            Err(e) => {
                assert!(
                    matches!(e.downcast_ref::<ServerError>(), Some(ServerError::Overloaded { .. })),
                    "evicted bulk must see Overloaded: {e}"
                );
                bulk_evicted += 1;
            }
        }
    }

    let stats = server.shutdown();
    assert_eq!(stats.classes[0].shed_overload, 0, "gold must not shed");
    assert_eq!(stats.classes[0].completed, 3);
    assert_eq!(stats.classes[1].shed_overload, bulk_refused + bulk_evicted);
    assert_eq!(stats.rejected, bulk_refused + bulk_evicted);
    assert_eq!(stats.completed, stats.submitted, "books balance after evictions");
}

/// A queue-latency burst drives the hysteresis autoscaler: a fleet that
/// starts at `min_replicas` grows under load, and the growth is visible
/// in the snapshot counters.
#[test]
fn autoscaler_grows_the_active_fleet_under_burst() {
    let server = InferenceServer::start(ServerConfig {
        replicas: (0..4).map(|_| EngineSpec::Sim(sim(300, 4))).collect(),
        max_batch: 4,
        max_wait: Duration::from_micros(200),
        queue_capacity: 2048,
        autoscale: Some(HysteresisPolicy::new(1, 4, 1_000, 10)),
        ..Default::default()
    })
    .unwrap();
    assert_eq!(server.stats().active_replicas, 1, "policy starts the fleet at min");

    let pending: Vec<_> = (0..200)
        .map(|i| server.infer_async(frame(i)).expect("queue holds the burst"))
        .collect();
    for rx in pending {
        rx.recv().unwrap().unwrap();
    }
    let stats = server.shutdown();
    assert_eq!(stats.completed, 200);
    assert!(stats.scale_ups >= 1, "a 200-request burst must trip the scale-up threshold");
    assert!(
        stats.active_replicas > 1,
        "active fleet must have grown: {}",
        stats.active_replicas
    );
    // More than one replica actually served frames after the scale-up.
    let serving = stats.replicas.iter().filter(|r| r.frames > 0).count();
    assert!(serving > 1, "scaled-up replicas must receive traffic");
}
