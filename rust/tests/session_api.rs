//! Staged-API contract tests: misuse returns typed errors (never panics),
//! the synthesis memo is deterministic, and legality follows the target's
//! clock.

use tvm_fpga_flow::device::{FpgaDevice, Target};
use tvm_fpga_flow::flow::{
    default_factors, legality, patterns, CompileError, Compiler, Mode, ModeChoice, OptConfig,
    OptLevel,
};
use tvm_fpga_flow::graph::models;
use tvm_fpga_flow::schedule::OptKind;

fn as_compile_error(e: &anyhow::Error) -> &CompileError {
    e.downcast_ref::<CompileError>()
        .unwrap_or_else(|| panic!("expected a typed CompileError, got: {e}"))
}

#[test]
fn unknown_target_is_a_typed_error() {
    let err = Compiler::for_target("virtex7").unwrap_err();
    match as_compile_error(&err) {
        CompileError::UnknownTarget { name } => assert_eq!(name, "virtex7"),
        other => panic!("wrong variant: {other:?}"),
    }
    // The message lists the registered names so the CLI error is actionable.
    let msg = err.to_string();
    for name in Target::names() {
        assert!(msg.contains(name), "{msg}");
    }
}

#[test]
fn simulating_before_lowering_is_a_typed_error() {
    let compiler = Compiler::default();
    let mut session = compiler.graph(&models::lenet5());
    let err = session.simulate().unwrap_err();
    assert!(matches!(
        as_compile_error(&err),
        CompileError::StageOrder { wanted: "simulate", .. }
    ));
    let err = session.synthesize().unwrap_err();
    assert!(matches!(
        as_compile_error(&err),
        CompileError::StageOrder { wanted: "synthesize", missing: "lower" }
    ));
    // Once the stages run in order, the same session succeeds.
    session.lower().unwrap();
    session.synthesize().unwrap();
    assert!(session.simulate().unwrap().performance.fps > 0.0);
}

#[test]
fn missing_graph_is_a_typed_error() {
    let compiler = Compiler::default();
    let err = compiler.session().lower().unwrap_err();
    assert!(matches!(as_compile_error(&err), CompileError::MissingGraph));
}

#[test]
fn invalid_graph_is_a_typed_error() {
    let mut g = models::lenet5();
    // Corrupt the DAG: node 1 now references a later node.
    g.nodes[1].inputs = vec![9];
    let err = Compiler::default().graph(&g).lower().unwrap_err();
    assert!(matches!(as_compile_error(&err), CompileError::InvalidGraph(_)), "{err}");
}

#[test]
fn illegal_plan_is_a_typed_error() {
    // Without cached reads the 3×3 group streams its weight tile straight
    // from DDR at 576 words/cycle — far over the S10SX's 76-word roof.
    let g = models::resnet34();
    let cfg = OptConfig::optimized().without(OptKind::CachedWrite);
    let err = Compiler::default()
        .graph(&g)
        .mode(Mode::Folded)
        .opts(cfg)
        .lower()
        .map(|_| ())
        .unwrap_err();
    match as_compile_error(&err) {
        CompileError::IllegalPlan { network, violations } => {
            assert_eq!(network, "resnet34");
            assert!(
                violations.iter().any(|v| v.message.contains("bandwidth roof")),
                "{violations:?}"
            );
        }
        other => panic!("wrong variant: {other:?}"),
    }
}

#[test]
fn routing_failure_is_a_typed_error() {
    // 64×64 tiles on every group pass rules 1/2 (operands are cached) but
    // blow the DSP budget — rule 3 surfaces as a typed routing failure.
    let g = models::resnet34();
    let mut plan = default_factors(&g);
    for (_, t) in plan.group_tiles.iter_mut() {
        *t = (64, 64);
    }
    let err = Compiler::default()
        .compile_with(&g, Mode::Folded, &OptConfig::optimized(), &plan)
        .unwrap_err();
    assert!(matches!(as_compile_error(&err), CompileError::RoutingFailure(_)), "{err}");
}

#[test]
fn legality_loosens_with_a_slower_target_clock() {
    // The same no-cache plan that violates the roof at 250 MHz is legal on
    // a target whose legality clock is 25 MHz (the DDR feeds ~768 words
    // per slow cycle). Checked both through the raw rule checker and the
    // staged API.
    let g = models::resnet34();
    let cfg = OptConfig::optimized().without(OptKind::CachedWrite);
    let plan = default_factors(&g);
    let (prog, _) = patterns::build_folded(&g, &cfg, &plan);

    let dev = FpgaDevice::stratix10sx();
    assert!(!legality::check_program(&prog, &dev, 250.0).is_empty());
    assert!(legality::check_program(&prog, &dev, 25.0).is_empty());

    let slow_dev = FpgaDevice { legality_clock_mhz: 25.0, ..FpgaDevice::stratix10sx() };
    let slow = Compiler::new(Target::custom("s10-slow-clock", slow_dev));
    slow.graph(&g).mode(Mode::Folded).opts(cfg).lower().expect("legal at 25 MHz");
}

#[test]
fn legality_tightens_with_a_faster_target_clock() {
    // At a 5 GHz legality clock the roof shrinks to ~3 words, so even the
    // default cached plan's output streams violate rule 1.
    let g = models::resnet34();
    let fast_dev = FpgaDevice { legality_clock_mhz: 5000.0, ..FpgaDevice::stratix10sx() };
    let fast = Compiler::new(Target::custom("s10-fast-clock", fast_dev));
    let err = fast.graph(&g).mode(Mode::Folded).lower().map(|_| ()).unwrap_err();
    assert!(matches!(as_compile_error(&err), CompileError::IllegalPlan { .. }), "{err}");
    // The identical plan lowers fine at the real 250 MHz clock.
    Compiler::default().graph(&g).mode(Mode::Folded).lower().expect("legal at 250 MHz");
}

#[test]
fn synthesis_memo_returns_identical_reports() {
    let compiler = Compiler::default();
    let g = models::mobilenet_v1();
    let mut first = compiler.graph(&g).mode(Mode::Folded);
    let d1 = first.lower().unwrap().synthesize().unwrap();
    let mut second = compiler.graph(&g).mode(Mode::Folded);
    let d2 = second.lower().unwrap().synthesize().unwrap();

    assert!(!d1.cache_hit && d2.cache_hit, "second synthesis must be a memo hit");
    assert_eq!(d1.synthesis.fmax_mhz, d2.synthesis.fmax_mhz);
    assert_eq!(d1.synthesis.routed, d2.synthesis.routed);
    assert_eq!(d1.synthesis.max_lsu_width_bytes, d2.synthesis.max_lsu_width_bytes);
    assert_eq!(d1.synthesis.resources.total, d2.synthesis.resources.total);
    assert_eq!(d1.synthesis.resources.utilization, d2.synthesis.resources.utilization);
    // And the simulated design built on top is byte-for-byte equivalent.
    assert_eq!(
        d1.simulate().unwrap().performance.fps,
        d2.simulate().unwrap().performance.fps
    );
}

#[test]
fn every_registered_target_compiles_lenet_end_to_end() {
    for name in Target::names() {
        let compiler = Compiler::for_target(name).unwrap();
        let g = models::lenet5();
        let acc = compiler
            .graph(&g)
            .mode(ModeChoice::Auto)
            .lower()
            .unwrap_or_else(|e| panic!("{name}: lower failed: {e}"))
            .synthesize()
            .unwrap_or_else(|e| panic!("{name}: synthesize failed: {e}"))
            .simulate()
            .unwrap();
        assert!(acc.performance.fps > 0.0, "{name}");
        assert!(acc.synthesis.resources.utilization.fits(), "{name}");
    }
}

#[test]
fn targets_change_the_synthesized_design() {
    // The same LeNet-5 lowering must synthesize to different utilization
    // and clock on different device envelopes.
    let g = models::lenet5();
    let on = |name: &str| {
        let c = Compiler::for_target(name).unwrap();
        let acc = c.compile(&g, Mode::Pipelined, OptLevel::Optimized).unwrap();
        (acc.synthesis.resources.utilization.logic_frac, acc.synthesis.fmax_mhz)
    };
    let (s10_logic, s10_fmax) = on("stratix10sx");
    let (a10_logic, a10_fmax) = on("arria10gx");
    assert!(a10_logic > s10_logic, "smaller device → higher utilization");
    assert!(a10_fmax < s10_fmax, "slower fabric + higher utilization → lower clock");
}

#[test]
fn weight_density_out_of_domain_is_a_typed_error() {
    // Regression: a weight density outside (0, 1] used to either panic
    // (assert inside the scheduler) or silently produce nonsense costs;
    // the session now rejects it up front with a typed error.
    let compiler = Compiler::default();
    let g = models::lenet5();
    let plan = default_factors(&g);
    for bad in [0.0, -0.25, 1.5, f64::NAN] {
        let cfg = OptConfig::optimized().with_sparsity(bad);
        let err = compiler.compile_with(&g, Mode::Pipelined, &cfg, &plan).unwrap_err();
        match as_compile_error(&err) {
            CompileError::InvalidOptConfig { field, .. } => assert_eq!(*field, "weight_density"),
            other => panic!("wrong variant for {bad}: {other:?}"),
        }
        assert!(err.to_string().contains("weight_density"), "{err}");
    }
    // The domain boundary itself is legal, as is any interior density.
    for ok in [1.0, 0.5, 1e-3] {
        let cfg = OptConfig::optimized().with_sparsity(ok);
        let acc = compiler.compile_with(&g, Mode::Pipelined, &cfg, &plan).unwrap();
        assert!(acc.performance.fps > 0.0, "density {ok}");
    }
}

#[test]
fn session_trace_is_cached_with_the_lowering() {
    // The pass trace is part of the stage-1 artifact: lowering twice
    // returns the same trace, and it survives into the Accelerator.
    let compiler = Compiler::default();
    let mut session = compiler.graph(&models::lenet5()).mode(Mode::Pipelined);
    let n = session.lower().unwrap().trace.records.len();
    assert!(n > 0);
    assert_eq!(session.lower().unwrap().trace.records.len(), n);
    let acc = session.run().unwrap();
    assert_eq!(acc.pass_trace.records.len(), n);
    assert!(acc.pass_trace.applied() > 0);
}
