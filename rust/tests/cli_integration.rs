//! Integration tests over the `fpga-flow` binary itself: spawn the real
//! CLI and assert the output *shape* of the subcommands scripts and CI
//! dashboards consume (`explain`, `quantize`, `dse --json`, `verify`).

use std::process::Command;

fn run(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(env!("CARGO_BIN_EXE_fpga-flow"))
        .args(args)
        .output()
        .expect("spawn fpga-flow");
    (
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
        out.status.success(),
    )
}

#[test]
fn explain_prints_ordered_trace_with_skips_and_obligations() {
    let (out, err, ok) = run(&["explain", "--net", "lenet5", "--mode", "pipelined"]);
    assert!(ok, "explain failed: {err}");
    assert!(out.contains("pass trace — lenet5"), "{out}");
    // Header + per-pass rows with the Table-I abbreviations.
    assert!(out.contains("preserves"), "equivalence column missing: {out}");
    for abbrev in ["LF", "OF", "LU", "CW", "CH", "AR", "CE"] {
        assert!(out.contains(abbrev), "{abbrev} missing from trace: {out}");
    }
    // Folded-only passes are skipped in pipelined mode, naming the rule.
    assert!(out.contains("skipped:"), "{out}");
    assert!(out.contains("applied"), "{out}");
    // OF carries the float-tolerant obligation.
    assert!(out.contains("float-tolerant"), "{out}");
}

#[test]
fn quantize_reports_calibration_boundaries_and_resources() {
    let (out, err, ok) = run(&["quantize", "--net", "lenet5", "--precision", "int8"]);
    assert!(ok, "quantize failed: {err}");
    for needle in ["lenet5", "boundaries", "quantize", "dequantize", "top-1", "fp32", "int8"] {
        assert!(out.contains(needle), "quantize output missing '{needle}': {out}");
    }
    // The resource comparison table has both rows.
    assert!(out.contains("logic"), "{out}");
    assert!(out.contains("fmax"), "{out}");
}

#[test]
fn dse_json_emits_a_parseable_pareto_front() {
    let (out, err, ok) = run(&["dse", "--net", "lenet5", "--budget", "2", "--json"]);
    assert!(ok, "dse failed: {err}");
    let json = tvm_fpga_flow::util::json::parse(out.trim()).unwrap_or_else(|e| {
        panic!("dse --json did not emit valid JSON ({e}): {out}");
    });
    let pareto = json
        .get("pareto")
        .and_then(|p| p.as_arr())
        .unwrap_or_else(|| panic!("no pareto array: {out}"));
    assert!(!pareto.is_empty(), "empty pareto front: {out}");
    for pt in pareto {
        for key in ["precision", "fps"] {
            assert!(pt.get(key).is_some(), "pareto point missing '{key}': {out}");
        }
    }
}

#[test]
fn verify_quick_sweep_passes_on_lenet() {
    let (out, err, ok) = run(&["verify", "--net", "lenet5", "--frames", "4", "--quick"]);
    assert!(ok, "verify failed:\nstdout: {out}\nstderr: {err}");
    assert!(out.contains("differential verification"), "{out}");
    assert!(out.contains("scenarios agree with the reference executor"), "{out}");
    assert!(!out.contains("FAIL"), "{out}");
}

#[test]
fn unknown_subcommand_prints_help_and_succeeds() {
    let (out, _, ok) = run(&["definitely-not-a-command"]);
    assert!(ok);
    assert!(out.contains("fpga-flow"), "{out}");
    assert!(out.contains("verify"), "help must document the verify subcommand: {out}");
}
