//! Integration tests over the PJRT runtime + coordinator against the real
//! AOT artifacts (skipped gracefully when `make artifacts` hasn't run).
//!
//! These are the execution-level half of the interchange contract whose
//! parse-level half lives in python/tests/test_aot.py.

use tvm_fpga_flow::coordinator::{InferenceServer, ServerConfig};
use tvm_fpga_flow::data;
use tvm_fpga_flow::runtime::{Impl, Manifest, Runtime};

fn ready() -> bool {
    let ok = Manifest::default_dir().join("manifest.json").exists()
        && tvm_fpga_flow::runtime::backend_available();
    if !ok {
        eprintln!("skipping: needs `make artifacts` + the real xla bindings");
    }
    ok
}

#[test]
fn manifest_matches_rust_graph_parameter_counts() {
    if !ready() {
        return;
    }
    let m = Manifest::load(Manifest::default_dir()).unwrap();
    // The python L2 models and the rust graph IR must describe the same
    // networks: parameter byte totals must agree exactly.
    for g in tvm_fpga_flow::graph::models::all() {
        let net = m.network(&g.name).expect("network in manifest");
        let total: usize = net.params.iter().map(|(_, _, _, nbytes)| nbytes).sum();
        assert_eq!(total as u64, g.weight_bytes(), "{}: python vs rust param bytes", g.name);
    }
}

#[test]
fn lenet_batch1_and_batch16_agree() {
    if !ready() {
        return;
    }
    let rt = Runtime::new(Manifest::default_dir()).unwrap();
    let b1 = rt.load("lenet5", Impl::Ref, 1).unwrap();
    let b16 = rt.load("lenet5", Impl::Ref, 16).unwrap();
    let frames = data::mnist_like(16, 32, 21);
    let batched = b16.infer(&rt.client, &frames.data).unwrap();
    for i in 0..16 {
        let single = b1.infer(&rt.client, frames.frame(i)).unwrap();
        for (a, b) in single.iter().zip(&batched[i * 10..(i + 1) * 10]) {
            assert!((a - b).abs() < 1e-4, "frame {i}: {a} vs {b}");
        }
    }
}

#[test]
fn deterministic_across_reloads() {
    if !ready() {
        return;
    }
    let rt = Runtime::new(Manifest::default_dir()).unwrap();
    let frames = data::mnist_like(1, 32, 22);
    let a = rt.load("lenet5", Impl::Ref, 1).unwrap().infer(&rt.client, frames.frame(0)).unwrap();
    let b = rt.load("lenet5", Impl::Ref, 1).unwrap().infer(&rt.client, frames.frame(0)).unwrap();
    assert_eq!(a, b);
}

#[test]
fn logits_are_finite_and_discriminative() {
    if !ready() {
        return;
    }
    let rt = Runtime::new(Manifest::default_dir()).unwrap();
    let model = rt.load("lenet5", Impl::Ref, 1).unwrap();
    let frames = data::mnist_like(8, 32, 23);
    let mut distinct = std::collections::BTreeSet::new();
    for i in 0..8 {
        let logits = model.infer(&rt.client, frames.frame(i)).unwrap();
        assert!(logits.iter().all(|v| v.is_finite()));
        let span = logits.iter().cloned().fold(f32::MIN, f32::max)
            - logits.iter().cloned().fold(f32::MAX, f32::min);
        assert!(span > 1e-4, "degenerate logits");
        distinct.insert(
            logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap(),
        );
    }
    // Synthetic strokes differ per class; at least two classes should win.
    assert!(distinct.len() >= 2, "model predicts a single class for all inputs");
}

#[test]
fn coordinator_throughput_improves_with_batching() {
    if !ready() {
        return;
    }
    let frames = data::mnist_like(64, 32, 24);
    let run = |max_batch: usize| {
        let server = InferenceServer::start(ServerConfig {
            workers: 1,
            max_batch,
            max_wait: std::time::Duration::from_millis(3),
            ..Default::default()
        })
        .unwrap();
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = (0..64)
            .map(|i| server.infer_async(frames.frame(i).to_vec()).unwrap())
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let dt = t0.elapsed();
        server.shutdown();
        dt
    };
    let unbatched = run(1);
    let batched = run(16);
    // Batching amortizes dispatch; allow generous slack for CI noise but
    // it must not be dramatically slower.
    assert!(
        batched < unbatched * 3,
        "batched {batched:?} vs unbatched {unbatched:?}"
    );
}

#[test]
fn mobilenet_single_frame_classifies() {
    if !ready() {
        return;
    }
    let rt = Runtime::new(Manifest::default_dir()).unwrap();
    let model = rt.load("mobilenet_v1", Impl::Ref, 1).unwrap();
    let imgs = data::for_network("mobilenet_v1", 1, 5).unwrap();
    let pred = model.classify(&rt.client, imgs.frame(0)).unwrap();
    assert_eq!(pred.len(), 1);
    assert!(pred[0] < 1000);
}
