//! Golden `report_json` regression gate.
//!
//! Compiles every evaluation network in both execution modes (and at
//! int8) on the default target and byte-compares the machine-readable
//! report against checked-in goldens under `rust/tests/goldens/`. Future
//! pass reorderings or cost-model changes then surface as reviewable
//! diffs instead of silent regressions.
//!
//! Blessing: when a golden file is missing (or `UPDATE_GOLDENS=1`), the
//! test writes the current output and passes — commit the generated
//! files. CI runs this test and then fails on any dirty/untracked golden
//! (`git diff` in the `golden-reports` job), so an unblessed or drifted
//! golden cannot land silently.

use std::path::PathBuf;

use tvm_fpga_flow::flow::{Compiler, Mode, OptLevel};
use tvm_fpga_flow::graph::models;
use tvm_fpga_flow::quant::QuantConfig;
use tvm_fpga_flow::texpr::Precision;

fn goldens_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/goldens")
}

/// Compile and render the report; compilation failures golden as text so
/// combinations that legitimately cannot route stay pinned too.
fn render(net: &str, mode: Mode, precision: Precision) -> String {
    let compiler = Compiler::default();
    let g = models::by_name(net).expect("known network");
    let result = match precision {
        Precision::F32 => compiler.compile(&g, mode, OptLevel::Optimized),
        p => compiler.graph(&g).mode(mode).with_quantization(QuantConfig::for_precision(p)).run(),
    };
    match result {
        Ok(acc) => acc.to_json().to_string(),
        Err(e) => format!("{{\"error\": \"{e}\"}}"),
    }
}

fn check_golden(net: &str, mode: Mode, precision: Precision) {
    let got = render(net, mode, precision);
    let dir = goldens_dir();
    let path = dir.join(format!("{net}_{}_{}.json", mode.name(), precision.name()));
    let bless = std::env::var("UPDATE_GOLDENS").is_ok() || !path.exists();
    if bless {
        std::fs::create_dir_all(&dir).expect("create goldens dir");
        std::fs::write(&path, &got).expect("write golden");
        eprintln!("blessed golden {} — commit it", path.display());
        return;
    }
    let want = std::fs::read_to_string(&path).expect("read golden");
    assert_eq!(
        got,
        want,
        "report_json drifted from {} — if intentional, re-bless with UPDATE_GOLDENS=1",
        path.display()
    );
}

#[test]
fn golden_reports_all_networks_both_modes() {
    for net in ["lenet5", "mobilenet_v1", "resnet34"] {
        for mode in [Mode::Pipelined, Mode::Folded] {
            for precision in [Precision::F32, Precision::Int8] {
                check_golden(net, mode, precision);
            }
        }
    }
}

#[test]
fn reports_are_deterministic() {
    // The golden gate only works if repeated compiles render identically.
    for (net, mode) in [("lenet5", Mode::Pipelined), ("mobilenet_v1", Mode::Folded)] {
        let a = render(net, mode, Precision::F32);
        let b = render(net, mode, Precision::F32);
        assert_eq!(a, b, "{net} non-deterministic");
        let qa = render(net, mode, Precision::Int8);
        let qb = render(net, mode, Precision::Int8);
        assert_eq!(qa, qb, "{net} int8 non-deterministic");
    }
}

/// Pipeline-partition golden: the 2-device ResNet-34 plan (cuts, stage
/// cost-model terms, per-stage reports) is pinned byte-for-byte, so a
/// cost-model or cut-search change must land as a reviewed golden diff.
#[test]
fn golden_partition_resnet34_two_devices() {
    use tvm_fpga_flow::flow::multi::{Link, PipelinePlan};
    let g = models::resnet34();
    let got = match PipelinePlan::build(&g, &["stratix10sx", "stratix10sx"], &Link::default()) {
        Ok(plan) => plan.to_json().to_string(),
        Err(e) => format!("{{\"error\": \"{e}\"}}"),
    };
    let dir = goldens_dir();
    let path = dir.join("resnet34_partition_2x_stratix10sx.json");
    let bless = std::env::var("UPDATE_GOLDENS").is_ok() || !path.exists();
    if bless {
        std::fs::create_dir_all(&dir).expect("create goldens dir");
        std::fs::write(&path, &got).expect("write golden");
        eprintln!("blessed golden {} — commit it", path.display());
        return;
    }
    let want = std::fs::read_to_string(&path).expect("read golden");
    assert_eq!(
        got,
        want,
        "partition plan drifted from {} — if intentional, re-bless with UPDATE_GOLDENS=1",
        path.display()
    );
}
