//! Cross-model consistency: the event-driven FIFO engine and the
//! analytical pipelined model must agree at steady state when channels are
//! deep (the analytical model assumes no back-pressure), and the folded
//! model must be invariant to work-list order permutations.

use tvm_fpga_flow::flow::{Compiler, Mode, OptLevel};
use tvm_fpga_flow::graph::models;
use tvm_fpga_flow::sim::{engine, folded};
use tvm_fpga_flow::util::rng::Rng;

#[test]
fn engine_steady_state_matches_analytical_bottleneck() {
    let flow = Compiler::default();
    let acc = flow.compile(&models::lenet5(), Mode::Pipelined, OptLevel::Optimized).unwrap();

    // Build engine stages from the analytical per-stage cycles.
    let stages: Vec<(String, f64, u64)> = acc
        .performance
        .per_layer
        .iter()
        .zip(&acc.program.kernels)
        .map(|(l, k)| (k.name.clone(), l.cycles, (k.nest.out_elems / 16).max(1)))
        .collect();
    let stages = engine::stages_from_cycles(&stages);

    let bottleneck = acc
        .performance
        .per_layer
        .iter()
        .map(|l| l.cycles)
        .fold(0.0f64, f64::max);

    // Deep channels: engine steady interval ≈ analytical bottleneck.
    let rep = engine::simulate(&stages, 1_000_000, 8);
    let ratio = rep.steady_interval_cycles / bottleneck;
    assert!(
        (0.8..1.3).contains(&ratio),
        "engine {} vs analytical {bottleneck} (ratio {ratio})",
        rep.steady_interval_cycles
    );

    // Shallow channels can only finish later overall (stalls shift the
    // completion times; the inter-completion *interval* can wobble, so
    // compare the makespan of the whole run).
    let shallow = engine::simulate(&stages, 1, 8);
    let makespan = |r: &engine::EngineReport| r.first_frame_cycles + r.steady_interval_cycles * 7.0;
    assert!(
        makespan(&shallow) >= makespan(&rep) * 0.99,
        "shallow {} vs deep {}",
        makespan(&shallow),
        makespan(&rep)
    );
}

#[test]
fn folded_total_invariant_under_work_permutation() {
    let flow = Compiler::default();
    let g = models::mobilenet_v1();
    let acc = flow.compile(&g, Mode::Folded, OptLevel::Optimized).unwrap();
    let fmax = acc.synthesis.fmax_mhz;

    let base = folded::simulate(&acc.program, &acc.work, &flow.device, fmax, &flow.host);

    // Shuffle the work list: total frame time must not change (layers are
    // sequential; order doesn't matter to the sum).
    let mut rng = Rng::new(99);
    let mut work = acc.work.clone();
    for i in (1..work.len()).rev() {
        let j = rng.below(i as u64 + 1) as usize;
        work.swap(i, j);
    }
    let shuffled = folded::simulate(&acc.program, &work, &flow.device, fmax, &flow.host);
    assert!(
        (base.frame_time_s - shuffled.frame_time_s).abs() / base.frame_time_s < 1e-9,
        "{} vs {}",
        base.frame_time_s,
        shuffled.frame_time_s
    );
}

#[test]
fn pipelined_latency_at_least_sum_of_stage_fills() {
    // The event engine's first-frame latency must exceed its steady
    // interval for any multi-stage pipeline (fill time is real).
    let flow = Compiler::default();
    let acc = flow.compile(&models::lenet5(), Mode::Pipelined, OptLevel::Optimized).unwrap();
    let stages: Vec<(String, f64, u64)> = acc
        .performance
        .per_layer
        .iter()
        .map(|l| (l.kernel.clone(), l.cycles, 32))
        .collect();
    let stages = engine::stages_from_cycles(&stages);
    let rep = engine::simulate(&stages, 64, 6);
    assert!(rep.first_frame_cycles > rep.steady_interval_cycles);
}
