//! Differential fuzzing of the pass pipeline: randomized (network ×
//! pass-subset × precision × mode) scenarios run through both the
//! kernel-program interpreter (`verify::interp`) and the graph-level
//! reference executor (`quant::exec`), asserting bit-exact int8 agreement
//! and toleranced f32/fp16 agreement (docs/VERIFICATION.md).
//!
//! Seeds honor `FLOW_TEST_SEED` (printed on failure for replay); the case
//! count honors `FLOW_DIFFER_CASES` (CI's nightly-style `verify-fuzz` job
//! raises it). Any failure is shrunk to a minimal (net, config, frame)
//! reproducer and written to `target/verify-repro.json`
//! (`VERIFY_REPRO_PATH` overrides), which CI uploads as an artifact.

use tvm_fpga_flow::flow::Mode;
use tvm_fpga_flow::graph::Op;
use tvm_fpga_flow::schedule::OptKind;
use tvm_fpga_flow::texpr::Precision;
use tvm_fpga_flow::util::rng::{test_seed, Rng};
use tvm_fpga_flow::verify::differ::{self, fuzz_opts, Fault, NetSpec, Scenario};

/// Shrink, persist and report a failing scenario, then panic with replay
/// instructions.
fn fail_with_repro(s: &Scenario, fault: Option<Fault>, summary: &str, seed: u64, case: u64) -> ! {
    let repro = differ::reproduce(s, fault);
    let where_ = match differ::write_reproducer(&repro) {
        Ok(p) => p.display().to_string(),
        Err(e) => format!("<unwritable: {e}>"),
    };
    // FLOW_DIFFER_CASES must ride along: CI runs more cases than the
    // local default, and a failure at case >= the default would otherwise
    // never be reached when replaying.
    let replay_cases = (case + 1).max(50);
    panic!(
        "differential case {case} failed (replay: FLOW_TEST_SEED={seed} \
         FLOW_DIFFER_CASES={replay_cases}):\n  scenario: {}\n  \
         {summary}\n  shrunk:   {}\n  reproducer: {where_}",
        s.describe(),
        repro.shrunk.describe()
    );
}

/// Scenario count: `FLOW_DIFFER_CASES` can raise it (the CI `verify-fuzz`
/// job does), never lower it below the 50-case CI floor.
fn differ_cases() -> u64 {
    std::env::var("FLOW_DIFFER_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(56).max(50)
}

/// ≥ 50 seeded random scenarios per CI run: random chains (structural
/// diversity) and LeNet-5, over random pass subsets, both modes, all
/// three precisions. One `Scratch` arena rides across every scenario —
/// the arena-backed fast path is what lets CI's `verify-fuzz` job run
/// 400 scenarios in the wall-clock budget 120 used to need; when
/// `FLOW_FUZZ_BUDGET_S` is set (CI does), the sweep asserts it stayed
/// inside that budget.
#[test]
fn seeded_random_scenarios_agree_with_oracle() {
    let seed = test_seed(0xD1FF_E12A);
    let mut rng = Rng::new(seed);
    let cases = differ_cases();
    let budget_s: Option<u64> =
        std::env::var("FLOW_FUZZ_BUDGET_S").ok().and_then(|s| s.parse().ok());
    let started = std::time::Instant::now();
    let mut scratch = tvm_fpga_flow::util::scratch::Scratch::new();
    for case in 0..cases {
        let s = differ::random_scenario(&mut rng);
        let rep = differ::run_scenario_in(&s, &mut scratch);
        if !rep.passed {
            fail_with_repro(&s, None, &rep.summary(), seed, case);
        }
        if s.precision == Precision::Int8 {
            assert!(rep.bit_exact, "case {case} int8 not bit-exact: {}", rep.summary());
        }
    }
    let elapsed = started.elapsed();
    eprintln!(
        "{cases} scenarios in {:.1}s ({} pooled scratch buffers)",
        elapsed.as_secs_f64(),
        scratch.pooled()
    );
    if let Some(budget) = budget_s {
        assert!(
            elapsed.as_secs() <= budget,
            "{cases} scenarios took {:.1}s — over the FLOW_FUZZ_BUDGET_S={budget}s budget \
             the 120-scenario sweep used to fit",
            elapsed.as_secs_f64()
        );
    }
}

/// The full canonical pipeline on LeNet-5: both modes × all precisions,
/// int8 bit-exact against `Executor::forward_quantized`.
#[test]
fn lenet_full_pipeline_verifies_everywhere() {
    for mode in [Mode::Pipelined, Mode::Folded] {
        for precision in Precision::all() {
            let s = Scenario {
                net: NetSpec::Named("lenet5".into()),
                mode,
                precision,
                opts: fuzz_opts(),
                frames: 4,
                frame: None,
                seed: 0xF1E1D,
            };
            let rep = differ::run_scenario(&s);
            assert!(rep.passed, "{}: {}", s.describe(), rep.summary());
            if precision == Precision::Int8 {
                assert!(rep.bit_exact, "{}", rep.summary());
            }
        }
    }
}

/// Forced-mismatch self-test: inject a known-wrong program (a kernel that
/// "forgets" its bias/activation epilogue), prove the harness catches it,
/// and prove the shrinker emits a *minimal* reproducer — one frame, no
/// removable passes, widest precision that still fails.
#[test]
fn forced_mismatch_is_caught_and_shrunk_to_minimal() {
    let s = Scenario {
        net: NetSpec::Named("lenet5".into()),
        mode: Mode::Pipelined,
        precision: Precision::Int8,
        opts: fuzz_opts(),
        frames: 3,
        frame: None,
        seed: 0xBAD,
    };
    let fault = Some(Fault::DropEpilogue);
    let rep = differ::run_scenario_with_fault(&s, fault);
    assert!(!rep.passed, "injected fault must fail verification");
    assert!(
        rep.violations.iter().any(|v| v.contains("epilogue")),
        "dropped epilogue should also trip the structural check: {:?}",
        rep.violations
    );

    let shrunk = differ::shrink(&s, fault);
    // Minimality: a single pinned frame, every pass removed, precision
    // widened to plain f32 — nothing left to take away.
    assert!(shrunk.frame.is_some(), "shrinker must pin one frame: {shrunk:?}");
    assert!(shrunk.opts.is_empty(), "shrinker must drop every pass: {shrunk:?}");
    assert_eq!(shrunk.precision, Precision::F32, "shrinker must widen precision");
    assert!(!differ::run_scenario_with_fault(&shrunk, fault).passed, "shrunk case still fails");
    // Re-shrinking is a fixed point.
    assert_eq!(differ::shrink(&shrunk, fault), shrunk);

    // The reproducer serializes with everything needed to replay.
    let repro = differ::reproduce(&s, fault);
    let json = repro.to_json().to_string();
    for key in ["\"original\"", "\"shrunk\"", "\"replay\"", "drop-epilogue", "\"seed\""] {
        assert!(json.contains(key), "reproducer json missing {key}: {json}");
    }
    let parsed = tvm_fpga_flow::util::json::parse(&json).expect("reproducer json parses");
    let back = Scenario::from_json(parsed.get("shrunk").expect("shrunk present"))
        .expect("shrunk scenario parses");
    assert_eq!(back, repro.shrunk);
}

/// Mismatch localization: re-widening one narrowed kernel to f32 while
/// the oracle stays int8 must point the report at exactly that layer.
#[test]
fn widened_kernel_localizes_to_its_layer() {
    let s = Scenario {
        net: NetSpec::Named("lenet5".into()),
        mode: Mode::Pipelined,
        precision: Precision::Int8,
        opts: fuzz_opts(),
        frames: 2,
        frame: None,
        seed: 0x10CA1,
    };
    let rep = differ::run_scenario_with_fault(&s, Some(Fault::WidenPrecision));
    assert!(!rep.passed, "widened kernel must break int8 bit-exactness");
    let m = rep.first_mismatch.expect("divergence must localize to a node");
    // The first narrowed kernel is the first conv (c1).
    assert_eq!(m.name, "c1", "localization pointed at {} instead", m.name);
}

/// Pinned regression: parameterized (PK) groups whose member layers carry
/// *different* absorbed epilogue chains (one conv with bn+relu, another
/// bare) must still verify — epilogues resolve per dispatched layer, not
/// from the representative's static nest.
#[test]
fn parameterized_groups_with_mixed_epilogue_chains_verify() {
    // Find a deterministic chain whose convs disagree on their bn/act
    // suffixes (they all share the conv3x3s1 group, so PK merges them).
    let mut found = None;
    for seed in 0..500u64 {
        let g = differ::random_chain(seed);
        let mut sigs = std::collections::BTreeSet::new();
        let mut convs = 0;
        for n in &g.nodes {
            if matches!(n.op, Op::Conv2d { .. }) {
                convs += 1;
                let has_bn = g.nodes.iter().any(|m| m.name == format!("{}.bn", n.name));
                let has_act = g.nodes.iter().any(|m| m.name == format!("{}.act", n.name));
                sigs.insert((has_bn, has_act));
            }
        }
        if convs >= 2 && sigs.len() >= 2 {
            found = Some(seed);
            break;
        }
    }
    let seed = found.expect("some chain in 0..500 mixes conv epilogue chains");
    for precision in [Precision::F32, Precision::Int8] {
        let s = Scenario {
            net: NetSpec::Chain { seed },
            mode: Mode::Folded,
            precision,
            opts: vec![
                OptKind::Fuse,
                OptKind::Parameterize,
                OptKind::Tile,
                OptKind::Unroll,
                OptKind::CachedWrite,
            ],
            frames: 2,
            frame: None,
            seed: 3,
        };
        // PK really merged multiple layers into one kernel.
        let g = s.graph();
        let built = tvm_fpga_flow::flow::patterns::build_with_passes(
            &g,
            Mode::Folded,
            &s.cfg(),
            &tvm_fpga_flow::flow::patterns::default_factors(&g),
        );
        assert!(
            built.program.kernels.iter().any(|k| k.layers.len() > 1),
            "chain:{seed:#x} did not exercise a merged kernel"
        );
        let rep = differ::run_scenario(&s);
        assert!(rep.passed, "{}: {}", s.describe(), rep.summary());
    }
}

/// Replay an uploaded reproducer (`VERIFY_REPRO_PATH`): parses the shrunk
/// scenario and re-runs it, printing the outcome. No-op without the env.
#[test]
fn replay_reproducer() {
    let Ok(path) = std::env::var("VERIFY_REPRO_PATH") else { return };
    if !std::path::Path::new(&path).exists() {
        return;
    }
    let text = std::fs::read_to_string(&path).expect("read reproducer");
    let json = tvm_fpga_flow::util::json::parse(&text).expect("reproducer parses");
    let s = Scenario::from_json(json.get("shrunk").expect("shrunk scenario"))
        .expect("scenario parses");
    let rep = differ::run_scenario(&s);
    println!("replayed {} → {}", s.describe(), rep.summary());
}

/// Nightly-scale coverage of the big evaluation networks (folded, paper
/// mode). Gated behind `FLOW_VERIFY_HEAVY=1` — each frame of ResNet-34 is
/// ~3.6 GMACs on *both* sides of the diff.
#[test]
fn heavy_networks_verify() {
    if std::env::var("FLOW_VERIFY_HEAVY").is_err() {
        eprintln!("skipped (set FLOW_VERIFY_HEAVY=1 to run the big-network sweep)");
        return;
    }
    for net in ["mobilenet_v1", "resnet34"] {
        for precision in [Precision::F32, Precision::Int8] {
            let s = Scenario {
                net: NetSpec::Named(net.into()),
                mode: Mode::Folded,
                precision,
                opts: fuzz_opts(),
                frames: 1,
                frame: None,
                seed: 0xB16,
            };
            let rep = differ::run_scenario(&s);
            assert!(rep.passed, "{}: {}", s.describe(), rep.summary());
        }
    }
}

/// Partitioned-vs-whole differential sweep (the `PartitionPass`
/// equivalence obligation): LeNet-5 and random chains, split at every
/// legal K∈{2,3} arrangement we can form from the candidate cuts, must
/// reproduce the unpartitioned oracle at all three precisions — int8
/// bit-exactly, since requantizing at a stage boundary replays the exact
/// integer pipeline of the whole network (docs/PASSES.md).
#[test]
fn partitioned_chains_match_whole_network() {
    use tvm_fpga_flow::graph::{models, Graph};
    use tvm_fpga_flow::pass::{candidate_cuts, split_stages};
    use tvm_fpga_flow::verify::{frames_for, verify_partition, VerifyOptions};

    let mut graphs: Vec<Graph> = vec![models::lenet5()];
    graphs.extend((0u64..10).map(differ::random_chain));
    let opts = VerifyOptions::default();
    let mut covered = 0usize;
    for g in &graphs {
        let legal: Vec<usize> = candidate_cuts(g)
            .into_iter()
            .filter(|&c| split_stages(g, &[c]).is_some())
            .collect();
        let mut cut_sets: Vec<Vec<usize>> = legal.iter().map(|&c| vec![c]).collect();
        if legal.len() >= 2 {
            // K=3: first and last legal frontier.
            cut_sets.push(vec![legal[0], *legal.last().unwrap()]);
        }
        let frames = frames_for(g, 2, 0xC0FFEE);
        for cuts in cut_sets {
            for precision in [Precision::F32, Precision::F16, Precision::Int8] {
                let r = verify_partition(g, &cuts, precision, &frames, &opts);
                assert!(
                    r.passed,
                    "{} cut at {cuts:?} @ {}: {:?} (max rel err {:.3e})",
                    g.name,
                    precision.name(),
                    r.failure,
                    r.max_rel_err
                );
                if precision == Precision::Int8 {
                    assert!(r.bit_exact, "{} cut at {cuts:?}: int8 must be bit-exact", g.name);
                }
                covered += 1;
            }
        }
    }
    // The generator is seeded, so the sweep size is deterministic; the
    // floor catches a regression that silently empties the cut sets.
    assert!(covered >= 15, "partition sweep degenerated: only {covered} verifications ran");
}

/// K=1 regression: a single-target "pipeline" must not perturb the plan —
/// no cuts, no search, and an accelerator byte-identical to the plain
/// staged compile of the whole network.
#[test]
fn degenerate_single_device_plan_is_byte_identical() {
    use tvm_fpga_flow::flow::multi::{Link, PipelinePlan};
    use tvm_fpga_flow::flow::{Compiler, ModeChoice};
    use tvm_fpga_flow::graph::models;

    let g = models::lenet5();
    let plan = PipelinePlan::build(&g, &["stratix10sx"], &Link::default()).expect("K=1 plan");
    assert!(plan.cuts.is_empty(), "degenerate plan must not cut: {:?}", plan.cuts);
    assert_eq!(plan.stages.len(), 1);
    assert_eq!(plan.bottleneck, 0);
    assert_eq!(plan.evaluated, 1, "K=1 must skip the cut search");

    let direct = Compiler::for_target("stratix10sx")
        .expect("target registered")
        .graph(&g)
        .mode(ModeChoice::Auto)
        .run()
        .expect("whole-network compile");
    assert_eq!(
        plan.stages[0].accelerator.to_json().to_string(),
        direct.to_json().to_string(),
        "single-stage accelerator diverged from the unpartitioned compile"
    );
    assert_eq!(plan.fps, direct.performance.fps);
}
