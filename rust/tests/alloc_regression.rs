//! Allocation regression tests for the host-executor fast path: after
//! warm-up, the steady-state frame loop of [`FastExecutor`] (all three
//! precisions) and of the verify interpreter's `run_frame_into` must
//! perform **zero** heap allocations per frame — the tentpole property
//! the `Scratch` arena exists to provide.
//!
//! A counting `#[global_allocator]` wraps the system allocator. The
//! counter is thread-local and armed only around the measured region, so
//! the test harness's other threads (and its own bookkeeping) never
//! pollute a measurement. `try_with` guards against TLS teardown — the
//! allocator runs during thread shutdown too, when the thread-local may
//! already be gone.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use tvm_fpga_flow::flow::patterns::{build_with_passes, default_factors, OptConfig};
use tvm_fpga_flow::flow::Mode;
use tvm_fpga_flow::graph::models;
use tvm_fpga_flow::quant::{calibrate_analytic, Calibrator, Executor, FastExecutor, QScheme};
use tvm_fpga_flow::texpr::Precision;
use tvm_fpga_flow::util::scratch::Scratch;
use tvm_fpga_flow::verify::Interpreter;

struct CountingAlloc;

thread_local! {
    static ARMED: Cell<bool> = const { Cell::new(false) };
    static COUNT: Cell<u64> = const { Cell::new(0) };
}

impl CountingAlloc {
    fn record() {
        // During TLS teardown `with` would panic inside the allocator;
        // `try_with` just skips counting there.
        let armed = ARMED.try_with(Cell::get).unwrap_or(false);
        if armed {
            let _ = COUNT.try_with(|c| c.set(c.get() + 1));
        }
    }
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        CountingAlloc::record();
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        CountingAlloc::record();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        CountingAlloc::record();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Heap allocations (alloc + alloc_zeroed + realloc) performed by `f` on
/// this thread.
fn allocations_in(f: impl FnOnce()) -> u64 {
    COUNT.with(|c| c.set(0));
    ARMED.with(|c| c.set(true));
    f();
    ARMED.with(|c| c.set(false));
    COUNT.with(Cell::get)
}

/// The harness itself must actually count — otherwise the zero-allocation
/// asserts below would pass vacuously.
#[test]
fn counting_allocator_counts() {
    let n = allocations_in(|| {
        let v: Vec<u64> = Vec::with_capacity(32);
        std::hint::black_box(&v);
    });
    assert!(n >= 1, "a fresh Vec allocation must be counted, got {n}");
}

/// f32 reference fast path: zero steady-state allocations per frame.
#[test]
fn f32_executor_frames_do_not_allocate() {
    let g = models::lenet5();
    let exec = Executor::new(&g);
    let data = tvm_fpga_flow::data::mnist_like(4, 32, 5);
    let mut scratch = Scratch::new();
    let mut fast = FastExecutor::reference(&exec, true, &mut scratch);
    // Warm-up: first frames touch lazily-initialized runtime state
    // (stdio locks etc.) that is not the executor's to avoid.
    for i in 0..2 {
        std::hint::black_box(fast.forward(data.frame(i)));
    }
    let n = allocations_in(|| {
        for i in 0..8 {
            let logits = fast.forward(data.frame(i % 4));
            std::hint::black_box(tvm_fpga_flow::quant::argmax(logits));
        }
    });
    fast.release(&mut scratch);
    assert_eq!(n, 0, "f32 fast path allocated {n} times across 8 frames");
}

/// int8 (and fp16) quantized fast paths: zero steady-state allocations
/// per frame — operand quantization reuses the arena's shared scratch.
#[test]
fn quantized_executor_frames_do_not_allocate() {
    let g = models::lenet5();
    let exec = Executor::new(&g);
    let table = calibrate_analytic(&g, Calibrator::Percentile(99.9));
    let data = tvm_fpga_flow::data::mnist_like(4, 32, 5);
    let mut scratch = Scratch::new();
    for precision in [Precision::Int8, Precision::F16] {
        let mut fast = FastExecutor::quantized(
            &exec,
            &table,
            precision,
            QScheme::PerChannel,
            true,
            &mut scratch,
        );
        for i in 0..2 {
            std::hint::black_box(fast.forward(data.frame(i)));
        }
        let n = allocations_in(|| {
            for i in 0..8 {
                let logits = fast.forward(data.frame(i % 4));
                std::hint::black_box(tvm_fpga_flow::quant::argmax(logits));
            }
        });
        fast.release(&mut scratch);
        assert_eq!(
            n,
            0,
            "{} fast path allocated {n} times across 8 frames",
            precision.name()
        );
    }
}

/// The verify interpreter's arena-backed frame loop: zero steady-state
/// allocations per `run_frame_into` on a compiled LeNet-5 program.
#[test]
fn interpreter_frames_do_not_allocate() {
    let g = models::lenet5();
    let plan = default_factors(&g);
    let built = build_with_passes(&g, Mode::Pipelined, &OptConfig::optimized(), &plan);
    let exec = Executor::new(&g);
    let table = calibrate_analytic(&g, Calibrator::Percentile(99.9));
    let itp = Interpreter::new(
        &g,
        &built.program,
        &exec,
        &table,
        QScheme::PerChannel,
        Precision::F32,
    );
    assert_eq!(itp.structure(), &[] as &[String]);
    let data = tvm_fpga_flow::data::mnist_like(4, 32, 5);
    let mut scratch = Scratch::new();
    let mut st = itp.frame_state(&mut scratch);
    for i in 0..2 {
        itp.run_frame_into(data.frame(i), &mut st).unwrap();
    }
    let n = allocations_in(|| {
        for i in 0..8 {
            itp.run_frame_into(data.frame(i % 4), &mut st).unwrap();
            std::hint::black_box(itp.logits(&st));
        }
    });
    itp.release_state(st, &mut scratch);
    assert_eq!(n, 0, "interpreter fast path allocated {n} times across 8 frames");
}

/// A disabled tracer must be pure overhead-free: constructing and
/// dropping span guards in steady state performs zero heap allocations.
/// This is the property that lets instrumentation live on hot paths
/// (per-frame, per-layer) without a feature gate.
#[test]
fn disabled_span_guards_do_not_allocate() {
    tvm_fpga_flow::obs::disable();
    // Warm-up: the first guard may touch lazily-initialized TLS.
    for _ in 0..4 {
        let _s = tvm_fpga_flow::obs::span("alloc", "probe");
    }
    let n = allocations_in(|| {
        for _ in 0..10_000 {
            let _s = tvm_fpga_flow::obs::span("alloc", "probe");
        }
        std::hint::black_box(tvm_fpga_flow::obs::enabled());
    });
    assert_eq!(n, 0, "disabled span guards allocated {n} times across 10k guards");
}

/// The traced-entry frame loop with tracing disabled is as allocation-free
/// as the plain one: `forward_traced` must fall through to `forward`
/// without touching the heap.
#[test]
fn disabled_traced_frames_do_not_allocate() {
    tvm_fpga_flow::obs::disable();
    let g = models::lenet5();
    let exec = Executor::new(&g);
    let data = tvm_fpga_flow::data::mnist_like(4, 32, 5);
    let mut scratch = Scratch::new();
    let mut fast = FastExecutor::reference(&exec, true, &mut scratch);
    for i in 0..2 {
        std::hint::black_box(fast.forward_traced(data.frame(i)));
    }
    let n = allocations_in(|| {
        for i in 0..8 {
            let logits = fast.forward_traced(data.frame(i % 4));
            std::hint::black_box(tvm_fpga_flow::quant::argmax(logits));
        }
    });
    fast.release(&mut scratch);
    assert_eq!(n, 0, "disabled traced fast path allocated {n} times across 8 frames");
}

/// Releasing one executor and building the next with the same shapes is
/// served from the pool — the cross-component reuse the arena promises
/// (calibrate → measure, scenario → scenario).
#[test]
fn released_buffers_are_reused_across_executors() {
    let g = models::lenet5();
    let exec = Executor::new(&g);
    let mut scratch = Scratch::new();
    let fast = FastExecutor::reference(&exec, true, &mut scratch);
    fast.release(&mut scratch);
    let before = scratch.stats();
    let fast2 = FastExecutor::reference(&exec, true, &mut scratch);
    let after = scratch.stats();
    fast2.release(&mut scratch);
    let checkouts = after.checkouts - before.checkouts;
    let hits = after.hits - before.hits;
    assert_eq!(checkouts, hits, "second executor must be served entirely from the pool");
    assert!(hits > 0, "second executor checked nothing out");
}
