//! Integration tests for the dynamic-batching replica scheduler, running
//! on simulated accelerator engines — no artifacts or PJRT build needed.
//!
//! The simulated engine charges a fixed per-dispatch overhead plus a
//! per-frame cost (the §IV-F amortization model), so batching effects are
//! measurable in wall-clock time with generous margins.

use std::time::{Duration, Instant};

use tvm_fpga_flow::coordinator::{
    EngineSpec, InferenceServer, ServerConfig, ServerError, SimEngine,
};

const FRAME_ELEMS: usize = 16;
const CLASSES: usize = 10;

/// One simulated accelerator: heavy dispatch overhead, cheap frames —
/// the regime in which the paper's batching/autorun optimizations matter.
fn slow_dispatch_engine(overhead: Duration) -> SimEngine {
    SimEngine::new("sim-accel", FRAME_ELEMS, CLASSES, 8, overhead, Duration::from_micros(50))
}

fn cfg(replicas: Vec<EngineSpec>, max_batch: usize, max_wait: Duration) -> ServerConfig {
    ServerConfig { replicas, max_batch, max_wait, ..Default::default() }
}

fn frames(n: usize) -> Vec<Vec<f32>> {
    let data = tvm_fpga_flow::data::mnist_like(n, 4, 42);
    (0..n).map(|i| data.frame(i).to_vec()).collect()
}

/// Drive `n` async requests through a fresh server, returning (elapsed,
/// final stats).
fn run_burst(
    server: InferenceServer,
    n: usize,
) -> (Duration, tvm_fpga_flow::coordinator::StatsSnapshot) {
    let t0 = Instant::now();
    let rxs: Vec<_> = frames(n)
        .into_iter()
        .map(|f| server.infer_async(f).expect("queue sized for the burst"))
        .collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let dt = t0.elapsed();
    (dt, server.shutdown())
}

#[test]
fn dynamic_batching_multiplies_throughput() {
    let engine = slow_dispatch_engine(Duration::from_millis(2));
    let n = 64;

    let unbatched = InferenceServer::start(cfg(
        vec![EngineSpec::Sim(engine.clone())],
        1,
        Duration::from_micros(100),
    ))
    .unwrap();
    let (dt1, s1) = run_burst(unbatched, n);

    let batched = InferenceServer::start(cfg(
        vec![EngineSpec::Sim(engine)],
        8,
        Duration::from_millis(2),
    ))
    .unwrap();
    let (dt8, s8) = run_burst(batched, n);

    assert_eq!(s1.completed, n as u64);
    assert_eq!(s8.completed, n as u64);
    assert_eq!(s1.batched_frames, 0);
    assert!(s8.batched_frames > 0, "{s8:?}");
    // The same simulated accelerator must serve ≥3× the frames/sec once
    // the batcher amortizes its 2 ms dispatch overhead (the bench
    // demonstrates ≥4× with a larger burst; the test keeps CI margin).
    let speedup = dt1.as_secs_f64() / dt8.as_secs_f64();
    assert!(speedup >= 3.0, "batching speedup only {speedup:.2}x ({dt1:?} vs {dt8:?})");
}

#[test]
fn deadline_flushes_partial_batch_through_the_server() {
    let server = InferenceServer::start(cfg(
        vec![EngineSpec::Sim(slow_dispatch_engine(Duration::ZERO))],
        8,
        Duration::from_millis(100),
    ))
    .unwrap();
    // 3 frames < max_batch: only the deadline can flush them.
    let rxs: Vec<_> =
        frames(3).into_iter().map(|f| server.infer_async(f).unwrap()).collect();
    for rx in rxs {
        assert!(rx.recv().unwrap().unwrap() < CLASSES as u32);
    }
    let stats = server.shutdown();
    assert_eq!(stats.completed, 3);
    // Nothing ever reached max_batch, so every flush was deadline-driven
    // partial batches. (Usually one batch of 3; a descheduled submitter
    // may split it, so the asserts avoid exact batch counts.)
    assert!(stats.batches >= 1 && stats.batches <= 3, "{stats:?}");
    assert_eq!(stats.batch_hist[7], 0, "a full batch should be impossible: {stats:?}");
    assert_eq!(stats.batch_hist.iter().sum::<u64>(), stats.batches, "{stats:?}");
    assert!(stats.mean_batch_size() >= 1.0 && stats.mean_batch_size() <= 3.0);
}

#[test]
fn shutdown_drains_nonempty_queue() {
    // Slow engine: 20 ms per dispatch, so the burst is still queued when
    // shutdown starts.
    let server = InferenceServer::start(cfg(
        vec![EngineSpec::Sim(slow_dispatch_engine(Duration::from_millis(20)))],
        8,
        Duration::from_millis(1),
    ))
    .unwrap();
    let rxs: Vec<_> =
        frames(32).into_iter().map(|f| server.infer_async(f).unwrap()).collect();
    // Shut down immediately: every accepted request must still be answered.
    let stats = server.shutdown();
    assert_eq!(stats.submitted, 32);
    assert_eq!(stats.completed, stats.submitted, "shutdown dropped work: {stats:?}");
    for rx in rxs {
        assert!(rx.recv().unwrap().is_ok());
    }
}

#[test]
fn overloaded_when_bounded_queue_is_full() {
    // Tiny queue + slow replica: the burst must overflow.
    let server = InferenceServer::start(ServerConfig {
        replicas: vec![EngineSpec::Sim(slow_dispatch_engine(Duration::from_millis(50)))],
        max_batch: 4,
        max_wait: Duration::from_micros(100),
        queue_capacity: 2,
        ..Default::default()
    })
    .unwrap();
    let mut accepted = Vec::new();
    let mut rejected = 0u64;
    for f in frames(24) {
        match server.infer_async(f) {
            Ok(rx) => accepted.push(rx),
            Err(e) => {
                let se = e.downcast_ref::<ServerError>().expect("typed error");
                assert!(matches!(se, ServerError::Overloaded { .. }), "{se:?}");
                rejected += 1;
            }
        }
    }
    assert!(rejected > 0, "bounded queue never pushed back");
    // Accepted work is still all served.
    for rx in &accepted {
        assert!(rx.recv().unwrap().is_ok());
    }
    let stats = server.shutdown();
    assert_eq!(stats.rejected, rejected);
    assert_eq!(stats.submitted, accepted.len() as u64);
    assert_eq!(stats.completed, stats.submitted);
    assert_eq!(stats.submitted + stats.rejected, 24);
}

#[test]
fn stats_report_occupancy_and_histogram_across_replicas() {
    // Two replicas with 3:1 modeled throughput (weights follow modeled
    // FPS, which follows the timing constants).
    let fast = SimEngine::new(
        "fast",
        FRAME_ELEMS,
        CLASSES,
        8,
        Duration::from_millis(1),
        Duration::from_micros(50),
    );
    let slow = SimEngine::new(
        "slow",
        FRAME_ELEMS,
        CLASSES,
        8,
        Duration::from_millis(3),
        Duration::from_micros(150),
    );
    let server = InferenceServer::start(cfg(
        vec![EngineSpec::Sim(fast), EngineSpec::Sim(slow)],
        8,
        Duration::from_millis(2),
    ))
    .unwrap();
    let (_, stats) = run_burst(server, 96);

    assert_eq!(stats.completed, 96);
    assert_eq!(stats.replicas.len(), 2);
    assert_eq!(stats.replicas[0].name, "r0:fast");
    assert_eq!(stats.replicas[1].name, "r1:slow");
    // Both replicas worked, and their busy time was measured.
    for r in &stats.replicas {
        assert!(r.frames > 0, "{stats:?}");
        assert!(r.busy_us > 0, "{stats:?}");
        assert!(r.occupancy > 0.0 && r.occupancy <= 1.5, "{stats:?}");
    }
    assert_eq!(stats.replicas.iter().map(|r| r.frames).sum::<u64>(), 96);
    // Weighted routing: the fast replica must carry more frames.
    assert!(
        stats.replicas[0].frames > stats.replicas[1].frames,
        "weighted routing ignored modeled throughput: {stats:?}"
    );
    // The histogram saw multi-frame batches and accounts for every batch.
    assert!(stats.batch_hist.iter().skip(1).any(|&n| n > 0), "{stats:?}");
    assert_eq!(stats.batch_hist.iter().sum::<u64>(), stats.batches, "{stats:?}");
    // Queue latency was recorded at dispatch.
    assert!(stats.queue_p50_us.is_some());
}
