//! Bit-exactness of the arena-backed host-executor fast path.
//!
//! [`FastExecutor`] (and the `*_into` cores it shares with the verify
//! interpreter) re-implements the reference [`Executor`]'s datapaths
//! without per-frame allocation and with optional conv→bn→relu epilogue
//! fusion. Its contract is **bit-identical output at every precision** —
//! the goldens, the differential harness and the ≥5× bench all lean on
//! it. These tests pin that contract on LeNet-5 and on seeded-random
//! layer chains (`util::prop` seeds; replay with `FLOW_TEST_SEED`).

use tvm_fpga_flow::graph::models;
use tvm_fpga_flow::quant::{calibrate_analytic, Calibrator, Executor, FastExecutor, QScheme};
use tvm_fpga_flow::texpr::Precision;
use tvm_fpga_flow::util::prop;
use tvm_fpga_flow::util::scratch::Scratch;
use tvm_fpga_flow::verify::differ::random_chain;
use tvm_fpga_flow::verify::frames_for;

/// Assert the fast path reproduces the baseline bitwise on `frames`, for
/// one (precision, scheme, fuse) combination.
#[allow(clippy::too_many_arguments)]
fn assert_bit_identical(
    exec: &Executor,
    table: &tvm_fpga_flow::quant::CalibrationTable,
    precision: Precision,
    scheme: QScheme,
    fuse: bool,
    frames: &[Vec<f32>],
    scratch: &mut Scratch,
    ctx: &str,
) {
    let mut fast = match precision {
        Precision::F32 => FastExecutor::reference(exec, fuse, scratch),
        _ => FastExecutor::quantized(exec, table, precision, scheme, fuse, scratch),
    };
    for (fi, frame) in frames.iter().enumerate() {
        let want = if precision == Precision::F32 {
            exec.forward(frame, |_, _| {})
        } else {
            exec.forward_quantized(frame, table, precision, scheme)
        };
        let got = fast.forward(frame);
        assert_eq!(want.len(), got.len(), "{ctx} frame {fi}: logit count");
        for (i, (a, b)) in want.iter().zip(got).enumerate() {
            assert!(
                a.to_bits() == b.to_bits(),
                "{ctx} [{} {} fuse={fuse}] frame {fi} logit {i}: \
                 baseline {a:?} ({:#010x}) vs fast {b:?} ({:#010x})",
                precision.name(),
                scheme.name(),
                a.to_bits(),
                b.to_bits()
            );
        }
    }
    fast.release(scratch);
}

/// LeNet-5, exhaustively: 3 precisions × both schemes × fused/unfused,
/// all bit-identical to the allocating baseline.
#[test]
fn lenet_fast_path_is_bit_identical_everywhere() {
    let g = models::lenet5();
    let exec = Executor::new(&g);
    let table = calibrate_analytic(&g, Calibrator::Percentile(99.9));
    let frames = frames_for(&g, 2, 0xFA57);
    let mut scratch = Scratch::new();
    for precision in [Precision::F32, Precision::F16, Precision::Int8] {
        for scheme in [QScheme::PerTensor, QScheme::PerChannel] {
            for fuse in [false, true] {
                assert_bit_identical(
                    &exec,
                    &table,
                    precision,
                    scheme,
                    fuse,
                    &frames,
                    &mut scratch,
                    "lenet5",
                );
            }
        }
    }
}

/// Seeded-random layer chains (the differ's generator: convs, depthwise,
/// BN, relu, pools, dense): each case draws one random
/// (precision, scheme, fuse) combination. Failures replay with the
/// printed `FLOW_TEST_SEED`.
#[test]
fn random_chain_fast_path_is_bit_identical() {
    prop::check("fastpath-equivalence", |rng, case| {
        let chain_seed = rng.next_u64();
        let g = random_chain(chain_seed);
        let exec = Executor::new(&g);
        let table = calibrate_analytic(&g, Calibrator::Percentile(99.9));
        let frames = frames_for(&g, 1, rng.next_u64());
        let precision = match rng.below(3) {
            0 => Precision::F32,
            1 => Precision::F16,
            _ => Precision::Int8,
        };
        let scheme =
            if rng.below(2) == 0 { QScheme::PerTensor } else { QScheme::PerChannel };
        let fuse = rng.below(2) == 0;
        let mut scratch = Scratch::new();
        assert_bit_identical(
            &exec,
            &table,
            precision,
            scheme,
            fuse,
            &frames,
            &mut scratch,
            &format!("case {case} chain:{chain_seed:#x}"),
        );
    });
}

/// The observed (calibration) path: fusion is disabled under an observer,
/// and every per-node activation must match the baseline observer's
/// bitwise — this is what makes `calibrate_in` produce byte-identical
/// calibration tables.
#[test]
fn observed_activations_match_baseline_observer() {
    let g = models::lenet5();
    let exec = Executor::new(&g);
    let frames = frames_for(&g, 2, 0x0B5E);
    let mut scratch = Scratch::new();
    let mut fast = FastExecutor::reference(&exec, true, &mut scratch);
    for frame in &frames {
        let mut want: Vec<Vec<f32>> = vec![Vec::new(); g.nodes.len()];
        exec.forward(frame, |id, a| want[id] = a.to_vec());
        let mut got: Vec<Vec<f32>> = vec![Vec::new(); g.nodes.len()];
        fast.forward_observed(frame, |id, a| got[id] = a.to_vec());
        for (id, (w, g_)) in want.iter().zip(&got).enumerate() {
            assert_eq!(w.len(), g_.len(), "node {id} activation length");
            for (i, (a, b)) in w.iter().zip(g_).enumerate() {
                assert!(
                    a.to_bits() == b.to_bits(),
                    "node {id} element {i}: baseline {a:?} vs fast-observed {b:?}"
                );
            }
        }
    }
    fast.release(&mut scratch);
}

/// Fused and unfused fast paths agree bitwise with each other (fusion
/// applies the same per-element chain order, just without materializing
/// intermediates).
#[test]
fn fusion_is_value_transparent() {
    // LeNet has conv→relu chains; a chain seed with conv→bn→relu
    // exercises the two-step fused epilogue.
    for g in [models::lenet5(), random_chain(3), random_chain(11)] {
        let exec = Executor::new(&g);
        let table = calibrate_analytic(&g, Calibrator::Percentile(99.9));
        let frames = frames_for(&g, 1, 0xF0);
        let mut scratch = Scratch::new();
        for precision in [Precision::F32, Precision::Int8] {
            let build = |fuse: bool, scratch: &mut Scratch| match precision {
                Precision::F32 => FastExecutor::reference(&exec, fuse, scratch),
                _ => FastExecutor::quantized(
                    &exec,
                    &table,
                    precision,
                    QScheme::PerChannel,
                    fuse,
                    scratch,
                ),
            };
            let mut fused = build(true, &mut scratch);
            let mut unfused = build(false, &mut scratch);
            for frame in &frames {
                let a = fused.forward(frame).to_vec();
                let b = unfused.forward(frame);
                assert_eq!(a.as_slice(), b, "{} {}", g.name, precision.name());
            }
            fused.release(&mut scratch);
            unfused.release(&mut scratch);
        }
    }
}
