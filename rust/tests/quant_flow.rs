//! Integration tests for the quantization-aware compilation flow: the
//! precision DSE must produce a Pareto front where reduced precision
//! actually pays on modeled resources, with a bounded simulated top-1
//! accuracy delta, and the staged session must thread precision end to
//! end (kernels, synthesis, serving).

use tvm_fpga_flow::coordinator::SimEngine;
use tvm_fpga_flow::dse::explore_precisions;
use tvm_fpga_flow::flow::multi::ReplicaPlan;
use tvm_fpga_flow::flow::{Compiler, Mode, ModeChoice};
use tvm_fpga_flow::graph::models;
use tvm_fpga_flow::quant::{self, QParams, QuantConfig, Range};
use tvm_fpga_flow::texpr::Precision;
use tvm_fpga_flow::util::prop;

/// Acceptance: `dse --precision int8` yields a front where at least one
/// int8 design strictly dominates the f32 baseline on every modeled
/// resource at equal-or-better FPS, and every int8 point carries a
/// bounded simulated top-1 accuracy delta.
#[test]
fn int8_dse_front_dominates_f32_baseline_on_resources() {
    let compiler = Compiler::default();
    let g = models::lenet5();
    let front = explore_precisions(
        &compiler,
        &g,
        Mode::Pipelined,
        4,
        &[Precision::F32, Precision::Int8],
    )
    .unwrap();

    let base = front.baseline_f32.as_ref().expect("f32 baseline routed");
    assert!(base.fps > 0.0);

    // Strict resource dominance at equal-or-better FPS.
    assert!(
        front.beats_baseline_on_resources(Precision::Int8),
        "no int8 design dominates the f32 baseline: baseline fps {:.1} dsp {:.3} logic {:.3} bram {:.3}; int8 points: {:?}",
        base.fps,
        base.dsp_frac,
        base.logic_frac,
        base.bram_frac,
        front
            .at(Precision::Int8)
            .map(|p| (p.fps, p.dsp_frac, p.logic_frac, p.bram_frac))
            .collect::<Vec<_>>()
    );

    // The accuracy delta is reported and bounded on every int8 point.
    let mut int8_points = 0;
    for p in front.at(Precision::Int8) {
        int8_points += 1;
        assert!(p.accuracy_delta_pp > 0.0, "int8 must report a nonzero modeled loss");
        assert!(p.accuracy_delta_pp < 5.0, "unbounded accuracy delta: {}pp", p.accuracy_delta_pp);
    }
    assert!(int8_points > 0, "front has no int8 representation");
}

/// The folded explorer also sweeps precision: mobilenet's int8 leg must
/// keep pace with fp32 throughput while spending strictly fewer DSPs.
#[test]
fn folded_precision_sweep_saves_resources_on_mobilenet() {
    let compiler = Compiler::default();
    let g = models::mobilenet_v1();
    let front =
        explore_precisions(&compiler, &g, Mode::Folded, 4, &[Precision::F32, Precision::Int8])
            .unwrap();
    let base = front.baseline_f32.as_ref().expect("baseline");
    let best_int8 = front
        .results
        .iter()
        .find(|(p, _)| *p == Precision::Int8)
        .and_then(|(_, r)| r.best.clone())
        .expect("some int8 design routes");
    assert!(
        best_int8.fps >= base.fps * 0.9,
        "int8 {:.2} FPS collapsed vs f32 {:.2}",
        best_int8.fps,
        base.fps
    );
    assert!(best_int8.dsp_frac < base.dsp_frac, "int8 must pack DSPs");
    assert!(best_int8.accuracy_delta_pp < 5.0);
    // The synthesis memo works across the precision sweep too.
    assert!(front.synth_cache().total() > 0);
}

/// End-to-end staged session: `with_quantization` threads precision into
/// kernels, synthesis and the emitted pseudo-OpenCL, and reports accuracy.
#[test]
fn with_quantization_threads_precision_end_to_end() {
    let compiler = Compiler::default();
    let g = models::lenet5();
    let f32_acc = compiler.graph(&g).mode(ModeChoice::Pipelined).run().unwrap();
    let int8_acc = compiler
        .graph(&g)
        .mode(ModeChoice::Pipelined)
        .with_quantization(QuantConfig::int8())
        .run()
        .unwrap();

    assert_eq!(int8_acc.precision, Precision::Int8);
    let report = int8_acc.quant.as_ref().expect("quant report");
    assert_eq!(report.precision, Precision::Int8);
    assert!(report.stats.quantize_nodes >= 1);
    assert!(report.accuracy.delta_pp < 5.0);

    // Modeled resources shrink across the board.
    let (uf, ui) = (
        &f32_acc.synthesis.resources.utilization,
        &int8_acc.synthesis.resources.utilization,
    );
    assert!(ui.dsp_frac < uf.dsp_frac, "dsp {} vs {}", ui.dsp_frac, uf.dsp_frac);
    assert!(ui.bram_frac < uf.bram_frac, "bram {} vs {}", ui.bram_frac, uf.bram_frac);
    assert!(int8_acc.synthesis.fmax_mhz >= f32_acc.synthesis.fmax_mhz);
    assert!(int8_acc.performance.fps >= f32_acc.performance.fps * 0.99);

    // Emitted kernels round-trip the dtype metadata. Pipelined activations
    // move through channels (which carry the narrow type); folded kernels
    // keep global buffers, which must be typed too.
    let src = int8_acc.program.to_pseudo_opencl();
    assert!(src.contains("channel char"), "{src}");
    assert!(src.contains("dequant_scale"), "{src}");
    assert!(!src.contains("__global float"), "{src}");
    let folded_int8 = compiler
        .graph(&g)
        .mode(ModeChoice::Folded)
        .with_quantization(QuantConfig::int8())
        .run()
        .unwrap();
    assert!(
        folded_int8.program.to_pseudo_opencl().contains("__global char* restrict"),
        "{}",
        folded_int8.program.to_pseudo_opencl()
    );
    // The f32 compilation is unchanged by the new plumbing.
    let f32_src = f32_acc.program.to_pseudo_opencl();
    assert!(f32_src.contains("channel float"));
    assert!(!f32_src.contains("char"));
}

/// Empirically-measured (not modeled) accuracy on LeNet-5 stays bounded:
/// the quantized executor's top-1 decisions overwhelmingly agree with f32.
#[test]
fn measured_int8_accuracy_is_bounded_on_lenet() {
    let g = models::lenet5();
    let prep = quant::prepare(&g, &QuantConfig::int8().with_data(12)).unwrap();
    assert!(!prep.report.accuracy.estimated);
    assert!(
        prep.report.accuracy.top1_agreement >= 0.75,
        "agreement {}",
        prep.report.accuracy.top1_agreement
    );
    assert!(prep.report.accuracy.delta_pp <= 25.0);
}

/// Quantized accelerators serve through the coordinator's sim engines with
/// precision-tagged replica names.
#[test]
fn quantized_replicas_serve_with_tagged_names() {
    let g = models::lenet5();
    let plan =
        ReplicaPlan::build_with(&g, &["stratix10sx"], Some(QuantConfig::int8())).unwrap();
    assert_eq!(plan.entries[0].accelerator.precision, Precision::Int8);
    let engines = SimEngine::from_plan(&plan, &g, 8).unwrap();
    assert_eq!(engines[0].name(), "lenet5@stratix10sx:int8");
    assert!(engines[0].modeled_fps() > 0.0);
}

/// Property (via `util::prop`): quantize→dequantize round-trip error is
/// bounded by half a grid step for in-range values, across both schemes,
/// and scales are monotone in the calibrated range.
#[test]
fn prop_roundtrip_bounds_and_scale_monotonicity() {
    prop::check("integration-qdq-bounds", |rng, _| {
        let channels = 1 + rng.below(6) as usize;
        let ranges: Vec<Range> = (0..channels)
            .map(|_| {
                let m = 0.001 + rng.f64() * 50.0;
                Range::new(-m, m)
            })
            .collect();
        let whole = ranges.iter().fold(Range::EMPTY, |a, r| a.merge(r));
        let pt = QParams::per_tensor(whole, Precision::Int8);
        let pc = QParams::per_channel(&ranges, Precision::Int8);
        for (ch, r) in ranges.iter().enumerate() {
            let x = (rng.f64() * 2.0 - 1.0) * r.max_abs();
            for (q, c) in [(&pt, 0), (&pc, ch)] {
                let err = (q.roundtrip(x, c) - x).abs();
                assert!(err <= q.step(c) / 2.0 + 1e-12, "err {err} step {}", q.step(c));
            }
            // Monotonicity: the per-channel grid never has a coarser step
            // than the per-tensor grid that must cover every channel.
            assert!(pc.scale(ch) <= pt.scale(0) + 1e-15);
        }
    });
}

/// fp16 is the gentle rung of the precision ladder: near-zero modeled
/// loss, DSP packing still engaged.
#[test]
fn fp16_compiles_with_negligible_loss() {
    let compiler = Compiler::default();
    let g = models::lenet5();
    let acc = compiler
        .graph(&g)
        .mode(ModeChoice::Pipelined)
        .with_quantization(QuantConfig::fp16())
        .run()
        .unwrap();
    assert_eq!(acc.precision, Precision::F16);
    assert!(acc.quant.as_ref().unwrap().accuracy.delta_pp < 0.5);
    assert!(acc.program.to_pseudo_opencl().contains("half"));
}
