//! Static design-rule analyzer: deliberately broken programs must be
//! rejected with the documented FLOW lint codes, and analyzer-clean
//! programs must actually run under the verify interpreter (soundness).

use tvm_fpga_flow::analysis::{self, Lint, Severity};
use tvm_fpga_flow::codegen::{Channel, KernelProgram};
use tvm_fpga_flow::device::FpgaDevice;
use tvm_fpga_flow::flow::patterns::build_with_passes;
use tvm_fpga_flow::flow::{default_factors, CompileError, Compiler, Mode, OptConfig};
use tvm_fpga_flow::graph::{models, Activation, Graph, GraphBuilder, Op, Shape};
use tvm_fpga_flow::quant::{calibrate_analytic, Calibrator, Executor, QScheme};
use tvm_fpga_flow::texpr::Precision;
use tvm_fpga_flow::verify::Interpreter;

fn lowered_lenet(mode: Mode) -> (Graph, KernelProgram) {
    let g = models::lenet5();
    let plan = default_factors(&g);
    let built = build_with_passes(&g, mode, &OptConfig::optimized(), &plan);
    (g, built.program)
}

fn codes(g: &Graph, prog: &KernelProgram) -> Vec<&'static str> {
    let dev = FpgaDevice::stratix10sx();
    analysis::analyze(g, prog, &dev, 250.0, None).diagnostics.iter().map(|d| d.code()).collect()
}

#[test]
fn clean_lenet_has_no_errors_in_either_mode() {
    let dev = FpgaDevice::stratix10sx();
    for mode in [Mode::Pipelined, Mode::Folded] {
        let (g, prog) = lowered_lenet(mode);
        let report = analysis::analyze(&g, &prog, &dev, 250.0, None);
        assert_eq!(report.errors().count(), 0, "{mode:?}: {}", report.render());
    }
}

#[test]
fn cyclic_channel_topology_is_flow001() {
    let (g, mut prog) = lowered_lenet(Mode::Pipelined);
    assert!(!prog.channels.is_empty(), "optimized pipelined LeNet is channelized");
    // A back-edge from the last kernel to the first closes a cycle over
    // the whole chain: no kernel can ever fire.
    prog.channels.push(Channel {
        name: "back_edge".into(),
        from_kernel: prog.kernels.len() - 1,
        to_kernel: 0,
        depth: 16,
        elem: Precision::F32,
    });
    let codes = codes(&g, &prog);
    assert!(codes.contains(&"FLOW001"), "expected FLOW001 deadlock, got {codes:?}");
}

#[test]
fn self_loop_channel_is_flow001() {
    let (g, mut prog) = lowered_lenet(Mode::Pipelined);
    let k = prog.channels[0].from_kernel;
    prog.channels.push(Channel {
        name: "self_loop".into(),
        from_kernel: k,
        to_kernel: k,
        depth: 16,
        elem: Precision::F32,
    });
    let codes = codes(&g, &prog);
    assert!(codes.contains(&"FLOW001"), "{codes:?}");
}

#[test]
fn unbalanced_channel_reads_are_flow002() {
    let (g, mut prog) = lowered_lenet(Mode::Pipelined);
    // Dispatch the consumer's layer twice per frame: it now reads the
    // producer's stream twice while the producer writes it once.
    let victim = prog.channels[0].to_kernel;
    let dup = prog.kernels[victim].layers[0];
    prog.kernels[victim].layers.push(dup);
    let dev = FpgaDevice::stratix10sx();
    let report = analysis::analyze(&g, &prog, &dev, 250.0, None);
    let imbalance: Vec<_> =
        report.diagnostics.iter().filter(|d| d.code() == "FLOW002").collect();
    assert!(!imbalance.is_empty(), "expected FLOW002, got {}", report.render());
    assert_eq!(imbalance[0].severity(), Severity::Error);
    assert!(imbalance[0].span.channel.is_some(), "token lints carry the channel span");
}

#[test]
fn under_depth_channel_is_flow003() {
    let (g, mut prog) = lowered_lenet(Mode::Pipelined);
    prog.channels[0].depth = 1;
    let codes = codes(&g, &prog);
    assert!(codes.contains(&"FLOW003"), "{codes:?}");
}

#[test]
fn channel_elem_mismatch_is_flow005() {
    let (g, mut prog) = lowered_lenet(Mode::Pipelined);
    prog.channels[0].elem = Precision::Int8;
    let codes = codes(&g, &prog);
    assert!(codes.contains(&"FLOW005"), "{codes:?}");
}

#[test]
fn rewired_channel_is_missing_plus_orphan() {
    let (g, mut prog) = lowered_lenet(Mode::Pipelined);
    let last = prog.kernels.len() - 1;
    prog.channels[0].to_kernel = if prog.channels[0].to_kernel == last { 0 } else { last };
    let codes = codes(&g, &prog);
    assert!(codes.contains(&"FLOW006"), "graph edge lost its channel: {codes:?}");
    assert!(codes.contains(&"FLOW007"), "rewired channel matches no edge: {codes:?}");
}

/// A Dense reduction of `in_features` at int8 accumulates up to
/// `in_features × 127²` in a 32-bit int.
fn dense_net(in_features: usize) -> Graph {
    let (mut b, x) = GraphBuilder::new("overflow_net", Shape::Flat(in_features));
    let d = b.add(
        "wide_dense",
        Op::Dense { out_features: 8, bias: true, activation: Activation::Relu },
        &[x],
    );
    b.finish(d)
}

#[test]
fn int8_accumulator_overflow_is_flow010() {
    // 200k × 127² ≈ 3.2e9 > i32::MAX ≈ 2.1e9: the accumulator can wrap.
    let g = dense_net(200_000);
    let plan = default_factors(&g);
    let cfg = OptConfig::optimized().with_precision(Precision::Int8);
    let built = build_with_passes(&g, Mode::Folded, &cfg, &plan);
    let dev = FpgaDevice::stratix10sx();
    let report = analysis::analyze(&g, &built.program, &dev, 250.0, None);
    let overflow: Vec<_> =
        report.diagnostics.iter().filter(|d| d.code() == "FLOW010").collect();
    assert!(!overflow.is_empty(), "expected FLOW010, got {}", report.render());
    assert_eq!(overflow[0].severity(), Severity::Error);
    assert_eq!(overflow[0].lint, Lint::AccumOverflow);
    // The span names the exact offending layer.
    assert_eq!(overflow[0].span.node.as_deref(), Some("wide_dense"), "{:?}", overflow[0].span);
    // The same design at f32 is not an overflow risk.
    let f32_built = build_with_passes(&g, Mode::Folded, &OptConfig::optimized(), &plan);
    let f32_report = analysis::analyze(&g, &f32_built.program, &dev, 250.0, None);
    assert!(!f32_report.diagnostics.iter().any(|d| d.code() == "FLOW010"));
}

#[test]
fn int8_accumulator_margin_is_flow011_warning() {
    // 100k × 127² ≈ 1.6e9: under the limit but within 2× of it.
    let g = dense_net(100_000);
    let plan = default_factors(&g);
    let cfg = OptConfig::optimized().with_precision(Precision::Int8);
    let built = build_with_passes(&g, Mode::Folded, &cfg, &plan);
    let dev = FpgaDevice::stratix10sx();
    let report = analysis::analyze(&g, &built.program, &dev, 250.0, None);
    let margin: Vec<_> = report.diagnostics.iter().filter(|d| d.code() == "FLOW011").collect();
    assert!(!margin.is_empty(), "expected FLOW011, got {}", report.render());
    assert_eq!(margin[0].severity(), Severity::Warning);
    assert!(!report.diagnostics.iter().any(|d| d.code() == "FLOW010"));
}

#[test]
fn lenet_int8_accumulators_are_proven_safe() {
    // LeNet's deepest reduction (400-element dense) is far from wrapping:
    // the proof should produce neither the error nor the margin warning.
    let g = models::lenet5();
    let plan = default_factors(&g);
    let cfg = OptConfig::optimized().with_precision(Precision::Int8);
    let built = build_with_passes(&g, Mode::Pipelined, &cfg, &plan);
    let dev = FpgaDevice::stratix10sx();
    let report = analysis::analyze(&g, &built.program, &dev, 250.0, None);
    assert!(
        !report.diagnostics.iter().any(|d| matches!(d.code(), "FLOW010" | "FLOW011")),
        "{}",
        report.render()
    );
}

#[test]
fn session_analyze_rejects_broken_designs_with_typed_error() {
    // Through the staged API: an analyzer-clean design returns the report…
    let compiler = Compiler::default();
    let report =
        compiler.graph(&models::lenet5()).mode(Mode::Pipelined).analyze().expect("clean");
    assert!(report.is_clean(false), "{}", report.render());
    // …and an overflow-prone one comes back as CompileError::Analysis
    // carrying the FLOW010 diagnostics.
    let g = dense_net(200_000);
    let err = compiler
        .graph(&g)
        .mode(Mode::Folded)
        .opts(OptConfig::optimized().with_precision(Precision::Int8))
        .analyze()
        .unwrap_err();
    match err.downcast_ref::<CompileError>() {
        Some(CompileError::Analysis { network, diagnostics }) => {
            assert_eq!(network, "overflow_net");
            assert!(diagnostics.iter().any(|d| d.code() == "FLOW010"), "{diagnostics:?}");
        }
        other => panic!("wrong error variant: {other:?}"),
    }
}

#[test]
fn analyzer_clean_programs_run_under_the_interpreter() {
    // Soundness cross-check: every (mode × precision × level) lowering of
    // LeNet the analyzer passes must execute to completion under the
    // verify interpreter on seeded frames — "clean" must mean "runnable".
    let g = models::lenet5();
    let plan = default_factors(&g);
    let dev = FpgaDevice::stratix10sx();
    let exec = Executor::new(&g);
    let table = calibrate_analytic(&g, Calibrator::Percentile(99.9));
    let mut checked = 0usize;
    for mode in [Mode::Pipelined, Mode::Folded] {
        for precision in Precision::all() {
            for base_cfg in [OptConfig::base(), OptConfig::optimized()] {
                let cfg = base_cfg.with_precision(precision);
                let built = build_with_passes(&g, mode, &cfg, &plan);
                let report =
                    analysis::analyze(&g, &built.program, &dev, 250.0, Some(&built.trace));
                assert_eq!(
                    report.errors().count(),
                    0,
                    "{mode:?} {precision:?}: {}",
                    report.render()
                );
                let itp = Interpreter::new(
                    &g,
                    &built.program,
                    &exec,
                    &table,
                    QScheme::PerChannel,
                    precision,
                );
                for seed in [0x5EED_0001u64, 0x5EED_0002] {
                    let frames = tvm_fpga_flow::verify::frames_for(&g, 1, seed);
                    let run = itp.run_frame(&frames[0]).unwrap_or_else(|e| {
                        panic!("{mode:?} {precision:?}: analyzer-clean but stuck: {e}")
                    });
                    assert!(!run.logits.is_empty());
                }
                checked += 1;
            }
        }
    }
    assert_eq!(checked, 12);
}
