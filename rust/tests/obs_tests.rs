//! Observability integration tests: span-tree shape across the compile
//! stages, metric values against known event counts, the serve request
//! lifecycle, and the metrics edge cases (empty percentiles, histogram
//! overflow, concurrent counters, disabled no-op paths).
//!
//! The tracer is process-global, so every test touching it serializes on
//! [`lock`] and starts by draining whatever a previous test left behind.
//! Metric assertions always diff two [`Registry::snapshot`]s — the global
//! registry is cumulative across tests in this binary.

use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

use tvm_fpga_flow::coordinator::{EngineSpec, InferenceServer, ServerConfig, SimEngine};
use tvm_fpga_flow::flow::multi::ReplicaPlan;
use tvm_fpga_flow::flow::{Compiler, Mode};
use tvm_fpga_flow::graph::models;
use tvm_fpga_flow::metrics::LatencyStats;
use tvm_fpga_flow::obs::{self, Registry};
use tvm_fpga_flow::quant::{Executor, FastExecutor};
use tvm_fpga_flow::util::pool::Pool;
use tvm_fpga_flow::util::scratch::Scratch;

static LOCK: Mutex<()> = Mutex::new(());

/// Serialize tests that touch the global tracer/registry (and recover
/// from a panicked holder — the poison is harmless here).
fn lock() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn delta(
    before: &std::collections::BTreeMap<String, f64>,
    after: &std::collections::BTreeMap<String, f64>,
    name: &str,
) -> f64 {
    after.get(name).copied().unwrap_or(0.0) - before.get(name).copied().unwrap_or(0.0)
}

#[test]
fn compile_span_tree_shape() {
    let _l = lock();
    let _ = obs::take();
    obs::enable();

    let compiler = Compiler::default();
    let g = models::lenet5();
    let mut session = compiler.graph(&g).mode(Mode::Pipelined);
    let (n_records, n_skipped) = {
        let lowered = session.lower().unwrap();
        (lowered.trace.records.len(), lowered.trace.skipped())
    };
    session.analyze().unwrap();
    let vrep = session.verify(1).unwrap();
    assert!(vrep.passed, "{}", vrep.summary());
    session.synthesize().unwrap();
    let _acc = session.simulate().unwrap();

    let trace = obs::take();
    // All compile stages present, as `compile`-category spans.
    for stage in ["lower", "analyze", "synthesize", "verify", "simulate"] {
        let span = trace.find(stage).unwrap_or_else(|| panic!("missing stage span {stage}"));
        assert_eq!(span.cat, "compile", "{stage} has wrong category");
    }

    // Every pass the PassManager ran is a `pass` child of the lower span,
    // and skipped passes carry their blocking reason as an arg.
    let lower = trace.find("lower").unwrap();
    let pass_children: Vec<_> =
        trace.children(lower.id).into_iter().filter(|e| e.cat == "pass").collect();
    assert_eq!(pass_children.len(), n_records, "one pass span per PassTrace record");
    let skipped_spans =
        pass_children.iter().filter(|e| e.args.iter().any(|(k, _)| *k == "skipped")).count();
    assert_eq!(skipped_spans, n_skipped);

    // Each analysis rule family is an `analysis` child of the analyze span
    // with a findings count.
    let analyze = trace.find("analyze").unwrap();
    let fams: Vec<_> =
        trace.children(analyze.id).into_iter().filter(|e| e.cat == "analysis").collect();
    for family in ["deadlock", "overflow", "legality", "structure", "budget", "consistency"] {
        let f = fams
            .iter()
            .find(|e| e.name == family)
            .unwrap_or_else(|| panic!("missing analysis family {family}"));
        assert!(f.num_arg("findings").is_some());
    }

    // The verify stage traced the kernel interpreter: per-frame spans
    // under the stage, per-dispatch kernel spans under each frame.
    let verify = trace.find("verify").unwrap();
    let frames: Vec<_> =
        trace.children(verify.id).into_iter().filter(|e| e.name == "interp_frame").collect();
    assert!(!frames.is_empty(), "verify stage recorded no interp_frame spans");
    let dispatches: Vec<_> = trace.children(frames[0].id);
    assert!(!dispatches.is_empty(), "interp_frame recorded no dispatch spans");
    assert!(dispatches.iter().all(|d| d.cat == "verify"));
}

#[test]
fn compile_metrics_count_events() {
    let _l = lock();
    let _ = obs::take();
    obs::enable();
    let before = obs::global_metrics().snapshot();

    let compiler = Compiler::default();
    let g = models::lenet5();
    let mut s1 = compiler.graph(&g).mode(Mode::Pipelined);
    let (applied, skipped) = {
        let l = s1.lower().unwrap();
        (l.trace.applied(), l.trace.skipped())
    };
    s1.synthesize().unwrap();
    // Identical program on the same compiler: memoized synthesis.
    let mut s2 = compiler.graph(&g).mode(Mode::Pipelined);
    s2.lower().unwrap();
    s2.synthesize().unwrap();

    let after = obs::global_metrics().snapshot();
    let _ = obs::take();
    assert_eq!(delta(&before, &after, "flow_lower_total"), 2.0);
    assert_eq!(delta(&before, &after, "flow_synth_cache_misses_total"), 1.0);
    assert_eq!(delta(&before, &after, "flow_synth_cache_hits_total"), 1.0);
    assert_eq!(delta(&before, &after, "flow_passes_applied_total"), 2.0 * applied as f64);
    assert_eq!(delta(&before, &after, "flow_passes_skipped_total"), 2.0 * skipped as f64);
}

#[test]
fn executor_per_layer_spans_and_stats() {
    let _l = lock();
    let _ = obs::take();

    let g = models::lenet5();
    let exec = Executor::new(&g);
    let data = tvm_fpga_flow::data::for_network("lenet5", 2, 3).unwrap();

    // Disabled: the traced entry points fall through to the plain paths.
    let plain = exec.forward(data.frame(0), |_, _| {});
    assert_eq!(exec.forward_traced(data.frame(0)), plain);

    obs::enable();
    let traced = exec.forward_traced(data.frame(0));
    assert_eq!(traced, plain, "tracing must not change results");

    let mut scratch = Scratch::new();
    let mut fast = FastExecutor::reference(&exec, true, &mut scratch);
    let fast_out = fast.forward_traced(data.frame(0)).to_vec();
    let trace = obs::take();
    assert_eq!(fast_out.len(), plain.len());
    for (a, b) in fast_out.iter().zip(plain.iter()) {
        assert!((a - b).abs() <= 1e-5 * b.abs().max(1.0), "{a} vs {b}");
    }

    // Two frame spans (reference + fast path), each with one child per
    // executed layer, named after the graph node.
    assert_eq!(trace.count("frame"), 2);
    for frame in trace.events.iter().filter(|e| e.name == "frame") {
        assert_eq!(frame.cat, "exec");
        let layers = trace.children(frame.id);
        assert!(!layers.is_empty(), "frame span has no per-layer children");
        for l in &layers {
            assert!(
                g.nodes.iter().any(|n| n.name == l.name),
                "span {} is not a node of {}",
                l.name,
                g.name
            );
            assert!(l.num_arg("elems").unwrap_or(0.0) > 0.0);
        }
    }

    // ExecStats: arena attribution from build time plus buffer accounting.
    let stats = fast.stats();
    assert!(stats.buffers > 0);
    assert!(stats.buffer_bytes > 0);
    assert_eq!(stats.scratch.checkouts, stats.scratch.hits + stats.scratch.misses);
    let j = stats.to_json();
    assert_eq!(j.get("buffers").and_then(|v| v.as_f64()), Some(stats.buffers as f64));
    assert_eq!(
        j.get("scratch_checkouts").and_then(|v| v.as_f64()),
        Some(stats.scratch.checkouts as f64)
    );
    fast.release(&mut scratch);
}

#[test]
fn serve_lifecycle_spans_and_metrics() {
    let _l = lock();
    let _ = obs::take();
    obs::enable();
    let before = obs::global_metrics().snapshot();

    let g = models::lenet5();
    let requests = 12usize;
    let plan = ReplicaPlan::build_with(&g, &["stratix10sx"], None).unwrap();
    let server = InferenceServer::start(ServerConfig {
        network: g.name.clone(),
        workers: 1,
        max_batch: 4,
        max_wait: Duration::from_micros(200),
        queue_capacity: 64,
        replicas: SimEngine::from_plan(&plan, &g, 4)
            .unwrap()
            .into_iter()
            .map(EngineSpec::Sim)
            .collect(),
        ..Default::default()
    })
    .unwrap();
    let data = tvm_fpga_flow::data::for_network("lenet5", 4, 1).unwrap();
    let pending: Vec<_> = (0..requests)
        .map(|i| server.infer_async(data.frame(i % 4).to_vec()).unwrap())
        .collect();
    for rx in pending {
        rx.recv().unwrap().unwrap();
    }
    let stats = server.shutdown();
    stats.export_metrics(obs::global_metrics());
    let after = obs::global_metrics().snapshot();
    let trace = obs::take();

    // One `request` span per request, each with queued + execute children.
    assert_eq!(trace.count("request"), requests);
    for r in trace.events.iter().filter(|e| e.name == "request") {
        assert_eq!(r.cat, "serve");
        assert_eq!(r.bool_arg("ok"), Some(true));
        let kids = trace.children(r.id);
        assert!(kids.iter().any(|k| k.name == "queued"), "request lacks queued child");
        assert!(kids.iter().any(|k| k.name == "execute"), "request lacks execute child");
    }
    // Batch spans match the executed-batch count; the engine traced too.
    assert_eq!(trace.count("batch"), stats.batches as usize);
    assert!(!trace.in_cat("engine").is_empty());

    // Lifecycle counters agree with the server's own accounting.
    assert_eq!(delta(&before, &after, "flow_serve_submitted_total"), requests as f64);
    assert_eq!(delta(&before, &after, "flow_serve_completed_total"), requests as f64);
    assert_eq!(delta(&before, &after, "flow_serve_batches_total"), stats.batches as f64);
    let flushes = delta(&before, &after, "flow_serve_flush_full_total")
        + delta(&before, &after, "flow_serve_flush_deadline_total")
        + delta(&before, &after, "flow_serve_flush_close_total");
    assert_eq!(flushes, stats.batches as f64);

    // Snapshot re-registration: gauges mirror the snapshot, the batch
    // histogram imported every executed batch.
    assert_eq!(delta(&before, &after, "flow_serve_submitted"), stats.submitted as f64);
    assert_eq!(delta(&before, &after, "flow_serve_batch_size_count"), stats.batches as f64);
}

#[test]
fn dse_candidate_spans_attribute_cache_hits() {
    let _l = lock();
    let _ = obs::take();
    obs::enable();
    let before = obs::global_metrics().snapshot();

    let compiler = Compiler::default();
    let g = models::lenet5();
    let result = tvm_fpga_flow::dse::explore_pipelined(&compiler, &g);
    let after = obs::global_metrics().snapshot();
    let trace = obs::take();

    let candidates = trace.in_cat("dse");
    assert_eq!(candidates.len(), result.evaluated);
    assert_eq!(delta(&before, &after, "flow_dse_candidates_total"), result.evaluated as f64);
    let cache_hit_spans =
        candidates.iter().filter(|c| c.bool_arg("synth_cache_hit") == Some(true)).count();
    // Candidates running concurrently each synthesize a distinct plan, so
    // a hit observed by a candidate's before/after delta is its own; the
    // span attribution can never exceed the sweep's memo-hit total.
    assert!(
        cache_hit_spans as u64 <= result.synth_cache.hits,
        "{cache_hit_spans} hit-attributed spans vs {} memo hits",
        result.synth_cache.hits
    );
    for c in &candidates {
        assert!(c.num_arg("fps").is_some(), "candidate span lacks fps arg");
        assert!(c.bool_arg("accepted").is_some(), "candidate span lacks accepted arg");
    }
}

// --- metrics edge cases -------------------------------------------------

#[test]
fn latency_percentiles_empty_and_single_sample() {
    let empty = LatencyStats::default();
    assert_eq!(empty.percentile(50.0), None);
    assert_eq!(empty.percentile(99.0), None);
    assert_eq!(empty.mean(), None);

    let mut one = LatencyStats::default();
    one.record(42);
    assert_eq!(one.percentile(0.0), Some(42));
    assert_eq!(one.percentile(50.0), Some(42));
    assert_eq!(one.percentile(99.0), Some(42));
    assert_eq!(one.percentile(100.0), Some(42));
    assert_eq!(one.mean(), Some(42.0));
}

#[test]
fn histogram_overflow_bucket_catches_everything() {
    let reg = Registry::new();
    let h = reg.histogram("t_obs_edge_us", "edge-case histogram", &[1.0, 10.0]);
    h.observe(0.5);
    h.observe(10.0); // inclusive upper bound: still the le=10 bucket
    h.observe(1e12); // far past the last bound → +Inf bucket
    assert_eq!(h.bucket_counts(), vec![1, 1, 1]);
    assert_eq!(h.count(), 3);
    let text = reg.render_prometheus();
    assert!(text.contains("t_obs_edge_us_bucket{le=\"+Inf\"} 3"), "{text}");
}

#[test]
fn concurrent_counter_increments_from_pool_workers() {
    let reg = std::sync::Arc::new(Registry::new());
    let c = reg.counter("t_obs_pool_total", "incremented from pool workers");
    let pool = Pool::new(4, "obs-test");
    let per_job = 1_000u64;
    let jobs = 16;
    let handles: Vec<_> = (0..jobs)
        .map(|_| {
            let c = std::sync::Arc::clone(&c);
            pool.submit_with_result(move || {
                for _ in 0..per_job {
                    c.inc();
                }
            })
        })
        .collect();
    for h in handles {
        h.recv().unwrap();
    }
    assert_eq!(c.get(), jobs as u64 * per_job, "lost increments under contention");
    assert_eq!(reg.snapshot()["t_obs_pool_total"], (jobs as u64 * per_job) as f64);
}

#[test]
fn disabled_tracer_is_a_no_op_everywhere() {
    let _l = lock();
    let _ = obs::take(); // ensure disabled and drained
    assert!(!obs::enabled());

    let mut s = obs::span("exec", "nothing");
    assert_eq!(s.id(), None);
    s.set_arg("k", 1u64);
    drop(s);
    assert_eq!(
        obs::span_at(
            "serve",
            "nothing",
            None,
            std::time::Instant::now(),
            std::time::Instant::now(),
            vec![],
        ),
        None
    );

    // A full compile with the tracer off records no spans and moves no
    // gated counters.
    let before = obs::global_metrics().snapshot();
    let compiler = Compiler::default();
    let mut session = compiler.graph(&models::lenet5()).mode(Mode::Pipelined);
    session.lower().unwrap();
    session.synthesize().unwrap();
    let after = obs::global_metrics().snapshot();
    assert_eq!(delta(&before, &after, "flow_lower_total"), 0.0);
    assert_eq!(delta(&before, &after, "flow_passes_applied_total"), 0.0);
    assert_eq!(delta(&before, &after, "flow_synth_cache_misses_total"), 0.0);
    assert!(obs::take().is_empty());
}

#[test]
fn observability_json_sections() {
    let _l = lock();
    let _ = obs::take();
    obs::enable();
    {
        let _s = obs::span("compile", "unit");
    }
    let trace = obs::take();

    let with = obs::observability_json(Some(&trace));
    let j = tvm_fpga_flow::util::json::parse(&with.to_string()).unwrap();
    assert!(j.get("metrics").is_some());
    assert_eq!(
        j.get("trace").unwrap().get("spans").and_then(|v| v.as_f64()),
        Some(trace.len() as f64)
    );
    let without = obs::observability_json(None);
    let j = tvm_fpga_flow::util::json::parse(&without.to_string()).unwrap();
    assert!(j.get("metrics").is_some());
    assert!(j.get("trace").is_none());
}
