//! Integration tests across graph → flow → aoc → sim, plus property tests
//! on the flow invariants (in-crate mini-prop harness; proptest is not in
//! the offline crate set).

use tvm_fpga_flow::aoc;
use tvm_fpga_flow::device::FpgaDevice;
use tvm_fpga_flow::flow::{default_factors, Compiler, Mode, OptConfig, OptLevel};
use tvm_fpga_flow::graph::{models, Activation, GraphBuilder, Op, Shape};
use tvm_fpga_flow::metrics::paper;
use tvm_fpga_flow::schedule::OptKind;
use tvm_fpga_flow::util::prop;

#[test]
fn table2_within_shape_of_paper() {
    let flow = Compiler::default();
    for (name, pl, pb, pd, pf) in paper::TABLE2 {
        let g = models::by_name(name).unwrap();
        let acc = flow.compile(&g, Compiler::paper_mode(name), OptLevel::Optimized).unwrap();
        let (l, b, d, f) = acc.synthesis.table2_row();
        // Every cell within 2× of the paper (most are far closer).
        for (ours, theirs, what) in [(l, pl, "logic"), (b, pb, "bram"), (d, pd, "dsp"), (f, pf, "fmax")] {
            let ratio = ours / theirs;
            assert!((0.5..2.0).contains(&ratio), "{name} {what}: {ours:.1} vs paper {theirs:.1}");
        }
    }
}

#[test]
fn table4_speedups_within_shape() {
    let flow = Compiler::default();
    for (name, pb, po, _) in paper::TABLE4 {
        let g = models::by_name(name).unwrap();
        let mode = Compiler::paper_mode(name);
        let base = flow.compile(&g, mode, OptLevel::Base).unwrap().performance.fps;
        let opt = flow.compile(&g, mode, OptLevel::Optimized).unwrap().performance.fps;
        assert!((0.2..5.0).contains(&(base / pb)), "{name} base {base} vs paper {pb}");
        assert!((0.2..5.0).contains(&(opt / po)), "{name} opt {opt} vs paper {po}");
        assert!(opt > base * 5.0, "{name}: optimizations must matter");
    }
}

#[test]
fn table3_exact_match() {
    let flow = Compiler::default();
    for (name, expected) in paper::TABLE3 {
        let g = models::by_name(name).unwrap();
        let acc = flow.compile(&g, Compiler::paper_mode(name), OptLevel::Optimized).unwrap();
        let ours: Vec<&str> = acc.applied.iter().map(|o| o.abbrev()).collect();
        for e in expected {
            assert!(ours.contains(e), "{name}: paper applies {e}, we don't ({ours:?})");
        }
        for o in &ours {
            assert!(expected.contains(o), "{name}: we apply {o}, paper doesn't ({expected:?})");
        }
    }
}

#[test]
fn per_layer_fps_never_negative_or_nan() {
    let flow = Compiler::default();
    for g in models::all() {
        for mode in [Mode::Pipelined, Mode::Folded] {
            // Pipelined mode for the big nets over-commits BRAM → allowed
            // to fail; when it compiles, numbers must be sane.
            if let Ok(acc) = flow.compile(&g, mode, OptLevel::Optimized) {
                assert!(acc.performance.fps.is_finite() && acc.performance.fps > 0.0);
                for l in &acc.performance.per_layer {
                    assert!(l.cycles.is_finite() && l.cycles >= 0.0, "{}: {l:?}", g.name);
                }
            }
        }
    }
}

#[test]
fn custom_graph_end_to_end() {
    // A hand-built CNN (not one of the paper's three) must flow through
    // compile cleanly — the flow is generic, not special-cased.
    let (mut b, x) = GraphBuilder::new("custom", Shape::Chw(3, 64, 64));
    let c1 = b.add("c1", Op::Conv2d { out_channels: 16, kernel: 3, stride: 1, padding: 1, bias: true, activation: Activation::Relu }, &[x]);
    let p1 = b.add("p1", Op::MaxPool { kernel: 2, stride: 2, padding: 0 }, &[c1]);
    let c2 = b.add("c2", Op::Conv2d { out_channels: 32, kernel: 3, stride: 1, padding: 1, bias: true, activation: Activation::Relu }, &[p1]);
    let g1 = b.add("gap", Op::GlobalAvgPool, &[c2]);
    let d = b.add("fc", Op::Dense { out_features: 10, bias: true, activation: Activation::None }, &[g1]);
    let g = b.finish(d);

    let flow = Compiler::default();
    for mode in [Mode::Pipelined, Mode::Folded] {
        let acc = flow.compile(&g, mode, OptLevel::Optimized).unwrap();
        assert!(acc.performance.fps > 0.0, "{:?}", mode);
        assert!(acc.synthesis.resources.utilization.fits());
    }
}

#[test]
fn routing_failure_is_reported_not_panicked() {
    // Absurd factor plan → clean error.
    let g = models::resnet34();
    let mut plan = default_factors(&g);
    for (_, t) in plan.group_tiles.iter_mut() {
        *t = (64, 64);
    }
    let err = Compiler::default().compile_with(&g, Mode::Folded, &OptConfig::optimized(), &plan);
    assert!(err.is_err());
    let msg = format!("{}", err.err().unwrap());
    assert!(msg.contains("routing failure") || msg.contains("bandwidth"), "{msg}");
}

// --------------------------- property tests ------------------------------

#[test]
fn prop_unrolling_never_changes_total_work() {
    // Schedule factors move cycles around but total MACs are invariant:
    // out_elems × reduction_size is untouched by any legal tiling.
    prop::check("work_invariant", |rng, _case| {
        let g = models::lenet5();
        let flow = Compiler::default();
        let mut plan = default_factors(&g);
        plan.pipelined_cap = *rng.pick(&[8u64, 16, 32, 64, 128, 256, 512]);
        plan.dense_tile = (*rng.pick(&[1u64, 2, 4, 8, 16]), 1);
        let acc = flow
            .compile_with(&g, Mode::Pipelined, &OptConfig::optimized(), &plan)
            .expect("lenet always fits");
        let macs: u64 = acc
            .program
            .kernels
            .iter()
            .filter(|k| k.nest.macs_per_iter > 0)
            .map(|k| k.nest.out_elems * k.nest.reduction_size)
            .sum();
        assert_eq!(macs, g.total_macs(), "unroll factors changed total work");
    });
}

#[test]
fn prop_factor_divisibility_holds_for_all_plans() {
    prop::check("divisibility", |rng, _case| {
        let g = models::mobilenet_v1();
        let flow = Compiler::default();
        let mut plan = default_factors(&g);
        // Random (possibly-illegal) tiles: the flow must clamp to divisors
        // or reject — it must never emit a non-dividing unroll.
        let keys: Vec<_> = plan.group_tiles.keys().copied().collect();
        for k in keys {
            let t = (rng.range(1, 16), rng.range(1, 16));
            plan.group_tiles.insert(k, t);
        }
        if let Ok(acc) = flow.compile_with(&g, Mode::Folded, &OptConfig::optimized(), &plan) {
            for k in &acc.program.kernels {
                for l in &k.nest.loops {
                    assert_eq!(l.extent % l.unroll, 0, "{} {:?}", k.name, l.var);
                }
            }
        }
    });
}

#[test]
fn prop_more_unroll_never_slower_at_fixed_fmax() {
    // With the device clock pinned, more lanes can only reduce per-kernel
    // cycles (monotonicity of the compute model).
    prop::check("monotone_unroll", |rng, _case| {
        let g = models::lenet5();
        let flow = Compiler::default();
        let caps: Vec<u64> = vec![8, 32, 128, 512];
        let i = rng.below(caps.len() as u64 - 1) as usize;
        let (small, big) = (caps[i], caps[i + 1]);
        let mk = |cap| {
            let mut plan = default_factors(&g);
            plan.pipelined_cap = cap;
            flow.compile_with(&g, Mode::Pipelined, &OptConfig::optimized(), &plan).unwrap()
        };
        let a = mk(small);
        let b = mk(big);
        let cycles = |acc: &tvm_fpga_flow::flow::Accelerator| {
            acc.performance.per_layer.iter().map(|l| l.compute_cycles).sum::<f64>()
        };
        assert!(
            cycles(&b) <= cycles(&a) * 1.001,
            "cap {big} produced more cycles than cap {small}"
        );
    });
}

#[test]
fn prop_resources_monotone_in_tiles() {
    prop::check("monotone_resources", |rng, _case| {
        let g = models::resnet34();
        let dev = FpgaDevice::stratix10sx();
        let small_t = rng.range(1, 4);
        let plan_small = {
            let mut p = default_factors(&g);
            for (_, t) in p.group_tiles.iter_mut() {
                *t = (small_t, small_t);
            }
            p
        };
        let plan_big = {
            let mut p = default_factors(&g);
            for (_, t) in p.group_tiles.iter_mut() {
                *t = (small_t * 2, small_t * 2);
            }
            p
        };
        let build = |plan| {
            let (prog, _) = tvm_fpga_flow::flow::patterns::build_folded(&g, &OptConfig::optimized(), plan);
            aoc::resources::program_resources(&prog, &dev).total
        };
        let a = build(&plan_small);
        let b = build(&plan_big);
        assert!(b.dsps >= a.dsps);
        assert!(b.aluts >= a.aluts);
    });
}
