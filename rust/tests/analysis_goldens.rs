//! Golden design-rule-check regression gate.
//!
//! Runs the static analyzer (`LoweredProgram::analyze`) over every
//! evaluation network × execution mode × precision and byte-compares the
//! JSON report against checked-in goldens under
//! `rust/tests/goldens/analysis/`. A new or re-ordered lint then surfaces
//! as a reviewable diff instead of silently changing `fpga-flow check`.
//!
//! Blessing: when a golden file is missing (or `UPDATE_GOLDENS=1`), the
//! test writes the current output and passes — commit the generated
//! files. CI runs this test and then fails on any dirty/untracked golden
//! (`git diff` in the `check` job), so drift cannot land silently.

use std::path::PathBuf;

use tvm_fpga_flow::analysis::AnalysisReport;
use tvm_fpga_flow::flow::{CompileError, Compiler, Mode};
use tvm_fpga_flow::graph::models;
use tvm_fpga_flow::quant::QuantConfig;
use tvm_fpga_flow::texpr::Precision;

fn goldens_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/goldens/analysis")
}

/// Lower and analyze; an illegal plan still yields a diagnostic report
/// (that is the point of the analyzer), any other failure goldens as an
/// error object so broken combinations stay pinned too.
fn report_for(net: &str, mode: Mode, precision: Precision) -> Result<AnalysisReport, String> {
    let compiler = Compiler::default();
    let g = models::by_name(net).expect("known network");
    let mut session = compiler.graph(&g).mode(mode);
    if precision != Precision::F32 {
        session = session.with_quantization(QuantConfig::for_precision(precision));
    }
    match session.lower() {
        Ok(lowered) => Ok(lowered.analyze()),
        Err(e) => match e.downcast::<CompileError>() {
            Ok(CompileError::IllegalPlan { violations, .. }) => {
                Ok(AnalysisReport { diagnostics: violations })
            }
            Ok(other) => Err(other.to_string()),
            Err(e) => Err(e.to_string()),
        },
    }
}

fn render(net: &str, mode: Mode, precision: Precision) -> String {
    match report_for(net, mode, precision) {
        Ok(report) => report.to_json().to_string(),
        Err(e) => format!("{{\"error\": \"{e}\"}}"),
    }
}

fn check_golden(net: &str, mode: Mode, precision: Precision) {
    let got = render(net, mode, precision);
    let dir = goldens_dir();
    let path = dir.join(format!("{net}_{}_{}.json", mode.name(), precision.name()));
    let bless = std::env::var("UPDATE_GOLDENS").is_ok() || !path.exists();
    if bless {
        std::fs::create_dir_all(&dir).expect("create goldens dir");
        std::fs::write(&path, &got).expect("write golden");
        eprintln!("blessed golden {} — commit it", path.display());
        return;
    }
    let want = std::fs::read_to_string(&path).expect("read golden");
    assert_eq!(
        got,
        want,
        "design-rule report drifted from {} — if intentional, re-bless with UPDATE_GOLDENS=1",
        path.display()
    );
}

#[test]
fn golden_checks_all_networks_modes_precisions() {
    for net in ["lenet5", "mobilenet_v1", "resnet34"] {
        for mode in [Mode::Pipelined, Mode::Folded] {
            for precision in Precision::all() {
                check_golden(net, mode, precision);
            }
        }
    }
}

#[test]
fn paper_configurations_are_error_free_at_every_precision() {
    // Acceptance gate: the three evaluation networks in their paper
    // mapping (LeNet pipelined, the big nets folded) must carry zero
    // error-level diagnostics at f32, fp16, and int8.
    for (net, mode) in
        [("lenet5", Mode::Pipelined), ("mobilenet_v1", Mode::Folded), ("resnet34", Mode::Folded)]
    {
        for precision in Precision::all() {
            let report = report_for(net, mode, precision)
                .unwrap_or_else(|e| panic!("{net} {precision:?}: {e}"));
            assert_eq!(
                report.errors().count(),
                0,
                "{net} {} {}: {}",
                mode.name(),
                precision.name(),
                report.render()
            );
        }
    }
}

#[test]
fn check_reports_are_deterministic() {
    // The golden gate only works if repeated analyses render identically.
    for (net, mode) in [("lenet5", Mode::Pipelined), ("resnet34", Mode::Folded)] {
        for precision in [Precision::F32, Precision::Int8] {
            let a = render(net, mode, precision);
            let b = render(net, mode, precision);
            assert_eq!(a, b, "{net} {} analysis non-deterministic", precision.name());
        }
    }
}
