//! Synthetic dataset generation (the paper classifies MNIST / ImageNet;
//! throughput is value-independent, so deterministic synthetic frames
//! exercise the identical code path — DESIGN.md §Substitutions).

use crate::util::rng::Rng;

/// A batch of NCHW fp32 frames + synthetic labels.
#[derive(Debug, Clone)]
pub struct Batch {
    pub data: Vec<f32>,
    pub shape: (usize, usize, usize, usize),
    pub labels: Vec<u32>,
}

impl Batch {
    pub fn frames(&self) -> usize {
        self.shape.0
    }

    pub fn frame_elems(&self) -> usize {
        self.shape.1 * self.shape.2 * self.shape.3
    }

    pub fn frame(&self, i: usize) -> &[f32] {
        let n = self.frame_elems();
        &self.data[i * n..(i + 1) * n]
    }
}

/// MNIST-like frames: a bright digit-ish stroke pattern per class on a dark
/// background, plus noise — deterministic per (seed, index).
pub fn mnist_like(n: usize, hw: usize, seed: u64) -> Batch {
    let mut rng = Rng::new(seed);
    let mut data = vec![0f32; n * hw * hw];
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let class = (rng.below(10)) as u32;
        labels.push(class);
        let frame = &mut data[i * hw * hw..(i + 1) * hw * hw];
        // noise floor
        for v in frame.iter_mut() {
            *v = rng.f32() * 0.1;
        }
        // class-dependent stroke: a line whose angle/offset encodes class
        let off = 4 + (class as usize) % (hw / 2);
        for y in 2..hw - 2 {
            let x = (off + y * (1 + class as usize % 3)) % (hw - 2);
            frame[y * hw + x] = 0.9 + rng.f32() * 0.1;
            frame[y * hw + x + 1] = 0.7;
        }
    }
    Batch { data, shape: (n, 1, hw, hw), labels }
}

/// ImageNet-like frames: 3-channel noise with per-class channel bias.
pub fn imagenet_like(n: usize, hw: usize, classes: u32, seed: u64) -> Batch {
    let mut rng = Rng::new(seed);
    let c = 3usize;
    let mut data = vec![0f32; n * c * hw * hw];
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let class = rng.below(classes as u64) as u32;
        labels.push(class);
        for ch in 0..c {
            let bias = ((class as usize + ch) % 7) as f32 * 0.1;
            let frame = &mut data[(i * c + ch) * hw * hw..(i * c + ch + 1) * hw * hw];
            for v in frame.iter_mut() {
                *v = rng.normal() * 0.5 + bias;
            }
        }
    }
    Batch { data, shape: (n, c, hw, hw), labels }
}

/// Inputs matching a network's expected shape (mirrors
/// `python/compile/model.py::make_inputs` shapes, not values).
pub fn for_network(net: &str, frames: usize, seed: u64) -> Option<Batch> {
    match net {
        "lenet5" => Some(mnist_like(frames, 32, seed)),
        "mobilenet_v1" | "resnet34" => Some(imagenet_like(frames, 224, 1000, seed)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = mnist_like(4, 32, 7);
        let b = mnist_like(4, 32, 7);
        assert_eq!(a.data, b.data);
        assert_eq!(a.labels, b.labels);
        let c = mnist_like(4, 32, 8);
        assert_ne!(a.data, c.data);
    }

    #[test]
    fn shapes() {
        let b = for_network("lenet5", 3, 0).unwrap();
        assert_eq!(b.shape, (3, 1, 32, 32));
        assert_eq!(b.frame(2).len(), 1024);
        let b = for_network("resnet34", 2, 0).unwrap();
        assert_eq!(b.shape, (2, 3, 224, 224));
        assert!(for_network("vgg", 1, 0).is_none());
    }

    #[test]
    fn values_bounded() {
        let b = mnist_like(8, 32, 1);
        assert!(b.data.iter().all(|v| (0.0..=1.1).contains(v)));
        assert!(b.labels.iter().all(|&l| l < 10));
    }
}
