//! The "Intel AOC compiler" model (§II-B): given generated OpenCL-like
//! kernels, infer LSUs, analyze loop pipelining (II), estimate resources
//! and predict routing/f_max — everything the paper's flow gets back from
//! `aoc` + Quartus place-and-route, at zero hours instead of 3–12 (§IV-J).

pub mod fmax;
pub mod lsu;
pub mod pipeline;
pub mod report;
pub mod resources;

pub use fmax::{FmaxModel, RouteResult};
pub use lsu::{Lsu, LsuKind};
pub use pipeline::PipelineReport;
pub use report::{synthesize, SynthesisReport};
pub use resources::{KernelResources, ProgramResources};
