//! Loop-pipelining analysis: initiation interval (II) per kernel.
//!
//! AOC pipelines the innermost loop body; the achievable II is set by the
//! longest loop-carried dependence. The paper's pathologies (§IV):
//!
//! * global-memory accumulation (read-modify-write) carries the dependence
//!   through the external memory system — the load-use distance stalls the
//!   pipeline hard;
//! * even a private fp32 accumulator carries an ~8-cycle adder-latency
//!   RAW unless `-fp-relaxed` lets AOC build a reduction tree (OF, §IV-I);
//! * the separate activation loop (unfused) blocks pipelining across the
//!   producer/consumer pair entirely — it runs as a second pass.


use crate::schedule::{AppliedOpts, OptKind};
use crate::texpr::{Dir, LoopNest, MemSpace};

/// fp32 accumulator latency on S10 without relaxed ordering.
pub const FP_ACC_LATENCY: u64 = 8;
/// Effective loop-carried II of a global read-modify-write accumulation:
/// the LSU's store-to-load forwarding keeps the dependence at II≈1; the
/// real damage shows up as doubled LSU occupancy + traffic (memory model).
pub const GLOBAL_RMW_II: u64 = 1;

/// Pipelining report for one kernel.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PipelineReport {
    /// Initiation interval of the reduction loop.
    pub ii: u64,
    /// True when the epilogue runs as a separate (second) pass over the
    /// output — costs an extra `out_elems` cycles plus its own LSUs.
    pub separate_pass: bool,
}

/// Analyze the initiation interval of a scheduled nest.
pub fn analyze(nest: &LoopNest, opts: &AppliedOpts) -> PipelineReport {
    let has_reduction = nest.reduction_size > 1 && nest.macs_per_iter > 0;
    let ii = if !has_reduction {
        1
    } else if nest.accum_space == MemSpace::Global
        || nest.accesses.iter().any(|a| a.dir == Dir::ReadWrite && a.space == MemSpace::Global)
    {
        GLOBAL_RMW_II
    } else if opts.contains(OptKind::FloatOpt) {
        // -fp-relaxed: reduction tree / fused FMAC chain → II = 1.
        1
    } else {
        // Private register accumulation, strict fp order.
        FP_ACC_LATENCY
    };
    PipelineReport { ii, separate_pass: nest.separate_epilogue }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::schedule::Scheduler;
    use crate::texpr;

    fn nest() -> texpr::LoopNest {
        let g = models::lenet5();
        texpr::lower(&g.nodes[1], &g.nodes[0].shape)
    }

    #[test]
    fn naive_nest_has_global_rmw_ii() {
        let n = nest();
        let r = analyze(&n, &AppliedOpts::default());
        assert_eq!(r.ii, GLOBAL_RMW_II);
        assert!(r.separate_pass);
    }

    #[test]
    fn cached_write_without_of_pays_fp_latency() {
        let mut n = nest();
        let mut s = Scheduler::new(&mut n);
        s.cache_write().unwrap();
        let applied = s.finish();
        let r = analyze(&n, &applied);
        assert_eq!(r.ii, FP_ACC_LATENCY);
    }

    #[test]
    fn cached_write_plus_float_opt_reaches_ii_1() {
        let mut n = nest();
        let mut s = Scheduler::new(&mut n);
        s.cache_write().unwrap();
        s.applied.record(OptKind::FloatOpt);
        let applied = s.finish();
        assert_eq!(analyze(&n, &applied).ii, 1);
    }

    #[test]
    fn elementwise_kernels_pipeline_at_ii_1() {
        let g = models::mobilenet_v1();
        let bn = g.nodes.iter().find(|n| n.name == "conv1.bn").unwrap();
        let n = texpr::lower(bn, &g.nodes[bn.inputs[0]].shape);
        assert_eq!(analyze(&n, &AppliedOpts::default()).ii, 1);
    }

    #[test]
    fn fusing_clears_separate_pass() {
        let mut n = nest();
        let mut s = Scheduler::new(&mut n);
        s.fuse_epilogue().unwrap();
        let applied = s.finish();
        assert!(!analyze(&n, &applied).separate_pass);
    }
}
