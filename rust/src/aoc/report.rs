//! Synthesis report (the Quartus area-report analog; rows of Table II).


use crate::aoc::fmax::{self, FmaxModel, RouteResult};
use crate::aoc::lsu;
use crate::aoc::resources::{self, ProgramResources};
use crate::codegen::KernelProgram;
use crate::device::FpgaDevice;

/// Full synthesis outcome for a program on a device.
#[derive(Debug, Clone)]
pub struct SynthesisReport {
    pub program: String,
    pub device: String,
    pub resources: ProgramResources,
    pub fmax_mhz: f64,
    pub routed: bool,
    /// Widest LSU in the design (fanout/congestion driver).
    pub max_lsu_width_bytes: u64,
}

impl SynthesisReport {
    /// Table II row: `Logic (%) | BRAM (%) | DSP (%) | fmax`.
    pub fn table2_row(&self) -> (f64, f64, f64, f64) {
        (
            self.resources.utilization.logic_frac * 100.0,
            self.resources.utilization.bram_frac * 100.0,
            self.resources.utilization.dsp_frac * 100.0,
            self.fmax_mhz,
        )
    }
}

/// Synthesize: estimate resources, predict routing/f_max.
pub fn synthesize(
    prog: &KernelProgram,
    dev: &FpgaDevice,
    model: &FmaxModel,
) -> crate::Result<SynthesisReport> {
    let res = resources::program_resources(prog, dev);
    let max_lsu = prog
        .kernels
        .iter()
        .flat_map(|k| lsu::infer(&k.nest))
        .map(|l| l.width_bytes)
        .max()
        .unwrap_or(0);
    match fmax::predict(model, &res.utilization, max_lsu) {
        RouteResult::Routed(f) => Ok(SynthesisReport {
            program: prog.name.clone(),
            device: dev.name.clone(),
            resources: res,
            fmax_mhz: f,
            routed: true,
            max_lsu_width_bytes: max_lsu,
        }),
        RouteResult::RoutingFailure => Err(anyhow::anyhow!(
            "routing failure: design for '{}' exceeds device capacity/congestion \
             (logic {:.0}%, bram {:.0}%, dsp {:.0}%)",
            prog.name,
            res.utilization.logic_frac * 100.0,
            res.utilization.bram_frac * 100.0,
            res.utilization.dsp_frac * 100.0
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_program_synthesizes_at_shell() {
        let prog = KernelProgram { name: "empty".into(), kernels: vec![], channels: vec![], queues: 1 };
        let dev = FpgaDevice::stratix10sx();
        let rep = synthesize(&prog, &dev, &FmaxModel::default()).unwrap();
        assert!(rep.routed);
        assert!(rep.fmax_mhz > 200.0);
        let (logic, _, dsp, _) = rep.table2_row();
        assert!(logic > 10.0 && logic < 15.0); // shell only
        assert_eq!(dsp, 0.0);
    }
}
