//! LSU (load-store unit) inference — §II-B.
//!
//! AOC materializes an LSU per global access site. The type depends on the
//! access pattern and decides both throughput and resource cost:
//!
//! * **burst-coalesced**: stride-1 aligned accesses; one wide unit whose
//!   width grows with the unroll factor (the efficient case §IV-A aims for).
//! * **pipelined/streaming**: scalar in-order accesses.
//! * **replicated**: non-consecutive accesses under unrolling — one LSU per
//!   lane, "which incurs a significant cost in logic and BRAM" (§IV-A).
//!
//! AOC also infers a BRAM cache in front of small, reused read-only arrays;
//! we model that with a capacity threshold.


use crate::texpr::{Access, Dir, LoopNest, MemSpace, Pattern};

/// Cache-inference capacity threshold (AOC's const-cache is 64 KiB by
/// default on S10 BSPs).
pub const CACHE_BYTES: u64 = 64 * 1024;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LsuKind {
    /// Wide stride-1 unit; `width_bytes` per cycle.
    BurstCoalesced,
    /// Scalar pipelined unit.
    Pipelined,
    /// Replicated scalar units (`count` of them) + arbitration.
    Replicated,
    /// Backed by an inferred on-chip cache (small read-only array).
    Cached,
}

/// One inferred LSU instance group for an access site.
#[derive(Debug, Clone, PartialEq)]
pub struct Lsu {
    pub buffer: String,
    pub kind: LsuKind,
    pub dir: Dir,
    /// Parallel width in bytes per cycle this site can sustain.
    pub width_bytes: u64,
    /// Number of replicated units (1 unless `Replicated`).
    pub count: u64,
    /// Effective stall factor: average cycles per useful word, ≥ 1 —
    /// models DDR burst waste for windowed/strided patterns.
    pub stall_factor: f64,
}

/// Infer the LSUs of one kernel loop nest.
pub fn infer(nest: &LoopNest) -> Vec<Lsu> {
    nest.accesses
        .iter()
        .filter(|a| a.space == MemSpace::Global)
        .map(|a| infer_one(nest, a))
        .collect()
}

fn infer_one(nest: &LoopNest, a: &Access) -> Lsu {
    // Unroll factor effective at this access = product of unroll factors of
    // the loops that index it.
    let unroll: u64 = nest
        .loops
        .iter()
        .filter(|l| l.unroll > 1 && a.indexed_by.contains(&l.var))
        .map(|l| l.unroll)
        .product();
    let unroll = unroll.max(1);
    // Cross-domain boundary kernels pin per-access element types.
    let eb = a.elem.unwrap_or(nest.precision).bytes();

    // Read-only array small enough for AOC's inferred cache: after the
    // first pass it streams from BRAM regardless of pattern.
    if a.dir == Dir::Read && a.array_bytes <= CACHE_BYTES {
        return Lsu {
            buffer: a.buffer.clone(),
            kind: LsuKind::Cached,
            dir: a.dir,
            width_bytes: eb * unroll,
            count: 1,
            stall_factor: 1.0,
        };
    }

    match a.pattern {
        Pattern::Consecutive => Lsu {
            buffer: a.buffer.clone(),
            kind: if unroll > 1 { LsuKind::BurstCoalesced } else { LsuKind::Pipelined },
            dir: a.dir,
            width_bytes: eb * unroll,
            count: 1,
            stall_factor: 1.0,
        },
        Pattern::Strided => Lsu {
            buffer: a.buffer.clone(),
            kind: if unroll > 1 { LsuKind::Replicated } else { LsuKind::Pipelined },
            dir: a.dir,
            width_bytes: eb * unroll,
            count: unroll,
            // Strided bursts waste most of each 64B line (row-replay of
            // K>1 stride-1 windows); narrower elements waste more.
            stall_factor: 6.0 * 4.0 / eb as f64,
        },
        Pattern::Windowed => Lsu {
            buffer: a.buffer.clone(),
            kind: if unroll > 1 { LsuKind::Replicated } else { LsuKind::Pipelined },
            dir: a.dir,
            width_bytes: eb * unroll,
            count: unroll,
            // Windowed/data-dependent addressing defeats coalescing: a full
            // 64B DDR burst feeds one element.
            stall_factor: 64.0 / eb as f64 / 1.0,
        },
    }
}

/// Aggregate resource cost of a set of LSUs, in the units of
/// [`crate::aoc::resources`]. Calibrated against AOC area-report orders of
/// magnitude (see DESIGN.md §Calibration).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LsuCost {
    pub aluts: u64,
    pub ffs: u64,
    pub bram_blocks: u64,
}

pub fn cost(lsus: &[Lsu]) -> LsuCost {
    let mut c = LsuCost::default();
    for l in lsus {
        match l.kind {
            LsuKind::BurstCoalesced => {
                c.aluts += 1_500 + 12 * l.width_bytes;
                c.ffs += 3_000 + 24 * l.width_bytes;
                c.bram_blocks += 2 + l.width_bytes / 64;
            }
            LsuKind::Pipelined => {
                c.aluts += 400;
                c.ffs += 800;
            }
            LsuKind::Cached => {
                c.aluts += 900;
                c.ffs += 1_500;
                // cache data + tag storage
                c.bram_blocks += 4;
            }
            LsuKind::Replicated => {
                c.aluts += l.count * 900;
                c.ffs += l.count * 1_400;
                c.bram_blocks += l.count; // per-unit burst buffer
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::schedule::Scheduler;
    use crate::texpr::{self, LoopVar};

    fn resnet_conv3x3_nest() -> crate::texpr::LoopNest {
        let g = models::resnet34();
        let n = g.nodes.iter().find(|n| n.name == "s0b0.conv1").unwrap();
        texpr::lower(n, &g.nodes[n.inputs[0]].shape)
    }

    #[test]
    fn rolled_accesses_are_pipelined_or_cached() {
        let nest = resnet_conv3x3_nest();
        let lsus = infer(&nest);
        assert!(lsus.iter().all(|l| l.count == 1));
        // 64×64×9 weights = 147KB > cache → pipelined; ifmap 802KB → pipelined
        let w = lsus.iter().find(|l| l.buffer == "weights").unwrap();
        assert_eq!(w.kind, LsuKind::Pipelined);
    }

    #[test]
    fn small_weights_get_cached() {
        let g = models::lenet5();
        let c1 = &g.nodes[1];
        let nest = texpr::lower(c1, &g.nodes[0].shape);
        let lsus = infer(&nest);
        let w = lsus.iter().find(|l| l.buffer == "weights").unwrap();
        assert_eq!(w.kind, LsuKind::Cached); // 156 params → 624 B
    }

    #[test]
    fn unrolled_consecutive_becomes_burst_coalesced() {
        let mut nest = resnet_conv3x3_nest();
        let mut s = Scheduler::new(&mut nest);
        s.cache_write().unwrap();
        s.tile_and_unroll(LoopVar::InC, 16).unwrap();
        let lsus = infer(&nest);
        let w = lsus.iter().find(|l| l.buffer == "weights").unwrap();
        assert_eq!(w.kind, LsuKind::BurstCoalesced);
        assert_eq!(w.width_bytes, 64);
    }

    #[test]
    fn unrolled_windowed_replicates() {
        let g = models::resnet34();
        let c1 = &g.nodes[1]; // 7×7 s2 → Windowed ifmap
        let mut nest = texpr::lower(c1, &g.nodes[0].shape);
        let mut s = Scheduler::new(&mut nest);
        s.tile_and_unroll(LoopVar::KW, 7).unwrap();
        let lsus = infer(&nest);
        let i = lsus.iter().find(|l| l.buffer == "ifmap").unwrap();
        assert_eq!(i.kind, LsuKind::Replicated);
        assert_eq!(i.count, 7);
        assert!(i.stall_factor > 8.0);
    }

    #[test]
    fn replication_cost_scales_with_count() {
        let a = cost(&[Lsu { buffer: "x".into(), kind: LsuKind::Replicated, dir: Dir::Read, width_bytes: 4, count: 4, stall_factor: 16.0 }]);
        let b = cost(&[Lsu { buffer: "x".into(), kind: LsuKind::Replicated, dir: Dir::Read, width_bytes: 4, count: 16, stall_factor: 16.0 }]);
        assert!(b.aluts == 4 * a.aluts);
        assert!(b.bram_blocks == 4 * a.bram_blocks);
    }

    #[test]
    fn channelized_kernel_has_no_lsus() {
        let mut nest = resnet_conv3x3_nest();
        let mut s = Scheduler::new(&mut nest);
        s.channelize("ifmap");
        s.channelize("ofmap");
        s.cache_read("weights").unwrap();
        assert!(infer(&nest).is_empty());
    }
}
