//! f_max prediction.
//!
//! The paper (§V-F): "Routing congestion increases with larger tile sizes,
//! leading to large drops in f_max … the fanout from these LSUs can lead to
//! the routing failure." We model achieved clock as the shell base clock
//! degraded by (a) overall utilization and (b) a congestion knee once any
//! resource class crosses ~50%, plus (c) a fanout term from the widest LSU.
//! Constants are fitted to Table II's three (utilization, f_max) points —
//! see DESIGN.md §Calibration.


use crate::device::Utilization;

/// Fitted model constants.
#[derive(Debug, Clone, Copy)]
pub struct FmaxModel {
    /// Clock of a near-empty design (shell-limited).
    pub base_mhz: f64,
    /// Linear degradation per unit of max utilization.
    pub util_slope: f64,
    /// Congestion knee position (fraction of device).
    pub knee: f64,
    /// Additional slope beyond the knee.
    pub knee_slope: f64,
    /// MHz lost per doubling of the widest LSU beyond 64 B.
    pub fanout_per_doubling: f64,
    /// Floor: below this the router fails outright (returns None).
    pub min_mhz: f64,
}

impl Default for FmaxModel {
    fn default() -> Self {
        // Fit to Table II: (0.25, 218), (0.48, 187), (0.61, 125).
        FmaxModel {
            base_mhz: 250.0,
            util_slope: 134.0,
            knee: 0.50,
            knee_slope: 400.0,
            fanout_per_doubling: 2.0,
            min_mhz: 60.0,
        }
    }
}

/// Routing outcome.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RouteResult {
    /// Achieved clock in MHz.
    Routed(f64),
    /// Congestion-driven routing failure (§V-F: "can also lead to routing
    /// failure before utilizing all DSPs").
    RoutingFailure,
}

impl RouteResult {
    pub fn mhz(&self) -> Option<f64> {
        match self {
            RouteResult::Routed(m) => Some(*m),
            RouteResult::RoutingFailure => None,
        }
    }
}

/// Predict f_max for a design with the given utilization and widest LSU.
pub fn predict(model: &FmaxModel, util: &Utilization, max_lsu_width_bytes: u64) -> RouteResult {
    if !util.fits() {
        return RouteResult::RoutingFailure;
    }
    let u = util.logic_frac.max(util.bram_frac); // congestion-relevant max
    let mut f = model.base_mhz - model.util_slope * u;
    if u > model.knee {
        f -= model.knee_slope * (u - model.knee);
    }
    if max_lsu_width_bytes > 64 {
        let doublings = ((max_lsu_width_bytes as f64) / 64.0).log2();
        f -= model.fanout_per_doubling * doublings;
    }
    if f < model.min_mhz {
        RouteResult::RoutingFailure
    } else {
        RouteResult::Routed(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn util(logic: f64, bram: f64, dsp: f64) -> Utilization {
        Utilization { logic_frac: logic, bram_frac: bram, dsp_frac: dsp, ff_frac: logic * 0.8 }
    }

    #[test]
    fn fit_matches_table2_lenet() {
        let m = FmaxModel::default();
        let f = predict(&m, &util(0.25, 0.19, 0.05), 16).mhz().unwrap();
        assert!((f - 218.0).abs() < 8.0, "{f}");
    }

    #[test]
    fn fit_matches_table2_mobilenet() {
        let m = FmaxModel::default();
        let f = predict(&m, &util(0.46, 0.48, 0.15), 128).mhz().unwrap();
        assert!((f - 187.0).abs() < 8.0, "{f}");
    }

    #[test]
    fn fit_matches_table2_resnet() {
        let m = FmaxModel::default();
        let f = predict(&m, &util(0.59, 0.61, 0.16), 128).mhz().unwrap();
        assert!((f - 125.0).abs() < 10.0, "{f}");
    }

    #[test]
    fn over_capacity_fails_routing() {
        let m = FmaxModel::default();
        assert_eq!(predict(&m, &util(1.02, 0.3, 0.1), 16), RouteResult::RoutingFailure);
    }

    #[test]
    fn extreme_congestion_fails_routing() {
        let m = FmaxModel::default();
        // 95% logic blows past the knee → below min clock → fail.
        assert_eq!(predict(&m, &util(0.97, 0.9, 0.5), 1024), RouteResult::RoutingFailure);
    }

    #[test]
    fn fmax_monotonically_decreases_with_utilization() {
        let m = FmaxModel::default();
        let mut prev = f64::INFINITY;
        for u in [0.1, 0.3, 0.5, 0.6, 0.7] {
            let f = predict(&m, &util(u, u, u), 16).mhz().unwrap();
            assert!(f < prev);
            prev = f;
        }
    }
}
