//! Resource estimation: ALUTs / FFs / DSPs / BRAM per kernel and per
//! program, on the Stratix 10SX model.
//!
//! Mechanisms follow the paper + Intel best-practices guide: DSPs replicate
//! with the unroll product (§IV-A), LSUs cost logic and BRAM (§II-B),
//! banked local buffers replicate BRAM with the unroll factor and add
//! arbitration logic (§IV-A), channels are registers/FIFOs (§IV-E), and the
//! board shell consumes a fixed slice. Constants are calibrated so the
//! three networks land near the paper's Table II (see EXPERIMENTS.md).


use crate::aoc::lsu;
use crate::codegen::{Kernel, KernelProgram};
use crate::device::{FpgaDevice, Utilization};
use crate::schedule::OptKind;
use crate::texpr::{Dir, MemSpace};

/// Per-kernel resource estimate.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct KernelResources {
    pub aluts: u64,
    pub ffs: u64,
    pub dsps: u64,
    pub bram_blocks: u64,
}

impl KernelResources {
    pub fn add(&mut self, o: KernelResources) {
        self.aluts += o.aluts;
        self.ffs += o.ffs;
        self.dsps += o.dsps;
        self.bram_blocks += o.bram_blocks;
    }
}

/// Whole-program estimate + derived utilization.
#[derive(Debug, Clone)]
pub struct ProgramResources {
    pub per_kernel: Vec<(String, KernelResources)>,
    pub total: KernelResources,
    pub utilization: Utilization,
}

// ---- calibrated cost constants -------------------------------------------

/// Fixed kernel overhead: interface, loop control, dispatch.
const KERNEL_BASE_ALUT: u64 = 6_000;
const KERNEL_BASE_FF: u64 = 11_000;
/// Loop-control logic per loop level.
const LOOP_ALUT: u64 = 220;
/// Glue logic per unrolled MAC lane (operand muxing, pipeline regs) when
/// -fp-relaxed/-fpc fuse the FMAC into the DSP.
const LANE_ALUT_OF: u64 = 560;
/// Without OF the fp32 add spills into soft logic.
const LANE_ALUT_NO_OF: u64 = 1_100;
const LANE_FF_FACTOR: u64 = 2;
/// Extra control for dynamic (parameterized) loop bounds, per dynamic loop.
const DYN_LOOP_ALUT: u64 = 1_800;
/// BRAM banking per MAC lane: folded kernels double-buffer banked operand
/// tiles (9/2 = 4.5 blocks/lane); pipelined kernels keep shallow
/// register-fed banks (2 blocks/lane).
const LANE_BRAM_X2_DYNAMIC: u64 = 9;
const LANE_BRAM_X2_STATIC: u64 = 4;
/// Interconnect/control mux per extra layer a parameterized kernel serves
/// (runtime shape dispatch, §IV-H).
const PARAM_LAYER_ALUT: u64 = 3_000;
const PARAM_LAYER_BRAM: u64 = 8;

/// Estimate one kernel.
pub fn kernel_resources(k: &Kernel) -> KernelResources {
    let nest = &k.nest;
    let lanes = nest.total_unroll().max(1) * nest.macs_per_iter.max(if nest.reduction_size > 1 { 1 } else { 0 });
    let of = k.applied.contains(OptKind::FloatOpt);

    let mut r = KernelResources {
        aluts: KERNEL_BASE_ALUT + LOOP_ALUT * nest.loops.len() as u64,
        ffs: KERNEL_BASE_FF,
        dsps: 0,
        bram_blocks: 0,
    };

    // DSPs: one hard-FP DSP per fp32 MAC lane with OF (reduced precisions
    // pack 2 MACs per DSP, §VII extension); without OF the multiplier
    // still maps to a DSP but the adder costs soft logic.
    if nest.macs_per_iter > 0 {
        let packing = nest.precision.macs_per_dsp();
        r.dsps = nest.total_unroll().div_ceil(packing);
        let lane_alut = if of { LANE_ALUT_OF } else { LANE_ALUT_NO_OF }
            * nest.precision.bytes() / 4;
        r.aluts += lane_alut.max(100) * nest.total_unroll();
        r.ffs += lane_alut.max(100) * LANE_FF_FACTOR * nest.total_unroll();
    } else {
        // Non-MAC lanes (pool compare/add) are pure logic.
        r.aluts += 150 * nest.total_unroll();
        r.ffs += 300 * nest.total_unroll();
    }

    // Banked local operand buffers for unrolled lanes.
    let dynamic_kernel = nest.loops.iter().any(|l| l.dynamic);
    if lanes > 1 {
        let per_lane_x2 = if dynamic_kernel { LANE_BRAM_X2_DYNAMIC } else { LANE_BRAM_X2_STATIC };
        // Operand banks shrink with element width (min 1 block per bank).
        r.bram_blocks += (lanes * per_lane_x2 / 2) * nest.precision.bytes() / 4
            + if nest.precision.bytes() < 4 { lanes / 4 } else { 0 };
    }

    // Parameterized kernels serving many layers pay shape-dispatch mux +
    // per-layer descriptor storage.
    if k.layers.len() > 1 {
        let extra = (k.layers.len() - 1) as u64;
        r.aluts += PARAM_LAYER_ALUT * extra;
        r.ffs += PARAM_LAYER_ALUT * extra;
        r.bram_blocks += PARAM_LAYER_BRAM * extra;
    }

    // Zero-skipping control (sparse datapaths, §VII #2): per-lane index
    // decode + weight-select muxing (HPIPE-style).
    if nest.weight_density < 1.0 && nest.macs_per_iter > 0 {
        r.aluts += 180 * nest.total_unroll();
        r.ffs += 260 * nest.total_unroll();
    }

    // Dynamic bounds (parameterized kernels).
    let dyn_loops = nest.loops.iter().filter(|l| l.dynamic).count() as u64;
    r.aluts += DYN_LOOP_ALUT * dyn_loops;
    r.ffs += DYN_LOOP_ALUT * dyn_loops;

    // LSUs.
    let lsus = lsu::infer(nest);
    let lc = lsu::cost(&lsus);
    r.aluts += lc.aluts;
    r.ffs += lc.ffs;
    r.bram_blocks += lc.bram_blocks;

    // Separate (unfused) epilogue pass: its own loop + temp-array LSUs.
    if nest.separate_epilogue && !nest.epilogue.is_empty() {
        r.aluts += 2_500 + 2 * 400;
        r.ffs += 4_000;
    }

    // Local buffers from cache_read (e.g. weight stash in pipelined mode):
    // data bits + banking by the reduction unroll.
    for a in &nest.accesses {
        if a.space == MemSpace::Local && a.dir == Dir::Read {
            // The stash holds the array (or the tile the schedule sized via
            // `array_bytes`), not the per-frame traffic.
            let bits = a.array_bytes.min(4 * 1024 * 1024) * 8;
            let blocks = bits.div_ceil(20 * 1024);
            let banks = nest.reduction_unroll().max(1).min(64);
            r.bram_blocks += blocks.max(banks);
            r.aluts += 40 * banks; // arbitration
        }
    }

    r
}

/// Estimate a whole program on a device.
pub fn program_resources(prog: &KernelProgram, dev: &FpgaDevice) -> ProgramResources {
    let mut per_kernel = Vec::with_capacity(prog.kernels.len());
    let mut total = KernelResources::default();

    // Board shell / BSP.
    let shell = KernelResources {
        aluts: (dev.aluts as f64 * dev.shell_overhead_frac) as u64,
        ffs: (dev.ffs as f64 * dev.shell_overhead_frac) as u64,
        dsps: 0,
        bram_blocks: (dev.bram_blocks() as f64 * dev.shell_overhead_frac) as u64,
    };
    total.add(shell);
    per_kernel.push(("(shell)".into(), shell));

    for k in &prog.kernels {
        let r = kernel_resources(k);
        total.add(r);
        per_kernel.push((k.name.clone(), r));
    }

    // Channel FIFOs: registers for shallow, BRAM for deep (§IV-E). Depth
    // is in elements, so narrow (quantized) streams need fewer bits.
    for ch in &prog.channels {
        let bits = ch.depth * 8 * ch.elem.bytes();
        let r = if ch.depth <= 16 {
            KernelResources { aluts: 80, ffs: ch.depth * 8 * ch.elem.bytes(), dsps: 0, bram_blocks: 0 }
        } else {
            KernelResources {
                aluts: 250,
                ffs: 500,
                dsps: 0,
                bram_blocks: bits.div_ceil(20 * 1024).max(1),
            }
        };
        total.add(r);
    }

    // Command-queue / host interface logic per extra queue (CE, §IV-G).
    if prog.queues > 1 {
        let q = KernelResources {
            aluts: 1_200 * prog.queues as u64,
            ffs: 2_400 * prog.queues as u64,
            dsps: 0,
            bram_blocks: 0,
        };
        total.add(q);
    }

    let utilization = Utilization {
        logic_frac: total.aluts as f64 / dev.aluts as f64,
        ff_frac: total.ffs as f64 / dev.ffs as f64,
        dsp_frac: total.dsps as f64 / dev.dsps as f64,
        bram_frac: total.bram_blocks as f64 / dev.bram_blocks() as f64,
    };

    ProgramResources { per_kernel, total, utilization }
}

/// Resource dimensions over the device budget, as `(FPGA resource name,
/// fraction)` — the analyzer's FLOW030 source (§IV-J rule 3). Names use
/// the device families' own vocabulary (ALM/FF/DSP/BRAM) so diagnostics
/// say *which* budget was blown. Empty iff `u.fits()`.
pub fn over_budget(u: &Utilization) -> Vec<(&'static str, f64)> {
    [
        ("ALM", u.logic_frac),
        ("FF", u.ff_frac),
        ("DSP", u.dsp_frac),
        ("BRAM", u.bram_frac),
    ]
    .into_iter()
    .filter(|&(_, f)| f > 1.0)
    .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::schedule::Scheduler;
    use crate::texpr::{self, LoopVar};

    fn mk_kernel(unroll_ic: Option<u64>, of: bool) -> Kernel {
        let g = models::resnet34();
        let n = g.nodes.iter().find(|n| n.name == "s0b0.conv1").unwrap();
        let mut nest = texpr::lower(n, &g.nodes[n.inputs[0]].shape);
        let mut s = Scheduler::new(&mut nest);
        s.cache_write().unwrap();
        if let Some(f) = unroll_ic {
            s.tile_and_unroll(LoopVar::InC, f).unwrap();
        }
        if of {
            s.applied.record(OptKind::FloatOpt);
        }
        let applied = s.finish();
        Kernel { id: 0, name: "k".into(), nest, applied, autorun: false, layers: vec![n.id], absorbed: vec![], group: None, queue: 0 }
    }

    #[test]
    fn dsps_equal_unroll_product() {
        assert_eq!(kernel_resources(&mk_kernel(None, true)).dsps, 1);
        assert_eq!(kernel_resources(&mk_kernel(Some(16), true)).dsps, 16);
    }

    #[test]
    fn float_opt_saves_logic() {
        let with = kernel_resources(&mk_kernel(Some(16), true));
        let without = kernel_resources(&mk_kernel(Some(16), false));
        assert!(without.aluts > with.aluts);
    }

    #[test]
    fn program_includes_shell() {
        let dev = FpgaDevice::stratix10sx();
        let prog = KernelProgram { name: "t".into(), kernels: vec![mk_kernel(Some(16), true)], channels: vec![], queues: 1 };
        let r = program_resources(&prog, &dev);
        assert!(r.utilization.logic_frac > dev.shell_overhead_frac);
        assert!(r.utilization.fits());
    }

    #[test]
    fn deep_channels_consume_bram() {
        let dev = FpgaDevice::stratix10sx();
        let mk = |depth| KernelProgram {
            name: "t".into(),
            kernels: vec![],
            channels: vec![crate::codegen::Channel::f32("c", 0, 1, depth)],
            queues: 1,
        };
        let shallow = program_resources(&mk(8), &dev);
        let deep = program_resources(&mk(100_000), &dev);
        assert!(deep.total.bram_blocks > shallow.total.bram_blocks);
    }

    #[test]
    fn int8_channels_and_kernels_shrink_resources() {
        use crate::texpr::Precision;
        let dev = FpgaDevice::stratix10sx();
        let mk = |elem| KernelProgram {
            name: "t".into(),
            kernels: vec![],
            channels: vec![crate::codegen::Channel {
                name: "c".into(),
                from_kernel: 0,
                to_kernel: 1,
                depth: 100_000,
                elem,
            }],
            queues: 1,
        };
        let wide = program_resources(&mk(Precision::F32), &dev);
        let narrow = program_resources(&mk(Precision::Int8), &dev);
        assert!(
            narrow.total.bram_blocks < wide.total.bram_blocks,
            "int8 FIFO {} vs f32 {}",
            narrow.total.bram_blocks,
            wide.total.bram_blocks
        );

        // A quantized MAC kernel packs 2 MACs/DSP and narrows its banks.
        let mut kf = mk_kernel(Some(16), true);
        let mut ki = mk_kernel(Some(16), true);
        crate::schedule::Scheduler::new(&mut ki.nest).quantize(Precision::Int8);
        crate::schedule::Scheduler::new(&mut kf.nest).quantize(Precision::F32);
        let rf = kernel_resources(&kf);
        let ri = kernel_resources(&ki);
        assert_eq!(ri.dsps * 2, rf.dsps);
        assert!(ri.bram_blocks <= rf.bram_blocks);
        assert!(ri.aluts < rf.aluts);
    }

    #[test]
    fn unrolling_grows_every_resource() {
        let small = kernel_resources(&mk_kernel(Some(4), true));
        let big = kernel_resources(&mk_kernel(Some(64), true));
        assert!(big.aluts > small.aluts);
        assert!(big.dsps > small.dsps);
        assert!(big.bram_blocks > small.bram_blocks);
    }
}
