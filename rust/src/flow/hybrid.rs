//! Mixed pipelined/folded deployment — the paper's §V-F mitigation
//! ("exploring deployments that use a mix of pipelined and folded
//! execution") and §III's observation that a fully-pipelined large network
//! cannot hold all activations on chip.
//!
//! The graph is cut at a topological point: the *front* (large feature
//! maps, small channel counts — where global round-trips hurt most) runs
//! pipelined with channels; the *back* runs folded with parameterized
//! kernels. The two sections decouple through a global-memory staging
//! buffer, so steady-state throughput is `1 / max(front interval, back
//! frame time)` while both sections must co-reside on the device.

use crate::aoc::SynthesisReport;
use crate::graph::{Graph, GraphBuilder, Op, Shape};
use crate::sim::{folded, pipelined};

use super::patterns::{self, FactorPlan, OptConfig};
use super::{Compiler, Flow};

/// A compiled hybrid deployment.
#[derive(Debug, Clone)]
pub struct HybridAccelerator {
    pub network: String,
    /// Number of graph nodes executed pipelined (prefix length).
    pub cut: usize,
    pub fps: f64,
    pub front_interval_s: f64,
    pub back_time_s: f64,
    pub synthesis: SynthesisReport,
}

/// Candidate cut points: after each spatial-reduction node (pool or
/// strided conv) the feature map shrinks — natural staging boundaries.
pub fn cut_points(graph: &Graph) -> Vec<usize> {
    let mut cuts = Vec::new();
    for n in graph.topo() {
        let shrinks = match n.op {
            Op::MaxPool { stride, .. } | Op::AvgPool { stride, .. } => stride > 1,
            Op::Conv2d { stride, .. } | Op::DepthwiseConv2d { stride, .. } => stride > 1,
            _ => false,
        };
        // Only cut on the linear spine (single consumer) to keep both
        // sections well-formed.
        if shrinks && n.id + 1 < graph.nodes.len() {
            cuts.push(n.id + 1);
        }
    }
    cuts
}

/// Split `graph` into a front prefix `[0, cut)` + back suffix; the back
/// gets a fresh Input node shaped like the cut tensor. Returns None when
/// the cut crosses a residual edge (not a clean frontier).
pub fn split(graph: &Graph, cut: usize) -> Option<(Graph, Graph)> {
    if cut == 0 || cut >= graph.nodes.len() {
        return None;
    }
    // Frontier must be exactly one value: the output of node cut-1, and no
    // back node may read any front node other than cut-1.
    for n in &graph.nodes[cut..] {
        for &i in &n.inputs {
            if i < cut && i != cut - 1 {
                return None;
            }
        }
    }

    let front = rebuild_range(graph, 0, cut, None)?;
    let boundary_shape = graph.nodes[cut - 1].shape.clone();
    let back = rebuild_range(graph, cut, graph.nodes.len(), Some(boundary_shape))?;
    Some((front, back))
}

fn rebuild_range(graph: &Graph, lo: usize, hi: usize, input_shape: Option<Shape>) -> Option<Graph> {
    let mut map: Vec<Option<usize>> = vec![None; graph.nodes.len()];
    let mut b: Option<GraphBuilder> = None;
    if let Some(shape) = input_shape {
        let (builder, id) = GraphBuilder::new(format!("{}_part", graph.name), shape);
        b = Some(builder);
        if lo > 0 {
            map[lo - 1] = Some(id);
        }
    }
    let mut last = 0usize;
    for node in &graph.nodes[lo..hi] {
        match node.op {
            Op::Input => {
                let (builder, id) = GraphBuilder::new(format!("{}_part", graph.name), node.shape.clone());
                b = Some(builder);
                map[node.id] = Some(id);
            }
            _ => {
                let builder = b.as_mut()?;
                let inputs: Vec<usize> = node.inputs.iter().map(|&i| map[i]).collect::<Option<_>>()?;
                let id = builder.add(node.name.clone(), node.op.clone(), &inputs);
                map[node.id] = Some(id);
            }
        }
        last = map[node.id]?;
    }
    let g = b?.finish(last);
    g.validate().ok()?;
    Some(g)
}


impl Compiler {
    /// Compile a hybrid deployment with an explicit cut.
    pub fn compile_hybrid(
        &self,
        graph: &Graph,
        cut: usize,
        cfg: &OptConfig,
        plan: &FactorPlan,
    ) -> crate::Result<HybridAccelerator> {
        cfg.validate()?;
        let (front_g, back_g) =
            split(graph, cut).ok_or_else(|| anyhow::anyhow!("cut {cut} is not a clean frontier"))?;

        let (front_prog, _front_work) = patterns::build_pipelined(&front_g, cfg, plan);
        let (back_prog, back_work) = patterns::build_folded(&back_g, cfg, plan);

        // Co-residency: merge programs for the resource/fmax check.
        let mut merged = front_prog.clone();
        merged.name = format!("{}_hybrid@{cut}", graph.name);
        let base = merged.kernels.len();
        for mut k in back_prog.kernels.clone() {
            k.id += base;
            k.queue += merged.queues;
            merged.kernels.push(k);
        }
        merged.queues += back_prog.queues;
        let (synthesis, _) = self.synthesize_memoized(&merged)?;
        let fmax = synthesis.fmax_mhz;

        let dev = &self.target.device;
        let front_perf = pipelined::simulate(&front_prog, dev, fmax, &self.host);
        let back_perf = folded::simulate(&back_prog, &back_work, dev, fmax, &self.host);

        // Sections overlap across frames (staged through global memory):
        // throughput is governed by the slower section.
        let interval = front_perf.frame_time_s.max(back_perf.frame_time_s);
        Ok(HybridAccelerator {
            network: graph.name.clone(),
            cut,
            fps: 1.0 / interval,
            front_interval_s: front_perf.frame_time_s,
            back_time_s: back_perf.frame_time_s,
            synthesis,
        })
    }

    /// Search all clean cut points; return the best hybrid (if any beats
    /// nothing — the caller compares against pure modes).
    pub fn best_hybrid(
        &self,
        graph: &Graph,
        cfg: &OptConfig,
        plan: &FactorPlan,
    ) -> Option<HybridAccelerator> {
        cut_points(graph)
            .into_iter()
            .filter_map(|cut| self.compile_hybrid(graph, cut, cfg, plan).ok())
            .max_by(|a, b| a.fps.total_cmp(&b.fps))
    }
}

impl Flow {
    /// Deprecated shim over [`Compiler::compile_hybrid`].
    #[deprecated(since = "0.2.0", note = "use Compiler::compile_hybrid")]
    pub fn compile_hybrid(
        &self,
        graph: &Graph,
        cut: usize,
        cfg: &OptConfig,
        plan: &FactorPlan,
    ) -> crate::Result<HybridAccelerator> {
        Compiler::from_parts(self.device.clone(), self.fmax_model, self.host)
            .compile_hybrid(graph, cut, cfg, plan)
    }

    /// Deprecated shim over [`Compiler::best_hybrid`].
    #[deprecated(since = "0.2.0", note = "use Compiler::best_hybrid")]
    pub fn best_hybrid(
        &self,
        graph: &Graph,
        cfg: &OptConfig,
        plan: &FactorPlan,
    ) -> Option<HybridAccelerator> {
        Compiler::from_parts(self.device.clone(), self.fmax_model, self.host)
            .best_hybrid(graph, cfg, plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::{default_factors, Compiler, Mode, OptLevel};
    use crate::graph::models;

    #[test]
    fn mobilenet_splits_cleanly() {
        let g = models::mobilenet_v1();
        let cuts = cut_points(&g);
        assert!(!cuts.is_empty());
        let (front, back) = split(&g, cuts[1]).expect("clean cut");
        assert_eq!(front.total_macs() + back.total_macs(), g.total_macs());
        front.validate().unwrap();
        back.validate().unwrap();
    }

    #[test]
    fn resnet_residual_cuts_rejected_or_clean() {
        let g = models::resnet34();
        // Splitting inside a residual block must be rejected (the shortcut
        // edge crosses the cut); boundary cuts succeed.
        let mid_block = g.nodes.iter().find(|n| n.name == "s0b0.conv2").unwrap().id;
        assert!(split(&g, mid_block).is_none());
    }

    #[test]
    fn hybrid_mobilenet_compiles_and_reports() {
        let compiler = Compiler::default();
        let g = models::mobilenet_v1();
        let plan = default_factors(&g);
        let hybrid = compiler.best_hybrid(&g, &OptConfig::optimized(), &plan);
        let Some(h) = hybrid else {
            // Acceptable outcome: no clean cut fits on the device.
            return;
        };
        assert!(h.fps > 0.0);
        assert!(h.front_interval_s > 0.0 && h.back_time_s > 0.0);
        // Compare against pure folded for the record.
        let folded = compiler.compile(&g, Mode::Folded, OptLevel::Optimized).unwrap();
        println!("hybrid {} FPS vs folded {} FPS", h.fps, folded.performance.fps);
    }

    #[test]
    fn bad_cut_errors() {
        let compiler = Compiler::default();
        let g = models::mobilenet_v1();
        let plan = default_factors(&g);
        assert!(compiler.compile_hybrid(&g, 0, &OptConfig::optimized(), &plan).is_err());
        assert!(compiler.compile_hybrid(&g, 10_000, &OptConfig::optimized(), &plan).is_err());
    }
}
