//! JSON emission for compiled accelerators and DSE results —
//! machine-readable reports for CI dashboards and the CLI's `--json` flag
//! (serde is unavailable offline; uses the in-crate `util::json`).

use std::collections::BTreeMap;

use crate::dse::{ParetoPoint, PrecisionFront};
use crate::pass::PassTrace;
use crate::util::json::Json;

use super::multi::PipelinePlan;
use super::Accelerator;

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn s(v: impl Into<String>) -> Json {
    Json::Str(v.into())
}

impl PassTrace {
    /// Machine-readable trace: one entry per pass in application order,
    /// with the matched count, the skip reason (when blocked) and the
    /// non-zero IR-diff counters.
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.records
                .iter()
                .map(|r| {
                    let mut m = BTreeMap::new();
                    m.insert("pass".into(), s(r.name.clone()));
                    m.insert("abbrev".into(), s(r.abbrev));
                    m.insert("level".into(), s(r.level.name()));
                    m.insert("equivalence".into(), s(r.equivalence.name()));
                    match &r.skipped {
                        Some(reason) => {
                            m.insert("skipped".into(), s(reason.clone()));
                        }
                        None => {
                            m.insert("matched".into(), num(r.matched as f64));
                            let mut d = BTreeMap::new();
                            for (k, v) in r.diff.entries() {
                                d.insert(k.to_string(), num(v as f64));
                            }
                            m.insert("diff".into(), Json::Obj(d));
                        }
                    }
                    Json::Obj(m)
                })
                .collect(),
        )
    }
}

impl Accelerator {
    /// Full machine-readable report.
    pub fn to_json(&self) -> Json {
        let mut root = BTreeMap::new();
        root.insert("network".into(), s(self.network.clone()));
        root.insert("mode".into(), s(self.mode.name()));
        root.insert("precision".into(), s(self.precision.name()));
        root.insert("flops_per_frame".into(), num(self.flops_per_frame as f64));
        root.insert(
            "applied".into(),
            Json::Arr(self.applied.iter().map(|o| s(o.abbrev())).collect()),
        );
        if !self.pass_trace.records.is_empty() {
            root.insert("pass_trace".into(), self.pass_trace.to_json());
        }
        // Static design-rule report (severity counts + every finding with
        // its FLOW code and span) — legality violations used to be dropped
        // from the JSON report entirely.
        root.insert("diagnostics".into(), self.analysis.to_json());
        if let Some(q) = &self.quant {
            let mut m = BTreeMap::new();
            m.insert("precision".into(), s(q.precision.name()));
            m.insert("scheme".into(), s(q.scheme.name()));
            m.insert("calibrator".into(), s(q.calibrator.clone()));
            m.insert("calibration_frames".into(), num(q.calibration_frames as f64));
            m.insert("quantize_nodes".into(), num(q.stats.quantize_nodes as f64));
            m.insert("dequantize_nodes".into(), num(q.stats.dequantize_nodes as f64));
            m.insert("folded_pairs".into(), num(q.stats.folded_pairs as f64));
            m.insert("top1_agreement".into(), num(q.accuracy.top1_agreement));
            m.insert("accuracy_delta_pp".into(), num(q.accuracy.delta_pp));
            m.insert("accuracy_estimated".into(), Json::Bool(q.accuracy.estimated));
            root.insert("quant".into(), Json::Obj(m));
        }

        let u = &self.synthesis.resources.utilization;
        let mut synth = BTreeMap::new();
        synth.insert("fmax_mhz".into(), num(self.synthesis.fmax_mhz));
        synth.insert("logic_frac".into(), num(u.logic_frac));
        synth.insert("bram_frac".into(), num(u.bram_frac));
        synth.insert("dsp_frac".into(), num(u.dsp_frac));
        synth.insert("max_lsu_width_bytes".into(), num(self.synthesis.max_lsu_width_bytes as f64));
        root.insert("synthesis".into(), Json::Obj(synth));

        let mut perf = BTreeMap::new();
        perf.insert("fps".into(), num(self.performance.fps));
        perf.insert("frame_time_s".into(), num(self.performance.frame_time_s));
        perf.insert("bottleneck".into(), s(self.performance.bottleneck.clone()));
        perf.insert("host_frac".into(), num(self.performance.host_frac));
        perf.insert("gflops".into(), num(self.gflops()));
        root.insert("performance".into(), Json::Obj(perf));

        root.insert(
            "kernels".into(),
            Json::Arr(
                self.program
                    .kernels
                    .iter()
                    .map(|k| {
                        let mut m = BTreeMap::new();
                        m.insert("name".into(), s(k.name.clone()));
                        m.insert("lanes".into(), num(k.nest.total_unroll() as f64));
                        m.insert("autorun".into(), Json::Bool(k.autorun));
                        m.insert("layers".into(), num(k.layers.len() as f64));
                        if let Some(g) = k.group {
                            m.insert("group".into(), s(g.to_string()));
                        }
                        Json::Obj(m)
                    })
                    .collect(),
            ),
        );
        Json::Obj(root)
    }

    /// [`Accelerator::to_json`] plus an `observability` section: the
    /// global metrics snapshot and (when a trace is supplied) a
    /// per-category span summary. Kept separate from `to_json` so the
    /// golden reports stay byte-identical whether or not a run traced.
    pub fn to_json_with_observability(&self, trace: Option<&crate::obs::Trace>) -> Json {
        let mut j = self.to_json();
        if let Json::Obj(root) = &mut j {
            root.insert("observability".into(), crate::obs::observability_json(trace));
        }
        j
    }
}

impl PipelinePlan {
    /// Machine-readable pipeline report (`fpga-flow partition --json`):
    /// the partition decision (cuts, per-stage cost-model terms, the
    /// bottleneck stage), the pass trace that recorded it, pipeline-level
    /// diagnostics, and each stage's full accelerator report.
    pub fn to_json(&self) -> Json {
        let mut root = BTreeMap::new();
        root.insert("network".into(), s(self.network.clone()));
        root.insert("kind".into(), s("pipeline"));
        root.insert("stages".into(), num(self.stages.len() as f64));
        root.insert(
            "cuts".into(),
            Json::Arr(self.cuts.iter().map(|&c| num(c as f64)).collect()),
        );
        root.insert("fps".into(), num(self.fps));
        root.insert("bottleneck_stage".into(), num(self.bottleneck as f64));
        let mut link = BTreeMap::new();
        link.insert("bandwidth_bytes_per_s".into(), num(self.link.bandwidth_bytes_per_s));
        link.insert("latency_s".into(), num(self.link.latency_s));
        root.insert("link".into(), Json::Obj(link));
        let mut search = BTreeMap::new();
        search.insert("evaluated".into(), num(self.evaluated as f64));
        let mut cache = BTreeMap::new();
        cache.insert("hits".into(), num(self.synth_cache.hits as f64));
        cache.insert("misses".into(), num(self.synth_cache.misses as f64));
        search.insert("synth_cache".into(), Json::Obj(cache));
        root.insert("search".into(), Json::Obj(search));
        root.insert("pass_trace".into(), self.trace.to_json());
        root.insert("diagnostics".into(), self.analysis.to_json());
        let occ = self.occupancy();
        root.insert(
            "stage".into(),
            Json::Arr(
                self.stages
                    .iter()
                    .zip(&occ)
                    .map(|(st, &o)| {
                        let mut m = BTreeMap::new();
                        m.insert("index".into(), num(st.index as f64));
                        m.insert("target".into(), s(st.target.name.clone()));
                        m.insert("compute_s".into(), num(st.cost.compute_s));
                        m.insert("transfer_s".into(), num(st.cost.transfer_s));
                        m.insert(
                            "transfer_bytes".into(),
                            num(st.cost.transfer_bytes as f64),
                        );
                        m.insert("stage_s".into(), num(st.cost.stage_s()));
                        m.insert("bound".into(), s(st.cost.bound()));
                        m.insert("occupancy".into(), num(o));
                        m.insert("accelerator".into(), st.accelerator.to_json());
                        Json::Obj(m)
                    })
                    .collect(),
            ),
        );
        Json::Obj(root)
    }

    /// Human-readable partition explanation (`fpga-flow explain` /
    /// `fpga-flow partition`): the chosen cuts, each stage's cost-model
    /// terms, which term binds it, and the bottleneck attribution.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "pipeline partition of {}: {} stage(s), cuts {:?}, {:.1} FPS steady-state\n",
            self.network,
            self.stages.len(),
            self.cuts,
            self.fps
        ));
        out.push_str(&format!(
            "cost model: stage_s = max(compute, link latency + bytes/{:.1} GB/s); \
             throughput = 1 / max stage_s ({} cut set(s) evaluated)\n",
            self.link.bandwidth_bytes_per_s / 1e9,
            self.evaluated
        ));
        out.push_str(&format!(
            "{:>5}  {:<12} {:<10} {:>11} {:>12} {:>12} {:>10} {:<9} {}\n",
            "stage", "target", "mode", "compute_ms", "transfer_ms", "transfer_kB", "occupancy",
            "bound", "layers"
        ));
        let occ = self.occupancy();
        for (st, &o) in self.stages.iter().zip(&occ) {
            let mark = if st.index == self.bottleneck { "*" } else { " " };
            out.push_str(&format!(
                "{mark}{:>4}  {:<12} {:<10} {:>11.3} {:>12.3} {:>12.1} {:>10.2} {:<9} {}\n",
                st.index,
                st.target.name,
                st.accelerator.mode.name(),
                st.cost.compute_s * 1e3,
                st.cost.transfer_s * 1e3,
                st.cost.transfer_bytes as f64 / 1e3,
                o,
                st.cost.bound(),
                st.graph.nodes.len()
            ));
        }
        out.push_str(&format!(
            "bottleneck: stage {} ({}-bound); moving a cut or a faster link {} raise FPS\n",
            self.bottleneck,
            self.stages[self.bottleneck].cost.bound(),
            if self.stages[self.bottleneck].cost.bound() == "transfer" {
                "would"
            } else {
                "would not"
            }
        ));
        out.push_str(&self.trace.render());
        out
    }
}

fn pareto_point_json(p: &ParetoPoint) -> Json {
    let mut m = BTreeMap::new();
    m.insert("precision".into(), s(p.precision.name()));
    m.insert("fps".into(), num(p.fps));
    m.insert("fmax_mhz".into(), num(p.fmax_mhz));
    m.insert("dsp_frac".into(), num(p.dsp_frac));
    m.insert("logic_frac".into(), num(p.logic_frac));
    m.insert("bram_frac".into(), num(p.bram_frac));
    m.insert("accuracy_delta_pp".into(), num(p.accuracy_delta_pp));
    m.insert(
        "tiles".into(),
        Json::Arr(
            p.plan
                .group_tiles
                .iter()
                .map(|(g, (a, b))| {
                    let mut t = BTreeMap::new();
                    t.insert("group".into(), s(g.to_string()));
                    t.insert("t_ic".into(), num(*a as f64));
                    t.insert("t_oc".into(), num(*b as f64));
                    Json::Obj(t)
                })
                .collect(),
        ),
    );
    Json::Obj(m)
}

impl PrecisionFront {
    /// Machine-readable Pareto front for `fpga-flow dse --json`: the
    /// accuracy-vs-FPS-vs-resources surface downstream tooling consumes.
    pub fn to_json(&self) -> Json {
        let mut root = BTreeMap::new();
        root.insert("network".into(), s(self.network.clone()));
        root.insert("mode".into(), s(self.mode.name()));
        root.insert(
            "precisions".into(),
            Json::Arr(self.results.iter().map(|(p, _)| s(p.name())).collect()),
        );
        root.insert(
            "evaluated".into(),
            num(self.results.iter().map(|(_, r)| r.evaluated).sum::<usize>() as f64),
        );
        let cache = self.synth_cache();
        let mut c = BTreeMap::new();
        c.insert("hits".into(), num(cache.hits as f64));
        c.insert("misses".into(), num(cache.misses as f64));
        c.insert("hit_rate".into(), num(cache.hit_rate()));
        root.insert("synth_cache".into(), Json::Obj(c));
        if let Some(b) = &self.baseline_f32 {
            root.insert("baseline_f32".into(), pareto_point_json(b));
        }
        root.insert("pareto".into(), Json::Arr(self.pareto.iter().map(pareto_point_json).collect()));
        Json::Obj(root)
    }
}

#[cfg(test)]
mod tests {
    use crate::flow::{Compiler, Mode, OptLevel};
    use crate::graph::models;
    use crate::util::json;

    #[test]
    fn json_roundtrips_and_carries_key_fields() {
        let acc = Compiler::default()
            .compile(&models::lenet5(), Mode::Pipelined, OptLevel::Optimized)
            .unwrap();
        let j = acc.to_json();
        let text = j.to_string();
        let parsed = json::parse(&text).unwrap();
        assert_eq!(parsed.get("network").unwrap().as_str(), Some("lenet5"));
        assert_eq!(parsed.get("mode").unwrap().as_str(), Some("pipelined"));
        let fps = parsed.get("performance").unwrap().get("fps").unwrap().as_f64().unwrap();
        assert!((fps - acc.performance.fps).abs() / fps < 1e-9);
        let kernels = parsed.get("kernels").unwrap().as_arr().unwrap();
        assert_eq!(kernels.len(), acc.program.kernels.len());
        let applied = parsed.get("applied").unwrap().as_arr().unwrap();
        assert!(applied.iter().any(|a| a.as_str() == Some("CH")));
        // fp32 compilations report their precision and carry no quant block.
        assert_eq!(parsed.get("precision").unwrap().as_str(), Some("fp32"));
        assert!(parsed.get("quant").is_none());
        // A compiled design carries its analyzer report with zero errors.
        let diags = parsed.get("diagnostics").unwrap();
        assert_eq!(diags.get("errors").unwrap().as_u64(), Some(0));
        assert!(diags.get("items").unwrap().as_arr().is_some());
    }

    #[test]
    fn json_carries_ordered_pass_trace() {
        let acc = Compiler::default()
            .compile(&models::lenet5(), Mode::Pipelined, OptLevel::Optimized)
            .unwrap();
        let parsed = json::parse(&acc.to_json().to_string()).unwrap();
        let trace = parsed.get("pass_trace").unwrap().as_arr().unwrap();
        assert_eq!(trace.len(), acc.pass_trace.records.len());
        let abbrevs: Vec<&str> =
            trace.iter().filter_map(|e| e.get("abbrev").and_then(|a| a.as_str())).collect();
        // Canonical order: LF leads, CE closes.
        assert_eq!(abbrevs.first().copied(), Some("LF"));
        assert_eq!(abbrevs.last().copied(), Some("CE"));
        // Applied passes carry matched + diff; skipped ones carry the rule.
        let lf = &trace[0];
        assert!(lf.get("matched").unwrap().as_f64().unwrap() > 0.0);
        assert!(lf.get("diff").is_some());
        let pk = trace.iter().find(|e| e.get("abbrev").and_then(|a| a.as_str()) == Some("PK"));
        let reason = pk.unwrap().get("skipped").unwrap().as_str().unwrap();
        assert!(reason.contains("folded"), "{reason}");
        // A base compile runs no passes and omits the section entirely.
        let base = Compiler::default()
            .compile(&models::lenet5(), Mode::Pipelined, OptLevel::Base)
            .unwrap();
        let parsed = json::parse(&base.to_json().to_string()).unwrap();
        assert!(parsed.get("pass_trace").is_none());
    }

    #[test]
    fn quantized_json_trace_includes_graph_passes() {
        use crate::quant::QuantConfig;
        let acc = Compiler::default()
            .graph(&models::mobilenet_v1())
            .with_quantization(QuantConfig::int8())
            .run()
            .unwrap();
        let parsed = json::parse(&acc.to_json().to_string()).unwrap();
        let trace = parsed.get("pass_trace").unwrap().as_arr().unwrap();
        let levels: Vec<&str> =
            trace.iter().filter_map(|e| e.get("level").and_then(|l| l.as_str())).collect();
        assert!(levels.contains(&"graph"));
        assert!(levels.contains(&"schedule"));
        // Graph front-end leads: bn-fold is the first pass.
        assert_eq!(trace[0].get("pass").unwrap().as_str(), Some("bn-fold"));
        assert!(trace[0].get("diff").unwrap().get("nodes_removed").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn quantized_accelerator_json_carries_accuracy_delta() {
        use crate::quant::QuantConfig;
        let acc = Compiler::default()
            .graph(&models::lenet5())
            .with_quantization(QuantConfig::int8())
            .run()
            .unwrap();
        let parsed = json::parse(&acc.to_json().to_string()).unwrap();
        assert_eq!(parsed.get("precision").unwrap().as_str(), Some("int8"));
        let q = parsed.get("quant").unwrap();
        assert_eq!(q.get("scheme").unwrap().as_str(), Some("per-channel"));
        let delta = q.get("accuracy_delta_pp").unwrap().as_f64().unwrap();
        assert!((0.0..25.0).contains(&delta), "{delta}");
        assert!(q.get("quantize_nodes").unwrap().as_f64().unwrap() >= 1.0);
    }

    #[test]
    fn pipeline_plan_json_and_render_carry_partition_decision() {
        use crate::flow::multi::{Link, PipelinePlan};
        let plan = PipelinePlan::build(
            &models::lenet5(),
            &["stratix10sx", "stratix10sx"],
            &Link::default(),
        )
        .unwrap();
        let parsed = json::parse(&plan.to_json().to_string()).unwrap();
        assert_eq!(parsed.get("kind").unwrap().as_str(), Some("pipeline"));
        assert_eq!(parsed.get("network").unwrap().as_str(), Some("lenet5"));
        assert_eq!(parsed.get("stages").unwrap().as_u64(), Some(2));
        assert_eq!(parsed.get("cuts").unwrap().as_arr().unwrap().len(), 1);
        assert!(parsed.get("bottleneck_stage").unwrap().as_u64().is_some());
        assert!(parsed.get("search").unwrap().get("evaluated").unwrap().as_u64().unwrap() >= 1);
        // Per-stage cost-model terms + the full nested accelerator report.
        let st = parsed.get("stage").unwrap().idx(1).unwrap();
        assert!(st.get("transfer_bytes").unwrap().as_f64().unwrap() > 0.0);
        assert!(st.get("compute_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(st.get("bound").unwrap().as_str().is_some());
        let acc = st.get("accelerator").unwrap();
        assert!(acc.get("performance").unwrap().get("fps").unwrap().as_f64().unwrap() > 0.0);
        // The partition decision is also in the human-readable rendering.
        let text = plan.render();
        assert!(text.contains("pipeline partition of lenet5"), "{text}");
        assert!(text.contains("bottleneck: stage"), "{text}");
        assert!(text.contains("partition-pipeline"), "{text}");
    }

    #[test]
    fn precision_front_json_carries_pareto() {
        use crate::texpr::Precision;
        let compiler = Compiler::default();
        let front = crate::dse::explore_precisions(
            &compiler,
            &models::lenet5(),
            Mode::Pipelined,
            4,
            &[Precision::F32, Precision::Int8],
        )
        .unwrap();
        let parsed = json::parse(&front.to_json().to_string()).unwrap();
        assert_eq!(parsed.get("network").unwrap().as_str(), Some("lenet5"));
        let pareto = parsed.get("pareto").unwrap().as_arr().unwrap();
        assert!(!pareto.is_empty());
        for p in pareto {
            assert!(p.get("accuracy_delta_pp").unwrap().as_f64().is_some());
            assert!(p.get("fps").unwrap().as_f64().unwrap() > 0.0);
        }
        assert!(parsed.get("baseline_f32").is_some());
    }
}
