//! JSON emission for compiled accelerators — machine-readable reports for
//! CI dashboards and the CLI's `--json` flag (serde is unavailable
//! offline; uses the in-crate `util::json`).

use std::collections::BTreeMap;

use crate::util::json::Json;

use super::Accelerator;

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn s(v: impl Into<String>) -> Json {
    Json::Str(v.into())
}

impl Accelerator {
    /// Full machine-readable report.
    pub fn to_json(&self) -> Json {
        let mut root = BTreeMap::new();
        root.insert("network".into(), s(self.network.clone()));
        root.insert("mode".into(), s(self.mode.name()));
        root.insert("flops_per_frame".into(), num(self.flops_per_frame as f64));
        root.insert(
            "applied".into(),
            Json::Arr(self.applied.iter().map(|o| s(o.abbrev())).collect()),
        );

        let u = &self.synthesis.resources.utilization;
        let mut synth = BTreeMap::new();
        synth.insert("fmax_mhz".into(), num(self.synthesis.fmax_mhz));
        synth.insert("logic_frac".into(), num(u.logic_frac));
        synth.insert("bram_frac".into(), num(u.bram_frac));
        synth.insert("dsp_frac".into(), num(u.dsp_frac));
        synth.insert("max_lsu_width_bytes".into(), num(self.synthesis.max_lsu_width_bytes as f64));
        root.insert("synthesis".into(), Json::Obj(synth));

        let mut perf = BTreeMap::new();
        perf.insert("fps".into(), num(self.performance.fps));
        perf.insert("frame_time_s".into(), num(self.performance.frame_time_s));
        perf.insert("bottleneck".into(), s(self.performance.bottleneck.clone()));
        perf.insert("host_frac".into(), num(self.performance.host_frac));
        perf.insert("gflops".into(), num(self.gflops()));
        root.insert("performance".into(), Json::Obj(perf));

        root.insert(
            "kernels".into(),
            Json::Arr(
                self.program
                    .kernels
                    .iter()
                    .map(|k| {
                        let mut m = BTreeMap::new();
                        m.insert("name".into(), s(k.name.clone()));
                        m.insert("lanes".into(), num(k.nest.total_unroll() as f64));
                        m.insert("autorun".into(), Json::Bool(k.autorun));
                        m.insert("layers".into(), num(k.layers.len() as f64));
                        if let Some(g) = k.group {
                            m.insert("group".into(), s(g.to_string()));
                        }
                        Json::Obj(m)
                    })
                    .collect(),
            ),
        );
        Json::Obj(root)
    }
}

#[cfg(test)]
mod tests {
    use crate::flow::{Compiler, Mode, OptLevel};
    use crate::graph::models;
    use crate::util::json;

    #[test]
    fn json_roundtrips_and_carries_key_fields() {
        let acc = Compiler::default()
            .compile(&models::lenet5(), Mode::Pipelined, OptLevel::Optimized)
            .unwrap();
        let j = acc.to_json();
        let text = j.to_string();
        let parsed = json::parse(&text).unwrap();
        assert_eq!(parsed.get("network").unwrap().as_str(), Some("lenet5"));
        assert_eq!(parsed.get("mode").unwrap().as_str(), Some("pipelined"));
        let fps = parsed.get("performance").unwrap().get("fps").unwrap().as_f64().unwrap();
        assert!((fps - acc.performance.fps).abs() / fps < 1e-9);
        let kernels = parsed.get("kernels").unwrap().as_arr().unwrap();
        assert_eq!(kernels.len(), acc.program.kernels.len());
        let applied = parsed.get("applied").unwrap().as_arr().unwrap();
        assert!(applied.iter().any(|a| a.as_str() == Some("CH")));
    }
}
