//! Staged compilation API: `Compiler` → `CompileSession` → typed stage
//! artifacts.
//!
//! The paper's flow is a staged pipeline (frozen graph → scheduled kernels
//! → AOC synthesis → performance), but the original driver exposed it only
//! as a monolithic `compile` call, so every explorer re-ran all stages per
//! design point. Here each stage returns a typed artifact that can be
//! inspected, cached and re-entered:
//!
//! * [`CompileSession::lower`] → [`LoweredProgram`]: scheduled kernels +
//!   legality check against the target's clock (§IV-J rules 1/2);
//! * [`LoweredProgram::synthesize`] → [`SynthesizedDesign`]: the AOC model
//!   (resources, routing, f_max), **memoized** by a content hash of the
//!   kernel program so sweeps that revisit a program skip the stage;
//! * [`SynthesizedDesign::simulate`] → [`Accelerator`]: the performance
//!   model at the synthesized f_max.
//!
//! ```
//! use tvm_fpga_flow::flow::{Compiler, ModeChoice};
//! use tvm_fpga_flow::graph::models;
//!
//! let net = models::lenet5();
//! let acc = Compiler::for_target("stratix10sx").unwrap()
//!     .graph(&net)
//!     .mode(ModeChoice::Auto)
//!     .lower().unwrap()
//!     .synthesize().unwrap()
//!     .simulate().unwrap();
//! assert!(acc.performance.fps > 0.0);
//! ```
//!
//! Errors are typed ([`CompileError`]) and surface through `anyhow` so
//! callers can `downcast_ref::<CompileError>()` to react programmatically.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::aoc::{self, FmaxModel, SynthesisReport};
use crate::codegen::KernelProgram;
use crate::device::Target;
use crate::graph::Graph;
use crate::obs;
use crate::quant::{self, QuantConfig, QuantReport};
use crate::sim::folded::LayerWork;
use crate::sim::{folded, pipelined, HostModel, PerformanceReport};
use crate::texpr::Precision;

use super::patterns::{self, default_factors, FactorPlan, OptConfig};
use super::{legality, Accelerator, Mode, OptLevel};

/// Typed failure modes of the staged compile API.
#[derive(Debug, Clone, PartialEq)]
pub enum CompileError {
    /// `Compiler::for_target` was given a name the registry doesn't know.
    UnknownTarget { name: String },
    /// A stage needing a graph ran on a session that never got one.
    MissingGraph,
    /// The input graph failed structural validation.
    InvalidGraph(String),
    /// The factor plan violates the §IV-J legality rules on this target.
    IllegalPlan { network: String, violations: Vec<crate::analysis::Diagnostic> },
    /// The static design-rule analyzer found Error-level diagnostics
    /// ([`CompileSession::analyze`]); the design would deadlock, overflow
    /// or fail synthesis.
    Analysis { network: String, diagnostics: Vec<crate::analysis::Diagnostic> },
    /// A stage was requested before the stage it consumes.
    StageOrder { wanted: &'static str, missing: &'static str },
    /// The AOC model failed to route the design (rule 3 / congestion).
    RoutingFailure(String),
    /// An [`OptConfig`] field is outside its legal domain (e.g. a weight
    /// density outside (0, 1]), which would silently corrupt modeled
    /// costs.
    InvalidOptConfig { field: &'static str, value: f64, reason: &'static str },
}

impl std::fmt::Display for CompileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CompileError::UnknownTarget { name } => write!(
                f,
                "unknown target '{name}' (known: {})",
                Target::names().join(", ")
            ),
            CompileError::MissingGraph => write!(f, "no graph attached to this session"),
            CompileError::InvalidGraph(e) => write!(f, "invalid graph: {e}"),
            CompileError::IllegalPlan { network, violations } => write!(
                f,
                "illegal factor plan for {network}: {}",
                violations.iter().map(|v| v.message.as_str()).collect::<Vec<_>>().join("; ")
            ),
            CompileError::Analysis { network, diagnostics } => write!(
                f,
                "design-rule analysis failed for {network}: {}",
                diagnostics
                    .iter()
                    .filter(|d| d.severity() == crate::analysis::Severity::Error)
                    .map(|d| d.to_string())
                    .collect::<Vec<_>>()
                    .join("; ")
            ),
            CompileError::StageOrder { wanted, missing } => {
                write!(f, "cannot {wanted} before {missing} has run")
            }
            CompileError::RoutingFailure(e) => write!(f, "{e}"),
            CompileError::InvalidOptConfig { field, value, reason } => {
                write!(f, "invalid OptConfig.{field} = {value}: {reason}")
            }
        }
    }
}

impl std::error::Error for CompileError {}

/// Hit/miss counters of the synthesis memo.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
}

impl CacheStats {
    pub fn total(&self) -> u64 {
        self.hits + self.misses
    }

    /// Fraction of synthesis requests served from the memo (0 when empty).
    pub fn hit_rate(&self) -> f64 {
        if self.total() == 0 {
            0.0
        } else {
            self.hits as f64 / self.total() as f64
        }
    }
}

/// One memo slot: either a finished outcome or a marker that some thread
/// is currently synthesizing this key (single-flight).
#[derive(Debug, Clone)]
enum MemoEntry {
    InFlight,
    Done(Result<SynthesisReport, String>),
}

/// Synthesis memo: program fingerprint → synthesis outcome. Failures are
/// cached too (a plan that failed routing once fails identically again).
/// Lookups are single-flight: concurrent requests for the same key (the
/// parallel DSE sweep revisits identical programs) wait on the first
/// synthesizer instead of duplicating the work, so the hit/miss counters
/// stay deterministic — misses = distinct programs, hits = revisits —
/// exactly as in a sequential sweep.
#[derive(Debug, Default)]
struct SynthMemo {
    map: Mutex<HashMap<u64, MemoEntry>>,
    done: std::sync::Condvar,
    hits: AtomicU64,
    misses: AtomicU64,
}

/// Clears an `InFlight` claim even if the synthesizing thread unwinds:
/// waiters then observe a cached failure instead of blocking forever.
struct InFlightGuard<'a> {
    memo: &'a SynthMemo,
    key: u64,
    armed: bool,
}

impl InFlightGuard<'_> {
    fn publish(&mut self, outcome: Result<SynthesisReport, String>) {
        self.memo.map.lock().unwrap().insert(self.key, MemoEntry::Done(outcome));
        self.memo.done.notify_all();
        self.armed = false;
    }
}

impl Drop for InFlightGuard<'_> {
    fn drop(&mut self) {
        if self.armed {
            // Unwinding: tolerate a poisoned lock (never held across the
            // model call, but stay panic-safe inside Drop).
            if let Ok(mut map) = self.memo.map.lock() {
                map.insert(
                    self.key,
                    MemoEntry::Done(Err("synthesis panicked for this design".to_string())),
                );
            }
            self.memo.done.notify_all();
        }
    }
}

/// Stable content hash of a kernel program (FNV-1a over the canonical
/// debug rendering — every schedule-relevant field of the kernels feeds
/// the synthesis model and is part of `Debug`).
pub fn program_fingerprint(prog: &KernelProgram) -> u64 {
    let repr = format!("{}|{:?}|{:?}|{}", prog.name, prog.kernels, prog.channels, prog.queues);
    crate::util::fnv64(repr.as_bytes())
}

/// Mode selection for a session: pin a mode or let the flow decide from
/// the target's resource envelope (§III's deployment choice).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModeChoice {
    /// Pick pipelined when the estimated design fits on-chip, else folded.
    Auto,
    Pipelined,
    Folded,
}

impl From<Mode> for ModeChoice {
    fn from(m: Mode) -> ModeChoice {
        match m {
            Mode::Pipelined => ModeChoice::Pipelined,
            Mode::Folded => ModeChoice::Folded,
        }
    }
}

/// Compilation driver for one target: owns the device envelope, the fitted
/// AOC/host models, and the synthesis memo shared by every session (and
/// every clone) it spawns.
#[derive(Debug, Clone)]
pub struct Compiler {
    pub target: Target,
    pub fmax_model: FmaxModel,
    pub host: HostModel,
    memo: Arc<SynthMemo>,
}

impl Default for Compiler {
    fn default() -> Self {
        Compiler::new(Target::stratix10sx())
    }
}

impl Compiler {
    /// Build a compiler for a registered target name (or alias).
    ///
    /// ```
    /// use tvm_fpga_flow::flow::{CompileError, Compiler};
    ///
    /// let c = Compiler::for_target("arria10gx").unwrap();
    /// assert_eq!(c.target.name, "arria10gx");
    /// // Aliases resolve to the canonical target…
    /// assert_eq!(Compiler::for_target("a10").unwrap().target.name, "arria10gx");
    /// // …and unknown names fail with a typed error listing the registry.
    /// let err = Compiler::for_target("virtex7").unwrap_err();
    /// assert!(matches!(
    ///     err.downcast_ref::<CompileError>(),
    ///     Some(CompileError::UnknownTarget { .. })
    /// ));
    /// ```
    pub fn for_target(name: &str) -> crate::Result<Compiler> {
        let target = Target::by_name(name)
            .ok_or(CompileError::UnknownTarget { name: name.to_string() })?;
        Ok(Compiler::new(target))
    }

    /// Build a compiler for an explicit target. The f_max model's base
    /// clock tracks the target's legality clock (a faster fabric both
    /// routes faster and tightens the bandwidth roof).
    pub fn new(target: Target) -> Compiler {
        let fmax_model =
            FmaxModel { base_mhz: target.device.legality_clock_mhz, ..FmaxModel::default() };
        Compiler { target, fmax_model, host: HostModel::default(), memo: Arc::default() }
    }

    /// Build from explicit parts (the deprecated `Flow` shim path; keeps a
    /// hand-tuned device/model combination working).
    pub fn from_parts(device: crate::device::FpgaDevice, fmax_model: FmaxModel, host: HostModel) -> Compiler {
        let name = format!("custom:{}", device.name);
        Compiler { target: Target::custom(name, device), fmax_model, host, memo: Arc::default() }
    }

    /// Start an empty session (attach a graph with [`CompileSession::graph`]).
    pub fn session(&self) -> CompileSession {
        CompileSession {
            compiler: self.clone(),
            graph: None,
            mode: ModeChoice::Auto,
            cfg: OptConfig::optimized(),
            plan: None,
            quant: None,
            lowered: None,
            design: None,
        }
    }

    /// Start a session on a graph.
    pub fn graph(&self, graph: &Graph) -> CompileSession {
        self.session().graph(graph)
    }

    /// Synthesis-memo counters accumulated by this compiler (shared across
    /// clones and sessions).
    pub fn cache_stats(&self) -> CacheStats {
        CacheStats {
            hits: self.memo.hits.load(Ordering::Relaxed),
            misses: self.memo.misses.load(Ordering::Relaxed),
        }
    }

    /// One-shot convenience: run all stages with defaults for the level.
    pub fn compile(
        &self,
        graph: &Graph,
        mode: impl Into<ModeChoice>,
        level: OptLevel,
    ) -> crate::Result<Accelerator> {
        let cfg = match level {
            OptLevel::Base => OptConfig::base(),
            OptLevel::Optimized => OptConfig::optimized(),
        };
        self.compile_with(graph, mode, &cfg, &default_factors(graph))
    }

    /// One-shot convenience with an explicit config + factor plan.
    pub fn compile_with(
        &self,
        graph: &Graph,
        mode: impl Into<ModeChoice>,
        cfg: &OptConfig,
        plan: &FactorPlan,
    ) -> crate::Result<Accelerator> {
        self.graph(graph)
            .mode(mode)
            .opts(*cfg)
            .plan(plan.clone())
            .lower()?
            .synthesize()?
            .simulate()
    }

    /// The mode the paper uses for each evaluation network (Table III).
    pub fn paper_mode(network: &str) -> Mode {
        match network {
            "lenet5" => Mode::Pipelined,
            _ => Mode::Folded,
        }
    }

    /// Memo key: the program fingerprint folded with the device + f_max
    /// model, so mutating a compiler's public `target`/`fmax_model` can
    /// never recall a report synthesized for a different context.
    fn memo_key(&self, prog: &KernelProgram) -> u64 {
        let ctx = format!("{:?}|{:?}", self.target.device, self.fmax_model);
        crate::util::fnv64_with(program_fingerprint(prog), ctx.as_bytes())
    }

    /// Memoized synthesis: returns the report and whether it was a hit.
    /// Single-flight: a request for an in-flight key blocks until the
    /// first synthesizer publishes, then counts as a hit.
    pub(crate) fn synthesize_memoized(
        &self,
        prog: &KernelProgram,
    ) -> crate::Result<(SynthesisReport, bool)> {
        let key = self.memo_key(prog);
        {
            let mut map = self.memo.map.lock().unwrap();
            loop {
                // Probe under the lock; clone out so no borrow outlives
                // the decision of what to do with the guard.
                let done: Option<Option<Result<SynthesisReport, String>>> =
                    map.get(&key).map(|entry| match entry {
                        MemoEntry::Done(outcome) => Some(outcome.clone()),
                        MemoEntry::InFlight => None,
                    });
                match done {
                    Some(Some(outcome)) => {
                        self.memo.hits.fetch_add(1, Ordering::Relaxed);
                        return match outcome {
                            Ok(rep) => Ok((rep, true)),
                            Err(msg) => Err(CompileError::RoutingFailure(msg).into()),
                        };
                    }
                    Some(None) => {
                        map = self.memo.done.wait(map).unwrap();
                    }
                    None => {
                        map.insert(key, MemoEntry::InFlight);
                        break;
                    }
                }
            }
        }
        self.memo.misses.fetch_add(1, Ordering::Relaxed);
        let mut guard = InFlightGuard { memo: &*self.memo, key, armed: true };
        let outcome = aoc::synthesize(prog, &self.target.device, &self.fmax_model)
            .map_err(|e| e.to_string());
        guard.publish(outcome.clone());
        match outcome {
            Ok(rep) => Ok((rep, false)),
            Err(msg) => Err(CompileError::RoutingFailure(msg).into()),
        }
    }
}

/// A configurable compile session. Builder-style setters consume and
/// return the session; stage methods cache their artifact so a session can
/// be driven incrementally (`lower` → inspect → `synthesize` → …) or in
/// one chain.
///
/// ```
/// use tvm_fpga_flow::flow::{Compiler, Mode, ModeChoice};
/// use tvm_fpga_flow::graph::models;
///
/// let compiler = Compiler::for_target("stratix10sx").unwrap();
/// let mut session = compiler.graph(&models::lenet5()).mode(ModeChoice::Auto);
/// // Drive the stages one at a time, inspecting each artifact…
/// let lowered = session.lower().unwrap();
/// assert_eq!(lowered.mode, Mode::Pipelined); // Auto resolved for this target
/// let fmax = session.synthesize().unwrap().fmax_mhz();
/// assert!(fmax > 100.0);
/// // …then finish; stage artifacts are cached, nothing reruns.
/// let acc = session.run().unwrap();
/// assert!(acc.performance.fps > 0.0);
/// ```
#[derive(Debug, Clone)]
pub struct CompileSession {
    compiler: Compiler,
    graph: Option<Graph>,
    mode: ModeChoice,
    cfg: OptConfig,
    plan: Option<FactorPlan>,
    quant: Option<QuantConfig>,
    lowered: Option<LoweredProgram>,
    design: Option<SynthesizedDesign>,
}

impl CompileSession {
    /// Attach (or replace) the input graph; invalidates staged artifacts.
    pub fn graph(mut self, graph: &Graph) -> Self {
        self.graph = Some(graph.clone());
        self.invalidate();
        self
    }

    /// Select the execution mode (accepts `Mode` or `ModeChoice`).
    pub fn mode(mut self, mode: impl Into<ModeChoice>) -> Self {
        self.mode = mode.into();
        self.invalidate();
        self
    }

    /// Set the optimization switch-board (defaults to all of Table I).
    pub fn opts(mut self, cfg: OptConfig) -> Self {
        self.cfg = cfg;
        self.invalidate();
        self
    }

    /// Set the factor plan (defaults to [`default_factors`] of the graph).
    pub fn plan(mut self, plan: FactorPlan) -> Self {
        self.plan = Some(plan);
        self.invalidate();
        self
    }

    /// Compile with a quantized datapath: the graph is BN-folded,
    /// calibrated and rewritten with quantize/dequantize boundaries
    /// ([`crate::quant::prepare`]), every kernel is scheduled at the
    /// requested precision, and the resulting
    /// [`Accelerator`] carries the [`QuantReport`] (modeled top-1 loss,
    /// boundary statistics).
    ///
    /// ```
    /// use tvm_fpga_flow::flow::{Compiler, ModeChoice};
    /// use tvm_fpga_flow::graph::models;
    /// use tvm_fpga_flow::quant::QuantConfig;
    /// use tvm_fpga_flow::texpr::Precision;
    ///
    /// let compiler = Compiler::for_target("stratix10sx").unwrap();
    /// let f32_acc = compiler.graph(&models::lenet5()).run().unwrap();
    /// let int8_acc = compiler
    ///     .graph(&models::lenet5())
    ///     .mode(ModeChoice::Auto)
    ///     .with_quantization(QuantConfig::int8())
    ///     .run()
    ///     .unwrap();
    /// assert_eq!(int8_acc.precision, Precision::Int8);
    /// let q = int8_acc.quant.as_ref().unwrap();
    /// assert!(q.accuracy.delta_pp < 25.0);
    /// // The narrower datapath never costs more modeled DSPs.
    /// let quantized_dsp = int8_acc.synthesis.resources.utilization.dsp_frac;
    /// let baseline_dsp = f32_acc.synthesis.resources.utilization.dsp_frac;
    /// assert!(quantized_dsp <= baseline_dsp);
    /// ```
    pub fn with_quantization(mut self, quant: QuantConfig) -> Self {
        self.quant = Some(quant);
        self.invalidate();
        self
    }

    fn invalidate(&mut self) {
        self.lowered = None;
        self.design = None;
    }

    /// Stage 1: run the graph- and schedule-pass pipelines through the
    /// [`crate::pass::PassManager`] and check §IV-J legality against the
    /// target's clock. Idempotent; the artifact (including the
    /// [`crate::pass::PassTrace`]) is cached on the session.
    pub fn lower(&mut self) -> crate::Result<&LoweredProgram> {
        if self.lowered.is_none() {
            let src = self.graph.as_ref().ok_or(CompileError::MissingGraph)?;
            let mut stage_span = obs::span("compile", "lower");
            stage_span.set_arg("network", src.name.as_str());
            if obs::enabled() {
                obs::global_metrics()
                    .counter("flow_lower_total", "CompileSession lower-stage executions")
                    .inc();
            }
            src.validate().map_err(CompileError::InvalidGraph)?;
            self.cfg.validate()?;
            // Quantization front-end (when requested): BN-fold, calibrate,
            // rewrite quantize/dequantize boundaries, and schedule every
            // kernel at the requested precision. The graph passes it ran
            // lead the session's pass trace.
            let (graph, quant_report, cfg, graph_trace) = match &self.quant {
                Some(q) if q.precision != Precision::F32 => {
                    let prep = quant::prepare(src, q)?;
                    (
                        std::borrow::Cow::Owned(prep.graph),
                        Some(prep.report),
                        self.cfg.with_precision(q.precision),
                        prep.trace,
                    )
                }
                _ => (
                    std::borrow::Cow::Borrowed(src),
                    None,
                    self.cfg,
                    crate::pass::PassTrace::default(),
                ),
            };
            let graph: &Graph = &graph;
            let target = &self.compiler.target;
            let plan = self.plan.clone().unwrap_or_else(|| default_factors(graph));
            // Resolve Auto with the session's own config + plan, reusing
            // the candidate build when pipelined wins rather than lowering
            // the same program twice.
            let (mode, prebuilt) = match self.mode {
                ModeChoice::Pipelined => (Mode::Pipelined, None),
                ModeChoice::Folded => (Mode::Folded, None),
                ModeChoice::Auto => {
                    match super::auto_pipelined_candidate(graph, &target.device, &cfg, &plan) {
                        Some(built) => (Mode::Pipelined, Some(built)),
                        None => (Mode::Folded, None),
                    }
                }
            };
            stage_span.set_arg("mode", mode.name());
            stage_span.set_arg("precision", cfg.precision.name());
            let built = match prebuilt {
                Some(built) => built,
                None => patterns::build_with_passes(graph, mode, &cfg, &plan),
            };
            let patterns::BuiltProgram { program, work, trace: schedule_trace } = built;
            let mut trace = graph_trace;
            trace.records.extend(schedule_trace.records);

            // Rules 1/2 (rule 3 = fit, checked by synthesize()).
            let violations =
                legality::check_program(&program, &target.device, target.device.legality_clock_mhz);
            if !violations.is_empty() {
                return Err(CompileError::IllegalPlan {
                    network: graph.name.clone(),
                    violations,
                }
                .into());
            }

            let applied = patterns::applied_summary(&program);
            self.lowered = Some(LoweredProgram {
                compiler: self.compiler.clone(),
                network: graph.name.clone(),
                mode,
                graph: Arc::new(graph.clone()),
                program: Arc::new(program),
                work: Arc::new(work),
                applied,
                flops_per_frame: graph.total_flops(),
                precision: cfg.precision,
                quant: quant_report,
                trace,
            });
        }
        Ok(self.lowered.as_ref().expect("just populated"))
    }

    /// Stage 2 on this session. Requires [`CompileSession::lower`] to have
    /// run (typed [`CompileError::StageOrder`] otherwise).
    pub fn synthesize(&mut self) -> crate::Result<&SynthesizedDesign> {
        if self.design.is_none() {
            let design = match self.lowered.as_ref() {
                Some(lowered) => lowered.synthesize()?,
                None => {
                    return Err(CompileError::StageOrder {
                        wanted: "synthesize",
                        missing: "lower",
                    }
                    .into())
                }
            };
            self.design = Some(design);
        }
        Ok(self.design.as_ref().expect("just populated"))
    }

    /// Stage 3 on this session. Requires [`CompileSession::synthesize`].
    pub fn simulate(&mut self) -> crate::Result<Accelerator> {
        match self.design.as_ref() {
            Some(d) => d.simulate(),
            None => {
                Err(CompileError::StageOrder { wanted: "simulate", missing: "synthesize" }.into())
            }
        }
    }

    /// Run every remaining stage and return the finished accelerator.
    pub fn run(&mut self) -> crate::Result<Accelerator> {
        self.lower()?;
        self.synthesize()?;
        self.simulate()
    }

    /// Analysis stage: lower (if needed) and run the static design-rule
    /// analyzer ([`crate::analysis`]) over the scheduled program — channel
    /// deadlock, accumulator overflow, resource budget, structural and
    /// pass-trace consistency lints. Sits between lowering and synthesis:
    /// Error-level findings return a typed [`CompileError::Analysis`]
    /// (the design must not synthesize); warnings and notes come back in
    /// the report for the caller to judge (`fpga-flow check
    /// --deny warnings` makes warnings fatal too).
    ///
    /// ```
    /// use tvm_fpga_flow::flow::{Compiler, Mode};
    /// use tvm_fpga_flow::graph::models;
    ///
    /// let compiler = Compiler::default();
    /// let mut session = compiler.graph(&models::lenet5()).mode(Mode::Pipelined);
    /// let report = session.analyze().unwrap();
    /// assert!(report.is_clean(false));
    /// ```
    pub fn analyze(&mut self) -> crate::Result<crate::analysis::AnalysisReport> {
        self.lower()?;
        let lowered = self.lowered.as_ref().expect("just lowered");
        let report = lowered.analyze();
        if report.count(crate::analysis::Severity::Error) > 0 {
            return Err(CompileError::Analysis {
                network: lowered.network.clone(),
                diagnostics: report.diagnostics,
            }
            .into());
        }
        Ok(report)
    }

    /// Verification stage: lower (if needed) and differentially check the
    /// scheduled program against the reference executor on `frames`
    /// deterministic frames. Returns the report; callers decide whether a
    /// failed report is fatal (the CLI's `fpga-flow verify` does).
    pub fn verify(&mut self, frames: usize) -> crate::Result<crate::verify::VerifyReport> {
        self.lower()?;
        Ok(self.lowered.as_ref().expect("just lowered").verify(frames, 0x5EED_F00D))
    }
}

/// Stage-1 artifact: scheduled, legality-checked kernels for one mode on
/// one target. Re-enterable: `synthesize()` can be called any number of
/// times (memoized). The heavy payloads are `Arc`-shared so cloning an
/// artifact (or carrying it into the next stage) costs refcount bumps,
/// not program deep-copies — explorers re-enter stages per design point.
#[derive(Debug, Clone)]
pub struct LoweredProgram {
    compiler: Compiler,
    pub network: String,
    pub mode: Mode,
    /// The (possibly quantization-rewritten) graph the program was lowered
    /// from — what [`LoweredProgram::verify`] diffs the kernels against.
    pub graph: Arc<Graph>,
    pub program: Arc<KernelProgram>,
    pub work: Arc<Vec<LayerWork>>,
    /// Table III row.
    pub applied: Vec<crate::schedule::OptKind>,
    /// FLOPs per frame (for GFLOPS accounting).
    pub flops_per_frame: u64,
    /// Datapath precision the kernels were scheduled at.
    pub precision: Precision,
    /// Quantization report (present when the session quantized).
    pub quant: Option<QuantReport>,
    /// Ordered trace of every pass (graph-level quantization front-end +
    /// schedule pipeline) that produced this program.
    pub trace: crate::pass::PassTrace,
}

impl LoweredProgram {
    /// The target this program was lowered for.
    pub fn target(&self) -> &Target {
        &self.compiler.target
    }

    /// Content hash of the kernel program. The synthesis memo additionally
    /// folds the target device + f_max model into its key, so equal
    /// fingerprints share a memo entry only within one compilation context.
    pub fn fingerprint(&self) -> u64 {
        program_fingerprint(&self.program)
    }

    /// Stage 2: run (or recall) the AOC model for this program.
    pub fn synthesize(&self) -> crate::Result<SynthesizedDesign> {
        let mut stage_span = obs::span("compile", "synthesize");
        stage_span.set_arg("network", self.network.as_str());
        let (synthesis, cache_hit) = self.compiler.synthesize_memoized(&self.program)?;
        stage_span.set_arg("cache_hit", cache_hit);
        if obs::enabled() {
            let m = obs::global_metrics();
            if cache_hit {
                m.counter("flow_synth_cache_hits_total", "synthesis-memo hits").inc();
            } else {
                m.counter("flow_synth_cache_misses_total", "synthesis-memo misses").inc();
            }
        }
        Ok(SynthesizedDesign { lowered: self.clone(), synthesis, cache_hit })
    }

    /// Static design-rule analysis of this program (infallible form: the
    /// full report, whatever its severity counts — the session-level
    /// [`CompileSession::analyze`] turns Error findings into a typed
    /// [`CompileError::Analysis`]). Independent of synthesis; the
    /// pass-trace consistency lints run against this lowering's trace.
    pub fn analyze(&self) -> crate::analysis::AnalysisReport {
        let mut stage_span = obs::span("compile", "analyze");
        stage_span.set_arg("network", self.network.as_str());
        let device = &self.compiler.target.device;
        let report = crate::analysis::analyze(
            &self.graph,
            &self.program,
            device,
            device.legality_clock_mhz,
            Some(&self.trace),
        );
        stage_span.set_arg("diagnostics", report.diagnostics.len());
        report
    }

    /// Differentially verify this program against the graph-level oracle
    /// ([`crate::quant::Executor`]) on `frames` deterministic frames:
    /// the kernel interpreter must agree bit-exactly at int8 and within
    /// the documented tolerance for f32/fp16 (`docs/VERIFICATION.md`).
    /// Independent of synthesis — callable straight after `lower`.
    pub fn verify(&self, frames: usize, seed: u64) -> crate::verify::VerifyReport {
        let mut stage_span = obs::span("compile", "verify");
        stage_span.set_arg("network", self.network.as_str());
        stage_span.set_arg("frames", frames);
        let opts = crate::verify::VerifyOptions {
            scheme: self.quant.as_ref().map(|q| q.scheme).unwrap_or_default(),
            ..Default::default()
        };
        let data = crate::verify::frames_for(&self.graph, frames, seed);
        crate::verify::verify_program(
            &self.graph,
            &self.program,
            self.precision,
            self.trace.required_equivalence(),
            &data,
            &opts,
        )
    }
}

/// Stage-2 artifact: a routed design with resources and achieved f_max.
#[derive(Debug, Clone)]
pub struct SynthesizedDesign {
    lowered: LoweredProgram,
    pub synthesis: SynthesisReport,
    /// True when the report came from the synthesis memo.
    pub cache_hit: bool,
}

impl SynthesizedDesign {
    /// The stage-1 artifact this design was synthesized from.
    pub fn lowered(&self) -> &LoweredProgram {
        &self.lowered
    }

    pub fn fmax_mhz(&self) -> f64 {
        self.synthesis.fmax_mhz
    }

    /// Stage 3, report only: run the performance model at the synthesized
    /// clock without materializing an [`Accelerator`]. Explorers that only
    /// need FPS/utilization per design point use this to avoid deep-copying
    /// the kernel program for every candidate.
    pub fn performance(&self) -> PerformanceReport {
        let l = &self.lowered;
        let c = &l.compiler;
        let fmax = self.synthesis.fmax_mhz;
        match l.mode {
            Mode::Pipelined => pipelined::simulate(&l.program, &c.target.device, fmax, &c.host),
            Mode::Folded => folded::simulate(&l.program, &l.work, &c.target.device, fmax, &c.host),
        }
    }

    /// Stage 3: simulate performance at the synthesized clock.
    pub fn simulate(&self) -> crate::Result<Accelerator> {
        let _stage_span = obs::span("compile", "simulate");
        let l = &self.lowered;
        let performance = self.performance();
        Ok(Accelerator {
            network: l.network.clone(),
            mode: l.mode,
            program: l.program.as_ref().clone(),
            synthesis: self.synthesis.clone(),
            performance,
            work: l.work.as_ref().clone(),
            applied: l.applied.clone(),
            flops_per_frame: l.flops_per_frame,
            precision: l.precision,
            quant: l.quant.clone(),
            pass_trace: l.trace.clone(),
            analysis: l.analyze(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    #[test]
    fn staged_chain_matches_one_shot() {
        let compiler = Compiler::default();
        let g = models::lenet5();
        let staged = compiler
            .graph(&g)
            .mode(Mode::Pipelined)
            .lower()
            .unwrap()
            .synthesize()
            .unwrap()
            .simulate()
            .unwrap();
        let oneshot = compiler.compile(&g, Mode::Pipelined, OptLevel::Optimized).unwrap();
        assert_eq!(staged.performance.fps, oneshot.performance.fps);
        assert_eq!(staged.synthesis.fmax_mhz, oneshot.synthesis.fmax_mhz);
    }

    #[test]
    fn lowered_artifact_is_inspectable_before_synthesis() {
        let compiler = Compiler::default();
        let g = models::mobilenet_v1();
        let mut session = compiler.graph(&g).mode(ModeChoice::Folded);
        let lowered = session.lower().unwrap();
        assert_eq!(lowered.network, "mobilenet_v1");
        assert_eq!(lowered.mode, Mode::Folded);
        assert!(!lowered.program.kernels.is_empty());
        assert!(lowered.fingerprint() != 0);
        // No synthesis has happened yet.
        assert_eq!(compiler.cache_stats().total(), 0);
    }

    #[test]
    fn memo_hits_on_identical_programs() {
        let compiler = Compiler::default();
        let g = models::lenet5();
        let d1 = compiler.graph(&g).mode(Mode::Pipelined).lower().unwrap().synthesize().unwrap();
        let d2 = compiler.graph(&g).mode(Mode::Pipelined).lower().unwrap().synthesize().unwrap();
        assert!(!d1.cache_hit);
        assert!(d2.cache_hit);
        assert_eq!(d1.synthesis.fmax_mhz, d2.synthesis.fmax_mhz);
        assert_eq!(d1.synthesis.resources.total, d2.synthesis.resources.total);
        let stats = compiler.cache_stats();
        assert_eq!((stats.hits, stats.misses), (1, 1));
        assert!((stats.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn auto_mode_resolves_per_target() {
        // LeNet-5 fits pipelined on the big S10SX; the big networks don't.
        let s10 = Compiler::default();
        let mut s = s10.graph(&models::lenet5()).mode(ModeChoice::Auto);
        assert_eq!(s.lower().unwrap().mode, Mode::Pipelined);
        let mut m = s10.graph(&models::resnet34()).mode(ModeChoice::Auto);
        assert_eq!(m.lower().unwrap().mode, Mode::Folded);
    }

    #[test]
    fn verify_stage_agrees_with_oracle() {
        let compiler = Compiler::default();
        // f32: toleranced agreement.
        let mut s = compiler.graph(&models::lenet5()).mode(Mode::Pipelined);
        let rep = s.verify(4).unwrap();
        assert!(rep.passed, "{}", rep.summary());
        // int8 through the full quantization front-end (Q/DQ-rewritten
        // graph): the kernel interpreter must be bit-exact against
        // Executor::forward_quantized.
        let mut q = compiler
            .graph(&models::lenet5())
            .mode(Mode::Pipelined)
            .with_quantization(crate::quant::QuantConfig::int8());
        let rep = q.verify(4).unwrap();
        assert!(rep.passed, "{}", rep.summary());
        assert!(rep.bit_exact, "{}", rep.summary());
        // The lowered artifact carries the rewritten graph it was built
        // from (Quantize/Dequantize boundaries included).
        let lowered = q.lower().unwrap();
        assert!(lowered
            .graph
            .nodes
            .iter()
            .any(|n| matches!(n.op, crate::graph::Op::Quantize { .. })));
    }

    #[test]
    fn fingerprint_distinguishes_programs() {
        let compiler = Compiler::default();
        let g = models::lenet5();
        let mut a = compiler.graph(&g).mode(Mode::Pipelined);
        let mut b = compiler.graph(&g).mode(Mode::Folded);
        assert_ne!(a.lower().unwrap().fingerprint(), b.lower().unwrap().fingerprint());
    }
}
