//! Factor-selection legality (§IV-J): the three requirements the paper
//! imposes on unroll/tile factors.
//!
//! 1. For loops that access *non-cached* global memory, the factor must not
//!    exceed the bandwidth roof (~76 fp32 words/cycle on the S10SX @250MHz).
//! 2. The loop count must be evenly divisible by the factor (no
//!    prologue/epilogue code).
//! 3. The design must fit the device (checked post-synthesis).

use crate::analysis::{Diagnostic, Lint, Span};
use crate::aoc::lsu::{infer, LsuKind};
use crate::codegen::KernelProgram;
use crate::device::FpgaDevice;

/// Largest divisor of `extent` that is ≤ `cap` (rule 2 helper). Always ≥ 1.
pub fn largest_divisor_leq(extent: u64, cap: u64) -> u64 {
    let cap = cap.min(extent).max(1);
    (1..=cap).rev().find(|f| extent % f == 0).unwrap_or(1)
}

/// All divisors of `extent` up to `cap` — the DSE's candidate factors.
pub fn divisors_leq(extent: u64, cap: u64) -> Vec<u64> {
    (1..=cap.min(extent)).filter(|f| extent % f == 0).collect()
}

/// Pass-level mode precondition: several Table I optimizations are legal
/// in one execution mode only (§III/§IV). `Err` carries the trace-visible
/// reason naming the restriction, so a skipped pass explains itself.
pub fn mode_restriction(
    pass: &str,
    required: super::Mode,
    actual: super::Mode,
    rule: &str,
) -> Result<(), String> {
    if required == actual {
        Ok(())
    } else {
        Err(format!(
            "{pass} requires {} mode but the design is {} — {rule}",
            required.name(),
            actual.name()
        ))
    }
}

/// §VII #2: the zero-skipping datapath's weight-density domain is (0, 1].
/// Values outside it would scale traffic by nonsense factors. `Err` is a
/// typed FLOW022 diagnostic (pass preconditions keep only its message).
pub fn sparsity_domain(density: f64) -> Result<(), Diagnostic> {
    if density > 0.0 && density <= 1.0 {
        Ok(())
    } else {
        Err(Diagnostic::new(
            Lint::SparsityDomain,
            Span::default(),
            format!("weight density {density} outside the (0, 1] sparsity domain (§VII #2)"),
        ))
    }
}

/// Check rules 1 and 2 on a scheduled program (rule 3 is the synthesis
/// fit + routing check in `aoc::report`, pre-checked statically by
/// [`crate::analysis::structure`]). Findings are FLOW020/FLOW021
/// diagnostics, sharing the analyzer's vocabulary.
pub fn check_program(prog: &KernelProgram, dev: &FpgaDevice, fmax_mhz: f64) -> Vec<Diagnostic> {
    // Roof in *bytes* per cycle so reduced-precision designs stream
    // proportionally more elements (§VII extension).
    let roof_bytes = (dev.bw_floats_per_cycle(fmax_mhz).floor() as u64) * 4;
    let mut out = Vec::new();
    for k in &prog.kernels {
        for l in &k.nest.loops {
            if l.extent % l.unroll != 0 {
                out.push(Diagnostic::new(
                    Lint::NotDivisible,
                    Span::kernel(k.name.clone()),
                    format!(
                        "{}: loop {} extent {} not divisible by factor {} (§IV-J rule 2)",
                        k.name,
                        l.var.name(),
                        l.extent,
                        l.unroll
                    ),
                ));
            }
        }
        let eb = k.nest.precision.bytes();
        for lsu in infer(&k.nest) {
            // Cached and BRAM-stashed operands are exempt (the roof binds
            // streamed operands only).
            if matches!(lsu.kind, LsuKind::BurstCoalesced | LsuKind::Replicated) {
                let bytes = lsu.width_bytes.max(lsu.count * eb);
                if bytes > roof_bytes {
                    out.push(Diagnostic::new(
                        Lint::BandwidthRoof,
                        Span::kernel(k.name.clone()),
                        format!(
                            "{}/{}: {} words/cycle exceeds the {}-word bandwidth roof \
                             (§IV-J rule 1)",
                            k.name,
                            lsu.buffer,
                            bytes / eb,
                            roof_bytes / eb
                        ),
                    ));
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::patterns::{build_folded, default_factors, OptConfig};
    use crate::graph::models;

    #[test]
    fn divisor_helpers() {
        assert_eq!(largest_divisor_leq(400, 8), 8);
        assert_eq!(largest_divisor_leq(28, 5), 4);
        assert_eq!(largest_divisor_leq(7, 3), 1);
        assert_eq!(largest_divisor_leq(84, 10), 7);
        assert_eq!(divisors_leq(12, 6), vec![1, 2, 3, 4, 6]);
    }

    #[test]
    fn roof_is_about_76_words_at_250() {
        let dev = crate::device::FpgaDevice::stratix10sx();
        assert_eq!(dev.bw_floats_per_cycle(250.0).floor() as u64, 76);
    }

    #[test]
    fn default_plans_are_legal() {
        let dev = crate::device::FpgaDevice::stratix10sx();
        for g in models::all() {
            let plan = default_factors(&g);
            let (prog, _) = build_folded(&g, &OptConfig::optimized(), &plan);
            let v = check_program(&prog, &dev, 250.0);
            assert!(v.is_empty(), "{}: {:?}", g.name, v);
        }
    }
}
