//! The compilation flow (Fig. 1): frozen graph → scheduled kernels →
//! "synthesis" (AOC model) → performance simulation. This module is the
//! paper's primary contribution, re-hosted on explicit models.
//!
//! The staged API lives in [`session`]: [`Compiler`] selects a device
//! [`crate::device::Target`], [`CompileSession`] stages the pipeline, and
//! each stage returns a typed artifact ([`LoweredProgram`],
//! [`SynthesizedDesign`], [`Accelerator`]). The old monolithic
//! [`Flow::compile`] remains as a thin deprecated shim.

pub mod hybrid;
pub mod legality;
pub mod multi;
pub mod patterns;
pub mod report_json;
pub mod session;

use crate::aoc::{FmaxModel, SynthesisReport};
use crate::codegen::KernelProgram;
use crate::device::FpgaDevice;
use crate::graph::Graph;
use crate::schedule::OptKind;
use crate::sim::folded::LayerWork;
use crate::sim::{HostModel, PerformanceReport};

pub use patterns::{default_factors, FactorPlan, OptConfig, CANONICAL_PIPELINE};
pub use session::{
    program_fingerprint, CacheStats, CompileError, CompileSession, Compiler, LoweredProgram,
    ModeChoice, SynthesizedDesign,
};

/// Execution mode (§III).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// One kernel per layer, channels between them, all concurrently live.
    Pipelined,
    /// Parameterized kernels reused across layers; global-memory hand-off.
    Folded,
}

impl Mode {
    /// The paper deploys LeNet-5 pipelined and the larger networks folded
    /// (§III: pipelining requires all activations in on-chip memory).
    /// Decide by estimating the pipelined design's resources on the target
    /// device — channel FIFOs, weight stashes and lane banks included —
    /// and falling back to folded when BRAM or logic would be strained.
    /// Estimates the fully-optimized default-plan design; use
    /// [`Mode::auto_with`] to decide for a specific config + plan.
    pub fn auto(graph: &Graph, dev: &FpgaDevice) -> Mode {
        Mode::auto_with(graph, dev, &OptConfig::optimized(), &default_factors(graph))
    }

    /// [`Mode::auto`] for an explicit optimization config + factor plan —
    /// what `ModeChoice::Auto` uses, so the estimate matches the design
    /// the session will actually lower.
    pub fn auto_with(graph: &Graph, dev: &FpgaDevice, cfg: &OptConfig, plan: &FactorPlan) -> Mode {
        match auto_pipelined_candidate(graph, dev, cfg, plan) {
            Some(_) => Mode::Pipelined,
            None => Mode::Folded,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Mode::Pipelined => "pipelined",
            Mode::Folded => "folded",
        }
    }
}

/// Build the pipelined candidate design and return it when its estimated
/// utilization fits the device — the auto-mode decision, exposed crate-side
/// so `CompileSession::lower` can reuse the build (program, work list and
/// pass trace) instead of lowering the same program twice.
pub(crate) fn auto_pipelined_candidate(
    graph: &Graph,
    dev: &FpgaDevice,
    cfg: &OptConfig,
    plan: &FactorPlan,
) -> Option<patterns::BuiltProgram> {
    let built = patterns::build_with_passes(graph, Mode::Pipelined, cfg, plan);
    let u = crate::aoc::resources::program_resources(&built.program, dev).utilization;
    (u.bram_frac < 0.6 && u.logic_frac < 0.8).then_some(built)
}

/// Optimization level shortcut.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptLevel {
    /// TVM default schedule (§IV pathologies intact).
    Base,
    /// All Table-I optimizations for the mode.
    Optimized,
}

/// A fully compiled accelerator: kernels + synthesis + performance.
#[derive(Debug, Clone)]
pub struct Accelerator {
    pub network: String,
    pub mode: Mode,
    pub program: KernelProgram,
    pub synthesis: SynthesisReport,
    pub performance: PerformanceReport,
    pub work: Vec<LayerWork>,
    /// Table III row.
    pub applied: Vec<OptKind>,
    /// FLOPs per frame (for GFLOPS accounting).
    pub flops_per_frame: u64,
    /// Datapath precision (fp32 unless compiled through
    /// [`CompileSession::with_quantization`] or an explicit
    /// [`OptConfig::with_precision`]).
    pub precision: crate::texpr::Precision,
    /// Quantization report when the session quantized (calibration,
    /// boundary statistics, modeled top-1 loss).
    pub quant: Option<crate::quant::QuantReport>,
    /// Ordered trace of every graph/schedule pass the [`PassManager`]
    /// ran (or skipped, with the blocking rule) for this compilation —
    /// rendered by `fpga-flow explain` and emitted as the `pass_trace`
    /// section of `report_json`.
    ///
    /// [`PassManager`]: crate::pass::PassManager
    pub pass_trace: crate::pass::PassTrace,
    /// Static design-rule report ([`CompileSession::analyze`]) for the
    /// lowered program this accelerator was built from — the
    /// `diagnostics` section of `report_json`. Always free of Error-level
    /// findings here (a design that reaches simulation passed legality
    /// and fit); warnings/notes ride along.
    pub analysis: crate::analysis::AnalysisReport,
}

impl Accelerator {
    pub fn gflops(&self) -> f64 {
        self.performance.gflops(self.flops_per_frame)
    }
}

/// Legacy flow driver. Owns the device + models; superseded by the staged
/// [`Compiler`]/[`CompileSession`] API, which adds target selection and
/// synthesis memoization — `Flow`'s compile entry points delegate there.
///
/// # Migration
///
/// | deprecated shim                  | replacement                               |
/// |----------------------------------|-------------------------------------------|
/// | `Flow::new()`                    | [`Compiler::for_target`] / [`Compiler::new`] |
/// | `Flow::compile(g, mode, level)`  | [`Compiler::compile`] (same arguments)    |
/// | `Flow::compile_with(g, m, c, p)` | [`Compiler::compile_with`]                |
/// | `Flow::compile_hybrid` / `best_hybrid` | the same methods on [`Compiler`]    |
/// | `Flow::compile_multi`            | [`Compiler::compile_multi`]               |
///
/// A hand-tuned `Flow { device, fmax_model, host }` maps to
/// [`Compiler::from_parts`]. The shims construct a fresh `Compiler` per
/// call, so they also get a fresh (empty) synthesis memo — sweeps that
/// want cache hits must hold one `Compiler` and go through it directly.
/// The shims will be removed once nothing in-tree calls them.
#[derive(Debug, Clone)]
pub struct Flow {
    pub device: FpgaDevice,
    pub fmax_model: FmaxModel,
    pub host: HostModel,
}

impl Default for Flow {
    fn default() -> Self {
        Self::new()
    }
}

impl Flow {
    pub fn new() -> Flow {
        Flow {
            device: FpgaDevice::stratix10sx(),
            fmax_model: FmaxModel::default(),
            host: HostModel::default(),
        }
    }

    /// The equivalent staged compiler (fresh synthesis memo per call).
    fn compiler(&self) -> Compiler {
        Compiler::from_parts(self.device.clone(), self.fmax_model, self.host)
    }

    /// Compile with defaults for the level.
    #[deprecated(since = "0.2.0", note = "use Compiler::for_target(..)?.graph(..) staged API")]
    pub fn compile(&self, graph: &Graph, mode: Mode, level: OptLevel) -> crate::Result<Accelerator> {
        self.compiler().compile(graph, mode, level)
    }

    /// Compile with an explicit optimization config + factor plan.
    #[deprecated(since = "0.2.0", note = "use Compiler::for_target(..)?.graph(..) staged API")]
    pub fn compile_with(
        &self,
        graph: &Graph,
        mode: Mode,
        cfg: &OptConfig,
        plan: &FactorPlan,
    ) -> crate::Result<Accelerator> {
        self.compiler().compile_with(graph, mode, cfg, plan)
    }

    /// The mode the paper uses for each evaluation network (Table III).
    pub fn paper_mode(network: &str) -> Mode {
        Compiler::paper_mode(network)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    #[test]
    fn auto_mode_matches_paper_choices() {
        let dev = FpgaDevice::stratix10sx();
        assert_eq!(Mode::auto(&models::lenet5(), &dev), Mode::Pipelined);
        assert_eq!(Mode::auto(&models::mobilenet_v1(), &dev), Mode::Folded);
        assert_eq!(Mode::auto(&models::resnet34(), &dev), Mode::Folded);
    }

    #[test]
    fn auto_mode_depends_on_target_size() {
        // LeNet-5 pipelines comfortably on the D5005 but strains the much
        // smaller Arria 10 BRAM budget only partially — it must still pick
        // a mode without panicking on any registered target.
        for t in crate::device::Target::all() {
            let m = Mode::auto(&models::lenet5(), &t.device);
            assert!(matches!(m, Mode::Pipelined | Mode::Folded));
        }
    }

    #[test]
    fn lenet_compiles_both_levels() {
        let compiler = Compiler::default();
        let g = models::lenet5();
        let base = compiler.compile(&g, Mode::Pipelined, OptLevel::Base).unwrap();
        let opt = compiler.compile(&g, Mode::Pipelined, OptLevel::Optimized).unwrap();
        assert!(opt.performance.fps > base.performance.fps * 3.0,
            "opt {} vs base {}", opt.performance.fps, base.performance.fps);
        assert!(opt.synthesis.fmax_mhz > 100.0);
    }

    #[test]
    fn optimized_applies_table3_rows() {
        let compiler = Compiler::default();
        // LeNet-5 row: LU LF CW OF CH AR CE (no PK/LT)
        let l = compiler.compile(&models::lenet5(), Mode::Pipelined, OptLevel::Optimized).unwrap();
        assert!(l.applied.contains(&OptKind::Channels));
        assert!(!l.applied.contains(&OptKind::Parameterize));
        // MobileNet row: PK LU LT LF CW OF (no CH/AR/CE)
        let m = compiler.compile(&models::mobilenet_v1(), Mode::Folded, OptLevel::Optimized).unwrap();
        assert!(m.applied.contains(&OptKind::Parameterize));
        assert!(m.applied.contains(&OptKind::Tile));
        assert!(!m.applied.contains(&OptKind::Channels));
        assert!(!m.applied.contains(&OptKind::Autorun));
        assert!(!m.applied.contains(&OptKind::Concurrent));
    }

    #[test]
    fn all_networks_fit_when_optimized() {
        let compiler = Compiler::default();
        for g in models::all() {
            let mode = Compiler::paper_mode(&g.name);
            let acc = compiler.compile(&g, mode, OptLevel::Optimized).unwrap();
            assert!(acc.synthesis.resources.utilization.fits(), "{}", g.name);
            assert!(acc.performance.fps > 0.0);
        }
    }

    #[test]
    fn gflops_scale_with_fps() {
        let compiler = Compiler::default();
        let acc = compiler.compile(&models::lenet5(), Mode::Pipelined, OptLevel::Optimized).unwrap();
        let expect = acc.performance.fps * acc.flops_per_frame as f64 / 1e9;
        assert!((acc.gflops() - expect).abs() < 1e-9);
    }

    #[test]
    #[allow(deprecated)]
    fn flow_shim_matches_staged_compiler() {
        // The deprecated monolithic entry point must produce the same
        // design as the staged API it delegates to.
        let g = models::lenet5();
        let via_flow = Flow::new().compile(&g, Mode::Pipelined, OptLevel::Optimized).unwrap();
        let via_compiler =
            Compiler::default().compile(&g, Mode::Pipelined, OptLevel::Optimized).unwrap();
        assert_eq!(via_flow.performance.fps, via_compiler.performance.fps);
        assert_eq!(via_flow.synthesis.fmax_mhz, via_compiler.synthesis.fmax_mhz);
        assert_eq!(via_flow.applied, via_compiler.applied);
    }
}
