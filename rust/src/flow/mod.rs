//! The compilation flow (Fig. 1): frozen graph → scheduled kernels →
//! "synthesis" (AOC model) → performance simulation. This module is the
//! paper's primary contribution, re-hosted on explicit models.

pub mod hybrid;
pub mod legality;
pub mod multi;
pub mod patterns;
pub mod report_json;

use crate::aoc::{self, FmaxModel, SynthesisReport};
use crate::codegen::KernelProgram;
use crate::device::FpgaDevice;
use crate::graph::Graph;
use crate::schedule::OptKind;
use crate::sim::folded::LayerWork;
use crate::sim::{folded, pipelined, HostModel, PerformanceReport};

pub use patterns::{default_factors, FactorPlan, OptConfig};

/// Execution mode (§III).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// One kernel per layer, channels between them, all concurrently live.
    Pipelined,
    /// Parameterized kernels reused across layers; global-memory hand-off.
    Folded,
}

impl Mode {
    /// The paper deploys LeNet-5 pipelined and the larger networks folded
    /// (§III: pipelining requires all activations in on-chip memory).
    /// Decide by whether weights + largest activations fit in ~60% of BRAM.
    pub fn auto(graph: &Graph, dev: &FpgaDevice) -> Mode {
        let need_bits = (graph.weight_bytes() + 2 * graph.max_activation_bytes()) * 8;
        if (need_bits as f64) < 0.6 * dev.bram_bits as f64 {
            Mode::Pipelined
        } else {
            Mode::Folded
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Mode::Pipelined => "pipelined",
            Mode::Folded => "folded",
        }
    }
}

/// Optimization level shortcut.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptLevel {
    /// TVM default schedule (§IV pathologies intact).
    Base,
    /// All Table-I optimizations for the mode.
    Optimized,
}

/// A fully compiled accelerator: kernels + synthesis + performance.
#[derive(Debug, Clone)]
pub struct Accelerator {
    pub network: String,
    pub mode: Mode,
    pub program: KernelProgram,
    pub synthesis: SynthesisReport,
    pub performance: PerformanceReport,
    pub work: Vec<LayerWork>,
    /// Table III row.
    pub applied: Vec<OptKind>,
    /// FLOPs per frame (for GFLOPS accounting).
    pub flops_per_frame: u64,
}

impl Accelerator {
    pub fn gflops(&self) -> f64 {
        self.performance.gflops(self.flops_per_frame)
    }
}

/// Flow driver. Owns the device + models; `compile` runs the whole Fig.-1
/// pipeline in milliseconds (the real flow's AOC+Quartus step takes
/// "3 to 12 hours", §IV-J).
#[derive(Debug, Clone)]
pub struct Flow {
    pub device: FpgaDevice,
    pub fmax_model: FmaxModel,
    pub host: HostModel,
}

impl Default for Flow {
    fn default() -> Self {
        Self::new()
    }
}

impl Flow {
    pub fn new() -> Flow {
        Flow {
            device: FpgaDevice::stratix10sx(),
            fmax_model: FmaxModel::default(),
            host: HostModel::default(),
        }
    }

    /// Compile with defaults for the level.
    pub fn compile(&self, graph: &Graph, mode: Mode, level: OptLevel) -> crate::Result<Accelerator> {
        let cfg = match level {
            OptLevel::Base => OptConfig::base(),
            OptLevel::Optimized => OptConfig::optimized(),
        };
        self.compile_with(graph, mode, &cfg, &default_factors(graph))
    }

    /// Compile with an explicit optimization config + factor plan (DSE and
    /// the ablation benches drive this).
    pub fn compile_with(
        &self,
        graph: &Graph,
        mode: Mode,
        cfg: &OptConfig,
        plan: &FactorPlan,
    ) -> crate::Result<Accelerator> {
        graph.validate().map_err(|e| anyhow::anyhow!("invalid graph: {e}"))?;
        let (program, work) = match mode {
            Mode::Pipelined => patterns::build_pipelined(graph, cfg, plan),
            Mode::Folded => patterns::build_folded(graph, cfg, plan),
        };

        // Rule 1/2 legality (rule 3 = fit, checked by synthesize()).
        let violations = legality::check_program(&program, &self.device, 250.0);
        if !violations.is_empty() {
            anyhow::bail!(
                "illegal factor plan for {}: {}",
                graph.name,
                violations.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("; ")
            );
        }

        let synthesis = aoc::synthesize(&program, &self.device, &self.fmax_model)?;
        let fmax = synthesis.fmax_mhz;
        let performance = match mode {
            Mode::Pipelined => pipelined::simulate(&program, &self.device, fmax, &self.host),
            Mode::Folded => folded::simulate(&program, &work, &self.device, fmax, &self.host),
        };
        let applied = patterns::applied_summary(&program);

        Ok(Accelerator {
            network: graph.name.clone(),
            mode,
            program,
            synthesis,
            performance,
            work,
            applied,
            flops_per_frame: graph.total_flops(),
        })
    }

    /// The mode the paper uses for each evaluation network (Table III).
    pub fn paper_mode(network: &str) -> Mode {
        match network {
            "lenet5" => Mode::Pipelined,
            _ => Mode::Folded,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    #[test]
    fn auto_mode_matches_paper_choices() {
        let dev = FpgaDevice::stratix10sx();
        assert_eq!(Mode::auto(&models::lenet5(), &dev), Mode::Pipelined);
        assert_eq!(Mode::auto(&models::mobilenet_v1(), &dev), Mode::Folded);
        assert_eq!(Mode::auto(&models::resnet34(), &dev), Mode::Folded);
    }

    #[test]
    fn lenet_compiles_both_levels() {
        let flow = Flow::new();
        let g = models::lenet5();
        let base = flow.compile(&g, Mode::Pipelined, OptLevel::Base).unwrap();
        let opt = flow.compile(&g, Mode::Pipelined, OptLevel::Optimized).unwrap();
        assert!(opt.performance.fps > base.performance.fps * 3.0,
            "opt {} vs base {}", opt.performance.fps, base.performance.fps);
        assert!(opt.synthesis.fmax_mhz > 100.0);
    }

    #[test]
    fn optimized_applies_table3_rows() {
        let flow = Flow::new();
        // LeNet-5 row: LU LF CW OF CH AR CE (no PK/LT)
        let l = flow.compile(&models::lenet5(), Mode::Pipelined, OptLevel::Optimized).unwrap();
        assert!(l.applied.contains(&OptKind::Channels));
        assert!(!l.applied.contains(&OptKind::Parameterize));
        // MobileNet row: PK LU LT LF CW OF (no CH/AR/CE)
        let m = flow.compile(&models::mobilenet_v1(), Mode::Folded, OptLevel::Optimized).unwrap();
        assert!(m.applied.contains(&OptKind::Parameterize));
        assert!(m.applied.contains(&OptKind::Tile));
        assert!(!m.applied.contains(&OptKind::Channels));
        assert!(!m.applied.contains(&OptKind::Autorun));
        assert!(!m.applied.contains(&OptKind::Concurrent));
    }

    #[test]
    fn all_networks_fit_when_optimized() {
        let flow = Flow::new();
        for g in models::all() {
            let mode = Flow::paper_mode(&g.name);
            let acc = flow.compile(&g, mode, OptLevel::Optimized).unwrap();
            assert!(acc.synthesis.resources.utilization.fits(), "{}", g.name);
            assert!(acc.performance.fps > 0.0);
        }
    }

    #[test]
    fn gflops_scale_with_fps() {
        let flow = Flow::new();
        let acc = flow.compile(&models::lenet5(), Mode::Pipelined, OptLevel::Optimized).unwrap();
        let expect = acc.performance.fps * acc.flops_per_frame as f64 / 1e9;
        assert!((acc.gflops() - expect).abs() < 1e-9);
    }
}
