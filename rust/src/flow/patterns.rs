//! Pattern-based application of the paper's optimizations (Table I) and
//! the construction of the kernel program for each execution mode (§III).
//!
//! | Opt | Pipelined | Folded | Pattern (Table I)                          |
//! |-----|-----------|--------|--------------------------------------------|
//! | LU  | ✓         | ✓      | all kernels except transpose/padding       |
//! | LF  | ✓         | ✓      | activation/batchnorm in conv, FC, pooling  |
//! | CW  | ✓         | ✓      | all kernels except transpose/padding       |
//! | OF  | ✓         | ✓      | -fpc -fp-relaxed for all bitstreams        |
//! | CH  | ✓         |        | movement of activations, all layers        |
//! | AR  | ✓         |        | pooling, transpose/padding                 |
//! | CE  | ✓         |        | host optimization                          |
//! | PK  |           | ✓      | convs with same stride and filter size     |
//! | LT  |           | ✓      | conv, FC                                   |

use std::collections::BTreeMap;

use crate::codegen::{Channel, Kernel, KernelProgram};
use crate::graph::{Graph, GroupKind, Node, Op, ParamGroup};
use crate::schedule::{OptKind, Scheduler};
use crate::sim::folded::LayerWork;
use crate::texpr::{self, Epilogue, LoopVar};

use super::legality;

/// Which optimizations are enabled (ablation switch-board).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OptConfig {
    pub unroll: bool,
    pub tile: bool,
    pub fuse: bool,
    pub cached_writes: bool,
    pub float_opt: bool,
    pub channels: bool,
    pub autorun: bool,
    pub concurrent: bool,
    pub parameterize: bool,
    /// Extension (§VII): datapath precision (fp32 = the paper's setting).
    pub precision: crate::texpr::Precision,
    /// Extension (§V-F): vector types align strided loads.
    pub vectorize: bool,
    /// Extension (§VII future work #2): weight density in (0, 1] — a
    /// zero-skipping datapath (HPIPE-style, the paper's related work §VI)
    /// skips MACs whose weight is pruned away. 1.0 = dense (the paper).
    pub weight_density: f64,
}

impl OptConfig {
    /// TVM's default schedule: nothing enabled (§IV's pathology list).
    pub fn base() -> Self {
        OptConfig {
            unroll: false,
            tile: false,
            fuse: false,
            cached_writes: false,
            float_opt: false,
            channels: false,
            autorun: false,
            concurrent: false,
            parameterize: false,
            precision: crate::texpr::Precision::F32,
            vectorize: false,
            weight_density: 1.0,
        }
    }

    /// Everything Table I allows for the mode.
    pub fn optimized() -> Self {
        OptConfig {
            unroll: true,
            tile: true,
            fuse: true,
            cached_writes: true,
            float_opt: true,
            channels: true,
            autorun: true,
            concurrent: true,
            parameterize: true,
            // The paper evaluates fp32 without vector types; the
            // extensions stay opt-in (see `with_precision`, `with_vectors`).
            precision: crate::texpr::Precision::F32,
            vectorize: false,
            weight_density: 1.0,
        }
    }

    /// Extension (§VII #2): prune weights to `density` and skip zero MACs.
    pub fn with_sparsity(mut self, density: f64) -> Self {
        assert!((0.0..=1.0).contains(&density) && density > 0.0);
        self.weight_density = density;
        self
    }

    /// Extension: reduced-precision datapath (paper §VII future work).
    pub fn with_precision(mut self, p: crate::texpr::Precision) -> Self {
        self.precision = p;
        self
    }

    /// Extension: vectorized aligned loads (§V-F mitigation).
    pub fn with_vectors(mut self) -> Self {
        self.vectorize = true;
        self
    }

    /// Disable one optimization (ablation benches).
    pub fn without(mut self, opt: OptKind) -> Self {
        match opt {
            OptKind::Unroll => self.unroll = false,
            OptKind::Tile => self.tile = false,
            OptKind::Fuse => self.fuse = false,
            OptKind::CachedWrite => self.cached_writes = false,
            OptKind::FloatOpt => self.float_opt = false,
            OptKind::Channels => self.channels = false,
            OptKind::Autorun => self.autorun = false,
            OptKind::Concurrent => self.concurrent = false,
            OptKind::Parameterize => self.parameterize = false,
            OptKind::Quantize => self.precision = crate::texpr::Precision::F32,
            OptKind::Vectorize => self.vectorize = false,
            OptKind::Sparsify => self.weight_density = 1.0,
        }
        self
    }
}

/// Per-group tile/unroll factors for folded mode; per-node caps for
/// pipelined. Produced by [`default_factors`] or by the DSE.
#[derive(Debug, Clone, Default)]
pub struct FactorPlan {
    /// Folded: (input-channel tile, output-channel tile) per group.
    pub group_tiles: BTreeMap<ParamGroup, (u64, u64)>,
    /// Pipelined: max unroll lanes per kernel.
    pub pipelined_cap: u64,
    /// Dense reduction tile (both modes).
    pub dense_tile: (u64, u64),
}

/// The factor choices used for the paper's Table II–V runs. Chosen by the
/// §IV-J rules (bandwidth roof, divisibility, resource fit); the DSE
/// (`crate::dse`) rediscovers factors of this magnitude automatically.
pub fn default_factors(graph: &Graph) -> FactorPlan {
    let mut plan = FactorPlan {
        group_tiles: BTreeMap::new(),
        pipelined_cap: 256,
        dense_tile: (8, 10),
    };
    for node in graph.topo() {
        if let Some(g) = node.op.param_group() {
            let tile = match g.kind {
                GroupKind::Conv => {
                    // Total MAC lanes = k² × t_ic × t_oc (the filter taps
                    // are fully unrolled for k ≥ 3): budget each group to a
                    // few hundred lanes so the summed DSP count lands near
                    // Table II's utilization.
                    if g.kernel == 1 && g.stride == 1 {
                        (32, 16) // the MobileNet workhorse (§III): 512 lanes
                    } else if g.kernel >= 7 {
                        (1, 2) // conv1-style: 49 taps × 2 = 98 lanes
                    } else if g.kernel >= 5 {
                        (2, 8) // 5×5: 400 lanes
                    } else if g.stride == 1 && g.kernel == 3 {
                        (8, 8) // 3×3 workhorse (ResNet): 576 lanes
                    } else if g.kernel == 1 {
                        (16, 8) // 1×1 downsample: 128 lanes
                    } else {
                        (2, 4) // strided 3×3: 72 lanes
                    }
                }
                GroupKind::Depthwise => (8, 1),
                GroupKind::Dense => (8, 10),
            };
            plan.group_tiles.entry(g).or_insert(tile);
        }
    }
    plan
}

/// Is `node` an epilogue op (BN / activation) fusible into its producer?
fn fusible_epilogue(graph: &Graph, node: &Node, consumers: &[Vec<usize>]) -> bool {
    if !matches!(node.op, Op::BatchNorm | Op::Activate(_)) {
        return false;
    }
    let producer = &graph.nodes[node.inputs[0]];
    // Fuse into compute ops and pooling (Table I pattern), when the
    // producer has no other consumer.
    (producer.op.is_compute()
        || matches!(producer.op, Op::BatchNorm | Op::Activate(_) | Op::Add | Op::MaxPool { .. } | Op::AvgPool { .. }))
        && consumers[producer.id].len() == 1
}

fn epilogue_of_node(node: &Node) -> Epilogue {
    match node.op {
        Op::BatchNorm => Epilogue::BatchNormFold,
        Op::Activate(a) => Epilogue::Activation(a),
        _ => unreachable!("only BN/Act absorb"),
    }
}

/// Resolve the kernel-bearing ancestor of `id` after fusion/skip decisions:
/// follows through absorbed BN/Act nodes and Flatten/Input pass-throughs.
fn resolve_producer(absorbed_into: &BTreeMap<usize, usize>, skipped: &[bool], graph: &Graph, mut id: usize) -> usize {
    loop {
        if let Some(&host) = absorbed_into.get(&id) {
            id = host;
            continue;
        }
        if skipped[id] {
            match graph.nodes[id].inputs.first() {
                Some(&prev) => {
                    id = prev;
                    continue;
                }
                None => return id, // graph input: no producing kernel
            }
        }
        return id;
    }
}

/// Layer-to-kernel construction shared by both modes. Returns, per
/// surviving node: its scheduled kernel, plus the absorption map.
struct Mapped {
    kernels: Vec<Kernel>,
    /// node id → kernel index (for surviving nodes).
    node_kernel: BTreeMap<usize, usize>,
    /// absorbed node → host node.
    absorbed_into: BTreeMap<usize, usize>,
    skipped: Vec<bool>,
}

fn map_layers(graph: &Graph, cfg: &OptConfig, folded: bool, plan: &FactorPlan) -> Mapped {
    let consumers = graph.consumers();
    let mut absorbed_into: BTreeMap<usize, usize> = BTreeMap::new();
    let mut skipped = vec![false; graph.nodes.len()];
    // Pass 1: decide skips (Input/Flatten/Transform are layout-only) and
    // epilogue absorption (LF).
    for node in graph.topo() {
        match node.op {
            Op::Input | Op::Flatten | Op::Transform => skipped[node.id] = true,
            _ => {}
        }
        if cfg.fuse && fusible_epilogue(graph, node, &consumers) {
            // Chase through already-absorbed producers so conv→bn→relu
            // folds completely into the conv kernel.
            let mut host = node.inputs[0];
            while let Some(&h) = absorbed_into.get(&host) {
                host = h;
            }
            // Table I pattern: activation/batchnorm fuse into conv, FC and
            // pooling; residual adds also take the trailing ReLU.
            if graph.nodes[host].op.is_compute()
                || matches!(
                    graph.nodes[host].op,
                    Op::Add | Op::MaxPool { .. } | Op::AvgPool { .. } | Op::GlobalAvgPool
                )
            {
                absorbed_into.insert(node.id, host);
            }
        }
    }

    // Pass 2: build kernels.
    let mut kernels: Vec<Kernel> = Vec::new();
    let mut node_kernel: BTreeMap<usize, usize> = BTreeMap::new();
    // Folded: one kernel per parameter group.
    let mut group_kernel: BTreeMap<ParamGroup, usize> = BTreeMap::new();

    for node in graph.topo() {
        if skipped[node.id] || absorbed_into.contains_key(&node.id) {
            continue;
        }
        let input_shape = &graph.nodes[node.inputs[0]].shape;

        if folded && cfg.parameterize {
            if let Some(g) = node.op.param_group() {
                if let Some(&kid) = group_kernel.get(&g) {
                    node_kernel.insert(node.id, kid);
                    // Extend the group's epilogue set with this layer's
                    // absorbed ops (runtime-selected per layer).
                    continue;
                }
            }
        }

        let mut nest = texpr::lower(node, input_shape);
        let mut s = Scheduler::new(&mut nest);

        // Absorb fused epilogues (LF).
        for (&abs, &host) in &absorbed_into {
            if host == node.id {
                s.absorb_epilogue(epilogue_of_node(&graph.nodes[abs]));
            }
        }
        if cfg.fuse && s.nest.separate_epilogue {
            let _ = s.fuse_epilogue();
        }

        // CW: cached accumulation (all kernels except transpose/padding).
        if cfg.cached_writes && !node.op.unroll_exempt() {
            let _ = s.cache_write();
        }

        // OF: float flags apply to the whole bitstream.
        if cfg.float_opt {
            s.applied.record(OptKind::FloatOpt);
        }

        // Extensions: reduced precision + vector types (§VII / §V-F).
        // Only grid-domain kernels narrow — f32 islands the Q/DQ rewrite
        // deliberately left wide (softmax, global pooling, dequantize)
        // keep their f32 buffers; a Quantize boundary writes the narrow
        // stream, so it is scheduled at the target precision too.
        if cfg.precision != crate::texpr::Precision::F32
            && (crate::quant::rewrite::grid_capable(&node.op)
                || matches!(node.op, Op::Quantize { .. }))
        {
            s.quantize(cfg.precision);
        }
        if cfg.vectorize {
            s.vectorize("ifmap");
        }
        if cfg.weight_density < 1.0 && node.op.is_compute() {
            s.sparsify(cfg.weight_density);
        }

        // LU/LT: factor selection per mode.
        if node.op.is_compute() {
            if folded {
                if cfg.parameterize {
                    s.parameterize();
                }
                if cfg.tile && cfg.unroll {
                    apply_folded_tiles(&mut s, node, plan);
                } else if cfg.unroll {
                    // unroll without tiling: full filter taps only
                    for v in [LoopVar::KH, LoopVar::KW] {
                        let _ = s.unroll(v);
                    }
                }
                // Folded kernels stage operand tiles in BRAM.
                if cfg.cached_writes {
                    let _ = s.cache_read("weights");
                    let _ = s.cache_read("ifmap");
                    tile_stash_bytes(&mut s, plan, node);
                }
            } else if cfg.unroll {
                apply_pipelined_unroll(&mut s, node, plan);
            }
        } else if cfg.unroll && !node.op.unroll_exempt() {
            // Pools etc: unroll the window taps (Table I: all kernels
            // except transpose/padding), capped at 8 per dim so huge
            // global-average windows stay under the bandwidth roof.
            for v in [LoopVar::KH, LoopVar::KW] {
                if let Some(l) = s.nest.find_loop(v) {
                    let f = legality::largest_divisor_leq(l.extent, 8);
                    let _ = s.tile_and_unroll(v, f);
                }
            }
            if !folded {
                record_strip_mine_as_unroll(&mut s);
            }
        }

        // CH: pipelined activations move via channels; first/last kernels
        // keep their global image/logits access.
        if !folded && cfg.channels {
            s.channelize("ifmap");
            s.channelize("ofmap");
            let _ = s.cache_read("weights"); // weight stash in BRAM
        }

        let applied = s.finish();
        let kid = kernels.len();
        kernels.push(Kernel {
            id: kid,
            name: format!("k{}_{}", kid, nest.name),
            nest,
            applied,
            autorun: false, // decided after channel wiring
            layers: vec![node.id],
            group: if folded && cfg.parameterize { node.op.param_group() } else { None },
            queue: 0,
        });
        node_kernel.insert(node.id, kid);
        if folded && cfg.parameterize {
            if let Some(g) = node.op.param_group() {
                group_kernel.insert(g, kid);
            }
        }
    }

    // Record layer membership for group kernels.
    for (&nid, &kid) in &node_kernel {
        if !kernels[kid].layers.contains(&nid) {
            kernels[kid].layers.push(nid);
        }
    }

    Mapped { kernels, node_kernel, absorbed_into, skipped }
}

/// In pipelined mode strip-mine+full-inner-unroll is reported as LU, not
/// LT — the paper's Table III applies LT only to folded designs.
fn record_strip_mine_as_unroll(s: &mut Scheduler) {
    if s.applied.opts.contains(&OptKind::Tile) {
        s.applied.opts.retain(|o| *o != OptKind::Tile);
        s.applied.record(OptKind::Unroll);
    }
}

fn apply_pipelined_unroll(s: &mut Scheduler, node: &Node, plan: &FactorPlan) {
    let cap = plan.pipelined_cap.max(1);
    match node.op {
        Op::Dense { .. } => {
            let (t_in, _) = plan.dense_tile;
            let extent = s.nest.find_loop(LoopVar::InC).map(|l| l.extent).unwrap_or(1);
            let f = legality::largest_divisor_leq(extent, t_in);
            let _ = s.tile_and_unroll(LoopVar::InC, f);
            record_strip_mine_as_unroll(s);
        }
        _ => {
            // Unroll reduction loops innermost-first while ≤ cap, then the
            // output-channel loop if it still fits (full unrolls only).
            let mut product = 1u64;
            for v in [LoopVar::KW, LoopVar::KH, LoopVar::InC] {
                if let Some(l) = s.nest.find_loop(v) {
                    if l.reduction && product * l.extent <= cap {
                        product *= l.extent;
                        let _ = s.unroll(v);
                    }
                }
            }
            if let Some(l) = s.nest.find_loop(LoopVar::OutC) {
                if product * l.extent <= cap {
                    let _ = s.unroll(LoopVar::OutC);
                }
            }
        }
    }
}

fn apply_folded_tiles(s: &mut Scheduler, node: &Node, plan: &FactorPlan) {
    let Some(g) = node.op.param_group() else { return };
    match g.kind {
        GroupKind::Dense => {
            let (t_in, t_out) = plan.dense_tile;
            for (v, t) in [(LoopVar::InC, t_in), (LoopVar::OutC, t_out)] {
                if let Some(l) = s.nest.find_loop(v) {
                    let f = legality::largest_divisor_leq(l.extent, t);
                    let _ = s.tile_and_unroll(v, f);
                }
            }
        }
        GroupKind::Depthwise => {
            let (t_c, _) = plan.group_tiles.get(&g).copied().unwrap_or((8, 1));
            for v in [LoopVar::KH, LoopVar::KW] {
                let _ = s.unroll(v);
            }
            if let Some(l) = s.nest.find_loop(LoopVar::OutC) {
                let f = legality::largest_divisor_leq(l.extent, t_c);
                let _ = s.tile_and_unroll(LoopVar::OutC, f);
            }
        }
        GroupKind::Conv => {
            let (t_ic, t_oc) = plan.group_tiles.get(&g).copied().unwrap_or((8, 8));
            if g.kernel >= 3 {
                for v in [LoopVar::KH, LoopVar::KW] {
                    let _ = s.unroll(v);
                }
            }
            if let Some(l) = s.nest.find_loop(LoopVar::InC) {
                let f = legality::largest_divisor_leq(l.extent, t_ic);
                let _ = s.tile_and_unroll(LoopVar::InC, f);
            }
            if let Some(l) = s.nest.find_loop(LoopVar::OutC) {
                let f = legality::largest_divisor_leq(l.extent, t_oc);
                let _ = s.tile_and_unroll(LoopVar::OutC, f);
            }
        }
    }
}

/// Size the BRAM tile stashes of a folded kernel: double-buffered weight
/// tile + an input line strip, at the datapath's element width.
fn tile_stash_bytes(s: &mut Scheduler, plan: &FactorPlan, node: &Node) {
    let Some(g) = node.op.param_group() else { return };
    let (t_ic, t_oc) = plan.group_tiles.get(&g).copied().unwrap_or((8, 8));
    let k2 = (g.kernel * g.kernel) as u64;
    let eb = s.nest.precision.bytes();
    for a in &mut s.nest.accesses {
        if a.space == crate::texpr::MemSpace::Local {
            a.array_bytes = match a.buffer.as_str() {
                "weights" => 2 * t_ic * t_oc * k2 * eb,
                // strip of k input rows × tile channels (max W on chip 224)
                "ifmap" => 2 * t_ic * (g.kernel as u64) * 224 * eb,
                _ => a.array_bytes,
            };
        }
    }
}

/// Build the pipelined-mode program (§III): one kernel per surviving layer,
/// channel-connected in topological order.
pub fn build_pipelined(graph: &Graph, cfg: &OptConfig, plan: &FactorPlan) -> (KernelProgram, Vec<LayerWork>) {
    let mut mapped = map_layers(graph, cfg, false, plan);

    // Channels between consecutive kernels (CH). Each FIFO carries its
    // *producer's* element type: quantized streams pack more elements per
    // BRAM block (§VII extension), while f32-island stages keep wide FIFOs.
    let mut channels = Vec::new();
    if cfg.channels {
        let depth = (graph.max_activation_bytes() / 4).max(16);
        for k in &mapped.kernels {
            let node = &graph.nodes[k.layers[0]];
            for &inp in &node.inputs {
                let src = resolve_producer(&mapped.absorbed_into, &mapped.skipped, graph, inp);
                if let Some(&src_k) = mapped.node_kernel.get(&src) {
                    if src_k != k.id {
                        channels.push(Channel {
                            name: format!("ch_{}_{}", src_k, k.id),
                            from_kernel: src_k,
                            to_kernel: k.id,
                            depth,
                            elem: mapped.kernels[src_k].nest.precision,
                        });
                    }
                }
            }
        }
    }

    // AR: weightless channel-only kernels become autorun.
    if cfg.autorun {
        for k in &mut mapped.kernels {
            let node = &graph.nodes[k.layers[0]];
            if !node.op.has_weights() && k.autorun_eligible() {
                k.autorun = true;
                k.applied.record(OptKind::Autorun);
            }
        }
    }

    // CE: one queue per kernel.
    let queues = if cfg.concurrent { mapped.kernels.len().max(1) } else { 1 };
    if cfg.concurrent {
        for (q, k) in mapped.kernels.iter_mut().enumerate() {
            k.queue = q;
            k.applied.record(OptKind::Concurrent);
        }
    }

    let prog = KernelProgram { name: format!("{}_pipelined", graph.name), kernels: mapped.kernels, channels, queues };
    let work = work_list(graph, &mapped.node_kernel, &mapped.absorbed_into, &mapped.skipped);
    (prog, work)
}

/// Build the folded-mode program (§III, §IV-H): parameterized kernels per
/// (filter, stride) group; feature maps round-trip through global memory.
pub fn build_folded(graph: &Graph, cfg: &OptConfig, plan: &FactorPlan) -> (KernelProgram, Vec<LayerWork>) {
    let mapped = map_layers(graph, cfg, true, plan);
    let prog = KernelProgram {
        name: format!("{}_folded", graph.name),
        kernels: mapped.kernels,
        channels: vec![],
        queues: 1, // CE not applicable (§IV-J)
    };
    let work = work_list(graph, &mapped.node_kernel, &mapped.absorbed_into, &mapped.skipped);
    (prog, work)
}

fn work_list(
    graph: &Graph,
    node_kernel: &BTreeMap<usize, usize>,
    absorbed: &BTreeMap<usize, usize>,
    skipped: &[bool],
) -> Vec<LayerWork> {
    let mut work = Vec::new();
    for node in graph.topo() {
        if skipped[node.id] || absorbed.contains_key(&node.id) {
            continue;
        }
        let Some(&kid) = node_kernel.get(&node.id) else { continue };
        let nest = texpr::lower(node, &graph.nodes[node.inputs[0]].shape);
        work.push(LayerWork {
            node_id: node.id,
            layer_name: node.name.clone(),
            kernel_id: kid,
            out_elems: nest.out_elems,
            reduction: nest.reduction_size,
        });
    }
    work
}

/// Which optimizations ended up applied across a program — the Table III
/// row for a network.
pub fn applied_summary(prog: &KernelProgram) -> Vec<OptKind> {
    let mut out: Vec<OptKind> = Vec::new();
    for k in &prog.kernels {
        for o in &k.applied.opts {
            if !out.contains(o) {
                out.push(*o);
            }
        }
    }
    out.sort();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    #[test]
    fn lenet_pipelined_optimized_structure() {
        let g = models::lenet5();
        let (prog, work) = build_pipelined(&g, &OptConfig::optimized(), &default_factors(&g));
        // c1, s2, c3, s4, f5, f6, f7 → 7 kernels (flatten skipped)
        assert_eq!(prog.kernels.len(), 7);
        assert_eq!(prog.queues, 7);
        assert_eq!(prog.channels.len(), 6);
        assert_eq!(work.len(), 7);
        // pools are autorun (weightless, channel-fed)
        assert!(prog.kernels.iter().any(|k| k.autorun));
        // convs/dense are not (weights still loaded from global at init)
        let summary = applied_summary(&prog);
        for o in [OptKind::Unroll, OptKind::Fuse, OptKind::CachedWrite, OptKind::FloatOpt, OptKind::Channels, OptKind::Autorun, OptKind::Concurrent] {
            assert!(summary.contains(&o), "{o:?} missing from {summary:?}");
        }
        assert!(!summary.contains(&OptKind::Parameterize));
    }

    #[test]
    fn lenet_base_has_no_opts() {
        let g = models::lenet5();
        let (prog, _) = build_pipelined(&g, &OptConfig::base(), &default_factors(&g));
        assert!(applied_summary(&prog).is_empty());
        assert_eq!(prog.queues, 1);
        assert!(prog.channels.is_empty());
        assert_eq!(prog.autorun_count(), 0);
        // BN/act don't exist in LeNet; epilogues stay separate
        assert!(prog.kernels.iter().filter(|k| k.nest.macs_per_iter > 0).all(|k| k.nest.separate_epilogue));
    }

    #[test]
    fn mobilenet_folded_groups() {
        let g = models::mobilenet_v1();
        let (prog, work) = build_folded(&g, &OptConfig::optimized(), &default_factors(&g));
        // groups: conv3x3s2 (conv1), dw3x3s1, dw3x3s2, conv1x1s1, dense,
        // plus gap kernel → 6 kernels
        let groups: Vec<_> = prog.kernels.iter().filter_map(|k| k.group).collect();
        assert!(groups.len() >= 5, "{groups:?}");
        assert_eq!(prog.kernels.iter().filter(|k| k.group == Some(crate::graph::ParamGroup { kind: GroupKind::Conv, kernel: 1, stride: 1 })).count(), 1);
        // all 13 pointwise layers share that one kernel
        let pw_kernel = prog.kernels.iter().find(|k| k.group == Some(crate::graph::ParamGroup { kind: GroupKind::Conv, kernel: 1, stride: 1 })).unwrap();
        assert_eq!(pw_kernel.layers.len(), 13);
        // bn/act absorbed: work = 27 conv/dw (conv1 + 13×2) + gap + fc = 29
        assert_eq!(work.len(), 29, "{:?}", work.iter().map(|w| &w.layer_name).collect::<Vec<_>>());
        assert_eq!(prog.queues, 1);
    }

    #[test]
    fn resnet_folded_kernel_count_is_small() {
        let g = models::resnet34();
        let (prog, _) = build_folded(&g, &OptConfig::optimized(), &default_factors(&g));
        // A non-parameterized design would need ~70 kernels; PK folds the
        // 36 convs into 5 groups. Residual adds stay per-layer (16) plus
        // maxpool + gap helpers.
        assert!(prog.kernels.len() <= 24, "{} kernels", prog.kernels.len());
    }

    #[test]
    fn no_parameterize_means_kernel_per_layer() {
        let g = models::mobilenet_v1();
        let cfg = OptConfig::optimized().without(OptKind::Parameterize);
        let (prog, _) = build_folded(&g, &cfg, &default_factors(&g));
        assert!(prog.kernels.len() > 25, "{}", prog.kernels.len());
    }

    #[test]
    fn fusion_absorbs_bn_act_chains() {
        let g = models::mobilenet_v1();
        let (_, work) = build_folded(&g, &OptConfig::optimized(), &default_factors(&g));
        assert!(!work.iter().any(|w| w.layer_name.contains(".bn") || w.layer_name.contains(".act")));
        let cfg = OptConfig::optimized().without(OptKind::Fuse);
        let (_, work_nofuse) = build_folded(&g, &cfg, &default_factors(&g));
        assert!(work_nofuse.len() > work.len() + 20);
    }

    #[test]
    fn default_factors_respect_divisibility() {
        let g = models::resnet34();
        let plan = default_factors(&g);
        let (prog, _) = build_folded(&g, &OptConfig::optimized(), &plan);
        for k in &prog.kernels {
            for l in &k.nest.loops {
                assert_eq!(l.extent % l.unroll, 0, "kernel {} loop {:?}", k.name, l.var);
            }
        }
    }
}
