//! Optimization selection and program construction for the two execution
//! modes (§III) — now a thin layer over the [`crate::pass`] subsystem.
//!
//! | Opt | Pipelined | Folded | Pattern (Table I)                          |
//! |-----|-----------|--------|--------------------------------------------|
//! | LU  | ✓         | ✓      | all kernels except transpose/padding       |
//! | LF  | ✓         | ✓      | activation/batchnorm in conv, FC, pooling  |
//! | CW  | ✓         | ✓      | all kernels except transpose/padding       |
//! | OF  | ✓         | ✓      | -fpc -fp-relaxed for all bitstreams        |
//! | CH  | ✓         |        | movement of activations, all layers        |
//! | AR  | ✓         |        | pooling, transpose/padding                 |
//! | CE  | ✓         |        | host optimization                          |
//! | PK  |           | ✓      | convs with same stride and filter size     |
//! | LT  |           | ✓      | conv, FC                                   |
//!
//! Each row is implemented by a registered [`crate::pass::SchedulePass`]
//! whose applicability pattern lives *in the pass*; [`OptConfig`] is the
//! thin builder that selects passes into a [`Pipeline`], and
//! [`build_with_passes`] lowers the graph to the neutral per-node program
//! ([`crate::pass::lower_to_kernels`]) and runs the
//! [`crate::pass::PassManager`] over it, returning the program, the
//! per-layer work list and the report-visible [`PassTrace`].

use std::collections::BTreeMap;

use crate::codegen::KernelProgram;
use crate::graph::{Graph, GroupKind, ParamGroup};
use crate::pass::{
    self, AutorunKernels, CachedWrites, Channelize, ConcurrentQueues, FloatOpts, FuseEpilogues,
    ParameterizeKernels, PassManager, PassTrace, Pipeline, QuantizeDatapath, ScheduleCtx,
    SparsifyWeights, TileLoops, UnrollLoops, VectorizeLoads,
};
use crate::schedule::OptKind;
use crate::sim::folded::LayerWork;
use crate::texpr;

use super::session::CompileError;
use super::Mode;

/// The nine Table-I optimizations in the canonical order
/// [`OptConfig::schedule_pipeline`] sequences them (the Q/VT/SP
/// extensions slot in after OF and are selected by `precision`/
/// `vectorize`/`weight_density`, not listed here). The single source of
/// truth for "every pass subset of the canonical pipeline": `fpga-flow
/// verify`'s sweep and the differ's fuzz set both consume it, and a unit
/// test pins it against the pipeline builder so adding a pass without
/// extending this list fails loudly.
pub const CANONICAL_PIPELINE: [OptKind; 9] = [
    OptKind::Fuse,
    OptKind::Parameterize,
    OptKind::FloatOpt,
    OptKind::Tile,
    OptKind::Unroll,
    OptKind::CachedWrite,
    OptKind::Channels,
    OptKind::Autorun,
    OptKind::Concurrent,
];

/// Which optimizations are enabled (ablation switch-board). A thin
/// builder: [`OptConfig::schedule_pipeline`] turns the selection into the
/// ordered pass [`Pipeline`] the [`PassManager`] executes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OptConfig {
    pub unroll: bool,
    pub tile: bool,
    pub fuse: bool,
    pub cached_writes: bool,
    pub float_opt: bool,
    pub channels: bool,
    pub autorun: bool,
    pub concurrent: bool,
    pub parameterize: bool,
    /// Extension (§VII): datapath precision (fp32 = the paper's setting).
    pub precision: crate::texpr::Precision,
    /// Extension (§V-F): vector types align strided loads.
    pub vectorize: bool,
    /// Extension (§VII future work #2): weight density in (0, 1] — a
    /// zero-skipping datapath (HPIPE-style, the paper's related work §VI)
    /// skips MACs whose weight is pruned away. 1.0 = dense (the paper).
    /// Values outside (0, 1] are rejected by [`OptConfig::validate`] with
    /// a typed [`CompileError`] when the session compiles.
    pub weight_density: f64,
}

impl OptConfig {
    /// TVM's default schedule: nothing enabled (§IV's pathology list).
    pub fn base() -> Self {
        OptConfig {
            unroll: false,
            tile: false,
            fuse: false,
            cached_writes: false,
            float_opt: false,
            channels: false,
            autorun: false,
            concurrent: false,
            parameterize: false,
            precision: crate::texpr::Precision::F32,
            vectorize: false,
            weight_density: 1.0,
        }
    }

    /// Everything Table I allows for the mode.
    pub fn optimized() -> Self {
        OptConfig {
            unroll: true,
            tile: true,
            fuse: true,
            cached_writes: true,
            float_opt: true,
            channels: true,
            autorun: true,
            concurrent: true,
            parameterize: true,
            // The paper evaluates fp32 without vector types; the
            // extensions stay opt-in (see `with_precision`, `with_vectors`).
            precision: crate::texpr::Precision::F32,
            vectorize: false,
            weight_density: 1.0,
        }
    }

    /// Extension (§VII #2): prune weights to `density` and skip zero MACs.
    /// The density's (0, 1] domain is enforced at compile time by
    /// [`OptConfig::validate`].
    pub fn with_sparsity(mut self, density: f64) -> Self {
        self.weight_density = density;
        self
    }

    /// Extension: reduced-precision datapath (paper §VII future work).
    pub fn with_precision(mut self, p: crate::texpr::Precision) -> Self {
        self.precision = p;
        self
    }

    /// Extension: vectorized aligned loads (§V-F mitigation).
    pub fn with_vectors(mut self) -> Self {
        self.vectorize = true;
        self
    }

    /// Disable one optimization (ablation benches).
    pub fn without(mut self, opt: OptKind) -> Self {
        match opt {
            OptKind::Unroll => self.unroll = false,
            OptKind::Tile => self.tile = false,
            OptKind::Fuse => self.fuse = false,
            OptKind::CachedWrite => self.cached_writes = false,
            OptKind::FloatOpt => self.float_opt = false,
            OptKind::Channels => self.channels = false,
            OptKind::Autorun => self.autorun = false,
            OptKind::Concurrent => self.concurrent = false,
            OptKind::Parameterize => self.parameterize = false,
            OptKind::Quantize => self.precision = crate::texpr::Precision::F32,
            OptKind::Vectorize => self.vectorize = false,
            OptKind::Sparsify => self.weight_density = 1.0,
        }
        self
    }

    /// Check every field against its legal domain. The compile session
    /// rejects invalid configs with a typed [`CompileError`] instead of
    /// silently producing nonsense costs.
    pub fn validate(&self) -> Result<(), CompileError> {
        if !(self.weight_density > 0.0 && self.weight_density <= 1.0) {
            return Err(CompileError::InvalidOptConfig {
                field: "weight_density",
                value: self.weight_density,
                reason: "must lie in (0, 1] — the zero-skipping datapath's density domain (§VII #2)",
            });
        }
        Ok(())
    }

    /// Build the ordered schedule-pass pipeline this selection enables.
    /// Mode-restricted passes (PK/LT folded-only, CH/AR/CE
    /// pipelined-only) are always included when selected; their
    /// preconditions skip them — visibly, with the blocking rule in the
    /// trace — when the mode forbids them.
    ///
    /// Order is canonical: LF → PK → OF → Q → VT → SP → LT → LU → CW →
    /// CH → AR → CE. The structural passes lead — LF must precede PK
    /// (absorption targets per-layer kernels, not merged groups) and both
    /// run before the per-kernel rewrites so merged-away kernels are
    /// never scheduled; Q precedes SP and CW because traffic rescaling
    /// truncates and BRAM stashes are sized at the datapath's element
    /// width.
    pub fn schedule_pipeline(&self) -> Pipeline {
        let mut p = Pipeline::default();
        if self.fuse {
            p = p.schedule(FuseEpilogues);
        }
        if self.parameterize {
            p = p.schedule(ParameterizeKernels);
        }
        if self.float_opt {
            p = p.schedule(FloatOpts);
        }
        if self.precision != crate::texpr::Precision::F32 {
            p = p.schedule(QuantizeDatapath::new(self.precision));
        }
        if self.vectorize {
            p = p.schedule(VectorizeLoads);
        }
        if self.weight_density < 1.0 {
            p = p.schedule(SparsifyWeights::new(self.weight_density));
        }
        if self.tile && self.unroll {
            p = p.schedule(TileLoops);
        }
        if self.unroll {
            p = p.schedule(UnrollLoops::new(self.tile));
        }
        if self.cached_writes {
            p = p.schedule(CachedWrites);
        }
        if self.channels {
            p = p.schedule(Channelize);
        }
        if self.autorun {
            p = p.schedule(AutorunKernels);
        }
        if self.concurrent {
            p = p.schedule(ConcurrentQueues);
        }
        p
    }
}

/// Per-group tile/unroll factors for folded mode; per-node caps for
/// pipelined. Produced by [`default_factors`] or by the DSE.
#[derive(Debug, Clone, Default)]
pub struct FactorPlan {
    /// Folded: (input-channel tile, output-channel tile) per group.
    pub group_tiles: BTreeMap<ParamGroup, (u64, u64)>,
    /// Pipelined: max unroll lanes per kernel.
    pub pipelined_cap: u64,
    /// Dense reduction tile (both modes).
    pub dense_tile: (u64, u64),
}

/// The factor choices used for the paper's Table II–V runs. Chosen by the
/// §IV-J rules (bandwidth roof, divisibility, resource fit); the DSE
/// (`crate::dse`) rediscovers factors of this magnitude automatically.
pub fn default_factors(graph: &Graph) -> FactorPlan {
    let mut plan = FactorPlan {
        group_tiles: BTreeMap::new(),
        pipelined_cap: 256,
        dense_tile: (8, 10),
    };
    for node in graph.topo() {
        if let Some(g) = node.op.param_group() {
            let tile = match g.kind {
                GroupKind::Conv => {
                    // Total MAC lanes = k² × t_ic × t_oc (the filter taps
                    // are fully unrolled for k ≥ 3): budget each group to a
                    // few hundred lanes so the summed DSP count lands near
                    // Table II's utilization.
                    if g.kernel == 1 && g.stride == 1 {
                        (32, 16) // the MobileNet workhorse (§III): 512 lanes
                    } else if g.kernel >= 7 {
                        (1, 2) // conv1-style: 49 taps × 2 = 98 lanes
                    } else if g.kernel >= 5 {
                        (2, 8) // 5×5: 400 lanes
                    } else if g.stride == 1 && g.kernel == 3 {
                        (8, 8) // 3×3 workhorse (ResNet): 576 lanes
                    } else if g.kernel == 1 {
                        (16, 8) // 1×1 downsample: 128 lanes
                    } else {
                        (2, 4) // strided 3×3: 72 lanes
                    }
                }
                GroupKind::Depthwise => (8, 1),
                GroupKind::Dense => (8, 10),
            };
            plan.group_tiles.entry(g).or_insert(tile);
        }
    }
    plan
}

/// A pass-built program: the kernels, the per-layer work list and the
/// trace of every pass that ran (or was skipped, with its reason).
#[derive(Debug, Clone)]
pub struct BuiltProgram {
    pub program: KernelProgram,
    pub work: Vec<LayerWork>,
    pub trace: PassTrace,
}

/// Lower `graph` to the neutral per-node program and run `cfg`'s schedule
/// pipeline over it through the [`PassManager`].
pub fn build_with_passes(
    graph: &Graph,
    mode: Mode,
    cfg: &OptConfig,
    plan: &FactorPlan,
) -> BuiltProgram {
    // The session path rejects invalid configs with a typed error before
    // reaching here; direct callers (hybrid/multi/benches) get a loud
    // debug check — in release an out-of-domain pass skips with its
    // reason recorded in the trace rather than panicking mid-build.
    debug_assert!(cfg.validate().is_ok(), "invalid OptConfig: {:?}", cfg.validate().err());
    let pipeline = cfg.schedule_pipeline();
    let mut manager = PassManager::new();
    let mut program = pass::lower_to_kernels(graph, mode);
    let ctx = ScheduleCtx { graph, plan, mode };
    manager.run_schedule_passes(&pipeline, &ctx, &mut program);
    let work = work_list(graph, &program);
    BuiltProgram { program, work, trace: manager.into_trace() }
}

/// Build the pipelined-mode program (§III): one kernel per surviving layer,
/// channel-connected in topological order.
pub fn build_pipelined(
    graph: &Graph,
    cfg: &OptConfig,
    plan: &FactorPlan,
) -> (KernelProgram, Vec<LayerWork>) {
    let built = build_with_passes(graph, Mode::Pipelined, cfg, plan);
    (built.program, built.work)
}

/// Build the folded-mode program (§III, §IV-H): parameterized kernels per
/// (filter, stride) group; feature maps round-trip through global memory.
pub fn build_folded(
    graph: &Graph,
    cfg: &OptConfig,
    plan: &FactorPlan,
) -> (KernelProgram, Vec<LayerWork>) {
    let built = build_with_passes(graph, Mode::Folded, cfg, plan);
    (built.program, built.work)
}

/// Per-layer dispatch list in topological order: every graph node that
/// survived lowering (owned by some kernel) contributes one entry.
fn work_list(graph: &Graph, prog: &KernelProgram) -> Vec<LayerWork> {
    let node_kernel = pass::schedule::node_kernel_map(prog);
    let mut work = Vec::new();
    for node in graph.topo() {
        let Some(&kid) = node_kernel.get(&node.id) else { continue };
        let nest = texpr::lower(node, &graph.nodes[node.inputs[0]].shape);
        work.push(LayerWork {
            node_id: node.id,
            layer_name: node.name.clone(),
            kernel_id: kid,
            out_elems: nest.out_elems,
            reduction: nest.reduction_size,
        });
    }
    work
}

/// Which optimizations ended up applied across a program — the Table III
/// row for a network.
pub fn applied_summary(prog: &KernelProgram) -> Vec<OptKind> {
    let mut out: Vec<OptKind> = Vec::new();
    for k in &prog.kernels {
        for o in &k.applied.opts {
            if !out.contains(o) {
                out.push(*o);
            }
        }
    }
    out.sort();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    #[test]
    fn lenet_pipelined_optimized_structure() {
        let g = models::lenet5();
        let (prog, work) = build_pipelined(&g, &OptConfig::optimized(), &default_factors(&g));
        // c1, s2, c3, s4, f5, f6, f7 → 7 kernels (flatten skipped)
        assert_eq!(prog.kernels.len(), 7);
        assert_eq!(prog.queues, 7);
        assert_eq!(prog.channels.len(), 6);
        assert_eq!(work.len(), 7);
        // pools are autorun (weightless, channel-fed)
        assert!(prog.kernels.iter().any(|k| k.autorun));
        // convs/dense are not (weights still loaded from global at init)
        let summary = applied_summary(&prog);
        for o in [OptKind::Unroll, OptKind::Fuse, OptKind::CachedWrite, OptKind::FloatOpt, OptKind::Channels, OptKind::Autorun, OptKind::Concurrent] {
            assert!(summary.contains(&o), "{o:?} missing from {summary:?}");
        }
        assert!(!summary.contains(&OptKind::Parameterize));
    }

    #[test]
    fn lenet_base_has_no_opts() {
        let g = models::lenet5();
        let (prog, _) = build_pipelined(&g, &OptConfig::base(), &default_factors(&g));
        assert!(applied_summary(&prog).is_empty());
        assert_eq!(prog.queues, 1);
        assert!(prog.channels.is_empty());
        assert_eq!(prog.autorun_count(), 0);
        // BN/act don't exist in LeNet; epilogues stay separate
        assert!(prog.kernels.iter().filter(|k| k.nest.macs_per_iter > 0).all(|k| k.nest.separate_epilogue));
    }

    #[test]
    fn mobilenet_folded_groups() {
        let g = models::mobilenet_v1();
        let (prog, work) = build_folded(&g, &OptConfig::optimized(), &default_factors(&g));
        // groups: conv3x3s2 (conv1), dw3x3s1, dw3x3s2, conv1x1s1, dense,
        // plus gap kernel → 6 kernels
        let groups: Vec<_> = prog.kernels.iter().filter_map(|k| k.group).collect();
        assert!(groups.len() >= 5, "{groups:?}");
        assert_eq!(prog.kernels.iter().filter(|k| k.group == Some(crate::graph::ParamGroup { kind: GroupKind::Conv, kernel: 1, stride: 1 })).count(), 1);
        // all 13 pointwise layers share that one kernel
        let pw_kernel = prog.kernels.iter().find(|k| k.group == Some(crate::graph::ParamGroup { kind: GroupKind::Conv, kernel: 1, stride: 1 })).unwrap();
        assert_eq!(pw_kernel.layers.len(), 13);
        // bn/act absorbed: work = 27 conv/dw (conv1 + 13×2) + gap + fc = 29
        assert_eq!(work.len(), 29, "{:?}", work.iter().map(|w| &w.layer_name).collect::<Vec<_>>());
        assert_eq!(prog.queues, 1);
    }

    #[test]
    fn resnet_folded_kernel_count_is_small() {
        let g = models::resnet34();
        let (prog, _) = build_folded(&g, &OptConfig::optimized(), &default_factors(&g));
        // A non-parameterized design would need ~70 kernels; PK folds the
        // 36 convs into 5 groups. Residual adds stay per-layer (16) plus
        // maxpool + gap helpers.
        assert!(prog.kernels.len() <= 24, "{} kernels", prog.kernels.len());
    }

    #[test]
    fn no_parameterize_means_kernel_per_layer() {
        let g = models::mobilenet_v1();
        let cfg = OptConfig::optimized().without(OptKind::Parameterize);
        let (prog, _) = build_folded(&g, &cfg, &default_factors(&g));
        assert!(prog.kernels.len() > 25, "{}", prog.kernels.len());
    }

    #[test]
    fn fusion_absorbs_bn_act_chains() {
        let g = models::mobilenet_v1();
        let (_, work) = build_folded(&g, &OptConfig::optimized(), &default_factors(&g));
        assert!(!work.iter().any(|w| w.layer_name.contains(".bn") || w.layer_name.contains(".act")));
        let cfg = OptConfig::optimized().without(OptKind::Fuse);
        let (_, work_nofuse) = build_folded(&g, &cfg, &default_factors(&g));
        assert!(work_nofuse.len() > work.len() + 20);
    }

    #[test]
    fn default_factors_respect_divisibility() {
        let g = models::resnet34();
        let plan = default_factors(&g);
        let (prog, _) = build_folded(&g, &OptConfig::optimized(), &plan);
        for k in &prog.kernels {
            for l in &k.nest.loops {
                assert_eq!(l.extent % l.unroll, 0, "kernel {} loop {:?}", k.name, l.var);
            }
        }
    }

    #[test]
    fn canonical_pipeline_matches_schedule_pipeline_order() {
        // CANONICAL_PIPELINE is what `fpga-flow verify` sweeps subsets of
        // and what the differ fuzzes over — it must stay in lockstep with
        // the pipeline the builder actually constructs. LT reports under
        // its own abbrev while `tile` also implies an LU stage, so compare
        // via each OptKind's abbreviation in pipeline order.
        let p = OptConfig::optimized().schedule_pipeline();
        let built: Vec<&str> = p.schedule_passes.iter().map(|s| s.abbrev()).collect();
        let canonical: Vec<&str> = CANONICAL_PIPELINE.iter().map(|o| o.abbrev()).collect();
        assert_eq!(built, canonical, "schedule_pipeline order drifted from CANONICAL_PIPELINE");
    }

    #[test]
    fn validate_rejects_out_of_domain_density() {
        for bad in [0.0, -0.5, 1.5, f64::NAN, f64::INFINITY] {
            let cfg = OptConfig::optimized().with_sparsity(bad);
            let err = cfg.validate().unwrap_err();
            assert!(
                matches!(err, CompileError::InvalidOptConfig { field: "weight_density", .. }),
                "{bad}: {err:?}"
            );
        }
        assert!(OptConfig::optimized().with_sparsity(0.5).validate().is_ok());
        assert!(OptConfig::optimized().validate().is_ok());
    }

    #[test]
    fn kernel_names_are_stable_across_structural_passes() {
        // Fused/merged kernels renumber densely; names carry the new ids.
        let g = models::resnet34();
        let (prog, _) = build_folded(&g, &OptConfig::optimized(), &default_factors(&g));
        for (i, k) in prog.kernels.iter().enumerate() {
            assert_eq!(k.id, i);
            assert!(k.name.starts_with(&format!("k{i}_")), "{}", k.name);
        }
    }
}
