//! Multi-FPGA deployment — the paper's §VII future work #4 ("support for
//! multi-FPGA devices can aid in generating accelerators for larger
//! networks").
//!
//! Folded layer work is partitioned into contiguous per-device chunks
//! (balanced by simulated cycles); devices form a frame pipeline, staging
//! boundary activations over the inter-FPGA link. Throughput is set by the
//! slowest device + its incoming transfer; each device synthesizes its own
//! (smaller) kernel subset, so per-device utilization drops and f_max
//! rises — the multi-FPGA win the paper anticipates.

use crate::device::Target;
use crate::graph::Graph;
use crate::sim::{folded, HostModel};

use super::patterns::{self, FactorPlan, OptConfig};
use super::{Accelerator, Compiler, Flow, ModeChoice};

/// Inter-FPGA link model (PCIe peer-to-peer / serial-lite style).
#[derive(Debug, Clone, Copy)]
pub struct Link {
    pub bandwidth_bytes_per_s: f64,
    pub latency_s: f64,
}

impl Default for Link {
    fn default() -> Self {
        // ~PCIe gen3 x8 effective.
        Link { bandwidth_bytes_per_s: 6.0e9, latency_s: 5e-6 }
    }
}

/// Per-device share of a multi-FPGA deployment.
#[derive(Debug, Clone)]
pub struct DeviceShare {
    pub device_index: usize,
    pub layers: Vec<String>,
    pub frame_time_s: f64,
    pub transfer_in_s: f64,
    pub fmax_mhz: f64,
    pub logic_frac: f64,
}

/// A compiled multi-FPGA deployment.
#[derive(Debug, Clone)]
pub struct MultiAccelerator {
    pub network: String,
    pub devices: usize,
    pub fps: f64,
    pub shares: Vec<DeviceShare>,
}

impl Compiler {
    /// Compile a folded deployment across `devices` identical FPGAs.
    pub fn compile_multi(
        &self,
        graph: &Graph,
        devices: usize,
        cfg: &OptConfig,
        plan: &FactorPlan,
        link: &Link,
    ) -> crate::Result<MultiAccelerator> {
        anyhow::ensure!(devices >= 1, "need at least one device");
        cfg.validate()?;
        let dev = &self.target.device;
        let (prog, work) = patterns::build_folded(graph, cfg, plan);

        // Single-device baseline timings for balancing.
        let (single, _) = self.synthesize_memoized(&prog)?;
        let base_perf = folded::simulate(&prog, &work, dev, single.fmax_mhz, &self.host);
        let total_cycles: f64 = base_perf.per_layer.iter().map(|l| l.cycles).sum();
        let target = total_cycles / devices as f64;

        // Contiguous partition, greedily filling each device to the target.
        let mut boundaries = vec![0usize];
        let mut acc = 0.0;
        for (i, l) in base_perf.per_layer.iter().enumerate() {
            acc += l.cycles;
            if acc >= target && boundaries.len() < devices && i + 1 < work.len() {
                boundaries.push(i + 1);
                acc = 0.0;
            }
        }
        boundaries.push(work.len());

        let mut shares = Vec::new();
        let mut interval: f64 = 0.0;
        for d in 0..boundaries.len() - 1 {
            let (lo, hi) = (boundaries[d], boundaries[d + 1]);
            let chunk: Vec<_> = work[lo..hi].to_vec();
            // Keep only the kernels this chunk touches (smaller design).
            let mut used: Vec<usize> = chunk.iter().map(|w| w.kernel_id).collect();
            used.sort_unstable();
            used.dedup();
            let mut sub = prog.clone();
            sub.name = format!("{}_dev{d}", prog.name);
            sub.kernels = prog
                .kernels
                .iter()
                .filter(|k| used.contains(&k.id))
                .cloned()
                .collect();
            // Re-index kernel ids within the sub-program.
            let mut remap = std::collections::BTreeMap::new();
            for (new_id, k) in sub.kernels.iter_mut().enumerate() {
                remap.insert(k.id, new_id);
                k.id = new_id;
            }
            let chunk: Vec<_> = chunk
                .into_iter()
                .map(|mut w| {
                    w.kernel_id = remap[&w.kernel_id];
                    w
                })
                .collect();

            let (synth, _) = self.synthesize_memoized(&sub)?;
            let host = HostModel { ..self.host };
            let perf = folded::simulate(&sub, &chunk, dev, synth.fmax_mhz, &host);

            // Boundary activation transfer into this device.
            let transfer = if d == 0 {
                0.0
            } else {
                let node = chunk.first().map(|w| w.node_id).unwrap_or(0);
                let in_bytes: f64 = graph.nodes[node]
                    .inputs
                    .iter()
                    .map(|&i| graph.nodes[i].shape.bytes() as f64)
                    .sum();
                link.latency_s + in_bytes / link.bandwidth_bytes_per_s
            };

            interval = interval.max(perf.frame_time_s + transfer);
            shares.push(DeviceShare {
                device_index: d,
                layers: chunk.iter().map(|w| w.layer_name.clone()).collect(),
                frame_time_s: perf.frame_time_s,
                transfer_in_s: transfer,
                fmax_mhz: synth.fmax_mhz,
                logic_frac: synth.resources.utilization.logic_frac,
            });
        }

        Ok(MultiAccelerator {
            network: graph.name.clone(),
            devices: shares.len(),
            fps: 1.0 / interval,
            shares,
        })
    }
}

/// One replica of a serving deployment: the accelerator the staged
/// session API compiled for one registry target, plus the routing weight
/// the scheduler derives from its modeled throughput.
#[derive(Debug, Clone)]
pub struct ReplicaPlanEntry {
    pub target: Target,
    pub accelerator: Accelerator,
    /// Modeled frames/sec — what weighted routing is proportional to.
    pub weight: f64,
}

/// A serving replica plan: one compiled design per requested target.
///
/// Unlike [`Compiler::compile_multi`] (which *partitions* one network
/// across devices), a replica plan gives every device the *whole* network
/// and lets the coordinator shard traffic across them — the §IV-G
/// concurrency idea lifted from command queues to whole accelerators.
/// Heterogeneous fleets are expected: each entry may name a different
/// registry target, and the per-entry weight keeps routing proportional
/// to what each board can actually sustain.
#[derive(Debug, Clone)]
pub struct ReplicaPlan {
    pub network: String,
    pub entries: Vec<ReplicaPlanEntry>,
}

impl ReplicaPlan {
    /// Compile `graph` once per target name (mode resolved per target by
    /// the session's `Auto` rule) through the staged
    /// [`crate::flow::CompileSession`] pipeline.
    ///
    /// ```
    /// use tvm_fpga_flow::flow::multi::ReplicaPlan;
    /// use tvm_fpga_flow::graph::models;
    ///
    /// let plan =
    ///     ReplicaPlan::build(&models::lenet5(), &["stratix10sx", "arria10gx"]).unwrap();
    /// assert_eq!(plan.entries.len(), 2);
    /// assert!(plan.entries.iter().all(|e| e.weight > 0.0));
    /// ```
    pub fn build(graph: &Graph, targets: &[&str]) -> crate::Result<ReplicaPlan> {
        ReplicaPlan::build_with(graph, targets, None)
    }

    /// [`ReplicaPlan::build`] with an optional quantization recipe, so a
    /// serving fleet can run int8/fp16 accelerators (higher modeled FPS →
    /// higher routing weight) with the accuracy delta carried on each
    /// entry's accelerator. The quantization front-end (calibration,
    /// accuracy, Q/DQ rewrite) is target-independent, so it runs **once**
    /// and every replica compiles the same prepared graph.
    pub fn build_with(
        graph: &Graph,
        targets: &[&str],
        quant: Option<crate::quant::QuantConfig>,
    ) -> crate::Result<ReplicaPlan> {
        anyhow::ensure!(!targets.is_empty(), "replica plan needs at least one target");
        let prepared = match &quant {
            Some(q) if q.precision != crate::texpr::Precision::F32 => {
                Some(crate::quant::prepare(graph, q)?)
            }
            _ => None,
        };
        let mut entries = Vec::with_capacity(targets.len());
        for name in targets {
            let compiler = Compiler::for_target(name)?;
            let accelerator = match &prepared {
                Some(prep) => {
                    let mut acc = compiler
                        .graph(&prep.graph)
                        .mode(ModeChoice::Auto)
                        .opts(OptConfig::optimized().with_precision(prep.report.precision))
                        .lower()?
                        .synthesize()?
                        .simulate()?;
                    // The per-target compile skipped the front-end; attach
                    // the shared report so serving keeps the accuracy
                    // metadata.
                    acc.quant = Some(prep.report.clone());
                    acc
                }
                None => compiler.graph(graph).mode(ModeChoice::Auto).lower()?.synthesize()?.simulate()?,
            };
            let weight = accelerator.performance.fps.max(f64::MIN_POSITIVE);
            entries.push(ReplicaPlanEntry { target: compiler.target.clone(), accelerator, weight });
        }
        Ok(ReplicaPlan { network: graph.name.clone(), entries })
    }

    /// Routing weights, in entry order.
    pub fn weights(&self) -> Vec<f64> {
        self.entries.iter().map(|e| e.weight).collect()
    }
}

impl Flow {
    /// Deprecated shim over [`Compiler::compile_multi`].
    #[deprecated(since = "0.2.0", note = "use Compiler::compile_multi")]
    pub fn compile_multi(
        &self,
        graph: &Graph,
        devices: usize,
        cfg: &OptConfig,
        plan: &FactorPlan,
        link: &Link,
    ) -> crate::Result<MultiAccelerator> {
        Compiler::from_parts(self.device.clone(), self.fmax_model, self.host)
            .compile_multi(graph, devices, cfg, plan, link)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::{default_factors, Compiler, Mode, OptLevel};
    use crate::graph::models;

    #[test]
    fn two_devices_beat_one_on_resnet() {
        let flow = Compiler::default();
        let g = models::resnet34();
        let plan = default_factors(&g);
        let single = flow.compile(&g, Mode::Folded, OptLevel::Optimized).unwrap().performance.fps;
        let multi = flow
            .compile_multi(&g, 2, &OptConfig::optimized(), &plan, &Link::default())
            .unwrap();
        assert_eq!(multi.devices, 2);
        assert!(multi.fps > single * 1.3, "multi {} vs single {single}", multi.fps);
        // Speedup can exceed 2×: each half-design is less congested, so
        // per-device f_max recovers from 134 toward ~190 MHz (the same
        // §V-F congestion mechanism, in reverse).
        assert!(multi.fps < single * 3.2, "implausible scaling: {} vs {single}", multi.fps);
    }

    #[test]
    fn one_device_matches_single_flow_closely() {
        let flow = Compiler::default();
        let g = models::mobilenet_v1();
        let plan = default_factors(&g);
        let single = flow.compile(&g, Mode::Folded, OptLevel::Optimized).unwrap().performance.fps;
        let multi = flow
            .compile_multi(&g, 1, &OptConfig::optimized(), &plan, &Link::default())
            .unwrap();
        assert!((multi.fps / single - 1.0).abs() < 0.05, "{} vs {single}", multi.fps);
    }

    #[test]
    fn scaling_has_diminishing_returns() {
        let flow = Compiler::default();
        let g = models::resnet34();
        let plan = default_factors(&g);
        let f2 = flow.compile_multi(&g, 2, &OptConfig::optimized(), &plan, &Link::default()).unwrap().fps;
        let f4 = flow.compile_multi(&g, 4, &OptConfig::optimized(), &plan, &Link::default()).unwrap().fps;
        let f8 = flow.compile_multi(&g, 8, &OptConfig::optimized(), &plan, &Link::default()).unwrap().fps;
        assert!(f4 >= f2 * 0.95);
        // Contiguous partitions + transfers: 8 devices gain less per device.
        assert!(f8 / f4 < f4 / f2 + 0.5);
    }

    #[test]
    fn replica_plan_is_heterogeneous_and_weighted() {
        let g = models::lenet5();
        let plan = ReplicaPlan::build(&g, &["stratix10sx", "arria10gx", "agilex7"]).unwrap();
        assert_eq!(plan.network, "lenet5");
        assert_eq!(plan.entries.len(), 3);
        let w = plan.weights();
        assert!(w.iter().all(|&x| x > 0.0));
        // Different boards must not collapse to identical modeled FPS.
        assert!(w.iter().any(|&x| (x - w[0]).abs() > 1e-9), "{w:?}");
    }

    #[test]
    fn replica_plan_rejects_unknown_target() {
        let g = models::lenet5();
        let err = ReplicaPlan::build(&g, &["virtex7"]).unwrap_err();
        assert!(
            err.downcast_ref::<crate::flow::CompileError>().is_some(),
            "expected typed CompileError, got: {err}"
        );
    }

    #[test]
    fn shares_cover_all_layers_once() {
        let flow = Compiler::default();
        let g = models::mobilenet_v1();
        let plan = default_factors(&g);
        let multi = flow
            .compile_multi(&g, 3, &OptConfig::optimized(), &plan, &Link::default())
            .unwrap();
        let total: usize = multi.shares.iter().map(|s| s.layers.len()).sum();
        let (_, work) = patterns::build_folded(&g, &OptConfig::optimized(), &plan);
        assert_eq!(total, work.len());
    }
}
