//! Multi-FPGA deployment — the paper's §VII future work #4 ("support for
//! multi-FPGA devices can aid in generating accelerators for larger
//! networks").
//!
//! Folded layer work is partitioned into contiguous per-device chunks
//! (balanced by simulated cycles); devices form a frame pipeline, staging
//! boundary activations over the inter-FPGA link. Throughput is set by the
//! slowest device + its incoming transfer; each device synthesizes its own
//! (smaller) kernel subset, so per-device utilization drops and f_max
//! rises — the multi-FPGA win the paper anticipates.

use crate::analysis::{AnalysisReport, PipelineStageFacts};
use crate::device::Target;
use crate::graph::Graph;
use crate::pass::{split_stages, PartitionPass, PassManager, PassTrace, Pipeline, StageCost};
use crate::sim::{folded, HostModel};

use super::patterns::{self, FactorPlan, OptConfig};
use super::{Accelerator, CacheStats, CompileError, Compiler, Flow, ModeChoice};

/// Inter-FPGA link model (PCIe peer-to-peer / serial-lite style).
#[derive(Debug, Clone, Copy)]
pub struct Link {
    pub bandwidth_bytes_per_s: f64,
    pub latency_s: f64,
}

impl Default for Link {
    fn default() -> Self {
        // ~PCIe gen3 x8 effective.
        Link { bandwidth_bytes_per_s: 6.0e9, latency_s: 5e-6 }
    }
}

/// Per-device share of a multi-FPGA deployment.
#[derive(Debug, Clone)]
pub struct DeviceShare {
    pub device_index: usize,
    pub layers: Vec<String>,
    pub frame_time_s: f64,
    pub transfer_in_s: f64,
    pub fmax_mhz: f64,
    pub logic_frac: f64,
}

/// A compiled multi-FPGA deployment.
#[derive(Debug, Clone)]
pub struct MultiAccelerator {
    pub network: String,
    pub devices: usize,
    pub fps: f64,
    pub shares: Vec<DeviceShare>,
}

impl Compiler {
    /// Compile a folded deployment across `devices` identical FPGAs.
    pub fn compile_multi(
        &self,
        graph: &Graph,
        devices: usize,
        cfg: &OptConfig,
        plan: &FactorPlan,
        link: &Link,
    ) -> crate::Result<MultiAccelerator> {
        anyhow::ensure!(devices >= 1, "need at least one device");
        cfg.validate()?;
        let dev = &self.target.device;
        let (prog, work) = patterns::build_folded(graph, cfg, plan);

        // Single-device baseline timings for balancing.
        let (single, _) = self.synthesize_memoized(&prog)?;
        let base_perf = folded::simulate(&prog, &work, dev, single.fmax_mhz, &self.host);
        let total_cycles: f64 = base_perf.per_layer.iter().map(|l| l.cycles).sum();
        let target = total_cycles / devices as f64;

        // Contiguous partition, greedily filling each device to the target.
        let mut boundaries = vec![0usize];
        let mut acc = 0.0;
        for (i, l) in base_perf.per_layer.iter().enumerate() {
            acc += l.cycles;
            if acc >= target && boundaries.len() < devices && i + 1 < work.len() {
                boundaries.push(i + 1);
                acc = 0.0;
            }
        }
        boundaries.push(work.len());

        let mut shares = Vec::new();
        let mut interval: f64 = 0.0;
        for d in 0..boundaries.len() - 1 {
            let (lo, hi) = (boundaries[d], boundaries[d + 1]);
            let chunk: Vec<_> = work[lo..hi].to_vec();
            // Keep only the kernels this chunk touches (smaller design).
            let mut used: Vec<usize> = chunk.iter().map(|w| w.kernel_id).collect();
            used.sort_unstable();
            used.dedup();
            let mut sub = prog.clone();
            sub.name = format!("{}_dev{d}", prog.name);
            sub.kernels = prog
                .kernels
                .iter()
                .filter(|k| used.contains(&k.id))
                .cloned()
                .collect();
            // Re-index kernel ids within the sub-program.
            let mut remap = std::collections::BTreeMap::new();
            for (new_id, k) in sub.kernels.iter_mut().enumerate() {
                remap.insert(k.id, new_id);
                k.id = new_id;
            }
            let chunk: Vec<_> = chunk
                .into_iter()
                .map(|mut w| {
                    w.kernel_id = remap[&w.kernel_id];
                    w
                })
                .collect();

            let (synth, _) = self.synthesize_memoized(&sub)?;
            let host = HostModel { ..self.host };
            let perf = folded::simulate(&sub, &chunk, dev, synth.fmax_mhz, &host);

            // Boundary activation transfer into this device.
            let transfer = if d == 0 {
                0.0
            } else {
                let node = chunk.first().map(|w| w.node_id).unwrap_or(0);
                let in_bytes: f64 = graph.nodes[node]
                    .inputs
                    .iter()
                    .map(|&i| graph.nodes[i].shape.bytes() as f64)
                    .sum();
                link.latency_s + in_bytes / link.bandwidth_bytes_per_s
            };

            interval = interval.max(perf.frame_time_s + transfer);
            shares.push(DeviceShare {
                device_index: d,
                layers: chunk.iter().map(|w| w.layer_name.clone()).collect(),
                frame_time_s: perf.frame_time_s,
                transfer_in_s: transfer,
                fmax_mhz: synth.fmax_mhz,
                logic_frac: synth.resources.utilization.logic_frac,
            });
        }

        Ok(MultiAccelerator {
            network: graph.name.clone(),
            devices: shares.len(),
            fps: 1.0 / interval,
            shares,
        })
    }
}

/// One replica of a serving deployment: the accelerator the staged
/// session API compiled for one registry target, plus the routing weight
/// the scheduler derives from its modeled throughput.
#[derive(Debug, Clone)]
pub struct ReplicaPlanEntry {
    pub target: Target,
    pub accelerator: Accelerator,
    /// Modeled frames/sec — what weighted routing is proportional to.
    pub weight: f64,
}

/// A serving replica plan: one compiled design per requested target.
///
/// Unlike [`Compiler::compile_multi`] (which *partitions* one network
/// across devices), a replica plan gives every device the *whole* network
/// and lets the coordinator shard traffic across them — the §IV-G
/// concurrency idea lifted from command queues to whole accelerators.
/// Heterogeneous fleets are expected: each entry may name a different
/// registry target, and the per-entry weight keeps routing proportional
/// to what each board can actually sustain.
#[derive(Debug, Clone)]
pub struct ReplicaPlan {
    pub network: String,
    pub entries: Vec<ReplicaPlanEntry>,
}

impl ReplicaPlan {
    /// Compile `graph` once per target name (mode resolved per target by
    /// the session's `Auto` rule) through the staged
    /// [`crate::flow::CompileSession`] pipeline.
    ///
    /// ```
    /// use tvm_fpga_flow::flow::multi::ReplicaPlan;
    /// use tvm_fpga_flow::graph::models;
    ///
    /// let plan =
    ///     ReplicaPlan::build(&models::lenet5(), &["stratix10sx", "arria10gx"]).unwrap();
    /// assert_eq!(plan.entries.len(), 2);
    /// assert!(plan.entries.iter().all(|e| e.weight > 0.0));
    /// ```
    pub fn build(graph: &Graph, targets: &[&str]) -> crate::Result<ReplicaPlan> {
        ReplicaPlan::build_with(graph, targets, None)
    }

    /// [`ReplicaPlan::build`] with an optional quantization recipe, so a
    /// serving fleet can run int8/fp16 accelerators (higher modeled FPS →
    /// higher routing weight) with the accuracy delta carried on each
    /// entry's accelerator. The quantization front-end (calibration,
    /// accuracy, Q/DQ rewrite) is target-independent, so it runs **once**
    /// and every replica compiles the same prepared graph.
    pub fn build_with(
        graph: &Graph,
        targets: &[&str],
        quant: Option<crate::quant::QuantConfig>,
    ) -> crate::Result<ReplicaPlan> {
        anyhow::ensure!(!targets.is_empty(), "replica plan needs at least one target");
        let prepared = match &quant {
            Some(q) if q.precision != crate::texpr::Precision::F32 => {
                Some(crate::quant::prepare(graph, q)?)
            }
            _ => None,
        };
        let mut entries = Vec::with_capacity(targets.len());
        for name in targets {
            let compiler = Compiler::for_target(name)?;
            let accelerator = match &prepared {
                Some(prep) => {
                    let mut acc = compiler
                        .graph(&prep.graph)
                        .mode(ModeChoice::Auto)
                        .opts(OptConfig::optimized().with_precision(prep.report.precision))
                        .lower()?
                        .synthesize()?
                        .simulate()?;
                    // The per-target compile skipped the front-end; attach
                    // the shared report so serving keeps the accuracy
                    // metadata.
                    acc.quant = Some(prep.report.clone());
                    acc
                }
                None => compiler.graph(graph).mode(ModeChoice::Auto).lower()?.synthesize()?.simulate()?,
            };
            let weight = accelerator.performance.fps.max(f64::MIN_POSITIVE);
            entries.push(ReplicaPlanEntry { target: compiler.target.clone(), accelerator, weight });
        }
        Ok(ReplicaPlan { network: graph.name.clone(), entries })
    }

    /// [`ReplicaPlan::build_with`] for a fleet of `replicas` boards cycling
    /// through `targets` (replica `i` runs `targets[i % len]`). Each
    /// *distinct* target compiles exactly once — a 16-replica homogeneous
    /// fleet costs one compile, not sixteen — and the compiled entry is
    /// cloned into every replica slot that names it.
    pub fn build_cycled(
        graph: &Graph,
        targets: &[&str],
        replicas: usize,
        quant: Option<crate::quant::QuantConfig>,
    ) -> crate::Result<ReplicaPlan> {
        anyhow::ensure!(!targets.is_empty(), "replica plan needs at least one target");
        let replicas = replicas.max(1);
        let mut distinct: Vec<&str> = Vec::new();
        for t in targets {
            if !distinct.contains(t) {
                distinct.push(t);
            }
        }
        let base = ReplicaPlan::build_with(graph, &distinct, quant)?;
        let by_name: std::collections::BTreeMap<&str, ReplicaPlanEntry> =
            distinct.into_iter().zip(base.entries).collect();
        let entries =
            (0..replicas).map(|i| by_name[targets[i % targets.len()]].clone()).collect();
        Ok(ReplicaPlan { network: base.network, entries })
    }

    /// Routing weights, in entry order.
    pub fn weights(&self) -> Vec<f64> {
        self.entries.iter().map(|e| e.weight).collect()
    }
}

/// One stage of a pipeline-parallel deployment: a contiguous subgraph
/// compiled for its own device, plus the provenance and modeled cost the
/// coordinator and verifier need.
#[derive(Debug, Clone)]
pub struct PipelineStage {
    /// Stage index (0 = receives the network input from the host).
    pub index: usize,
    /// The device this stage synthesizes on.
    pub target: Target,
    /// The stage subgraph (named `"{parent}.s{index}"`; the parent graph
    /// itself for the degenerate single-stage plan).
    pub graph: Graph,
    /// Parent node id for each stage node id (see
    /// [`crate::pass::StageGraph`]).
    pub parent_ids: Vec<usize>,
    /// The compiled accelerator for this stage.
    pub accelerator: Accelerator,
    /// Modeled stage cost under the latency-balancing model.
    pub cost: StageCost,
}

/// A pipeline-parallel multi-FPGA plan: the network split at `cuts` into
/// one stage per device, connected by bounded host channels
/// ([`crate::coordinator::PipelineServer`] runs it). Unlike
/// [`Compiler::compile_multi`] (which folds one kernel program across
/// identical devices) each stage here is an independently compiled — and
/// possibly heterogeneous — [`Accelerator`], so per-stage mode, f_max and
/// utilization are first-class, and unlike [`ReplicaPlan`] the devices
/// cooperate on every frame instead of sharding traffic.
///
/// Steady-state throughput is set by the bottleneck stage:
/// `fps = 1 / max_i max(compute_i, transfer_i)` — frame i+1 occupies
/// stage 0 while frame i occupies stage 1, and the host-channel transfer
/// into a stage overlaps the previous stage's next frame.
#[derive(Debug, Clone)]
pub struct PipelinePlan {
    pub network: String,
    /// Chosen cut points (parent node ids; empty for a single stage).
    pub cuts: Vec<usize>,
    pub stages: Vec<PipelineStage>,
    /// Steady-state pipeline throughput.
    pub fps: f64,
    /// Index of the bottleneck stage.
    pub bottleneck: usize,
    pub link: Link,
    /// Pass trace recording the partition decision (the
    /// [`PartitionPass`] record; skipped for the degenerate plan).
    pub trace: PassTrace,
    /// Pipeline-level diagnostics (FLOW053–FLOW055); error-free by
    /// construction — `build` fails on errors.
    pub analysis: AnalysisReport,
    /// Cut combinations the search evaluated (1 for a single stage).
    pub evaluated: usize,
    /// Synthesis-memo statistics across search + materialization.
    pub synth_cache: CacheStats,
}

impl PipelinePlan {
    /// Search cut points and compile one stage per target (`K =
    /// targets.len()`). The degenerate `K = 1` plan compiles the parent
    /// graph unchanged — byte-identical to the unpartitioned session
    /// compile — and records the partition pass as skipped.
    ///
    /// ```
    /// use tvm_fpga_flow::flow::multi::{Link, PipelinePlan};
    /// use tvm_fpga_flow::graph::models;
    ///
    /// let plan = PipelinePlan::build(
    ///     &models::lenet5(),
    ///     &["stratix10sx", "arria10gx"],
    ///     &Link::default(),
    /// )
    /// .unwrap();
    /// assert_eq!(plan.stages.len(), 2);
    /// assert!(plan.fps > 0.0);
    /// ```
    pub fn build(graph: &Graph, targets: &[&str], link: &Link) -> crate::Result<PipelinePlan> {
        PipelinePlan::build_with(graph, targets, link, None)
    }

    /// [`PipelinePlan::build`] with an optional quantization recipe: the
    /// cut search runs on the fp32 graph (stage balance is driven by MAC
    /// distribution and boundary bytes, which precision scales nearly
    /// uniformly — and the host channels carry dequantized fp32 either
    /// way), then each winning stage is quantized and compiled at the
    /// requested precision.
    pub fn build_with(
        graph: &Graph,
        targets: &[&str],
        link: &Link,
        quant: Option<crate::quant::QuantConfig>,
    ) -> crate::Result<PipelinePlan> {
        anyhow::ensure!(!targets.is_empty(), "pipeline plan needs at least one target");
        // One compiler (= one synthesis memo) per distinct target, shared
        // between the search and the materialization below, so the winning
        // stages re-synthesize as cache hits.
        let mut by_name: std::collections::BTreeMap<&str, Compiler> = Default::default();
        for name in targets {
            if let std::collections::btree_map::Entry::Vacant(e) = by_name.entry(*name) {
                e.insert(Compiler::for_target(name)?);
            }
        }
        let compilers: Vec<Compiler> = targets.iter().map(|n| by_name[n].clone()).collect();

        let (cuts, evaluated) = if targets.len() == 1 {
            (Vec::new(), 1)
        } else {
            let r = crate::dse::explore_partitions_with(graph, &compilers, link);
            let best = r.best.ok_or_else(|| {
                anyhow::anyhow!(
                    "no legal {}-stage partition of {} fits {:?} (evaluated {} cut sets)",
                    targets.len(),
                    graph.name,
                    targets,
                    r.evaluated
                )
            })?;
            (best.cuts, r.evaluated)
        };

        // Record the partition decision in a first-class pass trace (the
        // degenerate plan records it as skipped by precondition).
        let mut pm = PassManager::new();
        pm.run_graph_passes(&Pipeline::default().graph(PartitionPass { cuts: cuts.clone() }), graph);
        let trace = pm.into_trace();

        let stage_graphs = split_stages(graph, &cuts)
            .ok_or_else(|| anyhow::anyhow!("chosen cuts {cuts:?} are not a clean partition"))?;
        let mut stages = Vec::with_capacity(stage_graphs.len());
        for (i, sg) in stage_graphs.into_iter().enumerate() {
            let compiler = &compilers[i];
            let accelerator = match &quant {
                Some(q) if q.precision != crate::texpr::Precision::F32 => {
                    let prep = crate::quant::prepare(&sg.graph, q)?;
                    let mut acc = compiler
                        .graph(&prep.graph)
                        .mode(ModeChoice::Auto)
                        .opts(OptConfig::optimized().with_precision(prep.report.precision))
                        .lower()?
                        .synthesize()?
                        .simulate()?;
                    acc.quant = Some(prep.report.clone());
                    acc
                }
                _ => compiler
                    .graph(&sg.graph)
                    .mode(ModeChoice::Auto)
                    .lower()?
                    .synthesize()?
                    .simulate()?,
            };
            let compute_s = accelerator.performance.frame_time_s;
            let cost = if i == 0 {
                StageCost { compute_s, transfer_s: 0.0, transfer_bytes: 0 }
            } else {
                StageCost::model(compute_s, sg.input_bytes(), link)
            };
            stages.push(PipelineStage {
                index: i,
                target: compiler.target.clone(),
                graph: sg.graph,
                parent_ids: sg.parent_ids,
                accelerator,
                cost,
            });
        }
        let (bottleneck, interval) = stages
            .iter()
            .map(|s| s.cost.stage_s())
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(&b.1))
            .expect("at least one stage");
        let fps = 1.0 / interval;

        let facts: Vec<PipelineStageFacts> = stages
            .iter()
            .map(|s| PipelineStageFacts {
                name: s.graph.name.clone(),
                device: s.target.name.clone(),
                utilization: s.accelerator.synthesis.resources.utilization,
                out_elems: s.graph.nodes[s.graph.output].shape.elems() as u64,
                in_elems: s.graph.nodes[s.graph.input].shape.elems() as u64,
                transfer_bound: s.cost.bound() == "transfer",
                stage_s: s.cost.stage_s(),
            })
            .collect();
        let analysis = crate::analysis::analyze_pipeline(&facts);
        if analysis.count(crate::analysis::Severity::Error) > 0 {
            return Err(CompileError::Analysis {
                network: graph.name.clone(),
                diagnostics: analysis.diagnostics,
            }
            .into());
        }

        let synth_cache = by_name.values().fold(CacheStats::default(), |acc, c| {
            let s = c.cache_stats();
            CacheStats { hits: acc.hits + s.hits, misses: acc.misses + s.misses }
        });
        if crate::obs::enabled() {
            let m = crate::obs::global_metrics();
            m.counter("flow_pipeline_plans_total", "pipeline plans built").inc();
            m.counter("flow_pipeline_stages_total", "pipeline stages compiled")
                .add(stages.len() as u64);
            m.counter(
                "flow_pipeline_transfer_bytes_total",
                "per-frame host-link bytes summed over built pipeline plans",
            )
            .add(stages.iter().map(|s| s.cost.transfer_bytes).sum::<u64>());
        }
        Ok(PipelinePlan {
            network: graph.name.clone(),
            cuts,
            stages,
            fps,
            bottleneck,
            link: *link,
            trace,
            analysis,
            evaluated,
            synth_cache,
        })
    }

    /// Per-stage occupancy: the fraction of the pipeline interval each
    /// stage is busy (1.0 for the bottleneck).
    pub fn occupancy(&self) -> Vec<f64> {
        let interval = 1.0 / self.fps;
        self.stages.iter().map(|s| s.cost.stage_s() / interval).collect()
    }

    /// Total host-link bytes per frame across all cuts.
    pub fn transfer_bytes_per_frame(&self) -> u64 {
        self.stages.iter().map(|s| s.cost.transfer_bytes).sum()
    }
}

impl Flow {
    /// Deprecated shim over [`Compiler::compile_multi`].
    #[deprecated(since = "0.2.0", note = "use Compiler::compile_multi")]
    pub fn compile_multi(
        &self,
        graph: &Graph,
        devices: usize,
        cfg: &OptConfig,
        plan: &FactorPlan,
        link: &Link,
    ) -> crate::Result<MultiAccelerator> {
        Compiler::from_parts(self.device.clone(), self.fmax_model, self.host)
            .compile_multi(graph, devices, cfg, plan, link)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::{default_factors, Compiler, Mode, OptLevel};
    use crate::graph::models;

    #[test]
    fn two_devices_beat_one_on_resnet() {
        let flow = Compiler::default();
        let g = models::resnet34();
        let plan = default_factors(&g);
        let single = flow.compile(&g, Mode::Folded, OptLevel::Optimized).unwrap().performance.fps;
        let multi = flow
            .compile_multi(&g, 2, &OptConfig::optimized(), &plan, &Link::default())
            .unwrap();
        assert_eq!(multi.devices, 2);
        assert!(multi.fps > single * 1.3, "multi {} vs single {single}", multi.fps);
        // Speedup can exceed 2×: each half-design is less congested, so
        // per-device f_max recovers from 134 toward ~190 MHz (the same
        // §V-F congestion mechanism, in reverse).
        assert!(multi.fps < single * 3.2, "implausible scaling: {} vs {single}", multi.fps);
    }

    #[test]
    fn one_device_matches_single_flow_closely() {
        let flow = Compiler::default();
        let g = models::mobilenet_v1();
        let plan = default_factors(&g);
        let single = flow.compile(&g, Mode::Folded, OptLevel::Optimized).unwrap().performance.fps;
        let multi = flow
            .compile_multi(&g, 1, &OptConfig::optimized(), &plan, &Link::default())
            .unwrap();
        assert!((multi.fps / single - 1.0).abs() < 0.05, "{} vs {single}", multi.fps);
    }

    #[test]
    fn scaling_has_diminishing_returns() {
        let flow = Compiler::default();
        let g = models::resnet34();
        let plan = default_factors(&g);
        let f2 = flow.compile_multi(&g, 2, &OptConfig::optimized(), &plan, &Link::default()).unwrap().fps;
        let f4 = flow.compile_multi(&g, 4, &OptConfig::optimized(), &plan, &Link::default()).unwrap().fps;
        let f8 = flow.compile_multi(&g, 8, &OptConfig::optimized(), &plan, &Link::default()).unwrap().fps;
        assert!(f4 >= f2 * 0.95);
        // Contiguous partitions + transfers: 8 devices gain less per device.
        assert!(f8 / f4 < f4 / f2 + 0.5);
    }

    #[test]
    fn replica_plan_is_heterogeneous_and_weighted() {
        let g = models::lenet5();
        let plan = ReplicaPlan::build(&g, &["stratix10sx", "arria10gx", "agilex7"]).unwrap();
        assert_eq!(plan.network, "lenet5");
        assert_eq!(plan.entries.len(), 3);
        let w = plan.weights();
        assert!(w.iter().all(|&x| x > 0.0));
        // Different boards must not collapse to identical modeled FPS.
        assert!(w.iter().any(|&x| (x - w[0]).abs() > 1e-9), "{w:?}");
    }

    #[test]
    fn replica_plan_cycles_targets_compiling_each_once() {
        let g = models::lenet5();
        let plan =
            ReplicaPlan::build_cycled(&g, &["stratix10sx", "arria10gx"], 5, None).unwrap();
        assert_eq!(plan.entries.len(), 5);
        let names: Vec<&str> =
            plan.entries.iter().map(|e| e.target.name.as_str()).collect();
        assert_eq!(
            names,
            vec!["stratix10sx", "arria10gx", "stratix10sx", "arria10gx", "stratix10sx"]
        );
        // Cloned slots carry identical compiles (same modeled weight).
        assert_eq!(plan.entries[0].weight, plan.entries[2].weight);
        assert_eq!(plan.entries[1].weight, plan.entries[3].weight);
    }

    #[test]
    fn replica_plan_rejects_unknown_target() {
        let g = models::lenet5();
        let err = ReplicaPlan::build(&g, &["virtex7"]).unwrap_err();
        assert!(
            err.downcast_ref::<crate::flow::CompileError>().is_some(),
            "expected typed CompileError, got: {err}"
        );
    }

    #[test]
    fn pipeline_plan_two_stages_beats_single_device() {
        let g = models::resnet34();
        let plan =
            PipelinePlan::build(&g, &["stratix10sx", "stratix10sx"], &Link::default()).unwrap();
        assert_eq!(plan.stages.len(), 2);
        assert_eq!(plan.cuts.len(), 1);
        assert!(plan.evaluated >= 2, "search must have compared cut sets");
        let single = Compiler::default()
            .compile(&g, Mode::Folded, OptLevel::Optimized)
            .unwrap()
            .performance
            .fps;
        assert!(plan.fps > single * 1.2, "pipeline {} vs single {single}", plan.fps);
        // The partition decision is a first-class pass-trace record.
        let rec = &plan.trace.records[0];
        assert_eq!(rec.abbrev, "PT");
        assert!(rec.skipped.is_none());
        assert_eq!(rec.diff.channels_inserted, 1);
        // The bottleneck stage is fully occupied; no stage exceeds 1.
        let occ = plan.occupancy();
        assert!((occ[plan.bottleneck] - 1.0).abs() < 1e-9, "{occ:?}");
        assert!(occ.iter().all(|&o| o <= 1.0 + 1e-9), "{occ:?}");
        // One host channel carries the boundary activation.
        assert!(plan.transfer_bytes_per_frame() > 0);
        assert_eq!(plan.stages[0].cost.transfer_bytes, 0);
        // Pipeline-level analysis is clean on the paper network.
        assert!(plan.analysis.is_clean(false));
        // Materialization re-synthesizes the winning stages as memo hits.
        assert!(plan.synth_cache.hits > 0, "{:?}", plan.synth_cache);
        // Stage provenance covers every parent node exactly once (the
        // boundary producer additionally seeds stage 1's Input).
        let total: usize = plan.stages.iter().map(|s| s.graph.nodes.len()).sum();
        assert_eq!(total, g.nodes.len() + plan.cuts.len());
    }

    #[test]
    fn pipeline_plan_degenerate_single_stage_matches_session_compile() {
        let g = models::lenet5();
        let plan = PipelinePlan::build(&g, &["stratix10sx"], &Link::default()).unwrap();
        assert_eq!(plan.stages.len(), 1);
        assert!(plan.cuts.is_empty());
        assert_eq!(plan.evaluated, 1);
        // The partition pass records itself as skipped (nothing to cut).
        assert!(plan.trace.records[0].skipped.is_some());
        // Byte-identical to the unpartitioned staged compile: same program
        // fingerprint, same modeled performance.
        let direct = Compiler::for_target("stratix10sx")
            .unwrap()
            .graph(&g)
            .mode(ModeChoice::Auto)
            .lower()
            .unwrap()
            .synthesize()
            .unwrap()
            .simulate()
            .unwrap();
        let stage = &plan.stages[0].accelerator;
        assert_eq!(
            crate::flow::program_fingerprint(&stage.program),
            crate::flow::program_fingerprint(&direct.program)
        );
        assert_eq!(stage.performance.fps, direct.performance.fps);
        assert_eq!(plan.fps, direct.performance.fps);
        assert_eq!(plan.bottleneck, 0);
        assert_eq!(plan.transfer_bytes_per_frame(), 0);
    }

    #[test]
    fn pipeline_plan_quantized_stages_carry_reports() {
        let g = models::lenet5();
        let quant = crate::quant::QuantConfig::for_precision(crate::texpr::Precision::Int8);
        let plan = PipelinePlan::build_with(
            &g,
            &["stratix10sx", "arria10gx"],
            &Link::default(),
            Some(quant),
        )
        .unwrap();
        assert_eq!(plan.stages.len(), 2);
        for s in &plan.stages {
            let q = s.accelerator.quant.as_ref().expect("stage carries its quant report");
            assert_eq!(q.precision, crate::texpr::Precision::Int8);
        }
        // Heterogeneous targets survive into the plan.
        assert_ne!(plan.stages[0].target.name, plan.stages[1].target.name);
    }

    #[test]
    fn shares_cover_all_layers_once() {
        let flow = Compiler::default();
        let g = models::mobilenet_v1();
        let plan = default_factors(&g);
        let multi = flow
            .compile_multi(&g, 3, &OptConfig::optimized(), &plan, &Link::default())
            .unwrap();
        let total: usize = multi.shares.iter().map(|s| s.layers.len()).sum();
        let (_, work) = patterns::build_folded(&g, &OptConfig::optimized(), &plan);
        assert_eq!(total, work.len());
    }
}
