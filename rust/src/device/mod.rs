//! Device models: the Stratix 10SX D5005 PAC the paper targets (§V-B) plus
//! throughput models for the baseline platforms of Table V.
//!
//! The FPGA numbers are the published device capacities the paper quotes:
//! "over 1.6M ALUTs, 3.4M FFs, 5.7K DSPs and 11M bits of on-chip RAM …
//! 32GB of external DDR4 arranged in 4 banks, with a theoretical peak
//! bandwidth of 76.8GB/s".
//!
//! [`target`] wraps the device envelopes in a named registry so the rest of
//! the flow (legality clock, bandwidth roof, shell overhead, f_max base)
//! picks everything from one `--target` selection.

pub mod target;

pub use target::Target;

/// An FPGA device resource envelope.
#[derive(Debug, Clone, PartialEq)]
pub struct FpgaDevice {
    pub name: String,
    /// Adaptive lookup tables.
    pub aluts: u64,
    /// Flip-flops.
    pub ffs: u64,
    /// Hard floating-point DSP blocks (1 fp32 FMAC per DSP per cycle on S10).
    pub dsps: u64,
    /// On-chip RAM capacity in bits (M20K fabric).
    pub bram_bits: u64,
    /// Size of one BRAM block in bits (M20K = 20 Kb).
    pub bram_block_bits: u64,
    /// External memory theoretical peak bandwidth, bytes/second.
    pub ext_bw_bytes_per_s: f64,
    /// Number of external memory banks.
    pub ext_banks: u32,
    /// Fraction of the device consumed by the board shell/BSP logic.
    pub shell_overhead_frac: f64,
    /// Clock the §IV-J legality rules assume when sizing the bandwidth
    /// roof (the paper's "Assuming a 250 MHz operating frequency" on the
    /// S10SX). It also anchors the f_max model's near-empty-design base
    /// clock via `flow::Compiler::new`. Faster fabrics stream fewer words
    /// per cycle from the same DDR, so the roof tightens as this rises.
    pub legality_clock_mhz: f64,
}

impl FpgaDevice {
    /// The paper's target: Intel Stratix 10SX 1SX280HN2F43E2VG on a D5005 PAC.
    pub fn stratix10sx() -> Self {
        FpgaDevice {
            name: "Stratix 10SX D5005 (1SX280HN2F43E2VG)".into(),
            aluts: 1_866_240,
            ffs: 3_732_480,
            dsps: 5_760,
            // 229 Mb of M20K (the paper's "11M bits" rounds the 11,721
            // M20K block count; utilization is reported against blocks).
            bram_bits: 11_721 * 20 * 1024,
            bram_block_bits: 20 * 1024,
            ext_bw_bytes_per_s: 76.8e9,
            ext_banks: 4,
            shell_overhead_frac: 0.12,
            legality_clock_mhz: 250.0,
        }
    }

    /// Arria 10 GX 1150 (10AX115) on a DDR4-2133 dual-bank board — the
    /// previous-generation mid-range device several related toolflows
    /// target. Roughly half the fabric, a quarter of the DSPs, and half
    /// the memory bandwidth of the D5005; the smaller shell is a larger
    /// fraction of the part.
    pub fn arria10gx() -> Self {
        FpgaDevice {
            name: "Arria 10 GX 1150 (10AX115N2F40)".into(),
            aluts: 854_400,
            ffs: 1_708_800,
            dsps: 1_518,
            bram_bits: 2_713 * 20 * 1024,
            bram_block_bits: 20 * 1024,
            ext_bw_bytes_per_s: 34.1e9,
            ext_banks: 2,
            shell_overhead_frac: 0.18,
            legality_clock_mhz: 200.0,
        }
    }

    /// Agilex 7 class envelope (AGF027-sized): a generation past the
    /// S10SX — more fabric, faster DDR4-3200 banks, a leaner shell, and a
    /// fabric that closes timing a hundred MHz higher.
    pub fn agilex7() -> Self {
        FpgaDevice {
            name: "Agilex 7 AGF027 (AGFB027R24C)".into(),
            aluts: 3_651_200,
            ffs: 7_302_400,
            dsps: 8_528,
            bram_bits: 13_272 * 20 * 1024,
            bram_block_bits: 20 * 1024,
            ext_bw_bytes_per_s: 102.4e9,
            ext_banks: 4,
            shell_overhead_frac: 0.10,
            legality_clock_mhz: 350.0,
        }
    }

    /// Total number of BRAM blocks.
    pub fn bram_blocks(&self) -> u64 {
        self.bram_bits / self.bram_block_bits
    }

    /// Peak external-memory floats per cycle at a given clock — the
    /// paper's §IV-J rule-1 bandwidth roof ("approximately 76 floats" at
    /// 250 MHz on this device).
    pub fn bw_floats_per_cycle(&self, clock_mhz: f64) -> f64 {
        self.ext_bw_bytes_per_s / (clock_mhz * 1e6) / 4.0
    }
}

/// Utilization of a synthesized design against a device.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Utilization {
    pub logic_frac: f64,
    pub bram_frac: f64,
    pub dsp_frac: f64,
    pub ff_frac: f64,
}

impl Utilization {
    /// True when every resource fits on the device (routing headroom is
    /// modeled separately in `aoc::fmax`).
    pub fn fits(&self) -> bool {
        self.logic_frac <= 1.0
            && self.bram_frac <= 1.0
            && self.dsp_frac <= 1.0
            && self.ff_frac <= 1.0
    }

    /// Largest single resource fraction — drives routing congestion.
    pub fn max_frac(&self) -> f64 {
        self.logic_frac
            .max(self.bram_frac)
            .max(self.dsp_frac)
            .max(self.ff_frac)
    }

    /// The tightest resource as `(FPGA resource name, fraction)` — the
    /// dimension [`Utilization::max_frac`] is reporting. Names follow the
    /// device families' own vocabulary (ALM/FF/DSP/BRAM) so diagnostics
    /// can tell the user *which* budget to partition around.
    pub fn peak(&self) -> (&'static str, f64) {
        [
            ("ALM", self.logic_frac),
            ("FF", self.ff_frac),
            ("DSP", self.dsp_frac),
            ("BRAM", self.bram_frac),
        ]
        .into_iter()
        .max_by(|a, b| a.1.total_cmp(&b.1))
        .unwrap()
    }
}

/// Calibrated throughput models for the comparison platforms of Table V.
/// The CPU columns are *measured* on this host through the PJRT runtime
/// (see `runtime`); these constants model the platforms we do not have
/// (56-thread Xeon 8280 scaling, GTX 1060 + cuDNN) so `bench table5` can
/// print the full table. Each value is FPS for batch-1 inference.
#[derive(Debug, Clone)]
pub struct BaselineModel {
    /// Parallel-scaling efficiency when going from 1 to `n` CPU threads:
    /// FPS(n) = FPS(1) * n * efficiency(net). Small nets scale poorly
    /// (per-op launch overhead dominates) — the paper sees LeNet-5 *lose*
    /// throughput from 1t to 56t (2345 → 1470).
    pub cpu_thread_efficiency_small: f64,
    pub cpu_thread_efficiency_large: f64,
    /// GTX 1060 sustained fp32 throughput fraction of its 4.4 TFLOPS peak
    /// for batch-1 CNN inference (cuDNN, no batching — heavily underutilized
    /// for small nets, which is why the paper's FPGA beats it on LeNet-5).
    pub gpu_peak_flops: f64,
    pub gpu_eff_small: f64,
    pub gpu_eff_large: f64,
}

impl Default for BaselineModel {
    fn default() -> Self {
        BaselineModel {
            cpu_thread_efficiency_small: 0.011,
            cpu_thread_efficiency_large: 0.20,
            gpu_peak_flops: 4.4e12,
            gpu_eff_small: 0.00028,
            gpu_eff_large: 0.011,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn s10sx_capacities_match_paper() {
        let d = FpgaDevice::stratix10sx();
        assert!(d.aluts > 1_600_000, "paper: over 1.6M ALUTs");
        assert!(d.ffs > 3_400_000, "paper: 3.4M FFs");
        assert_eq!(d.dsps, 5_760, "paper: 5.7K DSPs");
        assert_eq!(d.ext_banks, 4);
        assert!((d.ext_bw_bytes_per_s - 76.8e9).abs() < 1e6);
    }

    #[test]
    fn bandwidth_roof_is_about_76_floats_at_250mhz() {
        // §IV-J rule 1: "Assuming a 250 MHz operating frequency, this can
        // support 307.2 bytes/cycle, which is approximately 76 floats."
        let d = FpgaDevice::stratix10sx();
        let floats = d.bw_floats_per_cycle(250.0);
        assert!((floats - 76.8).abs() < 1.0, "{floats}");
    }

    #[test]
    fn utilization_fits() {
        let u = Utilization { logic_frac: 0.59, bram_frac: 0.61, dsp_frac: 0.16, ff_frac: 0.3 };
        assert!(u.fits());
        assert!((u.max_frac() - 0.61).abs() < 1e-12);
        let over = Utilization { logic_frac: 1.01, ..u };
        assert!(!over.fits());
    }

    #[test]
    fn bram_blocks_m20k() {
        let d = FpgaDevice::stratix10sx();
        assert_eq!(d.bram_blocks(), 11_721);
    }

    #[test]
    fn profiles_are_ordered_by_generation() {
        let a10 = FpgaDevice::arria10gx();
        let s10 = FpgaDevice::stratix10sx();
        let agx = FpgaDevice::agilex7();
        for (small, big) in [(&a10, &s10), (&s10, &agx)] {
            assert!(small.dsps < big.dsps);
            assert!(small.ext_bw_bytes_per_s < big.ext_bw_bytes_per_s);
            assert!(small.legality_clock_mhz <= big.legality_clock_mhz);
        }
        assert!(a10.aluts < s10.aluts);
    }

    #[test]
    fn legality_roof_tightens_with_clock() {
        // The same DDR moves fewer words per (faster) cycle: the rule-1
        // roof must shrink monotonically as the legality clock rises.
        let d = FpgaDevice::stratix10sx();
        assert!(d.bw_floats_per_cycle(200.0) > d.bw_floats_per_cycle(250.0));
        assert!(d.bw_floats_per_cycle(250.0) > d.bw_floats_per_cycle(350.0));
    }
}
