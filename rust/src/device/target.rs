//! Named compilation targets — the registry behind `--target` and
//! [`crate::flow::Compiler::for_target`].
//!
//! A [`Target`] bundles a device envelope with the identity the CLI and the
//! staged compile API select it by, so the legality clock, bandwidth roof
//! and shell overhead all come from one place instead of constants strewn
//! through the flow (the hard-coded 250 MHz the monolithic driver used).

use super::FpgaDevice;

/// A named compilation target: a device envelope plus registry identity.
#[derive(Debug, Clone, PartialEq)]
pub struct Target {
    /// Canonical registry name (what `--target` matches).
    pub name: String,
    /// Human-readable description for `--help`-style listings.
    pub description: String,
    /// The device resource/bandwidth envelope.
    pub device: FpgaDevice,
}

impl Target {
    /// The paper's target: Stratix 10SX D5005 PAC (§V-B).
    pub fn stratix10sx() -> Target {
        Target {
            name: "stratix10sx".into(),
            description: "Intel Stratix 10SX D5005 PAC (the paper's board)".into(),
            device: FpgaDevice::stratix10sx(),
        }
    }

    /// Previous-generation mid-range part.
    pub fn arria10gx() -> Target {
        Target {
            name: "arria10gx".into(),
            description: "Intel Arria 10 GX 1150, DDR4-2133 x2 board".into(),
            device: FpgaDevice::arria10gx(),
        }
    }

    /// Next-generation envelope.
    pub fn agilex7() -> Target {
        Target {
            name: "agilex7".into(),
            description: "Intel Agilex 7 AGF027-class board, DDR4-3200 x4".into(),
            device: FpgaDevice::agilex7(),
        }
    }

    /// Wrap an ad-hoc device envelope (tests, what-if studies).
    pub fn custom(name: impl Into<String>, device: FpgaDevice) -> Target {
        Target { name: name.into(), description: "custom device envelope".into(), device }
    }

    /// Canonical names of every registered target. Adding a target means
    /// adding its constructor, its name here, and its `by_name` arm — the
    /// registry tests assert the three stay in sync.
    pub fn names() -> &'static [&'static str] {
        &["stratix10sx", "arria10gx", "agilex7"]
    }

    /// All registered targets, derived from [`Target::names`].
    pub fn all() -> Vec<Target> {
        Self::names()
            .iter()
            .map(|n| Self::by_name(n).expect("every registered name resolves"))
            .collect()
    }

    /// Look up a target by canonical name or alias (case-insensitive).
    pub fn by_name(name: &str) -> Option<Target> {
        match name.to_ascii_lowercase().as_str() {
            "stratix10sx" | "stratix10" | "s10" | "s10sx" | "d5005" => Some(Target::stratix10sx()),
            "arria10gx" | "arria10" | "a10" | "a10gx" => Some(Target::arria10gx()),
            "agilex7" | "agilex" | "agf027" => Some(Target::agilex7()),
            _ => None,
        }
    }

    /// The clock the §IV-J legality rules assume for this target.
    pub fn legality_clock_mhz(&self) -> f64 {
        self.device.legality_clock_mhz
    }

    /// Rule-1 bandwidth roof at the target's legality clock, in fp32 words
    /// per cycle.
    pub fn bandwidth_roof_words(&self) -> u64 {
        self.device.bw_floats_per_cycle(self.device.legality_clock_mhz).floor() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_names_and_aliases() {
        for name in Target::names() {
            let t = Target::by_name(name).expect("canonical name resolves");
            assert_eq!(&t.name, name);
        }
        assert_eq!(Target::by_name("S10").unwrap().name, "stratix10sx");
        assert_eq!(Target::by_name("arria10").unwrap().name, "arria10gx");
        assert_eq!(Target::by_name("AGILEX").unwrap().name, "agilex7");
        assert!(Target::by_name("virtex7").is_none());
    }

    #[test]
    fn all_matches_names() {
        let all = Target::all();
        assert_eq!(all.len(), Target::names().len());
        for (t, n) in all.iter().zip(Target::names()) {
            assert_eq!(&t.name, n);
        }
    }

    #[test]
    fn s10_roof_is_the_papers_76_words() {
        assert_eq!(Target::stratix10sx().bandwidth_roof_words(), 76);
    }

    #[test]
    fn roofs_differ_across_targets() {
        // Arria: less bandwidth but a slower clock → a different roof;
        // Agilex: more bandwidth but a faster clock.
        let s10 = Target::stratix10sx().bandwidth_roof_words();
        let a10 = Target::arria10gx().bandwidth_roof_words();
        let agx = Target::agilex7().bandwidth_roof_words();
        assert!(a10 < s10, "{a10} vs {s10}");
        assert!(agx != s10, "{agx} vs {s10}");
    }
}
