//! Scheduling primitives (§IV-A..D, H): the transformations the paper
//! automates inside TVM's AOCL schedules. Each primitive rewrites a
//! [`LoopNest`] and records itself so Table III ("applied optimizations")
//! can be reported per network.


use crate::texpr::{Dir, Epilogue, LoopNest, LoopVar, MemSpace, Pattern, Precision};

/// The paper's optimization vocabulary (Table I abbreviations).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum OptKind {
    /// PK — parameterized kernels.
    Parameterize,
    /// LU — loop unrolling.
    Unroll,
    /// LT — loop tiling / strip mining.
    Tile,
    /// LF — loop fusion.
    Fuse,
    /// CW — cached writes.
    CachedWrite,
    /// OF — optimized float ops (-fp-relaxed -fpc).
    FloatOpt,
    /// CH — channelization.
    Channels,
    /// AR — autorun kernels.
    Autorun,
    /// CE — concurrent execution.
    Concurrent,
    /// Q — reduced-precision datapath (extension; paper §VII future work).
    Quantize,
    /// VT — vector types for aligned loads/stores (extension; §V-F
    /// mitigation).
    Vectorize,
    /// SP — sparse (zero-skipping) datapath (extension; §VII #2).
    Sparsify,
}

impl OptKind {
    pub fn abbrev(&self) -> &'static str {
        match self {
            OptKind::Parameterize => "PK",
            OptKind::Unroll => "LU",
            OptKind::Tile => "LT",
            OptKind::Fuse => "LF",
            OptKind::CachedWrite => "CW",
            OptKind::FloatOpt => "OF",
            OptKind::Channels => "CH",
            OptKind::Autorun => "AR",
            OptKind::Concurrent => "CE",
            OptKind::Quantize => "Q",
            OptKind::Vectorize => "VT",
            OptKind::Sparsify => "SP",
        }
    }

    /// Column order of the paper's Table III.
    pub fn table_order() -> [OptKind; 9] {
        [
            OptKind::Parameterize,
            OptKind::Unroll,
            OptKind::Tile,
            OptKind::Fuse,
            OptKind::CachedWrite,
            OptKind::FloatOpt,
            OptKind::Channels,
            OptKind::Autorun,
            OptKind::Concurrent,
        ]
    }
}

/// Error type for illegal schedule directives.
#[derive(Debug, Clone, PartialEq)]
pub enum ScheduleError {
    NoSuchLoop(LoopVar),
    /// §IV-J rule 2: "The loop count must be evenly divisible by the factor
    /// to avoid prologues and epilogues."
    NotDivisible { var: LoopVar, extent: u64, factor: u64 },
    AlreadyUnrolled(LoopVar),
    NothingToFuse,
    NotAReduction(LoopVar),
}

impl std::fmt::Display for ScheduleError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScheduleError::NoSuchLoop(v) => write!(f, "no loop {}", v.name()),
            ScheduleError::NotDivisible { var, extent, factor } => {
                write!(f, "loop {} extent {extent} not divisible by factor {factor}", var.name())
            }
            ScheduleError::AlreadyUnrolled(v) => write!(f, "loop {} already unrolled", v.name()),
            ScheduleError::NothingToFuse => write!(f, "no separate epilogue to fuse"),
            ScheduleError::NotAReduction(v) => write!(f, "loop {} is not a reduction", v.name()),
        }
    }
}

impl std::error::Error for ScheduleError {}

/// Record of primitives applied to one kernel.
#[derive(Debug, Clone, Default)]
pub struct AppliedOpts {
    pub opts: Vec<OptKind>,
    /// (loop, factor) pairs for LU/LT reporting and the DSE.
    pub factors: Vec<(LoopVar, u64)>,
}

impl AppliedOpts {
    pub fn record(&mut self, opt: OptKind) {
        if !self.opts.contains(&opt) {
            self.opts.push(opt);
        }
    }

    pub fn contains(&self, opt: OptKind) -> bool {
        self.opts.contains(&opt)
    }

    /// Merge another record into this one, deduplicating both the
    /// optimization set and the (loop, factor) pairs — how a kernel
    /// accumulates what each [`crate::pass`] pipeline stage applied.
    pub fn merge(&mut self, other: AppliedOpts) {
        for o in other.opts {
            self.record(o);
        }
        for f in other.factors {
            if !self.factors.contains(&f) {
                self.factors.push(f);
            }
        }
    }
}

/// Schedule handle over a loop nest (TVM's `s[op]` analog).
pub struct Scheduler<'a> {
    pub nest: &'a mut LoopNest,
    pub applied: AppliedOpts,
}

impl<'a> Scheduler<'a> {
    pub fn new(nest: &'a mut LoopNest) -> Self {
        Scheduler { nest, applied: AppliedOpts::default() }
    }

    /// §IV-A loop unrolling: fully unroll `var`. "We only fully unroll
    /// loops since partial unrolling may limit performance gains."
    pub fn unroll(&mut self, var: LoopVar) -> Result<u64, ScheduleError> {
        let l = self.nest.find_loop_mut(var).ok_or(ScheduleError::NoSuchLoop(var))?;
        if l.unroll != 1 {
            return Err(ScheduleError::AlreadyUnrolled(var));
        }
        l.unroll = l.extent;
        let f = l.unroll;
        self.applied.record(OptKind::Unroll);
        self.applied.factors.push((var, f));
        Ok(f)
    }

    /// §IV-B strip mining / tiling with intent to fully unroll the inner
    /// loop: equivalent to partial unrolling by `factor`, subject to the
    /// §IV-J divisibility rule.
    pub fn tile_and_unroll(&mut self, var: LoopVar, factor: u64) -> Result<(), ScheduleError> {
        let l = self.nest.find_loop_mut(var).ok_or(ScheduleError::NoSuchLoop(var))?;
        if l.extent % factor != 0 {
            return Err(ScheduleError::NotDivisible { var, extent: l.extent, factor });
        }
        if l.unroll != 1 {
            return Err(ScheduleError::AlreadyUnrolled(var));
        }
        l.unroll = factor;
        self.applied.record(if factor == l.extent { OptKind::Unroll } else { OptKind::Tile });
        if factor != l.extent {
            self.applied.record(OptKind::Unroll); // inner loop is fully unrolled
        }
        self.applied.factors.push((var, factor));
        Ok(())
    }

    /// §IV-C loop fusion: merge the adjacent activation/batchnorm loop into
    /// the reduction — the temporary global array disappears and with it
    /// its LSUs.
    pub fn fuse_epilogue(&mut self) -> Result<(), ScheduleError> {
        if !self.nest.separate_epilogue {
            return Err(ScheduleError::NothingToFuse);
        }
        self.nest.separate_epilogue = false;
        self.applied.record(OptKind::Fuse);
        Ok(())
    }

    /// Fold a downstream BatchNorm/Activation node into this nest's
    /// epilogue (pattern of Table I: "Activation/batchnorm in Conv, FC,
    /// pooling").
    pub fn absorb_epilogue(&mut self, e: Epilogue) {
        self.nest.epilogue.push(e);
        // Fused from birth: absorbed ops never materialize a temporary.
        self.applied.record(OptKind::Fuse);
    }

    /// §IV-D cached writes: accumulate in a private register, write global
    /// memory once per output element. Removes the ReadWrite LSU.
    pub fn cache_write(&mut self) -> Result<(), ScheduleError> {
        self.nest.accum_space = MemSpace::Private;
        for a in &mut self.nest.accesses {
            if a.dir == Dir::ReadWrite && a.space == MemSpace::Global {
                a.dir = Dir::Write;
                a.pattern = Pattern::Consecutive;
            }
        }
        self.applied.record(OptKind::CachedWrite);
        Ok(())
    }

    /// Move an input buffer into on-chip BRAM (weight stash for pipelined
    /// kernels; implied by channelization of activations in §IV-E).
    pub fn cache_read(&mut self, buffer: &str) -> Result<(), ScheduleError> {
        for a in &mut self.nest.accesses {
            if a.buffer == buffer && a.space == MemSpace::Global && a.dir == Dir::Read {
                a.space = MemSpace::Local;
            }
        }
        Ok(())
    }

    /// §IV-E channelization: activations arrive/leave via channels instead
    /// of global LSUs.
    pub fn channelize(&mut self, buffer: &str) {
        for a in &mut self.nest.accesses {
            if a.buffer == buffer {
                a.space = MemSpace::Channel;
            }
        }
        self.applied.record(OptKind::Channels);
    }

    /// §IV-H parameterized kernels: mark non-filter dims dynamic so one
    /// hardware kernel serves every layer in its (filter, stride) group.
    pub fn parameterize(&mut self) {
        for l in &mut self.nest.loops {
            if !matches!(l.var, LoopVar::KH | LoopVar::KW) {
                l.dynamic = true;
            }
        }
        self.applied.record(OptKind::Parameterize);
    }

    /// Extension (§VII): quantize the datapath. Scales every access's
    /// traffic/array bytes and sets the nest precision (DSP packing and
    /// the bandwidth roof pick it up downstream). Accesses pinned to a
    /// fixed element type (cross-domain quantize/dequantize boundaries)
    /// keep their width.
    pub fn quantize(&mut self, p: Precision) {
        let old = self.nest.precision.bytes();
        let new = p.bytes();
        self.nest.precision = p;
        for a in &mut self.nest.accesses {
            if a.elem.is_some() {
                continue;
            }
            a.bytes_per_frame = a.bytes_per_frame * new / old;
            a.array_bytes = a.array_bytes * new / old;
        }
        if p != Precision::F32 {
            self.applied.record(OptKind::Quantize);
        }
    }

    /// Extension (§VII #2): prune weights to `density`, skipping zero MACs
    /// (HPIPE-style). Weight traffic and effective reduction work scale by
    /// the density; the skip logic costs extra ALUTs per lane (resources).
    pub fn sparsify(&mut self, density: f64) {
        assert!(density > 0.0 && density <= 1.0);
        self.nest.weight_density = density;
        for a in &mut self.nest.accesses {
            if a.buffer == "weights" {
                a.bytes_per_frame = (a.bytes_per_frame as f64 * density) as u64;
                a.array_bytes = (a.array_bytes as f64 * density) as u64;
            }
        }
        if density < 1.0 {
            self.applied.record(OptKind::Sparsify);
        }
    }

    /// Extension (§V-F): vector types align a strided/windowed access into
    /// wide vector loads — the LSU coalesces instead of replicating.
    pub fn vectorize(&mut self, buffer: &str) {
        let mut hit = false;
        for a in &mut self.nest.accesses {
            if a.buffer == buffer && a.pattern != Pattern::Consecutive {
                a.pattern = Pattern::Consecutive;
                hit = true;
            }
        }
        if hit {
            self.applied.record(OptKind::Vectorize);
        }
    }

    pub fn finish(self) -> AppliedOpts {
        self.applied
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::texpr::{self, MemSpace};

    fn lenet_c1_nest() -> LoopNest {
        let g = models::lenet5();
        texpr::lower(&g.nodes[1], &g.nodes[0].shape)
    }

    #[test]
    fn unroll_is_full() {
        let mut nest = lenet_c1_nest();
        let mut s = Scheduler::new(&mut nest);
        let f = s.unroll(LoopVar::KW).unwrap();
        assert_eq!(f, 5);
        assert_eq!(s.nest.total_unroll(), 5);
        assert!(s.applied.contains(OptKind::Unroll));
    }

    #[test]
    fn unroll_twice_rejected() {
        let mut nest = lenet_c1_nest();
        let mut s = Scheduler::new(&mut nest);
        s.unroll(LoopVar::KW).unwrap();
        assert_eq!(s.unroll(LoopVar::KW), Err(ScheduleError::AlreadyUnrolled(LoopVar::KW)));
    }

    #[test]
    fn tile_divisibility_rule() {
        let mut nest = lenet_c1_nest();
        let mut s = Scheduler::new(&mut nest);
        // OutH extent 28: factor 7 divides, factor 5 does not (§IV-J rule 2)
        assert!(s.tile_and_unroll(LoopVar::OutH, 7).is_ok());
        let err = Scheduler::new(&mut lenet_c1_nest()).tile_and_unroll(LoopVar::OutH, 5);
        assert_eq!(err, Err(ScheduleError::NotDivisible { var: LoopVar::OutH, extent: 28, factor: 5 }));
    }

    #[test]
    fn cache_write_removes_rmw() {
        let mut nest = lenet_c1_nest();
        assert!(nest.accesses.iter().any(|a| a.dir == Dir::ReadWrite));
        let mut s = Scheduler::new(&mut nest);
        s.cache_write().unwrap();
        assert!(!s.nest.accesses.iter().any(|a| a.dir == Dir::ReadWrite));
        assert_eq!(s.nest.accum_space, MemSpace::Private);
    }

    #[test]
    fn fuse_clears_separate_epilogue() {
        let mut nest = lenet_c1_nest();
        assert!(nest.separate_epilogue);
        let mut s = Scheduler::new(&mut nest);
        s.fuse_epilogue().unwrap();
        assert!(!s.nest.separate_epilogue);
        assert_eq!(s.fuse_epilogue(), Err(ScheduleError::NothingToFuse));
    }

    #[test]
    fn channelize_moves_to_channel_space() {
        let mut nest = lenet_c1_nest();
        let mut s = Scheduler::new(&mut nest);
        s.channelize("ifmap");
        let ifmap = s.nest.accesses.iter().find(|a| a.buffer == "ifmap").unwrap();
        assert_eq!(ifmap.space, MemSpace::Channel);
    }

    #[test]
    fn parameterize_keeps_filter_static() {
        let mut nest = lenet_c1_nest();
        let mut s = Scheduler::new(&mut nest);
        s.parameterize();
        assert!(s.nest.find_loop(LoopVar::OutC).unwrap().dynamic);
        assert!(!s.nest.find_loop(LoopVar::KH).unwrap().dynamic);
        assert!(!s.nest.find_loop(LoopVar::KW).unwrap().dynamic);
    }

    #[test]
    fn applied_opts_dedup() {
        let mut a = AppliedOpts::default();
        a.record(OptKind::Unroll);
        a.record(OptKind::Unroll);
        assert_eq!(a.opts.len(), 1);
    }
}
