//! Quantization schemes: symmetric fixed-point grids with per-tensor or
//! per-channel scales, plus fp16 rounding.
//!
//! The grids are symmetric (zero-point 0) — the standard choice for FPGA
//! datapaths because the MAC array then needs no zero-point correction
//! terms (Abdelouahab et al., 1806.01683 §V). Scales are chosen from
//! calibrated value ranges: per-tensor for activations (one scale keeps
//! the inter-kernel interface a plain int stream), per-tensor *or*
//! per-channel for weights (per-channel tracks the very different filter
//! magnitudes of depthwise/pointwise layers).

use crate::texpr::Precision;

/// An observed (or propagated) value range.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Range {
    pub lo: f64,
    pub hi: f64,
}

impl Default for Range {
    fn default() -> Self {
        Range::EMPTY
    }
}

impl Range {
    /// The empty range (absorbs anything under [`Range::observe`]).
    pub const EMPTY: Range = Range { lo: f64::INFINITY, hi: f64::NEG_INFINITY };

    pub fn new(lo: f64, hi: f64) -> Range {
        Range { lo: lo.min(hi), hi: hi.max(lo) }
    }

    pub fn is_empty(&self) -> bool {
        self.lo > self.hi
    }

    /// Widen to include `v`.
    pub fn observe(&mut self, v: f64) {
        if v < self.lo {
            self.lo = v;
        }
        if v > self.hi {
            self.hi = v;
        }
    }

    /// Union with another range.
    pub fn merge(&self, o: &Range) -> Range {
        Range { lo: self.lo.min(o.lo), hi: self.hi.max(o.hi) }
    }

    /// Largest absolute value covered (0 for the empty range) — what a
    /// symmetric grid must represent.
    pub fn max_abs(&self) -> f64 {
        if self.is_empty() {
            0.0
        } else {
            self.lo.abs().max(self.hi.abs())
        }
    }
}

/// Scale granularity of a quantized tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum QScheme {
    /// One scale for the whole tensor.
    PerTensor,
    /// One scale per output channel (weights only; activations stay
    /// per-tensor so the kernel interface is a single int stream).
    #[default]
    PerChannel,
}

impl QScheme {
    pub fn name(&self) -> &'static str {
        match self {
            QScheme::PerTensor => "per-tensor",
            QScheme::PerChannel => "per-channel",
        }
    }

    pub fn parse(s: &str) -> Option<QScheme> {
        match s {
            "per-tensor" | "tensor" => Some(QScheme::PerTensor),
            "per-channel" | "channel" => Some(QScheme::PerChannel),
            _ => None,
        }
    }
}

/// Largest positive code of the symmetric integer grid at a precision
/// (fp16/f32 have no integer grid — quantization degenerates to rounding).
pub fn qmax(p: Precision) -> Option<i32> {
    match p {
        Precision::Int8 => Some(127),
        Precision::F16 | Precision::F32 => None,
    }
}

/// Accumulator magnitude limit of a precision's emitted C accumulator
/// type ([`Precision::accum_c_type`]): int8 reductions accumulate in a
/// 32-bit `int`, float datapaths in `float` (no wrap, only saturation —
/// the analyzer checks their *range* instead). This is what the FLOW010
/// overflow proof compares the worst-case `R · qmax²` bound against.
pub fn accum_limit(p: Precision) -> Option<i64> {
    match p {
        Precision::Int8 => Some(i32::MAX as i64),
        Precision::F16 | Precision::F32 => None,
    }
}

/// Quantization parameters of one tensor: a symmetric grid per scale
/// group (1 group = per-tensor, N groups = per-channel).
///
/// ```
/// use tvm_fpga_flow::quant::{QParams, Range};
/// use tvm_fpga_flow::texpr::Precision;
///
/// let q = QParams::per_tensor(Range::new(-2.0, 4.0), Precision::Int8);
/// // The grid covers max |x| = 4.0 with 127 positive codes…
/// assert!((q.scale(0) - 4.0 / 127.0).abs() < 1e-12);
/// // …and round-trip error is bounded by half a step.
/// let x = 1.234_f64;
/// let err = (q.dequantize(q.quantize(x, 0), 0) - x).abs();
/// assert!(err <= q.step(0) / 2.0 + 1e-12);
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct QParams {
    pub precision: Precision,
    /// One scale per group; `scales[0]` is the per-tensor scale.
    scales: Vec<f64>,
}

impl QParams {
    /// Per-tensor symmetric parameters for a range.
    pub fn per_tensor(range: Range, precision: Precision) -> QParams {
        QParams { precision, scales: vec![scale_for(range.max_abs(), precision)] }
    }

    /// Per-channel symmetric parameters (one range per output channel).
    pub fn per_channel(ranges: &[Range], precision: Precision) -> QParams {
        assert!(!ranges.is_empty(), "per-channel QParams need at least one range");
        QParams {
            precision,
            scales: ranges.iter().map(|r| scale_for(r.max_abs(), precision)).collect(),
        }
    }

    pub fn groups(&self) -> usize {
        self.scales.len()
    }

    /// Scale of group `ch` (clamped into range so per-tensor params accept
    /// any channel index).
    pub fn scale(&self, ch: usize) -> f64 {
        self.scales[ch.min(self.scales.len() - 1)]
    }

    /// Grid step = scale (symmetric grid with unit code spacing).
    pub fn step(&self, ch: usize) -> f64 {
        self.scale(ch)
    }

    /// Quantize a value onto the grid of group `ch` (round-to-nearest,
    /// saturating at the code range).
    pub fn quantize(&self, x: f64, ch: usize) -> i32 {
        let m = qmax(self.precision).unwrap_or(i32::MAX >> 1) as f64;
        let q = (x / self.scale(ch)).round();
        q.clamp(-m, m) as i32
    }

    /// Map a code back to the real line.
    pub fn dequantize(&self, q: i32, ch: usize) -> f64 {
        q as f64 * self.scale(ch)
    }

    /// Round-trip a value through the grid (`dequantize(quantize(x))`).
    pub fn roundtrip(&self, x: f64, ch: usize) -> f64 {
        self.dequantize(self.quantize(x, ch), ch)
    }
}

fn scale_for(max_abs: f64, precision: Precision) -> f64 {
    let m = qmax(precision).unwrap_or(1) as f64;
    // A degenerate (all-zero) tensor still needs a nonzero scale.
    (max_abs.max(1e-12)) / m
}

/// Round an f32 to the nearest fp16-representable value (round to nearest
/// even, handling overflow to ±inf and flushing subnormals' extra bits),
/// returned as f32 — how the fp16 datapath is simulated without a half
/// type in std.
pub fn f16_round(x: f32) -> f32 {
    let bits = x.to_bits();
    let sign = bits & 0x8000_0000;
    let exp = ((bits >> 23) & 0xff) as i32;
    if exp == 0xff {
        return x; // inf/nan pass through
    }
    let e = exp - 127; // unbiased
    if e > 15 {
        // Overflows fp16 → ±inf.
        return f32::from_bits(sign | 0x7f80_0000);
    }
    if e < -24 {
        return f32::from_bits(sign); // below smallest subnormal → ±0
    }
    // Keep 10 mantissa bits (fewer for subnormals), round to nearest even.
    let drop_bits: i32 = if e >= -14 { 13 } else { 13 + (-14 - e) };
    let drop = drop_bits as u32;
    let keep_mask = !((1u32 << drop) - 1);
    let half = 1u32 << (drop - 1);
    let mant = bits & 0x7fff_ffff; // exponent+mantissa as magnitude
    let rem = mant & !keep_mask;
    let mut m = mant & keep_mask;
    if rem > half || (rem == half && (m >> drop) & 1 == 1) {
        m += 1u32 << drop; // may carry into the exponent: still correct
    }
    f32::from_bits(sign | m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn roundtrip_error_bounded_by_half_step_per_tensor() {
        prop::check("qdq-roundtrip-per-tensor", |rng, _| {
            let max_abs = 0.01 + rng.f64() * 100.0;
            let q = QParams::per_tensor(Range::new(-max_abs, max_abs), Precision::Int8);
            // In-range values round-trip within half a grid step.
            let x = (rng.f64() * 2.0 - 1.0) * max_abs;
            let err = (q.roundtrip(x, 0) - x).abs();
            assert!(err <= q.step(0) / 2.0 + 1e-12, "x={x} err={err} step={}", q.step(0));
        });
    }

    #[test]
    fn roundtrip_error_bounded_per_channel() {
        prop::check("qdq-roundtrip-per-channel", |rng, _| {
            let n = 1 + rng.below(8) as usize;
            let ranges: Vec<Range> = (0..n)
                .map(|_| {
                    let m = 0.01 + rng.f64() * 10.0;
                    Range::new(-m, m)
                })
                .collect();
            let q = QParams::per_channel(&ranges, Precision::Int8);
            for (ch, r) in ranges.iter().enumerate() {
                let x = (rng.f64() * 2.0 - 1.0) * r.max_abs();
                let err = (q.roundtrip(x, ch) - x).abs();
                assert!(err <= q.step(ch) / 2.0 + 1e-12);
            }
        });
    }

    #[test]
    fn out_of_range_values_saturate() {
        prop::check("qdq-saturates", |rng, _| {
            let m = 0.1 + rng.f64() * 10.0;
            let q = QParams::per_tensor(Range::new(-m, m), Precision::Int8);
            let x = m * (1.5 + rng.f64() * 10.0);
            assert_eq!(q.quantize(x, 0), 127);
            assert_eq!(q.quantize(-x, 0), -127);
        });
    }

    #[test]
    fn scale_monotone_in_range_across_schemes() {
        // A wider calibrated range must never produce a finer grid — in
        // either scheme (coarser grid ⇒ larger step, monotonically).
        prop::check("scale-monotone", |rng, _| {
            let a = 0.01 + rng.f64() * 10.0;
            let b = a * (1.0 + rng.f64() * 10.0);
            let qa = QParams::per_tensor(Range::new(-a, a), Precision::Int8);
            let qb = QParams::per_tensor(Range::new(-b, b), Precision::Int8);
            assert!(qb.scale(0) >= qa.scale(0));
            let ca = QParams::per_channel(&[Range::new(-a, a), Range::new(-b, b)], Precision::Int8);
            assert!(ca.scale(1) >= ca.scale(0));
        });
    }

    #[test]
    fn per_channel_scale_never_coarser_than_covering_per_tensor() {
        prop::check("per-channel-refines", |rng, _| {
            let n = 2 + rng.below(6) as usize;
            let ranges: Vec<Range> = (0..n)
                .map(|_| {
                    let m = 0.01 + rng.f64() * 5.0;
                    Range::new(-m, m)
                })
                .collect();
            let whole = ranges.iter().fold(Range::EMPTY, |acc, r| acc.merge(r));
            let pt = QParams::per_tensor(whole, Precision::Int8);
            let pc = QParams::per_channel(&ranges, Precision::Int8);
            for ch in 0..n {
                assert!(pc.scale(ch) <= pt.scale(0) + 1e-15);
            }
        });
    }

    #[test]
    fn f16_round_is_idempotent_and_close() {
        prop::check("f16-round", |rng, _| {
            let x = (rng.f64() as f32 * 2.0 - 1.0) * 1000.0;
            let r = f16_round(x);
            assert_eq!(f16_round(r), r, "not idempotent at {x}");
            // fp16 has 11 significand bits → relative error ≤ 2^-11.
            if x != 0.0 {
                assert!(((r - x) / x).abs() <= 1.0 / 2048.0 + 1e-7, "x={x} r={r}");
            }
        });
    }

    #[test]
    fn f16_round_known_values() {
        assert_eq!(f16_round(1.0), 1.0);
        assert_eq!(f16_round(0.5), 0.5);
        assert_eq!(f16_round(65504.0), 65504.0); // fp16 max normal
        assert!(f16_round(1e6).is_infinite());
        assert_eq!(f16_round(1e-30), 0.0); // below fp16 subnormal range
        // 1 + 2^-12 rounds back to 1 (beyond the 10-bit mantissa).
        assert_eq!(f16_round(1.0 + 1.0 / 4096.0), 1.0);
    }

    #[test]
    fn range_operations() {
        let mut r = Range::EMPTY;
        assert!(r.is_empty());
        assert_eq!(r.max_abs(), 0.0);
        r.observe(-3.0);
        r.observe(1.0);
        assert_eq!((r.lo, r.hi), (-3.0, 1.0));
        assert_eq!(r.max_abs(), 3.0);
        let m = r.merge(&Range::new(0.0, 5.0));
        assert_eq!((m.lo, m.hi), (-3.0, 5.0));
    }
}
