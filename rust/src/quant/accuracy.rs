//! Accuracy accounting for quantized datapaths: how many top-1 decisions
//! survive the precision drop, relative to the f32 reference.
//!
//! Two estimators, same [`AccuracyReport`]:
//!
//! * [`measure`] — *empirical*: run frames through the f32 and quantized
//!   executors and count top-1 agreement. Exact for the synthetic-weight
//!   model, costs real forwards — used for small networks and the
//!   `fpga-flow quantize` report.
//! * [`estimate`] — *analytic*: accumulate per-layer quantization noise
//!   (grid step Δ ⇒ noise σ_q = Δ/√12, taken relative to the layer's
//!   activation σ), combine across quantized layers in quadrature and map
//!   to an expected top-1 flip rate. O(nodes) — what the precision DSE
//!   reports for every design point.

use crate::graph::Graph;
use crate::texpr::Precision;
use crate::util::scratch::Scratch;

use super::calibrate::CalibrationTable;
use super::exec::{argmax, Executor, FastExecutor};
use super::scheme::{qmax, QScheme};

/// Top-1 fidelity of a quantized datapath vs the f32 reference.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AccuracyReport {
    /// Fraction of frames whose top-1 prediction matches f32 (1.0 = no
    /// degradation).
    pub top1_agreement: f64,
    /// Modeled top-1 accuracy loss in percentage points.
    pub delta_pp: f64,
    /// Frames evaluated (0 for the analytic estimate).
    pub frames: usize,
    /// True when the numbers come from the noise model, not execution.
    pub estimated: bool,
}

impl AccuracyReport {
    /// Lossless report (the f32 baseline).
    pub fn exact() -> AccuracyReport {
        AccuracyReport { top1_agreement: 1.0, delta_pp: 0.0, frames: 0, estimated: true }
    }
}

/// Dataset seed for held-out accuracy measurement — deliberately distinct
/// from the calibration batch's seed so min-max ranges can genuinely
/// saturate during measurement.
pub const HELD_OUT_SEED: u64 = 31;

/// Empirical top-1 agreement between the f32 and quantized executors over
/// `frames` *held-out* frames of the network's synthetic dataset (not the
/// calibration frames — the reported delta must not be the optimistic
/// train-on-test number). Networks without a representative dataset fall
/// back to the analytic [`estimate`] (`estimated: true` in the report).
pub fn measure(
    graph: &Graph,
    table: &CalibrationTable,
    precision: Precision,
    scheme: QScheme,
    frames: usize,
) -> AccuracyReport {
    measure_in(graph, table, precision, scheme, frames, &mut Scratch::new())
}

/// [`measure`] over a caller-owned [`Scratch`] arena: both executors are
/// built once (weights quantized once, buffers checked out once) and run
/// the whole held-out sweep allocation-free — what lets the precision DSE
/// afford realistic frame counts per design point. Bit-identical to the
/// allocating baseline (the fast path is, per executor, bit-exact).
pub fn measure_in(
    graph: &Graph,
    table: &CalibrationTable,
    precision: Precision,
    scheme: QScheme,
    frames: usize,
    scratch: &mut Scratch,
) -> AccuracyReport {
    if precision == Precision::F32 {
        return AccuracyReport::exact();
    }
    let frames = frames.max(1);
    let Some(data) = crate::data::for_network(&graph.name, frames, HELD_OUT_SEED) else {
        return estimate(graph, table, precision, scheme);
    };
    let exec = Executor::new(graph);
    let mut fref = FastExecutor::reference(&exec, true, scratch);
    let mut fq = FastExecutor::quantized(&exec, table, precision, scheme, true, scratch);
    let mut agree = 0usize;
    for i in 0..frames {
        let f = argmax(fref.forward(data.frame(i)));
        let q = argmax(fq.forward(data.frame(i)));
        if f == q {
            agree += 1;
        }
    }
    fref.release(scratch);
    fq.release(scratch);
    let top1_agreement = agree as f64 / frames as f64;
    AccuracyReport {
        top1_agreement,
        delta_pp: (1.0 - top1_agreement) * 100.0,
        frames,
        estimated: false,
    }
}

/// Analytic accuracy estimate from accumulated quantization noise.
pub fn estimate(
    graph: &Graph,
    table: &CalibrationTable,
    precision: Precision,
    scheme: QScheme,
) -> AccuracyReport {
    if precision == Precision::F32 {
        return AccuracyReport::exact();
    }
    let mut noise_sq = 0.0f64;
    for node in table.quantized_nodes() {
        let rel = match precision {
            Precision::F32 => 0.0,
            // fp16 rounding: relative error ≤ 2⁻¹¹ per operand; activations
            // and weights both round.
            Precision::F16 => 2.0 * 2f64.powi(-11),
            Precision::Int8 => {
                let m = qmax(Precision::Int8).unwrap() as f64;
                let input = graph.nodes[node].inputs[0];
                // Activation grid noise relative to the activation σ.
                let a_step = 2.0 * table.activation(input).max_abs() / (2.0 * m);
                let a_rel = a_step / 12f64.sqrt() / table.activation_std(input);
                // Weight grid noise relative to the weight envelope σ≈max/3.5.
                let ranges = table.weight_ranges(node);
                let w_max = ranges.iter().map(|r| r.max_abs()).fold(0.0, f64::max).max(1e-12);
                let w_eff = match scheme {
                    // Per-channel grids track each filter's own envelope.
                    QScheme::PerChannel => {
                        ranges.iter().map(|r| r.max_abs()).sum::<f64>() / ranges.len().max(1) as f64
                    }
                    QScheme::PerTensor => w_max,
                };
                let w_rel = (w_eff / m) / 12f64.sqrt() / (w_max / 3.5);
                a_rel.hypot(w_rel)
            }
        };
        noise_sq += rel * rel;
    }
    let total = noise_sq.sqrt();
    // Noise → flip-rate map, calibrated so LeNet-5 int8 lands in the
    // empirically-observed ≈0–4 pp band, the deep networks stay under
    // ~4 pp (the usual post-training-quantization outcome with per-channel
    // scales), and fp16 is negligible.
    let delta_pp = 100.0 * (1.0 - (-0.4 * total).exp());
    AccuracyReport {
        top1_agreement: 1.0 - delta_pp / 100.0,
        delta_pp,
        frames: 0,
        estimated: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::quant::calibrate::{calibrate, calibrate_analytic, Calibrator};

    #[test]
    fn f32_is_exact_by_definition() {
        let g = models::lenet5();
        let t = calibrate_analytic(&g, Calibrator::MinMax);
        let r = estimate(&g, &t, Precision::F32, QScheme::PerChannel);
        assert_eq!(r.delta_pp, 0.0);
        assert_eq!(r.top1_agreement, 1.0);
    }

    #[test]
    fn estimated_losses_order_fp16_below_int8() {
        for g in models::all() {
            let t = calibrate_analytic(&g, Calibrator::Percentile(99.9));
            let f16 = estimate(&g, &t, Precision::F16, QScheme::PerChannel);
            let i8pc = estimate(&g, &t, Precision::Int8, QScheme::PerChannel);
            let i8pt = estimate(&g, &t, Precision::Int8, QScheme::PerTensor);
            assert!(f16.delta_pp < i8pc.delta_pp, "{}: {f16:?} vs {i8pc:?}", g.name);
            assert!(i8pc.delta_pp <= i8pt.delta_pp + 1e-12, "{}", g.name);
            // The estimate stays in a sane post-training-quantization band.
            assert!(i8pt.delta_pp < 25.0, "{}: {}", g.name, i8pt.delta_pp);
            assert!(f16.delta_pp < 0.5, "{}: {}", g.name, f16.delta_pp);
        }
    }

    #[test]
    fn measured_lenet_int8_loss_is_small() {
        let g = models::lenet5();
        let data = crate::data::mnist_like(8, 32, 5);
        let t = calibrate(&g, &data, 8, Calibrator::MinMax);
        let r = measure(&g, &t, Precision::Int8, QScheme::PerChannel, 12);
        assert!(!r.estimated);
        assert_eq!(r.frames, 12);
        assert!(r.top1_agreement >= 0.75, "agreement {}", r.top1_agreement);
        assert!((r.delta_pp - (1.0 - r.top1_agreement) * 100.0).abs() < 1e-9);
    }
}
