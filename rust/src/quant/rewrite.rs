//! Graph rewriter: make quantization explicit in the IR.
//!
//! [`insert_qdq`] wraps every compute node (conv / depthwise / dense) in
//! `Quantize → op → Dequantize` and then *folds* boundaries: where one
//! quantized op feeds another, the inner `Dequantize → Quantize` pair is
//! never materialized and the activations stay on the integer grid across
//! the edge — the dq/q folding every post-training-quantization flow does
//! (and the reason an int8 accelerator's inter-kernel channels carry int8,
//! not floats). BatchNorm is folded into convs by `graph::passes` *before*
//! rewriting, so Q/DQ boundaries never straddle a BN.

use crate::graph::{Graph, GraphBuilder, NodeId, Op};
use crate::texpr::Precision;

/// What the rewriter did.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QuantStats {
    /// Quantize nodes inserted (f32 → grid boundaries).
    pub quantize_nodes: usize,
    /// Dequantize nodes inserted (grid → f32 boundaries).
    pub dequantize_nodes: usize,
    /// Quantized→quantized edges where a dq/q pair was folded away.
    pub folded_pairs: usize,
}

/// Does `op` execute on the integer grid once the datapath is quantized?
/// Compute ops always (int MACs, f32 epilogue); pooling, residual adds
/// and ReLU-family activations are grid-preserving under a shared
/// per-tensor scale (max/average/sum/clip of grid points needs only a
/// fixed-point rescale — the standard int8 deployment treatment), so they
/// ride along instead of forcing a dequantize/quantize island per node.
/// Transcendental activations (tanh), softmax, global pooling into the
/// classifier head and BN (when not already folded away) stay in f32.
/// `flow::patterns` consults this when scheduling so f32-island kernels
/// are never narrowed.
pub fn grid_capable(op: &Op) -> bool {
    match op {
        Op::Conv2d { .. } | Op::DepthwiseConv2d { .. } | Op::Dense { .. } => true,
        Op::MaxPool { .. } | Op::AvgPool { .. } | Op::Add | Op::Flatten => true,
        Op::Activate(a) => matches!(
            a,
            crate::graph::Activation::Relu | crate::graph::Activation::Relu6
        ),
        _ => false,
    }
}

/// Rewrite `graph` so the quantized regions are explicit. Returns the new
/// graph and the insertion/fold statistics. `precision` = `F32` is the
/// identity.
///
/// ```
/// use tvm_fpga_flow::graph::models;
/// use tvm_fpga_flow::quant::rewrite::insert_qdq;
/// use tvm_fpga_flow::texpr::Precision;
///
/// let (g, stats) = insert_qdq(&models::lenet5(), Precision::Int8);
/// // Boundaries exist, and chained compute ops share them.
/// assert!(stats.quantize_nodes >= 1);
/// assert!(stats.folded_pairs > 0);
/// g.validate().unwrap();
/// ```
pub fn insert_qdq(graph: &Graph, precision: Precision) -> (Graph, QuantStats) {
    let mut stats = QuantStats::default();
    if precision == Precision::F32 {
        return (graph.clone(), stats);
    }

    // New-graph ids of each old node, in both domains.
    let mut f32_id: Vec<Option<NodeId>> = vec![None; graph.nodes.len()];
    let mut grid_id: Vec<Option<NodeId>> = vec![None; graph.nodes.len()];
    // True when the node itself executes on the grid (so a grid-domain
    // consumer edge is a genuine dq/q elision, not a shared Quantize).
    let mut grid_native = vec![false; graph.nodes.len()];

    let input_shape = graph.nodes[graph.input].shape.clone();
    let (mut b, new_input) = GraphBuilder::new(graph.name.clone(), input_shape);
    f32_id[graph.input] = Some(new_input);

    for node in graph.topo() {
        if matches!(node.op, Op::Input) {
            continue;
        }
        let quantized = grid_capable(&node.op);
        let inputs: Vec<NodeId> = node
            .inputs
            .iter()
            .map(|&src| {
                if quantized {
                    // Need the grid-domain value of `src`.
                    if let Some(q) = grid_id[src] {
                        if grid_native[src] {
                            stats.folded_pairs += 1; // dq/q pair never built
                        }
                        q
                    } else {
                        let f = f32_id[src].expect("topo order");
                        let q = b.add(
                            format!("{}.q", graph.nodes[src].name),
                            Op::Quantize { precision },
                            &[f],
                        );
                        stats.quantize_nodes += 1;
                        grid_id[src] = Some(q);
                        q
                    }
                } else {
                    // Need the f32-domain value of `src`.
                    if let Some(f) = f32_id[src] {
                        f
                    } else {
                        let q = grid_id[src].expect("topo order");
                        let f = b.add(
                            format!("{}.dq", graph.nodes[src].name),
                            Op::Dequantize { precision },
                            &[q],
                        );
                        stats.dequantize_nodes += 1;
                        f32_id[src] = Some(f);
                        f
                    }
                }
            })
            .collect();
        let id = b.add(node.name.clone(), node.op.clone(), &inputs);
        if quantized {
            grid_id[node.id] = Some(id);
            grid_native[node.id] = true;
        } else {
            f32_id[node.id] = Some(id);
        }
    }

    // The network output leaves in f32.
    let out = match f32_id[graph.output] {
        Some(f) => f,
        None => {
            let q = grid_id[graph.output].expect("output lowered");
            stats.dequantize_nodes += 1;
            b.add(
                format!("{}.dq", graph.nodes[graph.output].name),
                Op::Dequantize { precision },
                &[q],
            )
        }
    };
    let g = b.finish(out);
    debug_assert!(g.validate().is_ok());
    (g, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::graph::passes;

    fn count(g: &Graph, f: impl Fn(&Op) -> bool) -> usize {
        g.nodes.iter().filter(|n| f(&n.op)).count()
    }

    #[test]
    fn f32_is_identity() {
        let g = models::lenet5();
        let (g2, stats) = insert_qdq(&g, Precision::F32);
        assert_eq!(stats, QuantStats::default());
        assert_eq!(g2.nodes.len(), g.nodes.len());
    }

    #[test]
    fn lenet_gets_boundaries_and_folds() {
        let g = models::lenet5();
        let (g2, stats) = insert_qdq(&g, Precision::Int8);
        g2.validate().unwrap();
        let q = count(&g2, |op| matches!(op, Op::Quantize { .. }));
        let dq = count(&g2, |op| matches!(op, Op::Dequantize { .. }));
        assert_eq!(q, stats.quantize_nodes);
        assert_eq!(dq, stats.dequantize_nodes);
        // The whole conv→pool→conv→…→dense chain stays on the grid: one
        // quantize at the image, one dequantize at the logits.
        assert_eq!((q, dq), (1, 1), "{stats:?}");
        assert!(stats.folded_pairs >= 3, "{stats:?}");
        // Output node is f32 (Dequantize or another f32-domain op).
        assert!(matches!(g2.nodes[g2.output].op, Op::Dequantize { .. }));
    }

    #[test]
    fn resnet_chain_stays_on_grid_through_relu_and_maxpool() {
        // BN folds away first (the conv/BN boundary of the issue), then
        // the conv→relu→conv chains share one quantized region.
        let (g, _) = passes::standard_pipeline(&models::resnet34());
        let (g2, stats) = insert_qdq(&g, Precision::Int8);
        g2.validate().unwrap();
        let computes = count(&g, |op| op.is_compute());
        // Far fewer quantize boundaries than compute nodes = real folding.
        assert!(
            stats.quantize_nodes * 2 < computes,
            "{} q-nodes for {computes} compute nodes",
            stats.quantize_nodes
        );
        assert!(stats.folded_pairs > computes / 2, "{stats:?}");
    }

    #[test]
    fn rewritten_graphs_preserve_macs_and_output_shape() {
        for g in models::all() {
            let (g1, _) = passes::standard_pipeline(&g);
            let (g2, _) = insert_qdq(&g1, Precision::Int8);
            assert_eq!(g1.total_macs(), g2.total_macs(), "{}", g.name);
            assert_eq!(
                g1.nodes[g1.output].shape,
                g2.nodes[g2.output].shape,
                "{}",
                g.name
            );
        }
    }

    #[test]
    fn rewritten_graph_still_compiles() {
        use crate::flow::{Compiler, Mode, OptLevel};
        let (g1, _) = passes::standard_pipeline(&models::mobilenet_v1());
        let (g2, _) = insert_qdq(&g1, Precision::Int8);
        let acc = Compiler::default().compile(&g2, Mode::Folded, OptLevel::Optimized).unwrap();
        assert!(acc.performance.fps > 0.0);
    }
}
