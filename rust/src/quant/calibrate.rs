//! Calibration: pick per-tensor activation ranges (and per-channel weight
//! ranges) that the symmetric grids of [`super::scheme`] are scaled from.
//!
//! Two calibration paths share one [`CalibrationTable`]:
//!
//! * [`calibrate`] — *empirical*: sweep representative frames through the
//!   reference executor (after the standard `graph::passes` pipeline has
//!   folded BN) and record what each node actually produces. Min-max keeps
//!   the extremes; percentile clips outliers against an absolute-value
//!   histogram, trading saturation error for grid resolution — the
//!   standard post-training-quantization recipe.
//! * [`calibrate_analytic`] — *propagated*: moment propagation through the
//!   graph (the synthetic weights have known statistics by construction),
//!   O(nodes) with no tensor materialization. This is what the DSE uses so
//!   a precision sweep over ResNet-34 costs microseconds, not forwards.

use std::collections::BTreeMap;

use crate::graph::{Activation, Graph, NodeId, Op};
use crate::util::scratch::Scratch;

use super::exec::{Executor, FastExecutor};
use super::scheme::Range;

/// Range-selection policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Calibrator {
    /// Exact observed extremes.
    MinMax,
    /// Clip to the given percentile of |activation| (e.g. 99.9).
    Percentile(f64),
}

impl Calibrator {
    pub fn name(&self) -> String {
        match self {
            Calibrator::MinMax => "min-max".into(),
            Calibrator::Percentile(p) => format!("p{p}"),
        }
    }

    pub fn parse(s: &str) -> Option<Calibrator> {
        match s {
            "minmax" | "min-max" => Some(Calibrator::MinMax),
            _ => s
                .strip_prefix('p')
                .and_then(|v| v.parse::<f64>().ok())
                .filter(|p| (50.0..=100.0).contains(p))
                .map(Calibrator::Percentile),
        }
    }
}

/// Calibrated ranges for one network: per-node activation ranges (and a
/// crude σ estimate for the analytic accuracy model), per-node per-channel
/// weight ranges.
#[derive(Debug, Clone)]
pub struct CalibrationTable {
    pub network: String,
    pub method: Calibrator,
    /// Frames observed (0 = analytic propagation).
    pub frames: usize,
    activations: BTreeMap<NodeId, Range>,
    act_std: BTreeMap<NodeId, f64>,
    weights: BTreeMap<NodeId, Vec<Range>>,
}

impl CalibrationTable {
    /// Calibrated activation range of a node (a conservative unit range if
    /// the node was never observed).
    pub fn activation(&self, node: NodeId) -> Range {
        self.activations.get(&node).copied().unwrap_or(Range::new(-1.0, 1.0))
    }

    /// Estimated standard deviation of a node's activations.
    pub fn activation_std(&self, node: NodeId) -> f64 {
        self.act_std.get(&node).copied().unwrap_or(0.25).max(1e-9)
    }

    /// Per-output-channel weight ranges of a node (empty if weightless).
    pub fn weight_ranges(&self, node: NodeId) -> Vec<Range> {
        self.weights.get(&node).cloned().unwrap_or_default()
    }

    /// Nodes with calibrated weights — the quantizable compute set.
    pub fn quantized_nodes(&self) -> impl Iterator<Item = NodeId> + '_ {
        self.weights.keys().copied()
    }

    /// Project this (whole-network) table onto a pipeline-stage subgraph:
    /// stage node `i` inherits the ranges of parent node `parent_ids[i]`
    /// ([`crate::pass::partition::StageGraph`]). A stage's fresh `Input`
    /// maps to the boundary producer, so the consumer side of a host
    /// channel re-quantizes the incoming activation with *exactly* the
    /// range the unpartitioned datapath used — this is what makes chained
    /// int8 stage execution bit-identical to the whole-graph oracle.
    pub fn for_stage(&self, stage_network: &str, parent_ids: &[usize]) -> CalibrationTable {
        let mut t = CalibrationTable {
            network: stage_network.to_string(),
            method: self.method,
            frames: self.frames,
            activations: BTreeMap::new(),
            act_std: BTreeMap::new(),
            weights: BTreeMap::new(),
        };
        for (stage_id, &parent_id) in parent_ids.iter().enumerate() {
            if let Some(&r) = self.activations.get(&parent_id) {
                t.activations.insert(stage_id, r);
            }
            if let Some(&s) = self.act_std.get(&parent_id) {
                t.act_std.insert(stage_id, s);
            }
            if stage_id > 0 || parent_id == 0 {
                // Weight ranges follow compute nodes; the fresh Input node
                // (stage_id 0 mapped to a boundary producer) has none even
                // when its parent producer does.
                if let Some(w) = self.weights.get(&parent_id) {
                    t.weights.insert(stage_id, w.clone());
                }
            }
        }
        t
    }
}

/// Absolute-value histogram with growable range (rebins by pairwise merge
/// when a sample exceeds the current top).
#[derive(Debug, Clone)]
struct AbsHist {
    bins: Vec<u64>,
    top: f64,
    count: u64,
    sum: f64,
    sumsq: f64,
    min: f64,
    max: f64,
}

const HIST_BINS: usize = 256;

impl AbsHist {
    fn new() -> AbsHist {
        AbsHist {
            bins: vec![0; HIST_BINS],
            top: 1e-6,
            count: 0,
            sum: 0.0,
            sumsq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    fn observe(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        self.sumsq += v * v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        let a = v.abs();
        while a > self.top {
            // Double the range: merge bins pairwise, freeing the top half.
            for i in 0..HIST_BINS / 2 {
                self.bins[i] = self.bins[2 * i] + self.bins[2 * i + 1];
            }
            for b in &mut self.bins[HIST_BINS / 2..] {
                *b = 0;
            }
            self.top *= 2.0;
        }
        let idx = ((a / self.top) * HIST_BINS as f64) as usize;
        self.bins[idx.min(HIST_BINS - 1)] += 1;
    }

    /// Smallest |v| threshold covering at least `pct`% of samples.
    fn percentile_abs(&self, pct: f64) -> f64 {
        let need = (self.count as f64 * pct / 100.0).ceil() as u64;
        let mut acc = 0;
        for (i, &b) in self.bins.iter().enumerate() {
            acc += b;
            if acc >= need {
                return (i + 1) as f64 / HIST_BINS as f64 * self.top;
            }
        }
        self.top
    }

    fn std(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let mean = self.sum / self.count as f64;
        (self.sumsq / self.count as f64 - mean * mean).max(0.0).sqrt()
    }

    fn range(&self, method: Calibrator) -> Range {
        if self.count == 0 {
            return Range::new(-1.0, 1.0);
        }
        match method {
            Calibrator::MinMax => Range::new(self.min, self.max),
            Calibrator::Percentile(p) => {
                let t = self.percentile_abs(p);
                Range::new(self.min.max(-t), self.max.min(t))
            }
        }
    }
}

/// Empirical calibration: run `frames` frames of `batch` through the f32
/// reference executor, collecting per-node activation statistics.
pub fn calibrate(
    graph: &Graph,
    batch: &crate::data::Batch,
    frames: usize,
    method: Calibrator,
) -> CalibrationTable {
    calibrate_in(graph, batch, frames, method, &mut Scratch::new())
}

/// [`calibrate`] over a caller-owned [`Scratch`] arena. Executor state
/// (synthetic weights, per-node activation buffers) is built **once** and
/// reused across the whole frame loop — nothing is constructed or heap-
/// allocated per frame, which is what lets default calibration frame
/// counts be raised without blowing the wall-clock budget
/// (`calibration_is_identical_through_the_fast_path` pins the results to
/// the allocating path bit-for-bit).
pub fn calibrate_in(
    graph: &Graph,
    batch: &crate::data::Batch,
    frames: usize,
    method: Calibrator,
    scratch: &mut Scratch,
) -> CalibrationTable {
    let exec = Executor::new(graph);
    let mut fast = FastExecutor::reference(&exec, false, scratch);
    let mut hists: Vec<AbsHist> = (0..graph.nodes.len()).map(|_| AbsHist::new()).collect();
    let frames = frames.min(batch.frames()).max(1);
    for i in 0..frames {
        fast.forward_observed(batch.frame(i), |id, act| {
            for &v in act {
                hists[id].observe(v as f64);
            }
        });
    }
    fast.release(scratch);
    let mut table = CalibrationTable {
        network: graph.name.clone(),
        method,
        frames,
        activations: BTreeMap::new(),
        act_std: BTreeMap::new(),
        weights: BTreeMap::new(),
    };
    for n in graph.topo() {
        table.activations.insert(n.id, hists[n.id].range(method));
        table.act_std.insert(n.id, hists[n.id].std());
        if n.op.is_compute() {
            table.weights.insert(n.id, exec.weight_channel_ranges(n.id));
        }
    }
    table
}

/// Analytic calibration: propagate (σ, max|x|) estimates through the graph
/// using the known statistics of the synthetic He-initialized weights —
/// no tensors are materialized, so this is cheap enough to run inside a
/// DSE sweep for any network.
pub fn calibrate_analytic(graph: &Graph, method: Calibrator) -> CalibrationTable {
    let mut table = CalibrationTable {
        network: graph.name.clone(),
        method,
        frames: 0,
        activations: BTreeMap::new(),
        act_std: BTreeMap::new(),
        weights: BTreeMap::new(),
    };
    // Percentile clipping under a roughly-Gaussian activation law: clip at
    // the two-sided p-quantile (√(2·ln(1/(1−p))) σ) instead of the 4σ tail.
    let clip_sigmas = match method {
        Calibrator::MinMax => 4.0,
        Calibrator::Percentile(p) => {
            let tail = (1.0 - p / 100.0).max(1e-9);
            (-2.0 * tail.ln()).sqrt().min(4.0)
        }
    };

    // (σ, max|x|) per node.
    let mut stats: Vec<(f64, f64)> = vec![(0.0, 0.0); graph.nodes.len()];
    for n in graph.topo() {
        let inp = |i: usize| stats[n.inputs[i]];
        let (std, absmax) = match &n.op {
            // The synthetic datasets are bounded ([0, 1.1] strokes or
            // biased unit normals) — a conservative shared envelope.
            Op::Input => (0.6, 2.5),
            Op::Conv2d { kernel, activation, .. } => {
                let cin = graph.nodes[n.inputs[0]].shape.chw().map(|c| c.0).unwrap_or(1);
                compute_stats(inp(0).0, cin * kernel * kernel, *activation, clip_sigmas)
            }
            Op::DepthwiseConv2d { kernel, activation, .. } => {
                compute_stats(inp(0).0, kernel * kernel, *activation, clip_sigmas)
            }
            Op::Dense { activation, .. } => {
                let cin = graph.nodes[n.inputs[0]].shape.elems();
                compute_stats(inp(0).0, cin, *activation, clip_sigmas)
            }
            Op::BatchNorm => inp(0),
            Op::Activate(a) => {
                let (s, m) = inp(0);
                apply_activation_stats(s, m, *a, clip_sigmas)
            }
            Op::MaxPool { .. } => {
                let (s, m) = inp(0);
                (s, m) // max keeps the envelope
            }
            Op::AvgPool { kernel, .. } => {
                let (s, m) = inp(0);
                (s / *kernel as f64, m)
            }
            Op::GlobalAvgPool => {
                // Averaging N values shrinks the fluctuation by √N but the
                // (post-ReLU) mean survives intact — the output envelope is
                // mean-dominated, not max-dominated.
                let (s, m) = inp(0);
                let (_, h, w) = graph.nodes[n.inputs[0]].shape.chw().unwrap_or((1, 1, 1));
                let s_new = s / ((h * w) as f64).sqrt();
                (s_new, (0.5 * s + clip_sigmas * s_new).min(m))
            }
            Op::Add => {
                let (s0, m0) = inp(0);
                let (s1, m1) = inp(1);
                ((s0 * s0 + s1 * s1).sqrt(), m0 + m1)
            }
            Op::Softmax => (0.2, 1.0),
            Op::Transform | Op::Flatten | Op::Quantize { .. } | Op::Dequantize { .. } => inp(0),
        };
        stats[n.id] = (std, absmax);
        table.activations.insert(n.id, Range::new(-absmax, absmax));
        table.act_std.insert(n.id, std);
        if n.op.is_compute() {
            // He init: σ_w = √(2/fan_in); per-channel extremes ≈ 3.5 σ_w.
            let (fan_in, oc) = match &n.op {
                Op::Conv2d { out_channels, kernel, .. } => {
                    let cin = graph.nodes[n.inputs[0]].shape.chw().map(|c| c.0).unwrap_or(1);
                    (cin * kernel * kernel, *out_channels)
                }
                Op::DepthwiseConv2d { kernel, .. } => {
                    (kernel * kernel, n.shape.chw().map(|c| c.0).unwrap_or(1))
                }
                Op::Dense { out_features, .. } => {
                    (graph.nodes[n.inputs[0]].shape.elems(), *out_features)
                }
                _ => unreachable!("is_compute covers conv/dw/dense"),
            };
            let w_absmax = 3.5 * (2.0 / fan_in.max(1) as f64).sqrt();
            table.weights.insert(n.id, vec![Range::new(-w_absmax, w_absmax); oc.max(1)]);
        }
    }
    table
}

/// Post-MAC statistics: He-initialized sums double the input variance
/// (σ_out = σ_in·σ_w·√fan_in = σ_in·√2), then the fused activation shapes
/// the law.
fn compute_stats(
    std_in: f64,
    _fan_in: usize,
    act: Activation,
    clip_sigmas: f64,
) -> (f64, f64) {
    let std = (std_in * std::f64::consts::SQRT_2).max(1e-6);
    apply_activation_stats(std, clip_sigmas * std, act, clip_sigmas)
}

fn apply_activation_stats(std: f64, absmax: f64, act: Activation, clip_sigmas: f64) -> (f64, f64) {
    match act {
        Activation::None => (std, absmax),
        // Half-Gaussian: σ shrinks to √(1−1/π)·σ ≈ 0.58 σ.
        Activation::Relu => (0.58 * std, clip_sigmas * 0.58 * std.max(1e-9) * 1.7),
        Activation::Relu6 => {
            let s = 0.58 * std;
            (s.min(2.0), (clip_sigmas * s * 1.7).min(6.0))
        }
        Activation::Tanh => (std.min(0.63), absmax.min(1.0)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    #[test]
    fn empirical_ranges_cover_observations() {
        let g = models::lenet5();
        let data = crate::data::mnist_like(4, 32, 5);
        let t = calibrate(&g, &data, 4, Calibrator::MinMax);
        // Input range must cover the generator's [0, 1.1] envelope.
        let r = t.activation(g.input);
        assert!(r.lo >= 0.0 && r.hi > 0.8 && r.hi <= 1.1, "{r:?}");
        // Every compute node got per-channel weight ranges.
        for n in g.nodes.iter().filter(|n| n.op.is_compute()) {
            assert!(!t.weight_ranges(n.id).is_empty(), "{}", n.name);
        }
        assert_eq!(t.frames, 4);
    }

    #[test]
    fn calibration_is_identical_through_the_fast_path() {
        // Satellite regression for the hoisted executor construction:
        // calibrate() now observes through the non-allocating FastExecutor.
        // Rebuild the table the old way (allocating Executor::forward per
        // frame) and demand bit-identical ranges, σ and weight ranges.
        let g = models::lenet5();
        let data = crate::data::mnist_like(6, 32, 5);
        for method in [Calibrator::MinMax, Calibrator::Percentile(99.5)] {
            let fast = calibrate(&g, &data, 6, method);
            let exec = Executor::new(&g);
            let mut hists: Vec<AbsHist> = (0..g.nodes.len()).map(|_| AbsHist::new()).collect();
            for i in 0..6 {
                exec.forward(data.frame(i), |id, act| {
                    for &v in act {
                        hists[id].observe(v as f64);
                    }
                });
            }
            for n in g.topo() {
                assert_eq!(fast.activation(n.id), hists[n.id].range(method), "{}", n.name);
                assert_eq!(fast.activation_std(n.id), hists[n.id].std().max(1e-9), "{}", n.name);
                if n.op.is_compute() {
                    assert_eq!(fast.weight_ranges(n.id), exec.weight_channel_ranges(n.id));
                }
            }
        }
    }

    #[test]
    fn percentile_clips_inside_minmax() {
        let g = models::lenet5();
        let data = crate::data::mnist_like(4, 32, 5);
        let mm = calibrate(&g, &data, 4, Calibrator::MinMax);
        let pc = calibrate(&g, &data, 4, Calibrator::Percentile(99.0));
        let mut clipped = 0;
        for n in g.topo() {
            let a = mm.activation(n.id);
            let b = pc.activation(n.id);
            assert!(b.max_abs() <= a.max_abs() + 1e-9, "{}: {b:?} vs {a:?}", n.name);
            if b.max_abs() < a.max_abs() * 0.999 {
                clipped += 1;
            }
        }
        assert!(clipped > 0, "p99 never clipped anything");
    }

    #[test]
    fn analytic_tables_exist_for_all_networks_instantly() {
        for g in models::all() {
            let t = calibrate_analytic(&g, Calibrator::Percentile(99.9));
            assert_eq!(t.frames, 0);
            for n in g.topo() {
                assert!(t.activation(n.id).max_abs() > 0.0, "{}", n.name);
                assert!(t.activation_std(n.id) > 0.0);
            }
            assert!(t.quantized_nodes().count() > 0);
        }
    }

    #[test]
    fn analytic_roughly_tracks_empirical_on_lenet() {
        let g = models::lenet5();
        let data = crate::data::mnist_like(8, 32, 5);
        let emp = calibrate(&g, &data, 8, Calibrator::MinMax);
        let ana = calibrate_analytic(&g, Calibrator::MinMax);
        for n in g.topo() {
            let (e, a) = (emp.activation(n.id).max_abs(), ana.activation(n.id).max_abs());
            // Same order of magnitude is all the analytic path promises.
            assert!(a > e / 30.0 && a < e * 30.0 + 5.0, "{}: emp {e} vs ana {a}", n.name);
        }
    }

    #[test]
    fn calibrator_parse() {
        assert_eq!(Calibrator::parse("minmax"), Some(Calibrator::MinMax));
        assert_eq!(Calibrator::parse("p99.9"), Some(Calibrator::Percentile(99.9)));
        assert_eq!(Calibrator::parse("p10"), None);
        assert_eq!(Calibrator::parse("bogus"), None);
    }

    #[test]
    fn hist_percentile_monotone() {
        let mut h = AbsHist::new();
        for i in 0..1000 {
            h.observe(i as f64 / 100.0);
        }
        let p50 = h.percentile_abs(50.0);
        let p99 = h.percentile_abs(99.0);
        assert!(p50 < p99);
        assert!(p99 <= h.top);
    }
}
