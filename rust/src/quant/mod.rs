//! Quantization-aware compilation — the paper's §VII future-work #1
//! ("reduced bit precision for weight/activation representation") built as
//! a first-class subsystem.
//!
//! The fp32 flow pays for its precision in DSPs and BRAM, which caps
//! unrolling and is a big part of why hand-optimized designs still win
//! (§V–VI); reduced precision is the standard lever the FPGA-CNN survey
//! literature identifies for closing that gap. This module provides the
//! compress-then-compile pipeline:
//!
//! * [`scheme`] — symmetric fixed-point grids ([`QParams`]) with
//!   per-tensor / per-channel scales ([`QScheme`]) and fp16 rounding;
//! * [`calibrate`] — activation-range calibration: empirical (min-max or
//!   percentile over representative frames through the reference
//!   executor) or analytic (moment propagation, O(nodes));
//! * [`rewrite`] — graph rewriter inserting explicit `Quantize` /
//!   `Dequantize` boundaries and folding them across compute chains;
//! * [`exec`] — the value-accurate reference + quantized executors that
//!   make accuracy loss *measurable*;
//! * [`accuracy`] — top-1 degradation, measured or estimated.
//!
//! Entry points: [`QuantConfig`] (what to quantize and how to calibrate)
//! and [`prepare`] (graph → quantized graph + calibration + report), which
//! [`crate::flow::CompileSession::with_quantization`] drives and
//! [`crate::dse`] sweeps as a search dimension.
//!
//! ```
//! use tvm_fpga_flow::graph::models;
//! use tvm_fpga_flow::quant::{prepare, QuantConfig};
//! use tvm_fpga_flow::texpr::Precision;
//!
//! let net = models::lenet5();
//! let prep = prepare(&net, &QuantConfig::int8()).unwrap();
//! assert_eq!(prep.report.precision, Precision::Int8);
//! // Quantize/dequantize boundaries were made explicit and folded…
//! assert!(prep.report.stats.quantize_nodes >= 1);
//! assert!(prep.report.stats.folded_pairs >= 1);
//! // …and the modeled top-1 loss is reported.
//! assert!(prep.report.accuracy.delta_pp < 25.0);
//! ```

pub mod accuracy;
pub mod calibrate;
pub mod exec;
pub mod rewrite;
pub mod scheme;

pub use accuracy::AccuracyReport;
pub use calibrate::{calibrate, calibrate_analytic, calibrate_in, CalibrationTable, Calibrator};
pub use exec::{argmax, Executor, FastExecutor, FUSE_BREAK_EVEN_ELEMS};
pub use rewrite::{insert_qdq, QuantStats};
pub use scheme::{accum_limit, f16_round, qmax, QParams, QScheme, Range};

use crate::graph::Graph;
use crate::texpr::Precision;

/// Where calibration ranges come from.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CalibrationSource {
    /// Moment propagation through the graph — no execution, any network.
    Analytic,
    /// Sweep `frames` frames of the network's representative dataset
    /// through the reference executor (small networks; exact statistics).
    Data { frames: usize },
}

/// A complete quantization recipe for one compilation.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantConfig {
    pub precision: Precision,
    pub scheme: QScheme,
    pub calibrator: Calibrator,
    pub source: CalibrationSource,
}

impl QuantConfig {
    /// The standard recipe for a precision: per-channel weights, p99.9
    /// percentile clipping, analytic calibration (works for any network).
    pub fn for_precision(precision: Precision) -> QuantConfig {
        QuantConfig {
            precision,
            scheme: QScheme::PerChannel,
            calibrator: Calibrator::Percentile(99.9),
            source: CalibrationSource::Analytic,
        }
    }

    /// int8, per-channel, percentile-calibrated.
    pub fn int8() -> QuantConfig {
        QuantConfig::for_precision(Precision::Int8)
    }

    /// fp16 (rounding only — no calibration sensitivity).
    pub fn fp16() -> QuantConfig {
        QuantConfig::for_precision(Precision::F16)
    }

    pub fn with_scheme(mut self, scheme: QScheme) -> Self {
        self.scheme = scheme;
        self
    }

    pub fn with_calibrator(mut self, calibrator: Calibrator) -> Self {
        self.calibrator = calibrator;
        self
    }

    /// Calibrate (and measure accuracy) on `frames` real frames instead of
    /// the analytic model.
    pub fn with_data(mut self, frames: usize) -> Self {
        self.source = CalibrationSource::Data { frames: frames.max(1) };
        self
    }
}

/// What one quantized compilation did — carried on
/// [`crate::flow::Accelerator::quant`] and the DSE's design points.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantReport {
    pub precision: Precision,
    pub scheme: QScheme,
    /// Calibration method name (`min-max`, `p99.9`, …).
    pub calibrator: String,
    /// Frames calibrated on (0 = analytic).
    pub calibration_frames: usize,
    pub stats: QuantStats,
    pub accuracy: AccuracyReport,
}

/// Output of [`prepare`]: the compile-ready rewritten graph plus
/// everything the rest of the flow needs to know about the quantization.
#[derive(Debug, Clone)]
pub struct PreparedQuant {
    /// BN-folded, Q/DQ-rewritten graph.
    pub graph: Graph,
    pub table: CalibrationTable,
    pub report: QuantReport,
    /// Trace of the graph passes (bn-fold, pad-fuse, dce, insert-qdq) the
    /// front-end ran — prepended to the session's pass trace.
    pub trace: crate::pass::PassTrace,
}

/// Run the quantization front-end on a graph: fold BN through the standard
/// pass pipeline, calibrate, insert + fold Q/DQ boundaries and produce the
/// accuracy report. `Precision::F32` degenerates to the pass pipeline with
/// a lossless report.
pub fn prepare(graph: &Graph, cfg: &QuantConfig) -> crate::Result<PreparedQuant> {
    use crate::pass::{EliminateDead, FoldBatchNorm, FusePad, InsertQdq, PassManager, Pipeline};

    let mut manager = PassManager::new();
    let folding = Pipeline::default().graph(FoldBatchNorm).graph(FusePad).graph(EliminateDead);
    let folded = manager.run_graph_passes(&folding, graph);
    // One arena for the whole front-end: calibration and accuracy
    // measurement run the same shapes, so the measure pass reuses the
    // buffers calibration checked back in.
    let mut scratch = crate::util::scratch::Scratch::new();
    let table = match cfg.source {
        CalibrationSource::Analytic => calibrate_analytic(&folded, cfg.calibrator),
        CalibrationSource::Data { frames } => {
            let batch = crate::data::for_network(&folded.name, frames, 17).ok_or_else(|| {
                anyhow::anyhow!(
                    "no representative dataset for '{}' — use analytic calibration",
                    folded.name
                )
            })?;
            calibrate_in(&folded, &batch, frames, cfg.calibrator, &mut scratch)
        }
    };
    let accuracy = match cfg.source {
        CalibrationSource::Analytic => {
            accuracy::estimate(&folded, &table, cfg.precision, cfg.scheme)
        }
        CalibrationSource::Data { frames } => {
            accuracy::measure_in(&folded, &table, cfg.precision, cfg.scheme, frames, &mut scratch)
        }
    };
    let qdq = Pipeline::default().graph(InsertQdq::new(cfg.precision));
    let rewritten = manager.run_graph_passes(&qdq, &folded);
    if let Some(reason) = manager.trace.records.last().and_then(|r| r.skipped.clone()) {
        anyhow::bail!("quantization front-end could not rewrite the graph: {reason}");
    }
    let stats = manager
        .trace
        .records
        .last()
        .map(|r| QuantStats {
            quantize_nodes: r.diff.quantize_nodes,
            dequantize_nodes: r.diff.dequantize_nodes,
            folded_pairs: r.diff.pairs_folded,
        })
        .unwrap_or_default();
    Ok(PreparedQuant {
        graph: rewritten,
        table,
        trace: manager.into_trace(),
        report: QuantReport {
            precision: cfg.precision,
            scheme: cfg.scheme,
            calibrator: cfg.calibrator.name(),
            calibration_frames: match cfg.source {
                CalibrationSource::Analytic => 0,
                CalibrationSource::Data { frames } => frames,
            },
            stats,
            accuracy,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    #[test]
    fn prepare_f32_is_lossless_passthrough() {
        let g = models::lenet5();
        let p = prepare(&g, &QuantConfig::for_precision(Precision::F32)).unwrap();
        assert_eq!(p.report.accuracy.delta_pp, 0.0);
        assert_eq!(p.report.stats, QuantStats::default());
        assert_eq!(p.graph.total_macs(), g.total_macs());
    }

    #[test]
    fn prepare_int8_with_data_measures_accuracy() {
        let g = models::lenet5();
        let p = prepare(&g, &QuantConfig::int8().with_data(8)).unwrap();
        assert!(!p.report.accuracy.estimated);
        assert_eq!(p.report.accuracy.frames, 8);
        assert!(p.report.accuracy.top1_agreement >= 0.75);
        assert!(p.report.stats.quantize_nodes > 0);
        assert_eq!(p.report.calibration_frames, 8);
    }

    #[test]
    fn prepare_analytic_works_for_every_network() {
        for g in models::all() {
            for cfg in [QuantConfig::int8(), QuantConfig::fp16()] {
                let p = prepare(&g, &cfg).unwrap();
                assert!(p.report.accuracy.estimated, "{}", g.name);
                assert!(p.report.accuracy.delta_pp < 25.0, "{}", g.name);
                p.graph.validate().unwrap();
            }
        }
    }

    #[test]
    fn data_calibration_requires_a_known_dataset() {
        use crate::graph::{GraphBuilder, Shape};
        let (mut b, x) = GraphBuilder::new("unknown-net", Shape::Chw(1, 8, 8));
        let d = b.add("f", crate::graph::Op::Flatten, &[x]);
        let g = b.finish(d);
        assert!(prepare(&g, &QuantConfig::int8().with_data(4)).is_err());
        assert!(prepare(&g, &QuantConfig::int8()).is_ok());
    }
}
