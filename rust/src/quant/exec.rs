//! Reference graph executor — the numeric ground truth quantization is
//! measured against.
//!
//! The flow has no trained weights (DESIGN.md §Substitutions), so the
//! executor materializes *deterministic synthetic* weights per node
//! (He-scaled normals seeded by network name + node id). That is exactly
//! what the rest of the repo does for data: throughput is value-independent
//! and accuracy *deltas* (f32 vs quantized on the same weights) exercise
//! the identical error mechanisms as trained weights — saturation, grid
//! rounding, per-channel scale mismatch.
//!
//! Two evaluation modes share one traversal:
//!
//! * [`Executor::forward`] — f32 reference, observing every activation
//!   (the calibration hook);
//! * [`Executor::forward_quantized`] — compute ops run on the symmetric
//!   integer grid (int8: quantized operands, i64 accumulation, rescale) or
//!   through fp16 rounding, everything else in f32 — the §VII
//!   reduced-precision datapath, value-accurate.
//!
//! [`Executor`] allocates fresh buffers per node per frame — it is the
//! *semantic baseline*. The hot paths (calibration, accuracy measurement,
//! differential verification) run on [`FastExecutor`] instead: the same
//! traversal over non-allocating `*_into` kernel cores with
//! [`Scratch`]-arena-owned buffers, frame-invariant operand caches
//! (quantized/fp16-rounded weights) and fused conv→bn→relu epilogue
//! chains — bit-identical to the baseline by construction
//! (`rust/tests/fastpath_equivalence.rs`) and allocation-free at steady
//! state (`rust/tests/alloc_regression.rs`). See docs/ARCHITECTURE.md
//! ("Host-executor fast path").

use std::time::Instant;

use crate::graph::{Activation, Graph, NodeId, Op, Shape};
use crate::texpr::Precision;
use crate::util::rng::Rng;
use crate::util::scratch::{Scratch, ScratchStats};

use super::calibrate::CalibrationTable;
use super::scheme::{f16_round, QParams, QScheme, Range};

/// Per-node synthetic parameters.
#[derive(Debug, Clone, Default)]
struct NodeParams {
    /// Conv: OIHW; dense: [out × in]; BN: gamma per channel.
    weights: Vec<f32>,
    /// Bias (or BN beta) per output channel.
    bias: Vec<f32>,
}

/// Deterministic reference interpreter for one graph.
pub struct Executor<'g> {
    pub graph: &'g Graph,
    params: Vec<NodeParams>,
}

impl<'g> Executor<'g> {
    /// Build the executor, materializing synthetic weights for every
    /// parameterized node.
    pub fn new(graph: &'g Graph) -> Executor<'g> {
        let seed = crate::util::fnv64(graph.name.as_bytes());
        Self::with_seed_map(graph, seed, |id| id as u64)
    }

    /// Build an executor for a pipeline-stage subgraph that reproduces the
    /// *parent* graph's synthetic weights. Stage graphs are rebuilt with
    /// fresh names and renumbered node ids, but weights are seeded by
    /// `(network name, node id)` — so each stage node must draw from its
    /// parent node's stream (`parent_ids` from
    /// [`crate::pass::partition::StageGraph`]) or chained stage execution
    /// would diverge from the unpartitioned oracle.
    pub fn for_stage(graph: &'g Graph, parent_name: &str, parent_ids: &[usize]) -> Executor<'g> {
        assert_eq!(parent_ids.len(), graph.nodes.len(), "parent id map must cover every node");
        let seed = crate::util::fnv64(parent_name.as_bytes());
        let ids = parent_ids.to_vec();
        Self::with_seed_map(graph, seed, move |id| ids[id] as u64)
    }

    fn with_seed_map(graph: &'g Graph, seed: u64, seed_id: impl Fn(usize) -> u64) -> Executor<'g> {
        let params = graph
            .nodes
            .iter()
            .map(|n| {
                let mut rng = Rng::new(seed ^ seed_id(n.id).wrapping_mul(0x9E3779B97F4A7C15));
                match &n.op {
                    Op::Conv2d { out_channels, kernel, bias, .. } => {
                        let cin = graph.nodes[n.inputs[0]].shape.chw().map(|c| c.0).unwrap_or(1);
                        let fan_in = cin * kernel * kernel;
                        he_params(&mut rng, *out_channels * fan_in, fan_in, *out_channels, *bias)
                    }
                    Op::DepthwiseConv2d { kernel, bias, .. } => {
                        let c = n.shape.chw().map(|c| c.0).unwrap_or(1);
                        let fan_in = kernel * kernel;
                        he_params(&mut rng, c * fan_in, fan_in, c, *bias)
                    }
                    Op::Dense { out_features, bias, .. } => {
                        let cin = graph.nodes[n.inputs[0]].shape.elems();
                        he_params(&mut rng, out_features * cin, cin, *out_features, *bias)
                    }
                    Op::BatchNorm => {
                        let c = channels_of(&n.shape);
                        NodeParams {
                            weights: (0..c).map(|_| 1.0 + 0.05 * rng.normal()).collect(),
                            bias: (0..c).map(|_| 0.02 * rng.normal()).collect(),
                        }
                    }
                    _ => NodeParams::default(),
                }
            })
            .collect();
        Executor { graph, params }
    }

    /// Synthetic weights of one node (oracle hook for the `crate::verify`
    /// kernel interpreter, which must run on the *same* parameters as the
    /// reference it is diffed against). Conv: OIHW; dense: [out × in];
    /// BN: per-channel γ. Empty for weightless nodes.
    pub fn weights(&self, node: NodeId) -> &[f32] {
        &self.params[node].weights
    }

    /// Synthetic per-output-channel bias (or BN β) of one node — the
    /// companion oracle hook to [`Executor::weights`].
    pub fn bias(&self, node: NodeId) -> &[f32] {
        &self.params[node].bias
    }

    /// Per-output-channel weight ranges of one node (empty for weightless
    /// nodes) — what per-channel calibration quantizes against.
    pub fn weight_channel_ranges(&self, node: NodeId) -> Vec<Range> {
        let n = &self.graph.nodes[node];
        let p = &self.params[node];
        let oc = match &n.op {
            Op::Conv2d { out_channels, .. } => *out_channels,
            Op::DepthwiseConv2d { .. } => n.shape.chw().map(|c| c.0).unwrap_or(1),
            Op::Dense { out_features, .. } => *out_features,
            _ => return Vec::new(),
        };
        let per = p.weights.len() / oc.max(1);
        (0..oc)
            .map(|c| {
                let mut r = Range::EMPTY;
                for &w in &p.weights[c * per..(c + 1) * per] {
                    r.observe(w as f64);
                }
                r
            })
            .collect()
    }

    /// f32 reference forward pass; `observe` sees every node's activation
    /// (in topological order) — the calibration hook. Returns the output
    /// node's activation (logits).
    pub fn forward(&self, frame: &[f32], mut observe: impl FnMut(NodeId, &[f32])) -> Vec<f32> {
        self.run(frame, None, &mut observe)
    }

    /// [`Executor::forward`] with a per-layer span tree under an `exec`
    /// `frame` span when the tracer is enabled (plain `forward` when not —
    /// the disabled cost is one atomic load). Layer durations are the
    /// wall-clock between consecutive observer callbacks, so the trace
    /// costs no extra traversal.
    pub fn forward_traced(&self, frame: &[f32]) -> Vec<f32> {
        if !crate::obs::enabled() {
            return self.forward(frame, |_, _| {});
        }
        let mut frame_span = crate::obs::span("exec", "frame");
        frame_span.set_arg("network", self.graph.name.as_str());
        let parent = frame_span.id();
        let g = self.graph;
        let mut prev = Instant::now();
        self.forward(frame, |nid, act| {
            let now = Instant::now();
            crate::obs::span_at(
                "exec",
                &g.nodes[nid].name,
                parent,
                prev,
                now,
                vec![("elems", crate::obs::ArgValue::Num(act.len() as f64))],
            );
            prev = now;
        })
    }

    /// Quantized forward pass: compute ops execute on the reduced-precision
    /// datapath described by (`table`, `precision`, `scheme`).
    pub fn forward_quantized(
        &self,
        frame: &[f32],
        table: &CalibrationTable,
        precision: Precision,
        scheme: QScheme,
    ) -> Vec<f32> {
        self.forward_quantized_observed(frame, table, precision, scheme, |_, _| {})
    }

    /// [`Executor::forward_quantized`] with an observer that sees every
    /// node's activation in topological order — the mismatch-localization
    /// hook of the `crate::verify` differential harness (find the first
    /// node where the kernel interpreter and this oracle diverge).
    pub fn forward_quantized_observed(
        &self,
        frame: &[f32],
        table: &CalibrationTable,
        precision: Precision,
        scheme: QScheme,
        mut observe: impl FnMut(NodeId, &[f32]),
    ) -> Vec<f32> {
        let q = QuantCtx { table, precision, scheme };
        self.run(frame, Some(&q), &mut observe)
    }

    fn run(
        &self,
        frame: &[f32],
        q: Option<&QuantCtx>,
        observe: &mut dyn FnMut(NodeId, &[f32]),
    ) -> Vec<f32> {
        let g = self.graph;
        let mut acts: Vec<Vec<f32>> = vec![Vec::new(); g.nodes.len()];
        for n in g.topo() {
            let out = match &n.op {
                Op::Input => {
                    assert_eq!(frame.len(), n.shape.elems(), "input frame size mismatch");
                    frame.to_vec()
                }
                Op::Conv2d { kernel, stride, padding, bias, activation, .. } => self.conv(
                    n.id,
                    &acts[n.inputs[0]],
                    &g.nodes[n.inputs[0]].shape,
                    &n.shape,
                    *kernel,
                    *stride,
                    *padding,
                    false,
                    *bias,
                    *activation,
                    q,
                ),
                Op::DepthwiseConv2d { kernel, stride, padding, bias, activation } => self.conv(
                    n.id,
                    &acts[n.inputs[0]],
                    &g.nodes[n.inputs[0]].shape,
                    &n.shape,
                    *kernel,
                    *stride,
                    *padding,
                    true,
                    *bias,
                    *activation,
                    q,
                ),
                Op::Dense { bias, activation, .. } => {
                    self.dense(n.id, &acts[n.inputs[0]], *bias, *activation, q)
                }
                Op::BatchNorm => {
                    let p = &self.params[n.id];
                    let x = &acts[n.inputs[0]];
                    let c = channels_of(&n.shape);
                    let per = x.len() / c.max(1);
                    x.iter()
                        .enumerate()
                        .map(|(i, &v)| v * p.weights[i / per.max(1)] + p.bias[i / per.max(1)])
                        .collect()
                }
                Op::Activate(a) => acts[n.inputs[0]].iter().map(|&v| activate(v, *a)).collect(),
                Op::MaxPool { kernel, stride, padding } => pool(
                    &acts[n.inputs[0]],
                    &g.nodes[n.inputs[0]].shape,
                    &n.shape,
                    *kernel,
                    *stride,
                    *padding,
                    true,
                ),
                Op::AvgPool { kernel, stride, padding } => pool(
                    &acts[n.inputs[0]],
                    &g.nodes[n.inputs[0]].shape,
                    &n.shape,
                    *kernel,
                    *stride,
                    *padding,
                    false,
                ),
                Op::GlobalAvgPool => {
                    let (c, h, w) = g.nodes[n.inputs[0]].shape.chw().expect("gap input CHW");
                    let x = &acts[n.inputs[0]];
                    (0..c)
                        .map(|ch| {
                            x[ch * h * w..(ch + 1) * h * w].iter().sum::<f32>() / (h * w) as f32
                        })
                        .collect()
                }
                Op::Add => {
                    let (a, b) = (&acts[n.inputs[0]], &acts[n.inputs[1]]);
                    a.iter().zip(b.iter()).map(|(x, y)| x + y).collect()
                }
                Op::Softmax => {
                    let x = &acts[n.inputs[0]];
                    let m = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let e: Vec<f32> = x.iter().map(|v| (v - m).exp()).collect();
                    let s: f32 = e.iter().sum();
                    e.into_iter().map(|v| v / s).collect()
                }
                Op::Transform | Op::Flatten => acts[n.inputs[0]].clone(),
                Op::Quantize { precision } => {
                    // A rewritten graph carries explicit grid boundaries:
                    // round-trip through the calibrated grid of the source.
                    let src = n.inputs[0];
                    match q {
                        Some(ctx) if *precision == Precision::Int8 => {
                            let qp = ctx.act_params(src);
                            acts[src].iter().map(|&v| qp.roundtrip(v as f64, 0) as f32).collect()
                        }
                        _ if *precision == Precision::F16 => {
                            acts[src].iter().map(|&v| f16_round(v)).collect()
                        }
                        _ => acts[src].clone(),
                    }
                }
                Op::Dequantize { .. } => acts[n.inputs[0]].clone(),
            };
            observe(n.id, &out);
            acts[n.id] = out;
        }
        std::mem::take(&mut acts[g.output])
    }

    #[allow(clippy::too_many_arguments)]
    fn conv(
        &self,
        node: NodeId,
        x: &[f32],
        in_shape: &Shape,
        out_shape: &Shape,
        k: usize,
        stride: usize,
        padding: usize,
        depthwise: bool,
        bias: bool,
        act: Activation,
        q: Option<&QuantCtx>,
    ) -> Vec<f32> {
        let (cin, h, w) = in_shape.chw().expect("conv input CHW");
        let (oc, oh, ow) = out_shape.chw().expect("conv output CHW");
        let p = &self.params[node];
        let dp = q.map(|ctx| ctx.datapath(self, node, x));
        let mut out = vec![0f32; oc * oh * ow];
        for o in 0..oc {
            let w_base = if depthwise { o * k * k } else { o * cin * k * k };
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc_f = 0f64;
                    let mut acc_i = 0i64;
                    let crange = if depthwise { o..o + 1 } else { 0..cin };
                    for c in crange {
                        for ky in 0..k {
                            for kx in 0..k {
                                let iy = (oy * stride + ky) as isize - padding as isize;
                                let ix = (ox * stride + kx) as isize - padding as isize;
                                if iy < 0 || ix < 0 || iy >= h as isize || ix >= w as isize {
                                    continue;
                                }
                                let xi = c * h * w + iy as usize * w + ix as usize;
                                let wi = if depthwise {
                                    w_base + ky * k + kx
                                } else {
                                    w_base + (c * k + ky) * k + kx
                                };
                                match &dp {
                                    Some(Datapath::Int8 { qx, qw, .. }) => {
                                        acc_i += qx[xi] as i64 * qw[wi] as i64;
                                    }
                                    Some(Datapath::F16 { rx }) => {
                                        acc_f +=
                                            (rx[xi] * f16_round(p.weights[wi])) as f64;
                                    }
                                    None => acc_f += (x[xi] * p.weights[wi]) as f64,
                                }
                            }
                        }
                    }
                    let mut v = match &dp {
                        Some(Datapath::Int8 { sx, wq, .. }) => {
                            acc_i as f64 * sx * wq.scale(o)
                        }
                        _ => acc_f,
                    } as f32;
                    if bias {
                        v += p.bias[o];
                    }
                    if matches!(dp, Some(Datapath::F16 { .. })) {
                        v = f16_round(v);
                    }
                    out[(o * oh + oy) * ow + ox] = activate(v, act);
                }
            }
        }
        out
    }

    fn dense(
        &self,
        node: NodeId,
        x: &[f32],
        bias: bool,
        act: Activation,
        q: Option<&QuantCtx>,
    ) -> Vec<f32> {
        let p = &self.params[node];
        let cin = x.len();
        let oc = p.bias.len().max(p.weights.len() / cin.max(1));
        let dp = q.map(|ctx| ctx.datapath(self, node, x));
        (0..oc)
            .map(|o| {
                let row = &p.weights[o * cin..(o + 1) * cin];
                let mut v = match &dp {
                    Some(Datapath::Int8 { qx, qw, sx, wq }) => {
                        let qrow = &qw[o * cin..(o + 1) * cin];
                        let acc: i64 =
                            qx.iter().zip(qrow).map(|(&a, &b)| a as i64 * b as i64).sum();
                        (acc as f64 * sx * wq.scale(o)) as f32
                    }
                    Some(Datapath::F16 { rx }) => f16_round(
                        rx.iter().zip(row).map(|(&a, &b)| a * f16_round(b)).sum::<f32>(),
                    ),
                    None => x.iter().zip(row).map(|(&a, &b)| a * b).sum::<f32>(),
                };
                if bias {
                    v += p.bias[o];
                }
                activate(v, act)
            })
            .collect()
    }
}

/// Quantized-datapath context for one forward pass.
struct QuantCtx<'a> {
    table: &'a CalibrationTable,
    precision: Precision,
    scheme: QScheme,
}

/// Prepared operands of one compute op on the reduced-precision datapath.
enum Datapath {
    Int8 { qx: Vec<i32>, qw: Vec<i32>, sx: f64, wq: QParams },
    F16 { rx: Vec<f32> },
}

/// Quantized operands of one compute op — the grid-side of [`Datapath`],
/// shared with the `verify` interpreter so both sides of the differential
/// prepare operands identically (scheme selection, range merge and
/// per-channel weight-group indexing are pass-invariant semantics).
pub(crate) struct QuantizedOperands {
    pub qx: Vec<i32>,
    pub qw: Vec<i32>,
    /// Activation (per-tensor) scale.
    pub sx: f64,
    /// Weight grid (per-tensor or per-channel).
    pub wq: QParams,
}

/// Quantize `x` against the calibrated activation range and `weights`
/// against the per-channel ranges under `scheme` (per-tensor = the merged
/// range) — the canonical int8 operand preparation.
pub(crate) fn quantize_operands(
    x: &[f32],
    weights: &[f32],
    act_range: Range,
    weight_ranges: &[Range],
    scheme: QScheme,
) -> QuantizedOperands {
    let prep = int8_prep(weights, act_range, weight_ranges, scheme);
    QuantizedOperands {
        qx: x.iter().map(|&v| prep.xq.quantize(v as f64, 0)).collect(),
        qw: prep.qw,
        sx: prep.sx,
        wq: prep.wq,
    }
}

/// Weight-grid selection under `scheme`: per-channel when asked for and
/// ranges exist, otherwise one per-tensor grid over the merged range.
/// Factored out so the per-frame [`quantize_operands`] and the
/// frame-invariant [`int8_prep`] provably build identical grids.
pub(crate) fn weight_grid(weight_ranges: &[Range], scheme: QScheme) -> QParams {
    match scheme {
        QScheme::PerChannel if !weight_ranges.is_empty() => {
            QParams::per_channel(weight_ranges, Precision::Int8)
        }
        _ => {
            let whole = weight_ranges.iter().fold(Range::EMPTY, |a, r| a.merge(r));
            QParams::per_tensor(whole, Precision::Int8)
        }
    }
}

/// Frame-invariant half of the int8 operand preparation: quantized
/// weights plus both grids. Built once per node (weights and calibrated
/// ranges never change between frames); only the activation quantization
/// remains per-frame ([`quantize_into`]).
///
/// Deliberately does *not* pre-multiply `sx * wq.scale(o)` into one
/// factor: f64 multiplication is non-associative, and the baseline
/// computes `(acc as f64 * sx * wq.scale(o)) as f32` — the fast path must
/// keep that exact grouping to stay bit-identical.
pub(crate) struct Int8Prep {
    pub qw: Vec<i32>,
    /// Activation (per-tensor) grid.
    pub xq: QParams,
    /// Activation scale (`xq.scale(0)`).
    pub sx: f64,
    /// Weight grid (per-tensor or per-channel).
    pub wq: QParams,
}

/// Build the frame-invariant int8 operand cache for one compute node.
pub(crate) fn int8_prep(
    weights: &[f32],
    act_range: Range,
    weight_ranges: &[Range],
    scheme: QScheme,
) -> Int8Prep {
    let xq = QParams::per_tensor(act_range, Precision::Int8);
    let wq = weight_grid(weight_ranges, scheme);
    let oc = wq.groups().max(1);
    let per = weights.len() / oc;
    Int8Prep {
        qw: weights
            .iter()
            .enumerate()
            .map(|(i, &w)| wq.quantize(w as f64, i / per.max(1)))
            .collect(),
        sx: xq.scale(0),
        xq,
        wq,
    }
}

/// Quantize a frame's activations into a caller-owned buffer (the
/// per-frame half of [`int8_prep`]). `out.len()` must equal `x.len()`.
pub(crate) fn quantize_into(x: &[f32], xq: &QParams, out: &mut [i32]) {
    for (o, &v) in out.iter_mut().zip(x) {
        *o = xq.quantize(v as f64, 0);
    }
}

/// Round a frame's activations onto the fp16 grid into a caller-owned
/// buffer (the per-frame half of the fp16 datapath).
pub(crate) fn f16_round_into(x: &[f32], out: &mut [f32]) {
    for (o, &v) in out.iter_mut().zip(x) {
        *o = f16_round(v);
    }
}

impl QuantCtx<'_> {
    fn act_params(&self, node: NodeId) -> QParams {
        QParams::per_tensor(self.table.activation(node), Precision::Int8)
    }

    fn datapath(&self, exec: &Executor, node: NodeId, x: &[f32]) -> Datapath {
        match self.precision {
            Precision::F16 => Datapath::F16 { rx: x.iter().map(|&v| f16_round(v)).collect() },
            _ => {
                let src = exec.graph.nodes[node].inputs[0];
                let q = quantize_operands(
                    x,
                    &exec.params[node].weights,
                    self.table.activation(src),
                    &self.table.weight_ranges(node),
                    self.scheme,
                );
                Datapath::Int8 { qx: q.qx, qw: q.qw, sx: q.sx, wq: q.wq }
            }
        }
    }
}

fn he_params(rng: &mut Rng, n_weights: usize, fan_in: usize, oc: usize, bias: bool) -> NodeParams {
    let std = (2.0 / fan_in.max(1) as f64).sqrt() as f32;
    NodeParams {
        weights: (0..n_weights).map(|_| rng.normal() * std).collect(),
        bias: if bias { (0..oc).map(|_| 0.01 * rng.normal()).collect() } else { vec![0.0; oc] },
    }
}

/// Channel count of a shape (flat tensors are all-channel). Shared with
/// the `verify` interpreter so both sides of the differential stay in
/// lockstep on scheduling-invariant semantics.
pub(crate) fn channels_of(s: &Shape) -> usize {
    match s {
        Shape::Chw(c, ..) => *c,
        Shape::Flat(n) => *n,
    }
}

/// Activation semantics (shared with the `verify` interpreter — no
/// schedule pass has value freedom here).
pub(crate) fn activate(v: f32, a: Activation) -> f32 {
    match a {
        Activation::None => v,
        Activation::Relu => v.max(0.0),
        Activation::Relu6 => v.clamp(0.0, 6.0),
        Activation::Tanh => v.tanh(),
    }
}

/// Pooling semantics (shared with the `verify` interpreter; average
/// pools divide by the full window even at padded borders).
pub(crate) fn pool(
    x: &[f32],
    in_shape: &Shape,
    out_shape: &Shape,
    k: usize,
    stride: usize,
    padding: usize,
    is_max: bool,
) -> Vec<f32> {
    let (c, _, _) = in_shape.chw().expect("pool input CHW");
    let (_, oh, ow) = out_shape.chw().expect("pool output CHW");
    let mut out = vec![0f32; c * oh * ow];
    pool_into(x, in_shape, out_shape, k, stride, padding, is_max, &mut out);
    out
}

/// Non-allocating [`pool`]: writes `c * oh * ow` values into `out`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn pool_into(
    x: &[f32],
    in_shape: &Shape,
    out_shape: &Shape,
    k: usize,
    stride: usize,
    padding: usize,
    is_max: bool,
    out: &mut [f32],
) {
    let (c, h, w) = in_shape.chw().expect("pool input CHW");
    let (_, oh, ow) = out_shape.chw().expect("pool output CHW");
    for ch in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut m = f32::NEG_INFINITY;
                let mut s = 0f32;
                for ky in 0..k {
                    for kx in 0..k {
                        let iy = (oy * stride + ky) as isize - padding as isize;
                        let ix = (ox * stride + kx) as isize - padding as isize;
                        if iy < 0 || ix < 0 || iy >= h as isize || ix >= w as isize {
                            continue;
                        }
                        let v = x[ch * h * w + iy as usize * w + ix as usize];
                        m = m.max(v);
                        s += v;
                    }
                }
                out[(ch * oh + oy) * ow + ox] = if is_max { m } else { s / (k * k) as f32 };
            }
        }
    }
}

/// Non-allocating BatchNorm: `v * γ[channel] + β[channel]`, channel-major
/// layout (identical index arithmetic to the baseline traversal).
pub(crate) fn batchnorm_into(
    x: &[f32],
    gamma: &[f32],
    beta: &[f32],
    channels: usize,
    out: &mut [f32],
) {
    let per = (x.len() / channels.max(1)).max(1);
    for (i, (o, &v)) in out.iter_mut().zip(x).enumerate() {
        *o = v * gamma[i / per] + beta[i / per];
    }
}

/// Operand views for one compute dispatch on the shared `*_into` cores.
/// Weights are frame-invariant (cached by [`FastExecutor`] /
/// the verify interpreter); only the activation side changes per frame.
pub(crate) enum MatOperands<'a> {
    F32 { x: &'a [f32], w: &'a [f32] },
    /// fp16: both sides pre-rounded onto the half grid.
    F16 { rx: &'a [f32], rw: &'a [f32] },
    /// int8: quantized operands plus the scales for the f32 rescale.
    Int8 { qx: &'a [i32], qw: &'a [i32], sx: f64, wq: &'a QParams },
}

/// Conv/depthwise geometry for [`conv_core_into`].
#[derive(Clone, Copy)]
pub(crate) struct ConvGeom {
    pub cin: usize,
    pub h: usize,
    pub w: usize,
    pub oc: usize,
    pub oh: usize,
    pub ow: usize,
    pub k: usize,
    pub stride: usize,
    pub padding: usize,
    pub depthwise: bool,
}

impl ConvGeom {
    /// Geometry from the graph shapes of a conv/depthwise node.
    pub fn from_shapes(
        in_shape: &Shape,
        out_shape: &Shape,
        k: usize,
        stride: usize,
        padding: usize,
        depthwise: bool,
    ) -> ConvGeom {
        let (cin, h, w) = in_shape.chw().expect("conv input CHW");
        let (oc, oh, ow) = out_shape.chw().expect("conv output CHW");
        ConvGeom { cin, h, w, oc, oh, ow, k, stride, padding, depthwise }
    }
}

/// Non-allocating conv/depthwise core, all three precisions. `epilogue`
/// receives `(macc_result, output_channel)` for every output element —
/// bias, fp16 rounding, activation and any fused elementwise chain live
/// in the caller's closure, so one core serves both executors and the
/// verify interpreter (whose recorded epilogue may differ from op attrs).
///
/// Bit-identical to [`Executor`]'s branchy reference loop: the nest
/// visits exactly the in-bounds `(c, ky, kx)` iterations in the same
/// ascending order (skipped padding taps contribute nothing there too),
/// and per-precision accumulation keeps the baseline expression shapes —
/// f32/fp16 `acc += (x * w) as f64`, int8 i64 MACs rescaled as
/// `(acc as f64 * sx * wq.scale(o)) as f32`.
pub(crate) fn conv_core_into(
    dp: &MatOperands<'_>,
    g: ConvGeom,
    epilogue: impl Fn(f32, usize) -> f32,
    out: &mut [f32],
) {
    match dp {
        MatOperands::F32 { x, w } => conv_nest(
            x,
            w,
            g,
            0f64,
            |acc, a: f32, b: f32| acc + (a * b) as f64,
            |acc, _| acc as f32,
            &epilogue,
            out,
        ),
        MatOperands::F16 { rx, rw } => conv_nest(
            rx,
            rw,
            g,
            0f64,
            |acc, a: f32, b: f32| acc + (a * b) as f64,
            |acc, _| acc as f32,
            &epilogue,
            out,
        ),
        MatOperands::Int8 { qx, qw, sx, wq } => conv_nest(
            qx,
            qw,
            g,
            0i64,
            |acc, a: i32, b: i32| acc + a as i64 * b as i64,
            |acc, o| (acc as f64 * sx * wq.scale(o)) as f32,
            &epilogue,
            out,
        ),
    }
}

/// The one conv loop nest, generic over element/accumulator type, with
/// per-output valid kernel ranges so the inner loop runs on contiguous
/// slices with no per-tap bounds branch.
#[allow(clippy::too_many_arguments)]
fn conv_nest<T: Copy, A: Copy>(
    x: &[T],
    wts: &[T],
    g: ConvGeom,
    zero: A,
    mac: impl Fn(A, T, T) -> A,
    finish: impl Fn(A, usize) -> f32,
    epilogue: &impl Fn(f32, usize) -> f32,
    out: &mut [f32],
) {
    let ConvGeom { cin, h, w, oc, oh, ow, k, stride, padding, depthwise } = g;
    for o in 0..oc {
        let w_base = if depthwise { o * k * k } else { o * cin * k * k };
        for oy in 0..oh {
            // Valid tap rows: padding.saturating_sub clamps the low edge,
            // (h + padding) the high edge; an empty range is a fully
            // padded window (the baseline accumulates nothing there too).
            let ky_lo = padding.saturating_sub(oy * stride).min(k);
            let ky_hi = (h + padding).saturating_sub(oy * stride).min(k);
            for ox in 0..ow {
                let kx_lo = padding.saturating_sub(ox * stride).min(k);
                let kx_hi = (w + padding).saturating_sub(ox * stride).min(k);
                let span = kx_hi.saturating_sub(kx_lo);
                let mut acc = zero;
                if span > 0 {
                    // kx_lo < k here, so it equals the unclamped low edge
                    // and ix0 cannot underflow.
                    let ix0 = ox * stride + kx_lo - padding;
                    let (c0, c1) = if depthwise { (o, o + 1) } else { (0, cin) };
                    for c in c0..c1 {
                        let xc = &x[c * h * w..(c + 1) * h * w];
                        let wc = w_base + if depthwise { 0 } else { c * k * k };
                        for ky in ky_lo..ky_hi {
                            let iy = oy * stride + ky - padding;
                            let xs = &xc[iy * w + ix0..iy * w + ix0 + span];
                            let ws = &wts[wc + ky * k + kx_lo..wc + ky * k + kx_hi];
                            for (&xa, &wb) in xs.iter().zip(ws) {
                                acc = mac(acc, xa, wb);
                            }
                        }
                    }
                }
                out[(o * oh + oy) * ow + ox] = epilogue(finish(acc, o), o);
            }
        }
    }
}

/// Non-allocating dense core, all three precisions; `epilogue` as in
/// [`conv_core_into`]. fp16 rounds the dot product *before* the epilogue
/// (the baseline's dense order — conv instead rounds after the bias,
/// which is why rounding sits in the caller's closure there).
pub(crate) fn dense_core_into(
    dp: &MatOperands<'_>,
    cin: usize,
    oc: usize,
    epilogue: impl Fn(f32, usize) -> f32,
    out: &mut [f32],
) {
    for (o, slot) in out.iter_mut().enumerate().take(oc) {
        let v = match dp {
            MatOperands::F32 { x, w } => {
                let row = &w[o * cin..(o + 1) * cin];
                x.iter().zip(row).map(|(&a, &b)| a * b).sum::<f32>()
            }
            MatOperands::F16 { rx, rw } => {
                let row = &rw[o * cin..(o + 1) * cin];
                f16_round(rx.iter().zip(row).map(|(&a, &b)| a * b).sum::<f32>())
            }
            MatOperands::Int8 { qx, qw, sx, wq } => {
                let qrow = &qw[o * cin..(o + 1) * cin];
                let acc: i64 = qx.iter().zip(qrow).map(|(&a, &b)| a as i64 * b as i64).sum();
                (acc as f64 * sx * wq.scale(o)) as f32
            }
        };
        *slot = epilogue(v, o);
    }
}

/// Outputs smaller than this skip epilogue fusion in [`FastExecutor`].
/// For tiny tensors the fused closure's per-element chain dispatch costs
/// more than the separate cache-warm elementwise passes it replaces;
/// measured by the fusion sweep in `benches/executor_fastpath.rs`
/// (re-run with `cargo bench --bench executor_fastpath` after touching
/// the epilogue code and update this constant from the printed table).
pub const FUSE_BREAK_EVEN_ELEMS: usize = 64;

/// One fused elementwise step a compute host absorbed into its epilogue.
enum ChainStep {
    /// BatchNorm node (γ/β indexed by the host's output channel).
    Bn(NodeId),
    Act(Activation),
}

/// Frame-invariant prepared operands of one node.
enum Prep {
    None,
    /// int8 compute op: quantized weights + activation/weight grids.
    Int8(Int8Prep),
    /// fp16 compute op: weights pre-rounded onto the half grid.
    F16 { rw: Vec<f32> },
    /// Explicit int8 `Quantize` boundary: the calibrated roundtrip grid.
    Grid(QParams),
}

/// Arena-interaction stats of one [`FastExecutor`]: how its construction
/// hit the [`Scratch`] pool plus what it holds checked out. Surfaced by
/// [`FastExecutor::stats`], `fpga-flow profile` and the report's
/// `observability.metrics` section.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecStats {
    /// Scratch-pool counters attributable to this executor's
    /// construction (delta over the build's checkouts).
    pub scratch: ScratchStats,
    /// Arena-owned buffers currently held (per-node activations plus the
    /// shared quantization scratch).
    pub buffers: u64,
    /// Total bytes of those held buffers.
    pub buffer_bytes: u64,
}

impl ExecStats {
    /// Register these stats as gauges (prefix `flow_exec_scratch_*`) on a
    /// metrics registry.
    pub fn export_metrics(&self, reg: &crate::obs::Registry) {
        reg.set_gauge("flow_exec_scratch_checkouts", "executor scratch checkouts at build", self.scratch.checkouts as f64);
        reg.set_gauge("flow_exec_scratch_hits", "executor scratch pool hits at build", self.scratch.hits as f64);
        reg.set_gauge("flow_exec_scratch_misses", "executor scratch pool misses at build", self.scratch.misses as f64);
        reg.set_gauge("flow_exec_scratch_bytes_allocated", "bytes freshly allocated for the executor's buffers", self.scratch.bytes_allocated as f64);
        reg.set_gauge("flow_exec_buffers", "arena buffers held by the executor", self.buffers as f64);
        reg.set_gauge("flow_exec_buffer_bytes", "bytes of arena buffers held by the executor", self.buffer_bytes as f64);
    }

    /// The `executor` object of `report_json.observability`.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut m = std::collections::BTreeMap::new();
        m.insert("scratch_checkouts".into(), Json::Num(self.scratch.checkouts as f64));
        m.insert("scratch_hits".into(), Json::Num(self.scratch.hits as f64));
        m.insert("scratch_misses".into(), Json::Num(self.scratch.misses as f64));
        m.insert("scratch_hit_rate".into(), Json::Num(self.scratch.hit_rate()));
        m.insert("scratch_bytes_allocated".into(), Json::Num(self.scratch.bytes_allocated as f64));
        m.insert("buffers".into(), Json::Num(self.buffers as f64));
        m.insert("buffer_bytes".into(), Json::Num(self.buffer_bytes as f64));
        Json::Obj(m)
    }
}

/// Zero-allocation forward executor over [`Scratch`]-owned buffers.
///
/// Wraps an [`Executor`] (same graph, same synthetic parameters) and
/// replays its exact numeric semantics through the non-allocating
/// `*_into` cores with frame-invariant operand caches. After the
/// constructor's warm-up checkouts, [`FastExecutor::forward`] performs
/// zero heap allocations per frame (`rust/tests/alloc_regression.rs`)
/// and is bit-identical to the baseline
/// (`rust/tests/fastpath_equivalence.rs`).
///
/// Single-consumer conv→bn→relu chains are fused into the host's
/// epilogue closure (one traversal instead of three) when the host
/// output has at least [`FUSE_BREAK_EVEN_ELEMS`] elements and no
/// observer needs the intermediate activations — fused elementwise ops
/// apply in the same per-element order, so fusion is bit-exact.
pub struct FastExecutor<'g> {
    exec: &'g Executor<'g>,
    prep: Vec<Prep>,
    /// Fused chain per host node (empty = nothing absorbed).
    chains: Vec<Vec<ChainStep>>,
    /// Node whose buffer receives the host's (possibly fused) result.
    target: Vec<NodeId>,
    /// Nodes evaluated inside some host's chain — skipped when fusing.
    fused_member: Vec<bool>,
    /// Per-node activation buffers, arena-owned.
    acts: Vec<Vec<f32>>,
    /// Shared input-quantization scratch (int8 datapath).
    qx: Vec<i32>,
    /// Shared fp16 input-rounding scratch.
    rx: Vec<f32>,
    /// Scratch-pool delta of this executor's construction.
    build_stats: ScratchStats,
}

impl<'g> FastExecutor<'g> {
    /// f32 reference datapath (mirrors [`Executor::forward`]).
    pub fn reference(exec: &'g Executor<'g>, fuse: bool, scratch: &mut Scratch) -> FastExecutor<'g> {
        FastExecutor::build(exec, None, None, QScheme::PerChannel, fuse, scratch)
    }

    /// Reduced-precision datapath (mirrors [`Executor::forward_quantized`]
    /// at `precision` under `scheme`). The table is only read here — the
    /// preps copy everything they need.
    pub fn quantized(
        exec: &'g Executor<'g>,
        table: &CalibrationTable,
        precision: Precision,
        scheme: QScheme,
        fuse: bool,
        scratch: &mut Scratch,
    ) -> FastExecutor<'g> {
        FastExecutor::build(exec, Some(precision), Some(table), scheme, fuse, scratch)
    }

    fn build(
        exec: &'g Executor<'g>,
        quant: Option<Precision>,
        table: Option<&CalibrationTable>,
        scheme: QScheme,
        fuse: bool,
        scratch: &mut Scratch,
    ) -> FastExecutor<'g> {
        let g = exec.graph;
        // The baseline routes every non-F16 quantized precision onto the
        // int8 operand path (QuantCtx::datapath); mirror that exactly.
        let prep: Vec<Prep> = g
            .nodes
            .iter()
            .map(|n| match (&n.op, quant) {
                (
                    Op::Conv2d { .. } | Op::DepthwiseConv2d { .. } | Op::Dense { .. },
                    Some(Precision::F16),
                ) => Prep::F16 {
                    rw: exec.params[n.id].weights.iter().map(|&w| f16_round(w)).collect(),
                },
                (Op::Conv2d { .. } | Op::DepthwiseConv2d { .. } | Op::Dense { .. }, Some(_)) => {
                    let t = table.expect("quantized mode carries a calibration table");
                    Prep::Int8(int8_prep(
                        &exec.params[n.id].weights,
                        t.activation(n.inputs[0]),
                        &t.weight_ranges(n.id),
                        scheme,
                    ))
                }
                (Op::Quantize { precision: Precision::Int8 }, Some(_)) => {
                    let t = table.expect("quantized mode carries a calibration table");
                    Prep::Grid(QParams::per_tensor(t.activation(n.inputs[0]), Precision::Int8))
                }
                _ => Prep::None,
            })
            .collect();

        let mut chains: Vec<Vec<ChainStep>> = vec![Vec::new(); g.nodes.len()];
        let mut target: Vec<NodeId> = (0..g.nodes.len()).collect();
        let mut fused_member = vec![false; g.nodes.len()];
        if fuse {
            let consumers = g.consumers();
            for n in g.topo() {
                if !matches!(
                    n.op,
                    Op::Conv2d { .. } | Op::DepthwiseConv2d { .. } | Op::Dense { .. }
                ) || n.shape.elems() < FUSE_BREAK_EVEN_ELEMS
                {
                    continue;
                }
                let mut steps = Vec::new();
                let mut cur = n.id;
                // Walk single-consumer elementwise successors; BN/Activate
                // preserve shape, so the chain tail has the host's layout.
                while consumers[cur].len() == 1 {
                    let next = consumers[cur][0];
                    match &g.nodes[next].op {
                        Op::BatchNorm => steps.push(ChainStep::Bn(next)),
                        Op::Activate(a) => steps.push(ChainStep::Act(*a)),
                        _ => break,
                    }
                    fused_member[next] = true;
                    cur = next;
                    if next == g.output {
                        break;
                    }
                }
                if !steps.is_empty() {
                    chains[n.id] = steps;
                    target[n.id] = cur;
                }
            }
        }

        let max_elems = g.nodes.iter().map(|n| n.shape.elems()).max().unwrap_or(0);
        let before = scratch.stats();
        let acts: Vec<Vec<f32>> =
            g.nodes.iter().map(|n| scratch.take_f32(n.shape.elems())).collect();
        let qx = match quant {
            Some(p) if p != Precision::F16 => scratch.take_i32(max_elems),
            _ => Vec::new(),
        };
        let rx = match quant {
            Some(Precision::F16) => scratch.take_f32(max_elems),
            _ => Vec::new(),
        };
        let after = scratch.stats();
        let build_stats = ScratchStats {
            checkouts: after.checkouts - before.checkouts,
            hits: after.hits - before.hits,
            misses: after.misses - before.misses,
            returns: after.returns - before.returns,
            bytes_allocated: after.bytes_allocated - before.bytes_allocated,
        };
        FastExecutor { exec, prep, chains, target, fused_member, acts, qx, rx, build_stats }
    }

    /// Arena-interaction stats: the scratch hit/miss/bytes delta of this
    /// executor's construction plus what it currently holds checked out.
    pub fn stats(&self) -> ExecStats {
        let buffers = self.acts.len() as u64
            + u64::from(!self.qx.is_empty())
            + u64::from(!self.rx.is_empty());
        let buffer_bytes = self
            .acts
            .iter()
            .map(|b| (b.len() * std::mem::size_of::<f32>()) as u64)
            .sum::<u64>()
            + (self.qx.len() * std::mem::size_of::<i32>()) as u64
            + (self.rx.len() * std::mem::size_of::<f32>()) as u64;
        ExecStats { scratch: self.build_stats, buffers, buffer_bytes }
    }

    /// Return every arena-owned buffer to `scratch` so the next executor
    /// (or frame state) with the same shapes reuses them.
    pub fn release(self, scratch: &mut Scratch) {
        for b in self.acts {
            scratch.put_f32(b);
        }
        if !self.qx.is_empty() {
            scratch.put_i32(self.qx);
        }
        if !self.rx.is_empty() {
            scratch.put_f32(self.rx);
        }
    }

    /// Run one frame (fused, allocation-free) and return the logits.
    pub fn forward(&mut self, frame: &[f32]) -> &[f32] {
        self.run(frame, None);
        &self.acts[self.exec.graph.output]
    }

    /// [`FastExecutor::forward`] with a per-layer span tree under an
    /// `exec` `frame` span when the tracer is enabled; identical to plain
    /// [`FastExecutor::forward`] when disabled (one atomic load, zero
    /// allocations — `rust/tests/alloc_regression.rs` pins this). Tracing
    /// runs the observer path, so epilogue fusion is off for the frame
    /// (every layer must be individually timed anyway).
    pub fn forward_traced(&mut self, frame: &[f32]) -> &[f32] {
        if !crate::obs::enabled() {
            return self.forward(frame);
        }
        let mut frame_span = crate::obs::span("exec", "frame");
        frame_span.set_arg("network", self.exec.graph.name.as_str());
        let parent = frame_span.id();
        let g = self.exec.graph;
        let mut prev = Instant::now();
        self.run(
            frame,
            Some(&mut |nid: NodeId, act: &[f32]| {
                let now = Instant::now();
                crate::obs::span_at(
                    "exec",
                    &g.nodes[nid].name,
                    parent,
                    prev,
                    now,
                    vec![("elems", crate::obs::ArgValue::Num(act.len() as f64))],
                );
                prev = now;
            }),
        );
        &self.acts[self.exec.graph.output]
    }

    /// Run one frame with an observer that sees every node's activation
    /// in topological order (the calibration / localization hook).
    /// Fusion is disabled for the pass — the observer needs the chain's
    /// intermediate activations — but execution stays allocation-free.
    pub fn forward_observed(
        &mut self,
        frame: &[f32],
        mut observe: impl FnMut(NodeId, &[f32]),
    ) -> &[f32] {
        self.run(frame, Some(&mut observe));
        &self.acts[self.exec.graph.output]
    }

    fn run(&mut self, frame: &[f32], mut observe: Option<&mut dyn FnMut(NodeId, &[f32])>) {
        let fusing = observe.is_none();
        let FastExecutor { exec, prep, chains, target, fused_member, acts, qx, rx } = self;
        let g = exec.graph;
        let params = &exec.params;
        for n in g.topo() {
            let nid = n.id;
            if fusing && fused_member[nid] {
                continue;
            }
            let tgt = if fusing { target[nid] } else { nid };
            let chain: &[ChainStep] = if fusing { &chains[nid] } else { &[] };
            // Detach the output buffer so the inputs stay readable.
            let mut out = std::mem::take(&mut acts[tgt]);
            match &n.op {
                Op::Input => {
                    assert_eq!(frame.len(), out.len(), "input frame size mismatch");
                    out.copy_from_slice(frame);
                }
                Op::Conv2d { kernel, stride, padding, bias, activation, .. }
                | Op::DepthwiseConv2d { kernel, stride, padding, bias, activation } => {
                    let depthwise = matches!(n.op, Op::DepthwiseConv2d { .. });
                    let x = &acts[n.inputs[0]];
                    let geom = ConvGeom::from_shapes(
                        &g.nodes[n.inputs[0]].shape,
                        &n.shape,
                        *kernel,
                        *stride,
                        *padding,
                        depthwise,
                    );
                    let p = &params[nid];
                    let f16 = matches!(prep[nid], Prep::F16 { .. });
                    let ep = |mut v: f32, o: usize| {
                        if *bias {
                            v += p.bias[o];
                        }
                        if f16 {
                            v = f16_round(v);
                        }
                        v = activate(v, *activation);
                        for s in chain {
                            v = match s {
                                ChainStep::Bn(b) => v * params[*b].weights[o] + params[*b].bias[o],
                                ChainStep::Act(a) => activate(v, *a),
                            };
                        }
                        v
                    };
                    match &prep[nid] {
                        Prep::Int8(ip) => {
                            let qxs = &mut qx[..x.len()];
                            quantize_into(x, &ip.xq, qxs);
                            let dp =
                                MatOperands::Int8 { qx: qxs, qw: &ip.qw, sx: ip.sx, wq: &ip.wq };
                            conv_core_into(&dp, geom, ep, &mut out);
                        }
                        Prep::F16 { rw } => {
                            let rxs = &mut rx[..x.len()];
                            f16_round_into(x, rxs);
                            conv_core_into(&MatOperands::F16 { rx: rxs, rw }, geom, ep, &mut out);
                        }
                        _ => {
                            let dp = MatOperands::F32 { x, w: &p.weights };
                            conv_core_into(&dp, geom, ep, &mut out);
                        }
                    }
                }
                Op::Dense { bias, activation, .. } => {
                    let x = &acts[n.inputs[0]];
                    let p = &params[nid];
                    let cin = x.len();
                    let oc = p.bias.len().max(p.weights.len() / cin.max(1));
                    debug_assert_eq!(out.len(), oc, "dense output shape mismatch");
                    let ep = |mut v: f32, o: usize| {
                        if *bias {
                            v += p.bias[o];
                        }
                        v = activate(v, *activation);
                        for s in chain {
                            v = match s {
                                ChainStep::Bn(b) => v * params[*b].weights[o] + params[*b].bias[o],
                                ChainStep::Act(a) => activate(v, *a),
                            };
                        }
                        v
                    };
                    match &prep[nid] {
                        Prep::Int8(ip) => {
                            let qxs = &mut qx[..cin];
                            quantize_into(x, &ip.xq, qxs);
                            let dp =
                                MatOperands::Int8 { qx: qxs, qw: &ip.qw, sx: ip.sx, wq: &ip.wq };
                            dense_core_into(&dp, cin, oc, ep, &mut out);
                        }
                        Prep::F16 { rw } => {
                            let rxs = &mut rx[..cin];
                            f16_round_into(x, rxs);
                            dense_core_into(&MatOperands::F16 { rx: rxs, rw }, cin, oc, ep, &mut out);
                        }
                        _ => {
                            let dp = MatOperands::F32 { x, w: &p.weights };
                            dense_core_into(&dp, cin, oc, ep, &mut out);
                        }
                    }
                }
                Op::BatchNorm => {
                    let p = &params[nid];
                    batchnorm_into(
                        &acts[n.inputs[0]],
                        &p.weights,
                        &p.bias,
                        channels_of(&n.shape),
                        &mut out,
                    );
                }
                Op::Activate(a) => {
                    for (o, &v) in out.iter_mut().zip(&acts[n.inputs[0]]) {
                        *o = activate(v, *a);
                    }
                }
                Op::MaxPool { kernel, stride, padding } => pool_into(
                    &acts[n.inputs[0]],
                    &g.nodes[n.inputs[0]].shape,
                    &n.shape,
                    *kernel,
                    *stride,
                    *padding,
                    true,
                    &mut out,
                ),
                Op::AvgPool { kernel, stride, padding } => pool_into(
                    &acts[n.inputs[0]],
                    &g.nodes[n.inputs[0]].shape,
                    &n.shape,
                    *kernel,
                    *stride,
                    *padding,
                    false,
                    &mut out,
                ),
                Op::GlobalAvgPool => {
                    let (c, h, w) = g.nodes[n.inputs[0]].shape.chw().expect("gap input CHW");
                    let x = &acts[n.inputs[0]];
                    for (ch, o) in out.iter_mut().enumerate().take(c) {
                        *o = x[ch * h * w..(ch + 1) * h * w].iter().sum::<f32>() / (h * w) as f32;
                    }
                }
                Op::Add => {
                    let (a, b) = (&acts[n.inputs[0]], &acts[n.inputs[1]]);
                    for ((o, &va), &vb) in out.iter_mut().zip(a).zip(b) {
                        *o = va + vb;
                    }
                }
                Op::Softmax => {
                    let x = &acts[n.inputs[0]];
                    let m = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    for (o, &v) in out.iter_mut().zip(x) {
                        *o = (v - m).exp();
                    }
                    let s: f32 = out.iter().sum();
                    for o in out.iter_mut() {
                        *o /= s;
                    }
                }
                Op::Transform | Op::Flatten | Op::Dequantize { .. } => {
                    out.copy_from_slice(&acts[n.inputs[0]]);
                }
                Op::Quantize { precision } => {
                    let x = &acts[n.inputs[0]];
                    match (&prep[nid], precision) {
                        (Prep::Grid(qp), _) => {
                            for (o, &v) in out.iter_mut().zip(x) {
                                *o = qp.roundtrip(v as f64, 0) as f32;
                            }
                        }
                        (_, Precision::F16) => f16_round_into(x, &mut out),
                        _ => out.copy_from_slice(x),
                    }
                }
            }
            acts[tgt] = out;
            if let Some(obs) = observe.as_deref_mut() {
                obs(nid, &acts[nid]);
            }
        }
    }
}

/// Index of the largest logit (the predicted class).
pub fn argmax(logits: &[f32]) -> u32 {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i as u32)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::quant::calibrate::{calibrate, Calibrator};

    #[test]
    fn forward_is_deterministic_and_shaped() {
        let g = models::lenet5();
        let exec = Executor::new(&g);
        let data = crate::data::mnist_like(2, 32, 7);
        let a = exec.forward(data.frame(0), |_, _| {});
        let b = exec.forward(data.frame(0), |_, _| {});
        assert_eq!(a.len(), 10);
        assert_eq!(a, b);
        let c = exec.forward(data.frame(1), |_, _| {});
        assert_ne!(a, c);
    }

    #[test]
    fn observer_sees_every_node() {
        let g = models::lenet5();
        let exec = Executor::new(&g);
        let data = crate::data::mnist_like(1, 32, 7);
        let mut seen = Vec::new();
        exec.forward(data.frame(0), |id, act| seen.push((id, act.len())));
        assert_eq!(seen.len(), g.nodes.len());
        for (id, len) in &seen {
            assert_eq!(*len, g.nodes[*id].shape.elems());
        }
    }

    #[test]
    fn int8_forward_tracks_f32_closely_on_lenet() {
        let g = models::lenet5();
        let exec = Executor::new(&g);
        let data = crate::data::mnist_like(8, 32, 11);
        let table = calibrate(&g, &data, 8, Calibrator::MinMax);
        let mut agree = 0;
        for i in 0..8 {
            let f = exec.forward(data.frame(i), |_, _| {});
            let q = exec.forward_quantized(data.frame(i), &table, Precision::Int8, QScheme::PerChannel);
            assert_eq!(f.len(), q.len());
            // Logit-level error stays small relative to the logit scale.
            let scale = f.iter().map(|v| v.abs()).fold(0f32, f32::max).max(1e-3);
            for (a, b) in f.iter().zip(&q) {
                assert!((a - b).abs() / scale < 0.25, "logit drift {a} vs {b}");
            }
            if argmax(&f) == argmax(&q) {
                agree += 1;
            }
        }
        // Random-weight logits can sit arbitrarily close together, so a
        // rare flip is legitimate — but wholesale disagreement is a bug.
        assert!(agree >= 6, "int8 agreement only {agree}/8");
    }

    #[test]
    fn fp16_forward_is_nearly_exact() {
        let g = models::lenet5();
        let exec = Executor::new(&g);
        let data = crate::data::mnist_like(4, 32, 3);
        let table = calibrate(&g, &data, 4, Calibrator::MinMax);
        for i in 0..4 {
            let f = exec.forward(data.frame(i), |_, _| {});
            let q = exec.forward_quantized(data.frame(i), &table, Precision::F16, QScheme::PerTensor);
            assert_eq!(argmax(&f), argmax(&q));
        }
    }

    #[test]
    fn per_channel_weight_ranges_cover_weights() {
        let g = models::lenet5();
        let exec = Executor::new(&g);
        let conv = g.nodes.iter().find(|n| n.op.is_compute()).unwrap();
        let ranges = exec.weight_channel_ranges(conv.id);
        assert!(!ranges.is_empty());
        assert!(ranges.iter().all(|r| !r.is_empty() && r.max_abs() > 0.0));
    }
}
