//! Reference graph executor — the numeric ground truth quantization is
//! measured against.
//!
//! The flow has no trained weights (DESIGN.md §Substitutions), so the
//! executor materializes *deterministic synthetic* weights per node
//! (He-scaled normals seeded by network name + node id). That is exactly
//! what the rest of the repo does for data: throughput is value-independent
//! and accuracy *deltas* (f32 vs quantized on the same weights) exercise
//! the identical error mechanisms as trained weights — saturation, grid
//! rounding, per-channel scale mismatch.
//!
//! Two evaluation modes share one traversal:
//!
//! * [`Executor::forward`] — f32 reference, observing every activation
//!   (the calibration hook);
//! * [`Executor::forward_quantized`] — compute ops run on the symmetric
//!   integer grid (int8: quantized operands, i64 accumulation, rescale) or
//!   through fp16 rounding, everything else in f32 — the §VII
//!   reduced-precision datapath, value-accurate.

use crate::graph::{Activation, Graph, NodeId, Op, Shape};
use crate::texpr::Precision;
use crate::util::rng::Rng;

use super::calibrate::CalibrationTable;
use super::scheme::{f16_round, QParams, QScheme, Range};

/// Per-node synthetic parameters.
#[derive(Debug, Clone, Default)]
struct NodeParams {
    /// Conv: OIHW; dense: [out × in]; BN: gamma per channel.
    weights: Vec<f32>,
    /// Bias (or BN beta) per output channel.
    bias: Vec<f32>,
}

/// Deterministic reference interpreter for one graph.
pub struct Executor<'g> {
    pub graph: &'g Graph,
    params: Vec<NodeParams>,
}

impl<'g> Executor<'g> {
    /// Build the executor, materializing synthetic weights for every
    /// parameterized node.
    pub fn new(graph: &'g Graph) -> Executor<'g> {
        let seed = crate::util::fnv64(graph.name.as_bytes());
        let params = graph
            .nodes
            .iter()
            .map(|n| {
                let mut rng = Rng::new(seed ^ (n.id as u64).wrapping_mul(0x9E3779B97F4A7C15));
                match &n.op {
                    Op::Conv2d { out_channels, kernel, bias, .. } => {
                        let cin = graph.nodes[n.inputs[0]].shape.chw().map(|c| c.0).unwrap_or(1);
                        let fan_in = cin * kernel * kernel;
                        he_params(&mut rng, *out_channels * fan_in, fan_in, *out_channels, *bias)
                    }
                    Op::DepthwiseConv2d { kernel, bias, .. } => {
                        let c = n.shape.chw().map(|c| c.0).unwrap_or(1);
                        let fan_in = kernel * kernel;
                        he_params(&mut rng, c * fan_in, fan_in, c, *bias)
                    }
                    Op::Dense { out_features, bias, .. } => {
                        let cin = graph.nodes[n.inputs[0]].shape.elems();
                        he_params(&mut rng, out_features * cin, cin, *out_features, *bias)
                    }
                    Op::BatchNorm => {
                        let c = channels_of(&n.shape);
                        NodeParams {
                            weights: (0..c).map(|_| 1.0 + 0.05 * rng.normal()).collect(),
                            bias: (0..c).map(|_| 0.02 * rng.normal()).collect(),
                        }
                    }
                    _ => NodeParams::default(),
                }
            })
            .collect();
        Executor { graph, params }
    }

    /// Synthetic weights of one node (oracle hook for the `crate::verify`
    /// kernel interpreter, which must run on the *same* parameters as the
    /// reference it is diffed against). Conv: OIHW; dense: [out × in];
    /// BN: per-channel γ. Empty for weightless nodes.
    pub fn weights(&self, node: NodeId) -> &[f32] {
        &self.params[node].weights
    }

    /// Synthetic per-output-channel bias (or BN β) of one node — the
    /// companion oracle hook to [`Executor::weights`].
    pub fn bias(&self, node: NodeId) -> &[f32] {
        &self.params[node].bias
    }

    /// Per-output-channel weight ranges of one node (empty for weightless
    /// nodes) — what per-channel calibration quantizes against.
    pub fn weight_channel_ranges(&self, node: NodeId) -> Vec<Range> {
        let n = &self.graph.nodes[node];
        let p = &self.params[node];
        let oc = match &n.op {
            Op::Conv2d { out_channels, .. } => *out_channels,
            Op::DepthwiseConv2d { .. } => n.shape.chw().map(|c| c.0).unwrap_or(1),
            Op::Dense { out_features, .. } => *out_features,
            _ => return Vec::new(),
        };
        let per = p.weights.len() / oc.max(1);
        (0..oc)
            .map(|c| {
                let mut r = Range::EMPTY;
                for &w in &p.weights[c * per..(c + 1) * per] {
                    r.observe(w as f64);
                }
                r
            })
            .collect()
    }

    /// f32 reference forward pass; `observe` sees every node's activation
    /// (in topological order) — the calibration hook. Returns the output
    /// node's activation (logits).
    pub fn forward(&self, frame: &[f32], mut observe: impl FnMut(NodeId, &[f32])) -> Vec<f32> {
        self.run(frame, None, &mut observe)
    }

    /// Quantized forward pass: compute ops execute on the reduced-precision
    /// datapath described by (`table`, `precision`, `scheme`).
    pub fn forward_quantized(
        &self,
        frame: &[f32],
        table: &CalibrationTable,
        precision: Precision,
        scheme: QScheme,
    ) -> Vec<f32> {
        self.forward_quantized_observed(frame, table, precision, scheme, |_, _| {})
    }

    /// [`Executor::forward_quantized`] with an observer that sees every
    /// node's activation in topological order — the mismatch-localization
    /// hook of the `crate::verify` differential harness (find the first
    /// node where the kernel interpreter and this oracle diverge).
    pub fn forward_quantized_observed(
        &self,
        frame: &[f32],
        table: &CalibrationTable,
        precision: Precision,
        scheme: QScheme,
        mut observe: impl FnMut(NodeId, &[f32]),
    ) -> Vec<f32> {
        let q = QuantCtx { table, precision, scheme };
        self.run(frame, Some(&q), &mut observe)
    }

    fn run(
        &self,
        frame: &[f32],
        q: Option<&QuantCtx>,
        observe: &mut dyn FnMut(NodeId, &[f32]),
    ) -> Vec<f32> {
        let g = self.graph;
        let mut acts: Vec<Vec<f32>> = vec![Vec::new(); g.nodes.len()];
        for n in g.topo() {
            let out = match &n.op {
                Op::Input => {
                    assert_eq!(frame.len(), n.shape.elems(), "input frame size mismatch");
                    frame.to_vec()
                }
                Op::Conv2d { kernel, stride, padding, bias, activation, .. } => self.conv(
                    n.id,
                    &acts[n.inputs[0]],
                    &g.nodes[n.inputs[0]].shape,
                    &n.shape,
                    *kernel,
                    *stride,
                    *padding,
                    false,
                    *bias,
                    *activation,
                    q,
                ),
                Op::DepthwiseConv2d { kernel, stride, padding, bias, activation } => self.conv(
                    n.id,
                    &acts[n.inputs[0]],
                    &g.nodes[n.inputs[0]].shape,
                    &n.shape,
                    *kernel,
                    *stride,
                    *padding,
                    true,
                    *bias,
                    *activation,
                    q,
                ),
                Op::Dense { bias, activation, .. } => {
                    self.dense(n.id, &acts[n.inputs[0]], *bias, *activation, q)
                }
                Op::BatchNorm => {
                    let p = &self.params[n.id];
                    let x = &acts[n.inputs[0]];
                    let c = channels_of(&n.shape);
                    let per = x.len() / c.max(1);
                    x.iter()
                        .enumerate()
                        .map(|(i, &v)| v * p.weights[i / per.max(1)] + p.bias[i / per.max(1)])
                        .collect()
                }
                Op::Activate(a) => acts[n.inputs[0]].iter().map(|&v| activate(v, *a)).collect(),
                Op::MaxPool { kernel, stride, padding } => pool(
                    &acts[n.inputs[0]],
                    &g.nodes[n.inputs[0]].shape,
                    &n.shape,
                    *kernel,
                    *stride,
                    *padding,
                    true,
                ),
                Op::AvgPool { kernel, stride, padding } => pool(
                    &acts[n.inputs[0]],
                    &g.nodes[n.inputs[0]].shape,
                    &n.shape,
                    *kernel,
                    *stride,
                    *padding,
                    false,
                ),
                Op::GlobalAvgPool => {
                    let (c, h, w) = g.nodes[n.inputs[0]].shape.chw().expect("gap input CHW");
                    let x = &acts[n.inputs[0]];
                    (0..c)
                        .map(|ch| {
                            x[ch * h * w..(ch + 1) * h * w].iter().sum::<f32>() / (h * w) as f32
                        })
                        .collect()
                }
                Op::Add => {
                    let (a, b) = (&acts[n.inputs[0]], &acts[n.inputs[1]]);
                    a.iter().zip(b.iter()).map(|(x, y)| x + y).collect()
                }
                Op::Softmax => {
                    let x = &acts[n.inputs[0]];
                    let m = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let e: Vec<f32> = x.iter().map(|v| (v - m).exp()).collect();
                    let s: f32 = e.iter().sum();
                    e.into_iter().map(|v| v / s).collect()
                }
                Op::Transform | Op::Flatten => acts[n.inputs[0]].clone(),
                Op::Quantize { precision } => {
                    // A rewritten graph carries explicit grid boundaries:
                    // round-trip through the calibrated grid of the source.
                    let src = n.inputs[0];
                    match q {
                        Some(ctx) if *precision == Precision::Int8 => {
                            let qp = ctx.act_params(src);
                            acts[src].iter().map(|&v| qp.roundtrip(v as f64, 0) as f32).collect()
                        }
                        _ if *precision == Precision::F16 => {
                            acts[src].iter().map(|&v| f16_round(v)).collect()
                        }
                        _ => acts[src].clone(),
                    }
                }
                Op::Dequantize { .. } => acts[n.inputs[0]].clone(),
            };
            observe(n.id, &out);
            acts[n.id] = out;
        }
        std::mem::take(&mut acts[g.output])
    }

    #[allow(clippy::too_many_arguments)]
    fn conv(
        &self,
        node: NodeId,
        x: &[f32],
        in_shape: &Shape,
        out_shape: &Shape,
        k: usize,
        stride: usize,
        padding: usize,
        depthwise: bool,
        bias: bool,
        act: Activation,
        q: Option<&QuantCtx>,
    ) -> Vec<f32> {
        let (cin, h, w) = in_shape.chw().expect("conv input CHW");
        let (oc, oh, ow) = out_shape.chw().expect("conv output CHW");
        let p = &self.params[node];
        let dp = q.map(|ctx| ctx.datapath(self, node, x));
        let mut out = vec![0f32; oc * oh * ow];
        for o in 0..oc {
            let w_base = if depthwise { o * k * k } else { o * cin * k * k };
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc_f = 0f64;
                    let mut acc_i = 0i64;
                    let crange = if depthwise { o..o + 1 } else { 0..cin };
                    for c in crange {
                        for ky in 0..k {
                            for kx in 0..k {
                                let iy = (oy * stride + ky) as isize - padding as isize;
                                let ix = (ox * stride + kx) as isize - padding as isize;
                                if iy < 0 || ix < 0 || iy >= h as isize || ix >= w as isize {
                                    continue;
                                }
                                let xi = c * h * w + iy as usize * w + ix as usize;
                                let wi = if depthwise {
                                    w_base + ky * k + kx
                                } else {
                                    w_base + (c * k + ky) * k + kx
                                };
                                match &dp {
                                    Some(Datapath::Int8 { qx, qw, .. }) => {
                                        acc_i += qx[xi] as i64 * qw[wi] as i64;
                                    }
                                    Some(Datapath::F16 { rx }) => {
                                        acc_f +=
                                            (rx[xi] * f16_round(p.weights[wi])) as f64;
                                    }
                                    None => acc_f += (x[xi] * p.weights[wi]) as f64,
                                }
                            }
                        }
                    }
                    let mut v = match &dp {
                        Some(Datapath::Int8 { sx, wq, .. }) => {
                            acc_i as f64 * sx * wq.scale(o)
                        }
                        _ => acc_f,
                    } as f32;
                    if bias {
                        v += p.bias[o];
                    }
                    if matches!(dp, Some(Datapath::F16 { .. })) {
                        v = f16_round(v);
                    }
                    out[(o * oh + oy) * ow + ox] = activate(v, act);
                }
            }
        }
        out
    }

    fn dense(
        &self,
        node: NodeId,
        x: &[f32],
        bias: bool,
        act: Activation,
        q: Option<&QuantCtx>,
    ) -> Vec<f32> {
        let p = &self.params[node];
        let cin = x.len();
        let oc = p.bias.len().max(p.weights.len() / cin.max(1));
        let dp = q.map(|ctx| ctx.datapath(self, node, x));
        (0..oc)
            .map(|o| {
                let row = &p.weights[o * cin..(o + 1) * cin];
                let mut v = match &dp {
                    Some(Datapath::Int8 { qx, qw, sx, wq }) => {
                        let qrow = &qw[o * cin..(o + 1) * cin];
                        let acc: i64 =
                            qx.iter().zip(qrow).map(|(&a, &b)| a as i64 * b as i64).sum();
                        (acc as f64 * sx * wq.scale(o)) as f32
                    }
                    Some(Datapath::F16 { rx }) => f16_round(
                        rx.iter().zip(row).map(|(&a, &b)| a * f16_round(b)).sum::<f32>(),
                    ),
                    None => x.iter().zip(row).map(|(&a, &b)| a * b).sum::<f32>(),
                };
                if bias {
                    v += p.bias[o];
                }
                activate(v, act)
            })
            .collect()
    }
}

/// Quantized-datapath context for one forward pass.
struct QuantCtx<'a> {
    table: &'a CalibrationTable,
    precision: Precision,
    scheme: QScheme,
}

/// Prepared operands of one compute op on the reduced-precision datapath.
enum Datapath {
    Int8 { qx: Vec<i32>, qw: Vec<i32>, sx: f64, wq: QParams },
    F16 { rx: Vec<f32> },
}

/// Quantized operands of one compute op — the grid-side of [`Datapath`],
/// shared with the `verify` interpreter so both sides of the differential
/// prepare operands identically (scheme selection, range merge and
/// per-channel weight-group indexing are pass-invariant semantics).
pub(crate) struct QuantizedOperands {
    pub qx: Vec<i32>,
    pub qw: Vec<i32>,
    /// Activation (per-tensor) scale.
    pub sx: f64,
    /// Weight grid (per-tensor or per-channel).
    pub wq: QParams,
}

/// Quantize `x` against the calibrated activation range and `weights`
/// against the per-channel ranges under `scheme` (per-tensor = the merged
/// range) — the canonical int8 operand preparation.
pub(crate) fn quantize_operands(
    x: &[f32],
    weights: &[f32],
    act_range: Range,
    weight_ranges: &[Range],
    scheme: QScheme,
) -> QuantizedOperands {
    let xq = QParams::per_tensor(act_range, Precision::Int8);
    let wq = match scheme {
        QScheme::PerChannel if !weight_ranges.is_empty() => {
            QParams::per_channel(weight_ranges, Precision::Int8)
        }
        _ => {
            let whole = weight_ranges.iter().fold(Range::EMPTY, |a, r| a.merge(r));
            QParams::per_tensor(whole, Precision::Int8)
        }
    };
    let oc = wq.groups().max(1);
    let per = weights.len() / oc;
    QuantizedOperands {
        qx: x.iter().map(|&v| xq.quantize(v as f64, 0)).collect(),
        qw: weights
            .iter()
            .enumerate()
            .map(|(i, &w)| wq.quantize(w as f64, i / per.max(1)))
            .collect(),
        sx: xq.scale(0),
        wq,
    }
}

impl QuantCtx<'_> {
    fn act_params(&self, node: NodeId) -> QParams {
        QParams::per_tensor(self.table.activation(node), Precision::Int8)
    }

    fn datapath(&self, exec: &Executor, node: NodeId, x: &[f32]) -> Datapath {
        match self.precision {
            Precision::F16 => Datapath::F16 { rx: x.iter().map(|&v| f16_round(v)).collect() },
            _ => {
                let src = exec.graph.nodes[node].inputs[0];
                let q = quantize_operands(
                    x,
                    &exec.params[node].weights,
                    self.table.activation(src),
                    &self.table.weight_ranges(node),
                    self.scheme,
                );
                Datapath::Int8 { qx: q.qx, qw: q.qw, sx: q.sx, wq: q.wq }
            }
        }
    }
}

fn he_params(rng: &mut Rng, n_weights: usize, fan_in: usize, oc: usize, bias: bool) -> NodeParams {
    let std = (2.0 / fan_in.max(1) as f64).sqrt() as f32;
    NodeParams {
        weights: (0..n_weights).map(|_| rng.normal() * std).collect(),
        bias: if bias { (0..oc).map(|_| 0.01 * rng.normal()).collect() } else { vec![0.0; oc] },
    }
}

/// Channel count of a shape (flat tensors are all-channel). Shared with
/// the `verify` interpreter so both sides of the differential stay in
/// lockstep on scheduling-invariant semantics.
pub(crate) fn channels_of(s: &Shape) -> usize {
    match s {
        Shape::Chw(c, ..) => *c,
        Shape::Flat(n) => *n,
    }
}

/// Activation semantics (shared with the `verify` interpreter — no
/// schedule pass has value freedom here).
pub(crate) fn activate(v: f32, a: Activation) -> f32 {
    match a {
        Activation::None => v,
        Activation::Relu => v.max(0.0),
        Activation::Relu6 => v.clamp(0.0, 6.0),
        Activation::Tanh => v.tanh(),
    }
}

/// Pooling semantics (shared with the `verify` interpreter; average
/// pools divide by the full window even at padded borders).
pub(crate) fn pool(
    x: &[f32],
    in_shape: &Shape,
    out_shape: &Shape,
    k: usize,
    stride: usize,
    padding: usize,
    is_max: bool,
) -> Vec<f32> {
    let (c, h, w) = in_shape.chw().expect("pool input CHW");
    let (_, oh, ow) = out_shape.chw().expect("pool output CHW");
    let mut out = vec![0f32; c * oh * ow];
    for ch in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut m = f32::NEG_INFINITY;
                let mut s = 0f32;
                for ky in 0..k {
                    for kx in 0..k {
                        let iy = (oy * stride + ky) as isize - padding as isize;
                        let ix = (ox * stride + kx) as isize - padding as isize;
                        if iy < 0 || ix < 0 || iy >= h as isize || ix >= w as isize {
                            continue;
                        }
                        let v = x[ch * h * w + iy as usize * w + ix as usize];
                        m = m.max(v);
                        s += v;
                    }
                }
                out[(ch * oh + oy) * ow + ox] = if is_max { m } else { s / (k * k) as f32 };
            }
        }
    }
    out
}

/// Index of the largest logit (the predicted class).
pub fn argmax(logits: &[f32]) -> u32 {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i as u32)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::quant::calibrate::{calibrate, Calibrator};

    #[test]
    fn forward_is_deterministic_and_shaped() {
        let g = models::lenet5();
        let exec = Executor::new(&g);
        let data = crate::data::mnist_like(2, 32, 7);
        let a = exec.forward(data.frame(0), |_, _| {});
        let b = exec.forward(data.frame(0), |_, _| {});
        assert_eq!(a.len(), 10);
        assert_eq!(a, b);
        let c = exec.forward(data.frame(1), |_, _| {});
        assert_ne!(a, c);
    }

    #[test]
    fn observer_sees_every_node() {
        let g = models::lenet5();
        let exec = Executor::new(&g);
        let data = crate::data::mnist_like(1, 32, 7);
        let mut seen = Vec::new();
        exec.forward(data.frame(0), |id, act| seen.push((id, act.len())));
        assert_eq!(seen.len(), g.nodes.len());
        for (id, len) in &seen {
            assert_eq!(*len, g.nodes[*id].shape.elems());
        }
    }

    #[test]
    fn int8_forward_tracks_f32_closely_on_lenet() {
        let g = models::lenet5();
        let exec = Executor::new(&g);
        let data = crate::data::mnist_like(8, 32, 11);
        let table = calibrate(&g, &data, 8, Calibrator::MinMax);
        let mut agree = 0;
        for i in 0..8 {
            let f = exec.forward(data.frame(i), |_, _| {});
            let q = exec.forward_quantized(data.frame(i), &table, Precision::Int8, QScheme::PerChannel);
            assert_eq!(f.len(), q.len());
            // Logit-level error stays small relative to the logit scale.
            let scale = f.iter().map(|v| v.abs()).fold(0f32, f32::max).max(1e-3);
            for (a, b) in f.iter().zip(&q) {
                assert!((a - b).abs() / scale < 0.25, "logit drift {a} vs {b}");
            }
            if argmax(&f) == argmax(&q) {
                agree += 1;
            }
        }
        // Random-weight logits can sit arbitrarily close together, so a
        // rare flip is legitimate — but wholesale disagreement is a bug.
        assert!(agree >= 6, "int8 agreement only {agree}/8");
    }

    #[test]
    fn fp16_forward_is_nearly_exact() {
        let g = models::lenet5();
        let exec = Executor::new(&g);
        let data = crate::data::mnist_like(4, 32, 3);
        let table = calibrate(&g, &data, 4, Calibrator::MinMax);
        for i in 0..4 {
            let f = exec.forward(data.frame(i), |_, _| {});
            let q = exec.forward_quantized(data.frame(i), &table, Precision::F16, QScheme::PerTensor);
            assert_eq!(argmax(&f), argmax(&q));
        }
    }

    #[test]
    fn per_channel_weight_ranges_cover_weights() {
        let g = models::lenet5();
        let exec = Executor::new(&g);
        let conv = g.nodes.iter().find(|n| n.op.is_compute()).unwrap();
        let ranges = exec.weight_channel_ranges(conv.id);
        assert!(!ranges.is_empty());
        assert!(ranges.iter().all(|r| !r.is_empty() && r.max_abs() > 0.0));
    }
}
