//! Schedule-level passes — one per Table I optimization (PK, LU, LT, LF,
//! CW, OF, CH, AR, CE) plus the extensions (Q reduced precision, VT vector
//! types, SP sparsity). Each pass owns its applicability pattern (the
//! "Pattern" column of Table I) and rewrites the [`KernelProgram`] in
//! place through the [`crate::schedule::Scheduler`] primitives; mode
//! restrictions and factor-domain rules surface as preconditions from
//! [`crate::flow::legality`], so a skipped pass names the rule that
//! blocked it.
//!
//! Passes start from [`lower_to_kernels`]: the *neutral* program with one
//! naive (TVM-default) kernel per non-layout graph node. Structural
//! passes then reshape it — [`FuseEpilogues`] absorbs BN/activation
//! kernels into their producers, [`ParameterizeKernels`] merges kernels of
//! one (filter, stride) group — and the remaining passes rewrite loop
//! nests, accesses, channels and host queues.
//!
//! Ordering constraints: the structural passes lead — [`FuseEpilogues`]
//! must precede [`ParameterizeKernels`] (absorption targets per-layer
//! kernels; merging first would pile every group member's epilogues onto
//! the representative) and both precede the per-kernel rewrites so
//! merged-away kernels are never scheduled; [`QuantizeDatapath`] must run
//! before [`SparsifyWeights`] and before the BRAM stashes of
//! [`CachedWrites`] are sized, because byte-traffic rescaling is
//! integer-truncating and stash sizes read the nest's element width. The
//! pipeline built by [`crate::flow::OptConfig::schedule_pipeline`] encodes
//! the canonical order.

use std::collections::{BTreeMap, BTreeSet};

use crate::codegen::{Channel, Kernel, KernelProgram};
use crate::flow::patterns::FactorPlan;
use crate::flow::{legality, Mode};
use crate::graph::{Graph, GroupKind, Node, Op, ParamGroup};
use crate::quant::rewrite;
use crate::schedule::{AppliedOpts, OptKind, Scheduler};
use crate::texpr::{self, Dir, Epilogue, LoopVar, MemSpace, Pattern, Precision};

use super::{Equivalence, PassDiff, ScheduleCtx, SchedulePass};

// ---------------------------------------------------------------------------
// Neutral lowering + program-surgery helpers
// ---------------------------------------------------------------------------

/// Lower every non-layout graph node to its own naive (TVM-default) kernel
/// — the neutral program that schedule passes rewrite. Layout-only nodes
/// (Input / Flatten / Transform) never become kernels.
pub fn lower_to_kernels(graph: &Graph, mode: Mode) -> KernelProgram {
    let mut kernels: Vec<Kernel> = Vec::new();
    for node in graph.topo() {
        if matches!(node.op, Op::Input | Op::Flatten | Op::Transform) {
            continue;
        }
        let input_shape = &graph.nodes[node.inputs[0]].shape;
        let nest = texpr::lower(node, input_shape);
        let id = kernels.len();
        let name = format!("k{}_{}", id, nest.name);
        kernels.push(Kernel {
            id,
            name,
            nest,
            applied: AppliedOpts::default(),
            autorun: false,
            layers: vec![node.id],
            absorbed: vec![],
            group: None,
            queue: 0,
        });
    }
    KernelProgram {
        name: format!("{}_{}", graph.name, mode.name()),
        kernels,
        channels: Vec::new(),
        queues: 1,
    }
}

/// node id → kernel index, for every node owned by some kernel.
pub fn node_kernel_map(prog: &KernelProgram) -> BTreeMap<usize, usize> {
    let mut map = BTreeMap::new();
    for (i, k) in prog.kernels.iter().enumerate() {
        for &nid in &k.layers {
            map.insert(nid, i);
        }
    }
    map
}

/// The kernel that produces node `id`'s value: climb through nodes that
/// own no kernel (layout skips and fused epilogues) via their first input.
/// `None` when the chain ends at the graph input.
fn producing_kernel(graph: &Graph, map: &BTreeMap<usize, usize>, mut id: usize) -> Option<usize> {
    loop {
        if let Some(&k) = map.get(&id) {
            return Some(k);
        }
        match graph.nodes[id].inputs.first() {
            Some(&prev) => id = prev,
            None => return None,
        }
    }
}

/// Remove the kernels at the given indices, renumbering ids and names so
/// the program stays dense. Only legal before channels are wired (the
/// structural passes LF and PK run ahead of CH).
fn remove_kernels(prog: &mut KernelProgram, remove: &BTreeSet<usize>) {
    if remove.is_empty() {
        return;
    }
    debug_assert!(prog.channels.is_empty(), "kernel removal would dangle channel endpoints");
    let kernels = std::mem::take(&mut prog.kernels);
    let mut kept: Vec<Kernel> = kernels
        .into_iter()
        .enumerate()
        .filter(|(i, _)| !remove.contains(i))
        .map(|(_, k)| k)
        .collect();
    for (new_id, k) in kept.iter_mut().enumerate() {
        k.id = new_id;
        k.name = format!("k{}_{}", new_id, k.nest.name);
    }
    prog.kernels = kept;
}

/// Run scheduling primitives on one kernel and merge what they recorded
/// into the kernel's cumulative applied-optimization set.
fn with_scheduler(k: &mut Kernel, f: impl FnOnce(&mut Scheduler)) {
    let mut s = Scheduler::new(&mut k.nest);
    f(&mut s);
    let applied = s.finish();
    k.applied.merge(applied);
}

/// Is `node` an epilogue op (BN / activation) fusible into its producer?
/// (Table I pattern: "activation/batchnorm in conv, FC, pooling".)
fn fusible_epilogue(graph: &Graph, node: &Node, consumers: &[Vec<usize>]) -> bool {
    if !matches!(node.op, Op::BatchNorm | Op::Activate(_)) {
        return false;
    }
    let producer = &graph.nodes[node.inputs[0]];
    (producer.op.is_compute()
        || matches!(
            producer.op,
            Op::BatchNorm | Op::Activate(_) | Op::Add | Op::MaxPool { .. } | Op::AvgPool { .. }
        ))
        && consumers[producer.id].len() == 1
}

fn epilogue_of_node(node: &Node) -> Epilogue {
    match node.op {
        Op::BatchNorm => Epilogue::BatchNormFold,
        Op::Activate(a) => Epilogue::Activation(a),
        _ => unreachable!("only BN/Act absorb"),
    }
}

/// In pipelined mode strip-mine+full-inner-unroll is reported as LU, not
/// LT — the paper's Table III applies LT only to folded designs.
fn record_strip_mine_as_unroll(s: &mut Scheduler) {
    if s.applied.opts.contains(&OptKind::Tile) {
        s.applied.opts.retain(|o| *o != OptKind::Tile);
        s.applied.record(OptKind::Unroll);
    }
}

// ---------------------------------------------------------------------------
// LF — loop fusion
// ---------------------------------------------------------------------------

/// LF (§IV-C): absorb downstream BatchNorm/activation kernels into their
/// producer's epilogue and fuse intrinsic adjacent epilogue loops into the
/// reduction — the temporary global array disappears and with it its LSUs.
///
/// Pattern (Table I): activation/batchnorm in conv, FC, pooling; residual
/// adds also take the trailing ReLU. Available in both modes.
pub struct FuseEpilogues;

impl SchedulePass for FuseEpilogues {
    fn name(&self) -> &'static str {
        "loop-fusion"
    }

    fn abbrev(&self) -> &'static str {
        "LF"
    }

    fn opt_kind(&self) -> Option<OptKind> {
        Some(OptKind::Fuse)
    }

    fn description(&self) -> &'static str {
        "fuse activation/batchnorm epilogues into the producing kernel's reduction"
    }

    fn run(&self, ctx: &ScheduleCtx, prog: &mut KernelProgram, diff: &mut PassDiff) -> usize {
        let graph = ctx.graph;
        let consumers = graph.consumers();
        // Absorption decisions over the graph, chasing through
        // already-absorbed producers so conv→bn→relu folds completely.
        let mut absorbed_into: BTreeMap<usize, usize> = BTreeMap::new();
        for node in graph.topo() {
            if fusible_epilogue(graph, node, &consumers) {
                let mut host = node.inputs[0];
                while let Some(&h) = absorbed_into.get(&host) {
                    host = h;
                }
                if graph.nodes[host].op.is_compute()
                    || matches!(
                        graph.nodes[host].op,
                        Op::Add | Op::MaxPool { .. } | Op::AvgPool { .. } | Op::GlobalAvgPool
                    )
                {
                    absorbed_into.insert(node.id, host);
                }
            }
        }

        let map = node_kernel_map(prog);
        let mut matched = 0;
        let mut remove: BTreeSet<usize> = BTreeSet::new();
        // Ascending absorbed-node-id order fixes the epilogue push order.
        for (&abs, &host) in &absorbed_into {
            let (Some(&abs_k), Some(&host_k)) = (map.get(&abs), map.get(&host)) else {
                continue; // already fused on a previous run
            };
            prog.kernels[host_k].nest.epilogue.push(epilogue_of_node(&graph.nodes[abs]));
            prog.kernels[host_k].applied.record(OptKind::Fuse);
            // Record *which* node was absorbed, in push order — without
            // this the program cannot name the BN parameters its
            // `BatchNormFold` epilogue applies, and `crate::verify` cannot
            // cross-check the fused chain against the graph.
            prog.kernels[host_k].absorbed.push(abs);
            remove.insert(abs_k);
            diff.epilogues_fused += 1;
            matched += 1;
        }
        remove_kernels(prog, &remove);

        // Intrinsic epilogues (bias/activation attributes) still running
        // in an adjacent loop fuse into the reduction.
        for k in &mut prog.kernels {
            if k.nest.separate_epilogue {
                matched += 1;
                diff.epilogues_fused += 1;
                with_scheduler(k, |s| {
                    let _ = s.fuse_epilogue();
                });
            }
        }
        matched
    }
}

// ---------------------------------------------------------------------------
// OF — optimized float operations
// ---------------------------------------------------------------------------

/// OF: compile the bitstream with `-fpc -fp-relaxed` (§IV; Table I:
/// "all bitstreams"). A whole-program flag — every kernel records it.
pub struct FloatOpts;

impl SchedulePass for FloatOpts {
    fn name(&self) -> &'static str {
        "float-opts"
    }

    fn abbrev(&self) -> &'static str {
        "OF"
    }

    fn opt_kind(&self) -> Option<OptKind> {
        Some(OptKind::FloatOpt)
    }

    fn description(&self) -> &'static str {
        "-fpc -fp-relaxed float contraction/relaxed ordering for the whole bitstream"
    }

    fn equivalence(&self) -> Equivalence {
        // -fp-relaxed reassociates reductions; results may drift within a
        // documented tolerance (never bit-exactly reproducible).
        Equivalence::FloatTolerant
    }

    fn run(&self, _ctx: &ScheduleCtx, prog: &mut KernelProgram, diff: &mut PassDiff) -> usize {
        let mut matched = 0;
        for k in &mut prog.kernels {
            matched += 1;
            if !k.applied.contains(OptKind::FloatOpt) {
                diff.kernels_rescheduled += 1;
            }
            k.applied.record(OptKind::FloatOpt);
        }
        matched
    }
}

// ---------------------------------------------------------------------------
// Q — reduced-precision datapath (extension)
// ---------------------------------------------------------------------------

/// Q (extension, §VII future-work #1): schedule grid-capable kernels at a
/// reduced datapath precision. f32 islands the Q/DQ graph rewrite left
/// wide (softmax, global pooling, dequantize) keep their f32 buffers; a
/// Quantize boundary writes the narrow stream, so it is narrowed too.
pub struct QuantizeDatapath {
    pub precision: Precision,
}

impl QuantizeDatapath {
    pub fn new(precision: Precision) -> QuantizeDatapath {
        QuantizeDatapath { precision }
    }
}

impl SchedulePass for QuantizeDatapath {
    fn name(&self) -> &'static str {
        "quantize-datapath"
    }

    fn abbrev(&self) -> &'static str {
        "Q"
    }

    fn opt_kind(&self) -> Option<OptKind> {
        Some(OptKind::Quantize)
    }

    fn description(&self) -> &'static str {
        "narrow grid-capable kernels' operand streams to the target precision"
    }

    fn equivalence(&self) -> Equivalence {
        // Operand streams move onto the fixed-point grid; agreement with
        // the quantized reference executor is exact on grid semantics.
        Equivalence::GridExact
    }

    fn run(&self, ctx: &ScheduleCtx, prog: &mut KernelProgram, diff: &mut PassDiff) -> usize {
        let mut matched = 0;
        for k in &mut prog.kernels {
            let op = &ctx.graph.nodes[k.layers[0]].op;
            if rewrite::grid_capable(op) || matches!(op, Op::Quantize { .. }) {
                matched += 1;
                if k.nest.precision != self.precision {
                    diff.kernels_rescheduled += 1;
                }
                with_scheduler(k, |s| s.quantize(self.precision));
            }
        }
        matched
    }
}

// ---------------------------------------------------------------------------
// VT — vector types (extension)
// ---------------------------------------------------------------------------

/// VT (extension, §V-F mitigation): vector types align strided/windowed
/// input loads into wide vector loads — the LSU coalesces instead of
/// replicating.
pub struct VectorizeLoads;

impl SchedulePass for VectorizeLoads {
    fn name(&self) -> &'static str {
        "vectorize-loads"
    }

    fn abbrev(&self) -> &'static str {
        "VT"
    }

    fn opt_kind(&self) -> Option<OptKind> {
        Some(OptKind::Vectorize)
    }

    fn description(&self) -> &'static str {
        "coalesce strided/windowed ifmap loads into aligned vector loads"
    }

    fn equivalence(&self) -> Equivalence {
        // Rewrites modeled LSU patterns only — no value claim to check.
        Equivalence::CostModelOnly
    }

    fn run(&self, _ctx: &ScheduleCtx, prog: &mut KernelProgram, diff: &mut PassDiff) -> usize {
        let mut matched = 0;
        for k in &mut prog.kernels {
            let hits = k
                .nest
                .accesses
                .iter()
                .filter(|a| a.buffer == "ifmap" && a.pattern != Pattern::Consecutive)
                .count();
            if hits > 0 {
                matched += 1;
                diff.accesses_reclassified += hits;
            }
            with_scheduler(k, |s| s.vectorize("ifmap"));
        }
        matched
    }
}

// ---------------------------------------------------------------------------
// SP — sparse datapath (extension)
// ---------------------------------------------------------------------------

/// SP (extension, §VII #2): prune weights to `density` and skip zero MACs
/// (HPIPE-style). Applies to compute kernels only.
pub struct SparsifyWeights {
    pub density: f64,
}

impl SparsifyWeights {
    pub fn new(density: f64) -> SparsifyWeights {
        SparsifyWeights { density }
    }
}

impl SchedulePass for SparsifyWeights {
    fn name(&self) -> &'static str {
        "sparsify-weights"
    }

    fn abbrev(&self) -> &'static str {
        "SP"
    }

    fn opt_kind(&self) -> Option<OptKind> {
        Some(OptKind::Sparsify)
    }

    fn description(&self) -> &'static str {
        "prune weights to the target density; zero MACs are skipped"
    }

    fn equivalence(&self) -> Equivalence {
        // The model rescales weight traffic/skip logic only; actual weight
        // pruning (a value change) is out of the modeled value domain.
        Equivalence::CostModelOnly
    }

    fn precondition(&self, _ctx: &ScheduleCtx) -> Result<(), String> {
        legality::sparsity_domain(self.density).map_err(|d| d.message)
    }

    fn run(&self, ctx: &ScheduleCtx, prog: &mut KernelProgram, diff: &mut PassDiff) -> usize {
        let mut matched = 0;
        for k in &mut prog.kernels {
            if !ctx.graph.nodes[k.layers[0]].op.is_compute() {
                continue;
            }
            matched += 1;
            // Idempotent: a nest already at the target density keeps its
            // (truncating) traffic rescale from being applied twice.
            if k.nest.weight_density > self.density {
                diff.kernels_rescheduled += 1;
                with_scheduler(k, |s| s.sparsify(self.density));
            }
        }
        matched
    }
}

// ---------------------------------------------------------------------------
// PK — parameterized kernels
// ---------------------------------------------------------------------------

/// PK (§IV-H): group compute kernels by (filter, stride); one hardware
/// kernel with runtime-dynamic extents serves every layer in its group.
/// Folded mode only (Table I).
pub struct ParameterizeKernels;

impl SchedulePass for ParameterizeKernels {
    fn name(&self) -> &'static str {
        "parameterized-kernels"
    }

    fn abbrev(&self) -> &'static str {
        "PK"
    }

    fn opt_kind(&self) -> Option<OptKind> {
        Some(OptKind::Parameterize)
    }

    fn description(&self) -> &'static str {
        "merge same-(filter, stride) compute kernels into one parameterized kernel"
    }

    fn precondition(&self, ctx: &ScheduleCtx) -> Result<(), String> {
        legality::mode_restriction(
            "PK parameterized kernels",
            Mode::Folded,
            ctx.mode,
            "Table I restricts PK to folded designs (§IV-H)",
        )
    }

    fn run(&self, ctx: &ScheduleCtx, prog: &mut KernelProgram, diff: &mut PassDiff) -> usize {
        let mut matched = 0;
        let mut group_rep: BTreeMap<ParamGroup, usize> = BTreeMap::new();
        let mut remove: BTreeSet<usize> = BTreeSet::new();
        let mut merged_layers: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (i, k) in prog.kernels.iter().enumerate() {
            let node = &ctx.graph.nodes[k.layers[0]];
            if !node.op.is_compute() {
                continue;
            }
            let Some(g) = node.op.param_group() else { continue };
            matched += 1;
            match group_rep.get(&g) {
                None => {
                    group_rep.insert(g, i);
                }
                Some(&rep) => {
                    remove.insert(i);
                    merged_layers.entry(rep).or_default().extend(k.layers.iter().copied());
                    diff.kernels_merged += 1;
                }
            }
        }

        for (&g, &rep) in &group_rep {
            let k = &mut prog.kernels[rep];
            k.group = Some(g);
            if let Some(mut extra) = merged_layers.remove(&rep) {
                extra.sort_unstable();
                for nid in extra {
                    if !k.layers.contains(&nid) {
                        k.layers.push(nid);
                    }
                }
            }
            let newly_dynamic = k
                .nest
                .loops
                .iter()
                .filter(|l| !matches!(l.var, LoopVar::KH | LoopVar::KW) && !l.dynamic)
                .count();
            diff.loops_parameterized += newly_dynamic;
            with_scheduler(k, |s| s.parameterize());
        }
        remove_kernels(prog, &remove);
        matched
    }
}

// ---------------------------------------------------------------------------
// LT — loop tiling (folded compute kernels)
// ---------------------------------------------------------------------------

/// LT (§IV-B): strip-mine channel loops with a fully-unrolled inner tile
/// sized by the [`FactorPlan`]; filter taps of k ≥ 3 convs fully unroll.
/// Pattern (Table I): conv, FC. Folded mode only.
pub struct TileLoops;

impl SchedulePass for TileLoops {
    fn name(&self) -> &'static str {
        "loop-tiling"
    }

    fn abbrev(&self) -> &'static str {
        "LT"
    }

    fn opt_kind(&self) -> Option<OptKind> {
        Some(OptKind::Tile)
    }

    fn description(&self) -> &'static str {
        "strip-mine channel loops to the plan's tiles with fully-unrolled inners"
    }

    fn precondition(&self, ctx: &ScheduleCtx) -> Result<(), String> {
        legality::mode_restriction(
            "LT loop tiling",
            Mode::Folded,
            ctx.mode,
            "Table III applies LT only to folded designs; pipelined strip-mines report as LU (§IV-B)",
        )
    }

    fn run(&self, ctx: &ScheduleCtx, prog: &mut KernelProgram, diff: &mut PassDiff) -> usize {
        let mut matched = 0;
        for k in &mut prog.kernels {
            let node = &ctx.graph.nodes[k.layers[0]];
            if !node.op.is_compute() {
                continue;
            }
            matched += 1;
            with_scheduler(k, |s| apply_folded_tiles(s, node, ctx.plan, diff));
        }
        matched
    }
}

fn apply_folded_tiles(s: &mut Scheduler, node: &Node, plan: &FactorPlan, diff: &mut PassDiff) {
    let Some(g) = node.op.param_group() else { return };
    match g.kind {
        GroupKind::Dense => {
            let (t_in, t_out) = plan.dense_tile;
            for (v, t) in [(LoopVar::InC, t_in), (LoopVar::OutC, t_out)] {
                tile_to_cap(s, v, t, diff);
            }
        }
        GroupKind::Depthwise => {
            let (t_c, _) = plan.group_tiles.get(&g).copied().unwrap_or((8, 1));
            for v in [LoopVar::KH, LoopVar::KW] {
                if s.unroll(v).is_ok() {
                    diff.loops_unrolled += 1;
                }
            }
            tile_to_cap(s, LoopVar::OutC, t_c, diff);
        }
        GroupKind::Conv => {
            let (t_ic, t_oc) = plan.group_tiles.get(&g).copied().unwrap_or((8, 8));
            if g.kernel >= 3 {
                for v in [LoopVar::KH, LoopVar::KW] {
                    if s.unroll(v).is_ok() {
                        diff.loops_unrolled += 1;
                    }
                }
            }
            tile_to_cap(s, LoopVar::InC, t_ic, diff);
            tile_to_cap(s, LoopVar::OutC, t_oc, diff);
        }
    }
}

/// Strip-mine `var` by the largest §IV-J-rule-2 divisor ≤ `cap`.
fn tile_to_cap(s: &mut Scheduler, var: LoopVar, cap: u64, diff: &mut PassDiff) {
    let Some(l) = s.nest.find_loop(var) else { return };
    let f = legality::largest_divisor_leq(l.extent, cap);
    let full = f == l.extent;
    if s.tile_and_unroll(var, f).is_ok() {
        if full {
            diff.loops_unrolled += 1;
        } else {
            diff.loops_tiled += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// LU — loop unrolling
// ---------------------------------------------------------------------------

/// LU (§IV-A): fully unroll loops ("we only fully unroll loops since
/// partial unrolling may limit performance gains"). Pattern (Table I):
/// all kernels except transpose/padding. In pipelined mode compute
/// kernels unroll reduction loops innermost-first under the plan's lane
/// cap; in folded mode without tiling only the filter taps unroll; pool
/// windows unroll capped at 8 taps per dimension in both modes.
pub struct UnrollLoops {
    /// True when [`TileLoops`] is also in the pipeline — folded compute
    /// kernels then belong to LT and LU leaves them alone.
    pub folded_tiling: bool,
}

impl UnrollLoops {
    pub fn new(folded_tiling: bool) -> UnrollLoops {
        UnrollLoops { folded_tiling }
    }
}

impl SchedulePass for UnrollLoops {
    fn name(&self) -> &'static str {
        "loop-unrolling"
    }

    fn abbrev(&self) -> &'static str {
        "LU"
    }

    fn opt_kind(&self) -> Option<OptKind> {
        Some(OptKind::Unroll)
    }

    fn description(&self) -> &'static str {
        "fully unroll reduction/filter loops into parallel MAC lanes"
    }

    fn run(&self, ctx: &ScheduleCtx, prog: &mut KernelProgram, diff: &mut PassDiff) -> usize {
        let mut matched = 0;
        for k in &mut prog.kernels {
            let node = &ctx.graph.nodes[k.layers[0]];
            if node.op.is_compute() {
                match ctx.mode {
                    Mode::Folded => {
                        if self.folded_tiling {
                            continue; // LT owns folded compute kernels
                        }
                        matched += 1;
                        with_scheduler(k, |s| {
                            for v in [LoopVar::KH, LoopVar::KW] {
                                if s.unroll(v).is_ok() {
                                    diff.loops_unrolled += 1;
                                }
                            }
                        });
                    }
                    Mode::Pipelined => {
                        matched += 1;
                        with_scheduler(k, |s| {
                            apply_pipelined_unroll(s, node, ctx.plan, diff);
                        });
                    }
                }
            } else if !node.op.unroll_exempt() {
                // Pools etc: unroll the window taps, capped at 8 per dim
                // so huge global-average windows stay under the roof.
                if k.nest.find_loop(LoopVar::KH).is_some() || k.nest.find_loop(LoopVar::KW).is_some()
                {
                    matched += 1;
                }
                let pipelined = ctx.mode == Mode::Pipelined;
                with_scheduler(k, |s| {
                    for v in [LoopVar::KH, LoopVar::KW] {
                        if s.nest.find_loop(v).is_some() {
                            tile_to_cap(s, v, 8, diff);
                        }
                    }
                    if pipelined {
                        record_strip_mine_as_unroll(s);
                    }
                });
            }
        }
        matched
    }
}

fn apply_pipelined_unroll(s: &mut Scheduler, node: &Node, plan: &FactorPlan, diff: &mut PassDiff) {
    let cap = plan.pipelined_cap.max(1);
    match node.op {
        Op::Dense { .. } => {
            let (t_in, _) = plan.dense_tile;
            tile_to_cap(s, LoopVar::InC, t_in, diff);
            record_strip_mine_as_unroll(s);
        }
        _ => {
            // Unroll reduction loops innermost-first while ≤ cap, then the
            // output-channel loop if it still fits (full unrolls only).
            // The lane budget accumulates from the loop extents (not the
            // unroll outcomes) so re-running the pass is a no-op.
            let mut product = 1u64;
            for v in [LoopVar::KW, LoopVar::KH, LoopVar::InC] {
                let extent = s
                    .nest
                    .find_loop(v)
                    .and_then(|l| (l.reduction && product * l.extent <= cap).then_some(l.extent));
                if let Some(e) = extent {
                    product *= e;
                    if s.unroll(v).is_ok() {
                        diff.loops_unrolled += 1;
                    }
                }
            }
            let oc_fits = match s.nest.find_loop(LoopVar::OutC) {
                Some(l) => product * l.extent <= cap,
                None => false,
            };
            if oc_fits && s.unroll(LoopVar::OutC).is_ok() {
                diff.loops_unrolled += 1;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// CW — cached writes (+ folded BRAM tile stashes)
// ---------------------------------------------------------------------------

/// CW (§IV-D): accumulate in a private register and write global memory
/// once per output element, removing the read-modify-write LSU. Folded
/// compute kernels additionally stage their weight/input tiles in BRAM
/// (double-buffered), sized for the plan's tiles at the datapath's element
/// width. Pattern (Table I): all kernels except transpose/padding.
pub struct CachedWrites;

impl SchedulePass for CachedWrites {
    fn name(&self) -> &'static str {
        "cached-writes"
    }

    fn abbrev(&self) -> &'static str {
        "CW"
    }

    fn opt_kind(&self) -> Option<OptKind> {
        Some(OptKind::CachedWrite)
    }

    fn description(&self) -> &'static str {
        "accumulate in private registers; folded kernels stash operand tiles in BRAM"
    }

    fn run(&self, ctx: &ScheduleCtx, prog: &mut KernelProgram, diff: &mut PassDiff) -> usize {
        let mut matched = 0;
        for k in &mut prog.kernels {
            let node = &ctx.graph.nodes[k.layers[0]];
            if node.op.unroll_exempt() {
                continue;
            }
            matched += 1;
            let rmw = k
                .nest
                .accesses
                .iter()
                .filter(|a| a.dir == Dir::ReadWrite && a.space == MemSpace::Global)
                .count();
            diff.accesses_reclassified += rmw;
            with_scheduler(k, |s| {
                let _ = s.cache_write();
            });
            if ctx.mode == Mode::Folded && node.op.is_compute() {
                let staged = k
                    .nest
                    .accesses
                    .iter()
                    .filter(|a| {
                        a.space == MemSpace::Global
                            && a.dir == Dir::Read
                            && (a.buffer == "weights" || a.buffer == "ifmap")
                    })
                    .count();
                diff.accesses_cached += staged;
                // The input-line strip must cover the widest feature map
                // this kernel actually reads — for a parameterized (PK)
                // kernel that is the max over every member layer.
                let max_w = max_input_width(ctx.graph, &k.layers);
                with_scheduler(k, |s| {
                    let _ = s.cache_read("weights");
                    let _ = s.cache_read("ifmap");
                    tile_stash_bytes(s, ctx.plan, node, max_w);
                });
            }
        }
        matched
    }
}

/// Widest input feature map (in elements per row; flat inputs count their
/// full length) any of `layers` reads — what the double-buffered ifmap
/// line strip of a folded kernel must span. Shared with the analyzer's
/// stash-capacity lint (`analysis::structure::stash_capacity`, FLOW032 —
/// also what the `verify` interpreter delegates to) so the sizing code and
/// its checker agree on what "the strip" means (the check still catches
/// sizing-formula bugs like a hard-coded on-chip width).
pub(crate) fn max_input_width(graph: &Graph, layers: &[usize]) -> u64 {
    layers
        .iter()
        .filter_map(|&nid| {
            let inp = graph.nodes[nid].inputs.first().copied()?;
            let shape = &graph.nodes[inp].shape;
            Some(match shape.chw() {
                Some((_, _, w)) => w as u64,
                None => shape.elems() as u64,
            })
        })
        .max()
        .unwrap_or(1)
}

/// Size the BRAM tile stashes of a folded kernel: double-buffered weight
/// tile + an input line strip, at the datapath's element width. `max_w`
/// is the widest member-layer input row (previously hard-coded to 224,
/// which over-sized the stash ~7× on LeNet-class maps and would
/// under-size it for anything wider — surfaced by the `verify` harness's
/// stash-capacity check).
fn tile_stash_bytes(s: &mut Scheduler, plan: &FactorPlan, node: &Node, max_w: u64) {
    let Some(g) = node.op.param_group() else { return };
    let (t_ic, t_oc) = plan.group_tiles.get(&g).copied().unwrap_or((8, 8));
    let k2 = (g.kernel * g.kernel) as u64;
    let eb = s.nest.precision.bytes();
    for a in &mut s.nest.accesses {
        if a.space == MemSpace::Local {
            a.array_bytes = match a.buffer.as_str() {
                "weights" => 2 * t_ic * t_oc * k2 * eb,
                // strip of k input rows × tile channels at the actual width
                "ifmap" => 2 * t_ic * (g.kernel as u64) * max_w * eb,
                _ => a.array_bytes,
            };
        }
    }
}

// ---------------------------------------------------------------------------
// CH — channelization
// ---------------------------------------------------------------------------

/// CH (§IV-E): activations move between kernels through OpenCL channels
/// instead of global LSUs; weights stash in BRAM. Each FIFO carries its
/// producer's element type. Pattern (Table I): movement of activations,
/// all layers. Pipelined mode only.
pub struct Channelize;

impl SchedulePass for Channelize {
    fn name(&self) -> &'static str {
        "channelize"
    }

    fn abbrev(&self) -> &'static str {
        "CH"
    }

    fn opt_kind(&self) -> Option<OptKind> {
        Some(OptKind::Channels)
    }

    fn description(&self) -> &'static str {
        "route activations through kernel-to-kernel FIFO channels; stash weights in BRAM"
    }

    fn precondition(&self, ctx: &ScheduleCtx) -> Result<(), String> {
        legality::mode_restriction(
            "CH channelization",
            Mode::Pipelined,
            ctx.mode,
            "folded kernels hand activations through global memory (§IV-E)",
        )
    }

    fn run(&self, ctx: &ScheduleCtx, prog: &mut KernelProgram, diff: &mut PassDiff) -> usize {
        // Channels between consecutive kernels; the FIFO depth must cover
        // the largest feature map (§IV-J).
        if prog.channels.is_empty() {
            let map = node_kernel_map(prog);
            let depth = (ctx.graph.max_activation_bytes() / 4).max(16);
            let mut channels = Vec::new();
            for k in &prog.kernels {
                let node = &ctx.graph.nodes[k.layers[0]];
                for &inp in &node.inputs {
                    if let Some(src_k) = producing_kernel(ctx.graph, &map, inp) {
                        if src_k != k.id {
                            channels.push(Channel {
                                name: format!("ch_{}_{}", src_k, k.id),
                                from_kernel: src_k,
                                to_kernel: k.id,
                                depth,
                                elem: prog.kernels[src_k].nest.precision,
                            });
                        }
                    }
                }
            }
            diff.channels_inserted += channels.len();
            prog.channels = channels;
        }

        let mut matched = 0;
        for k in &mut prog.kernels {
            matched += 1;
            let moving = k
                .nest
                .accesses
                .iter()
                .filter(|a| {
                    ((a.buffer == "ifmap" || a.buffer == "ofmap")
                        && a.space != MemSpace::Channel)
                        || (a.buffer == "weights"
                            && a.space == MemSpace::Global
                            && a.dir == Dir::Read)
                })
                .count();
            diff.accesses_cached += moving;
            with_scheduler(k, |s| {
                s.channelize("ifmap");
                s.channelize("ofmap");
                let _ = s.cache_read("weights"); // weight stash in BRAM
            });
        }
        matched
    }
}

// ---------------------------------------------------------------------------
// AR — autorun kernels
// ---------------------------------------------------------------------------

/// AR (§IV-F): weightless channel-only kernels need no host arguments and
/// launch themselves. Pattern (Table I): pooling, transpose/padding.
/// Pipelined mode only (requires CH to have removed global accesses).
pub struct AutorunKernels;

impl SchedulePass for AutorunKernels {
    fn name(&self) -> &'static str {
        "autorun-kernels"
    }

    fn abbrev(&self) -> &'static str {
        "AR"
    }

    fn opt_kind(&self) -> Option<OptKind> {
        Some(OptKind::Autorun)
    }

    fn description(&self) -> &'static str {
        "declare weightless channel-only kernels autorun (no host control)"
    }

    fn precondition(&self, ctx: &ScheduleCtx) -> Result<(), String> {
        legality::mode_restriction(
            "AR autorun",
            Mode::Pipelined,
            ctx.mode,
            "autorun requires channel-fed kernels with no global arguments (§IV-F)",
        )
    }

    fn run(&self, ctx: &ScheduleCtx, prog: &mut KernelProgram, diff: &mut PassDiff) -> usize {
        let mut matched = 0;
        for k in &mut prog.kernels {
            let node = &ctx.graph.nodes[k.layers[0]];
            if !node.op.has_weights() && k.autorun_eligible() {
                matched += 1;
                if !k.autorun {
                    diff.autorun_marked += 1;
                }
                k.autorun = true;
                k.applied.record(OptKind::Autorun);
            }
        }
        matched
    }
}

// ---------------------------------------------------------------------------
// CE — concurrent execution
// ---------------------------------------------------------------------------

/// CE (§IV-G): one host command queue per kernel so all kernels launch
/// concurrently. A host-side optimization; pipelined mode only (§IV-J:
/// folded designs serialize layer dispatches on one queue).
pub struct ConcurrentQueues;

impl SchedulePass for ConcurrentQueues {
    fn name(&self) -> &'static str {
        "concurrent-queues"
    }

    fn abbrev(&self) -> &'static str {
        "CE"
    }

    fn opt_kind(&self) -> Option<OptKind> {
        Some(OptKind::Concurrent)
    }

    fn description(&self) -> &'static str {
        "one host command queue per kernel; all kernels launch concurrently"
    }

    fn precondition(&self, ctx: &ScheduleCtx) -> Result<(), String> {
        legality::mode_restriction(
            "CE concurrent execution",
            Mode::Pipelined,
            ctx.mode,
            "CE is not applicable to folded designs, which serialize layer dispatches (§IV-J)",
        )
    }

    fn run(&self, _ctx: &ScheduleCtx, prog: &mut KernelProgram, diff: &mut PassDiff) -> usize {
        prog.queues = prog.kernels.len().max(1);
        diff.queues_created = prog.queues;
        let mut matched = 0;
        for (q, k) in prog.kernels.iter_mut().enumerate() {
            k.queue = q;
            k.applied.record(OptKind::Concurrent);
            matched += 1;
        }
        matched
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    #[test]
    fn neutral_lowering_skips_layout_nodes() {
        let g = models::lenet5();
        let prog = lower_to_kernels(&g, Mode::Pipelined);
        assert_eq!(prog.name, "lenet5_pipelined");
        // input + flatten are skipped; every other node owns one kernel.
        let layout = g
            .topo()
            .filter(|n| matches!(n.op, Op::Input | Op::Flatten | Op::Transform))
            .count();
        assert_eq!(prog.kernels.len(), g.nodes.len() - layout);
        assert!(prog.channels.is_empty());
        assert_eq!(prog.queues, 1);
        for (i, k) in prog.kernels.iter().enumerate() {
            assert_eq!(k.id, i);
            assert!(k.name.starts_with(&format!("k{i}_")));
            assert_eq!(k.layers.len(), 1);
        }
    }

    #[test]
    fn remove_kernels_renumbers_densely() {
        let g = models::lenet5();
        let mut prog = lower_to_kernels(&g, Mode::Pipelined);
        let before = prog.kernels.len();
        let mut remove = BTreeSet::new();
        remove.insert(1);
        remove.insert(3);
        remove_kernels(&mut prog, &remove);
        assert_eq!(prog.kernels.len(), before - 2);
        for (i, k) in prog.kernels.iter().enumerate() {
            assert_eq!(k.id, i);
            assert!(k.name.starts_with(&format!("k{i}_")), "{}", k.name);
        }
    }

    #[test]
    fn absorbed_chain_recorded_in_fusion_order() {
        // Regression (surfaced by the verify harness): LF used to discard
        // the identity of absorbed BN/activation nodes, so a
        // `BatchNormFold` epilogue named no parameters and the fused chain
        // was unrecoverable from the program. Kernels now record the
        // absorbed node ids in push (= graph) order.
        use crate::flow::patterns::{build_with_passes, default_factors, OptConfig};
        let g = models::mobilenet_v1();
        let plan = default_factors(&g);
        let built = build_with_passes(&g, Mode::Pipelined, &OptConfig::optimized(), &plan);
        let mut checked = 0;
        for k in &built.program.kernels {
            if !ctx_is_conv(&g, k.layers[0]) {
                continue;
            }
            // Every MobileNet conv/dw hosts a bn → relu chain.
            assert_eq!(k.absorbed.len(), 2, "kernel {}: {:?}", k.name, k.absorbed);
            assert!(matches!(g.nodes[k.absorbed[0]].op, Op::BatchNorm), "{}", k.name);
            assert!(matches!(g.nodes[k.absorbed[1]].op, Op::Activate(_)), "{}", k.name);
            // Push order is graph order: the epilogue suffix mirrors it.
            let n = k.nest.epilogue.len();
            assert!(matches!(k.nest.epilogue[n - 2], Epilogue::BatchNormFold), "{}", k.name);
            assert!(matches!(k.nest.epilogue[n - 1], Epilogue::Activation(_)), "{}", k.name);
            checked += 1;
        }
        assert!(checked >= 14, "only {checked} conv kernels checked");
    }

    fn ctx_is_conv(g: &Graph, node: usize) -> bool {
        matches!(g.nodes[node].op, Op::Conv2d { .. } | Op::DepthwiseConv2d { .. })
    }

    #[test]
    fn folded_ifmap_stash_sized_to_actual_layer_width() {
        // Regression (surfaced by the verify harness's stash-capacity
        // check): the folded ifmap line strip was hard-coded to a 224-wide
        // feature map, over-sizing LeNet-class stashes ~7× and
        // under-sizing anything wider. It now spans the widest member
        // layer's actual input row.
        use crate::flow::patterns::{build_folded, default_factors, OptConfig};
        let g = models::lenet5();
        let plan = default_factors(&g);
        let (prog, _) = build_folded(&g, &OptConfig::optimized(), &plan);
        let group = ParamGroup { kind: GroupKind::Conv, kernel: 5, stride: 1 };
        let k = prog
            .kernels
            .iter()
            .find(|k| k.group == Some(group))
            .expect("lenet folded has a conv5x5s1 kernel");
        let (t_ic, _) = plan.group_tiles[&group];
        // Widest member input: c1 reads the 32-wide image (c3 reads 14).
        let expect = 2 * t_ic * 5 * 32 * k.nest.precision.bytes();
        let ifmap = k
            .nest
            .accesses
            .iter()
            .find(|a| a.buffer == "ifmap" && a.space == MemSpace::Local)
            .expect("folded conv stashes its ifmap strip in BRAM");
        assert_eq!(ifmap.array_bytes, expect, "kernel {}", k.name);
        let old_2240 = 2 * t_ic * 5 * 224 * k.nest.precision.bytes();
        assert!(ifmap.array_bytes < old_2240, "stash still sized for a 224-wide map");
    }

    #[test]
    fn producing_kernel_climbs_through_fused_nodes() {
        let g = models::mobilenet_v1();
        let prog = lower_to_kernels(&g, Mode::Pipelined);
        let map = node_kernel_map(&prog);
        // Every non-layout node resolves to its own kernel.
        for n in g.topo() {
            if matches!(n.op, Op::Input | Op::Flatten | Op::Transform) {
                continue;
            }
            assert_eq!(producing_kernel(&g, &map, n.id), map.get(&n.id).copied());
        }
        // The graph input resolves to no kernel.
        assert_eq!(producing_kernel(&g, &map, g.input), None);
    }
}
