//! Pipeline-parallel multi-FPGA partitioning (ROADMAP item 2; the
//! direction DNNVM pursues with subgraph partitioning + heuristic
//! scheduling, and the standard path past single-chip resource walls in
//! the FPGA CNN acceleration survey).
//!
//! The network is cut at K-1 topological points into K contiguous stage
//! subgraphs, one per device, connected by host channels. Cut legality
//! reuses the hybrid-deployment rule (§V-F): a cut is clean only when the
//! frontier is exactly one value — every node after the cut that reads
//! across it reads the boundary producer and nothing else — so residual
//! shortcuts can never straddle two devices.
//!
//! The cost model ([`StageCost`]) is latency-balancing: a stage's time is
//! `max(compute, transfer)` because the host channel transfer into stage i
//! overlaps stage i-1's compute on the previous frame, and the objective
//! is to minimize the bottleneck stage (steady-state pipeline throughput
//! is `1 / max_i stage_s`), subject to each stage fitting its device's
//! BRAM/DSP/ALM budget. The search over cut combinations lives in
//! [`crate::dse::explore_partitions`]; the chosen plan is materialized by
//! [`crate::flow::multi::PipelinePlan`].

use crate::flow::hybrid;
use crate::flow::multi::Link;
use crate::graph::{Graph, GraphBuilder, Op};

use super::{Equivalence, GraphPass, PassDiff};

/// One stage subgraph plus the node-id provenance needed to reproduce the
/// parent graph's semantics exactly.
///
/// Stage graphs are rebuilt with fresh names and renumbered node ids, but
/// the reference executor seeds parameters from `(graph name, node id)` —
/// so equivalence against the unpartitioned oracle requires mapping every
/// stage node back to its parent node. `parent_ids[stage_id]` is that
/// parent node id; a stage's fresh `Input` node maps to the boundary
/// producer it receives its tensor from.
#[derive(Debug, Clone)]
pub struct StageGraph {
    pub graph: Graph,
    /// Parent node id for each stage node id (same length as
    /// `graph.nodes`).
    pub parent_ids: Vec<usize>,
}

impl StageGraph {
    /// Bytes of the tensor this stage receives over the host link (fp32
    /// boundary activations; the network input for stage 0).
    pub fn input_bytes(&self) -> u64 {
        self.graph.nodes[self.graph.input].shape.bytes() as u64
    }
}

/// Candidate cut points: after each spatial-reduction node the feature
/// map shrinks, so these are the natural (cheapest-transfer) boundaries —
/// the hybrid-deployment candidate set. A residual network's strided
/// convs sit *inside* shortcut blocks, though, so every post-reduction
/// frontier there is crossed by the skip edge and never splits cleanly;
/// the frontier *entering* each reduction — the end of a resolution
/// stage — lies between blocks and does split, so it is offered too
/// (transfer there costs the pre-reduction feature map). Candidates are
/// not guaranteed legal: [`split_stages`] is the arbiter, and the search
/// records illegal combinations as rejected.
pub fn candidate_cuts(graph: &Graph) -> Vec<usize> {
    let mut cuts: std::collections::BTreeSet<usize> =
        hybrid::cut_points(graph).into_iter().collect();
    for n in graph.topo() {
        let shrinks = match n.op {
            Op::MaxPool { stride, .. } | Op::AvgPool { stride, .. } => stride > 1,
            Op::Conv2d { stride, .. } | Op::DepthwiseConv2d { stride, .. } => stride > 1,
            _ => false,
        };
        if !shrinks {
            continue;
        }
        for &p in &n.inputs {
            // Skip the graph input (a compute-free front stage) and keep
            // the cut in range.
            if p != graph.input && p + 1 < graph.nodes.len() {
                cuts.insert(p + 1);
            }
        }
    }
    cuts.into_iter().collect()
}

/// Split `graph` into `cuts.len() + 1` contiguous stages. `cuts` must be
/// strictly increasing, each in `(0, len)`. Returns `None` when any cut
/// is not a clean single-value frontier (e.g. inside a residual block).
///
/// With no cuts the single stage is the parent graph itself (same name,
/// same ids) — the degenerate K=1 partition is byte-identical to the
/// unpartitioned plan by construction.
pub fn split_stages(graph: &Graph, cuts: &[usize]) -> Option<Vec<StageGraph>> {
    if cuts.is_empty() {
        return Some(vec![StageGraph {
            graph: graph.clone(),
            parent_ids: (0..graph.nodes.len()).collect(),
        }]);
    }
    let len = graph.nodes.len();
    for (i, &c) in cuts.iter().enumerate() {
        if c == 0 || c >= len {
            return None;
        }
        if i > 0 && c <= cuts[i - 1] {
            return None;
        }
    }
    // Every cut must be a clean frontier: a node may only read across the
    // nearest cut below it, and only the boundary producer.
    for n in graph.topo() {
        for &i in &n.inputs {
            for &c in cuts {
                if n.id >= c && i < c && i != c - 1 {
                    return None;
                }
            }
        }
    }
    let k = cuts.len() + 1;
    let mut stages = Vec::with_capacity(k);
    for s in 0..k {
        let lo = if s == 0 { 0 } else { cuts[s - 1] };
        let hi = if s == k - 1 { len } else { cuts[s] };
        stages.push(rebuild_stage(graph, s, lo, hi)?);
    }
    Some(stages)
}

/// Rebuild nodes `[lo, hi)` as a standalone stage graph named
/// `"{parent}.s{index}"`. Stages after the first get a fresh `Input`
/// node shaped like the boundary tensor, mapped back to parent node
/// `lo - 1` (the producer whose activation crosses the link).
fn rebuild_stage(graph: &Graph, index: usize, lo: usize, hi: usize) -> Option<StageGraph> {
    let name = format!("{}.s{index}", graph.name);
    let mut map: Vec<Option<usize>> = vec![None; graph.nodes.len()];
    let mut parent_ids: Vec<usize> = Vec::with_capacity(hi - lo + 1);
    let mut b: Option<GraphBuilder> = None;
    if lo > 0 {
        let boundary = &graph.nodes[lo - 1];
        let (builder, id) = GraphBuilder::new(name.clone(), boundary.shape.clone());
        b = Some(builder);
        map[lo - 1] = Some(id);
        parent_ids.push(lo - 1);
    }
    let mut last = 0usize;
    for node in &graph.nodes[lo..hi] {
        match node.op {
            Op::Input => {
                let (builder, id) = GraphBuilder::new(name.clone(), node.shape.clone());
                b = Some(builder);
                map[node.id] = Some(id);
                parent_ids.push(node.id);
            }
            _ => {
                let builder = b.as_mut()?;
                let inputs: Vec<usize> =
                    node.inputs.iter().map(|&i| map[i]).collect::<Option<_>>()?;
                let id = builder.add(node.name.clone(), node.op.clone(), &inputs);
                map[node.id] = Some(id);
                parent_ids.push(node.id);
            }
        }
        last = map[node.id]?;
    }
    let g = b?.finish(last);
    g.validate().ok()?;
    debug_assert_eq!(g.nodes.len(), parent_ids.len());
    Some(StageGraph { graph: g, parent_ids })
}

/// Modeled cost of one pipeline stage under the latency-balancing model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageCost {
    /// Modeled compute time per frame on the stage's device.
    pub compute_s: f64,
    /// Host-link transfer time for the stage's input tensor.
    pub transfer_s: f64,
    /// Bytes entering the stage over the host link per frame.
    pub transfer_bytes: u64,
}

impl StageCost {
    /// Model a stage: transfer = link latency + bytes / bandwidth.
    pub fn model(compute_s: f64, transfer_bytes: u64, link: &Link) -> StageCost {
        let transfer_s = link.latency_s + transfer_bytes as f64 / link.bandwidth_bytes_per_s;
        StageCost { compute_s, transfer_s, transfer_bytes }
    }

    /// Stage time under overlap: the transfer into stage i runs while
    /// stage i-1 computes the previous frame, so the stage occupies
    /// `max(compute, transfer)` of pipeline interval.
    pub fn stage_s(&self) -> f64 {
        self.compute_s.max(self.transfer_s)
    }

    /// Which term binds this stage.
    pub fn bound(&self) -> &'static str {
        if self.transfer_s > self.compute_s {
            "transfer"
        } else {
            "compute"
        }
    }
}

/// Graph-level pass that records a chosen pipeline partition in the pass
/// trace. The rewrite itself is the identity — stage subgraphs are
/// materialized by [`split_stages`] on the flow side — but running it
/// through the [`crate::pass::PassManager`] makes the partition decision
/// a first-class, inspectable trace record (`fpga-flow explain`) with the
/// same applicability/legality/equivalence contract as every other pass.
///
/// Applicability pattern: the graph must split cleanly at every chosen
/// cut (single-value frontier). Equivalence obligation: bit-exact — a
/// partition only relocates nodes across devices; chained stage execution
/// must reproduce the unpartitioned values exactly at every precision.
#[derive(Debug, Clone)]
pub struct PartitionPass {
    /// Chosen cut points (parent node ids; `stages = cuts.len() + 1`).
    pub cuts: Vec<usize>,
}

impl GraphPass for PartitionPass {
    fn name(&self) -> &'static str {
        "partition-pipeline"
    }

    fn abbrev(&self) -> &'static str {
        "PT"
    }

    fn description(&self) -> &'static str {
        "split the network into per-device pipeline stages at clean spatial-reduction frontiers"
    }

    fn precondition(&self, graph: &Graph) -> Result<(), String> {
        if self.cuts.is_empty() {
            return Err("single device — degenerate partition, nothing to cut".into());
        }
        if split_stages(graph, &self.cuts).is_none() {
            return Err(format!(
                "cuts {:?} are not clean single-value frontiers (residual edge crosses a cut)",
                self.cuts
            ));
        }
        Ok(())
    }

    fn equivalence(&self) -> Equivalence {
        Equivalence::BitExact
    }

    fn run(&self, graph: &Graph, diff: &mut PassDiff) -> (Graph, usize) {
        // One fresh Input node and one host channel per cut.
        diff.nodes_inserted += self.cuts.len();
        diff.channels_inserted += self.cuts.len();
        (graph.clone(), self.cuts.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::pass::{PassManager, Pipeline};

    #[test]
    fn degenerate_split_is_identity() {
        let g = models::lenet5();
        let stages = split_stages(&g, &[]).unwrap();
        assert_eq!(stages.len(), 1);
        assert_eq!(stages[0].graph.name, g.name);
        assert_eq!(stages[0].graph.nodes.len(), g.nodes.len());
        assert_eq!(stages[0].parent_ids, (0..g.nodes.len()).collect::<Vec<_>>());
    }

    #[test]
    fn two_way_split_preserves_macs_and_maps_parents() {
        let g = models::lenet5();
        let cuts = candidate_cuts(&g);
        assert!(!cuts.is_empty());
        let stages = split_stages(&g, &cuts[..1]).unwrap();
        assert_eq!(stages.len(), 2);
        let macs: u64 = stages.iter().map(|s| s.graph.total_macs()).sum();
        assert_eq!(macs, g.total_macs());
        // Stage 1's Input maps to the boundary producer.
        assert_eq!(stages[1].parent_ids[0], cuts[0] - 1);
        assert_eq!(stages[1].graph.nodes[0].shape, g.nodes[cuts[0] - 1].shape);
        // Every mapped node keeps its parent op.
        for s in &stages {
            for n in s.graph.topo() {
                if !matches!(n.op, Op::Input) {
                    assert_eq!(
                        std::mem::discriminant(&n.op),
                        std::mem::discriminant(&g.nodes[s.parent_ids[n.id]].op)
                    );
                }
            }
        }
    }

    #[test]
    fn three_way_split_on_resnet_boundaries() {
        let g = models::resnet34();
        let cuts = candidate_cuts(&g);
        // Keep only cuts that are individually clean, then pick two.
        let clean: Vec<usize> =
            cuts.into_iter().filter(|&c| split_stages(&g, &[c]).is_some()).collect();
        assert!(clean.len() >= 2, "resnet34 needs ≥2 clean cuts, got {clean:?}");
        let stages = split_stages(&g, &[clean[0], clean[1]]).unwrap();
        assert_eq!(stages.len(), 3);
        let macs: u64 = stages.iter().map(|s| s.graph.total_macs()).sum();
        assert_eq!(macs, g.total_macs());
    }

    #[test]
    fn residual_crossing_cut_rejected() {
        let g = models::resnet34();
        let mid = g.nodes.iter().find(|n| n.name == "s0b0.conv2").unwrap().id;
        assert!(split_stages(&g, &[mid]).is_none());
    }

    #[test]
    fn unsorted_and_out_of_range_cuts_rejected() {
        let g = models::lenet5();
        let cuts = candidate_cuts(&g);
        assert!(split_stages(&g, &[0]).is_none());
        assert!(split_stages(&g, &[g.nodes.len()]).is_none());
        if cuts.len() >= 2 {
            assert!(split_stages(&g, &[cuts[1], cuts[0]]).is_none());
            assert!(split_stages(&g, &[cuts[0], cuts[0]]).is_none());
        }
    }

    #[test]
    fn stage_cost_overlap_model() {
        let link = Link::default();
        let c = StageCost::model(1e-3, 1_000_000, &link);
        assert!(c.transfer_s > 0.0);
        assert_eq!(c.stage_s(), c.compute_s.max(c.transfer_s));
        let slow_link = Link { bandwidth_bytes_per_s: 1e3, latency_s: 0.0 };
        let t = StageCost::model(1e-6, 1_000_000, &slow_link);
        assert_eq!(t.bound(), "transfer");
        assert_eq!(c.bound(), "compute");
    }

    #[test]
    fn partition_pass_records_in_trace() {
        let g = models::lenet5();
        let cuts = candidate_cuts(&g);
        let mut pm = PassManager::new();
        let pipeline = Pipeline::default().graph(PartitionPass { cuts: cuts[..1].to_vec() });
        let out = pm.run_graph_passes(&pipeline, &g);
        assert_eq!(out.nodes.len(), g.nodes.len());
        let rec = &pm.trace.records[0];
        assert_eq!(rec.abbrev, "PT");
        assert_eq!(rec.matched, 1);
        assert!(rec.skipped.is_none());
        assert_eq!(rec.diff.channels_inserted, 1);
        // Degenerate and illegal partitions are recorded as skipped.
        let mut pm2 = PassManager::new();
        let p2 = Pipeline::default().graph(PartitionPass { cuts: vec![] });
        pm2.run_graph_passes(&p2, &g);
        assert!(pm2.trace.records[0].skipped.is_some());
    }
}
