//! Graph-level passes — the Relay-style rewrites (§II-A) hosted on the
//! [`super::GraphPass`] trait so the [`super::PassManager`] can run and
//! trace them. The rewrite machinery itself lives in
//! [`crate::graph::passes`] (BN-fold, pad-fuse, DCE) and
//! [`crate::quant::rewrite`] (quantize/dequantize boundary insertion and
//! folding); these types carry the pattern description, legality
//! precondition and IR-diff accounting.

use crate::graph::{passes, Graph, Op};
use crate::quant::rewrite;
use crate::texpr::Precision;

use super::{Equivalence, GraphPass, PassDiff};

/// Fold inference-mode `conv(bias=false) → BatchNorm` chains into the
/// conv's weights/bias: the BN node disappears from the graph (strictly
/// stronger than schedule-level LF, which keeps the BN arithmetic).
pub struct FoldBatchNorm;

impl GraphPass for FoldBatchNorm {
    fn name(&self) -> &'static str {
        "bn-fold"
    }

    fn abbrev(&self) -> &'static str {
        "BN"
    }

    fn description(&self) -> &'static str {
        "fold BatchNorm after a bias-less conv into the conv's weights/bias"
    }

    fn equivalence(&self) -> Equivalence {
        // Folding γ/β into conv weights re-rounds every product — results
        // track the unfolded graph only within float tolerance.
        Equivalence::FloatTolerant
    }

    fn run(&self, graph: &Graph, diff: &mut PassDiff) -> (Graph, usize) {
        let (g, stats) = passes::fold_batchnorm(graph);
        diff.nodes_removed += stats.removed;
        diff.nodes_rewritten += stats.rewritten;
        (g, stats.removed)
    }
}

/// Merge standalone padding `Transform` nodes into the consuming conv.
pub struct FusePad;

impl GraphPass for FusePad {
    fn name(&self) -> &'static str {
        "pad-fuse"
    }

    fn abbrev(&self) -> &'static str {
        "PF"
    }

    fn description(&self) -> &'static str {
        "merge explicit padding Transform nodes into the consuming conv"
    }

    fn run(&self, graph: &Graph, diff: &mut PassDiff) -> (Graph, usize) {
        let (g, stats) = passes::fuse_pad(graph);
        diff.nodes_removed += stats.removed;
        (g, stats.removed)
    }
}

/// Remove nodes that cannot reach the graph output.
pub struct EliminateDead;

impl GraphPass for EliminateDead {
    fn name(&self) -> &'static str {
        "dce"
    }

    fn abbrev(&self) -> &'static str {
        "DCE"
    }

    fn description(&self) -> &'static str {
        "drop nodes that cannot reach the output"
    }

    fn run(&self, graph: &Graph, diff: &mut PassDiff) -> (Graph, usize) {
        let (g, stats) = passes::eliminate_dead(graph);
        diff.nodes_removed += stats.removed;
        (g, stats.removed)
    }
}

/// Make quantization explicit: wrap grid-capable regions in
/// `Quantize → … → Dequantize` boundaries and fold interior dq/q pairs so
/// chained compute stays on the integer grid
/// ([`crate::quant::rewrite::insert_qdq`]). BN must already be folded
/// (the precondition) so boundaries never straddle a BatchNorm.
pub struct InsertQdq {
    pub precision: Precision,
}

impl InsertQdq {
    pub fn new(precision: Precision) -> InsertQdq {
        InsertQdq { precision }
    }
}

impl GraphPass for InsertQdq {
    fn name(&self) -> &'static str {
        "insert-qdq"
    }

    fn abbrev(&self) -> &'static str {
        "QDQ"
    }

    fn description(&self) -> &'static str {
        "insert quantize/dequantize boundaries and fold them across compute chains"
    }

    fn equivalence(&self) -> Equivalence {
        Equivalence::GridExact
    }

    fn precondition(&self, graph: &Graph) -> Result<(), String> {
        // Foldable BNs must be gone first (run `bn-fold` ahead of this
        // pass): a Q/DQ boundary straddling a BN would quantize the
        // pre-normalization range and miscalibrate the grid.
        let has_foldable_bn = graph.topo().any(|n| {
            matches!(n.op, Op::BatchNorm)
                && matches!(
                    graph.nodes[n.inputs[0]].op,
                    Op::Conv2d { bias: false, .. } | Op::DepthwiseConv2d { bias: false, .. }
                )
        });
        if has_foldable_bn {
            Err("graph still contains foldable BatchNorm nodes — run bn-fold first".to_string())
        } else {
            Ok(())
        }
    }

    fn run(&self, graph: &Graph, diff: &mut PassDiff) -> (Graph, usize) {
        let matched = graph.topo().filter(|n| rewrite::grid_capable(&n.op)).count();
        let (g, stats) = rewrite::insert_qdq(graph, self.precision);
        diff.nodes_inserted += stats.quantize_nodes + stats.dequantize_nodes;
        diff.quantize_nodes += stats.quantize_nodes;
        diff.dequantize_nodes += stats.dequantize_nodes;
        diff.pairs_folded += stats.folded_pairs;
        (g, matched)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::pass::{PassManager, Pipeline};

    #[test]
    fn graph_pipeline_matches_standard_pipeline() {
        let g = models::mobilenet_v1();
        let pipeline = Pipeline::default().graph(FoldBatchNorm).graph(FusePad).graph(EliminateDead);
        let mut pm = PassManager::new();
        let via_manager = pm.run_graph_passes(&pipeline, &g);
        let (via_fn, stats) = passes::standard_pipeline(&g);
        assert_eq!(via_manager.nodes.len(), via_fn.nodes.len());
        assert_eq!(via_manager.total_macs(), via_fn.total_macs());
        let removed: usize = pm.trace.records.iter().map(|r| r.diff.nodes_removed).sum();
        assert_eq!(removed, stats.removed);
        assert_eq!(pm.trace.records.len(), 3);
        assert!(pm.trace.records.iter().all(|r| r.skipped.is_none()));
    }

    #[test]
    fn qdq_precondition_blocks_unfolded_bn() {
        let g = models::mobilenet_v1(); // full of foldable BNs
        let pass = InsertQdq::new(Precision::Int8);
        assert!(pass.precondition(&g).is_err());
        let (folded, _) = passes::standard_pipeline(&g);
        assert!(pass.precondition(&folded).is_ok());
    }

    #[test]
    fn qdq_pass_reports_boundary_diff() {
        let g = models::lenet5();
        let pipeline = Pipeline::default().graph(InsertQdq::new(Precision::Int8));
        let mut pm = PassManager::new();
        let g2 = pm.run_graph_passes(&pipeline, &g);
        g2.validate().unwrap();
        let rec = &pm.trace.records[0];
        assert_eq!(rec.skipped, None);
        assert_eq!((rec.diff.quantize_nodes, rec.diff.dequantize_nodes), (1, 1));
        assert!(rec.diff.pairs_folded >= 3);
        assert!(rec.matched > 0);
    }
}
