//! Unified optimization-pass infrastructure.
//!
//! The paper's contribution is an *automated sequence* of optimizations
//! (Table I) applied to TVM-generated kernels. This module makes that
//! sequence a first-class, inspectable object instead of hard-coded
//! branching: every optimization is a [`GraphPass`] (rewrites the
//! [`crate::graph::Graph`]) or a [`SchedulePass`] (rewrites the per-kernel
//! [`crate::texpr::LoopNest`]s inside a [`KernelProgram`]), a
//! [`Pipeline`] is an ordered pass list, and the [`PassManager`] runs it
//! while recording a [`PassTrace`] — for every pass: what its pattern
//! matched, what it changed (IR-diff statistics: loops unrolled/tiled,
//! epilogues fused, channels inserted, accesses reclassified, …) and, when
//! it did not run, which legality rule or mode restriction blocked it.
//!
//! * [`graph`] hosts the graph-level passes (BN-fold, pad-fuse, DCE and
//!   the quantize/dequantize boundary insertion+folding chain).
//! * [`schedule`] hosts one pass per Table I entry — PK, LU, LT, LF, CW,
//!   OF, CH, AR, CE — plus the Q/VT/SP extensions, and the neutral
//!   [`schedule::lower_to_kernels`] builder they all start from.
//!
//! [`crate::flow::OptConfig`] is the thin builder that selects passes into
//! a pipeline ([`crate::flow::OptConfig::schedule_pipeline`]);
//! [`crate::flow::CompileSession`] runs the manager and carries the trace
//! onto the finished [`crate::flow::Accelerator`], where `report_json`
//! emits it as the `pass_trace` section and `fpga-flow explain` renders it.

pub mod graph;
pub mod partition;
pub mod schedule;

pub use self::graph::{EliminateDead, FoldBatchNorm, FusePad, InsertQdq};
pub use self::partition::{candidate_cuts, split_stages, PartitionPass, StageCost, StageGraph};
pub use self::schedule::{
    lower_to_kernels, AutorunKernels, CachedWrites, Channelize, ConcurrentQueues, FloatOpts,
    FuseEpilogues, ParameterizeKernels, QuantizeDatapath, SparsifyWeights, TileLoops, UnrollLoops,
    VectorizeLoads,
};

use crate::codegen::KernelProgram;
use crate::flow::patterns::FactorPlan;
use crate::flow::Mode;
use crate::graph::Graph;
use crate::schedule::OptKind;

/// Which IR a pass rewrites.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PassLevel {
    /// Rewrites the CNN graph (Relay-analog IR, §II-A).
    Graph,
    /// Rewrites per-kernel loop nests inside the kernel program (§IV).
    Schedule,
}

impl PassLevel {
    pub fn name(&self) -> &'static str {
        match self {
            PassLevel::Graph => "graph",
            PassLevel::Schedule => "schedule",
        }
    }
}

/// The semantics-preservation obligation a pass carries — what the
/// differential verifier (`crate::verify`) may assume survived the pass.
/// Ordered by the numeric drift the obligation permits (none → float
/// reassociation), so a trace's overall obligation is the `max` over its
/// applied passes and [`FloatTolerant`](Equivalence::FloatTolerant) — the
/// only variant that licenses drift — dominates.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum Equivalence {
    /// The pass must not change computed values at all (structural
    /// rewrites: LF, PK, LU, LT, CW, CH, AR, CE, DCE, pad-fuse).
    #[default]
    BitExact,
    /// The pass only rewrites modeled costs (traffic, LSU patterns,
    /// density bookkeeping); computed values stay bit-identical (VT, SP).
    CostModelOnly,
    /// Values move onto/off a fixed-point grid; agreement is exact *on the
    /// grid semantics* (Q datapath narrowing, quantize/dequantize
    /// boundary insertion).
    GridExact,
    /// Floating-point contraction/reassociation is permitted (OF
    /// `-fp-relaxed`, BN folding into conv weights) — agreement within a
    /// documented tolerance.
    FloatTolerant,
}

impl Equivalence {
    pub fn name(&self) -> &'static str {
        match self {
            Equivalence::BitExact => "bit-exact",
            Equivalence::GridExact => "grid-exact",
            Equivalence::FloatTolerant => "float-tolerant",
            Equivalence::CostModelOnly => "cost-model-only",
        }
    }
}

/// IR-diff statistics of one pass application — what actually changed.
/// Counters a pass does not touch stay zero; [`PassDiff::entries`] lists
/// only the non-zero ones for reports.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PassDiff {
    /// Graph nodes removed (BN-fold, DCE, pad-fuse).
    pub nodes_removed: usize,
    /// Graph nodes rewritten in place (conv gaining the folded BN bias).
    pub nodes_rewritten: usize,
    /// Graph nodes inserted (quantize/dequantize boundaries).
    pub nodes_inserted: usize,
    /// Quantize boundaries inserted (f32 → grid).
    pub quantize_nodes: usize,
    /// Dequantize boundaries inserted (grid → f32).
    pub dequantize_nodes: usize,
    /// dq/q pairs folded away across quantized→quantized edges.
    pub pairs_folded: usize,
    /// Epilogue loops fused into their producer's reduction (LF).
    pub epilogues_fused: usize,
    /// Kernels merged into a parameterized group kernel (PK).
    pub kernels_merged: usize,
    /// Kernels whose datapath/flags were rewritten (OF, Q, SP).
    pub kernels_rescheduled: usize,
    /// Loops fully unrolled (LU).
    pub loops_unrolled: usize,
    /// Loops strip-mined with an unrolled inner tile (LT).
    pub loops_tiled: usize,
    /// Loops made runtime-dynamic for parameterized kernels (PK).
    pub loops_parameterized: usize,
    /// Accesses whose direction/pattern changed (CW rmw→write, VT
    /// strided→consecutive).
    pub accesses_reclassified: usize,
    /// Accesses moved off global memory (BRAM stashes, channels).
    pub accesses_cached: usize,
    /// Kernel-to-kernel FIFO channels inserted (CH).
    pub channels_inserted: usize,
    /// Kernels marked autorun (AR).
    pub autorun_marked: usize,
    /// Host command queues created (CE).
    pub queues_created: usize,
}

impl PassDiff {
    pub fn is_empty(&self) -> bool {
        *self == PassDiff::default()
    }

    /// Non-zero counters as (name, value) pairs, in declaration order.
    pub fn entries(&self) -> Vec<(&'static str, usize)> {
        let all = [
            ("nodes_removed", self.nodes_removed),
            ("nodes_rewritten", self.nodes_rewritten),
            ("nodes_inserted", self.nodes_inserted),
            ("quantize_nodes", self.quantize_nodes),
            ("dequantize_nodes", self.dequantize_nodes),
            ("pairs_folded", self.pairs_folded),
            ("epilogues_fused", self.epilogues_fused),
            ("kernels_merged", self.kernels_merged),
            ("kernels_rescheduled", self.kernels_rescheduled),
            ("loops_unrolled", self.loops_unrolled),
            ("loops_tiled", self.loops_tiled),
            ("loops_parameterized", self.loops_parameterized),
            ("accesses_reclassified", self.accesses_reclassified),
            ("accesses_cached", self.accesses_cached),
            ("channels_inserted", self.channels_inserted),
            ("autorun_marked", self.autorun_marked),
            ("queues_created", self.queues_created),
        ];
        all.into_iter().filter(|&(_, v)| v > 0).collect()
    }

    /// Human-readable one-line summary of the non-zero counters.
    pub fn summary(&self) -> String {
        let e = self.entries();
        if e.is_empty() {
            "no changes".to_string()
        } else {
            e.iter().map(|(k, v)| format!("{k}={v}")).collect::<Vec<_>>().join(" ")
        }
    }
}

/// One pass application (or skip) recorded by the [`PassManager`].
#[derive(Debug, Clone)]
pub struct PassRecord {
    pub name: String,
    /// Table I abbreviation (LU, LT, …) or a short tag for graph passes.
    pub abbrev: &'static str,
    pub level: PassLevel,
    /// Kernels / nodes the pass's applicability pattern matched.
    pub matched: usize,
    /// `Some(reason)` when the pass did not run; the reason names the
    /// blocking legality rule or mode restriction.
    pub skipped: Option<String>,
    pub diff: PassDiff,
    /// The equivalence obligation the pass declared (recorded even for
    /// skipped passes; a skipped pass contributes nothing to the trace's
    /// overall obligation).
    pub equivalence: Equivalence,
}

/// Ordered record of every pass the manager ran (or skipped) for one
/// compilation — the report-visible artifact behind `fpga-flow explain`
/// and the `pass_trace` section of `report_json`.
#[derive(Debug, Clone, Default)]
pub struct PassTrace {
    pub records: Vec<PassRecord>,
}

impl PassTrace {
    /// Passes that ran.
    pub fn applied(&self) -> usize {
        self.records.iter().filter(|r| r.skipped.is_none()).count()
    }

    /// Passes blocked by a precondition.
    pub fn skipped(&self) -> usize {
        self.records.len() - self.applied()
    }

    /// The strongest tolerance the *applied* passes are allowed to need —
    /// what the differential verifier must budget for when comparing the
    /// compiled program against the reference executor. An empty (or
    /// all-skipped) trace demands bit-exactness.
    pub fn required_equivalence(&self) -> Equivalence {
        self.records
            .iter()
            .filter(|r| r.skipped.is_none())
            .map(|r| r.equivalence)
            .max()
            .unwrap_or(Equivalence::BitExact)
    }

    /// Render the ordered trace for terminal output.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:>2}  {:<4} {:<22} {:<8} {:>7}  {:<15} result\n",
            "#", "abbr", "pass", "level", "matched", "preserves"
        ));
        for (i, r) in self.records.iter().enumerate() {
            let result = match &r.skipped {
                Some(reason) => format!("skipped: {reason}"),
                None => r.diff.summary(),
            };
            out.push_str(&format!(
                "{:>2}  {:<4} {:<22} {:<8} {:>7}  {:<15} {}\n",
                i + 1,
                r.abbrev,
                r.name,
                r.level.name(),
                if r.skipped.is_some() { "-".to_string() } else { r.matched.to_string() },
                r.equivalence.name(),
                result
            ));
        }
        out
    }
}

/// A graph-level rewrite (Relay-analog, §II-A): consumes a [`Graph`] and
/// produces a rewritten one, reporting what it matched and changed.
pub trait GraphPass {
    fn name(&self) -> &'static str;
    /// Short tag shown in traces (graph passes have no Table I column).
    fn abbrev(&self) -> &'static str;
    fn description(&self) -> &'static str;
    /// Legality precondition; `Err(reason)` records the pass as skipped.
    fn precondition(&self, graph: &Graph) -> Result<(), String> {
        let _ = graph;
        Ok(())
    }
    /// The semantics-preservation obligation this pass carries (checked by
    /// the `crate::verify` differential harness). Defaults to bit-exact —
    /// a pass that reorders floats or moves values onto a grid must say so.
    fn equivalence(&self) -> Equivalence {
        Equivalence::BitExact
    }
    /// Apply the rewrite. Returns the new graph and the number of nodes
    /// the pass's pattern matched; IR-diff counters go into `diff`.
    fn run(&self, graph: &Graph, diff: &mut PassDiff) -> (Graph, usize);
}

/// Everything a schedule-level pass may consult while rewriting a program.
pub struct ScheduleCtx<'a> {
    /// The (possibly graph-pass-rewritten) source graph the program was
    /// lowered from — passes match on node ops and wire channels from it.
    pub graph: &'a Graph,
    /// Unroll/tile factor plan (defaults or a DSE point).
    pub plan: &'a FactorPlan,
    /// Execution mode (§III) — several Table I rows are mode-restricted.
    pub mode: Mode,
}

/// A schedule-level transform (§IV): rewrites kernels' loop nests, the
/// channel graph, or the program's host-queue structure in place.
pub trait SchedulePass {
    fn name(&self) -> &'static str;
    /// Table I abbreviation (LU, LT, LF, CW, OF, CH, AR, CE, PK) or the
    /// extension tags (Q, VT, SP).
    fn abbrev(&self) -> &'static str;
    /// The [`OptKind`] this pass records on kernels it rewrites.
    fn opt_kind(&self) -> Option<OptKind>;
    fn description(&self) -> &'static str;
    /// Legality precondition (mode availability, §IV-J domains);
    /// `Err(reason)` records the pass as skipped with that reason.
    fn precondition(&self, ctx: &ScheduleCtx) -> Result<(), String> {
        let _ = ctx;
        Ok(())
    }
    /// The semantics-preservation obligation this pass carries (checked by
    /// the `crate::verify` differential harness). Defaults to bit-exact.
    fn equivalence(&self) -> Equivalence {
        Equivalence::BitExact
    }
    /// Apply the transform. Returns the number of kernels the pass's
    /// applicability pattern matched; IR-diff counters go into `diff`.
    fn run(&self, ctx: &ScheduleCtx, prog: &mut KernelProgram, diff: &mut PassDiff) -> usize;
}

/// A declarative, ordered pass list — what [`crate::flow::OptConfig`]
/// builds and the [`PassManager`] executes.
#[derive(Default)]
pub struct Pipeline {
    pub graph_passes: Vec<Box<dyn GraphPass>>,
    pub schedule_passes: Vec<Box<dyn SchedulePass>>,
}

impl Pipeline {
    /// Append a graph-level pass.
    pub fn graph(mut self, pass: impl GraphPass + 'static) -> Self {
        self.graph_passes.push(Box::new(pass));
        self
    }

    /// Append a schedule-level pass.
    pub fn schedule(mut self, pass: impl SchedulePass + 'static) -> Self {
        self.schedule_passes.push(Box::new(pass));
        self
    }

    pub fn len(&self) -> usize {
        self.graph_passes.len() + self.schedule_passes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl std::fmt::Debug for Pipeline {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let g: Vec<&str> = self.graph_passes.iter().map(|p| p.name()).collect();
        let s: Vec<&str> = self.schedule_passes.iter().map(|p| p.name()).collect();
        f.debug_struct("Pipeline").field("graph", &g).field("schedule", &s).finish()
    }
}

/// Executes [`Pipeline`]s, checking each pass's precondition and recording
/// a [`PassRecord`] per pass (applied or skipped) into its [`PassTrace`].
#[derive(Debug, Default)]
pub struct PassManager {
    pub trace: PassTrace,
}

impl PassManager {
    pub fn new() -> PassManager {
        PassManager::default()
    }

    /// Run the pipeline's graph passes in order, threading the graph
    /// through each. Passes whose precondition fails are recorded as
    /// skipped and leave the graph untouched.
    pub fn run_graph_passes(&mut self, pipeline: &Pipeline, graph: &Graph) -> Graph {
        let mut g = graph.clone();
        for pass in &pipeline.graph_passes {
            let mut span = crate::obs::span("pass", pass.name());
            span.set_arg("level", "graph");
            let mut rec = PassRecord {
                name: pass.name().to_string(),
                abbrev: pass.abbrev(),
                level: PassLevel::Graph,
                matched: 0,
                skipped: None,
                diff: PassDiff::default(),
                equivalence: pass.equivalence(),
            };
            match pass.precondition(&g) {
                Err(reason) => rec.skipped = Some(reason),
                Ok(()) => {
                    let mut diff = PassDiff::default();
                    let (next, matched) = pass.run(&g, &mut diff);
                    rec.matched = matched;
                    rec.diff = diff;
                    g = next;
                }
            }
            Self::observe(&mut span, &rec);
            self.trace.records.push(rec);
        }
        g
    }

    /// Run the pipeline's schedule passes in order over `prog`.
    pub fn run_schedule_passes(
        &mut self,
        pipeline: &Pipeline,
        ctx: &ScheduleCtx,
        prog: &mut KernelProgram,
    ) {
        for pass in &pipeline.schedule_passes {
            let mut span = crate::obs::span("pass", pass.name());
            span.set_arg("level", "schedule");
            let mut rec = PassRecord {
                name: pass.name().to_string(),
                abbrev: pass.abbrev(),
                level: PassLevel::Schedule,
                matched: 0,
                skipped: None,
                diff: PassDiff::default(),
                equivalence: pass.equivalence(),
            };
            match pass.precondition(ctx) {
                Err(reason) => rec.skipped = Some(reason),
                Ok(()) => {
                    let mut diff = PassDiff::default();
                    rec.matched = pass.run(ctx, prog, &mut diff);
                    rec.diff = diff;
                }
            }
            Self::observe(&mut span, &rec);
            self.trace.records.push(rec);
        }
    }

    /// Stamp a finished pass record onto its span and bump the pass
    /// counters. Every call site already opened the span, so the
    /// disabled-mode cost is the guard's single flag check.
    fn observe(span: &mut crate::obs::Span, rec: &PassRecord) {
        if !crate::obs::enabled() {
            return;
        }
        span.set_arg("matched", rec.matched);
        let m = crate::obs::global_metrics();
        match &rec.skipped {
            Some(reason) => {
                span.set_arg("skipped", reason.as_str());
                m.counter("flow_passes_skipped_total", "passes skipped by precondition").inc();
            }
            None => {
                m.counter("flow_passes_applied_total", "passes executed by the PassManager").inc();
            }
        }
    }

    /// Consume the manager, yielding the accumulated trace.
    pub fn into_trace(self) -> PassTrace {
        self.trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::patterns::{default_factors, OptConfig};
    use crate::graph::models;

    #[test]
    fn diff_summary_lists_only_nonzero() {
        let d = PassDiff { loops_unrolled: 3, channels_inserted: 2, ..PassDiff::default() };
        let s = d.summary();
        assert!(s.contains("loops_unrolled=3"));
        assert!(s.contains("channels_inserted=2"));
        assert!(!s.contains("nodes_removed"));
        assert!(PassDiff::default().is_empty());
        assert_eq!(PassDiff::default().summary(), "no changes");
    }

    #[test]
    fn optimized_pipeline_names_every_table1_pass() {
        let p = OptConfig::optimized().schedule_pipeline();
        let abbrevs: Vec<&str> = p.schedule_passes.iter().map(|s| s.abbrev()).collect();
        for want in ["LF", "OF", "PK", "LT", "LU", "CW", "CH", "AR", "CE"] {
            assert!(abbrevs.contains(&want), "{want} missing from {abbrevs:?}");
        }
        // Extensions are opt-in and absent from the paper's default set.
        for absent in ["Q", "VT", "SP"] {
            assert!(!abbrevs.contains(&absent), "{absent} unexpectedly in {abbrevs:?}");
        }
    }

    #[test]
    fn folded_trace_skips_pipelined_only_passes_with_reasons() {
        let g = models::mobilenet_v1();
        let plan = default_factors(&g);
        let built = crate::flow::patterns::build_with_passes(
            &g,
            Mode::Folded,
            &OptConfig::optimized(),
            &plan,
        );
        let by_abbrev = |a: &str| {
            built
                .trace
                .records
                .iter()
                .find(|r| r.abbrev == a)
                .unwrap_or_else(|| panic!("{a} missing from trace"))
        };
        for a in ["CH", "AR", "CE"] {
            let r = by_abbrev(a);
            assert!(r.skipped.is_some(), "{a} should be skipped in folded mode");
            let reason = r.skipped.as_ref().unwrap();
            assert!(reason.contains("folded"), "{a} reason should name the mode rule: {reason}");
        }
        for a in ["PK", "LT", "LU", "LF", "CW", "OF"] {
            assert!(by_abbrev(a).skipped.is_none(), "{a} should run in folded mode");
        }
        let pk = by_abbrev("PK");
        assert!(pk.diff.kernels_merged > 0, "{:?}", pk.diff);
        assert!(pk.diff.loops_parameterized > 0);
    }

    #[test]
    fn pipelined_trace_skips_folded_only_passes() {
        let g = models::lenet5();
        let plan = default_factors(&g);
        let built = crate::flow::patterns::build_with_passes(
            &g,
            Mode::Pipelined,
            &OptConfig::optimized(),
            &plan,
        );
        let skipped: Vec<&str> = built
            .trace
            .records
            .iter()
            .filter(|r| r.skipped.is_some())
            .map(|r| r.abbrev)
            .collect();
        assert!(skipped.contains(&"PK"), "{skipped:?}");
        assert!(skipped.contains(&"LT"), "{skipped:?}");
        let ch = built.trace.records.iter().find(|r| r.abbrev == "CH").unwrap();
        assert_eq!(ch.skipped, None);
        assert_eq!(ch.diff.channels_inserted, 6);
        let render = built.trace.render();
        assert!(render.contains("LF"));
        assert!(render.contains("skipped:"));
    }

    #[test]
    fn trace_equivalence_is_max_over_applied_passes() {
        let g = models::lenet5();
        let plan = default_factors(&g);
        // Base pipeline: nothing applied → bit-exact by definition.
        let base =
            crate::flow::patterns::build_with_passes(&g, Mode::Pipelined, &OptConfig::base(), &plan);
        assert_eq!(base.trace.required_equivalence(), Equivalence::BitExact);
        // OF is in the optimized set → float reassociation allowed.
        let opt = crate::flow::patterns::build_with_passes(
            &g,
            Mode::Pipelined,
            &OptConfig::optimized(),
            &plan,
        );
        assert_eq!(opt.trace.required_equivalence(), Equivalence::FloatTolerant);
        // Dropping OF leaves only structural (bit-exact) passes applied.
        let cfg = OptConfig::optimized().without(crate::schedule::OptKind::FloatOpt);
        let strict = crate::flow::patterns::build_with_passes(&g, Mode::Pipelined, &cfg, &plan);
        assert_eq!(strict.trace.required_equivalence(), Equivalence::BitExact);
        // VT makes no value claim at all — the weakest obligation wins.
        let vt = cfg.with_vectors();
        let cost = crate::flow::patterns::build_with_passes(&g, Mode::Pipelined, &vt, &plan);
        assert_eq!(cost.trace.required_equivalence(), Equivalence::CostModelOnly);
        // The rendered trace names each pass's obligation.
        assert!(opt.trace.render().contains("float-tolerant"));
    }

    #[test]
    fn base_pipeline_is_empty() {
        let p = OptConfig::base().schedule_pipeline();
        assert!(p.is_empty());
        assert_eq!(format!("{:?}", p), "Pipeline { graph: [], schedule: [] }");
    }
}
