//! Shape, FLOP, parameter and activation-size inference per node.
//!
//! FLOP counting follows the paper's §V-C convention: GFLOPS is computed
//! from FPS × "the number of floating point operations performed by the
//! networks", with a multiply-accumulate counted as 2 FP operations.


use super::ops::{Activation, Op};

/// Feature-map shape (batch excluded; the graph is per-frame).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum Shape {
    /// Channels × height × width.
    Chw(usize, usize, usize),
    /// Flat feature vector.
    Flat(usize),
}

impl Shape {
    pub fn elems(&self) -> usize {
        match *self {
            Shape::Chw(c, h, w) => c * h * w,
            Shape::Flat(n) => n,
        }
    }

    pub fn bytes(&self) -> usize {
        self.elems() * 4 // fp32 everywhere, as in the paper (§V-A)
    }

    pub fn chw(&self) -> Option<(usize, usize, usize)> {
        match *self {
            Shape::Chw(c, h, w) => Some((c, h, w)),
            Shape::Flat(_) => None,
        }
    }
}

impl std::fmt::Display for Shape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            Shape::Chw(c, h, w) => write!(f, "{c}x{h}x{w}"),
            Shape::Flat(n) => write!(f, "{n}"),
        }
    }
}

/// Static per-node cost summary.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct NodeCost {
    /// Multiply-accumulates.
    pub macs: u64,
    /// Total FLOPs (2 per MAC + elementwise work).
    pub flops: u64,
    /// Trainable parameter count.
    pub params: u64,
    /// Output feature-map bytes.
    pub out_bytes: u64,
}

fn conv_out(h: usize, k: usize, s: usize, p: usize) -> usize {
    (h + 2 * p - k) / s + 1
}

/// Infer the output shape of `op` applied to `inputs` (first input is the
/// data path; residual `Add` takes two).
pub fn infer_shape(op: &Op, inputs: &[&Shape]) -> Result<Shape, String> {
    let first = *inputs.first().ok_or("op has no inputs")?;
    match op {
        Op::Input => Ok(first.clone()),
        Op::Conv2d { out_channels, kernel, stride, padding, .. } => {
            let (_, h, w) = first.chw().ok_or("conv2d needs CHW input")?;
            if h + 2 * padding < *kernel {
                return Err(format!("conv2d kernel {kernel} larger than padded input {h}"));
            }
            Ok(Shape::Chw(*out_channels, conv_out(h, *kernel, *stride, *padding), conv_out(w, *kernel, *stride, *padding)))
        }
        Op::DepthwiseConv2d { kernel, stride, padding, .. } => {
            let (c, h, w) = first.chw().ok_or("dwconv needs CHW input")?;
            Ok(Shape::Chw(c, conv_out(h, *kernel, *stride, *padding), conv_out(w, *kernel, *stride, *padding)))
        }
        Op::Dense { out_features, .. } => match first {
            Shape::Flat(_) => Ok(Shape::Flat(*out_features)),
            Shape::Chw(..) => Err("dense needs flat input (insert Flatten)".into()),
        },
        Op::BatchNorm | Op::Activate(_) | Op::Transform => Ok(first.clone()),
        Op::MaxPool { kernel, stride, padding } | Op::AvgPool { kernel, stride, padding } => {
            let (c, h, w) = first.chw().ok_or("pool needs CHW input")?;
            Ok(Shape::Chw(c, conv_out(h, *kernel, *stride, *padding), conv_out(w, *kernel, *stride, *padding)))
        }
        Op::GlobalAvgPool => {
            let (c, _, _) = first.chw().ok_or("gap needs CHW input")?;
            Ok(Shape::Flat(c))
        }
        Op::Add => {
            if inputs.len() != 2 {
                return Err("add needs exactly two inputs".into());
            }
            if inputs[0] != inputs[1] {
                return Err(format!("add shape mismatch: {} vs {}", inputs[0], inputs[1]));
            }
            Ok(first.clone())
        }
        Op::Flatten => Ok(Shape::Flat(first.elems())),
        Op::Softmax => Ok(first.clone()),
        // Grid boundaries change the element type, not the shape.
        Op::Quantize { .. } | Op::Dequantize { .. } => Ok(first.clone()),
    }
}

/// Compute static costs for `op` given its input and inferred output shape.
pub fn node_cost(op: &Op, input: &Shape, output: &Shape) -> NodeCost {
    let out_elems = output.elems() as u64;
    let act_flops = |a: &Activation| a.flops_per_elem() * out_elems;
    let (macs, mut flops, params) = match op {
        Op::Conv2d { out_channels, kernel, bias, activation, .. } => {
            let (cin, _, _) = input.chw().expect("checked in infer_shape");
            let k2 = (kernel * kernel) as u64;
            let macs = out_elems * cin as u64 * k2;
            let mut flops = 2 * macs + act_flops(activation);
            let mut params = *out_channels as u64 * cin as u64 * k2;
            if *bias {
                params += *out_channels as u64;
                flops += out_elems;
            }
            (macs, flops, params)
        }
        Op::DepthwiseConv2d { kernel, bias, activation, .. } => {
            let (c, _, _) = input.chw().expect("checked");
            let k2 = (kernel * kernel) as u64;
            let macs = out_elems * k2;
            let mut flops = 2 * macs + act_flops(activation);
            let mut params = c as u64 * k2;
            if *bias {
                params += c as u64;
                flops += out_elems;
            }
            (macs, flops, params)
        }
        Op::Dense { out_features, bias, activation } => {
            let cin = input.elems() as u64;
            let macs = cin * *out_features as u64;
            let mut flops = 2 * macs + act_flops(activation);
            let mut params = cin * *out_features as u64;
            if *bias {
                params += *out_features as u64;
                flops += out_elems;
            }
            (macs, flops, params)
        }
        Op::BatchNorm => {
            let c = match input {
                Shape::Chw(c, ..) => *c as u64,
                Shape::Flat(n) => *n as u64,
            };
            (0, 2 * out_elems, 4 * c)
        }
        Op::Activate(a) => (0, act_flops(a), 0),
        Op::MaxPool { kernel, .. } => (0, out_elems * ((kernel * kernel - 1) as u64), 0),
        Op::AvgPool { kernel, .. } => (0, out_elems * ((kernel * kernel) as u64), 0),
        Op::GlobalAvgPool => {
            let (_, h, w) = input.chw().expect("checked");
            (0, out_elems * (h * w) as u64, 0)
        }
        Op::Add => (0, out_elems, 0),
        Op::Softmax => (0, 5 * out_elems, 0),
        // One scale (+ round) per element at each grid boundary.
        Op::Quantize { .. } | Op::Dequantize { .. } => (0, out_elems, 0),
        Op::Input | Op::Transform | Op::Flatten => (0, 0, 0),
    };
    if matches!(op, Op::Input) {
        flops = 0;
    }
    NodeCost { macs, flops, params, out_bytes: output.bytes() as u64 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ops::Activation;

    #[test]
    fn conv_shape_and_cost() {
        let op = Op::Conv2d { out_channels: 6, kernel: 5, stride: 1, padding: 0, bias: true, activation: Activation::Tanh };
        let input = Shape::Chw(1, 32, 32);
        let out = infer_shape(&op, &[&input]).unwrap();
        assert_eq!(out, Shape::Chw(6, 28, 28));
        let c = node_cost(&op, &input, &out);
        // LeNet C1: 6·28·28 outputs × 25 taps = 117,600 MACs
        assert_eq!(c.macs, 117_600);
        assert_eq!(c.params, 6 * 25 + 6);
    }

    #[test]
    fn dwconv_costs_scale_with_channels_not_channel_sq() {
        let op = Op::DepthwiseConv2d { kernel: 3, stride: 1, padding: 1, bias: false, activation: Activation::None };
        let input = Shape::Chw(32, 16, 16);
        let out = infer_shape(&op, &[&input]).unwrap();
        assert_eq!(out, Shape::Chw(32, 16, 16));
        let c = node_cost(&op, &input, &out);
        assert_eq!(c.macs, (32 * 16 * 16 * 9) as u64);
    }

    #[test]
    fn add_requires_matching_shapes() {
        let a = Shape::Chw(64, 8, 8);
        let b = Shape::Chw(64, 8, 8);
        assert!(infer_shape(&Op::Add, &[&a, &b]).is_ok());
        let c = Shape::Chw(32, 8, 8);
        assert!(infer_shape(&Op::Add, &[&a, &c]).is_err());
    }

    #[test]
    fn dense_needs_flatten() {
        let op = Op::Dense { out_features: 10, bias: true, activation: Activation::None };
        assert!(infer_shape(&op, &[&Shape::Chw(16, 5, 5)]).is_err());
        assert_eq!(infer_shape(&op, &[&Shape::Flat(400)]).unwrap(), Shape::Flat(10));
    }

    #[test]
    fn pool_window_arithmetic() {
        let op = Op::MaxPool { kernel: 3, stride: 2, padding: 1 };
        let out = infer_shape(&op, &[&Shape::Chw(64, 112, 112)]).unwrap();
        assert_eq!(out, Shape::Chw(64, 56, 56));
    }

    #[test]
    fn conv_too_small_errors() {
        let op = Op::Conv2d { out_channels: 4, kernel: 7, stride: 1, padding: 0, bias: false, activation: Activation::None };
        assert!(infer_shape(&op, &[&Shape::Chw(3, 4, 4)]).is_err());
    }
}
