//! Graph-level optimization passes — the Relay-style rewrites TVM applies
//! before lowering (§II-A: "rules-based transformations such as operator
//! fusion, dead code elimination, and layout changes").
//!
//! * [`fold_batchnorm`] — inference-mode BN after a bias-less conv folds
//!   into the conv's weights/bias: the BN node disappears from the graph
//!   (strictly stronger than the schedule-level LF, which keeps the BN
//!   arithmetic but fuses its loop).
//! * [`eliminate_dead`] — drop nodes that cannot reach the output.
//! * [`fuse_pad`] — explicit `Transform` padding nodes merge into the
//!   consuming conv's padding attribute.

use super::ops::Op;
use super::{Graph, Node, NodeId};

/// Statistics returned by a pass.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PassStats {
    pub removed: usize,
    pub rewritten: usize,
}

/// Fold `conv(bias=false) → BatchNorm` into `conv(bias=true)`.
///
/// Numerically: `bn(conv(x, W)) = conv(x, W·γ/σ) + (β − μγ/σ)` — a conv
/// with scaled weights and a bias. At the graph level the BN node is
/// removed and the conv gains `bias = true` (the weight rewrite itself
/// happens at parameter-load time in a real deployment; costs/shapes here
/// only need the structural change).
pub fn fold_batchnorm(graph: &Graph) -> (Graph, PassStats) {
    let consumers = graph.consumers();
    let mut stats = PassStats::default();
    // BN node id → its producer (conv) id, for BNs we can fold.
    let mut fold: Vec<Option<NodeId>> = vec![None; graph.nodes.len()];
    for n in graph.topo() {
        if let Op::BatchNorm = n.op {
            let p = &graph.nodes[n.inputs[0]];
            let foldable = match p.op {
                Op::Conv2d { bias, .. } | Op::DepthwiseConv2d { bias, .. } => !bias,
                _ => false,
            };
            if foldable && consumers[p.id].len() == 1 {
                fold[n.id] = Some(p.id);
            }
        }
    }

    rebuild(graph, |node, _new_id_of| match &node.op {
        Op::BatchNorm if fold[node.id].is_some() => {
            stats.removed += 1;
            Rewrite::ReplaceWithInput
        }
        Op::Conv2d { out_channels, kernel, stride, padding, bias: false, activation }
            if consumers[node.id].iter().any(|&c| fold[c] == Some(node.id)) =>
        {
            stats.rewritten += 1;
            Rewrite::NewOp(Op::Conv2d {
                out_channels: *out_channels,
                kernel: *kernel,
                stride: *stride,
                padding: *padding,
                bias: true,
                activation: *activation,
            })
        }
        Op::DepthwiseConv2d { kernel, stride, padding, bias: false, activation }
            if consumers[node.id].iter().any(|&c| fold[c] == Some(node.id)) =>
        {
            stats.rewritten += 1;
            Rewrite::NewOp(Op::DepthwiseConv2d {
                kernel: *kernel,
                stride: *stride,
                padding: *padding,
                bias: true,
                activation: *activation,
            })
        }
        _ => Rewrite::Keep,
    })
    .map(|g| (g, stats))
    .expect("fold_batchnorm preserves validity")
}

/// Remove nodes that do not reach the output.
pub fn eliminate_dead(graph: &Graph) -> (Graph, PassStats) {
    let mut live = vec![false; graph.nodes.len()];
    let mut stack = vec![graph.output];
    while let Some(id) = stack.pop() {
        if live[id] {
            continue;
        }
        live[id] = true;
        stack.extend(&graph.nodes[id].inputs);
    }
    let mut stats = PassStats::default();
    let g = rebuild(graph, |node, _| {
        if live[node.id] {
            Rewrite::Keep
        } else {
            stats.removed += 1;
            Rewrite::Drop
        }
    })
    .expect("DCE preserves validity");
    (g, stats)
}

/// Merge a standalone padding `Transform` into the consuming conv. (Our
/// models don't emit standalone pads, but imported graphs may.)
pub fn fuse_pad(graph: &Graph) -> (Graph, PassStats) {
    // Structural no-op placeholder for imported graphs: Transform nodes
    // adjacent to convs are dropped (their cost is zero).
    let consumers = graph.consumers();
    let mut stats = PassStats::default();
    let g = rebuild(graph, |node, _| {
        if matches!(node.op, Op::Transform)
            && consumers[node.id].len() == 1
            && matches!(graph.nodes[consumers[node.id][0]].op, Op::Conv2d { .. } | Op::DepthwiseConv2d { .. })
        {
            stats.removed += 1;
            Rewrite::ReplaceWithInput
        } else {
            Rewrite::Keep
        }
    })
    .expect("fuse_pad preserves validity");
    (g, stats)
}

/// Run the standard pass pipeline.
pub fn standard_pipeline(graph: &Graph) -> (Graph, PassStats) {
    let (g, s1) = fold_batchnorm(graph);
    let (g, s2) = fuse_pad(&g);
    let (g, s3) = eliminate_dead(&g);
    (
        g,
        PassStats {
            removed: s1.removed + s2.removed + s3.removed,
            rewritten: s1.rewritten + s2.rewritten + s3.rewritten,
        },
    )
}

enum Rewrite {
    Keep,
    NewOp(Op),
    /// Remove this node, re-pointing consumers at its first input.
    ReplaceWithInput,
    /// Remove this node entirely (must be dead).
    Drop,
}

/// Rebuild a graph applying per-node rewrites, recomputing ids, shapes and
/// costs. Returns None if the result fails validation.
fn rebuild(graph: &Graph, mut f: impl FnMut(&Node, &[Option<NodeId>]) -> Rewrite) -> Option<Graph> {
    let mut new_id: Vec<Option<NodeId>> = vec![None; graph.nodes.len()];
    let mut builder: Option<super::GraphBuilder> = None;
    for node in graph.topo() {
        match f(node, &new_id) {
            Rewrite::Drop => continue,
            Rewrite::ReplaceWithInput => {
                let src = node.inputs[0];
                new_id[node.id] = new_id[src];
            }
            rewrite => {
                let op = match rewrite {
                    Rewrite::NewOp(op) => op,
                    _ => node.op.clone(),
                };
                if matches!(node.op, Op::Input) {
                    let (b, id) = super::GraphBuilder::new(graph.name.clone(), node.shape.clone());
                    builder = Some(b);
                    new_id[node.id] = Some(id);
                } else {
                    let b = builder.as_mut()?;
                    let inputs: Vec<NodeId> =
                        node.inputs.iter().map(|&i| new_id[i].expect("topo order")).collect();
                    let id = b.add(node.name.clone(), op, &inputs);
                    new_id[node.id] = Some(id);
                }
            }
        }
    }
    let out = new_id[graph.output]?;
    let g = builder?.finish(out);
    g.validate().ok()?;
    Some(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    #[test]
    fn fold_bn_removes_all_mobilenet_bns() {
        let g = models::mobilenet_v1();
        let bns_before = g.nodes.iter().filter(|n| matches!(n.op, Op::BatchNorm)).count();
        assert_eq!(bns_before, 27);
        let (g2, stats) = fold_batchnorm(&g);
        assert_eq!(stats.removed, 27);
        assert_eq!(stats.rewritten, 27);
        assert_eq!(g2.nodes.iter().filter(|n| matches!(n.op, Op::BatchNorm)).count(), 0);
        // Every conv now carries a bias.
        assert!(g2.nodes.iter().all(|n| match n.op {
            Op::Conv2d { bias, .. } | Op::DepthwiseConv2d { bias, .. } => bias,
            _ => true,
        }));
    }

    #[test]
    fn fold_bn_preserves_macs_and_shapes() {
        let g = models::resnet34();
        let (g2, _) = fold_batchnorm(&g);
        assert_eq!(g.total_macs(), g2.total_macs());
        assert_eq!(g.nodes[g.output].shape, g2.nodes[g2.output].shape);
        g2.validate().unwrap();
    }

    #[test]
    fn folded_resnet_still_compiles() {
        use crate::flow::{Compiler, Mode, OptLevel};
        let (g2, _) = standard_pipeline(&models::resnet34());
        let acc = Compiler::default().compile(&g2, Mode::Folded, OptLevel::Optimized).unwrap();
        assert!(acc.performance.fps > 0.0);
        // Fewer nodes → no BN kernels/work entries at all.
        assert!(!acc.work.iter().any(|w| w.layer_name.contains("bn")));
    }

    #[test]
    fn dce_removes_unreachable() {
        use crate::graph::{Activation, GraphBuilder, Shape};
        let (mut b, x) = GraphBuilder::new("dead", Shape::Chw(1, 8, 8));
        let live = b.add("live", Op::Conv2d { out_channels: 2, kernel: 3, stride: 1, padding: 1, bias: true, activation: Activation::Relu }, &[x]);
        let _dead = b.add("dead", Op::Conv2d { out_channels: 4, kernel: 3, stride: 1, padding: 1, bias: true, activation: Activation::Relu }, &[x]);
        let g = b.finish(live);
        let (g2, stats) = eliminate_dead(&g);
        assert_eq!(stats.removed, 1);
        assert_eq!(g2.nodes.len(), 2);
        g2.validate().unwrap();
    }

    #[test]
    fn lenet_unchanged_by_pipeline() {
        // No BNs, no pads, nothing dead.
        let g = models::lenet5();
        let (g2, stats) = standard_pipeline(&g);
        assert_eq!(stats, PassStats::default());
        assert_eq!(g.nodes.len(), g2.nodes.len());
    }

    #[test]
    fn pass_is_idempotent() {
        let (g1, _) = standard_pipeline(&models::mobilenet_v1());
        let (g2, stats) = standard_pipeline(&g1);
        assert_eq!(stats, PassStats::default());
        assert_eq!(g1.nodes.len(), g2.nodes.len());
    }
}
