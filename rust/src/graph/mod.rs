//! Relay-analog CNN graph IR (§II-A): a DAG of [`ops::Op`] nodes with shape
//! and cost inference, topological iteration, and the three evaluation
//! networks of the paper in [`models`].

pub mod models;
pub mod passes;
pub mod ops;
pub mod shape;


pub use ops::{Activation, GroupKind, Op, ParamGroup};
pub use shape::{NodeCost, Shape};

/// Node identifier (index into `Graph::nodes`).
pub type NodeId = usize;

/// One node of the network graph.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    pub name: String,
    pub op: Op,
    pub inputs: Vec<NodeId>,
    pub shape: Shape,
    pub cost: NodeCost,
}

/// A frozen inference graph (per-frame; batch handled by the runtime).
#[derive(Debug, Clone)]
pub struct Graph {
    pub name: String,
    pub nodes: Vec<Node>,
    pub input: NodeId,
    pub output: NodeId,
}

/// Incremental graph builder: nodes are appended in topological order
/// (inputs must already exist), shapes and costs inferred on insert.
pub struct GraphBuilder {
    name: String,
    nodes: Vec<Node>,
}

impl GraphBuilder {
    pub fn new(name: impl Into<String>, input_shape: Shape) -> (Self, NodeId) {
        let mut b = GraphBuilder { name: name.into(), nodes: Vec::new() };
        let cost = shape::node_cost(&Op::Input, &input_shape, &input_shape);
        b.nodes.push(Node {
            id: 0,
            name: "input".into(),
            op: Op::Input,
            inputs: vec![],
            shape: input_shape,
            cost,
        });
        (b, 0)
    }

    /// Append a node; panics on shape errors (model definitions are static).
    pub fn add(&mut self, name: impl Into<String>, op: Op, inputs: &[NodeId]) -> NodeId {
        let name = name.into();
        let in_shapes: Vec<&Shape> = inputs
            .iter()
            .map(|&i| &self.nodes.get(i).unwrap_or_else(|| panic!("{name}: bad input id {i}")).shape)
            .collect();
        let out = shape::infer_shape(&op, &in_shapes)
            .unwrap_or_else(|e| panic!("{}: shape error: {e}", name));
        let cost = shape::node_cost(&op, in_shapes[0], &out);
        let id = self.nodes.len();
        self.nodes.push(Node { id, name, op, inputs: inputs.to_vec(), shape: out, cost });
        id
    }

    pub fn finish(self, output: NodeId) -> Graph {
        assert!(output < self.nodes.len());
        Graph { name: self.name, nodes: self.nodes, input: 0, output }
    }
}

impl Graph {
    /// Nodes in topological order (construction order is topological).
    pub fn topo(&self) -> impl Iterator<Item = &Node> {
        self.nodes.iter()
    }

    /// Total multiply-accumulates per frame.
    pub fn total_macs(&self) -> u64 {
        self.nodes.iter().map(|n| n.cost.macs).sum()
    }

    /// Total FLOPs per frame (§V-C convention: 2 per MAC + elementwise).
    pub fn total_flops(&self) -> u64 {
        self.nodes.iter().map(|n| n.cost.flops).sum()
    }

    /// Total trainable parameters.
    pub fn total_params(&self) -> u64 {
        self.nodes.iter().map(|n| n.cost.params).sum()
    }

    /// Largest intermediate feature map in bytes — sizes the channel FIFO
    /// depth requirement for pipelined mode (§IV-J: "the depth must be
    /// sufficient to hold the output of the largest feature map").
    pub fn max_activation_bytes(&self) -> u64 {
        self.nodes
            .iter()
            .filter(|n| !matches!(n.op, Op::Input))
            .map(|n| n.cost.out_bytes)
            .max()
            .unwrap_or(0)
    }

    /// Sum of all weight bytes (fp32).
    pub fn weight_bytes(&self) -> u64 {
        self.total_params() * 4
    }

    /// Consumers of each node (fan-out), indexed by NodeId.
    pub fn consumers(&self) -> Vec<Vec<NodeId>> {
        let mut out = vec![Vec::new(); self.nodes.len()];
        for n in &self.nodes {
            for &i in &n.inputs {
                out[i].push(n.id);
            }
        }
        out
    }

    /// FLOPs performed by 3×3 convolutions only — the paper reports
    /// "70.4 GFLOPS for our 3×3 convolutions in ResNet-34" (§V-E).
    pub fn flops_3x3_conv(&self) -> u64 {
        self.nodes
            .iter()
            .filter(|n| matches!(n.op, Op::Conv2d { kernel: 3, .. }))
            .map(|n| n.cost.flops)
            .sum()
    }

    /// Validate structural invariants (acyclic by construction; here:
    /// input reachability, id consistency, single-consumer flatten chain).
    pub fn validate(&self) -> Result<(), String> {
        for (i, n) in self.nodes.iter().enumerate() {
            if n.id != i {
                return Err(format!("node {i} has id {}", n.id));
            }
            for &inp in &n.inputs {
                if inp >= i {
                    return Err(format!("node {} references later node {}", n.name, inp));
                }
            }
            if !matches!(n.op, Op::Input) && n.inputs.is_empty() {
                return Err(format!("node {} has no inputs", n.name));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use ops::Activation;

    fn tiny() -> Graph {
        let (mut b, x) = GraphBuilder::new("tiny", Shape::Chw(1, 8, 8));
        let c = b.add(
            "c1",
            Op::Conv2d { out_channels: 4, kernel: 3, stride: 1, padding: 1, bias: true, activation: Activation::Relu },
            &[x],
        );
        let p = b.add("p1", Op::MaxPool { kernel: 2, stride: 2, padding: 0 }, &[c]);
        let f = b.add("f", Op::Flatten, &[p]);
        let d = b.add("fc", Op::Dense { out_features: 10, bias: true, activation: Activation::None }, &[f]);
        b.finish(d)
    }

    #[test]
    fn builder_infers_shapes() {
        let g = tiny();
        assert_eq!(g.nodes[1].shape, Shape::Chw(4, 8, 8));
        assert_eq!(g.nodes[2].shape, Shape::Chw(4, 4, 4));
        assert_eq!(g.nodes[4].shape, Shape::Flat(10));
        g.validate().unwrap();
    }

    #[test]
    fn totals_accumulate() {
        let g = tiny();
        let conv_macs = 4 * 8 * 8 * 9;
        let fc_macs = 64 * 10;
        assert_eq!(g.total_macs(), (conv_macs + fc_macs) as u64);
        assert!(g.total_flops() > 2 * g.total_macs());
        assert_eq!(g.total_params(), (4 * 9 + 4 + 64 * 10 + 10) as u64);
    }

    #[test]
    fn consumers_fanout() {
        let g = tiny();
        let cons = g.consumers();
        assert_eq!(cons[0], vec![1]);
        assert_eq!(cons[1], vec![2]);
        assert!(cons[4].is_empty());
    }

    #[test]
    fn max_activation_excludes_input() {
        let g = tiny();
        // conv output 4·8·8·4B = 1024B is the largest
        assert_eq!(g.max_activation_bytes(), 1024);
    }

    #[test]
    #[should_panic(expected = "shape error")]
    fn bad_shape_panics() {
        let (mut b, x) = GraphBuilder::new("bad", Shape::Chw(1, 2, 2));
        b.add(
            "c",
            Op::Conv2d { out_channels: 1, kernel: 5, stride: 1, padding: 0, bias: false, activation: Activation::None },
            &[x],
        );
    }
}
