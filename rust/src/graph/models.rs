//! The paper's three evaluation networks (§V-A) as graph IR, mirroring the
//! L2 JAX definitions in `python/compile/model.py` exactly — parameter
//! counts are cross-checked against the python side through
//! `artifacts/manifest.json` in the integration tests.

use super::ops::{Activation, Op};
use super::{Graph, GraphBuilder, Shape};

/// LeNet-5 over 32×32×1 (classic C1..F7; MNIST).
pub fn lenet5() -> Graph {
    let (mut b, x) = GraphBuilder::new("lenet5", Shape::Chw(1, 32, 32));
    let c1 = b.add(
        "c1",
        Op::Conv2d { out_channels: 6, kernel: 5, stride: 1, padding: 0, bias: true, activation: Activation::Tanh },
        &[x],
    );
    let s2 = b.add("s2", Op::AvgPool { kernel: 2, stride: 2, padding: 0 }, &[c1]);
    let c3 = b.add(
        "c3",
        Op::Conv2d { out_channels: 16, kernel: 5, stride: 1, padding: 0, bias: true, activation: Activation::Tanh },
        &[s2],
    );
    let s4 = b.add("s4", Op::AvgPool { kernel: 2, stride: 2, padding: 0 }, &[c3]);
    let fl = b.add("flatten", Op::Flatten, &[s4]);
    let f5 = b.add("f5", Op::Dense { out_features: 120, bias: true, activation: Activation::Tanh }, &[fl]);
    let f6 = b.add("f6", Op::Dense { out_features: 84, bias: true, activation: Activation::Tanh }, &[f5]);
    let f7 = b.add("f7", Op::Dense { out_features: 10, bias: true, activation: Activation::None }, &[f6]);
    b.finish(f7)
}

/// MobileNetV1 block plan: (depthwise stride, pointwise output channels).
pub const MOBILENET_BLOCKS: [(usize, usize); 13] = [
    (1, 64), (2, 128), (1, 128), (2, 256), (1, 256), (2, 512),
    (1, 512), (1, 512), (1, 512), (1, 512), (1, 512),
    (2, 1024), (1, 1024),
];

/// MobileNetV1 (α = 1.0, 224², 1000-class head).
pub fn mobilenet_v1() -> Graph {
    let (mut b, x) = GraphBuilder::new("mobilenet_v1", Shape::Chw(3, 224, 224));
    let mut y = b.add(
        "conv1",
        Op::Conv2d { out_channels: 32, kernel: 3, stride: 2, padding: 1, bias: false, activation: Activation::None },
        &[x],
    );
    y = b.add("conv1.bn", Op::BatchNorm, &[y]);
    y = b.add("conv1.act", Op::Activate(Activation::Relu6), &[y]);
    for (i, (stride, cout)) in MOBILENET_BLOCKS.iter().enumerate() {
        y = b.add(
            format!("b{i}.dw"),
            Op::DepthwiseConv2d { kernel: 3, stride: *stride, padding: 1, bias: false, activation: Activation::None },
            &[y],
        );
        y = b.add(format!("b{i}.dw.bn"), Op::BatchNorm, &[y]);
        y = b.add(format!("b{i}.dw.act"), Op::Activate(Activation::Relu6), &[y]);
        y = b.add(
            format!("b{i}.pw"),
            Op::Conv2d { out_channels: *cout, kernel: 1, stride: 1, padding: 0, bias: false, activation: Activation::None },
            &[y],
        );
        y = b.add(format!("b{i}.pw.bn"), Op::BatchNorm, &[y]);
        y = b.add(format!("b{i}.pw.act"), Op::Activate(Activation::Relu6), &[y]);
    }
    y = b.add("gap", Op::GlobalAvgPool, &[y]);
    y = b.add("fc", Op::Dense { out_features: 1000, bias: true, activation: Activation::None }, &[y]);
    b.finish(y)
}

/// ResNet-34 stage plan: (channels, basic blocks).
pub const RESNET34_STAGES: [(usize, usize); 4] = [(64, 3), (128, 4), (256, 6), (512, 3)];

/// ResNet-34 (224², 1000-class head, basic blocks).
pub fn resnet34() -> Graph {
    let (mut b, x) = GraphBuilder::new("resnet34", Shape::Chw(3, 224, 224));
    let mut y = b.add(
        "conv1",
        Op::Conv2d { out_channels: 64, kernel: 7, stride: 2, padding: 3, bias: false, activation: Activation::None },
        &[x],
    );
    y = b.add("conv1.bn", Op::BatchNorm, &[y]);
    y = b.add("conv1.act", Op::Activate(Activation::Relu), &[y]);
    y = b.add("maxpool", Op::MaxPool { kernel: 3, stride: 2, padding: 1 }, &[y]);
    let mut cin = 64usize;
    for (s, (c, nblocks)) in RESNET34_STAGES.iter().enumerate() {
        for blk in 0..*nblocks {
            let stride = if blk == 0 && s > 0 { 2 } else { 1 };
            let name = format!("s{s}b{blk}");
            let mut z = b.add(
                format!("{name}.conv1"),
                Op::Conv2d { out_channels: *c, kernel: 3, stride, padding: 1, bias: false, activation: Activation::None },
                &[y],
            );
            z = b.add(format!("{name}.bn1"), Op::BatchNorm, &[z]);
            z = b.add(format!("{name}.act1"), Op::Activate(Activation::Relu), &[z]);
            z = b.add(
                format!("{name}.conv2"),
                Op::Conv2d { out_channels: *c, kernel: 3, stride: 1, padding: 1, bias: false, activation: Activation::None },
                &[z],
            );
            z = b.add(format!("{name}.bn2"), Op::BatchNorm, &[z]);
            let shortcut = if blk == 0 && cin != *c {
                let d = b.add(
                    format!("{name}.down"),
                    Op::Conv2d { out_channels: *c, kernel: 1, stride, padding: 0, bias: false, activation: Activation::None },
                    &[y],
                );
                b.add(format!("{name}.down.bn"), Op::BatchNorm, &[d])
            } else {
                y
            };
            let a = b.add(format!("{name}.add"), Op::Add, &[z, shortcut]);
            y = b.add(format!("{name}.out"), Op::Activate(Activation::Relu), &[a]);
            cin = *c;
        }
    }
    y = b.add("gap", Op::GlobalAvgPool, &[y]);
    y = b.add("fc", Op::Dense { out_features: 1000, bias: true, activation: Activation::None }, &[y]);
    b.finish(y)
}

/// AlexNet (224², ungrouped variant) — the §V-E comparison network: the
/// paper weighs its MobileNetV1 against DNNWeaver's AlexNet ("their
/// AlexNet (1.33G FP operations)").
pub fn alexnet() -> Graph {
    let (mut b, x) = GraphBuilder::new("alexnet", Shape::Chw(3, 224, 224));
    let mut y = b.add(
        "conv1",
        Op::Conv2d { out_channels: 96, kernel: 11, stride: 4, padding: 2, bias: true, activation: Activation::Relu },
        &[x],
    );
    y = b.add("pool1", Op::MaxPool { kernel: 3, stride: 2, padding: 0 }, &[y]);
    y = b.add(
        "conv2",
        Op::Conv2d { out_channels: 256, kernel: 5, stride: 1, padding: 2, bias: true, activation: Activation::Relu },
        &[y],
    );
    y = b.add("pool2", Op::MaxPool { kernel: 3, stride: 2, padding: 0 }, &[y]);
    y = b.add(
        "conv3",
        Op::Conv2d { out_channels: 384, kernel: 3, stride: 1, padding: 1, bias: true, activation: Activation::Relu },
        &[y],
    );
    y = b.add(
        "conv4",
        Op::Conv2d { out_channels: 384, kernel: 3, stride: 1, padding: 1, bias: true, activation: Activation::Relu },
        &[y],
    );
    y = b.add(
        "conv5",
        Op::Conv2d { out_channels: 256, kernel: 3, stride: 1, padding: 1, bias: true, activation: Activation::Relu },
        &[y],
    );
    y = b.add("pool5", Op::MaxPool { kernel: 3, stride: 2, padding: 0 }, &[y]);
    y = b.add("flatten", Op::Flatten, &[y]);
    y = b.add("fc6", Op::Dense { out_features: 4096, bias: true, activation: Activation::Relu }, &[y]);
    y = b.add("fc7", Op::Dense { out_features: 4096, bias: true, activation: Activation::Relu }, &[y]);
    y = b.add("fc8", Op::Dense { out_features: 1000, bias: true, activation: Activation::None }, &[y]);
    b.finish(y)
}

/// VGG-16 (224²) — a classic large CNN to stress the folded flow (13
/// 3×3 convs, 138M parameters; far beyond on-chip weight capacity).
pub fn vgg16() -> Graph {
    let (mut b, x) = GraphBuilder::new("vgg16", Shape::Chw(3, 224, 224));
    let mut y = x;
    let plan: [(usize, usize); 5] = [(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)];
    for (stage, (c, n)) in plan.iter().enumerate() {
        for i in 0..*n {
            y = b.add(
                format!("s{stage}c{i}"),
                Op::Conv2d { out_channels: *c, kernel: 3, stride: 1, padding: 1, bias: true, activation: Activation::Relu },
                &[y],
            );
        }
        y = b.add(format!("pool{stage}"), Op::MaxPool { kernel: 2, stride: 2, padding: 0 }, &[y]);
    }
    y = b.add("flatten", Op::Flatten, &[y]);
    y = b.add("fc6", Op::Dense { out_features: 4096, bias: true, activation: Activation::Relu }, &[y]);
    y = b.add("fc7", Op::Dense { out_features: 4096, bias: true, activation: Activation::Relu }, &[y]);
    y = b.add("fc8", Op::Dense { out_features: 1000, bias: true, activation: Activation::None }, &[y]);
    b.finish(y)
}

/// Look up an evaluation network by name.
pub fn by_name(name: &str) -> Option<Graph> {
    match name {
        "lenet5" => Some(lenet5()),
        "mobilenet_v1" => Some(mobilenet_v1()),
        "resnet34" => Some(resnet34()),
        "alexnet" => Some(alexnet()),
        "vgg16" => Some(vgg16()),
        _ => None,
    }
}

/// All three evaluation networks, in the order of the paper's tables.
pub fn all() -> Vec<Graph> {
    vec![lenet5(), mobilenet_v1(), resnet34()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lenet5_params_match_python() {
        // python/tests/test_models.py EXPECTED_PARAM_COUNTS
        assert_eq!(lenet5().total_params(), 61_706);
    }

    #[test]
    fn mobilenet_params_match_python() {
        assert_eq!(mobilenet_v1().total_params(), 4_253_864);
    }

    #[test]
    fn resnet34_params_match_python() {
        assert_eq!(resnet34().total_params(), 21_814_696);
    }

    #[test]
    fn lenet5_macs_order_of_magnitude() {
        // §V-E: the paper calculates 389K FP ops for LeNet-5 ⇒ ~hundreds of
        // K FLOPs. Our exact count of the classic topology:
        let g = lenet5();
        assert!(g.total_flops() > 300_000 && g.total_flops() < 1_500_000, "{}", g.total_flops());
    }

    #[test]
    fn mobilenet_flops_about_1_1g() {
        // §V-E: "our MobileNetV1 (1.11G FP operations)"
        let g = mobilenet_v1();
        let flops = g.total_flops() as f64;
        assert!((flops / 1.11e9 - 1.0).abs() < 0.15, "{flops}");
    }

    #[test]
    fn resnet34_flops_about_3_6g_macs() {
        // The commonly-quoted "ResNet-34 @224 = 3.6 GFLOPs" counts MACs;
        // with the §V-C convention (2 FP ops per MAC) that is ~7.3 GFLOPs.
        let g = resnet34();
        let macs = g.total_macs() as f64;
        assert!((macs / 3.66e9 - 1.0).abs() < 0.05, "{macs}");
        let flops = g.total_flops() as f64;
        assert!((flops / 7.3e9 - 1.0).abs() < 0.05, "{flops}");
    }

    #[test]
    fn mobilenet_1x1_dominates_macs() {
        // §III: "1×1 convolutions constitute 94.9% of multiply-adds in
        // MobileNetV1" (Howard et al. count; ours includes the fc head and
        // conv1, landing close).
        let g = mobilenet_v1();
        let pw: u64 = g
            .nodes
            .iter()
            .filter(|n| matches!(n.op, crate::graph::Op::Conv2d { kernel: 1, .. }))
            .map(|n| n.cost.macs)
            .sum();
        let frac = pw as f64 / g.total_macs() as f64;
        assert!(frac > 0.90 && frac < 0.97, "{frac}");
    }

    #[test]
    fn resnet34_graph_validates() {
        let g = resnet34();
        g.validate().unwrap();
        // 34 weight layers: 36 convs (incl. 3 downsample) + fc = 37 nodes
        // with conv/dense ops; named depth 34 counts conv1 + 32 block convs
        // + fc.
        let convs = g.nodes.iter().filter(|n| matches!(n.op, Op::Conv2d { .. })).count();
        assert_eq!(convs, 36);
    }

    #[test]
    fn all_networks_validate() {
        for g in all() {
            g.validate().unwrap();
            assert!(g.total_macs() > 0);
            assert!(g.max_activation_bytes() > 0);
        }
    }

    #[test]
    fn by_name_roundtrip() {
        for name in ["lenet5", "mobilenet_v1", "resnet34", "alexnet", "vgg16"] {
            assert_eq!(by_name(name).unwrap().name, name);
        }
        assert!(by_name("inception").is_none());
    }

    #[test]
    fn alexnet_matches_published_scale() {
        let g = alexnet();
        g.validate().unwrap();
        // ~61M params; the ungrouped variant is ~1.13 GMACs (the grouped
        // original the paper quotes as "1.33G FP operations" halves conv2/4/5).
        assert!((g.total_params() as f64 / 61e6 - 1.0).abs() < 0.05, "{}", g.total_params());
        assert!((g.total_macs() as f64 / 1.13e9 - 1.0).abs() < 0.10, "{}", g.total_macs());
    }

    #[test]
    fn vgg16_matches_published_scale() {
        let g = vgg16();
        g.validate().unwrap();
        assert!((g.total_params() as f64 / 138e6 - 1.0).abs() < 0.05, "{}", g.total_params());
        // ~15.5 GFLOPs = 2 × 7.7 GMACs? VGG-16 is ~15.5 GMACs ⇒ 31 GFLOPs.
        assert!((g.total_macs() as f64 / 15.5e9 - 1.0).abs() < 0.05, "{}", g.total_macs());
    }

    #[test]
    fn extra_networks_compile_folded() {
        use crate::flow::{Compiler, Mode, OptLevel};
        let flow = Compiler::default();
        for name in ["alexnet", "vgg16"] {
            let g = by_name(name).unwrap();
            let acc = flow.compile(&g, Mode::Folded, OptLevel::Optimized).unwrap();
            assert!(acc.performance.fps > 0.0, "{name}");
            assert!(acc.synthesis.resources.utilization.fits(), "{name}");
        }
    }
}
