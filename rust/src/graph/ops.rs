//! Operator set of the graph IR — the subset of Relay the paper's three
//! networks need (§V-A), plus the transpose/padding helper ops TVM inserts
//! (Table I exempts them from unrolling and marks them autorun-eligible).


/// Activation functions — fused into the producing op by loop fusion (§IV-C).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Activation {
    None,
    Relu,
    Relu6,
    Tanh,
}

impl Activation {
    /// Elementwise FLOPs this activation costs per output element.
    pub fn flops_per_elem(&self) -> u64 {
        match self {
            Activation::None => 0,
            Activation::Relu => 1,
            Activation::Relu6 => 2,
            // tanh is polynomial/LUT on FPGA; count the paper's convention
            // of one "FP operation" per transcendental call.
            Activation::Tanh => 1,
        }
    }
}

/// Graph operators. Feature maps are NCHW; conv weights are OIHW.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// External input (the image).
    Input,
    /// 2-D convolution.
    Conv2d {
        out_channels: usize,
        kernel: usize,
        stride: usize,
        padding: usize,
        bias: bool,
        activation: Activation,
    },
    /// Depthwise 2-D convolution (channel multiplier 1).
    DepthwiseConv2d {
        kernel: usize,
        stride: usize,
        padding: usize,
        bias: bool,
        activation: Activation,
    },
    /// Fully-connected layer over flattened input.
    Dense { out_features: usize, bias: bool, activation: Activation },
    /// Inference-mode batch normalization (folded scale/shift).
    BatchNorm,
    /// Standalone activation (when not fused).
    Activate(Activation),
    /// Max pooling.
    MaxPool { kernel: usize, stride: usize, padding: usize },
    /// Average pooling.
    AvgPool { kernel: usize, stride: usize, padding: usize },
    /// Global average pooling NCHW → NC.
    GlobalAvgPool,
    /// Elementwise residual addition of two inputs.
    Add,
    /// Explicit padding / layout transpose helper (TVM-inserted; Table I
    /// exempts these from unrolling and allows autorun).
    Transform,
    /// Flatten NCHW → N(CHW).
    Flatten,
    /// Softmax over the class dimension.
    Softmax,
    /// Quantize onto the symmetric fixed-point grid of the given precision
    /// (inserted by `crate::quant::rewrite`; elementwise scale + round).
    Quantize { precision: crate::texpr::Precision },
    /// Map grid codes of the given precision back to f32 (elementwise
    /// scale).
    Dequantize { precision: crate::texpr::Precision },
}

impl Op {
    /// Short mnemonic used in kernel names and reports.
    pub fn mnemonic(&self) -> &'static str {
        match self {
            Op::Input => "input",
            Op::Conv2d { .. } => "conv2d",
            Op::DepthwiseConv2d { .. } => "dwconv2d",
            Op::Dense { .. } => "dense",
            Op::BatchNorm => "batchnorm",
            Op::Activate(_) => "activate",
            Op::MaxPool { .. } => "maxpool",
            Op::AvgPool { .. } => "avgpool",
            Op::GlobalAvgPool => "gap",
            Op::Add => "add",
            Op::Transform => "transform",
            Op::Flatten => "flatten",
            Op::Softmax => "softmax",
            Op::Quantize { .. } => "quantize",
            Op::Dequantize { .. } => "dequantize",
        }
    }

    /// Does this op carry trainable weights? (Weightless ops are the
    /// paper's autorun candidates, §IV-F.)
    pub fn has_weights(&self) -> bool {
        matches!(self, Op::Conv2d { .. } | Op::DepthwiseConv2d { .. } | Op::Dense { .. } | Op::BatchNorm)
    }

    /// Is this a MAC-dominated op that the unroll/tile optimizations target?
    pub fn is_compute(&self) -> bool {
        matches!(self, Op::Conv2d { .. } | Op::DepthwiseConv2d { .. } | Op::Dense { .. })
    }

    /// Table I exempts transpose/padding helpers from unrolling.
    pub fn unroll_exempt(&self) -> bool {
        matches!(self, Op::Transform | Op::Input | Op::Flatten)
    }

    /// The convolution "shape class" the paper groups parameterized kernels
    /// by: (kernel, stride) for convs, discriminated by op kind (§IV-H).
    pub fn param_group(&self) -> Option<ParamGroup> {
        match *self {
            Op::Conv2d { kernel, stride, .. } => Some(ParamGroup {
                kind: GroupKind::Conv,
                kernel,
                stride,
            }),
            Op::DepthwiseConv2d { kernel, stride, .. } => Some(ParamGroup {
                kind: GroupKind::Depthwise,
                kernel,
                stride,
            }),
            Op::Dense { .. } => Some(ParamGroup { kind: GroupKind::Dense, kernel: 1, stride: 1 }),
            _ => None,
        }
    }
}

/// Parameterized-kernel grouping key (§IV-H): "we group operations by the
/// filter size and stride of convolutions".
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ParamGroup {
    pub kind: GroupKind,
    pub kernel: usize,
    pub stride: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum GroupKind {
    Conv,
    Depthwise,
    Dense,
}

impl std::fmt::Display for ParamGroup {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let k = match self.kind {
            GroupKind::Conv => "conv",
            GroupKind::Depthwise => "dw",
            GroupKind::Dense => "dense",
        };
        write!(f, "{k}{}x{}s{}", self.kernel, self.kernel, self.stride)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_groups_follow_filter_and_stride() {
        let a = Op::Conv2d { out_channels: 64, kernel: 3, stride: 1, padding: 1, bias: false, activation: Activation::None };
        let b = Op::Conv2d { out_channels: 128, kernel: 3, stride: 1, padding: 1, bias: true, activation: Activation::Relu };
        // Same filter size + stride → same group even with different
        // channel counts (those become runtime parameters, §IV-H).
        assert_eq!(a.param_group(), b.param_group());
        let c = Op::Conv2d { out_channels: 64, kernel: 3, stride: 2, padding: 1, bias: false, activation: Activation::None };
        assert_ne!(a.param_group(), c.param_group());
        let d = Op::DepthwiseConv2d { kernel: 3, stride: 1, padding: 1, bias: false, activation: Activation::None };
        assert_ne!(a.param_group(), d.param_group());
    }

    #[test]
    fn autorun_candidates_are_weightless() {
        assert!(!Op::MaxPool { kernel: 2, stride: 2, padding: 0 }.has_weights());
        assert!(!Op::Transform.has_weights());
        assert!(Op::Conv2d { out_channels: 1, kernel: 1, stride: 1, padding: 0, bias: false, activation: Activation::None }.has_weights());
    }

    #[test]
    fn group_display() {
        let g = ParamGroup { kind: GroupKind::Conv, kernel: 3, stride: 1 };
        assert_eq!(g.to_string(), "conv3x3s1");
    }
}
