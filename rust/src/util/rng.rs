//! Deterministic PRNG (splitmix64 + xoshiro256**) — no external crates in
//! the offline environment. Used by the synthetic data generator, the
//! coordinator's jittered workloads and the in-crate property tests.

/// Base seed for randomized tests: the `FLOW_TEST_SEED` environment
/// variable (decimal, or hex with a `0x` prefix) when set, else `default`.
/// Every randomized test derives its cases from this seed and prints it on
/// failure, so any CI failure replays locally with
/// `FLOW_TEST_SEED=<seed> cargo test …`.
pub fn test_seed(default: u64) -> u64 {
    std::env::var("FLOW_TEST_SEED").ok().and_then(|s| parse_seed(&s)).unwrap_or(default)
}

/// Parse a seed spelling: decimal or `0x`-prefixed hex.
pub fn parse_seed(s: &str) -> Option<u64> {
    let s = s.trim();
    match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16).ok(),
        None => s.parse().ok(),
    }
}

/// xoshiro256** seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [0, 1) with f64 precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Modulo bias is negligible for our n ≪ 2^64 uses.
        self.next_u64() % n
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()) as f32
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Exponentially-distributed inter-arrival time with the given rate.
    pub fn exp(&mut self, rate_per_s: f64) -> f64 {
        -self.f64().max(1e-12).ln() / rate_per_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(2);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn seed_spellings_parse() {
        assert_eq!(parse_seed("42"), Some(42));
        assert_eq!(parse_seed(" 0xC0DEC0DE "), Some(0xC0DE_C0DE));
        assert_eq!(parse_seed("0Xff"), Some(255));
        assert_eq!(parse_seed("nope"), None);
        // Without the env override the default passes through.
        if std::env::var("FLOW_TEST_SEED").is_err() {
            assert_eq!(test_seed(7), 7);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(4);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..1000 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 5;
        }
        assert!(seen_lo && seen_hi);
    }
}
