//! Fixed worker-thread pool over std::sync primitives (tokio is not in the
//! vendored crate set). The coordinator uses one pool per "command queue":
//! a single-worker pool serializes like one OpenCL queue; N pools of one
//! worker each model concurrent execution (CE, §IV-G).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size thread pool.
pub struct Pool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl Pool {
    pub fn new(threads: usize, name: &str) -> Pool {
        assert!(threads > 0);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx: Arc<Mutex<Receiver<Job>>> = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped → shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Pool { tx: Some(tx), workers }
    }

    /// Submit a job.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.tx.as_ref().expect("pool alive").send(Box::new(job)).expect("workers alive");
    }

    /// Submit a job returning a value; receive it via the returned handle.
    pub fn submit_with_result<T: Send + 'static>(
        &self,
        job: impl FnOnce() -> T + Send + 'static,
    ) -> Receiver<T> {
        let (rtx, rrx) = channel();
        self.submit(move || {
            let _ = rtx.send(job());
        });
        rrx
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = Pool::new(4, "t");
        let counter = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..100)
            .map(|_| {
                let c = Arc::clone(&counter);
                pool.submit_with_result(move || c.fetch_add(1, Ordering::SeqCst))
            })
            .collect();
        for h in handles {
            h.recv().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn single_worker_serializes() {
        let pool = Pool::new(1, "serial");
        let order = Arc::new(Mutex::new(Vec::new()));
        let handles: Vec<_> = (0..10)
            .map(|i| {
                let o = Arc::clone(&order);
                pool.submit_with_result(move || o.lock().unwrap().push(i))
            })
            .collect();
        for h in handles {
            h.recv().unwrap();
        }
        assert_eq!(*order.lock().unwrap(), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins_workers() {
        let pool = Pool::new(2, "d");
        let done = pool.submit_with_result(|| 42);
        drop(pool); // must not hang
        assert_eq!(done.recv().unwrap(), 42);
    }
}
