//! Scratch arena: shape-keyed buffer pools with checkout/reset semantics.
//!
//! The host-side executors ([`crate::quant::exec::FastExecutor`], the
//! verify interpreter's frame state) run the same network over many
//! frames, and every intermediate tensor has a frame-invariant length.
//! Allocating those buffers per node per frame dominated the executors'
//! wall-clock (ROADMAP open item 3); the arena makes steady-state
//! execution allocation-free instead:
//!
//! * [`Scratch::take_f32`] / [`Scratch::take_i32`] check a buffer of an
//!   exact length out of the pool (a fresh heap allocation only on a pool
//!   miss — the warm-up frame);
//! * [`Scratch::put_f32`] / [`Scratch::put_i32`] return it for reuse by
//!   the next executor, frame state or fuzz scenario with the same shape;
//! * [`Scratch::reset`] drops every pooled buffer (frees the memory but
//!   keeps the arena usable); [`Scratch::stats`] reports hit/miss
//!   counters so tests can prove steady-state reuse.
//!
//! Checked-out buffers have the requested length but **unspecified
//! contents** (pooled buffers keep their previous values) — every kernel
//! in the fast path fully overwrites its output, which is why the arena
//! never needs to zero.
//!
//! `rust/tests/alloc_regression.rs` pins the end-to-end guarantee: after
//! warm-up, a [`crate::quant::exec::FastExecutor`] frame performs zero
//! heap allocations.

use std::collections::BTreeMap;

/// Pool-usage counters (cumulative since construction or the last
/// [`Scratch::reset`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ScratchStats {
    /// Buffers checked out.
    pub checkouts: u64,
    /// Checkouts served from the pool (no heap allocation).
    pub hits: u64,
    /// Checkouts that had to allocate fresh.
    pub misses: u64,
    /// Buffers returned to the pool.
    pub returns: u64,
    /// Bytes heap-allocated by pool misses (cumulative).
    pub bytes_allocated: u64,
}

impl ScratchStats {
    /// Pool hit rate in [0, 1]; 0 when nothing was checked out.
    pub fn hit_rate(&self) -> f64 {
        if self.checkouts == 0 {
            0.0
        } else {
            self.hits as f64 / self.checkouts as f64
        }
    }
}

/// A reusable arena of `f32`/`i32` buffers pooled by exact length.
#[derive(Debug, Default)]
pub struct Scratch {
    f32s: BTreeMap<usize, Vec<Vec<f32>>>,
    i32s: BTreeMap<usize, Vec<Vec<i32>>>,
    stats: ScratchStats,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// Check out an `f32` buffer of exactly `len` elements. Contents are
    /// unspecified — the caller must fully overwrite.
    pub fn take_f32(&mut self, len: usize) -> Vec<f32> {
        self.stats.checkouts += 1;
        if let Some(buf) = self.f32s.get_mut(&len).and_then(Vec::pop) {
            self.stats.hits += 1;
            return buf;
        }
        self.stats.misses += 1;
        self.stats.bytes_allocated += (len * std::mem::size_of::<f32>()) as u64;
        vec![0.0; len]
    }

    /// Return an `f32` buffer to the pool (keyed by its current length).
    pub fn put_f32(&mut self, buf: Vec<f32>) {
        self.stats.returns += 1;
        self.f32s.entry(buf.len()).or_default().push(buf);
    }

    /// Check out an `i32` buffer of exactly `len` elements (unspecified
    /// contents, like [`Scratch::take_f32`]).
    pub fn take_i32(&mut self, len: usize) -> Vec<i32> {
        self.stats.checkouts += 1;
        if let Some(buf) = self.i32s.get_mut(&len).and_then(Vec::pop) {
            self.stats.hits += 1;
            return buf;
        }
        self.stats.misses += 1;
        self.stats.bytes_allocated += (len * std::mem::size_of::<i32>()) as u64;
        vec![0; len]
    }

    /// Return an `i32` buffer to the pool.
    pub fn put_i32(&mut self, buf: Vec<i32>) {
        self.stats.returns += 1;
        self.i32s.entry(buf.len()).or_default().push(buf);
    }

    /// Drop every pooled buffer and zero the counters. The arena stays
    /// usable; the next checkouts allocate fresh.
    pub fn reset(&mut self) {
        self.f32s.clear();
        self.i32s.clear();
        self.stats = ScratchStats::default();
    }

    pub fn stats(&self) -> ScratchStats {
        self.stats
    }

    /// Buffers currently parked in the pool (diagnostics).
    pub fn pooled(&self) -> usize {
        self.f32s.values().map(Vec::len).sum::<usize>()
            + self.i32s.values().map(Vec::len).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkout_reuses_by_exact_length() {
        let mut s = Scratch::new();
        let a = s.take_f32(64);
        assert_eq!(a.len(), 64);
        s.put_f32(a);
        let b = s.take_f32(64);
        assert_eq!(b.len(), 64);
        let st = s.stats();
        assert_eq!(st.checkouts, 2);
        assert_eq!(st.hits, 1, "second checkout must reuse the pooled buffer");
        assert_eq!(st.misses, 1);
        // A different length is a miss, never a resize of the wrong buffer.
        let c = s.take_f32(65);
        assert_eq!(c.len(), 65);
        assert_eq!(s.stats().misses, 2);
    }

    #[test]
    fn pooled_contents_are_preserved_not_zeroed() {
        let mut s = Scratch::new();
        let mut a = s.take_f32(4);
        a.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        s.put_f32(a);
        // The arena's contract is "unspecified contents" — it deliberately
        // does not pay for zeroing, so the pooled values survive.
        let b = s.take_f32(4);
        assert_eq!(b, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn i32_pool_is_independent() {
        let mut s = Scratch::new();
        let q = s.take_i32(16);
        assert_eq!(q.len(), 16);
        s.put_i32(q);
        assert_eq!(s.take_i32(16).len(), 16);
        assert_eq!(s.stats().hits, 1);
        assert_eq!(s.pooled(), 0);
    }

    #[test]
    fn reset_frees_and_zeroes_counters() {
        let mut s = Scratch::new();
        s.put_f32(vec![0.0; 8]);
        s.put_i32(vec![0; 8]);
        assert_eq!(s.pooled(), 2);
        s.reset();
        assert_eq!(s.pooled(), 0);
        assert_eq!(s.stats(), ScratchStats::default());
        // Still usable after reset.
        assert_eq!(s.take_f32(8).len(), 8);
        assert_eq!(s.stats().misses, 1);
    }

    #[test]
    fn steady_state_take_put_cycle_stays_in_pool() {
        let mut s = Scratch::new();
        for _ in 0..3 {
            let b = s.take_f32(32);
            s.put_f32(b);
        }
        let st = s.stats();
        assert_eq!(st.misses, 1, "only the first checkout allocates");
        assert_eq!(st.hits, 2);
    }

    #[test]
    fn bytes_allocated_counts_only_misses() {
        let mut s = Scratch::new();
        let a = s.take_f32(16); // miss: 64 bytes
        s.put_f32(a);
        let _b = s.take_f32(16); // hit: no new bytes
        let _c = s.take_i32(8); // miss: 32 bytes
        let st = s.stats();
        assert_eq!(st.bytes_allocated, 64 + 32);
        assert!((st.hit_rate() - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(ScratchStats::default().hit_rate(), 0.0);
    }
}
