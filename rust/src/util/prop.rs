//! Miniature property-testing harness (no proptest offline): run a
//! predicate over many seeded-random cases; on failure report the seed and
//! case number so the exact case replays deterministically.

use super::rng::Rng;

/// Number of cases per property (override with env `PROP_CASES`).
pub fn cases() -> u64 {
    std::env::var("PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(128)
}

/// Run `f(rng, case_idx)`; panic with replay info on the first failure.
/// The base seed honors the `FLOW_TEST_SEED` env override
/// ([`super::rng::test_seed`]) and is printed on failure so the exact
/// failing case replays deterministically.
pub fn check(name: &str, mut f: impl FnMut(&mut Rng, u64)) {
    let seed_base = super::rng::test_seed(0xC0DEC0DE);
    for case in 0..cases() {
        let mut rng = Rng::new(seed_base ^ case.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng, case);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            let replay = case + 1;
            panic!(
                "property '{name}' failed at case {case} (replay: FLOW_TEST_SEED={seed_base} \
                 PROP_CASES={replay}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0u64;
        check("trivial", |_rng, _case| {
            // count via a cell-free trick: this closure is FnMut
        });
        // run again counting
        check("count", |_rng, case| {
            n = n.max(case + 1);
        });
        assert_eq!(n, cases());
    }

    #[test]
    #[should_panic(expected = "property 'fails' failed at case")]
    fn failing_property_reports_case() {
        check("fails", |rng, _| {
            assert!(rng.below(10) < 5, "value too big");
        });
    }
}
