//! Minimal JSON parser + emitter (the offline environment has no serde).
//!
//! Parses the subset of JSON that `artifacts/manifest.json` uses (objects,
//! arrays, strings, numbers, booleans, null) and emits reports. Strict
//! enough for round-tripping our own artifacts; not a general-purpose
//! validator.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize (stable key order; floats in shortest round-trip form).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, String> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing garbage at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err("unterminated string".into()),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or("bad \\u escape")? as char;
                            code = code * 16 + c.to_digit(16).ok_or("bad hex in \\u")?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    other => return Err(format!("bad escape {other:?}")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequence.
                    let len = if c >= 0xf0 { 4 } else if c >= 0xe0 { 3 } else { 2 };
                    let start = self.pos - 1;
                    self.pos = (start + len).min(self.bytes.len());
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| "bad utf8")?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                other => return Err(format!("expected ',' or ']' got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                other => return Err(format!("expected ',' or '}}' got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
          "networks": {
            "lenet5": {
              "input_shape": [1, 32, 32],
              "num_classes": 10,
              "weights_file": "lenet5.weights.bin",
              "total_bytes": 246824,
              "executables": [{"file": "lenet5.b1.hlo.txt", "impl": "pallas", "batch": 1}]
            }
          },
          "generated_unix": 1752000000
        }"#;
        let j = parse(doc).unwrap();
        let net = j.get("networks").unwrap().get("lenet5").unwrap();
        assert_eq!(net.get("num_classes").unwrap().as_u64(), Some(10));
        assert_eq!(net.get("input_shape").unwrap().idx(1).unwrap().as_u64(), Some(32));
        assert_eq!(
            net.get("executables").unwrap().idx(0).unwrap().get("impl").unwrap().as_str(),
            Some("pallas")
        );
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"a":[1,2.5,-3],"b":"x\"y","c":true,"d":null}"#;
        let j = parse(doc).unwrap();
        let j2 = parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("12 34").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let j = parse(r#""A\n""#).unwrap();
        assert_eq!(j.as_str(), Some("A\n"));
    }

    #[test]
    fn nested_empty() {
        let j = parse(r#"{"a":{},"b":[]}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_obj().unwrap().len(), 0);
        assert_eq!(j.get("b").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn emits_escaped_strings() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(parse(&j.to_string()).unwrap(), j);
    }
}
