//! Micro-bench harness (criterion is unavailable offline): warmup, timed
//! iterations, median/mean/min/max/stddev, criterion-like one-line output.
//! All `benches/*.rs` targets (harness = false) use this.
//!
//! [`BenchWriter`] is the one emitter for every `BENCH_*.json` artifact:
//! it stamps shared run metadata ([`RunMeta`]: git rev, target device,
//! precision) so CI dashboards can join results across bench targets
//! without per-bench serialization code.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
    pub stddev: Duration,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "{:<44} time: [{} {} {}]  (±{}, {} iters)",
            self.name,
            fmt_dur(self.min),
            fmt_dur(self.median),
            fmt_dur(self.max),
            fmt_dur(self.stddev),
            self.iters
        )
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Benchmark a closure: warm up for `warmup`, then run until `budget` has
/// elapsed (at least 10 iterations; at most `max_iters`).
pub fn bench<T>(name: &str, warmup: Duration, budget: Duration, max_iters: u64, mut f: impl FnMut() -> T) -> BenchStats {
    // Warmup.
    let wstart = Instant::now();
    while wstart.elapsed() < warmup {
        std::hint::black_box(f());
    }
    // Timed runs.
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    while (start.elapsed() < budget || samples.len() < 10) && (samples.len() as u64) < max_iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    stats_from(name, &mut samples)
}

/// Quick preset: 50 ms warmup, 500 ms budget.
pub fn quick<T>(name: &str, f: impl FnMut() -> T) -> BenchStats {
    bench(name, Duration::from_millis(50), Duration::from_millis(500), 100_000, f)
}

fn stats_from(name: &str, samples: &mut [Duration]) -> BenchStats {
    samples.sort_unstable();
    let n = samples.len().max(1);
    let sum: Duration = samples.iter().sum();
    let mean = sum / n as u32;
    let median = samples[n / 2];
    let min = *samples.first().unwrap_or(&Duration::ZERO);
    let max = *samples.last().unwrap_or(&Duration::ZERO);
    let mean_ns = mean.as_nanos() as f64;
    let var = samples
        .iter()
        .map(|s| {
            let d = s.as_nanos() as f64 - mean_ns;
            d * d
        })
        .sum::<f64>()
        / n as f64;
    BenchStats {
        name: name.to_string(),
        iters: n as u64,
        mean,
        median,
        min,
        max,
        stddev: Duration::from_nanos(var.sqrt() as u64),
    }
}

/// Shared run metadata stamped into every `BENCH_*.json` artifact.
#[derive(Debug, Clone)]
pub struct RunMeta {
    /// Bench target name (drives the default output filename
    /// `target/BENCH_<bench>.json`).
    pub bench: String,
    /// Commit of the benched tree: `git rev-parse --short HEAD`, falling
    /// back to `$GITHUB_SHA`, then `"unknown"`.
    pub git_rev: String,
    /// Device target the bench compiled for (empty when N/A).
    pub target: String,
    /// Datapath precision (empty when the bench sweeps several).
    pub precision: String,
}

impl RunMeta {
    pub fn new(bench: &str) -> RunMeta {
        RunMeta {
            bench: bench.to_string(),
            git_rev: detect_git_rev(),
            target: String::new(),
            precision: String::new(),
        }
    }

    pub fn target(mut self, t: &str) -> RunMeta {
        self.target = t.to_string();
        self
    }

    pub fn precision(mut self, p: &str) -> RunMeta {
        self.precision = p.to_string();
        self
    }
}

fn detect_git_rev() -> String {
    if let Ok(out) = std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
    {
        if out.status.success() {
            let rev = String::from_utf8_lossy(&out.stdout).trim().to_string();
            if !rev.is_empty() {
                return rev;
            }
        }
    }
    std::env::var("GITHUB_SHA").ok().filter(|s| !s.is_empty()).unwrap_or_else(|| "unknown".into())
}

/// Unified `BENCH_*.json` emitter. Every bench builds one of these,
/// inserts its sections, and writes — the metadata block is identical
/// across artifacts by construction.
pub struct BenchWriter {
    meta: RunMeta,
    sections: BTreeMap<String, Json>,
}

impl BenchWriter {
    pub fn new(meta: RunMeta) -> BenchWriter {
        BenchWriter { meta, sections: BTreeMap::new() }
    }

    /// Add a bench-specific section (overwrites an existing key).
    pub fn insert(&mut self, key: &str, value: Json) {
        self.sections.insert(key.to_string(), value);
    }

    /// Add the standard `benchmarks` array from measured stats.
    pub fn stats(&mut self, rows: &[BenchStats]) {
        let arr = rows
            .iter()
            .map(|r| {
                let mut m = BTreeMap::new();
                m.insert("name".into(), Json::Str(r.name.clone()));
                m.insert("iters".into(), Json::Num(r.iters as f64));
                m.insert("mean_ns".into(), Json::Num(r.mean.as_nanos() as f64));
                m.insert("median_ns".into(), Json::Num(r.median.as_nanos() as f64));
                m.insert("min_ns".into(), Json::Num(r.min.as_nanos() as f64));
                m.insert("max_ns".into(), Json::Num(r.max.as_nanos() as f64));
                m.insert("stddev_ns".into(), Json::Num(r.stddev.as_nanos() as f64));
                Json::Obj(m)
            })
            .collect();
        self.insert("benchmarks", Json::Arr(arr));
    }

    /// The artifact as JSON (metadata block + every section).
    pub fn to_json(&self) -> Json {
        let mut root = BTreeMap::new();
        let mut meta = BTreeMap::new();
        meta.insert("bench".into(), Json::Str(self.meta.bench.clone()));
        meta.insert("git_rev".into(), Json::Str(self.meta.git_rev.clone()));
        if !self.meta.target.is_empty() {
            meta.insert("target".into(), Json::Str(self.meta.target.clone()));
        }
        if !self.meta.precision.is_empty() {
            meta.insert("precision".into(), Json::Str(self.meta.precision.clone()));
        }
        root.insert("meta".into(), Json::Obj(meta));
        for (k, v) in &self.sections {
            root.insert(k.clone(), v.clone());
        }
        Json::Obj(root)
    }

    /// Resolved output path: `$FLOW_BENCH_OUT` when set, else
    /// `target/BENCH_<bench>.json`.
    pub fn out_path(&self) -> PathBuf {
        match std::env::var("FLOW_BENCH_OUT") {
            Ok(p) if !p.is_empty() => PathBuf::from(p),
            _ => PathBuf::from("target").join(format!("BENCH_{}.json", self.meta.bench)),
        }
    }

    /// Write the artifact, creating the parent directory if needed.
    /// Returns the path written.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let path = self.out_path();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(&path, self.to_json().to_string())?;
        Ok(path)
    }
}

/// Pretty table printer shared by the table-reproduction benches.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                } else {
                    widths.push(c.len());
                }
            }
        }
        let sep = |w: &Vec<usize>| -> String {
            let mut s = String::from("+");
            for width in w {
                s.push_str(&"-".repeat(width + 2));
                s.push('+');
            }
            s
        };
        let render_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (i, w) in widths.iter().enumerate() {
                let c = cells.get(i).map(|c| c.as_str()).unwrap_or("");
                s.push_str(&format!(" {c:<w$} |", w = w));
            }
            s
        };
        let mut out = format!("\n## {}\n{}\n{}\n{}\n", self.title, sep(&widths), render_row(&self.headers), sep(&widths));
        for row in &self.rows {
            out.push_str(&render_row(row));
            out.push('\n');
        }
        out.push_str(&sep(&widths));
        out.push('\n');
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let s = bench("noop", Duration::from_millis(1), Duration::from_millis(20), 10_000, || 1 + 1);
        assert!(s.iters >= 10);
        assert!(s.min <= s.median && s.median <= s.max);
    }

    #[test]
    fn fmt_dur_units() {
        assert!(fmt_dur(Duration::from_nanos(500)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(50)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).ends_with("s"));
    }

    #[test]
    fn bench_writer_stamps_shared_metadata() {
        let meta = RunMeta::new("unit").target("stratix10sx").precision("int8");
        let mut w = BenchWriter::new(meta);
        w.stats(&[bench("noop", Duration::ZERO, Duration::from_millis(1), 20, || 1)]);
        w.insert("custom", Json::Num(7.0));
        let j = crate::util::json::parse(&w.to_json().to_string()).unwrap();
        let m = j.get("meta").unwrap();
        assert_eq!(m.get("bench").unwrap().as_str(), Some("unit"));
        assert_eq!(m.get("target").unwrap().as_str(), Some("stratix10sx"));
        assert_eq!(m.get("precision").unwrap().as_str(), Some("int8"));
        assert!(!m.get("git_rev").unwrap().as_str().unwrap().is_empty());
        let rows = j.get("benchmarks").unwrap().as_arr().unwrap();
        assert_eq!(rows[0].get("name").unwrap().as_str(), Some("noop"));
        assert!(rows[0].get("median_ns").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(j.get("custom").unwrap().as_f64(), Some(7.0));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Table II", &["net", "fmax"]);
        t.row(&["lenet5".into(), "218".into()]);
        let s = t.render();
        assert!(s.contains("Table II"));
        assert!(s.contains("| lenet5 |"));
    }
}
