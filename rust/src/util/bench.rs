//! Micro-bench harness (criterion is unavailable offline): warmup, timed
//! iterations, median/mean/min/max/stddev, criterion-like one-line output.
//! All `benches/*.rs` targets (harness = false) use this.

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
    pub stddev: Duration,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "{:<44} time: [{} {} {}]  (±{}, {} iters)",
            self.name,
            fmt_dur(self.min),
            fmt_dur(self.median),
            fmt_dur(self.max),
            fmt_dur(self.stddev),
            self.iters
        )
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Benchmark a closure: warm up for `warmup`, then run until `budget` has
/// elapsed (at least 10 iterations; at most `max_iters`).
pub fn bench<T>(name: &str, warmup: Duration, budget: Duration, max_iters: u64, mut f: impl FnMut() -> T) -> BenchStats {
    // Warmup.
    let wstart = Instant::now();
    while wstart.elapsed() < warmup {
        std::hint::black_box(f());
    }
    // Timed runs.
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    while (start.elapsed() < budget || samples.len() < 10) && (samples.len() as u64) < max_iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    stats_from(name, &mut samples)
}

/// Quick preset: 50 ms warmup, 500 ms budget.
pub fn quick<T>(name: &str, f: impl FnMut() -> T) -> BenchStats {
    bench(name, Duration::from_millis(50), Duration::from_millis(500), 100_000, f)
}

fn stats_from(name: &str, samples: &mut [Duration]) -> BenchStats {
    samples.sort_unstable();
    let n = samples.len().max(1);
    let sum: Duration = samples.iter().sum();
    let mean = sum / n as u32;
    let median = samples[n / 2];
    let min = *samples.first().unwrap_or(&Duration::ZERO);
    let max = *samples.last().unwrap_or(&Duration::ZERO);
    let mean_ns = mean.as_nanos() as f64;
    let var = samples
        .iter()
        .map(|s| {
            let d = s.as_nanos() as f64 - mean_ns;
            d * d
        })
        .sum::<f64>()
        / n as f64;
    BenchStats {
        name: name.to_string(),
        iters: n as u64,
        mean,
        median,
        min,
        max,
        stddev: Duration::from_nanos(var.sqrt() as u64),
    }
}

/// Pretty table printer shared by the table-reproduction benches.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                } else {
                    widths.push(c.len());
                }
            }
        }
        let sep = |w: &Vec<usize>| -> String {
            let mut s = String::from("+");
            for width in w {
                s.push_str(&"-".repeat(width + 2));
                s.push('+');
            }
            s
        };
        let render_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (i, w) in widths.iter().enumerate() {
                let c = cells.get(i).map(|c| c.as_str()).unwrap_or("");
                s.push_str(&format!(" {c:<w$} |", w = w));
            }
            s
        };
        let mut out = format!("\n## {}\n{}\n{}\n{}\n", self.title, sep(&widths), render_row(&self.headers), sep(&widths));
        for row in &self.rows {
            out.push_str(&render_row(row));
            out.push('\n');
        }
        out.push_str(&sep(&widths));
        out.push('\n');
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let s = bench("noop", Duration::from_millis(1), Duration::from_millis(20), 10_000, || 1 + 1);
        assert!(s.iters >= 10);
        assert!(s.min <= s.median && s.median <= s.max);
    }

    #[test]
    fn fmt_dur_units() {
        assert!(fmt_dur(Duration::from_nanos(500)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(50)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).ends_with("s"));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Table II", &["net", "fmax"]);
        t.row(&["lenet5".into(), "218".into()]);
        let s = t.render();
        assert!(s.contains("Table II"));
        assert!(s.contains("| lenet5 |"));
    }
}
