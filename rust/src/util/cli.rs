//! Tiny argv parser: `--key value`, `--flag`, and positionals.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

/// Parse argv-style tokens. A token `--name` followed by a non-`--` token
/// is an option; a trailing or `--x --y` style token is a flag.
pub fn parse(tokens: &[String]) -> Args {
    let mut args = Args::default();
    let mut i = 0;
    while i < tokens.len() {
        let t = &tokens[i];
        if let Some(name) = t.strip_prefix("--") {
            if i + 1 < tokens.len() && !tokens[i + 1].starts_with("--") {
                args.options.insert(name.to_string(), tokens[i + 1].clone());
                i += 2;
            } else {
                args.flags.push(name.to_string());
                i += 1;
            }
        } else {
            args.positional.push(t.clone());
            i += 1;
        }
    }
    args
}

impl Args {
    pub fn from_env() -> Args {
        parse(&std::env::args().skip(1).collect::<Vec<_>>())
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str) -> Option<T> {
        self.opt(name).and_then(|s| s.parse().ok())
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = parse(&toks("compile --net lenet5 --mode pipelined --verbose"));
        assert_eq!(a.positional, vec!["compile"]);
        assert_eq!(a.opt("net"), Some("lenet5"));
        assert_eq!(a.opt("mode"), Some("pipelined"));
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn parse_typed() {
        let a = parse(&toks("--frames 1000"));
        assert_eq!(a.opt_parse::<u64>("frames"), Some(1000));
        assert_eq!(a.opt_parse::<u64>("missing"), None);
    }

    #[test]
    fn default_values() {
        let a = parse(&toks(""));
        assert_eq!(a.opt_or("net", "lenet5"), "lenet5");
    }
}
