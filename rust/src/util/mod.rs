//! In-crate utilities replacing crates unavailable in the offline build
//! environment: JSON (serde_json), PRNG (rand), CLI parsing (clap),
//! property testing (proptest), a micro-bench harness (criterion) and a
//! thread pool (tokio's runtime on the coordinator's hot path).

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
