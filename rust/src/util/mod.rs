//! In-crate utilities replacing crates unavailable in the offline build
//! environment: JSON (serde_json), PRNG (rand), CLI parsing (clap),
//! property testing (proptest), a micro-bench harness (criterion) and a
//! thread pool (tokio's runtime on the coordinator's hot path).

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod scratch;

/// FNV-1a offset basis (the crate's shared content-hash seed).
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// Continue an FNV-1a hash over `bytes` from state `h` (start from
/// [`FNV_OFFSET`], or a prior hash to chain multiple fields).
pub fn fnv64_with(mut h: u64, bytes: &[u8]) -> u64 {
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// FNV-1a content hash of a byte string.
pub fn fnv64(bytes: &[u8]) -> u64 {
    fnv64_with(FNV_OFFSET, bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_is_stable_and_chains() {
        let a = fnv64(b"hello");
        assert_eq!(a, fnv64(b"hello"));
        assert_ne!(a, fnv64(b"hellp"));
        // Chaining two pieces equals hashing the concatenation.
        assert_eq!(fnv64_with(fnv64(b"he"), b"llo"), fnv64(b"hello"));
    }
}
