//! `fpga-flow` — CLI for the compilation flow.
//!
//! ```text
//! fpga-flow compile  --net lenet5 [--target stratix10sx|arria10gx|agilex7]
//!                    [--mode pipelined|folded] [--base] [--precision int8|fp16]
//!                    [--explain] [--json]
//! fpga-flow explain  --net lenet5 [--mode pipelined]   # ordered pass trace
//! fpga-flow verify   --net lenet5 --frames 16          # differential check
//!                    [--mode pipelined|folded] [--precision f32|fp16|int8]
//!                    [--seed N] [--quick]
//! fpga-flow check    --net lenet5 [--mode pipelined|folded] [--base]
//!                    [--precision int8|fp16] [--deny warnings] [--json]
//!                    # static design-rule analysis (FLOW lints)
//! fpga-flow targets                     # list registered device targets
//! fpga-flow report                      # Tables II/III/IV vs the paper
//! fpga-flow codegen  --net lenet5 [--precision int8]  # dump pseudo-OpenCL
//! fpga-flow simulate --net resnet34 [--base]
//! fpga-flow dse      --net mobilenet_v1 [--budget 16] [--precision int8|all]
//!                    [--json]           # Pareto front + cache hit rate
//! fpga-flow quantize --net lenet5 [--precision int8] [--scheme per-channel]
//!                    [--calibrate minmax|p99.9] [--frames 64]
//! fpga-flow infer    --net lenet5 --frames 100 [--impl pallas|ref]
//! fpga-flow serve    --net lenet5 --requests 256 [--replicas 2]
//!                    [--max-batch 8] [--max-delay-us 2000]
//!                    [--queue-capacity 1024] [--engine sim|pjrt]
//!                    [--targets stratix10sx,arria10gx] [--precision int8]
//!                    [--time-scale 1] [--classes gold=20ms,best-effort]
//!                    [--autoscale min,max[,up_us,down_us]]
//!                    [--trace trace.json]  # replay a recorded trace
//! fpga-flow loadgen  --net lenet5 [--replicas 2] [--pattern bursty|diurnal]
//!                    [--requests 512] [--burst 64] [--period-us 20000]
//!                    [--classes gold=20ms,silver=100ms,bulk=best-effort]
//!                    [--mix 1,3,6] [--trace in.json] [--save-trace out.json]
//!                    [--out report.json] [--json]
//!                    # replay a bursty/diurnal trace against a SimEngine
//!                    # fleet; per-class latency + shed-rate report
//! fpga-flow hybrid   --net mobilenet_v1      # mixed pipelined/folded (§V-F)
//! fpga-flow multi    --net resnet34 --devices 2  # multi-FPGA (§VII)
//! fpga-flow partition --net resnet34 --devices stratix10sx,arria10gx
//!                    [--stages K] [--precision int8|fp16] [--json]
//!                    # pipeline-parallel multi-FPGA: cut search +
//!                    # latency-balancing cost model (cuts, per-stage
//!                    # cost terms, bottleneck attribution)
//! fpga-flow passes   --net resnet34          # graph-level passes (bn-fold, DCE)
//! fpga-flow profile  --net lenet5 [--requests 100] [--trace-out p.json]
//!                    [--metrics-out p.prom] [--json]
//!                    # trace the whole flow: compile stages, passes,
//!                    # per-layer execution, serve lifecycle
//! fpga-flow validate                          # artifact cross-checks
//! ```
//!
//! Every compiling command accepts `--target <name>` (default stratix10sx);
//! the target supplies the device envelope, the §IV-J legality clock and
//! the f_max base the AOC model degrades from. `--precision` routes the
//! compilation through the `quant` subsystem (calibration, Q/DQ rewrite,
//! accuracy accounting). `--trace-out <path>` on any subcommand records
//! the run with the `obs` tracer and writes a Chrome trace-event JSON
//! (load it at <https://ui.perfetto.dev>); see docs/OBSERVABILITY.md.

use tvm_fpga_flow::coordinator::{
    slo, EngineSpec, HysteresisPolicy, InferenceServer, ServerConfig, ServerError, SimEngine,
};
use tvm_fpga_flow::device::Target;
use tvm_fpga_flow::dse;
use tvm_fpga_flow::flow::{Compiler, Mode, ModeChoice, OptConfig, OptLevel};
use tvm_fpga_flow::graph::models;
use tvm_fpga_flow::metrics::{self, paper};
use tvm_fpga_flow::quant::{Calibrator, QScheme, QuantConfig};
use tvm_fpga_flow::runtime::{Impl, Manifest, Runtime};
use tvm_fpga_flow::texpr::Precision;
use tvm_fpga_flow::util::bench::Table;
use tvm_fpga_flow::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    // `--trace-out` on any subcommand records the whole run with the obs
    // tracer; `profile` always traces and manages its own exports.
    let trace_out = if cmd == "profile" {
        None
    } else {
        args.opt("trace-out").map(std::path::PathBuf::from)
    };
    if trace_out.is_some() {
        tvm_fpga_flow::obs::enable();
    }
    let result = match cmd {
        "compile" => cmd_compile(&args),
        "explain" => cmd_explain(&args),
        "verify" => cmd_verify(&args),
        "check" => cmd_check(&args),
        "targets" => cmd_targets(),
        "report" => cmd_report(),
        "codegen" => cmd_codegen(&args),
        "simulate" => cmd_simulate(&args),
        "dse" => cmd_dse(&args),
        "quantize" => cmd_quantize(&args),
        "infer" => cmd_infer(&args),
        "serve" => cmd_serve(&args),
        "loadgen" => cmd_loadgen(&args),
        "hybrid" => cmd_hybrid(&args),
        "multi" => cmd_multi(&args),
        "partition" => cmd_partition(&args),
        "passes" => cmd_passes(&args),
        "profile" => cmd_profile(&args),
        "validate" => cmd_validate(),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Some(path) = &trace_out {
        // Written even when the command failed — a failing run's trace is
        // the one worth looking at. Status goes to stderr so `--json`
        // stdout stays parseable.
        tvm_fpga_flow::obs::disable();
        let trace = tvm_fpga_flow::obs::take();
        match write_trace(path, &trace) {
            Ok(()) => eprintln!("trace: {} span(s) written to {}", trace.len(), path.display()),
            Err(e) => eprintln!("trace: could not write {}: {e}", path.display()),
        }
    }
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// Write a collected trace as Chrome trace-event JSON.
fn write_trace(path: &std::path::Path, trace: &tvm_fpga_flow::obs::Trace) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, trace.to_chrome_json().to_string())
}

fn print_help() {
    println!(
        "fpga-flow — CNN-accelerator compilation flow (paper reproduction)\n\
         \n\
         compile   --net <n> [--target <t>] [--mode pipelined|folded] [--base]\n\
                   [--precision int8|fp16] [--explain] [--json]\n\
         explain   --net <n> [--target <t>] [--mode pipelined|folded] [--base]\n\
                   [--precision int8|fp16]\n\
                   print the ordered optimization-pass trace: per-pass\n\
                   IR-diff stats; skipped passes name the blocking rule\n\
         verify    --net <n> [--frames 16] [--mode pipelined|folded]\n\
                   [--precision f32|fp16|int8] [--seed N] [--quick]\n\
                   differentially test the compiled kernels against the\n\
                   reference executor for every pass subset of the\n\
                   canonical pipeline (prefixes + leave-one-out), both\n\
                   modes, all precisions; int8 must be bit-exact; failing\n\
                   cases shrink to a reproducer (docs/VERIFICATION.md)\n\
         check     --net <n> [--target <t>] [--mode pipelined|folded] [--base]\n\
                   [--precision int8|fp16] [--deny warnings] [--json]\n\
                   static design-rule analysis before synthesis: channel\n\
                   deadlock, accumulator overflow, resource budget and\n\
                   pass-trace consistency lints (stable FLOW0xx codes,\n\
                   docs/ANALYSIS.md); exits nonzero on errors (and on\n\
                   warnings under --deny warnings); --devices t1,t2,...\n\
                   checks a pipeline partition instead (FLOW053-055)\n\
         targets   list registered device targets (legality clock, roof, DSPs)\n\
         report    Tables II/III/IV, ours vs the paper\n\
         codegen   --net <n> [--target <t>] [--precision int8]  dump pseudo-OpenCL\n\
         simulate  --net <n> [--target <t>] [--base]  per-layer timing\n\
         dse       --net <n> [--budget 16] [--precision int8|fp16|all] [--json]\n\
                   explore tiles (and precisions); prints the Pareto front\n\
                   and the synthesis-cache hit rate\n\
         quantize  --net <n> [--precision int8|fp16] [--scheme per-tensor|per-channel]\n\
                   [--calibrate minmax|p99.9] [--frames 64]\n\
                   calibration report, accuracy delta, resources vs fp32\n\
                   (--calib-frames is the historical alias for --frames)\n\
         infer     --net <n> --frames 100 [--impl pallas|ref]   (needs artifacts)\n\
         serve     --net <n> --requests 256 [--replicas 2] [--max-batch 8]\n\
                   [--max-delay-us 2000] [--queue-capacity 1024]\n\
                   [--engine sim|pjrt] [--targets t1,t2,...] [--precision int8]\n\
                   [--time-scale 1] [--classes gold=20ms,best-effort]\n\
                   [--autoscale min,max[,up_us,down_us]] [--trace t.json]\n\
                   sim (default): replicas are modeled accelerators compiled for\n\
                   --targets (cycled to --replicas), weighted by modeled FPS —\n\
                   works without artifacts. pjrt: --replicas identical runtime\n\
                   workers over artifacts/. --classes adds SLO admission\n\
                   control (deadline-unmeetable requests shed before\n\
                   queueing); --trace replays a recorded trace instead of\n\
                   the closed-loop driver.\n\
         loadgen   --net <n> [--replicas 2] [--pattern bursty|diurnal]\n\
                   [--requests 512] [--burst 64] [--period-us 20000]\n\
                   [--span-us 1000000] [--cycles 2] [--seed 42] [--scale 1]\n\
                   [--classes gold=20ms,silver=100ms,bulk=best-effort]\n\
                   [--mix 1,3,6] [--trace in.json] [--save-trace out.json]\n\
                   [--autoscale min,max] [--out report.json] [--json]\n\
                   synthesize (or load) a request trace and replay it\n\
                   against a SimEngine fleet at trace pacing; prints the\n\
                   per-class latency/shed report (docs/CLI.md)\n\
         hybrid    --net <n>                       mixed pipelined/folded (§V-F)\n\
         multi     --net <n> --devices 2           multi-FPGA partition (§VII)\n\
         partition --net <n> --devices t1,t2,... [--stages K]\n\
                   [--precision int8|fp16] [--json]\n\
                   pipeline-parallel multi-FPGA: search the legal cut\n\
                   points for the stage assignment that minimizes the\n\
                   bottleneck stage time max(compute, transfer) subject\n\
                   to per-device budgets; prints chosen cuts, per-stage\n\
                   cost terms and bottleneck attribution (--stages cycles\n\
                   the device list to K stages)\n\
         passes    --net <n>                       graph passes (bn-fold, DCE)\n\
         profile   --net <n> [--requests 100] [--frames 8]\n\
                   [--trace-out <p>] [--metrics-out <p>] [--json]\n\
                   run the whole flow with the tracer on (compile stages,\n\
                   passes, analysis rules, per-layer execution, a serve\n\
                   run) and export a Perfetto-loadable Chrome trace plus\n\
                   Prometheus metrics text (docs/OBSERVABILITY.md)\n\
         validate  artifact cross-checks           (needs artifacts)\n\
         \n\
         every subcommand also accepts --trace-out <path> to record the\n\
         run as a Chrome trace\n\
         targets: {}\n\
         docs: docs/CLI.md has one worked example per subcommand",
        Target::names().join(" ")
    );
}

/// Resolve `--target` (default: the paper's Stratix 10SX D5005).
fn compiler_arg(args: &Args) -> tvm_fpga_flow::Result<Compiler> {
    Compiler::for_target(args.opt_or("target", "stratix10sx"))
}

fn cmd_targets() -> tvm_fpga_flow::Result<()> {
    for t in Target::all() {
        println!(
            "{:<12} {}  (legality clock {:.0} MHz, roof {} words/cycle, {} DSPs)",
            t.name,
            t.description,
            t.legality_clock_mhz(),
            t.bandwidth_roof_words(),
            t.device.dsps
        );
    }
    Ok(())
}

fn net_arg(args: &Args) -> tvm_fpga_flow::Result<tvm_fpga_flow::graph::Graph> {
    let name = args.opt_or("net", "lenet5");
    models::by_name(name).ok_or_else(|| anyhow::anyhow!("unknown network {name} (lenet5|mobilenet_v1|resnet34)"))
}

/// Explicit `--mode`, or Auto — resolved by the session against the
/// target (pipelined when the estimated design fits the device, matching
/// the paper's choices on the S10SX) without lowering the program twice.
fn mode_arg(args: &Args) -> ModeChoice {
    match args.opt("mode") {
        Some("pipelined") => ModeChoice::Pipelined,
        Some("folded") => ModeChoice::Folded,
        _ => ModeChoice::Auto,
    }
}

/// Pin Auto to a concrete mode for commands that need one up front
/// (explorer choice, pass comparisons).
fn resolve_mode(choice: ModeChoice, g: &tvm_fpga_flow::graph::Graph, compiler: &Compiler) -> Mode {
    match choice {
        ModeChoice::Pipelined => Mode::Pipelined,
        ModeChoice::Folded => Mode::Folded,
        ModeChoice::Auto => Mode::auto(g, &compiler.target.device),
    }
}

/// Parse `--precision` (None when absent; error on an unknown spelling).
fn precision_arg(args: &Args) -> tvm_fpga_flow::Result<Option<Precision>> {
    match args.opt("precision") {
        None => Ok(None),
        Some(s) => Precision::parse(s)
            .map(Some)
            .ok_or_else(|| anyhow::anyhow!("unknown --precision {s} (f32|fp16|int8)")),
    }
}

/// Quantization recipe from `--scheme` / `--calibrate` / `--frames`
/// (`--calib-frames` is the historical alias and wins when both are set).
fn quant_cfg_args(args: &Args, p: Precision) -> tvm_fpga_flow::Result<QuantConfig> {
    let mut cfg = QuantConfig::for_precision(p);
    if let Some(s) = args.opt("scheme") {
        cfg.scheme = QScheme::parse(s)
            .ok_or_else(|| anyhow::anyhow!("unknown --scheme {s} (per-tensor|per-channel)"))?;
    }
    if let Some(c) = args.opt("calibrate") {
        cfg.calibrator = Calibrator::parse(c)
            .ok_or_else(|| anyhow::anyhow!("unknown --calibrate {c} (minmax|p<pct>, e.g. p99.9)"))?;
    }
    if let Some(frames) = args
        .opt_parse::<usize>("calib-frames")
        .or_else(|| args.opt_parse::<usize>("frames"))
    {
        cfg = cfg.with_data(frames);
    }
    Ok(cfg)
}

/// Compile honoring `--base` and `--precision` (quantized compilations go
/// through the session's quantization front-end).
fn compile_arg(
    compiler: &Compiler,
    g: &tvm_fpga_flow::graph::Graph,
    args: &Args,
) -> tvm_fpga_flow::Result<tvm_fpga_flow::flow::Accelerator> {
    let level = if args.has_flag("base") { OptLevel::Base } else { OptLevel::Optimized };
    match precision_arg(args)? {
        Some(p) if p != Precision::F32 => {
            let cfg =
                if level == OptLevel::Base { OptConfig::base() } else { OptConfig::optimized() };
            compiler
                .graph(g)
                .mode(mode_arg(args))
                .opts(cfg)
                .with_quantization(quant_cfg_args(args, p)?)
                .run()
        }
        _ => compiler.compile(g, mode_arg(args), level),
    }
}

fn cmd_compile(args: &Args) -> tvm_fpga_flow::Result<()> {
    let g = net_arg(args)?;
    let compiler = compiler_arg(args)?;
    let choice = mode_arg(args);
    let level = if args.has_flag("base") { OptLevel::Base } else { OptLevel::Optimized };
    if args.has_flag("explain") {
        println!(
            "flow stages (Fig. 1): frozen graph [{} nodes, {:.2} GFLOPs]\n\
             → relay-analog IR → tensor-expression loop nests\n\
             → schedule ({:?} mode: {})\n\
             → OpenCL-like kernels → AOC model (LSU inference, II, resources, fmax)\n\
             → performance simulation",
            g.nodes.len(),
            g.total_flops() as f64 / 1e9,
            choice,
            if level == OptLevel::Base { "TVM default" } else { "Table-I optimizations" },
        );
    }
    let acc = compile_arg(&compiler, &g, args)?;
    if args.has_flag("json") {
        // Under --trace-out the report gains its observability section
        // (metrics snapshot; the span tree goes to the trace file).
        let j = if tvm_fpga_flow::obs::enabled() {
            acc.to_json_with_observability(None)
        } else {
            acc.to_json()
        };
        println!("{}", j.to_string());
        return Ok(());
    }
    let (logic, bram, dsp, fmax) = acc.synthesis.table2_row();
    println!("network      : {} ({} mode, {})", acc.network, acc.mode.name(), acc.precision);
    println!("target       : {} [{}]", compiler.target.name, compiler.target.device.name);
    println!("kernels      : {} (+{} channels, {} queues)", acc.program.kernels.len(), acc.program.channels.len(), acc.program.queues);
    println!("applied opts : {}", acc.applied.iter().map(|o| o.abbrev()).collect::<Vec<_>>().join(" "));
    println!("resources    : logic {logic:.1}%  bram {bram:.1}%  dsp {dsp:.1}%  fmax {fmax:.0} MHz");
    println!("performance  : {:.2} FPS ({:.3} ms/frame, bottleneck: {})", acc.performance.fps, acc.performance.frame_time_s * 1e3, acc.performance.bottleneck);
    println!("GFLOPS       : {:.2}", acc.gflops());
    if let Some(q) = &acc.quant {
        println!(
            "quantization : {} {} ({} calibration, {} q / {} dq boundaries, {} folded), top-1 \u{0394} {:.2}pp{}",
            q.precision,
            q.scheme.name(),
            q.calibrator,
            q.stats.quantize_nodes,
            q.stats.dequantize_nodes,
            q.stats.folded_pairs,
            q.accuracy.delta_pp,
            if q.accuracy.estimated { " (modeled)" } else { " (measured)" }
        );
    }
    Ok(())
}

/// `fpga-flow explain`: lower the network through the pass manager and
/// print the ordered pass trace — per-pass IR-diff statistics for applied
/// passes; for skipped passes, the legality rule or mode restriction that
/// blocked them.
fn cmd_explain(args: &Args) -> tvm_fpga_flow::Result<()> {
    let g = net_arg(args)?;
    let compiler = compiler_arg(args)?;
    let level = if args.has_flag("base") { OptLevel::Base } else { OptLevel::Optimized };
    let cfg = if level == OptLevel::Base { OptConfig::base() } else { OptConfig::optimized() };
    let mut session = compiler.graph(&g).mode(mode_arg(args)).opts(cfg);
    if let Some(p) = precision_arg(args)? {
        if p != Precision::F32 {
            session = session.with_quantization(quant_cfg_args(args, p)?);
        }
    }
    let lowered = session.lower()?;
    println!(
        "pass trace — {} on {} ({} mode, {}, {} kernels, {} channels)",
        lowered.network,
        compiler.target.name,
        lowered.mode.name(),
        lowered.precision,
        lowered.program.kernels.len(),
        lowered.program.channels.len()
    );
    if lowered.trace.records.is_empty() {
        println!("no passes selected (TVM default schedule — §IV's pathology list intact)");
        return Ok(());
    }
    println!(
        "{} applied, {} skipped (skips name the blocking rule):",
        lowered.trace.applied(),
        lowered.trace.skipped()
    );
    print!("{}", lowered.trace.render());
    Ok(())
}

/// `fpga-flow verify`: differentially test the compiled kernel program
/// against the graph-level reference executor, for every pass subset of
/// the canonical pipeline (cumulative prefixes + leave-one-out), in both
/// execution modes and at all three datapath precisions. int8 results
/// must agree bit-exactly with `Executor::forward_quantized`; f32/fp16
/// within the tolerances documented in docs/VERIFICATION.md. Any failing
/// scenario is shrunk to a minimal reproducer and written to
/// `target/verify-repro.json` (override with `VERIFY_REPRO_PATH`).
fn cmd_verify(args: &Args) -> tvm_fpga_flow::Result<()> {
    use tvm_fpga_flow::flow::CANONICAL_PIPELINE as CANONICAL;
    use tvm_fpga_flow::schedule::OptKind;
    use tvm_fpga_flow::verify::differ::{self, NetSpec, Scenario};

    let g = net_arg(args)?;
    let frames: usize = args.opt_parse("frames").unwrap_or(8).max(1);
    // Accept both spellings the tool itself prints (decimal and 0x-hex),
    // and reject garbage loudly instead of silently reseeding.
    let seed: u64 = match args.opt("seed") {
        None => 0x5EED_F00D,
        Some(s) => tvm_fpga_flow::util::rng::parse_seed(s)
            .ok_or_else(|| anyhow::anyhow!("invalid --seed {s} (decimal or 0x-prefixed hex)"))?,
    };

    // Canonical pipeline order (Table I as OptConfig::schedule_pipeline
    // sequences it, pinned by a unit test): LF PK OF LT LU CW CH AR CE.
    let mut subsets: Vec<(String, Vec<OptKind>)> = Vec::new();
    if args.has_flag("quick") {
        subsets.push(("base".into(), Vec::new()));
        subsets.push(("full".into(), CANONICAL.to_vec()));
    } else {
        for n in 0..=CANONICAL.len() {
            let label = if n == 0 {
                "base".to_string()
            } else if n == CANONICAL.len() {
                "full".to_string()
            } else {
                format!("+{}", CANONICAL[..n].iter().map(|o| o.abbrev()).collect::<Vec<_>>().join("+"))
            };
            subsets.push((label, CANONICAL[..n].to_vec()));
        }
        for skip in 0..CANONICAL.len() {
            let opts: Vec<OptKind> =
                CANONICAL.iter().enumerate().filter(|&(i, _)| i != skip).map(|(_, o)| *o).collect();
            subsets.push((format!("full-minus-{}", CANONICAL[skip].abbrev()), opts));
        }
    }

    let modes: Vec<Mode> = match mode_arg(args) {
        ModeChoice::Pipelined => vec![Mode::Pipelined],
        ModeChoice::Folded => vec![Mode::Folded],
        ModeChoice::Auto => vec![Mode::Pipelined, Mode::Folded],
    };
    let precisions: Vec<Precision> = match precision_arg(args)? {
        Some(p) => vec![p],
        None => Precision::all().to_vec(),
    };

    println!(
        "differential verification — {} vs reference executor, {frames} frame(s)/scenario, \
         {} subsets × {} mode(s) × {} precision(s)",
        g.name,
        subsets.len(),
        modes.len(),
        precisions.len()
    );
    let mut ran = 0usize;
    let mut failures: Vec<(Scenario, String)> = Vec::new();
    // One arena across the whole sweep: every scenario is the same
    // network, so after the first scenario the buffers all recycle.
    let mut scratch = tvm_fpga_flow::util::scratch::Scratch::new();
    for &mode in &modes {
        for &precision in &precisions {
            let mut worst = 0f64;
            let mut ok = 0usize;
            for (label, opts) in &subsets {
                let s = Scenario {
                    net: NetSpec::Named(g.name.clone()),
                    mode,
                    precision,
                    opts: opts.clone(),
                    frames,
                    frame: None,
                    seed,
                };
                let rep = differ::run_scenario_in(&s, &mut scratch);
                ran += 1;
                if rep.max_rel_err > worst {
                    worst = rep.max_rel_err;
                }
                if rep.passed {
                    ok += 1;
                } else {
                    println!("  FAIL [{} {} {label}] {}", mode.name(), precision, rep.summary());
                    failures.push((s, rep.summary()));
                }
            }
            println!(
                "  {:<9} {:<5} {ok}/{} subsets ok, worst rel err {worst:.3e}{}",
                mode.name(),
                precision.name(),
                subsets.len(),
                if precision == Precision::Int8 { " (bit-exact required)" } else { "" }
            );
        }
    }
    if let Some((scenario, _)) = failures.first() {
        let repro = differ::reproduce(scenario, None);
        match differ::write_reproducer(&repro) {
            Ok(path) => println!("shrunk reproducer written to {}", path.display()),
            Err(e) => println!("could not write reproducer: {e}"),
        }
        println!("shrunk: {}", repro.shrunk.describe());
    }
    anyhow::ensure!(
        failures.is_empty(),
        "{}/{} verification scenarios failed",
        failures.len(),
        ran
    );
    println!("all {ran} scenarios agree with the reference executor.");
    Ok(())
}

/// `fpga-flow check`: lower the network and run the static design-rule
/// analyzer — every finding prints as `severity[FLOWnnn] message`
/// (catalog: docs/ANALYSIS.md). Exits nonzero when the report carries
/// Error-level findings, or any Warning under `--deny warnings`. A plan
/// the §IV-J legality gate rejects still produces a diagnostics report
/// (FLOW020/FLOW021) instead of a bare compile error.
fn cmd_check(args: &Args) -> tvm_fpga_flow::Result<()> {
    use tvm_fpga_flow::analysis::AnalysisReport;
    use tvm_fpga_flow::flow::CompileError;

    let g = net_arg(args)?;
    // Partitioned configs: `--devices t1,t2,...` runs the pipeline
    // analyzer (FLOW053–055) over the planned stage assignment instead of
    // lowering for a single device.
    if args.opt("devices").is_some() {
        use tvm_fpga_flow::flow::multi::{Link, PipelinePlan};
        let targets = devices_arg(args)?;
        let names: Vec<&str> = targets.iter().map(String::as_str).collect();
        let quant = match precision_arg(args)? {
            Some(p) if p != Precision::F32 => Some(quant_cfg_args(args, p)?),
            _ => None,
        };
        let deny = matches!(args.opt("deny"), Some("warnings"));
        let report = match PipelinePlan::build_with(&g, &names, &Link::default(), quant) {
            Ok(plan) => plan.analysis,
            Err(e) => match e.downcast::<CompileError>() {
                Ok(CompileError::Analysis { diagnostics, .. }) => {
                    AnalysisReport { diagnostics }
                }
                Ok(other) => return Err(other.into()),
                Err(e) => return Err(e),
            },
        };
        if args.has_flag("json") {
            println!("{}", report.to_json().to_string());
        } else {
            println!("design-rule check — {} partitioned across {}", g.name, names.join(","));
            print!("{}", report.render());
        }
        anyhow::ensure!(
            report.is_clean(deny),
            "design-rule check failed for partitioned {}{}",
            g.name,
            if deny { " (--deny warnings)" } else { "" }
        );
        return Ok(());
    }
    let compiler = compiler_arg(args)?;
    let level = if args.has_flag("base") { OptLevel::Base } else { OptLevel::Optimized };
    let cfg = if level == OptLevel::Base { OptConfig::base() } else { OptConfig::optimized() };
    let mut session = compiler.graph(&g).mode(mode_arg(args)).opts(cfg);
    if let Some(p) = precision_arg(args)? {
        if p != Precision::F32 {
            session = session.with_quantization(quant_cfg_args(args, p)?);
        }
    }
    let deny = matches!(args.opt("deny"), Some("warnings"));
    let report = match session.lower() {
        Ok(lowered) => lowered.analyze(),
        Err(e) => match e.downcast::<CompileError>() {
            Ok(CompileError::IllegalPlan { violations, .. }) => {
                AnalysisReport { diagnostics: violations }
            }
            Ok(other) => return Err(other.into()),
            Err(e) => return Err(e),
        },
    };
    if args.has_flag("json") {
        println!("{}", report.to_json().to_string());
    } else {
        println!("design-rule check — {} on {}", g.name, compiler.target.name);
        print!("{}", report.render());
    }
    anyhow::ensure!(
        report.is_clean(deny),
        "design-rule check failed for {}{}",
        g.name,
        if deny { " (--deny warnings)" } else { "" }
    );
    Ok(())
}

fn cmd_report() -> tvm_fpga_flow::Result<()> {
    // The report compares against the paper, so it pins the paper's board.
    let flow = Compiler::default();
    let mut t2 = Table::new("Table II — resources & fmax (ours vs paper)", &["network", "logic%", "paper", "bram%", "paper", "dsp%", "paper", "fmax", "paper"]);
    let mut t3 = Table::new("Table III — applied optimizations", &["network", "ours", "paper"]);
    let mut t4 = Table::new("Table IV — base vs optimized FPS", &["network", "base", "paper", "opt", "paper", "speedup", "paper"]);
    for ((name, pl, pb, pd, pf), ((_, p3), (_, p4b, p4o, p4s))) in paper::TABLE2
        .iter()
        .zip(paper::TABLE3.iter().zip(paper::TABLE4.iter()))
    {
        let g = models::by_name(name).unwrap();
        let mode = Compiler::paper_mode(name);
        let opt = flow.compile(&g, mode, OptLevel::Optimized)?;
        let base = flow.compile(&g, mode, OptLevel::Base)?;
        let (l, b, d, f) = opt.synthesis.table2_row();
        t2.row(&[
            name.to_string(),
            format!("{l:.0}"), format!("{pl:.0}"),
            format!("{b:.0}"), format!("{pb:.0}"),
            format!("{d:.0}"), format!("{pd:.0}"),
            format!("{f:.0}"), format!("{pf:.0}"),
        ]);
        t3.row(&[
            name.to_string(),
            opt.applied.iter().map(|o| o.abbrev()).collect::<Vec<_>>().join(" "),
            p3.join(" "),
        ]);
        let (bf, of) = (base.performance.fps, opt.performance.fps);
        t4.row(&[
            name.to_string(),
            format!("{bf:.4}"), format!("{p4b:.4}"),
            format!("{of:.2}"), format!("{p4o:.2}"),
            format!("{:.1}x", of / bf), format!("{p4s:.1}x"),
        ]);
    }
    t2.print();
    t3.print();
    t4.print();
    Ok(())
}

fn cmd_codegen(args: &Args) -> tvm_fpga_flow::Result<()> {
    let g = net_arg(args)?;
    let compiler = compiler_arg(args)?;
    let acc = compile_arg(&compiler, &g, args)?;
    println!("// pseudo-OpenCL for {} ({} mode, {})\n", g.name, acc.mode.name(), acc.precision);
    print!("{}", acc.program.to_pseudo_opencl());
    Ok(())
}

fn cmd_simulate(args: &Args) -> tvm_fpga_flow::Result<()> {
    let g = net_arg(args)?;
    let compiler = compiler_arg(args)?;
    let level = if args.has_flag("base") { OptLevel::Base } else { OptLevel::Optimized };
    let acc = compiler.compile(&g, mode_arg(args), level)?;
    let mut t = Table::new(
        &format!("per-layer timing — {} ({}, fmax {:.0} MHz)", g.name, acc.mode.name(), acc.synthesis.fmax_mhz),
        &["layer", "kernel", "compute cyc", "memory cyc", "governing"],
    );
    for l in acc.performance.per_layer.iter().take(40) {
        t.row(&[
            l.layer.clone(),
            l.kernel.clone(),
            format!("{:.0}", l.compute_cycles),
            format!("{:.0}", l.memory_cycles),
            if l.compute_cycles >= l.memory_cycles { "compute".into() } else { "memory".into() },
        ]);
    }
    t.print();
    println!("total: {:.2} FPS, host fraction {:.1}%", acc.performance.fps, acc.performance.host_frac * 100.0);
    Ok(())
}

fn cmd_dse(args: &Args) -> tvm_fpga_flow::Result<()> {
    let g = net_arg(args)?;
    let compiler = compiler_arg(args)?;
    let budget: usize = args.opt_parse("budget").unwrap_or(16);
    let mode = resolve_mode(mode_arg(args), &g, &compiler);
    let precisions: Vec<Precision> = match args.opt("precision") {
        None => vec![Precision::F32],
        Some("all") => Precision::all().to_vec(),
        Some(s) => {
            let p = Precision::parse(s)
                .ok_or_else(|| anyhow::anyhow!("unknown --precision {s} (f32|fp16|int8|all)"))?;
            if p == Precision::F32 {
                vec![Precision::F32]
            } else {
                vec![Precision::F32, p]
            }
        }
    };
    let front = dse::explore_precisions(&compiler, &g, mode, budget, &precisions)?;
    if args.has_flag("json") {
        println!("{}", front.to_json().to_string());
        return Ok(());
    }
    for (p, r) in &front.results {
        println!(
            "[{p}] evaluated {} design points ({} rejected), synthesis cache {} hits / {} misses ({:.0}%)",
            r.evaluated,
            r.log.iter().filter(|pt| pt.rejected.is_some()).count(),
            r.synth_cache.hits,
            r.synth_cache.misses,
            r.synth_cache_hit_rate() * 100.0
        );
        println!(
            "  sweep: {:.2}s wall, {:.2}s summed across workers ({:.1}x parallel speedup)",
            r.wall_s,
            r.cpu_s,
            r.parallel_speedup()
        );
        if let Some(best) = &r.best {
            println!(
                "  best: {:.2} FPS @ {:.0} MHz  (dsp {:.1}%, logic {:.1}%, bram {:.1}%)  top-1 \u{0394} {:.2}pp",
                best.fps,
                best.fmax_mhz,
                best.dsp_frac * 100.0,
                best.logic_frac * 100.0,
                best.bram_frac * 100.0,
                best.accuracy_delta_pp
            );
            for (grp, (a, b)) in &best.plan.group_tiles {
                println!("    {grp}: tile ({a}, {b})");
            }
        }
    }
    println!("pareto front ({} points: FPS vs resources vs accuracy):", front.pareto.len());
    for pt in &front.pareto {
        println!(
            "  {:<5} {:>10.2} FPS  dsp {:>5.1}%  logic {:>5.1}%  bram {:>5.1}%  top-1 \u{0394} {:.2}pp",
            pt.precision.name(),
            pt.fps,
            pt.dsp_frac * 100.0,
            pt.logic_frac * 100.0,
            pt.bram_frac * 100.0,
            pt.accuracy_delta_pp
        );
    }
    for p in precisions.iter().filter(|&&p| p != Precision::F32) {
        if front.beats_baseline_on_resources(*p) {
            println!(
                "{p}: at least one design strictly beats the fp32 baseline on every modeled \
                 resource at equal-or-better FPS"
            );
        }
    }
    Ok(())
}

fn cmd_quantize(args: &Args) -> tvm_fpga_flow::Result<()> {
    use tvm_fpga_flow::quant::{self, CalibrationSource};

    let g = net_arg(args)?;
    let compiler = compiler_arg(args)?;
    let p = precision_arg(args)?.unwrap_or(Precision::Int8);
    anyhow::ensure!(p != Precision::F32, "--precision must be fp16 or int8 for quantize");
    let mut qcfg = quant_cfg_args(args, p)?;
    // Default to empirical calibration where forwards are cheap (LeNet);
    // the big networks calibrate analytically unless --frames asks. The
    // default rode the arena-backed calibration fast path from 16 up to
    // 64 frames — better range statistics at less cost than 16 used to be.
    if matches!(qcfg.source, CalibrationSource::Analytic) && g.name == "lenet5" {
        qcfg = qcfg.with_data(64);
    }
    let prep = quant::prepare(&g, &qcfg)?;
    let rep = &prep.report;
    println!(
        "{}: {} {} calibration ({})",
        g.name,
        rep.precision,
        rep.scheme.name(),
        if rep.calibration_frames == 0 {
            "analytic".to_string()
        } else {
            format!("{} frames, {}", rep.calibration_frames, rep.calibrator)
        }
    );

    // Per-layer calibrated ranges (over the BN-folded graph the table is
    // keyed by).
    let (folded, _) = tvm_fpga_flow::graph::passes::standard_pipeline(&g);
    let mut shown = 0;
    for n in folded.topo().filter(|n| n.op.is_compute()) {
        if shown >= 16 {
            println!("  … ({} more compute layers)", folded.nodes.iter().filter(|n| n.op.is_compute()).count() - shown);
            break;
        }
        let a = prep.table.activation(n.id);
        let w = prep.table.weight_ranges(n.id);
        let wmax = w.iter().map(|r| r.max_abs()).fold(0.0, f64::max);
        println!(
            "  {:<16} act [{:+.3}, {:+.3}]  |w|max {:.3} ({} ch)",
            n.name, a.lo, a.hi, wmax, w.len()
        );
        shown += 1;
    }
    println!(
        "boundaries   : {} quantize, {} dequantize, {} folded dq/q pairs",
        rep.stats.quantize_nodes, rep.stats.dequantize_nodes, rep.stats.folded_pairs
    );
    println!(
        "top-1        : {:.1}% agreement vs fp32 (\u{0394} {:.2}pp, {})",
        rep.accuracy.top1_agreement * 100.0,
        rep.accuracy.delta_pp,
        if rep.accuracy.estimated {
            "modeled".to_string()
        } else {
            format!("measured on {} frames", rep.accuracy.frames)
        }
    );

    // Modeled cost vs the fp32 compilation of the *same pass-folded*
    // graph, so the delta is quantization — not BN-fold smuggled into one
    // column. The quantized design compiles from the already-prepared
    // graph (no second calibration pass) at the requested precision.
    let base = compiler.compile(&folded, mode_arg(args), OptLevel::Optimized)?;
    let qacc = compiler
        .graph(&prep.graph)
        .mode(mode_arg(args))
        .opts(OptConfig::optimized().with_precision(p))
        .run()?;
    let (bl, bb, bd, bf) = base.synthesis.table2_row();
    let (ql, qb, qd, qf) = qacc.synthesis.table2_row();
    println!("             :      logic     bram      dsp     fmax       fps");
    println!(
        "fp32         : {bl:>9.1}% {bb:>7.1}% {bd:>7.1}% {bf:>7.0}M {:>9.2}",
        base.performance.fps
    );
    println!(
        "{:<12} : {ql:>9.1}% {qb:>7.1}% {qd:>7.1}% {qf:>7.0}M {:>9.2}",
        rep.precision.name(),
        qacc.performance.fps
    );
    Ok(())
}

fn cmd_infer(args: &Args) -> tvm_fpga_flow::Result<()> {
    let name = args.opt_or("net", "lenet5").to_string();
    let frames: usize = args.opt_parse("frames").unwrap_or(100);
    let impl_ = match args.opt("impl") {
        Some("pallas") => Impl::Pallas,
        _ => Impl::Ref,
    };
    let rt = Runtime::new(Manifest::default_dir())?;
    let model = rt.load(&name, impl_, 1)?;
    let data = tvm_fpga_flow::data::for_network(&name, frames, 0)
        .ok_or_else(|| anyhow::anyhow!("no data generator for {name}"))?;
    let t0 = std::time::Instant::now();
    let mut hist = [0u64; 16];
    for i in 0..frames {
        let pred = model.classify(&rt.client, data.frame(i))?[0];
        hist[(pred as usize).min(15)] += 1;
    }
    let dt = t0.elapsed().as_secs_f64();
    let fps = metrics::fps(frames as u64, dt);
    let g = models::by_name(&name).unwrap();
    println!(
        "{name} [{}]: {frames} frames in {dt:.3}s → {fps:.1} FPS, {:.2} GFLOPS (CPU/PJRT)",
        impl_.tag(),
        metrics::gflops(fps, g.total_flops())
    );
    println!("prediction histogram (first 16 classes): {hist:?}");
    Ok(())
}

fn cmd_hybrid(args: &Args) -> tvm_fpga_flow::Result<()> {
    use tvm_fpga_flow::flow::{default_factors, OptConfig};
    let g = net_arg(args)?;
    let flow = compiler_arg(args)?;
    let plan = default_factors(&g);
    let folded = flow.compile(&g, Mode::Folded, OptLevel::Optimized)?;
    match flow.best_hybrid(&g, &OptConfig::optimized(), &plan) {
        Some(h) => {
            println!(
                "{}: best hybrid cut at node {} → {:.2} FPS (front {:.2} ms pipelined, back {:.2} ms folded)",
                g.name, h.cut, h.fps, h.front_interval_s * 1e3, h.back_time_s * 1e3
            );
            println!("pure folded: {:.2} FPS", folded.performance.fps);
        }
        None => println!("{}: no clean hybrid cut fits the device", g.name),
    }
    Ok(())
}

fn cmd_multi(args: &Args) -> tvm_fpga_flow::Result<()> {
    use tvm_fpga_flow::flow::multi::Link;
    use tvm_fpga_flow::flow::{default_factors, OptConfig};
    let g = net_arg(args)?;
    let devices: usize = args.opt_parse("devices").unwrap_or(2);
    let flow = compiler_arg(args)?;
    let plan = default_factors(&g);
    let m = flow.compile_multi(&g, devices, &OptConfig::optimized(), &plan, &Link::default())?;
    println!("{}: {} devices → {:.2} FPS", g.name, m.devices, m.fps);
    for sh in &m.shares {
        println!(
            "  dev{}: {} layers, {:.2} ms/frame (+{:.2} ms link), fmax {:.0} MHz, logic {:.0}%",
            sh.device_index,
            sh.layers.len(),
            sh.frame_time_s * 1e3,
            sh.transfer_in_s * 1e3,
            sh.fmax_mhz,
            sh.logic_frac * 100.0
        );
    }
    Ok(())
}

/// Parse `--devices t1,t2,...` into target names, cycling the list to
/// `--stages K` entries when asked (`--devices stratix10sx --stages 3`
/// means three stages on identical boards).
fn devices_arg(args: &Args) -> tvm_fpga_flow::Result<Vec<String>> {
    let spec = args.opt_or("devices", "stratix10sx,stratix10sx");
    let mut targets: Vec<String> =
        spec.split(',').map(str::trim).filter(|s| !s.is_empty()).map(String::from).collect();
    anyhow::ensure!(!targets.is_empty(), "--devices needs at least one target name");
    if let Some(k) = args.opt_parse::<usize>("stages") {
        anyhow::ensure!(k >= 1, "--stages must be at least 1");
        let seed = targets.clone();
        while targets.len() < k {
            targets.push(seed[targets.len() % seed.len()].clone());
        }
        targets.truncate(k);
    }
    Ok(targets)
}

/// `fpga-flow partition`: pipeline-parallel multi-FPGA deployment. Search
/// the legal cut points of the network for the stage assignment that
/// minimizes the bottleneck stage time `max(compute, transfer)` subject
/// to every stage fitting its device's resource budget, then print the
/// decision: chosen cuts, per-stage cost-model terms, occupancy and
/// bottleneck attribution, plus the recorded partition pass trace.
fn cmd_partition(args: &Args) -> tvm_fpga_flow::Result<()> {
    use tvm_fpga_flow::flow::multi::{Link, PipelinePlan};
    let g = net_arg(args)?;
    let targets = devices_arg(args)?;
    let names: Vec<&str> = targets.iter().map(String::as_str).collect();
    let quant = match precision_arg(args)? {
        Some(p) if p != Precision::F32 => Some(quant_cfg_args(args, p)?),
        _ => None,
    };
    let plan = PipelinePlan::build_with(&g, &names, &Link::default(), quant)?;
    if args.has_flag("json") {
        println!("{}", plan.to_json().to_string());
    } else {
        print!("{}", plan.render());
    }
    Ok(())
}

fn cmd_passes(args: &Args) -> tvm_fpga_flow::Result<()> {
    use tvm_fpga_flow::graph::passes;
    let g = net_arg(args)?;
    let (g2, stats) = passes::standard_pipeline(&g);
    println!(
        "{}: {} nodes → {} nodes ({} removed, {} rewritten by bn-fold/pad-fuse/DCE)",
        g.name,
        g.nodes.len(),
        g2.nodes.len(),
        stats.removed,
        stats.rewritten
    );
    let flow = compiler_arg(args)?;
    let mode = resolve_mode(mode_arg(args), &g, &flow);
    let before = flow.compile(&g, mode, OptLevel::Optimized)?;
    let after = flow.compile(&g2, mode, OptLevel::Optimized)?;
    println!(
        "compiled FPS: {:.2} (original graph) vs {:.2} (after passes)",
        before.performance.fps, after.performance.fps
    );
    Ok(())
}

/// `fpga-flow profile`: one traced pass over the whole flow. Runs the
/// staged compile (lower → analyze → verify → synthesize → simulate), a
/// per-layer-traced host-execution loop on both executor paths, and a
/// serve run through the simulated engine — all with the `obs` tracer on —
/// then exports the Chrome trace-event JSON (Perfetto-loadable) and the
/// Prometheus metrics text. With `--json`, prints the accelerator report
/// with its `observability` section (metrics snapshot + span summary).
fn cmd_profile(args: &Args) -> tvm_fpga_flow::Result<()> {
    use tvm_fpga_flow::flow::multi::ReplicaPlan;
    use tvm_fpga_flow::obs;
    use tvm_fpga_flow::quant::{Executor, FastExecutor};

    let g = net_arg(args)?;
    let compiler = compiler_arg(args)?;
    let requests: usize = args.opt_parse("requests").unwrap_or(100).max(1);
    let frames: usize = args.opt_parse("frames").unwrap_or(8).max(1);
    let max_batch: usize = args.opt_parse("max-batch").unwrap_or(8).max(1);
    let time_scale: f64 = args.opt_parse("time-scale").unwrap_or(1.0);

    obs::enable();
    let metrics = obs::global_metrics();

    // Compile stages — each becomes a `compile` span with pass and
    // analysis-rule children.
    let level = if args.has_flag("base") { OptLevel::Base } else { OptLevel::Optimized };
    let cfg = if level == OptLevel::Base { OptConfig::base() } else { OptConfig::optimized() };
    let mut session = compiler.graph(&g).mode(mode_arg(args)).opts(cfg);
    if let Some(p) = precision_arg(args)? {
        if p != Precision::F32 {
            session = session.with_quantization(quant_cfg_args(args, p)?);
        }
    }
    let analysis = session.analyze()?;
    let verify_rep = session.verify(2)?;
    let acc = session.run()?;

    // Host execution: one frame through the reference executor and
    // `frames` through the arena fast path, each layer a child span.
    let data = tvm_fpga_flow::data::for_network(&g.name, frames.min(16), 7)
        .ok_or_else(|| anyhow::anyhow!("no data generator for {}", g.name))?;
    let exec = Executor::new(&g);
    std::hint::black_box(exec.forward_traced(data.frame(0)));
    let mut scratch = tvm_fpga_flow::util::scratch::Scratch::new();
    let mut fast = FastExecutor::reference(&exec, true, &mut scratch);
    for i in 0..frames {
        std::hint::black_box(fast.forward_traced(data.frame(i % data.frames())));
    }
    let exec_stats = fast.stats();
    exec_stats.export_metrics(metrics);
    fast.release(&mut scratch);

    // Serve run: every request's enqueue → batch → dispatch → complete
    // lifecycle lands in the trace; the snapshot re-registers the serving
    // stats as first-class metrics.
    let plan = ReplicaPlan::build_with(&g, &[compiler.target.name.as_str()], None)?;
    let server = InferenceServer::start(ServerConfig {
        network: g.name.clone(),
        workers: 1,
        max_batch,
        max_wait: std::time::Duration::from_micros(500),
        queue_capacity: requests.max(64),
        replicas: SimEngine::from_plan(&plan, &g, max_batch)?
            .into_iter()
            .map(|e| EngineSpec::Sim(e.with_time_scale(time_scale)))
            .collect(),
        ..Default::default()
    })?;
    let mut pending = Vec::with_capacity(requests);
    for i in 0..requests {
        pending.push(server.infer_async(data.frame(i % data.frames()).to_vec())?);
    }
    for rx in pending {
        rx.recv().map_err(|_| anyhow::anyhow!("response dropped"))??;
    }
    let serve_stats = server.shutdown();
    serve_stats.export_metrics(metrics);

    // Export: Chrome trace + Prometheus text.
    obs::disable();
    let trace = obs::take();
    let trace_path = std::path::PathBuf::from(
        args.opt_or("trace-out", &format!("target/trace-{}.json", g.name)),
    );
    write_trace(&trace_path, &trace)?;
    let prom_path = std::path::PathBuf::from(
        args.opt_or("metrics-out", &format!("target/metrics-{}.prom", g.name)),
    );
    if let Some(dir) = prom_path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(&prom_path, metrics.render_prometheus())?;

    if args.has_flag("json") {
        println!("{}", acc.to_json_with_observability(Some(&trace)).to_string());
        return Ok(());
    }
    println!("profile — {} on {} ({} mode, {})", g.name, compiler.target.name, acc.mode.name(), acc.precision);
    println!(
        "compile : {} passes applied, {} skipped; {} diagnostics; verify {}",
        acc.pass_trace.applied(),
        acc.pass_trace.skipped(),
        analysis.diagnostics.len(),
        if verify_rep.passed { "ok" } else { "FAILED" }
    );
    println!(
        "exec    : {frames} fast-path frame(s), scratch hit rate {:.0}% ({} buffers, {} B)",
        exec_stats.scratch.hit_rate() * 100.0,
        exec_stats.buffers,
        exec_stats.buffer_bytes
    );
    println!(
        "serve   : {requests} request(s) → {} batch(es), p50 {}µs  p99 {}µs",
        serve_stats.batches,
        serve_stats.p50_us.unwrap_or(0),
        serve_stats.p99_us.unwrap_or(0)
    );
    println!(
        "spans   : {} total ({} compile, {} pass, {} analysis, {} exec, {} verify, {} serve)",
        trace.len(),
        trace.in_cat("compile").len(),
        trace.in_cat("pass").len(),
        trace.in_cat("analysis").len(),
        trace.in_cat("exec").len(),
        trace.in_cat("verify").len(),
        trace.in_cat("serve").len()
    );
    println!("trace   : {}", trace_path.display());
    println!("metrics : {}", prom_path.display());
    Ok(())
}

fn cmd_validate() -> tvm_fpga_flow::Result<()> {
    use tvm_fpga_flow::runtime::hlo;
    let m = Manifest::load(Manifest::default_dir())?;
    let mut problems = 0usize;
    for net in &m.networks {
        let g = models::by_name(&net.name);
        // 1. manifest weights must match the rust graph definition.
        let total: usize = net.params.iter().map(|(_, _, _, nb)| nb).sum();
        match &g {
            Some(g) if total as u64 == g.weight_bytes() => {
                println!("[ok] {}: {} params, {:.1} MB weights", net.name, net.params.len(), total as f64 / 1e6)
            }
            Some(g) => {
                println!("[!!] {}: weights {} B != graph {} B", net.name, total, g.weight_bytes());
                problems += 1;
            }
            None => println!("[--] {}: no rust graph (python-only network)", net.name),
        }
        // 2. every executable parses and has image+weights parameters.
        for (file, impl_, batch) in &net.executables {
            let text = std::fs::read_to_string(m.dir.join(file))?;
            let s = hlo::stats(&text);
            let expect = net.params.len() + 1;
            if s.entry_parameters != expect {
                println!("[!!] {file}: {} entry params, expected {expect}", s.entry_parameters);
                problems += 1;
            } else {
                println!(
                    "[ok] {file} (impl={impl_}, b{batch}): {} instrs, {} convs, {} dots, {} whiles",
                    s.instructions, s.convolutions, s.dots, s.while_loops
                );
            }
        }
    }
    anyhow::ensure!(problems == 0, "{problems} validation problem(s)");
    println!("artifacts validated.");
    Ok(())
}

/// Build the sim fleet both `serve` and `loadgen` use: compile the
/// network once per distinct `--targets` entry, cycle the compiled
/// entries to `replicas` slots, and print the plan.
fn sim_fleet(
    args: &Args,
    replicas: usize,
    max_batch: usize,
    time_scale: f64,
) -> tvm_fpga_flow::Result<Vec<EngineSpec>> {
    use tvm_fpga_flow::flow::multi::ReplicaPlan;

    let g = net_arg(args)?;
    let target_csv = args.opt_or("targets", "stratix10sx").to_string();
    let targets: Vec<&str> = target_csv.split(',').filter(|s| !s.is_empty()).collect();
    anyhow::ensure!(!targets.is_empty(), "--targets must name at least one target");
    let qcfg = match precision_arg(args)? {
        Some(p) if p != Precision::F32 => Some(quant_cfg_args(args, p)?),
        _ => None,
    };
    let plan = ReplicaPlan::build_cycled(&g, &targets, replicas, qcfg)?;
    println!("replica plan for {}:", g.name);
    for e in &plan.entries {
        println!(
            "  {:<12} {} mode ({}), modeled {:.1} FPS (routing weight)",
            e.target.name,
            e.accelerator.mode.name(),
            e.accelerator.precision,
            e.weight
        );
    }
    Ok(SimEngine::from_plan(&plan, &g, max_batch)?
        .into_iter()
        .map(|e| EngineSpec::Sim(e.with_time_scale(time_scale)))
        .collect())
}

/// `--classes` → the SLO table (empty = the server's single best-effort
/// default).
fn classes_arg(args: &Args) -> tvm_fpga_flow::Result<Vec<tvm_fpga_flow::coordinator::SloClass>> {
    match args.opt("classes") {
        Some(spec) => slo::parse_classes(spec),
        None => Ok(Vec::new()),
    }
}

/// `--autoscale min,max[,up_us,down_us]` → a hysteresis policy.
fn autoscale_arg(args: &Args) -> tvm_fpga_flow::Result<Option<HysteresisPolicy>> {
    let Some(spec) = args.opt("autoscale") else { return Ok(None) };
    let parts: Vec<&str> = spec.split(',').map(str::trim).collect();
    anyhow::ensure!(
        parts.len() == 2 || parts.len() == 4,
        "--autoscale wants min,max or min,max,up_us,down_us (got {spec:?})"
    );
    let num = |s: &str| {
        s.parse::<u64>().map_err(|_| anyhow::anyhow!("bad --autoscale component {s:?}"))
    };
    let (min, max) = (num(parts[0])? as usize, num(parts[1])? as usize);
    let (up_us, down_us) =
        if parts.len() == 4 { (num(parts[2])?, num(parts[3])?) } else { (5_000, 500) };
    anyhow::ensure!(min >= 1 && max >= min, "--autoscale needs 1 <= min <= max");
    Ok(Some(HysteresisPolicy::new(min, max, up_us, down_us)))
}

fn cmd_serve(args: &Args) -> tvm_fpga_flow::Result<()> {
    let name = args.opt_or("net", "lenet5").to_string();
    // `--workers` is the pre-replica name for the same knob.
    let replicas: usize = args
        .opt_parse("replicas")
        .or_else(|| args.opt_parse("workers"))
        .unwrap_or(2)
        .max(1);
    let max_batch: usize = args.opt_parse("max-batch").unwrap_or(8).max(1);
    let max_delay_us: u64 = args.opt_parse("max-delay-us").unwrap_or(2000);
    let queue_capacity: usize = args.opt_parse("queue-capacity").unwrap_or(1024);
    let time_scale: f64 = args.opt_parse("time-scale").unwrap_or(1.0);
    let engine = args.opt_or("engine", "sim");

    let specs: Vec<EngineSpec> = match engine {
        "sim" => sim_fleet(args, replicas, max_batch, time_scale)?,
        // Empty spec list = the legacy homogeneous PJRT fleet.
        "pjrt" => {
            anyhow::ensure!(
                precision_arg(args)?.is_none(),
                "--precision only applies to the sim engine (PJRT runs the fp32 artifacts)"
            );
            Vec::new()
        }
        other => anyhow::bail!("unknown --engine {other} (sim|pjrt)"),
    };

    let server = InferenceServer::start(ServerConfig {
        network: name.clone(),
        workers: replicas,
        max_batch,
        max_wait: std::time::Duration::from_micros(max_delay_us),
        queue_capacity,
        replicas: specs,
        classes: classes_arg(args)?,
        autoscale: autoscale_arg(args)?,
        ..Default::default()
    })?;

    let requests: usize = args.opt_parse("requests").unwrap_or(256);
    let data = tvm_fpga_flow::data::for_network(&name, requests.min(512), 1)
        .ok_or_else(|| anyhow::anyhow!("no data generator for {name}"))?;
    let t0 = std::time::Instant::now();
    if let Some(path) = args.opt("trace") {
        // Replay a recorded trace (open-loop, trace-paced) instead of the
        // closed-loop synthetic driver.
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read trace {path}: {e}"))?;
        let trace = tvm_fpga_flow::coordinator::loadgen::LoadTrace::parse(&text)?
            .scaled(args.opt_parse("scale").unwrap_or(1.0));
        let frames: Vec<Vec<f32>> = (0..data.frames()).map(|i| data.frame(i).to_vec()).collect();
        let report = tvm_fpga_flow::coordinator::loadgen::replay(&server, &trace, &frames);
        print!("{}", report.render());
    } else {
        let mut pending = std::collections::VecDeque::new();
        for i in 0..requests {
            let frame = data.frame(i % data.frames()).to_vec();
            let mut frame = Some(frame);
            loop {
                match server.infer_async(frame.take().expect("frame present")) {
                    Ok(rx) => {
                        pending.push_back(rx);
                        break;
                    }
                    // Backpressure: drain one in-flight response, then retry.
                    Err(e)
                        if matches!(
                            e.downcast_ref::<ServerError>(),
                            Some(ServerError::Overloaded { .. })
                        ) =>
                    {
                        let rx = pending.pop_front().ok_or(e)?;
                        rx.recv().map_err(|_| anyhow::anyhow!("dropped"))??;
                        frame = Some(data.frame(i % data.frames()).to_vec());
                    }
                    Err(e) => return Err(e),
                }
            }
        }
        for rx in pending {
            rx.recv().map_err(|_| anyhow::anyhow!("dropped"))??;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    let stats = server.shutdown();

    println!(
        "{} requests completed, {} replica(s) ({} active), max_batch {max_batch}: {:.1} req/s",
        stats.completed,
        stats.replicas.len(),
        stats.active_replicas,
        stats.completed as f64 / dt
    );
    println!(
        "latency: p50 {}µs  p99 {}µs   queued: p50 {}µs  p99 {}µs   shed: {} overload + {} deadline",
        stats.p50_us.unwrap_or(0),
        stats.p99_us.unwrap_or(0),
        stats.queue_p50_us.unwrap_or(0),
        stats.queue_p99_us.unwrap_or(0),
        stats.rejected,
        stats.deadline_rejected
    );
    println!(
        "batches: {} (mean size {:.2})  histogram: {}",
        stats.batches,
        stats.mean_batch_size(),
        stats.batch_hist_render()
    );
    if stats.classes.len() > 1 {
        for (i, c) in stats.classes.iter().enumerate() {
            println!(
                "  class {i} {:<12} completed {:>6}  shed {:>5}  p99 {}µs",
                c.name,
                c.completed,
                c.shed_total(),
                c.p99_us.unwrap_or(0)
            );
        }
    }
    for r in &stats.replicas {
        println!(
            "  {:<24} {:>6} batches {:>7} frames  occupancy {:>5.1}%",
            r.name,
            r.batches,
            r.frames,
            r.occupancy * 100.0
        );
    }
    Ok(())
}

fn cmd_loadgen(args: &Args) -> tvm_fpga_flow::Result<()> {
    use tvm_fpga_flow::coordinator::loadgen::{self, LoadTrace};

    let name = args.opt_or("net", "lenet5").to_string();
    let replicas: usize = args.opt_parse("replicas").unwrap_or(2).max(1);
    let max_batch: usize = args.opt_parse("max-batch").unwrap_or(8).max(1);
    let max_delay_us: u64 = args.opt_parse("max-delay-us").unwrap_or(2000);
    let queue_capacity: usize = args.opt_parse("queue-capacity").unwrap_or(64);
    let time_scale: f64 = args.opt_parse("time-scale").unwrap_or(1.0);
    let classes =
        slo::parse_classes(args.opt_or("classes", "gold=20ms,silver=100ms,bulk=best-effort"))?;
    let mix = slo::parse_mix(args.opt_or("mix", "1,3,6"))?;
    anyhow::ensure!(
        mix.len() <= classes.len(),
        "--mix names {} classes but the table has {}",
        mix.len(),
        classes.len()
    );
    let seed: u64 = args.opt_parse("seed").unwrap_or(42);

    let trace = match args.opt("trace") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("cannot read trace {path}: {e}"))?;
            LoadTrace::parse(&text)?
        }
        None => {
            let requests: usize = args.opt_parse("requests").unwrap_or(512);
            match args.opt_or("pattern", "bursty") {
                "bursty" => LoadTrace::bursty(
                    requests,
                    args.opt_parse("burst").unwrap_or(64),
                    args.opt_parse("period-us").unwrap_or(20_000),
                    &mix,
                    seed,
                ),
                "diurnal" => LoadTrace::diurnal(
                    requests,
                    args.opt_parse("span-us").unwrap_or(1_000_000),
                    args.opt_parse("cycles").unwrap_or(2),
                    &mix,
                    seed,
                ),
                other => anyhow::bail!("unknown --pattern {other} (bursty|diurnal)"),
            }
        }
    }
    .scaled(args.opt_parse("scale").unwrap_or(1.0));
    if let Some(path) = args.opt("save-trace") {
        std::fs::write(path, trace.to_json().to_string())?;
        eprintln!("trace: {} event(s) written to {path}", trace.events.len());
    }

    let specs = sim_fleet(args, replicas, max_batch, time_scale)?;
    let server = InferenceServer::start(ServerConfig {
        network: name.clone(),
        workers: replicas,
        max_batch,
        max_wait: std::time::Duration::from_micros(max_delay_us),
        queue_capacity,
        replicas: specs,
        classes,
        autoscale: autoscale_arg(args)?,
        ..Default::default()
    })?;

    let data = tvm_fpga_flow::data::for_network(&name, 64, 1)
        .ok_or_else(|| anyhow::anyhow!("no data generator for {name}"))?;
    let frames: Vec<Vec<f32>> = (0..data.frames()).map(|i| data.frame(i).to_vec()).collect();
    println!(
        "replaying {} event(s) ({:.0} rps offered) against {replicas} replica(s)...",
        trace.events.len(),
        trace.offered_rps()
    );
    let mut report = loadgen::replay(&server, &trace, &frames);
    // Fold in the post-shutdown snapshot: the uptime denominator freezes
    // and every in-flight response is accounted.
    report.snapshot = server.shutdown();
    if tvm_fpga_flow::obs::enabled() {
        report.export_metrics(tvm_fpga_flow::obs::global_metrics());
    }
    if let Some(path) = args.opt("out") {
        std::fs::write(path, report.to_json().to_string())?;
        eprintln!("report: written to {path}");
    }
    if args.has_flag("json") {
        println!("{}", report.to_json().to_string());
    } else {
        print!("{}", report.render());
        for r in &report.snapshot.replicas {
            println!(
                "  {:<24} {:>6} batches {:>7} frames  occupancy {:>5.1}%",
                r.name,
                r.batches,
                r.frames,
                r.occupancy * 100.0
            );
        }
    }
    Ok(())
}
