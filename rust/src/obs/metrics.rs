//! Typed metrics registry: counters, gauges and histograms with a
//! Prometheus text exporter and a JSON snapshot for `report_json`.
//!
//! Handles are `Arc`-shared and lock-free to update (atomics), so pool
//! workers and replica threads increment concurrently without contending
//! on the registry lock — the registry is only locked to register or
//! export. Names follow Prometheus conventions
//! (`flow_passes_applied_total`, `serve_queue_latency_us`); the catalog
//! lives in docs/OBSERVABILITY.md.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

/// Monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins float gauge (stored as `f64` bits in an atomic).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Fixed-bucket histogram. `bounds` are inclusive upper bounds; one
/// implicit `+Inf` overflow bucket catches everything beyond the last
/// bound, so no observation is ever dropped.
#[derive(Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    /// `bounds.len() + 1` buckets; the last is the overflow bucket.
    buckets: Vec<AtomicU64>,
    /// Sum of observed values, as `f64` bits (CAS loop — observations
    /// race but never lose updates).
    sum_bits: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    pub fn new(bounds: &[f64]) -> Histogram {
        let mut b = bounds.to_vec();
        b.sort_by(f64::total_cmp);
        b.dedup();
        let buckets = (0..=b.len()).map(|_| AtomicU64::new(0)).collect();
        Histogram { bounds: b, buckets, sum_bits: AtomicU64::new(0f64.to_bits()), count: AtomicU64::new(0) }
    }

    pub fn observe(&self, v: f64) {
        self.observe_n(v, 1);
    }

    /// Record `n` observations of the same value (bulk import of an
    /// already-aggregated histogram, e.g. [`crate::metrics::BatchHistogram`]).
    pub fn observe_n(&self, v: f64, n: u64) {
        if n == 0 {
            return;
        }
        let idx = self.bounds.partition_point(|&b| b < v);
        self.buckets[idx].fetch_add(n, Ordering::Relaxed);
        self.count.fetch_add(n, Ordering::Relaxed);
        let add = v * n as f64;
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + add).to_bits();
            match self.sum_bits.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    /// Per-bucket (non-cumulative) counts; the final entry is the `+Inf`
    /// overflow bucket.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }
}

#[derive(Debug, Clone)]
enum Handle {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

impl Handle {
    fn type_name(&self) -> &'static str {
        match self {
            Handle::Counter(_) => "counter",
            Handle::Gauge(_) => "gauge",
            Handle::Histogram(_) => "histogram",
        }
    }
}

#[derive(Debug, Clone)]
struct Entry {
    help: String,
    handle: Handle,
}

/// A named collection of metrics. [`crate::obs::global_metrics`] is the
/// process-wide instance; tests build private ones.
#[derive(Debug, Default)]
pub struct Registry {
    inner: Mutex<BTreeMap<String, Entry>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    fn register(&self, name: &str, help: &str, make: impl FnOnce() -> Handle) -> Handle {
        let mut m = self.inner.lock().unwrap();
        let e = m.entry(name.to_string()).or_insert_with(|| Entry { help: help.to_string(), handle: make() });
        e.handle.clone()
    }

    /// Get-or-register a counter. Panics if `name` is already registered
    /// as a different metric type (a programming error, not a data error).
    pub fn counter(&self, name: &str, help: &str) -> Arc<Counter> {
        match self.register(name, help, || Handle::Counter(Arc::new(Counter::default()))) {
            Handle::Counter(c) => c,
            other => panic!("metric {name} is a {}, not a counter", other.type_name()),
        }
    }

    /// Get-or-register a gauge.
    pub fn gauge(&self, name: &str, help: &str) -> Arc<Gauge> {
        match self.register(name, help, || Handle::Gauge(Arc::new(Gauge::default()))) {
            Handle::Gauge(g) => g,
            other => panic!("metric {name} is a {}, not a gauge", other.type_name()),
        }
    }

    /// Get-or-register a histogram (bounds are fixed by the first
    /// registration).
    pub fn histogram(&self, name: &str, help: &str, bounds: &[f64]) -> Arc<Histogram> {
        match self.register(name, help, || Handle::Histogram(Arc::new(Histogram::new(bounds)))) {
            Handle::Histogram(h) => h,
            other => panic!("metric {name} is a {}, not a histogram", other.type_name()),
        }
    }

    /// Register-and-set in one call (export paths that write snapshots).
    pub fn set_gauge(&self, name: &str, help: &str, v: f64) {
        self.gauge(name, help).set(v);
    }

    pub fn is_empty(&self) -> bool {
        self.inner.lock().unwrap().is_empty()
    }

    /// Drop every registered metric (test isolation; existing handles
    /// keep working but are no longer exported).
    pub fn clear(&self) {
        self.inner.lock().unwrap().clear();
    }

    /// Flat name → value view of counters and gauges (histograms expand
    /// to `_count` and `_sum`). Tests diff two snapshots to assert exact
    /// deltas without assuming a pristine registry.
    pub fn snapshot(&self) -> BTreeMap<String, f64> {
        let m = self.inner.lock().unwrap();
        let mut out = BTreeMap::new();
        for (name, e) in m.iter() {
            match &e.handle {
                Handle::Counter(c) => {
                    out.insert(name.clone(), c.get() as f64);
                }
                Handle::Gauge(g) => {
                    out.insert(name.clone(), g.get());
                }
                Handle::Histogram(h) => {
                    out.insert(format!("{name}_count"), h.count() as f64);
                    out.insert(format!("{name}_sum"), h.sum());
                }
            }
        }
        out
    }

    /// Prometheus text exposition format (`# HELP` / `# TYPE` / samples;
    /// histograms render cumulative `_bucket{le=...}` series).
    pub fn render_prometheus(&self) -> String {
        let m = self.inner.lock().unwrap();
        let mut out = String::new();
        for (name, e) in m.iter() {
            let _ = writeln!(out, "# HELP {name} {}", e.help.replace('\n', " "));
            let _ = writeln!(out, "# TYPE {name} {}", e.handle.type_name());
            match &e.handle {
                Handle::Counter(c) => {
                    let _ = writeln!(out, "{name} {}", c.get());
                }
                Handle::Gauge(g) => {
                    let _ = writeln!(out, "{name} {}", fmt_f64(g.get()));
                }
                Handle::Histogram(h) => {
                    let mut cum = 0u64;
                    let counts = h.bucket_counts();
                    for (i, b) in h.bounds().iter().enumerate() {
                        cum += counts[i];
                        let _ = writeln!(out, "{name}_bucket{{le=\"{}\"}} {cum}", fmt_f64(*b));
                    }
                    cum += counts.last().copied().unwrap_or(0);
                    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {cum}");
                    let _ = writeln!(out, "{name}_sum {}", fmt_f64(h.sum()));
                    let _ = writeln!(out, "{name}_count {}", h.count());
                }
            }
        }
        out
    }

    /// JSON snapshot (the `observability.metrics` section of
    /// `report_json`).
    pub fn to_json(&self) -> Json {
        let m = self.inner.lock().unwrap();
        let mut root = BTreeMap::new();
        for (name, e) in m.iter() {
            let mut o = BTreeMap::new();
            o.insert("type".into(), Json::Str(e.handle.type_name().into()));
            o.insert("help".into(), Json::Str(e.help.clone()));
            match &e.handle {
                Handle::Counter(c) => {
                    o.insert("value".into(), Json::Num(c.get() as f64));
                }
                Handle::Gauge(g) => {
                    o.insert("value".into(), Json::Num(g.get()));
                }
                Handle::Histogram(h) => {
                    o.insert("bounds".into(), Json::Arr(h.bounds().iter().map(|b| Json::Num(*b)).collect()));
                    o.insert(
                        "buckets".into(),
                        Json::Arr(h.bucket_counts().iter().map(|c| Json::Num(*c as f64)).collect()),
                    );
                    o.insert("sum".into(), Json::Num(h.sum()));
                    o.insert("count".into(), Json::Num(h.count() as f64));
                }
            }
            root.insert(name.clone(), Json::Obj(o));
        }
        Json::Obj(root)
    }
}

/// Shortest float form that still round-trips integers without a dot
/// (Prometheus accepts both; integers keep the text diff-friendly).
fn fmt_f64(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_roundtrip() {
        let r = Registry::new();
        let c = r.counter("flow_tests_total", "test counter");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // Re-registration returns the same underlying handle.
        assert_eq!(r.counter("flow_tests_total", "ignored").get(), 5);
        r.set_gauge("flow_gauge", "g", 2.5);
        assert_eq!(r.gauge("flow_gauge", "g").get(), 2.5);
        let snap = r.snapshot();
        assert_eq!(snap["flow_tests_total"], 5.0);
        assert_eq!(snap["flow_gauge"], 2.5);
    }

    #[test]
    fn histogram_buckets_and_overflow() {
        let h = Histogram::new(&[1.0, 5.0, 10.0]);
        h.observe(0.5); // bucket le=1
        h.observe(1.0); // le=1 (inclusive upper bound)
        h.observe(3.0); // le=5
        h.observe(100.0); // overflow (+Inf)
        assert_eq!(h.bucket_counts(), vec![2, 1, 0, 1]);
        assert_eq!(h.count(), 4);
        assert!((h.sum() - 104.5).abs() < 1e-9);
    }

    #[test]
    fn histogram_bulk_observe() {
        let h = Histogram::new(&[2.0, 4.0]);
        h.observe_n(1.0, 3);
        h.observe_n(9.0, 2);
        h.observe_n(1.0, 0); // no-op
        assert_eq!(h.bucket_counts(), vec![3, 0, 2]);
        assert_eq!(h.count(), 5);
        assert!((h.sum() - 21.0).abs() < 1e-9);
    }

    #[test]
    fn prometheus_text_format() {
        let r = Registry::new();
        r.counter("a_total", "a counter").add(3);
        r.set_gauge("b_gauge", "a gauge", 1.5);
        let h = r.histogram("c_us", "a histogram", &[1.0, 2.0]);
        h.observe(0.5);
        h.observe(5.0);
        let text = r.render_prometheus();
        assert!(text.contains("# TYPE a_total counter\na_total 3\n"), "{text}");
        assert!(text.contains("# TYPE b_gauge gauge\nb_gauge 1.5\n"), "{text}");
        assert!(text.contains("c_us_bucket{le=\"1\"} 1\n"), "{text}");
        assert!(text.contains("c_us_bucket{le=\"+Inf\"} 2\n"), "{text}");
        assert!(text.contains("c_us_sum 5.5\n"), "{text}");
        assert!(text.contains("c_us_count 2\n"), "{text}");
    }

    #[test]
    fn json_snapshot_shape() {
        let r = Registry::new();
        r.counter("x_total", "x").inc();
        r.histogram("h_us", "h", &[1.0]).observe(3.0);
        let j = crate::util::json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(j.get("x_total").unwrap().get("value").unwrap().as_u64(), Some(1));
        let h = j.get("h_us").unwrap();
        assert_eq!(h.get("type").unwrap().as_str(), Some("histogram"));
        assert_eq!(h.get("count").unwrap().as_u64(), Some(1));
        assert_eq!(h.get("buckets").unwrap().idx(1).unwrap().as_u64(), Some(1));
    }

    #[test]
    fn clear_empties_the_registry() {
        let r = Registry::new();
        r.counter("x_total", "x").inc();
        assert!(!r.is_empty());
        r.clear();
        assert!(r.is_empty());
        assert_eq!(r.render_prometheus(), "");
    }
}
